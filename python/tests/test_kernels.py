"""L1 tests: Pallas Gram-matrix kernel vs the pure-jnp oracle.

hypothesis sweeps shapes (including tile-divisibility edge cases), dtypes and
hyper-parameters; every case is checked with assert_allclose against ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import kernel_matrix as km
from compile.kernels.ref import gram_matrix_ref

KINDS = list(km.KERNELS)


def rand(shape, seed, dtype=np.float32, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(dtype)


@pytest.mark.parametrize("kind", KINDS)
def test_matches_ref_basic(kind):
    x, z = rand((32, 8), 1), rand((16, 8), 2)
    got = km.gram_matrix(x, z, kind=kind)
    want = gram_matrix_ref(x, z, kind=kind)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind", KINDS)
def test_matches_ref_artifact_shapes(kind):
    """Exactly the shapes baked into the AOT artifacts."""
    x, q = rand((256, 8), 3), rand((64, 8), 4)
    np.testing.assert_allclose(
        km.gram_matrix(x, x, kind=kind), gram_matrix_ref(x, x, kind=kind),
        rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        km.gram_matrix(q, x, kind=kind), gram_matrix_ref(q, x, kind=kind),
        rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 96),
    n=st.integers(1, 96),
    d=st.integers(1, 24),
    kind=st.sampled_from(KINDS),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_hypothesis_shapes(m, n, d, kind, seed):
    """Arbitrary (non-tile-aligned) shapes must still agree with the oracle."""
    x, z = rand((m, d), seed), rand((n, d), seed + 1)
    got = km.gram_matrix(x, z, kind=kind)
    want = gram_matrix_ref(x, z, kind=kind)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    gamma=st.floats(1e-3, 8.0),
    coef0=st.floats(-2.0, 2.0),
    kind=st.sampled_from(KINDS),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_hypothesis_params(gamma, coef0, kind, seed):
    x, z = rand((40, 8), seed, scale=0.5), rand((24, 8), seed + 7, scale=0.5)
    got = km.gram_matrix(x, z, kind=kind, gamma=gamma, coef0=coef0)
    want = gram_matrix_ref(x, z, kind=kind, gamma=gamma, coef0=coef0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from([np.float32, np.float64, np.int32]))
def test_dtype_coercion(seed, dtype):
    """Inputs of any numeric dtype are computed in f32 like the oracle."""
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((16, 8)) * 3).astype(dtype)
    z = (rng.standard_normal((8, 8)) * 3).astype(dtype)
    got = km.gram_matrix(x, z, kind="rbf", gamma=0.1)
    assert got.dtype == np.float32
    np.testing.assert_allclose(
        got, gram_matrix_ref(x, z, kind="rbf", gamma=0.1),
        rtol=1e-5, atol=1e-5)


def test_rbf_properties():
    """RBF Gram: symmetric, unit diagonal, values in (0, 1]."""
    x = rand((48, 8), 11, scale=0.4)
    k = np.asarray(km.gram_matrix(x, x, kind="rbf", gamma=0.5))
    np.testing.assert_allclose(k, k.T, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.diagonal(k), 1.0, rtol=1e-5)
    assert (k > 0).all() and (k <= 1.0 + 1e-6).all()


def test_tile_picker():
    assert km._pick_tile(256, 128) == 128
    assert km._pick_tile(100, 128) == 100
    assert km._pick_tile(96, 128) == 96
    assert km._pick_tile(7, 4) == 1   # prime: falls back to 1
    assert km._pick_tile(12, 8) == 6


def test_feature_dim_mismatch_raises():
    with pytest.raises(ValueError, match="feature dims differ"):
        km.gram_matrix(rand((4, 3), 0), rand((4, 5), 1))


def test_unknown_kernel_raises():
    with pytest.raises(ValueError, match="unknown kernel"):
        km.gram_matrix(rand((4, 4), 0), rand((4, 4), 1), kind="poly")


def test_vmem_budget():
    """Default tiles stay far below a TPU core's ~16 MiB VMEM."""
    bytes_used = km.vmem_tile_bytes(km.TILE_M, km.TILE_N, 128)
    assert bytes_used < 16 * 1024 * 1024 / 8  # < 1/8 of VMEM
