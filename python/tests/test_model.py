"""L2 tests: dual-SVM trainer and predictor (the functions AOT ships to Rust)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model


def blobs(n_per_class, d=model.N_FEATURES, seed=0, centers=(0.25, 0.75),
          sigma=0.08):
    """Two padded Gaussian blobs in the unit cube, labels +1 / -1."""
    rng = np.random.default_rng(seed)
    n = model.N_TRAIN
    x = np.zeros((n, d), np.float32)
    y = np.zeros(n, np.float32)
    mask = np.zeros(n, np.float32)
    m = n_per_class
    x[:m] = rng.normal(centers[0], sigma, (m, d))
    y[:m] = 1.0
    x[m:2 * m] = rng.normal(centers[1], sigma, (m, d))
    y[m:2 * m] = -1.0
    mask[:2 * m] = 1.0
    return x, y, mask


def predict_all(x_query, x, y, params, mask, kind, use_pallas=True):
    """Predict in artifact-sized batches, like the Rust predictor does."""
    b = model.N_PREDICT_BATCH
    out = []
    n = x_query.shape[0]
    padded = np.zeros(((n + b - 1) // b * b, x_query.shape[1]), np.float32)
    padded[:n] = x_query
    for i in range(0, padded.shape[0], b):
        s = model.svm_predict(padded[i:i + b], x, y, params.alpha, mask,
                              params.bias, kind=kind, use_pallas=use_pallas)
        out.append(np.asarray(s))
    return np.concatenate(out)[:n]


@pytest.mark.parametrize("kind", ["linear", "rbf"])
def test_separable_blobs_high_accuracy(kind):
    x, y, mask = blobs(100, seed=3)
    params = model.svm_train(x, y, mask, kind=kind)
    s = predict_all(x[:200], x, y, params, mask, kind)
    acc = np.mean((s > 0) == (y[:200] > 0))
    assert acc >= 0.99, f"{kind}: acc={acc}"


def test_sigmoid_kernel_degrades():
    """The paper's Table 5: sigmoid is the worst kernel (acc 0.57, F1_1 = 0).

    Our reproduction should also show sigmoid clearly below RBF — the
    non-PSD sigmoid Gram breaks dual concavity.
    """
    x, y, mask = blobs(100, seed=3)
    p_rbf = model.svm_train(x, y, mask, kind="rbf")
    p_sig = model.svm_train(x, y, mask, kind="sigmoid")
    acc_rbf = np.mean(
        (predict_all(x[:200], x, y, p_rbf, mask, "rbf") > 0) == (y[:200] > 0))
    acc_sig = np.mean(
        (predict_all(x[:200], x, y, p_sig, mask, "sigmoid") > 0)
        == (y[:200] > 0))
    assert acc_rbf > acc_sig + 0.2


@pytest.mark.parametrize("kind", ["linear", "rbf", "sigmoid"])
def test_dual_feasibility(kind):
    """Box constraint 0 <= alpha <= C and padded rows pinned to 0."""
    x, y, mask = blobs(80, seed=5)
    params = model.svm_train(x, y, mask, kind=kind)
    a = np.asarray(params.alpha)
    assert (a >= -1e-7).all()
    assert (a <= model.DEFAULT_C + 1e-6).all()
    assert np.abs(a[mask == 0]).max() == 0.0


def test_padding_rows_do_not_affect_model():
    """Garbage in masked rows must not change alpha on real rows."""
    x, y, mask = blobs(60, seed=9)
    x2 = x.copy()
    rng = np.random.default_rng(1)
    x2[mask == 0] = rng.normal(5.0, 3.0, (int((mask == 0).sum()),
                                          x.shape[1])).astype(np.float32)
    p1 = model.svm_train(x, y, mask, kind="rbf", use_pallas=False)
    p2 = model.svm_train(x2, y, mask, kind="rbf", use_pallas=False)
    np.testing.assert_allclose(np.asarray(p1.alpha)[mask == 1],
                               np.asarray(p2.alpha)[mask == 1],
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind", ["linear", "rbf", "sigmoid"])
def test_pallas_ref_train_parity(kind):
    x, y, mask = blobs(100, seed=3)
    p_pal = model.svm_train(x, y, mask, kind=kind, use_pallas=True)
    p_ref = model.svm_train(x, y, mask, kind=kind, use_pallas=False)
    np.testing.assert_allclose(np.asarray(p_pal.alpha),
                               np.asarray(p_ref.alpha), rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(float(p_pal.bias), float(p_ref.bias),
                               rtol=5e-3, atol=5e-3)


def test_pallas_ref_predict_parity():
    x, y, mask = blobs(100, seed=3)
    p = model.svm_train(x, y, mask, kind="rbf", use_pallas=False)
    q = x[:model.N_PREDICT_BATCH]
    s_pal = model.svm_predict(q, x, y, p.alpha, mask, p.bias, kind="rbf",
                              use_pallas=True)
    s_ref = model.svm_predict(q, x, y, p.alpha, mask, p.bias, kind="rbf",
                              use_pallas=False)
    np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(20, 120))
def test_hypothesis_blob_sweep(seed, m):
    """Random blob sizes/seeds: RBF stays accurate and feasible."""
    x, y, mask = blobs(m, seed=seed)
    params = model.svm_train(x, y, mask, kind="rbf", use_pallas=False)
    a = np.asarray(params.alpha)
    assert (a >= -1e-7).all() and (a <= model.DEFAULT_C + 1e-6).all()
    s = predict_all(x[:2 * m], x, y, params, mask, "rbf", use_pallas=False)
    acc = np.mean((s > 0) == (y[:2 * m] > 0))
    assert acc >= 0.95


def test_overlapping_blobs_still_learn():
    """Non-separable data: should beat chance comfortably, not collapse."""
    x, y, mask = blobs(100, seed=4, centers=(0.42, 0.58), sigma=0.12)
    params = model.svm_train(x, y, mask, kind="rbf", use_pallas=False)
    s = predict_all(x[:200], x, y, params, mask, "rbf", use_pallas=False)
    acc = np.mean((s > 0) == (y[:200] > 0))
    assert acc >= 0.8


def test_all_one_class_degenerates_gracefully():
    """Single-class training data must not produce NaNs."""
    x, y, mask = blobs(50, seed=6)
    y[:] = np.where(mask > 0, 1.0, 0.0)
    params = model.svm_train(x, y, mask, kind="rbf", use_pallas=False)
    assert np.isfinite(np.asarray(params.alpha)).all()
    assert np.isfinite(float(params.bias))
    s = predict_all(x[:64], x, y, params, mask, "rbf", use_pallas=False)
    assert np.isfinite(s).all()
