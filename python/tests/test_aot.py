"""AOT pipeline tests: HLO-text emission, manifest, and determinism."""

import os
import subprocess
import sys

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    for kind in ("rbf", "linear"):
        for stage, lower in (("train", aot.lower_train),
                             ("predict", aot.lower_predict)):
            text = aot.to_hlo_text(lower(kind))
            (out / f"svm_{stage}_{kind}.hlo.txt").write_text(text)
    aot.write_manifest(str(out))
    return out


def test_hlo_text_structure(emitted):
    train = (emitted / "svm_train_rbf.hlo.txt").read_text()
    predict = (emitted / "svm_predict_rbf.hlo.txt").read_text()
    for text in (train, predict):
        assert "ENTRY" in text, "not HLO text"
        assert "HloModule" in text
    # train: 3 params (x, y, mask); predict: 6 params — count the Arg_k
    # parameters of the ENTRY computation only (inner while/fusion bodies
    # have their own numbering).
    def entry_arity(text):
        header = next(l for l in text.splitlines() if "entry_computation_layout" in l)
        sig = header.split("entry_computation_layout={(", 1)[1].split(")->")[0]
        return sig.count("f32[")

    assert entry_arity(train) == 3
    assert entry_arity(predict) == 6
    # fixed shapes baked in
    assert f"f32[{model.N_TRAIN},{model.N_FEATURES}]" in train
    assert f"f32[{model.N_PREDICT_BATCH},{model.N_FEATURES}]" in predict


def test_emission_is_deterministic():
    a = aot.to_hlo_text(aot.lower_predict("rbf"))
    b = aot.to_hlo_text(aot.lower_predict("rbf"))
    assert a == b


def test_kernel_variants_differ():
    rbf = aot.to_hlo_text(aot.lower_predict("rbf"))
    lin = aot.to_hlo_text(aot.lower_predict("linear"))
    assert rbf != lin
    assert "exponential" in rbf  # RBF exp() survives lowering
    assert "exponential" not in lin


def test_manifest_contents(emitted):
    text = (emitted / "manifest.txt").read_text()
    entries = dict(
        line.split("=", 1) for line in text.splitlines()
        if line and not line.startswith("#"))
    assert int(entries["n_train"]) == model.N_TRAIN
    assert int(entries["n_features"]) == model.N_FEATURES
    assert int(entries["n_predict_batch"]) == model.N_PREDICT_BATCH
    assert float(entries["gamma"]) == model.DEFAULT_GAMMA
    assert "rbf" in entries["kernels"].split(",")


def test_cli_emits_all_files(tmp_path):
    """End-to-end `python -m compile.aot` as the Makefile invokes it."""
    out = tmp_path / "arts"
    proc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--kinds", "rbf"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert (out / "svm_train_rbf.hlo.txt").exists()
    assert (out / "svm_predict_rbf.hlo.txt").exists()
    assert (out / "manifest.txt").exists()
