"""L2 — JAX SVM model (train + predict) for the H-SVM-LRU classifier.

The paper trains a two-class SVM ("reused in the future" vs "not reused") on
features extracted from the Hadoop job-history server and consults it on every
cache decision (Algorithm 1). Here the model is written in JAX, with the Gram
matrix computed by the L1 Pallas kernel, and AOT-lowered by aot.py to HLO text
that the Rust coordinator executes through PJRT.

Trainer: projected-gradient ascent on the SVM dual with the augmented-kernel
bias trick.

  maximize  W(a) = sum(a) - 1/2 a^T Q a,   Q = (y y^T) * (K + 1)
  s.t.      0 <= a_i <= C,   a_i = 0 for padded rows (mask_i = 0)

Adding the constant 1 to the kernel folds the bias into the weight vector
(standard "augmented" formulation), which removes the sum(a*y) = 0 equality
constraint, so the feasible set is a box and projection is a clip. The
per-coordinate step 1/Q_ii preconditions the ascent; a fixed number of
lax.fori_loop iterations keeps the lowered HLO free of dynamic shapes.

Everything is fixed-shape: N training rows, D features, B query rows; Rust
pads with mask=0 rows. Hyper-parameters are baked per AOT artifact variant
(one pair of artifacts per kernel function: linear / rbf / sigmoid), matching
the paper's Table 5 kernel-selection experiment.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import kernel_matrix as km
from .kernels.ref import gram_matrix_ref

# AOT artifact shapes (must match rust/src/runtime/artifacts.rs).
N_TRAIN = 256
N_FEATURES = 9
N_PREDICT_BATCH = 64

# Baked hyper-parameters (one artifact family; see aot.py variants).
DEFAULT_C = 4.0
DEFAULT_GAMMA = 0.5
DEFAULT_COEF0 = 0.0
DEFAULT_ITERS = 300


class SvmParams(NamedTuple):
    """Trained dual parameters, as returned by the train artifact."""
    alpha: jax.Array  # (N,) box-constrained dual coefficients
    bias: jax.Array   # () implicit bias sum(alpha * y) from the augmented trick


def _gram(x, z, *, kind, gamma, coef0, use_pallas):
    if use_pallas:
        return km.gram_matrix(x, z, kind=kind, gamma=gamma, coef0=coef0)
    return gram_matrix_ref(x, z, kind=kind, gamma=gamma, coef0=coef0)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "c", "gamma", "coef0", "iters", "use_pallas"))
def svm_train(x, y, mask, *, kind: str = "rbf", c: float = DEFAULT_C,
              gamma: float = DEFAULT_GAMMA, coef0: float = DEFAULT_COEF0,
              iters: int = DEFAULT_ITERS, use_pallas: bool = True) -> SvmParams:
    """Train the dual SVM.

    x: (N, D) f32 normalized features; y: (N,) f32 labels in {-1, +1};
    mask: (N,) f32 in {0, 1}, 0 marks padding rows.
    Returns SvmParams(alpha (N,), bias ()).
    """
    x = x.astype(jnp.float32)
    mask = mask.astype(jnp.float32)
    y = y.astype(jnp.float32) * mask
    k = _gram(x, x, kind=kind, gamma=gamma, coef0=coef0,
              use_pallas=use_pallas)
    # Augmented kernel folds the bias in; padded rows are neutralized through
    # y (zeroed above), so Q has zero rows/cols at padding.
    q = (y[:, None] * y[None, :]) * (k + 1.0)
    # Global step from a power-iteration estimate of lambda_max(Q): the dual
    # objective is a concave quadratic, so ascent with step 1/lambda_max is
    # monotone (a per-coordinate 1/Q_ii Jacobi step oscillates on the
    # near-rank-one Q that RBF produces for tightly clustered features).
    def power_body(_, v):
        w = q @ v
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-12)

    v0 = mask / jnp.maximum(jnp.linalg.norm(mask), 1e-12)
    v = jax.lax.fori_loop(0, 16, power_body, v0)
    lam_max = jnp.maximum(jnp.vdot(v, q @ v), 1e-6)
    # Nesterov-accelerated projected gradient (FISTA): the plain 1/lam step
    # crawls on ill-conditioned Q; acceleration gets within float tolerance
    # of the optimum in the fixed iteration budget.
    step = 1.0 / (1.05 * lam_max)

    def body(i, carry):
        alpha, z_prev, t = carry
        grad = 1.0 - q @ z_prev
        alpha_new = jnp.clip(z_prev + step * grad, 0.0, c) * mask
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        z_new = alpha_new + ((t - 1.0) / t_new) * (alpha_new - alpha)
        return alpha_new, z_new * mask, t_new

    alpha0 = jnp.zeros_like(y)
    alpha, _, _ = jax.lax.fori_loop(
        0, iters, body, (alpha0, alpha0, jnp.float32(1.0)))
    bias = jnp.sum(alpha * y)
    return SvmParams(alpha=alpha, bias=bias)


@functools.partial(
    jax.jit, static_argnames=("kind", "gamma", "coef0", "use_pallas"))
def svm_predict(q, x, y, alpha, mask, bias, *, kind: str = "rbf",
                gamma: float = DEFAULT_GAMMA, coef0: float = DEFAULT_COEF0,
                use_pallas: bool = True):
    """Decision scores for a batch of queries.

    q: (B, D) queries; x/y/alpha/mask: training set and trained duals;
    bias: () from svm_train. Returns (B,) f32 scores; class = sign(score),
    class 1 ("reused in the future") iff score > 0.
    """
    q = q.astype(jnp.float32)
    y = y.astype(jnp.float32) * mask.astype(jnp.float32)
    kq = _gram(q, x.astype(jnp.float32), kind=kind, gamma=gamma, coef0=coef0,
               use_pallas=use_pallas)  # (B, N)
    return kq @ (alpha * y) + bias


def train_fn_for_aot(kind: str, *, c: float = DEFAULT_C,
                     gamma: float = DEFAULT_GAMMA, coef0: float = DEFAULT_COEF0,
                     iters: int = DEFAULT_ITERS):
    """Concrete (x, y, mask) -> (alpha, bias) function for jax.jit().lower()."""
    def fn(x, y, mask):
        params = svm_train(x, y, mask, kind=kind, c=c, gamma=gamma,
                           coef0=coef0, iters=iters, use_pallas=True)
        return (params.alpha, params.bias)
    return fn


def predict_fn_for_aot(kind: str, *, gamma: float = DEFAULT_GAMMA,
                       coef0: float = DEFAULT_COEF0):
    """Concrete (q, x, y, alpha, mask, bias) -> (scores,) function for AOT."""
    def fn(q, x, y, alpha, mask, bias):
        return (svm_predict(q, x, y, alpha, mask, bias, kind=kind,
                            gamma=gamma, coef0=coef0, use_pallas=True),)
    return fn
