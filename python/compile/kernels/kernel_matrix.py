"""L1 — Pallas Gram-matrix kernels for the H-SVM-LRU classifier.

The compute hot-spot of the paper's SVM (train *and* predict) is the kernel
(Gram) matrix K[i, j] = k(x_i, z_j) over the feature vectors of data blocks.
This module implements it as a tiled Pallas kernel:

  * the inner product block X_tile @ Z_tile^T is MXU-shaped (a small matmul),
  * the elementwise kernel transform (exp / tanh / identity) is VPU work,
  * BlockSpec tiles keep one (TM, D) x (TN, D) pair plus the (TM, TN) output
    tile resident in VMEM.

TPU hardware adaptation (paper is CPU-only; see DESIGN.md §Hardware-Adaptation):
instead of porting a CPU loop we tile for VMEM and feed the MXU with the
squared-distance expansion ||x||^2 - 2 x.z + ||z||^2 so the O(TM*TN*D) work is
a single dot per tile pair.

All kernels are lowered with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls; real-TPU numbers are estimated analytically in
DESIGN.md §9.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Kernel-function identifiers (must match rust/src/svm/kernel.rs).
KERNEL_LINEAR = "linear"
KERNEL_RBF = "rbf"
KERNEL_SIGMOID = "sigmoid"
KERNELS = (KERNEL_LINEAR, KERNEL_RBF, KERNEL_SIGMOID)

# Default tile sizes. TM=TN=128 matches the MXU systolic array edge; for the
# small shapes used by the AOT artifacts (N=256) this still divides evenly.
TILE_M = 128
TILE_N = 128


def _apply_kernel_fn(dots, sq_x, sq_z, kind: str, gamma: float, coef0: float):
    """Elementwise kernel transform applied to a tile of inner products.

    dots: (TM, TN) tile of X @ Z^T
    sq_x: (TM, 1) tile of ||x||^2,  sq_z: (1, TN) tile of ||z||^2
    """
    if kind == KERNEL_LINEAR:
        return dots
    if kind == KERNEL_RBF:
        # ||x - z||^2 = ||x||^2 - 2 x.z + ||z||^2 ; clamp for fp safety.
        sq_dist = jnp.maximum(sq_x - 2.0 * dots + sq_z, 0.0)
        return jnp.exp(-gamma * sq_dist)
    if kind == KERNEL_SIGMOID:
        return jnp.tanh(gamma * dots + coef0)
    raise ValueError(f"unknown kernel kind: {kind!r}")


def _gram_tile_kernel(x_ref, z_ref, o_ref, *, kind: str, gamma: float,
                      coef0: float):
    """Pallas body: one (TM, TN) output tile from (TM, D) and (TN, D) inputs."""
    x = x_ref[...]  # (TM, D) in VMEM
    z = z_ref[...]  # (TN, D) in VMEM
    # MXU-shaped contraction. preferred_element_type pins f32 accumulation so
    # a bf16 input variant keeps full-precision partial sums.
    dots = jax.lax.dot_general(
        x, z,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    sq_x = jnp.sum(x * x, axis=1, keepdims=True)       # (TM, 1), VPU
    sq_z = jnp.sum(z * z, axis=1, keepdims=True).T     # (1, TN), VPU
    o_ref[...] = _apply_kernel_fn(dots, sq_x, sq_z, kind, gamma, coef0)


def _pick_tile(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is <= preferred (tiles must divide)."""
    t = min(preferred, dim)
    while dim % t != 0:
        t -= 1
    return max(t, 1)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "gamma", "coef0", "tile_m", "tile_n", "interpret"))
def gram_matrix(x, z, *, kind: str = KERNEL_RBF, gamma: float = 0.5,
                coef0: float = 0.0, tile_m: int = TILE_M, tile_n: int = TILE_N,
                interpret: bool = True):
    """Compute K[i, j] = k(x_i, z_j) with a tiled Pallas kernel.

    x: (M, D) f32, z: (N, D) f32  ->  (M, N) f32.

    The grid is (M/tm, N/tn); each program reads one row-tile of x and one
    row-tile of z (both full-D) and writes one output tile. gamma/coef0 are
    baked as compile-time constants — the AOT artifacts are per-kernel-variant
    so the request path never passes hyper-parameters.
    """
    m, d = x.shape
    n, d2 = z.shape
    if d != d2:
        raise ValueError(f"feature dims differ: {d} vs {d2}")
    tm = _pick_tile(m, tile_m)
    tn = _pick_tile(n, tile_n)
    kernel = functools.partial(
        _gram_tile_kernel, kind=kind, gamma=float(gamma), coef0=float(coef0))
    return pl.pallas_call(
        kernel,
        grid=(m // tm, n // tn),
        in_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), z.astype(jnp.float32))


def vmem_tile_bytes(tile_m: int, tile_n: int, d: int,
                    dtype_bytes: int = 4) -> int:
    """VMEM footprint of one program instance (inputs + output tile).

    Used by tests and by DESIGN.md §9 to check the tiles stay far below the
    ~16 MiB VMEM budget of a TPU core.
    """
    return dtype_bytes * (tile_m * d + tile_n * d + tile_m * tile_n)
