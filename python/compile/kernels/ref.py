"""Pure-jnp oracle for the L1 Pallas Gram-matrix kernel.

No pallas, no tiling — the straightforward dense formulas. pytest compares
kernels.kernel_matrix.gram_matrix against these with assert_allclose, and the
L2 model can be flipped to the reference path (model.py use_pallas=False) to
isolate kernel bugs from model bugs.
"""

from __future__ import annotations

import jax.numpy as jnp


def gram_matrix_ref(x, z, *, kind: str = "rbf", gamma: float = 0.5,
                    coef0: float = 0.0):
    """K[i, j] = k(x_i, z_j); x: (M, D), z: (N, D) -> (M, N) f32."""
    x = x.astype(jnp.float32)
    z = z.astype(jnp.float32)
    dots = x @ z.T
    if kind == "linear":
        return dots
    if kind == "rbf":
        sq_x = jnp.sum(x * x, axis=1, keepdims=True)
        sq_z = jnp.sum(z * z, axis=1, keepdims=True).T
        sq_dist = jnp.maximum(sq_x - 2.0 * dots + sq_z, 0.0)
        return jnp.exp(-gamma * sq_dist)
    if kind == "sigmoid":
        return jnp.tanh(gamma * dots + coef0)
    raise ValueError(f"unknown kernel kind: {kind!r}")
