//! End-to-end driver: the full system on a real small workload.
//!
//! Exercises every layer at once:
//!   * L1/L2 — the AOT-compiled JAX/Pallas SVM (train + predict artifacts),
//!   * runtime — PJRT CPU execution from the Rust request path,
//!   * L3 — HDFS + MapReduce simulation, the H-SVM-LRU coordinator,
//!     workload suites W1–W6 with shared inputs and shuffle pollution.
//!
//! Reports the paper's headline metric: normalized run time per workload
//! under H-NoCache / H-LRU / H-SVM-LRU (Fig 5) plus hit ratios, and the
//! resulting average improvements. The run is recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example pipeline_e2e`
//! (add `RUST_LOG=info` for classifier telemetry; pass `rust` as argv[1]
//! to force the SMO fallback backend).

use anyhow::Result;

use h_svm_lru::config::{ClusterConfig, SvmConfig};
use h_svm_lru::experiments::fig5;
use h_svm_lru::experiments::{run_workload, Scenario};
use h_svm_lru::svm::KernelKind;
use h_svm_lru::util::table::Table;
use h_svm_lru::workload::WORKLOADS;

fn main() -> Result<()> {
    h_svm_lru::util::logger::init_from_env();
    let backend_arg = std::env::args().nth(1);
    let artifacts = std::path::Path::new("artifacts");
    let backend = match backend_arg.as_deref() {
        Some(b) => b.to_string(),
        None if h_svm_lru::runtime::artifacts::available(artifacts, KernelKind::Rbf) => {
            "hlo".to_string()
        }
        None => {
            eprintln!("note: artifacts/ missing, using the rust SMO backend");
            "rust".to_string()
        }
    };
    let svm_cfg = SvmConfig { backend, ..Default::default() };
    let scale = 0.05; // Table 8 inputs scaled 1/20 (254-447 GB -> 12-22 GB)
    let seed = 20230101;

    println!("pipeline_e2e: workloads W1-W6, scale {scale}, svm backend {}", svm_cfg.backend);
    println!("cluster: 9 DataNodes, 1.5GB cache each, 128MB blocks (Table 6)\n");

    let mut table = Table::new(vec![
        "workload",
        "apps",
        "H-NoCache (s)",
        "H-LRU (s)",
        "H-SVM-LRU (s)",
        "LRU norm",
        "SVM norm",
        "SVM hit ratio",
    ]);
    let mut points = Vec::new();
    for def in &WORKLOADS {
        let cfg = ClusterConfig { seed, ..Default::default() };
        let nocache = run_workload(def, &cfg, &Scenario::NoCache, &svm_cfg, scale)?;
        let lru = run_workload(def, &cfg, &Scenario::Policy("lru".into()), &svm_cfg, scale)?;
        let svm = run_workload(def, &cfg, &Scenario::SvmLru, &svm_cfg, scale)?;
        let base = nocache.makespan_s.max(1e-9);
        table.add_row(vec![
            def.name.to_string(),
            def.apps.iter().map(|a| a.name()).collect::<Vec<_>>().join("+"),
            format!("{:.1}", nocache.makespan_s),
            format!("{:.1}", lru.makespan_s),
            format!("{:.1}", svm.makespan_s),
            format!("{:.4}", lru.makespan_s / base),
            format!("{:.4}", svm.makespan_s / base),
            format!("{:.3}", svm.hit_ratio),
        ]);
        points.push(fig5::WorkloadPoint {
            name: def.name,
            nocache_s: nocache.makespan_s,
            lru_norm: lru.makespan_s / base,
            svm_lru_norm: svm.makespan_s / base,
            lru_hit_ratio: lru.hit_ratio,
            svm_hit_ratio: svm.hit_ratio,
        });
    }
    print!("{}", table.render());
    let (lru_impr, svm_impr, over) = fig5::summary(&points);
    println!(
        "\nheadline: avg improvement vs H-NoCache — H-LRU {lru_impr:.2}%, \
         H-SVM-LRU {svm_impr:.2}% ({over:.2}% over H-LRU)"
    );
    println!("paper:    H-LRU 11.33%, H-SVM-LRU 16.16% (4.83% over H-LRU)");
    Ok(())
}
