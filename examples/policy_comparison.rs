//! Policy-comparison ablation: every replacement strategy from the paper's
//! Table 1 survey (plus FIFO and H-SVM-LRU itself) replayed over the same
//! seeded request trace at several cache sizes.
//!
//! Run: `cargo run --release --example policy_comparison [seed]`

use anyhow::Result;

use h_svm_lru::config::SvmConfig;
use h_svm_lru::experiments::policies;
use h_svm_lru::svm::KernelKind;
use h_svm_lru::util::table::Table;

fn main() -> Result<()> {
    h_svm_lru::util::logger::init_from_env();
    let seed: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(7);
    let artifacts = std::path::Path::new("artifacts");
    let backend = if h_svm_lru::runtime::artifacts::available(artifacts, KernelKind::Rbf) {
        "hlo"
    } else {
        "rust"
    };
    let svm_cfg = SvmConfig { backend: backend.into(), ..Default::default() };

    for cache_blocks in [6u64, 12, 24] {
        let results = policies::run(&svm_cfg, seed, cache_blocks)?;
        let mut t = Table::new(vec!["rank", "policy", "hit ratio", "byte hit", "evictions"]);
        for (i, r) in results.iter().enumerate() {
            t.add_row(vec![
                (i + 1).to_string(),
                r.policy.clone(),
                format!("{:.4}", r.hit_ratio),
                format!("{:.4}", r.byte_hit_ratio),
                r.evictions.to_string(),
            ]);
        }
        println!("\n=== cache = {cache_blocks} blocks (64MB each), seed {seed} ===");
        print!("{}", t.render());
        let hsvm = results.iter().position(|r| r.policy == "h-svm-lru").unwrap() + 1;
        println!("h-svm-lru rank: {hsvm}/{}", results.len());
    }
    Ok(())
}
