//! Hit-ratio sweep (the Fig 3 / Table 7 series) with CSV output for
//! plotting: cache size vs hit ratio for LRU and H-SVM-LRU at both block
//! sizes, plus the per-size improvement ratio.
//!
//! Run: `cargo run --release --example hitratio_sweep [seed] > fig3.csv`

use anyhow::Result;

use h_svm_lru::config::SvmConfig;
use h_svm_lru::experiments::{fig3, table7};
use h_svm_lru::svm::KernelKind;

fn main() -> Result<()> {
    h_svm_lru::util::logger::init_from_env();
    let seed: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20230101);
    let artifacts = std::path::Path::new("artifacts");
    let backend = if h_svm_lru::runtime::artifacts::available(artifacts, KernelKind::Rbf) {
        "hlo"
    } else {
        "rust"
    };
    let svm_cfg = SvmConfig { backend: backend.into(), ..Default::default() };

    let points = fig3::run(&svm_cfg, seed)?;
    // CSV to stdout (plot-ready), human tables to stderr.
    print!("{}", fig3::render(&points).to_csv());
    eprintln!("{}", fig3::render(&points).render());
    eprintln!("{}", table7::render(&points).render());

    // Sanity: the paper's qualitative claims.
    let small64 = points
        .iter()
        .find(|p| p.block_size == 64 * 1024 * 1024 && p.cache_blocks == 6)
        .expect("cache size 6 present");
    eprintln!(
        "IR at the smallest cache (paper: largest): {:.1}%",
        small64.improvement_ratio() * 100.0
    );
    Ok(())
}
