//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Provisions a one-node cluster, replays the paper's Fig 3 request trace
//! through LRU and H-SVM-LRU coordinators, and prints the hit ratios.
//! Uses the AOT HLO artifacts when present (run `make artifacts`), falling
//! back to the in-process SMO backend otherwise.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;

use h_svm_lru::config::SvmConfig;
use h_svm_lru::experiments::common::provision_fig3_cluster;
use h_svm_lru::experiments::{make_coordinator, replay_trace_two_pass, Scenario};
use h_svm_lru::svm::KernelKind;
use h_svm_lru::util::bytes::MB;
use h_svm_lru::workload::fig3_trace;

fn main() -> Result<()> {
    h_svm_lru::util::logger::init_from_env();

    // Pick the backend: HLO artifacts if built, else the SMO fallback.
    let artifacts = std::path::Path::new("artifacts");
    let backend = if h_svm_lru::runtime::artifacts::available(artifacts, KernelKind::Rbf) {
        "hlo"
    } else {
        eprintln!("note: artifacts/ not found, using --svm-backend rust (run `make artifacts`)");
        "rust"
    };
    let svm_cfg = SvmConfig { backend: backend.into(), ..Default::default() };

    let block_size = 64 * MB;
    let cache_blocks = 8;
    let seed = 42;
    let trace = fig3_trace(block_size, seed);
    println!(
        "replaying {} requests (2GB shared input + shuffle pollution), cache = {} blocks",
        trace.len(),
        cache_blocks
    );

    for scenario in [Scenario::Policy("lru".to_string()), Scenario::SvmLru] {
        let (_cfg, cluster) = provision_fig3_cluster(block_size, cache_blocks, seed);
        let mut coord = make_coordinator(cluster, &scenario, &svm_cfg)?;
        let hit_ratio = replay_trace_two_pass(&mut coord, &trace)?;
        println!(
            "{:<12} hit ratio {:.4}  (hits {:4}  misses {:4}  evictions {:4})",
            scenario.label(),
            hit_ratio,
            coord.stats.hits,
            coord.stats.misses,
            coord.stats.evictions
        );
        if scenario == Scenario::SvmLru {
            let bs = coord.batcher_stats();
            println!(
                "  classifier[{}]: {} trainings, {} queries -> {} backend calls",
                coord.backend_name(),
                coord.pipeline.trainings,
                bs.queries,
                bs.backend_calls
            );
        }
    }
    Ok(())
}
