//! Mini property-testing framework (no `proptest` in the offline cache).
//!
//! `forall` runs a property over N seeded random cases; on failure it
//! re-runs the shrink candidates produced by the case's `Shrink`
//! implementation (smaller vectors / values) until a minimal failing case
//! is found, then panics with the seed and the shrunken case so the
//! failure is reproducible.

use crate::util::rng::Pcg64;

/// A generator of random test cases.
pub trait Gen {
    type Value: std::fmt::Debug + Clone;
    fn generate(&self, rng: &mut Pcg64) -> Self::Value;

    /// Shrink candidates, largest reduction first. Default: none.
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: u32,
    pub seed: u64,
    pub max_shrink_steps: u32,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 100, seed: 0x9E3779B9, max_shrink_steps: 200 }
    }
}

/// Run `prop` over `cfg.cases` random cases from `gen`. Panics with a
/// minimal counterexample on failure.
pub fn forall<G, P>(cfg: &Config, gen: &G, mut prop: P)
where
    G: Gen,
    P: FnMut(&G::Value) -> Result<(), String>,
{
    let mut rng = Pcg64::new(cfg.seed, 0x7E57);
    for case_idx in 0..cfg.cases {
        let value = gen.generate(&mut rng);
        if let Err(msg) = prop(&value) {
            // Shrink.
            let mut best = value.clone();
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: while steps < cfg.max_shrink_steps {
                for candidate in gen.shrink(&best) {
                    steps += 1;
                    if let Err(m) = prop(&candidate) {
                        best = candidate;
                        best_msg = m;
                        continue 'outer;
                    }
                    if steps >= cfg.max_shrink_steps {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case_idx}, seed {:#x}): {best_msg}\nminimal case: {best:?}",
                cfg.seed
            );
        }
    }
}

/// Generator: vectors of `u64` in [0, max_value) with length in
/// [min_len, max_len]. Shrinks by halving length and zeroing values.
#[derive(Debug, Clone)]
pub struct VecU64Gen {
    pub min_len: usize,
    pub max_len: usize,
    pub max_value: u64,
}

impl Gen for VecU64Gen {
    type Value = Vec<u64>;

    fn generate(&self, rng: &mut Pcg64) -> Vec<u64> {
        let len = self.min_len
            + rng.gen_range((self.max_len - self.min_len + 1) as u64) as usize;
        (0..len).map(|_| rng.gen_range(self.max_value.max(1))).collect()
    }

    fn shrink(&self, value: &Vec<u64>) -> Vec<Vec<u64>> {
        let mut out = Vec::new();
        if value.len() > self.min_len {
            // Drop the second half / first half.
            let keep = (value.len() / 2).max(self.min_len);
            out.push(value[..keep].to_vec());
            out.push(value[value.len() - keep..].to_vec());
        }
        // Halve all values.
        if value.iter().any(|&v| v > 0) {
            out.push(value.iter().map(|&v| v / 2).collect());
        }
        out
    }
}

/// Generator: (sequence of ops over a keyspace, capacity) for cache
/// property tests. Ops are (key, predicted_reuse).
#[derive(Debug, Clone)]
pub struct CacheOpsGen {
    pub max_ops: usize,
    pub keyspace: u64,
    pub max_capacity: u64,
}

impl Gen for CacheOpsGen {
    type Value = (Vec<(u64, bool)>, u64);

    fn generate(&self, rng: &mut Pcg64) -> Self::Value {
        let len = 1 + rng.gen_range(self.max_ops as u64) as usize;
        let capacity = 1 + rng.gen_range(self.max_capacity);
        let ops = (0..len)
            .map(|_| (rng.gen_range(self.keyspace.max(1)), rng.gen_bool(0.5)))
            .collect();
        (ops, capacity)
    }

    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let (ops, cap) = value;
        let mut out = Vec::new();
        if ops.len() > 1 {
            out.push((ops[..ops.len() / 2].to_vec(), *cap));
            out.push((ops[ops.len() / 2..].to_vec(), *cap));
            let mut dropped = ops.clone();
            dropped.remove(ops.len() / 2);
            out.push((dropped, *cap));
        }
        if *cap > 1 {
            out.push((ops.clone(), cap / 2));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let gen = VecU64Gen { min_len: 0, max_len: 16, max_value: 100 };
        let mut count = 0;
        forall(&Config { cases: 50, ..Default::default() }, &gen, |v| {
            count += 1;
            if v.iter().sum::<u64>() > u64::MAX / 2 {
                Err("overflow".into())
            } else {
                Ok(())
            }
        });
        assert!(count >= 50);
    }

    #[test]
    fn failing_property_shrinks() {
        let gen = VecU64Gen { min_len: 0, max_len: 32, max_value: 1000 };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            forall(&Config::default(), &gen, |v| {
                // Fails whenever any element >= 500.
                if v.iter().any(|&x| x >= 500) {
                    Err(format!("has large element: {v:?}"))
                } else {
                    Ok(())
                }
            });
        }));
        let msg = match result {
            Err(e) => *e.downcast::<String>().expect("panic message"),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("minimal case"), "{msg}");
        // The shrunken case should be small (few elements).
        let tail = msg.split("minimal case: ").nth(1).unwrap();
        let elems = tail.matches(',').count() + 1;
        assert!(elems <= 8, "did not shrink well: {tail}");
    }

    #[test]
    fn cache_ops_gen_produces_valid_cases() {
        let gen = CacheOpsGen { max_ops: 50, keyspace: 10, max_capacity: 8 };
        let mut rng = Pcg64::new(1, 0);
        for _ in 0..20 {
            let (ops, cap) = gen.generate(&mut rng);
            assert!(!ops.is_empty());
            assert!((1..=8).contains(&cap));
            assert!(ops.iter().all(|(k, _)| *k < 10));
            // Shrinks stay valid.
            for (sops, scap) in gen.shrink(&(ops.clone(), cap)) {
                assert!(scap >= 1);
                assert!(sops.len() <= ops.len());
            }
        }
    }
}
