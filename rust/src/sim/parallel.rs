//! Scoped-thread fan-out for shard-parallel work.
//!
//! The DES engine itself is single-threaded by design (the event queue owns
//! time), but *replay* workloads — a fixed request stream partitioned by
//! cache shard — are embarrassingly parallel: each worker touches exactly
//! one shard of a [`crate::cache::ShardedCache`]. This module provides the
//! one primitive that needs: run N workers on `std::thread::scope` and
//! collect their results in worker order. No `unsafe`, no detached threads;
//! the borrow checker proves the workers cannot outlive the borrowed state.

/// Run `worker(0..n_workers)` concurrently on scoped threads and return the
/// results in worker order. `n_workers == 1` runs inline (no thread spawn),
/// which keeps the single-shard path identical to a plain loop.
///
/// Panics propagate: a panicking worker fails the whole call, like the
/// sequential loop it replaces would.
pub fn run_sharded<R, F>(n_workers: usize, worker: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    assert!(n_workers > 0, "run_sharded with zero workers");
    if n_workers == 1 {
        return vec![worker(0)];
    }
    std::thread::scope(|scope| {
        let worker = &worker;
        let handles: Vec<_> = (0..n_workers)
            .map(|i| scope.spawn(move || worker(i)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("shard worker panicked"))
            .collect()
    })
}

/// Run `worker(0..n_workers)` concurrently on scoped threads, **containing
/// panics**: each worker's result comes back as `Some(R)`, or `None` if
/// that worker panicked, instead of aborting the whole call. Partial
/// per-shard results survive a single bad shard — the graceful-degradation
/// variant of [`run_sharded`] for chaos runs and other best-effort sweeps.
///
/// Unlike [`run_sharded`], a single worker still runs on its own scoped
/// thread: a panic must be caught at the thread boundary (no
/// `catch_unwind`, no `unsafe`), so the inline fast path is not available.
pub fn run_sharded_resilient<R, F>(n_workers: usize, worker: F) -> Vec<Option<R>>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    assert!(n_workers > 0, "run_sharded_resilient with zero workers");
    std::thread::scope(|scope| {
        let worker = &worker;
        let handles: Vec<_> = (0..n_workers)
            .map(|i| scope.spawn(move || worker(i)))
            .collect();
        handles.into_iter().map(|h| h.join().ok()).collect()
    })
}

/// Run `worker(0..n_workers)` concurrently *plus* one background task on
/// the same scope, and return `(worker results, background result)`.
///
/// The online-learning replay is the motivating shape: shard workers
/// replay the trace while the background task runs the trainer loop,
/// consuming the sample channel the workers feed. `finish` runs after
/// every worker has joined and *before* the background task is joined —
/// the place to drop the channel sender whose disconnect tells the
/// background loop to drain and exit. Forgetting to close the channel in
/// `finish` deadlocks the join, exactly like the equivalent hand-rolled
/// scope would.
///
/// Panics propagate from workers and background task alike.
pub fn run_sharded_with_background<R, B, F, G, D>(
    n_workers: usize,
    worker: F,
    background: G,
    finish: D,
) -> (Vec<R>, B)
where
    R: Send,
    B: Send,
    F: Fn(usize) -> R + Sync,
    G: FnOnce() -> B + Send,
    D: FnOnce(),
{
    assert!(n_workers > 0, "run_sharded_with_background with zero workers");
    std::thread::scope(|scope| {
        let bg = scope.spawn(background);
        let worker = &worker;
        let handles: Vec<_> = (0..n_workers)
            .map(|i| scope.spawn(move || worker(i)))
            .collect();
        // Join every worker BEFORE propagating any panic: `finish` must
        // run even on worker failure, or the background task would never
        // see its shutdown signal and the scope would deadlock.
        let joined: Vec<_> = handles.into_iter().map(|h| h.join()).collect();
        finish();
        let b = bg.join().expect("background task panicked");
        let results: Vec<R> = joined
            .into_iter()
            .map(|r| match r {
                Ok(v) => v,
                Err(p) => std::panic::resume_unwind(p),
            })
            .collect();
        (results, b)
    })
}

/// Run `worker(0..n_workers)` concurrently *plus* one polling monitor on
/// the same scope, and return `(worker results, monitor result)`.
///
/// The monitor receives a `done` flag that flips to `true` (Release) once
/// every worker has joined; it is expected to loop — observing shared
/// state like lock-free cache stats — until the flag is set, then return.
/// The reader-contention replay is the motivating shape: shard workers
/// hammer a [`crate::cache::ShardedCache`] while the monitor loops
/// `stats()` / `used()`, which must never serialize the workers.
///
/// Panics propagate from workers and monitor alike; the flag is set even
/// when a worker panics, so the monitor always terminates.
pub fn run_sharded_with_monitor<R, M, F, G>(
    n_workers: usize,
    worker: F,
    monitor: G,
) -> (Vec<R>, M)
where
    R: Send,
    M: Send,
    F: Fn(usize) -> R + Sync,
    G: FnOnce(&crate::util::sync::atomic::AtomicBool) -> M + Send,
{
    use crate::util::sync::atomic::{AtomicBool, Ordering};

    assert!(n_workers > 0, "run_sharded_with_monitor with zero workers");
    let done = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let done = &done;
        let mon = scope.spawn(move || monitor(done));
        let worker = &worker;
        let handles: Vec<_> = (0..n_workers)
            .map(|i| scope.spawn(move || worker(i)))
            .collect();
        // Join every worker BEFORE propagating any panic: the monitor must
        // see its stop signal even on worker failure, or the scope would
        // never finish joining it.
        let joined: Vec<_> = handles.into_iter().map(|h| h.join()).collect();
        // Release: pairs with the monitor's Acquire poll so everything the
        // workers wrote happens-before the monitor's final observation.
        done.store(true, Ordering::Release);
        let m = mon.join().expect("monitor panicked");
        let results: Vec<R> = joined
            .into_iter()
            .map(|r| match r {
                Ok(v) => v,
                Err(p) => std::panic::resume_unwind(p),
            })
            .collect();
        (results, m)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use crate::util::sync::hint;

    #[test]
    fn results_come_back_in_worker_order() {
        let out = run_sharded(8, |i| i * 10);
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60, 70]);
    }

    #[test]
    fn single_worker_runs_inline() {
        let out = run_sharded(1, |i| {
            assert_eq!(i, 0);
            "inline"
        });
        assert_eq!(out, vec!["inline"]);
    }

    #[test]
    fn workers_share_borrowed_state() {
        let data: Vec<u64> = (0..1000).collect();
        let n = 4;
        let partial = run_sharded(n, |w| {
            data.iter().filter(|&&x| x as usize % n == w).sum::<u64>()
        });
        assert_eq!(partial.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn background_task_consumes_worker_output() {
        // Workers feed a channel; the background task sums until the
        // senders disappear (the last one dropped by `finish`).
        let (tx, rx) = std::sync::mpsc::sync_channel::<u64>(64);
        let master = std::sync::Mutex::new(Some(tx));
        let (results, total) = run_sharded_with_background(
            4,
            |w| {
                let tx = master
                    .lock()
                    .unwrap()
                    .as_ref()
                    .expect("sender taken before workers finished")
                    .clone();
                for k in 0..10u64 {
                    tx.send(w as u64 * 100 + k).unwrap();
                }
                w
            },
            move || rx.iter().sum::<u64>(),
            || {
                master.lock().unwrap().take();
            },
        );
        assert_eq!(results, vec![0, 1, 2, 3]);
        let expected: u64 = (0..4u64).map(|w| (0..10).map(|k| w * 100 + k).sum::<u64>()).sum();
        assert_eq!(total, expected);
    }

    #[test]
    fn monitor_observes_until_workers_finish() {
        let progress = AtomicU64::new(0);
        let (results, polls) = run_sharded_with_monitor(
            4,
            |w| {
                for _ in 0..1000 {
                    progress.fetch_add(1, Ordering::Relaxed);
                }
                w
            },
            |done: &AtomicBool| {
                let mut polls = 0u64;
                // Acquire: pairs with run_sharded_with_monitor's Release
                // store, so worker writes precede the final poll.
                while !done.load(Ordering::Acquire) {
                    let p = progress.load(Ordering::Relaxed);
                    assert!(p <= 4000);
                    polls += 1;
                }
                polls
            },
        );
        assert_eq!(results, vec![0, 1, 2, 3]);
        assert!(polls > 0, "monitor must have observed at least once");
        assert_eq!(progress.load(Ordering::Relaxed), 4000);
    }

    #[test]
    #[should_panic(expected = "monitor panicked")]
    fn monitor_terminates_even_when_a_worker_panics() {
        run_sharded_with_monitor(
            2,
            |i| {
                if i == 1 {
                    panic!("worker boom");
                }
                i
            },
            |done: &AtomicBool| {
                // Acquire: pairs with the harness's Release store (set
                // even on worker panic, which is the point of this test).
                while !done.load(Ordering::Acquire) {
                    hint::spin_loop();
                }
                // The monitor sees the stop signal despite the worker
                // panic; its own panic is what the harness reports first.
                panic!("monitor saw shutdown");
            },
        );
    }

    #[test]
    fn resilient_contains_panics_and_keeps_partial_results() {
        let out = run_sharded_resilient(4, |i| {
            if i == 2 {
                panic!("shard 2 boom");
            }
            i * 10
        });
        assert_eq!(out, vec![Some(0), Some(10), None, Some(30)]);
    }

    #[test]
    fn resilient_single_worker_still_contains() {
        let out: Vec<Option<u32>> = run_sharded_resilient(1, |_| panic!("boom"));
        assert_eq!(out, vec![None]);
    }

    #[test]
    #[should_panic(expected = "shard worker panicked")]
    fn worker_panic_propagates() {
        run_sharded(2, |i| {
            if i == 1 {
                panic!("boom");
            }
            i
        });
    }
}
