//! Scoped-thread fan-out for shard-parallel work.
//!
//! The DES engine itself is single-threaded by design (the event queue owns
//! time), but *replay* workloads — a fixed request stream partitioned by
//! cache shard — are embarrassingly parallel: each worker touches exactly
//! one shard of a [`crate::cache::ShardedCache`]. This module provides the
//! one primitive that needs: run N workers on `std::thread::scope` and
//! collect their results in worker order. No `unsafe`, no detached threads;
//! the borrow checker proves the workers cannot outlive the borrowed state.

/// Run `worker(0..n_workers)` concurrently on scoped threads and return the
/// results in worker order. `n_workers == 1` runs inline (no thread spawn),
/// which keeps the single-shard path identical to a plain loop.
///
/// Panics propagate: a panicking worker fails the whole call, like the
/// sequential loop it replaces would.
pub fn run_sharded<R, F>(n_workers: usize, worker: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    assert!(n_workers > 0, "run_sharded with zero workers");
    if n_workers == 1 {
        return vec![worker(0)];
    }
    std::thread::scope(|scope| {
        let worker = &worker;
        let handles: Vec<_> = (0..n_workers)
            .map(|i| scope.spawn(move || worker(i)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("shard worker panicked"))
            .collect()
    })
}

/// Run `worker(0..n_workers)` concurrently *plus* one background task on
/// the same scope, and return `(worker results, background result)`.
///
/// The online-learning replay is the motivating shape: shard workers
/// replay the trace while the background task runs the trainer loop,
/// consuming the sample channel the workers feed. `finish` runs after
/// every worker has joined and *before* the background task is joined —
/// the place to drop the channel sender whose disconnect tells the
/// background loop to drain and exit. Forgetting to close the channel in
/// `finish` deadlocks the join, exactly like the equivalent hand-rolled
/// scope would.
///
/// Panics propagate from workers and background task alike.
pub fn run_sharded_with_background<R, B, F, G, D>(
    n_workers: usize,
    worker: F,
    background: G,
    finish: D,
) -> (Vec<R>, B)
where
    R: Send,
    B: Send,
    F: Fn(usize) -> R + Sync,
    G: FnOnce() -> B + Send,
    D: FnOnce(),
{
    assert!(n_workers > 0, "run_sharded_with_background with zero workers");
    std::thread::scope(|scope| {
        let bg = scope.spawn(background);
        let worker = &worker;
        let handles: Vec<_> = (0..n_workers)
            .map(|i| scope.spawn(move || worker(i)))
            .collect();
        // Join every worker BEFORE propagating any panic: `finish` must
        // run even on worker failure, or the background task would never
        // see its shutdown signal and the scope would deadlock.
        let joined: Vec<_> = handles.into_iter().map(|h| h.join()).collect();
        finish();
        let b = bg.join().expect("background task panicked");
        let results: Vec<R> = joined
            .into_iter()
            .map(|r| match r {
                Ok(v) => v,
                Err(p) => std::panic::resume_unwind(p),
            })
            .collect();
        (results, b)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_worker_order() {
        let out = run_sharded(8, |i| i * 10);
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60, 70]);
    }

    #[test]
    fn single_worker_runs_inline() {
        let out = run_sharded(1, |i| {
            assert_eq!(i, 0);
            "inline"
        });
        assert_eq!(out, vec!["inline"]);
    }

    #[test]
    fn workers_share_borrowed_state() {
        let data: Vec<u64> = (0..1000).collect();
        let n = 4;
        let partial = run_sharded(n, |w| {
            data.iter().filter(|&&x| x as usize % n == w).sum::<u64>()
        });
        assert_eq!(partial.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn background_task_consumes_worker_output() {
        // Workers feed a channel; the background task sums until the
        // senders disappear (the last one dropped by `finish`).
        let (tx, rx) = std::sync::mpsc::sync_channel::<u64>(64);
        let master = std::sync::Mutex::new(Some(tx));
        let (results, total) = run_sharded_with_background(
            4,
            |w| {
                let tx = master
                    .lock()
                    .unwrap()
                    .as_ref()
                    .expect("sender taken before workers finished")
                    .clone();
                for k in 0..10u64 {
                    tx.send(w as u64 * 100 + k).unwrap();
                }
                w
            },
            move || rx.iter().sum::<u64>(),
            || {
                master.lock().unwrap().take();
            },
        );
        assert_eq!(results, vec![0, 1, 2, 3]);
        let expected: u64 = (0..4u64).map(|w| (0..10).map(|k| w * 100 + k).sum::<u64>()).sum();
        assert_eq!(total, expected);
    }

    #[test]
    #[should_panic(expected = "shard worker panicked")]
    fn worker_panic_propagates() {
        run_sharded(2, |i| {
            if i == 1 {
                panic!("boom");
            }
            i
        });
    }
}
