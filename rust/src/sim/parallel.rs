//! Scoped-thread fan-out for shard-parallel work.
//!
//! The DES engine itself is single-threaded by design (the event queue owns
//! time), but *replay* workloads — a fixed request stream partitioned by
//! cache shard — are embarrassingly parallel: each worker touches exactly
//! one shard of a [`crate::cache::ShardedCache`]. This module provides the
//! one primitive that needs: run N workers on `std::thread::scope` and
//! collect their results in worker order. No `unsafe`, no detached threads;
//! the borrow checker proves the workers cannot outlive the borrowed state.

/// Run `worker(0..n_workers)` concurrently on scoped threads and return the
/// results in worker order. `n_workers == 1` runs inline (no thread spawn),
/// which keeps the single-shard path identical to a plain loop.
///
/// Panics propagate: a panicking worker fails the whole call, like the
/// sequential loop it replaces would.
pub fn run_sharded<R, F>(n_workers: usize, worker: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    assert!(n_workers > 0, "run_sharded with zero workers");
    if n_workers == 1 {
        return vec![worker(0)];
    }
    std::thread::scope(|scope| {
        let worker = &worker;
        let handles: Vec<_> = (0..n_workers)
            .map(|i| scope.spawn(move || worker(i)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("shard worker panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_worker_order() {
        let out = run_sharded(8, |i| i * 10);
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60, 70]);
    }

    #[test]
    fn single_worker_runs_inline() {
        let out = run_sharded(1, |i| {
            assert_eq!(i, 0);
            "inline"
        });
        assert_eq!(out, vec!["inline"]);
    }

    #[test]
    fn workers_share_borrowed_state() {
        let data: Vec<u64> = (0..1000).collect();
        let n = 4;
        let partial = run_sharded(n, |w| {
            data.iter().filter(|&&x| x as usize % n == w).sum::<u64>()
        });
        assert_eq!(partial.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    #[should_panic(expected = "shard worker panicked")]
    fn worker_panic_propagates() {
        run_sharded(2, |i| {
            if i == 1 {
                panic!("boom");
            }
            i
        });
    }
}
