//! Scoped-thread fan-out for shard-parallel work.
//!
//! The DES engine itself is single-threaded by design (the event queue owns
//! time), but *replay* workloads — a fixed request stream partitioned by
//! cache shard — are embarrassingly parallel: each worker touches exactly
//! one shard of a [`crate::cache::ShardedCache`]. This module provides the
//! one primitive that needs: [`run_fanout`] runs N workers on
//! `std::thread::scope` and collects their results in worker order, with
//! the orthogonal extras the replay drivers grew — a background task (the
//! online-learning trainer loop), a polling monitor (lock-free stats
//! readers), panic containment (chaos sweeps) — selected per call through
//! [`FanoutOptions`] instead of four near-duplicate entry points. No
//! `unsafe`, no detached threads; the borrow checker proves the workers
//! cannot outlive the borrowed state.
//!
//! The removed entry points map onto options like this:
//!
//! | old entry point              | options |
//! |------------------------------|---------|
//! | `run_sharded`                | `FanoutOptions::new()` |
//! | `run_sharded_resilient`      | `.resilient(true)` |
//! | `run_sharded_with_background`| `.background(task, finish)` |
//! | `run_sharded_with_monitor`   | `.monitor(task)` |

use crate::util::sync::atomic::{AtomicBool, Ordering};

/// Closure type of the absent background task (concrete, so
/// [`FanoutOptions::new`] needs no type annotations).
pub type NoBackground = fn();
/// Closure type of the absent background-finish hook.
pub type NoFinish = fn();
/// Closure type of the absent monitor.
pub type NoMonitor = fn(&AtomicBool);

/// What to run alongside the shard workers of a [`run_fanout`] call.
///
/// Starts empty (plain fan-out) and grows by builder calls; `background`
/// and `monitor` change the option's type parameters, which is why the
/// absent defaults are concrete `fn` types.
pub struct FanoutOptions<G, D, M> {
    background: Option<(G, D)>,
    monitor: Option<M>,
    resilient: bool,
}

impl FanoutOptions<NoBackground, NoFinish, NoMonitor> {
    /// Plain fan-out: no background task, no monitor, panics propagate.
    pub fn new() -> Self {
        FanoutOptions { background: None, monitor: None, resilient: false }
    }
}

impl Default for FanoutOptions<NoBackground, NoFinish, NoMonitor> {
    fn default() -> Self {
        Self::new()
    }
}

impl<G, D, M> FanoutOptions<G, D, M> {
    /// Run `task` on the same scope as the workers and keep its result.
    ///
    /// `finish` runs after every worker has joined and *before* `task` is
    /// joined — the place to drop the channel sender whose disconnect
    /// tells a consumer loop to drain and exit. Forgetting to close the
    /// channel in `finish` deadlocks the join, exactly like the
    /// equivalent hand-rolled scope would. The online-learning replay is
    /// the motivating shape: shard workers replay the trace while the
    /// task runs the trainer loop consuming the sample channel they feed.
    pub fn background<G2, D2>(self, task: G2, finish: D2) -> FanoutOptions<G2, D2, M> {
        FanoutOptions {
            background: Some((task, finish)),
            monitor: self.monitor,
            resilient: self.resilient,
        }
    }

    /// Run a polling monitor on the same scope as the workers and keep
    /// its result.
    ///
    /// The monitor receives a `done` flag that flips to `true` (Release)
    /// once every worker has joined; it is expected to loop — observing
    /// shared state like lock-free cache stats — until the flag is set,
    /// then return. The flag is set even when a worker panics, so the
    /// monitor always terminates.
    pub fn monitor<M2>(self, task: M2) -> FanoutOptions<G, D, M2> {
        FanoutOptions {
            background: self.background,
            monitor: Some(task),
            resilient: self.resilient,
        }
    }

    /// Contain worker panics instead of propagating them: a panicked
    /// worker's slot comes back as `None` in
    /// [`FanoutReport::workers`] and the other shards' results survive —
    /// the graceful-degradation mode for chaos runs and other best-effort
    /// sweeps.
    pub fn resilient(mut self, contained: bool) -> Self {
        self.resilient = contained;
        self
    }
}

/// Everything a [`run_fanout`] call produced.
#[derive(Debug)]
pub struct FanoutReport<R, B, M> {
    /// Per-worker results in worker order. `None` marks a panicked worker,
    /// which can only happen under [`FanoutOptions::resilient`] — without
    /// it the panic resumes on the caller instead.
    pub workers: Vec<Option<R>>,
    /// The background task's result, when one was configured.
    pub background: Option<B>,
    /// The monitor's result, when one was configured.
    pub monitor: Option<M>,
}

impl<R, B, M> FanoutReport<R, B, M> {
    /// Unwrap the per-worker results of a non-resilient run.
    ///
    /// Panics on a `None` slot — impossible unless the run was
    /// [`FanoutOptions::resilient`], where the caller must inspect
    /// [`FanoutReport::workers`] itself.
    pub fn into_workers(self) -> Vec<R> {
        self.workers
            .into_iter()
            .map(|r| r.expect("panicked worker slot in a resilient run"))
            .collect()
    }
}

/// Run `worker(0..n_workers)` concurrently on scoped threads — plus
/// whatever [`FanoutOptions`] selects — and return the results in worker
/// order.
///
/// A plain single-worker call (no background, no monitor, no resilience)
/// runs inline with no thread spawn, which keeps the single-shard path
/// identical to a plain loop. Worker panics propagate (resuming the
/// original panic payload) unless [`FanoutOptions::resilient`] contains
/// them; background-task and monitor panics always propagate, after every
/// worker has joined.
pub fn run_fanout<R, B, M, F, G, D, MO>(
    n_workers: usize,
    worker: F,
    opts: FanoutOptions<G, D, MO>,
) -> FanoutReport<R, B, M>
where
    R: Send,
    B: Send,
    M: Send,
    F: Fn(usize) -> R + Sync,
    G: FnOnce() -> B + Send,
    D: FnOnce(),
    MO: FnOnce(&AtomicBool) -> M + Send,
{
    assert!(n_workers > 0, "run_fanout with zero workers");
    let FanoutOptions { background, monitor, resilient } = opts;
    if n_workers == 1 && background.is_none() && monitor.is_none() && !resilient {
        // Inline fast path. Resilient runs are excluded: a panic must be
        // caught at a thread boundary (no `catch_unwind`, no `unsafe`).
        return FanoutReport { workers: vec![Some(worker(0))], background: None, monitor: None };
    }
    let done = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let done = &done;
        let (bg, finish) = match background {
            Some((task, finish)) => (Some(scope.spawn(task)), Some(finish)),
            None => (None, None),
        };
        let mon = monitor.map(|task| scope.spawn(move || task(done)));
        let worker = &worker;
        let handles: Vec<_> = (0..n_workers)
            .map(|i| scope.spawn(move || worker(i)))
            .collect();
        // Join every worker BEFORE propagating any panic: the shutdown
        // hooks below must run even on worker failure, or a background
        // task / monitor would never see its stop signal and the scope
        // would deadlock.
        let joined: Vec<_> = handles.into_iter().map(|h| h.join()).collect();
        if let Some(finish) = finish {
            finish();
        }
        // Release: pairs with the monitor's Acquire poll so everything the
        // workers wrote happens-before the monitor's final observation.
        done.store(true, Ordering::Release);
        let background = bg.map(|h| h.join().expect("background task panicked"));
        let monitor = mon.map(|h| h.join().expect("monitor panicked"));
        let workers: Vec<Option<R>> = if resilient {
            joined.into_iter().map(|r| r.ok()).collect()
        } else {
            joined
                .into_iter()
                .map(|r| match r {
                    Ok(v) => Some(v),
                    Err(p) => std::panic::resume_unwind(p),
                })
                .collect()
        };
        FanoutReport { workers, background, monitor }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use crate::util::sync::hint;

    // One-line parity wrappers re-expressing the four removed entry points
    // over `run_fanout` — the legacy tests below run against these, pinning
    // the collapsed API to the old contracts.
    fn run_sharded<R: Send>(n: usize, worker: impl Fn(usize) -> R + Sync) -> Vec<R> {
        run_fanout(n, worker, FanoutOptions::new()).into_workers()
    }

    fn run_sharded_resilient<R: Send>(
        n: usize,
        worker: impl Fn(usize) -> R + Sync,
    ) -> Vec<Option<R>> {
        run_fanout(n, worker, FanoutOptions::new().resilient(true)).workers
    }

    fn run_sharded_with_background<R: Send, B: Send>(
        n: usize,
        worker: impl Fn(usize) -> R + Sync,
        background: impl FnOnce() -> B + Send,
        finish: impl FnOnce(),
    ) -> (Vec<R>, B) {
        let mut report = run_fanout(n, worker, FanoutOptions::new().background(background, finish));
        let b = report.background.take().expect("background configured");
        (report.into_workers(), b)
    }

    fn run_sharded_with_monitor<R: Send, M: Send>(
        n: usize,
        worker: impl Fn(usize) -> R + Sync,
        monitor: impl FnOnce(&AtomicBool) -> M + Send,
    ) -> (Vec<R>, M) {
        let mut report = run_fanout(n, worker, FanoutOptions::new().monitor(monitor));
        let m = report.monitor.take().expect("monitor configured");
        (report.into_workers(), m)
    }

    #[test]
    fn results_come_back_in_worker_order() {
        let out = run_sharded(8, |i| i * 10);
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60, 70]);
    }

    #[test]
    fn single_worker_runs_inline() {
        let out = run_sharded(1, |i| {
            assert_eq!(i, 0);
            "inline"
        });
        assert_eq!(out, vec!["inline"]);
    }

    #[test]
    fn workers_share_borrowed_state() {
        let data: Vec<u64> = (0..1000).collect();
        let n = 4;
        let partial = run_sharded(n, |w| {
            data.iter().filter(|&&x| x as usize % n == w).sum::<u64>()
        });
        assert_eq!(partial.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn background_task_consumes_worker_output() {
        // Workers feed a channel; the background task sums until the
        // senders disappear (the last one dropped by `finish`).
        let (tx, rx) = std::sync::mpsc::sync_channel::<u64>(64);
        let master = std::sync::Mutex::new(Some(tx));
        let (results, total) = run_sharded_with_background(
            4,
            |w| {
                let tx = master
                    .lock()
                    .unwrap()
                    .as_ref()
                    .expect("sender taken before workers finished")
                    .clone();
                for k in 0..10u64 {
                    tx.send(w as u64 * 100 + k).unwrap();
                }
                w
            },
            move || rx.iter().sum::<u64>(),
            || {
                master.lock().unwrap().take();
            },
        );
        assert_eq!(results, vec![0, 1, 2, 3]);
        let expected: u64 = (0..4u64).map(|w| (0..10).map(|k| w * 100 + k).sum::<u64>()).sum();
        assert_eq!(total, expected);
    }

    #[test]
    fn monitor_observes_until_workers_finish() {
        let progress = AtomicU64::new(0);
        let (results, polls) = run_sharded_with_monitor(
            4,
            |w| {
                for _ in 0..1000 {
                    progress.fetch_add(1, Ordering::Relaxed);
                }
                w
            },
            |done: &AtomicBool| {
                let mut polls = 0u64;
                // Acquire: pairs with run_fanout's Release store, so
                // worker writes precede the final poll.
                while !done.load(Ordering::Acquire) {
                    let p = progress.load(Ordering::Relaxed);
                    assert!(p <= 4000);
                    polls += 1;
                }
                polls
            },
        );
        assert_eq!(results, vec![0, 1, 2, 3]);
        assert!(polls > 0, "monitor must have observed at least once");
        assert_eq!(progress.load(Ordering::Relaxed), 4000);
    }

    #[test]
    #[should_panic(expected = "monitor panicked")]
    fn monitor_terminates_even_when_a_worker_panics() {
        run_sharded_with_monitor(
            2,
            |i| {
                if i == 1 {
                    panic!("worker boom");
                }
                i
            },
            |done: &AtomicBool| {
                // Acquire: pairs with the harness's Release store (set
                // even on worker panic, which is the point of this test).
                while !done.load(Ordering::Acquire) {
                    hint::spin_loop();
                }
                // The monitor sees the stop signal despite the worker
                // panic; its own panic is what the harness reports first.
                panic!("monitor saw shutdown");
            },
        );
    }

    #[test]
    fn resilient_contains_panics_and_keeps_partial_results() {
        let out = run_sharded_resilient(4, |i| {
            if i == 2 {
                panic!("shard 2 boom");
            }
            i * 10
        });
        assert_eq!(out, vec![Some(0), Some(10), None, Some(30)]);
    }

    #[test]
    fn resilient_single_worker_still_contains() {
        let out: Vec<Option<u32>> = run_sharded_resilient(1, |_| panic!("boom"));
        assert_eq!(out, vec![None]);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn worker_panic_propagates_with_its_original_payload() {
        run_sharded(2, |i| {
            if i == 1 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn background_and_monitor_compose_on_one_scope() {
        // The collapse's new capability: both extras at once. The monitor
        // watches progress while the background task consumes the channel.
        let (tx, rx) = std::sync::mpsc::sync_channel::<u64>(16);
        let master = std::sync::Mutex::new(Some(tx));
        let progress = AtomicU64::new(0);
        let report = run_fanout(
            2,
            |w| {
                let tx = master.lock().unwrap().as_ref().unwrap().clone();
                for k in 0..5u64 {
                    tx.send(k).unwrap();
                    progress.fetch_add(1, Ordering::Relaxed);
                }
                w
            },
            FanoutOptions::new()
                .background(
                    move || rx.iter().sum::<u64>(),
                    || {
                        master.lock().unwrap().take();
                    },
                )
                .monitor(|done: &AtomicBool| {
                    let mut polls = 0u64;
                    while !done.load(Ordering::Acquire) {
                        assert!(progress.load(Ordering::Relaxed) <= 10);
                        polls += 1;
                    }
                    polls
                }),
        );
        assert_eq!(report.workers, vec![Some(0), Some(1)]);
        assert_eq!(report.background, Some(20), "both workers sent 0..5");
        assert!(report.monitor.unwrap() > 0);
    }
}
