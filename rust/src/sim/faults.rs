//! Deterministic fault injection: seeded chaos plans on the simulated
//! clock.
//!
//! A [`FaultPlan`] scripts *when* things break — classifier-backend
//! outages and latency spikes as simulated-time windows, trainer crashes
//! as sample-count thresholds, DataNode down/up events as timestamped
//! transitions. Everything is keyed on the request clock
//! ([`SimTime`]), never the wall clock, so the same plan replayed under
//! the same seed produces byte-identical results at any shard count —
//! the same discipline as the rest of the simulator (DESIGN.md §2).
//!
//! A [`FaultInjector`] is the shared, cloneable runtime view of one plan:
//! it answers "does this backend call fail *now*?" and counts every
//! injected fault in relaxed atomics (through the `util::sync` facade, so
//! the loom/lint rules of rust/tests/lint_invariants.rs hold by
//! construction). [`FaultyBackend`] wraps any [`SvmBackend`] with the
//! injector: the replay worker stamps it with the current request time
//! and injected outages surface as ordinary `Err` results on the
//! prediction path — exactly what the batcher's circuit breaker
//! ([`crate::coordinator::batcher::BreakerConfig`]) is built to absorb.
//!
//! An **all-clear plan** ([`FaultPlan::all_clear`]) injects nothing: the
//! injector answers [`BackendFate::Healthy`] unconditionally and the
//! wrapped backend is behaviorally identical to the bare one —
//! property-tested in rust/tests/property_faults.rs.

use std::sync::Arc;

use crate::runtime::SvmBackend;
use crate::sim::{SimDuration, SimTime};
use crate::svm::features::FeatureVec;
use crate::util::sync::atomic::{AtomicU64, Ordering};

/// A half-open simulated-time interval `[start, end)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultWindow {
    pub start: SimTime,
    pub end: SimTime,
}

impl FaultWindow {
    /// Window from `start` (inclusive) to `end` (exclusive).
    pub fn new(start: SimTime, end: SimTime) -> Self {
        FaultWindow { start, end }
    }

    /// Does the window cover simulated instant `t`?
    pub fn contains(&self, t: SimTime) -> bool {
        self.start <= t && t < self.end
    }

    /// Does the window intersect `[a, b)`?
    pub fn overlaps(&self, a: SimTime, b: SimTime) -> bool {
        self.start < b && a < self.end
    }
}

/// One scripted fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultEvent {
    /// Every classifier-backend call inside the window fails.
    BackendOutage(FaultWindow),
    /// Backend calls inside the window succeed but cost `extra` simulated
    /// latency (accounted by [`FaultyBackend::injected_latency`]).
    BackendSlow { window: FaultWindow, extra: SimDuration },
    /// The background trainer crashes (and restarts) once it has consumed
    /// this many samples. Count-based rather than time-based because the
    /// sample stream carries no timestamps — and a count is every bit as
    /// deterministic.
    TrainerCrash { after_samples: u64 },
    /// DataNode `node` dies at `at` (replicas unreachable, cached copies
    /// lost).
    NodeDown { node: u32, at: SimTime },
    /// DataNode `node` rejoins at `at`.
    NodeUp { node: u32, at: SimTime },
}

/// A deterministic, seeded fault schedule. The seed is identity metadata
/// (carried into the metrics export) — the events themselves are the
/// script.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    seed: u64,
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// A plan that injects nothing. Replays under an all-clear plan are
    /// bit-identical to replays with no injection at all.
    pub fn all_clear(seed: u64) -> Self {
        FaultPlan { seed, events: Vec::new() }
    }

    /// Builder-style event append.
    pub fn with_event(mut self, event: FaultEvent) -> Self {
        self.events.push(event);
        self
    }

    /// The plan's identity seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The scripted events, in insertion order.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// True when the plan scripts no faults at all.
    pub fn is_all_clear(&self) -> bool {
        self.events.is_empty()
    }

    /// Is the classifier backend down at simulated instant `t`?
    pub fn backend_down(&self, t: SimTime) -> bool {
        self.events.iter().any(|e| matches!(e, FaultEvent::BackendOutage(w) if w.contains(t)))
    }

    /// Injected backend latency active at `t` (sum of overlapping spikes).
    pub fn backend_extra_latency(&self, t: SimTime) -> SimDuration {
        let micros: u64 = self
            .events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::BackendSlow { window, extra } if window.contains(t) => {
                    Some(extra.micros())
                }
                _ => None,
            })
            .sum();
        SimDuration::from_micros(micros)
    }

    /// Sample-count thresholds at which the trainer crashes, ascending.
    pub fn trainer_crash_points(&self) -> Vec<u64> {
        let mut points: Vec<u64> = self
            .events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::TrainerCrash { after_samples } => Some(*after_samples),
                _ => None,
            })
            .collect();
        points.sort_unstable();
        points
    }

    /// All scripted node transitions as `(at, node, down)`, sorted by
    /// `(at, node, up-before-down)` so replaying them in order is
    /// deterministic regardless of plan construction order.
    pub fn node_events(&self) -> Vec<(SimTime, u32, bool)> {
        let mut evs: Vec<(SimTime, u32, bool)> = self
            .events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::NodeDown { node, at } => Some((*at, *node, true)),
                FaultEvent::NodeUp { node, at } => Some((*at, *node, false)),
            _ => None,
            })
            .collect();
        evs.sort_unstable_by_key(|&(at, node, down)| (at, node, down));
        evs
    }

    /// The scripted backend outage windows, in insertion order.
    pub fn outage_windows(&self) -> Vec<FaultWindow> {
        self.events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::BackendOutage(w) => Some(*w),
                _ => None,
            })
            .collect()
    }
}

/// What the injector decided about one backend call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendFate {
    /// Call proceeds untouched.
    Healthy,
    /// Call proceeds but costs this much extra simulated latency.
    Slow(SimDuration),
    /// Call fails.
    Fail,
}

/// Shared injection tallies (explicit ctor: loom atomics lack `Default`).
#[derive(Debug)]
struct InjectionCounters {
    backend_failures: AtomicU64,
    backend_slowdowns: AtomicU64,
    trainer_crashes: AtomicU64,
    node_downs: AtomicU64,
    node_ups: AtomicU64,
}

impl InjectionCounters {
    fn new() -> Self {
        InjectionCounters {
            backend_failures: AtomicU64::new(0),
            backend_slowdowns: AtomicU64::new(0),
            trainer_crashes: AtomicU64::new(0),
            node_downs: AtomicU64::new(0),
            node_ups: AtomicU64::new(0),
        }
    }
}

/// Cloneable runtime view of one [`FaultPlan`]: consults the script and
/// tallies every injected fault. Clones share the plan and the counters,
/// so one injector can serve every shard worker plus the trainer and the
/// DAG service while the driver reads a single set of totals.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    plan: Arc<FaultPlan>,
    counters: Arc<InjectionCounters>,
}

impl FaultInjector {
    /// An injector over `plan` with fresh zeroed tallies.
    pub fn new(plan: FaultPlan) -> Self {
        FaultInjector { plan: Arc::new(plan), counters: Arc::new(InjectionCounters::new()) }
    }

    /// The underlying plan.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Decide (and tally) the fate of a backend call at simulated `now`.
    pub fn backend_fate(&self, now: SimTime) -> BackendFate {
        if self.plan.backend_down(now) {
            self.counters.backend_failures.fetch_add(1, Ordering::Relaxed);
            return BackendFate::Fail;
        }
        let extra = self.plan.backend_extra_latency(now);
        if extra > SimDuration::ZERO {
            self.counters.backend_slowdowns.fetch_add(1, Ordering::Relaxed);
            return BackendFate::Slow(extra);
        }
        BackendFate::Healthy
    }

    /// Tally one injected trainer crash.
    pub fn note_trainer_crash(&self) {
        self.counters.trainer_crashes.fetch_add(1, Ordering::Relaxed);
    }

    /// Tally one applied node transition.
    pub fn note_node_event(&self, down: bool) {
        if down {
            self.counters.node_downs.fetch_add(1, Ordering::Relaxed);
        } else {
            self.counters.node_ups.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Backend calls failed by injection.
    pub fn backend_failures(&self) -> u64 {
        self.counters.backend_failures.load(Ordering::Relaxed)
    }

    /// Backend calls slowed by injection.
    pub fn backend_slowdowns(&self) -> u64 {
        self.counters.backend_slowdowns.load(Ordering::Relaxed)
    }

    /// Trainer crashes injected.
    pub fn trainer_crashes(&self) -> u64 {
        self.counters.trainer_crashes.load(Ordering::Relaxed)
    }

    /// Node-down transitions applied.
    pub fn node_downs(&self) -> u64 {
        self.counters.node_downs.load(Ordering::Relaxed)
    }

    /// Node-up transitions applied.
    pub fn node_ups(&self) -> u64 {
        self.counters.node_ups.load(Ordering::Relaxed)
    }

    /// Expose every injection tally as a `{prefix}.…` gauge — the probe
    /// pattern of [`crate::coordinator::batcher::BatcherProbe`]: the
    /// accessors stay the programmatic view, the gauges put the same
    /// cells in the `--metrics-out` JSONL.
    pub fn register_gauges(&self, registry: &crate::obs::MetricsRegistry, prefix: &str) {
        let gauge = |name: &str, read: fn(&InjectionCounters) -> &AtomicU64| {
            let counters = Arc::clone(&self.counters);
            registry.gauge(&format!("{prefix}.{name}"), move || {
                read(&counters).load(Ordering::Relaxed)
            });
        };
        gauge("backend_failures", |c| &c.backend_failures);
        gauge("backend_slowdowns", |c| &c.backend_slowdowns);
        gauge("trainer_crashes", |c| &c.trainer_crashes);
        gauge("node_downs", |c| &c.node_downs);
        gauge("node_ups", |c| &c.node_ups);
    }
}

/// An [`SvmBackend`] wrapper that injects the plan's backend faults.
///
/// The owning worker stamps it with the current request time
/// ([`FaultyBackend::set_now`]) before each prediction; calls made during
/// a scripted outage fail with an ordinary `Err`, calls under a latency
/// spike succeed while accruing simulated delay into
/// [`FaultyBackend::injected_latency`]. With an all-clear plan every call
/// delegates untouched.
#[derive(Debug)]
pub struct FaultyBackend<B> {
    inner: B,
    injector: FaultInjector,
    now: SimTime,
    injected_latency: SimDuration,
}

impl<B> FaultyBackend<B> {
    /// Wrap `inner` under `injector`'s plan.
    pub fn new(inner: B, injector: FaultInjector) -> Self {
        FaultyBackend { inner, injector, now: SimTime::ZERO, injected_latency: SimDuration::ZERO }
    }

    /// Advance the injection clock to the current request time.
    pub fn set_now(&mut self, now: SimTime) {
        self.now = now;
    }

    /// Total simulated latency injected into successful calls.
    pub fn injected_latency(&self) -> SimDuration {
        self.injected_latency
    }

    /// The wrapped backend.
    pub fn inner_mut(&mut self) -> &mut B {
        &mut self.inner
    }
}

impl<B: SvmBackend> SvmBackend for FaultyBackend<B> {
    fn name(&self) -> &'static str {
        "faulty"
    }

    fn train(&mut self, ds: &crate::svm::Dataset) -> anyhow::Result<()> {
        if let BackendFate::Fail = self.injector.backend_fate(self.now) {
            anyhow::bail!("injected backend outage at {}us (train)", self.now.micros());
        }
        self.inner.train(ds)
    }

    fn decision_batch(&mut self, queries: &[FeatureVec]) -> anyhow::Result<Vec<f32>> {
        match self.injector.backend_fate(self.now) {
            BackendFate::Fail => {
                anyhow::bail!("injected backend outage at {}us", self.now.micros())
            }
            BackendFate::Slow(extra) => {
                self.injected_latency = self.injected_latency + extra;
                self.inner.decision_batch(queries)
            }
            BackendFate::Healthy => self.inner.decision_batch(queries),
        }
    }

    fn is_trained(&self) -> bool {
        self.inner.is_trained()
    }

    fn export_model(&self) -> Option<crate::svm::smo::SmoModel> {
        self.inner.export_model()
    }

    fn import_model(&mut self, model: crate::svm::smo::SmoModel) -> anyhow::Result<()> {
        self.inner.import_model(model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::Result;
    use crate::svm::features::N_FEATURES;

    struct OkBackend {
        calls: u64,
    }

    impl SvmBackend for OkBackend {
        fn name(&self) -> &'static str {
            "ok"
        }
        fn train(&mut self, _ds: &crate::svm::Dataset) -> Result<()> {
            Ok(())
        }
        fn decision_batch(&mut self, q: &[FeatureVec]) -> Result<Vec<f32>> {
            self.calls += 1;
            Ok(vec![1.0; q.len()])
        }
        fn is_trained(&self) -> bool {
            true
        }
    }

    fn fv() -> FeatureVec {
        [0.0f32; N_FEATURES]
    }

    fn secs(s: f64) -> SimTime {
        SimTime::from_secs_f64(s)
    }

    #[test]
    fn all_clear_plan_injects_nothing() {
        let plan = FaultPlan::all_clear(7);
        assert!(plan.is_all_clear());
        let inj = FaultInjector::new(plan);
        for t in [0.0, 1.0, 1e6] {
            assert_eq!(inj.backend_fate(secs(t)), BackendFate::Healthy);
        }
        assert_eq!(inj.backend_failures(), 0);
        assert_eq!(inj.backend_slowdowns(), 0);
    }

    #[test]
    fn outage_window_fails_calls_inside_only() {
        let plan = FaultPlan::all_clear(7)
            .with_event(FaultEvent::BackendOutage(FaultWindow::new(secs(10.0), secs(20.0))));
        assert!(!plan.is_all_clear());
        let inj = FaultInjector::new(plan);
        assert_eq!(inj.backend_fate(secs(9.9)), BackendFate::Healthy);
        assert_eq!(inj.backend_fate(secs(10.0)), BackendFate::Fail);
        assert_eq!(inj.backend_fate(secs(19.9)), BackendFate::Fail);
        assert_eq!(inj.backend_fate(secs(20.0)), BackendFate::Healthy, "half-open interval");
        assert_eq!(inj.backend_failures(), 2);
    }

    #[test]
    fn latency_spikes_sum_and_tally() {
        let w = FaultWindow::new(secs(0.0), secs(5.0));
        let plan = FaultPlan::all_clear(1)
            .with_event(FaultEvent::BackendSlow { window: w, extra: SimDuration::from_micros(100) })
            .with_event(FaultEvent::BackendSlow { window: w, extra: SimDuration::from_micros(50) });
        let inj = FaultInjector::new(plan);
        match inj.backend_fate(secs(1.0)) {
            BackendFate::Slow(d) => assert_eq!(d.micros(), 150),
            other => panic!("expected Slow, got {other:?}"),
        }
        assert_eq!(inj.backend_slowdowns(), 1);
    }

    #[test]
    fn faulty_backend_fails_during_outage_and_recovers() {
        let plan = FaultPlan::all_clear(3)
            .with_event(FaultEvent::BackendOutage(FaultWindow::new(secs(1.0), secs(2.0))));
        let mut be = FaultyBackend::new(OkBackend { calls: 0 }, FaultInjector::new(plan));
        be.set_now(secs(0.5));
        assert!(be.decision_batch(&[fv()]).is_ok());
        be.set_now(secs(1.5));
        let err = be.decision_batch(&[fv()]).unwrap_err();
        assert!(err.to_string().contains("injected backend outage"), "{err}");
        be.set_now(secs(2.5));
        assert!(be.decision_batch(&[fv()]).is_ok());
        assert_eq!(be.inner_mut().calls, 2, "outage call never reached the inner backend");
    }

    #[test]
    fn faulty_backend_accrues_injected_latency() {
        let plan = FaultPlan::all_clear(3).with_event(FaultEvent::BackendSlow {
            window: FaultWindow::new(secs(0.0), secs(10.0)),
            extra: SimDuration::from_micros(250),
        });
        let mut be = FaultyBackend::new(OkBackend { calls: 0 }, FaultInjector::new(plan));
        be.set_now(secs(1.0));
        assert!(be.decision_batch(&[fv()]).is_ok());
        be.set_now(secs(2.0));
        assert!(be.decision_batch(&[fv()]).is_ok());
        assert_eq!(be.injected_latency().micros(), 500);
    }

    #[test]
    fn node_events_sort_deterministically() {
        let plan = FaultPlan::all_clear(0)
            .with_event(FaultEvent::NodeUp { node: 2, at: secs(30.0) })
            .with_event(FaultEvent::NodeDown { node: 2, at: secs(10.0) })
            .with_event(FaultEvent::NodeDown { node: 1, at: secs(10.0) });
        let evs = plan.node_events();
        assert_eq!(
            evs,
            vec![
                (secs(10.0), 1, true),
                (secs(10.0), 2, true),
                (secs(30.0), 2, false),
            ]
        );
    }

    #[test]
    fn trainer_crash_points_sorted() {
        let plan = FaultPlan::all_clear(0)
            .with_event(FaultEvent::TrainerCrash { after_samples: 500 })
            .with_event(FaultEvent::TrainerCrash { after_samples: 100 });
        assert_eq!(plan.trainer_crash_points(), vec![100, 500]);
    }

    #[test]
    fn injector_gauges_mirror_accessors() {
        let registry = crate::obs::MetricsRegistry::new();
        let plan = FaultPlan::all_clear(0)
            .with_event(FaultEvent::BackendOutage(FaultWindow::new(secs(0.0), secs(1.0))));
        let inj = FaultInjector::new(plan);
        inj.register_gauges(&registry, "faults");
        let _ = inj.backend_fate(secs(0.5));
        inj.note_trainer_crash();
        inj.note_node_event(true);
        let gauges = registry.gauge_values();
        let get = |name: &str| {
            gauges
                .iter()
                .find(|(n, _)| n == &format!("faults.{name}"))
                .map(|(_, v)| *v)
                .unwrap_or(u64::MAX)
        };
        assert_eq!(get("backend_failures"), 1);
        assert_eq!(get("trainer_crashes"), 1);
        assert_eq!(get("node_downs"), 1);
        assert_eq!(get("node_ups"), 0);
    }
}
