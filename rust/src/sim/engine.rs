//! Discrete-event engine: a time-ordered queue of closures over a state `S`.
//!
//! Events scheduled for the same tick fire in schedule order (a monotone
//! sequence number breaks ties), which makes whole simulations bit-for-bit
//! reproducible for a given seed.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::time::{SimDuration, SimTime};

/// An event callback: gets the engine (to schedule more events) and the
/// simulation state.
pub type EventFn<S> = Box<dyn FnOnce(&mut Engine<S>, &mut S)>;

struct Scheduled<S> {
    at: SimTime,
    seq: u64,
    f: EventFn<S>,
}

impl<S> PartialEq for Scheduled<S> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl<S> Eq for Scheduled<S> {}

impl<S> PartialOrd for Scheduled<S> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<S> Ord for Scheduled<S> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want the earliest first.
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

/// The discrete-event engine.
pub struct Engine<S> {
    now: SimTime,
    seq: u64,
    fired: u64,
    queue: BinaryHeap<Scheduled<S>>,
}

impl<S> Default for Engine<S> {
    fn default() -> Self {
        Self::new()
    }
}

impl<S> Engine<S> {
    pub fn new() -> Self {
        Engine { now: SimTime::ZERO, seq: 0, fired: 0, queue: BinaryHeap::new() }
    }

    /// Current simulated time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events fired so far (bench/diagnostic metric).
    pub fn events_fired(&self) -> u64 {
        self.fired
    }

    /// Pending event count.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Schedule an event `delay` after now.
    pub fn schedule_in<F>(&mut self, delay: SimDuration, f: F)
    where
        F: FnOnce(&mut Engine<S>, &mut S) + 'static,
    {
        self.schedule_at(self.now + delay, f);
    }

    /// Schedule an event at an absolute time (must not be in the past).
    pub fn schedule_at<F>(&mut self, at: SimTime, f: F)
    where
        F: FnOnce(&mut Engine<S>, &mut S) + 'static,
    {
        assert!(at >= self.now, "scheduling into the past: {at} < {}", self.now);
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Scheduled { at, seq, f: Box::new(f) });
    }

    /// Run until the queue drains. Returns the final simulated time.
    pub fn run(&mut self, state: &mut S) -> SimTime {
        while self.step(state) {}
        self.now
    }

    /// Run until the queue drains or `deadline` is reached (events at the
    /// deadline still fire).
    pub fn run_until(&mut self, state: &mut S, deadline: SimTime) -> SimTime {
        while let Some(next) = self.queue.peek() {
            if next.at > deadline {
                self.now = deadline;
                return self.now;
            }
            self.step(state);
        }
        self.now
    }

    /// Fire the single earliest event; false when the queue is empty.
    pub fn step(&mut self, state: &mut S) -> bool {
        match self.queue.pop() {
            Some(ev) => {
                debug_assert!(ev.at >= self.now, "event queue time travel");
                self.now = ev.at;
                self.fired += 1;
                (ev.f)(self, state);
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_in_time_order() {
        let mut eng: Engine<Vec<u32>> = Engine::new();
        let mut order = Vec::new();
        eng.schedule_at(SimTime(30), |_, s: &mut Vec<u32>| s.push(3));
        eng.schedule_at(SimTime(10), |_, s| s.push(1));
        eng.schedule_at(SimTime(20), |_, s| s.push(2));
        eng.run(&mut order);
        assert_eq!(order, vec![1, 2, 3]);
        assert_eq!(eng.now(), SimTime(30));
        assert_eq!(eng.events_fired(), 3);
    }

    #[test]
    fn same_tick_fires_in_schedule_order() {
        let mut eng: Engine<Vec<u32>> = Engine::new();
        let mut order = Vec::new();
        for i in 0..10 {
            eng.schedule_at(SimTime(5), move |_, s: &mut Vec<u32>| s.push(i));
        }
        eng.run(&mut order);
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn events_can_schedule_events() {
        // A chain of events, each scheduling the next.
        fn chain(eng: &mut Engine<u64>, state: &mut u64) {
            *state += 1;
            if *state < 100 {
                eng.schedule_in(SimDuration(7), chain);
            }
        }
        let mut eng = Engine::new();
        let mut count = 0u64;
        eng.schedule_at(SimTime(0), chain);
        eng.run(&mut count);
        assert_eq!(count, 100);
        assert_eq!(eng.now(), SimTime(99 * 7));
    }

    #[test]
    fn run_until_stops_at_deadline() {
        let mut eng: Engine<Vec<u64>> = Engine::new();
        let mut seen = Vec::new();
        for t in [10u64, 20, 30, 40] {
            eng.schedule_at(SimTime(t), move |_, s: &mut Vec<u64>| s.push(t));
        }
        eng.run_until(&mut seen, SimTime(25));
        assert_eq!(seen, vec![10, 20]);
        assert_eq!(eng.now(), SimTime(25));
        assert_eq!(eng.pending(), 2);
        eng.run(&mut seen);
        assert_eq!(seen, vec![10, 20, 30, 40]);
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn past_scheduling_panics() {
        let mut eng: Engine<()> = Engine::new();
        eng.schedule_at(SimTime(10), |eng, _| {
            eng.schedule_at(SimTime(5), |_, _| {});
        });
        eng.run(&mut ());
    }
}
