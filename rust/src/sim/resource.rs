//! FIFO-server resources (disk, NIC, CPU slots) with deterministic queueing.
//!
//! A `Resource` models `c` identical servers. A request occupies the
//! earliest-free server for its service duration; the returned completion
//! time accounts for queueing delay. This is the standard "earliest idle
//! server" shortcut: it produces exact FIFO M/G/c dynamics without
//! materializing queue objects, which keeps the simulator hot path
//! allocation-free.

use super::time::{SimDuration, SimTime};

/// A multi-server FIFO resource.
#[derive(Debug, Clone)]
pub struct Resource {
    name: String,
    /// Earliest time each server becomes idle.
    free_at: Vec<SimTime>,
    busy_total: SimDuration,
    requests: u64,
}

impl Resource {
    pub fn new(name: impl Into<String>, servers: usize) -> Self {
        assert!(servers > 0, "resource with zero servers");
        Resource {
            name: name.into(),
            free_at: vec![SimTime::ZERO; servers],
            busy_total: SimDuration::ZERO,
            requests: 0,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn servers(&self) -> usize {
        self.free_at.len()
    }

    /// Acquire a server at `now` for `service`; returns (start, completion).
    /// `start >= now`, and `completion - start == service`.
    pub fn acquire(&mut self, now: SimTime, service: SimDuration) -> (SimTime, SimTime) {
        // earliest-free server
        let (idx, &free) = self
            .free_at
            .iter()
            .enumerate()
            .min_by_key(|(_, &t)| t)
            .expect("non-empty servers");
        let start = free.max(now);
        let end = start + service;
        self.free_at[idx] = end;
        self.busy_total += service;
        self.requests += 1;
        (start, end)
    }

    /// When the earliest server is free (>= now queueing estimate).
    pub fn next_free(&self) -> SimTime {
        *self.free_at.iter().min().expect("non-empty servers")
    }

    /// Total service time ever granted (for utilization reports).
    pub fn busy_time(&self) -> SimDuration {
        self.busy_total
    }

    pub fn requests(&self) -> u64 {
        self.requests
    }

    /// Utilization in [0, 1] over the horizon `[0, until]`.
    pub fn utilization(&self, until: SimTime) -> f64 {
        if until == SimTime::ZERO {
            return 0.0;
        }
        let capacity = until.as_secs_f64() * self.servers() as f64;
        (self.busy_total.as_secs_f64() / capacity).min(1.0)
    }

    /// Reset all servers to idle at t=0 (between experiment repetitions).
    pub fn reset(&mut self) {
        for t in self.free_at.iter_mut() {
            *t = SimTime::ZERO;
        }
        self.busy_total = SimDuration::ZERO;
        self.requests = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn us(n: u64) -> SimDuration {
        SimDuration::from_micros(n)
    }

    #[test]
    fn single_server_serializes() {
        let mut disk = Resource::new("disk", 1);
        let (s1, e1) = disk.acquire(SimTime(0), us(100));
        assert_eq!((s1, e1), (SimTime(0), SimTime(100)));
        // Second request at t=10 queues behind the first.
        let (s2, e2) = disk.acquire(SimTime(10), us(50));
        assert_eq!((s2, e2), (SimTime(100), SimTime(150)));
        // A late request after the disk went idle starts immediately.
        let (s3, e3) = disk.acquire(SimTime(500), us(20));
        assert_eq!((s3, e3), (SimTime(500), SimTime(520)));
    }

    #[test]
    fn multi_server_runs_in_parallel() {
        let mut cpu = Resource::new("cpu", 2);
        let (_, e1) = cpu.acquire(SimTime(0), us(100));
        let (s2, e2) = cpu.acquire(SimTime(0), us(100));
        assert_eq!(e1, SimTime(100));
        assert_eq!(s2, SimTime(0));
        assert_eq!(e2, SimTime(100));
        // third request queues behind whichever frees first
        let (s3, _) = cpu.acquire(SimTime(0), us(10));
        assert_eq!(s3, SimTime(100));
    }

    #[test]
    fn utilization_accounting() {
        let mut disk = Resource::new("disk", 1);
        disk.acquire(SimTime(0), us(500_000));
        assert!((disk.utilization(SimTime(1_000_000)) - 0.5).abs() < 1e-9);
        assert_eq!(disk.requests(), 1);
        disk.reset();
        assert_eq!(disk.busy_time(), SimDuration::ZERO);
        assert_eq!(disk.next_free(), SimTime::ZERO);
    }

    #[test]
    #[should_panic(expected = "zero servers")]
    fn zero_servers_panics() {
        Resource::new("x", 0);
    }
}
