//! Simulated time: u64 microsecond ticks (deterministic, totally ordered —
//! no floating-point drift in event ordering).

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in simulated time, in microseconds since simulation start.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

/// A span of simulated time, in microseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimDuration(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_secs_f64(s: f64) -> Self {
        assert!(s >= 0.0 && s.is_finite(), "bad sim time {s}");
        SimTime((s * 1e6).round() as u64)
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }

    pub fn micros(self) -> u64 {
        self.0
    }

    /// Saturating difference (earlier.duration_until(later)).
    pub fn duration_until(self, later: SimTime) -> SimDuration {
        SimDuration(later.0.saturating_sub(self.0))
    }
}

impl SimDuration {
    pub const ZERO: SimDuration = SimDuration(0);

    pub fn from_secs_f64(s: f64) -> Self {
        assert!(s >= 0.0 && s.is_finite(), "bad sim duration {s}");
        SimDuration((s * 1e6).round() as u64)
    }

    pub fn from_micros(us: u64) -> Self {
        SimDuration(us)
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }

    pub fn micros(self) -> u64 {
        self.0
    }
}

impl Add<SimDuration> for SimTime {
    type Output = SimTime;
    fn add(self, d: SimDuration) -> SimTime {
        SimTime(self.0 + d.0)
    }
}

impl AddAssign<SimDuration> for SimTime {
    fn add_assign(&mut self, d: SimDuration) {
        self.0 += d.0;
    }
}

impl Add for SimDuration {
    type Output = SimDuration;
    fn add(self, other: SimDuration) -> SimDuration {
        SimDuration(self.0 + other.0)
    }
}

impl AddAssign for SimDuration {
    fn add_assign(&mut self, other: SimDuration) {
        self.0 += other.0;
    }
}

impl Sub for SimTime {
    type Output = SimDuration;
    fn sub(self, earlier: SimTime) -> SimDuration {
        SimDuration(self.0.checked_sub(earlier.0).expect("negative sim duration"))
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.6}s", self.as_secs_f64())
    }
}

impl fmt::Display for SimDuration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.6}s", self.as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_seconds() {
        let t = SimTime::from_secs_f64(1.5);
        assert_eq!(t.0, 1_500_000);
        assert!((t.as_secs_f64() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn arithmetic() {
        let t = SimTime::from_secs_f64(1.0) + SimDuration::from_secs_f64(0.5);
        assert_eq!(t, SimTime::from_secs_f64(1.5));
        assert_eq!(t - SimTime::from_secs_f64(1.0), SimDuration::from_secs_f64(0.5));
    }

    #[test]
    #[should_panic(expected = "negative sim duration")]
    fn negative_duration_panics() {
        let _ = SimTime::from_secs_f64(1.0) - SimTime::from_secs_f64(2.0);
    }

    #[test]
    fn duration_until_saturates() {
        let a = SimTime::from_secs_f64(2.0);
        let b = SimTime::from_secs_f64(1.0);
        assert_eq!(a.duration_until(b), SimDuration::ZERO);
        assert_eq!(b.duration_until(a), SimDuration::from_secs_f64(1.0));
    }
}
