//! Discrete-event simulation core.
//!
//! The paper's testbed is a physical 10-node Hadoop cluster; we reproduce
//! its behaviour with a deterministic DES (see DESIGN.md §2): `time` defines
//! integer-microsecond simulated time, `engine` the event queue, and
//! `resource` FIFO multi-server resources used to model disks, NICs and CPU
//! slots on each node.

pub mod engine;
pub mod faults;
pub mod parallel;
pub mod resource;
pub mod time;

pub use engine::Engine;
pub use faults::{BackendFate, FaultEvent, FaultInjector, FaultPlan, FaultWindow, FaultyBackend};
pub use parallel::{run_fanout, FanoutOptions, FanoutReport};
pub use resource::Resource;
pub use time::{SimDuration, SimTime};
