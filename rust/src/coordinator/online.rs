//! Concurrent online learning: the paper's §5 retrain-as-you-go loop,
//! running *beside* a shard-parallel replay instead of inside a
//! single-threaded coordinator.
//!
//! The subsystem has three moving parts:
//!
//! * [`ClassifierSnapshot`] — an **immutable** trained classifier (the
//!   exported [`SmoModel`] plus a monotonically increasing version).
//!   Shard workers never lock a backend; they read a snapshot.
//! * [`SnapshotCell`] — the publication point: one atomic version counter
//!   plus a mutex-held `Arc<ClassifierSnapshot>`. Readers keep a local
//!   `Arc` clone and re-check only the atomic on every prediction
//!   ([`SnapshotReader`]), so the hot path is a single `Acquire` load
//!   unless a new model was actually published.
//! * the **background trainer** — [`trainer_loop`] drains a bounded
//!   [`sample_channel`] of labeled observations (emitted by every shard
//!   worker through a cloned [`SampleSender`]) into the existing
//!   [`TrainingPipeline`], retrains the [`SvmBackend`] on the pipeline's
//!   cadence, and publishes each fresh model to the cell.
//!
//! Emission never blocks the request path: [`SampleSender::emit`] uses
//! `try_send` and counts drops when the trainer falls behind. The trainer
//! exits when every sender is dropped, draining whatever is still queued
//! (so short traces still get their final retrain).
//!
//! The single-threaded [`CacheCoordinator`](super::CacheCoordinator) is a
//! degenerate participant of the same protocol: it publishes to a
//! [`SnapshotCell`] after every retrain, so anything that can consume a
//! snapshot (shard workers, tests, dashboards) sees the same classifier
//! the coordinator itself batches predictions through.

use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::Arc;

use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::Mutex;

use anyhow::Result;

use crate::runtime::SvmBackend;
use crate::svm::features::FeatureVec;
use crate::svm::smo::SmoModel;

use super::training_pipeline::TrainingPipeline;

// ------------------------------------------------------------- snapshots

/// An immutable, versioned classifier. Version 0 is the untrained
/// snapshot every [`SnapshotCell`] starts from; published models get
/// versions 1, 2, … in publication order.
#[derive(Debug, Clone)]
pub struct ClassifierSnapshot {
    version: u64,
    model: Option<SmoModel>,
}

impl ClassifierSnapshot {
    /// The version-0 snapshot: no model, every prediction is `None`.
    pub fn untrained() -> Self {
        ClassifierSnapshot { version: 0, model: None }
    }

    /// Monotonic publish version (0 = untrained).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Whether the snapshot carries a model.
    pub fn is_trained(&self) -> bool {
        self.model.is_some()
    }

    /// Decision score (class "reused" iff > 0), or `None` when untrained.
    pub fn decision(&self, features: &FeatureVec) -> Option<f32> {
        self.model.as_ref().map(|m| m.decision(features))
    }

    /// Predicted class, or `None` when untrained — exactly the
    /// `predicted_reuse` an [`AccessContext`](crate::cache::AccessContext)
    /// carries.
    pub fn predict(&self, features: &FeatureVec) -> Option<bool> {
        self.decision(features).map(|s| s > 0.0)
    }
}

/// The atomically swappable publication point for classifier snapshots.
///
/// `version` is the fast-path gate: readers compare it against their
/// cached snapshot's version with one `Acquire` load and only take the
/// `slot` lock when a publish actually happened. Publishing stores the
/// new `Arc` and bumps `version` under the same lock, so the atomic can
/// never run ahead of (or behind) the slot.
///
/// ```
/// use std::sync::Arc;
/// use h_svm_lru::coordinator::online::SnapshotCell;
/// use h_svm_lru::svm::kernel::{KernelKind, KernelParams};
/// use h_svm_lru::svm::smo::SmoModel;
///
/// let cell = Arc::new(SnapshotCell::new());
/// let mut reader = cell.reader();
/// assert_eq!(reader.predict(&[0.5; 9]), None); // version 0: untrained
///
/// // Publish a trivial model whose decision is sign(bias) everywhere.
/// let model = SmoModel::new(
///     KernelParams::new(KernelKind::Linear),
///     Vec::new(), Vec::new(), Vec::new(),
///     1.0,
/// );
/// assert_eq!(cell.publish(model), 1); // publish bumps the version...
/// assert_eq!(reader.predict(&[0.5; 9]), Some(true)); // ...readers see it
/// ```
#[derive(Debug)]
pub struct SnapshotCell {
    version: AtomicU64,
    slot: Mutex<Arc<ClassifierSnapshot>>,
}

impl Default for SnapshotCell {
    fn default() -> Self {
        Self::new()
    }
}

impl SnapshotCell {
    /// A cell holding the untrained version-0 snapshot.
    pub fn new() -> Self {
        SnapshotCell {
            version: AtomicU64::new(0),
            slot: Mutex::new(Arc::new(ClassifierSnapshot::untrained())),
        }
    }

    /// Latest published version (0 = nothing published yet).
    pub fn version(&self) -> u64 {
        // Acquire: pairs with the Release store in `publish` — a reader
        // that observes version N and then takes the slot lock is
        // guaranteed to find a snapshot of version >= N there.
        self.version.load(Ordering::Acquire)
    }

    /// Publish a freshly trained model; returns its version.
    pub fn publish(&self, model: SmoModel) -> u64 {
        let mut slot = self.slot.lock().expect("snapshot cell poisoned");
        let version = slot.version() + 1;
        *slot = Arc::new(ClassifierSnapshot { version, model: Some(model) });
        // Release (still under the slot lock): publishes the slot swap
        // before the version bump, and the lock serializes publishers, so
        // the atomic can never run ahead of the slot — loom-modeled in
        // rust/tests/loom_protocols.rs.
        self.version.store(version, Ordering::Release);
        version
    }

    /// The current snapshot (shared, immutable).
    pub fn load(&self) -> Arc<ClassifierSnapshot> {
        self.slot.lock().expect("snapshot cell poisoned").clone()
    }

    /// A reader with its own cached `Arc` (one per shard worker).
    pub fn reader(self: &Arc<Self>) -> SnapshotReader {
        SnapshotReader {
            cached: self.load(),
            refreshes: 0,
            cell: Arc::clone(self),
        }
    }
}

/// A per-worker handle that caches the latest snapshot `Arc` and
/// re-clones only when [`SnapshotCell::version`] moved — predictions on
/// an unchanged model are entirely lock-free.
#[derive(Debug)]
pub struct SnapshotReader {
    cell: Arc<SnapshotCell>,
    cached: Arc<ClassifierSnapshot>,
    refreshes: u64,
}

impl SnapshotReader {
    /// A reader over `cell`, pre-loaded with its current snapshot.
    pub fn new(cell: Arc<SnapshotCell>) -> Self {
        cell.reader()
    }

    /// The freshest snapshot (refreshing the local cache if needed).
    pub fn current(&mut self) -> &ClassifierSnapshot {
        if self.cell.version() != self.cached.version() {
            self.cached = self.cell.load();
            self.refreshes += 1;
        }
        &self.cached
    }

    /// Predict through the freshest snapshot (`None` while untrained).
    pub fn predict(&mut self, features: &FeatureVec) -> Option<bool> {
        self.current().predict(features)
    }

    /// How many times this reader observed a newly published snapshot.
    pub fn refreshes(&self) -> u64 {
        self.refreshes
    }
}

/// An [`SvmBackend`] view over the latest published classifier snapshot:
/// `decision_batch` scores through a [`SnapshotReader`], so the scoring
/// path is as lock-free as the reader (one `Acquire` load on unchanged
/// models). Read-only — `train` fails; the background trainer owns the
/// real backend.
///
/// This is the bridge that lets a per-shard
/// [`ShardBatcher`](super::batcher::ShardBatcher) run on the concurrent
/// replay path: each shard worker owns one `SnapshotBackend`, flushes its
/// own cold-query queue through it, and never waits behind another
/// shard's flush (the miss-storm serialization of a single shared
/// backend is gone).
#[derive(Debug)]
pub struct SnapshotBackend {
    reader: SnapshotReader,
}

impl SnapshotBackend {
    /// A read-only backend view over `cell`.
    pub fn new(cell: Arc<SnapshotCell>) -> Self {
        SnapshotBackend { reader: SnapshotReader::new(cell) }
    }

    /// The freshest published version (refreshing the cached snapshot).
    /// Feed this to the shard batcher's `note_model_version` (see
    /// [`super::batcher::ShardBatcher`]) so a publish invalidates the
    /// shard's cached classes.
    pub fn version(&mut self) -> u64 {
        self.reader.current().version()
    }

    /// Newly published snapshots this backend has observed.
    pub fn refreshes(&self) -> u64 {
        self.reader.refreshes()
    }
}

impl SvmBackend for SnapshotBackend {
    fn name(&self) -> &'static str {
        "snapshot"
    }

    fn train(&mut self, _ds: &crate::svm::Dataset) -> Result<()> {
        anyhow::bail!("snapshot backend is read-only (the trainer owns the real backend)")
    }

    fn decision_batch(&mut self, queries: &[FeatureVec]) -> Result<Vec<f32>> {
        let snap = self.reader.current();
        anyhow::ensure!(snap.is_trained(), "no classifier snapshot published yet");
        Ok(queries
            .iter()
            .map(|q| snap.decision(q).expect("trained snapshot scores"))
            .collect())
    }

    fn is_trained(&self) -> bool {
        // Version 0 is the untrained snapshot; every published version
        // carries a model.
        self.reader.cell.version() > 0
    }
}

// -------------------------------------------------------------- samples

/// One labeled observation flowing from a shard worker to the trainer.
#[derive(Debug, Clone, Copy)]
pub struct LabeledSample {
    /// The access's feature vector at observation time.
    pub features: FeatureVec,
    /// Ground truth (request awareness) or retrospective label.
    pub reused: bool,
}

/// Shared counters for a sample channel (all sender clones).
#[derive(Debug)]
struct SampleCounters {
    sent: AtomicU64,
    dropped: AtomicU64,
}

impl SampleCounters {
    /// Zeroed counters (explicit because loom atomics lack `Default`).
    fn new() -> Self {
        SampleCounters { sent: AtomicU64::new(0), dropped: AtomicU64::new(0) }
    }
}

/// Cloneable, never-blocking emitter of labeled samples. When the bounded
/// channel is full (trainer busy) the sample is dropped and counted —
/// shard workers must not stall on the learning path.
#[derive(Debug, Clone)]
pub struct SampleSender {
    tx: SyncSender<LabeledSample>,
    counters: Arc<SampleCounters>,
}

impl SampleSender {
    /// Emit one labeled sample; returns whether it was accepted.
    pub fn emit(&self, features: FeatureVec, reused: bool) -> bool {
        match self.tx.try_send(LabeledSample { features, reused }) {
            Ok(()) => {
                self.counters.sent.fetch_add(1, Ordering::Relaxed);
                true
            }
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                // Warn exactly once per channel (first drop wins the race;
                // Relaxed is fine — double-logging under contention would
                // merely repeat a diagnostic). Per-drop logging would melt
                // the hot path during sustained saturation; the running
                // totals live in the `samples.dropped` / `drop_rate_ppm`
                // gauges instead.
                let prev = self.counters.dropped.fetch_add(1, Ordering::Relaxed);
                if prev == 0 {
                    log::warn!(
                        "sample channel saturated: dropping labeled samples \
                         (see samples.dropped / samples.drop_rate_ppm gauges)"
                    );
                }
                false
            }
        }
    }

    /// Samples accepted across all clones of this sender.
    pub fn sent(&self) -> u64 {
        self.counters.sent.load(Ordering::Relaxed)
    }

    /// Samples dropped (channel full / trainer gone) across all clones.
    pub fn dropped(&self) -> u64 {
        self.counters.dropped.load(Ordering::Relaxed)
    }

    /// A counters-only handle. Unlike a sender clone it does NOT keep the
    /// channel connected, so it can outlive the senders and read the final
    /// totals after the trainer observed the disconnect.
    pub fn probe(&self) -> SampleProbe {
        SampleProbe { counters: Arc::clone(&self.counters) }
    }
}

/// Read-only view of a sample channel's counters (see
/// [`SampleSender::probe`]).
#[derive(Debug, Clone)]
pub struct SampleProbe {
    counters: Arc<SampleCounters>,
}

impl SampleProbe {
    /// Samples accepted into the channel.
    pub fn sent(&self) -> u64 {
        self.counters.sent.load(Ordering::Relaxed)
    }

    /// Samples dropped because the channel was full.
    pub fn dropped(&self) -> u64 {
        self.counters.dropped.load(Ordering::Relaxed)
    }

    /// Expose the counters as `{prefix}.sent` / `{prefix}.dropped` /
    /// `{prefix}.drop_rate_ppm` gauges — the accessor API stays the
    /// programmatic view, the gauges put the same cells in the
    /// `--metrics-out` JSONL. The drop rate is parts-per-million of all
    /// emit attempts, so saturation is visible at a glance without
    /// cross-referencing two counters.
    pub fn register_gauges(&self, registry: &crate::obs::MetricsRegistry, prefix: &str) {
        let counters = Arc::clone(&self.counters);
        registry.gauge(&format!("{prefix}.sent"), move || {
            counters.sent.load(Ordering::Relaxed)
        });
        let counters = Arc::clone(&self.counters);
        registry.gauge(&format!("{prefix}.dropped"), move || {
            counters.dropped.load(Ordering::Relaxed)
        });
        let counters = Arc::clone(&self.counters);
        registry.gauge(&format!("{prefix}.drop_rate_ppm"), move || {
            let sent = counters.sent.load(Ordering::Relaxed);
            let dropped = counters.dropped.load(Ordering::Relaxed);
            let total = sent + dropped;
            if total == 0 {
                0
            } else {
                dropped * 1_000_000 / total
            }
        });
    }
}

/// A bounded sample channel: `(emitter, trainer-side receiver)`. The
/// bound is the backpressure limit — beyond it, [`SampleSender::emit`]
/// drops instead of blocking.
pub fn sample_channel(bound: usize) -> (SampleSender, Receiver<LabeledSample>) {
    let (tx, rx) = mpsc::sync_channel(bound.max(1));
    (
        SampleSender { tx, counters: Arc::new(SampleCounters::new()) },
        rx,
    )
}

// -------------------------------------------------------------- trainer

/// Cadence knobs for the background trainer.
#[derive(Debug, Clone, Copy)]
pub struct TrainerConfig {
    /// First training once this many samples accumulated.
    pub min_samples: usize,
    /// Retrain every this many *new* observations after that.
    pub retrain_interval: usize,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig { min_samples: 32, retrain_interval: 64 }
    }
}

/// What the trainer did over its lifetime.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TrainerReport {
    /// Samples received from the channel.
    pub samples: u64,
    /// Retrainings performed.
    pub trainings: u64,
    /// Snapshots published (== trainings when the backend exports).
    pub publishes: u64,
    /// Version of the last published snapshot (0 = never published).
    pub final_version: u64,
    /// Training rounds that failed (resilient loop only — the plain
    /// [`trainer_loop`] propagates the first error instead of counting).
    pub train_errors: u64,
    /// Injected trainer crashes survived (resilient loop only).
    pub restarts: u64,
    /// Samples consumed after the last publish — the staleness of the
    /// serving snapshot at loop exit, measured on the sample stream's own
    /// clock (counts, like everything else on this path, stay
    /// deterministic). 0 right after a publish; equal to `samples` when
    /// nothing was ever published.
    pub stale_samples: u64,
}

/// The background trainer body: drain `rx` into `pipeline`, retrain
/// `backend` on the pipeline's cadence and publish every fresh model to
/// `cell`. Returns when every [`SampleSender`] clone is dropped, after
/// draining the queue — so a short trace still gets its final retrain
/// published.
///
/// Run it on a scoped thread next to the shard workers (see
/// [`crate::sim::parallel::FanoutOptions::background`]) or a detached
/// `std::thread` for long-lived deployments.
pub fn trainer_loop(
    rx: Receiver<LabeledSample>,
    backend: &mut dyn SvmBackend,
    pipeline: &mut TrainingPipeline,
    cell: &SnapshotCell,
) -> Result<TrainerReport> {
    let mut report = TrainerReport::default();
    let mut samples_at_publish = 0u64;
    while let Ok(sample) = rx.recv() {
        report.samples += 1;
        pipeline.observe(sample.features, sample.reused);
        if pipeline.maybe_train(backend)? {
            report.trainings += 1;
            if let Some(model) = backend.export_model() {
                report.final_version = cell.publish(model);
                report.publishes += 1;
                samples_at_publish = report.samples;
            }
        }
    }
    // Senders gone: train once more on whatever arrived since the last
    // cadence point, so the published model covers the full stream.
    if pipeline.pending_since_train() > 0 && pipeline.train_now(backend)? {
        report.trainings += 1;
        if let Some(model) = backend.export_model() {
            report.final_version = cell.publish(model);
            report.publishes += 1;
            samples_at_publish = report.samples;
        }
    }
    report.stale_samples =
        if report.publishes > 0 { report.samples - samples_at_publish } else { report.samples };
    Ok(report)
}

/// The graceful-degradation variant of [`trainer_loop`]: training errors
/// are counted and logged instead of aborting the loop (shard workers keep
/// serving the last published snapshot), and injected trainer crashes —
/// sample-count thresholds from
/// [`FaultPlan::trainer_crash_points`](crate::sim::FaultPlan) — reset the
/// pipeline's in-flight buffer, modeling a trainer process restart that
/// loses its accumulation window but never the published model (the
/// [`SnapshotCell`] is the durable hand-off point).
///
/// With `injector == None` and an error-free backend this behaves exactly
/// like [`trainer_loop`]; the plain loop stays the baseline (it propagates
/// the first training error, the pre-existing contract).
pub fn trainer_loop_resilient(
    rx: Receiver<LabeledSample>,
    backend: &mut dyn SvmBackend,
    pipeline: &mut TrainingPipeline,
    cell: &SnapshotCell,
    injector: Option<&crate::sim::FaultInjector>,
) -> Result<TrainerReport> {
    let crash_points: Vec<u64> =
        injector.map(|i| i.plan().trainer_crash_points()).unwrap_or_default();
    let mut next_crash = 0usize;
    let mut report = TrainerReport::default();
    let mut samples_at_publish = 0u64;
    while let Ok(sample) = rx.recv() {
        report.samples += 1;
        // Injected crash: the restarting trainer loses its buffered window
        // (and this sample), keeps its published snapshots, and resumes
        // accumulating from empty.
        if next_crash < crash_points.len() && report.samples >= crash_points[next_crash] {
            next_crash += 1;
            pipeline.reset();
            report.restarts += 1;
            if let Some(inj) = injector {
                inj.note_trainer_crash();
            }
            log::warn!(
                "injected trainer crash at sample {}: buffer lost, snapshot v{} still serving",
                report.samples,
                report.final_version
            );
            continue;
        }
        pipeline.observe(sample.features, sample.reused);
        match pipeline.maybe_train(backend) {
            Ok(true) => publish(backend, cell, &mut report, &mut samples_at_publish),
            Ok(false) => {}
            Err(e) => {
                report.train_errors += 1;
                log::warn!("training failed (still serving snapshot v{}): {e:#}", report.final_version);
            }
        }
    }
    if pipeline.pending_since_train() > 0 {
        match pipeline.train_now(backend) {
            Ok(true) => publish(backend, cell, &mut report, &mut samples_at_publish),
            Ok(false) => {}
            Err(e) => {
                report.train_errors += 1;
                log::warn!("final drain training failed: {e:#}");
            }
        }
    }
    report.stale_samples =
        if report.publishes > 0 { report.samples - samples_at_publish } else { report.samples };
    Ok(report)
}

/// Shared publish tail of the trainer loops: export the freshly trained
/// model (when the backend can), publish it, and move the staleness
/// anchor to the current sample count.
fn publish(
    backend: &mut dyn SvmBackend,
    cell: &SnapshotCell,
    report: &mut TrainerReport,
    samples_at_publish: &mut u64,
) {
    report.trainings += 1;
    if let Some(model) = backend.export_model() {
        report.final_version = cell.publish(model);
        report.publishes += 1;
        *samples_at_publish = report.samples;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::RustBackend;
    use crate::svm::features::N_FEATURES;
    use crate::svm::kernel::{KernelKind, KernelParams};

    /// A model whose decision is a constant: sign(bias).
    fn constant_model(bias: f32) -> SmoModel {
        SmoModel::new(
            KernelParams::new(KernelKind::Linear),
            Vec::new(),
            Vec::new(),
            Vec::new(),
            bias,
        )
    }

    fn fv(v: f32) -> FeatureVec {
        let mut f = [0.0f32; N_FEATURES];
        f[0] = v;
        f
    }

    #[test]
    fn untrained_snapshot_predicts_none() {
        let s = ClassifierSnapshot::untrained();
        assert_eq!(s.version(), 0);
        assert!(!s.is_trained());
        assert_eq!(s.predict(&fv(0.9)), None);
        assert_eq!(s.decision(&fv(0.9)), None);
    }

    #[test]
    fn publish_bumps_version_and_swaps_model() {
        let cell = Arc::new(SnapshotCell::new());
        assert_eq!(cell.version(), 0);
        let mut reader = cell.reader();
        assert_eq!(reader.predict(&fv(0.5)), None);

        assert_eq!(cell.publish(constant_model(1.0)), 1);
        assert_eq!(cell.version(), 1);
        assert_eq!(reader.predict(&fv(0.5)), Some(true));
        assert_eq!(reader.refreshes(), 1);

        assert_eq!(cell.publish(constant_model(-1.0)), 2);
        assert_eq!(reader.predict(&fv(0.5)), Some(false));
        assert_eq!(reader.refreshes(), 2);
        // No new publish: the reader stays on its cached Arc.
        assert_eq!(reader.predict(&fv(0.5)), Some(false));
        assert_eq!(reader.refreshes(), 2);
    }

    #[test]
    fn snapshot_backend_scores_through_published_models() {
        let cell = Arc::new(SnapshotCell::new());
        let mut be = SnapshotBackend::new(Arc::clone(&cell));
        assert!(!be.is_trained());
        assert!(be.decision_batch(&[fv(0.5)]).is_err(), "unpublished = untrained");
        assert!(be.train(&crate::svm::Dataset::new()).is_err(), "read-only");
        cell.publish(constant_model(1.0));
        assert!(be.is_trained());
        assert_eq!(be.version(), 1);
        let scores = be.decision_batch(&[fv(0.1), fv(0.9)]).unwrap();
        assert!(scores.iter().all(|&s| s > 0.0));
        cell.publish(constant_model(-1.0));
        let scores = be.decision_batch(&[fv(0.1)]).unwrap();
        assert!(scores[0] < 0.0, "publish reaches the backend");
        assert_eq!(be.refreshes(), 2);
    }

    #[test]
    fn sample_channel_counts_drops_when_full() {
        let (tx, rx) = sample_channel(2);
        assert!(tx.emit(fv(0.1), true));
        assert!(tx.emit(fv(0.2), false));
        assert!(!tx.emit(fv(0.3), true), "third emit exceeds the bound");
        assert_eq!(tx.sent(), 2);
        assert_eq!(tx.dropped(), 1);
        drop(rx);
        assert!(!tx.emit(fv(0.4), true), "disconnected channel drops");
        assert_eq!(tx.dropped(), 2);
    }

    #[test]
    fn sample_probe_gauges_mirror_the_accessors() {
        let registry = crate::obs::MetricsRegistry::new();
        let (tx, _rx) = sample_channel(1);
        tx.probe().register_gauges(&registry, "samples");
        assert!(tx.emit(fv(0.1), true));
        assert!(!tx.emit(fv(0.2), false), "bound 1: second emit drops");
        let gauges = registry.gauge_values();
        assert_eq!(
            gauges,
            vec![
                ("samples.drop_rate_ppm".to_string(), 500_000),
                ("samples.dropped".to_string(), 1),
                ("samples.sent".to_string(), 1),
            ]
        );
    }

    #[test]
    fn drop_rate_gauge_is_zero_before_any_emit() {
        let registry = crate::obs::MetricsRegistry::new();
        let (tx, _rx) = sample_channel(4);
        tx.probe().register_gauges(&registry, "samples");
        let gauges = registry.gauge_values();
        assert!(gauges.iter().all(|(_, v)| *v == 0), "{gauges:?}");
    }

    #[test]
    fn trainer_loop_trains_and_publishes() {
        let (tx, rx) = sample_channel(1024);
        let cell = Arc::new(SnapshotCell::new());
        let mut backend = RustBackend::new(KernelKind::Rbf);
        let mut pipeline = TrainingPipeline::new(8, 16);
        // Two separable classes, enough for several cadence points.
        for i in 0..64 {
            let reused = i % 2 == 0;
            tx.emit(fv(if reused { 0.2 } else { 0.8 }), reused);
        }
        drop(tx);
        let report = trainer_loop(rx, &mut backend, &mut pipeline, &cell).unwrap();
        assert_eq!(report.samples, 64);
        assert!(report.trainings >= 1, "{report:?}");
        assert_eq!(report.publishes, report.trainings, "rust backend exports");
        assert_eq!(report.final_version, cell.version());
        assert!(cell.version() >= 1);
        // The published snapshot separates the classes.
        let snap = cell.load();
        assert_eq!(snap.predict(&fv(0.2)), Some(true));
        assert_eq!(snap.predict(&fv(0.8)), Some(false));
    }

    #[test]
    fn trainer_loop_single_class_never_publishes() {
        let (tx, rx) = sample_channel(64);
        let cell = Arc::new(SnapshotCell::new());
        let mut backend = RustBackend::new(KernelKind::Rbf);
        let mut pipeline = TrainingPipeline::new(4, 4);
        for i in 0..32 {
            tx.emit(fv(i as f32 / 32.0), false);
        }
        drop(tx);
        let report = trainer_loop(rx, &mut backend, &mut pipeline, &cell).unwrap();
        assert_eq!(report.samples, 32);
        assert_eq!(report.trainings, 0);
        assert_eq!(report.publishes, 0);
        assert_eq!(cell.version(), 0, "nothing to publish from one class");
        assert_eq!(report.stale_samples, 32, "never published: the whole stream is stale");
    }

    #[test]
    fn trainer_drains_after_disconnect_and_publishes_the_tail() {
        let (tx, rx) = sample_channel(1024);
        let cell = Arc::new(SnapshotCell::new());
        let mut backend = RustBackend::new(KernelKind::Rbf);
        // min_samples larger than the stream: no cadence training fires,
        // only the final drain training covers the tail.
        let mut pipeline = TrainingPipeline::new(1000, 1000);
        for i in 0..20 {
            let reused = i % 2 == 0;
            tx.emit(fv(if reused { 0.1 } else { 0.9 }), reused);
        }
        drop(tx);
        let report = trainer_loop(rx, &mut backend, &mut pipeline, &cell).unwrap();
        assert_eq!(report.trainings, 1, "drain training");
        assert_eq!(report.publishes, 1);
        assert_eq!(cell.version(), 1);
        assert_eq!(report.stale_samples, 0, "the drain publish covers the whole stream");
    }

    // ------------------------------------------------ resilient trainer

    use crate::sim::{FaultEvent, FaultInjector, FaultPlan};

    fn alternating_stream(tx: &SampleSender, n: usize) {
        for i in 0..n {
            let reused = i % 2 == 0;
            tx.emit(fv(if reused { 0.2 } else { 0.8 }), reused);
        }
    }

    #[test]
    fn resilient_loop_without_faults_matches_plain_loop() {
        let run_plain = || {
            let (tx, rx) = sample_channel(1024);
            let cell = Arc::new(SnapshotCell::new());
            let mut backend = RustBackend::new(KernelKind::Rbf);
            let mut pipeline = TrainingPipeline::new(8, 16);
            alternating_stream(&tx, 64);
            drop(tx);
            trainer_loop(rx, &mut backend, &mut pipeline, &cell).unwrap()
        };
        let run_resilient = |injector: Option<&FaultInjector>| {
            let (tx, rx) = sample_channel(1024);
            let cell = Arc::new(SnapshotCell::new());
            let mut backend = RustBackend::new(KernelKind::Rbf);
            let mut pipeline = TrainingPipeline::new(8, 16);
            alternating_stream(&tx, 64);
            drop(tx);
            trainer_loop_resilient(rx, &mut backend, &mut pipeline, &cell, injector).unwrap()
        };
        let all_clear = FaultInjector::new(FaultPlan::all_clear(7));
        assert_eq!(run_plain(), run_resilient(None));
        assert_eq!(run_plain(), run_resilient(Some(&all_clear)));
    }

    #[test]
    fn resilient_loop_counts_train_errors_instead_of_aborting() {
        /// Training always fails; predictions would work if it trained.
        struct FailingTrain;
        impl SvmBackend for FailingTrain {
            fn name(&self) -> &'static str {
                "failing-train"
            }
            fn train(&mut self, _ds: &crate::svm::Dataset) -> Result<()> {
                anyhow::bail!("injected train failure")
            }
            fn decision_batch(&mut self, q: &[FeatureVec]) -> Result<Vec<f32>> {
                Ok(vec![0.0; q.len()])
            }
            fn is_trained(&self) -> bool {
                false
            }
        }
        let (tx, rx) = sample_channel(1024);
        let cell = Arc::new(SnapshotCell::new());
        let mut backend = FailingTrain;
        let mut pipeline = TrainingPipeline::new(8, 16);
        alternating_stream(&tx, 64);
        drop(tx);
        let report =
            trainer_loop_resilient(rx, &mut backend, &mut pipeline, &cell, None).unwrap();
        assert_eq!(report.samples, 64);
        assert_eq!(report.trainings, 0);
        assert!(report.train_errors >= 1, "{report:?}");
        assert_eq!(cell.version(), 0, "nothing published, snapshot stays v0");
    }

    #[test]
    fn injected_trainer_crash_loses_buffer_but_keeps_snapshot() {
        let plan =
            FaultPlan::all_clear(3).with_event(FaultEvent::TrainerCrash { after_samples: 40 });
        let injector = FaultInjector::new(plan);
        let (tx, rx) = sample_channel(1024);
        let cell = Arc::new(SnapshotCell::new());
        let mut backend = RustBackend::new(KernelKind::Rbf);
        let mut pipeline = TrainingPipeline::new(8, 16);
        alternating_stream(&tx, 96);
        drop(tx);
        let report =
            trainer_loop_resilient(rx, &mut backend, &mut pipeline, &cell, Some(&injector))
                .unwrap();
        assert_eq!(report.samples, 96, "the crash never stops the loop");
        assert_eq!(report.restarts, 1);
        assert_eq!(injector.trainer_crashes(), 1);
        assert!(report.trainings >= 2, "retrains before AND after the crash: {report:?}");
        assert_eq!(report.final_version, cell.version());
        assert!(cell.version() >= 1, "published snapshots survive the restart");
    }
}
