//! SVM-gated sequential prefetching — the paper's stated future work
//! (§7: "extend intelligent caching by applying machine learning
//! techniques to prefetch requested data from HDFS").
//!
//! MapReduce tasks scan input files block-by-block, so a read of block
//! `i` of a file strongly predicts reads of `i+1..i+depth`. The prefetcher
//! tracks per-file progress and proposes the next blocks; the coordinator
//! only caches a proposal when the SVM classifies it as "reused in the
//! future" — the same classifier that drives replacement gates admission,
//! keeping pollution out of the prefetch path too.

use crate::hdfs::BlockId;
use crate::util::fasthash::IdHashMap;

/// Per-file sequential-scan detector state.
#[derive(Debug, Clone, Copy)]
struct FileScan {
    /// Highest block index observed.
    last_index: u32,
    /// Consecutive in-order observations (confidence).
    streak: u32,
}

/// Prefetch statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefetchStats {
    /// Proposals emitted to the coordinator.
    pub proposed: u64,
    /// Proposals the classifier admitted and the cache accepted.
    pub inserted: u64,
    /// Hits on blocks that were in cache because of a prefetch.
    pub useful_hits: u64,
}

/// Sequential prefetcher.
#[derive(Debug)]
pub struct Prefetcher {
    /// Blocks ahead of the scan front to propose.
    depth: u32,
    /// In-order observations required before prefetching starts.
    min_streak: u32,
    scans: IdHashMap<u64, FileScan>,
    /// Blocks currently cached due to prefetch (for usefulness tracking).
    prefetched: IdHashMap<BlockId, ()>,
    /// Prefetch telemetry (issued, inserted, useful hits).
    pub stats: PrefetchStats,
}

impl Prefetcher {
    /// A prefetcher issuing up to `depth` readahead blocks per trigger.
    pub fn new(depth: u32) -> Self {
        Prefetcher {
            depth,
            min_streak: 2,
            scans: IdHashMap::default(),
            prefetched: IdHashMap::default(),
            stats: PrefetchStats::default(),
        }
    }

    /// Configured readahead depth.
    pub fn depth(&self) -> u32 {
        self.depth
    }

    /// Record an access to `(file, index)`; returns the block indexes to
    /// prefetch (empty until a sequential streak is established).
    pub fn observe(&mut self, file: u64, index: u32) -> Vec<u32> {
        let scan = self.scans.entry(file).or_insert(FileScan { last_index: index, streak: 0 });
        if index == scan.last_index + 1 || (index == scan.last_index && scan.streak == 0) {
            scan.streak += 1;
        } else if index > scan.last_index {
            scan.streak = 1;
        } else {
            // Backward/random access: lose confidence.
            scan.streak = scan.streak.saturating_sub(1);
        }
        scan.last_index = scan.last_index.max(index);
        if scan.streak < self.min_streak {
            return Vec::new();
        }
        let from = scan.last_index + 1;
        let proposals: Vec<u32> = (from..from + self.depth).collect();
        self.stats.proposed += proposals.len() as u64;
        proposals
    }

    /// The coordinator confirms it cached a proposed block.
    pub fn note_inserted(&mut self, block: BlockId) {
        self.stats.inserted += 1;
        self.prefetched.insert(block, ());
    }

    /// A cache hit landed; credit the prefetcher if it staged the block.
    /// Returns true when the hit was prefetch-induced (first use only).
    pub fn note_hit(&mut self, block: BlockId) -> bool {
        if self.prefetched.remove(&block).is_some() {
            self.stats.useful_hits += 1;
            true
        } else {
            false
        }
    }

    /// A block left the cache; it can no longer claim prefetch credit.
    pub fn note_evicted(&mut self, block: BlockId) {
        self.prefetched.remove(&block);
    }

    /// Fraction of prefetched blocks that produced a hit before eviction.
    pub fn usefulness(&self) -> f64 {
        if self.stats.inserted == 0 {
            0.0
        } else {
            self.stats.useful_hits as f64 / self.stats.inserted as f64
        }
    }

    /// Drop all scan state and telemetry (fresh run).
    pub fn reset(&mut self) {
        self.scans.clear();
        self.prefetched.clear();
        self.stats = PrefetchStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_scan_triggers_prefetch() {
        let mut p = Prefetcher::new(2);
        assert!(p.observe(1, 0).is_empty(), "no confidence yet");
        let proposals = p.observe(1, 1);
        assert_eq!(proposals, vec![2, 3], "streak of 2 -> prefetch ahead");
        let proposals = p.observe(1, 2);
        assert_eq!(proposals, vec![3, 4]);
    }

    #[test]
    fn random_access_suppresses_prefetch() {
        let mut p = Prefetcher::new(2);
        p.observe(1, 5);
        assert!(p.observe(1, 1).is_empty(), "backward jump");
        assert!(p.observe(1, 3).is_empty(), "still below streak");
    }

    #[test]
    fn files_tracked_independently() {
        let mut p = Prefetcher::new(1);
        p.observe(1, 0);
        p.observe(2, 7);
        assert_eq!(p.observe(1, 1), vec![2]);
        assert_eq!(p.observe(2, 8), vec![9]);
    }

    #[test]
    fn usefulness_accounting() {
        let mut p = Prefetcher::new(1);
        p.note_inserted(BlockId(10));
        p.note_inserted(BlockId(11));
        assert!(p.note_hit(BlockId(10)));
        assert!(!p.note_hit(BlockId(10)), "credit only once");
        assert!(!p.note_hit(BlockId(99)), "unprefetched block");
        p.note_evicted(BlockId(11));
        assert!(!p.note_hit(BlockId(11)), "evicted before use");
        assert!((p.usefulness() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn reset_clears_state() {
        let mut p = Prefetcher::new(2);
        p.observe(1, 0);
        p.observe(1, 1);
        p.note_inserted(BlockId(2));
        p.reset();
        assert_eq!(p.stats.proposed, 0);
        assert!(p.observe(1, 5).is_empty());
    }
}
