//! The centralized cache coordinator — the paper's Algorithm 1 running on
//! the NameNode.
//!
//! The coordinator owns the per-DataNode off-heap caches (the NameNode is
//! the single decision point; DataNodes only execute cache/uncache
//! commands), the replacement policy instances, the SVM classifier
//! (batched through a per-shard `BatcherPool`) and the online training
//! pipeline.
//!
//! Request flow (`read_block`, called by the MapReduce scheduler):
//!
//! 1. look the block up in the cache metadata — **GetCache** on a hit:
//!    classify the block, move it within the LRU stack per its class, and
//!    serve from memory (plus a network hop when remote);
//! 2. otherwise **PutCache**: serve from the first disk replica, then cache
//!    the block on that DataNode, evicting per policy when space is needed.
//!
//! Labels for online training are *retrospective*: a block's features at
//! access time become a positive sample when the block is re-accessed, and
//! a negative sample when no reuse happens within a window — exactly the
//! "reused in the future or not" semantics without an oracle. Trace replay
//! (`handle_trace_request`) can instead use the request-awareness labels
//! carried by the trace (§5.1 scenario 1).

use std::collections::HashMap;
use std::sync::Arc;

use crate::util::fasthash::IdHashMap;

use anyhow::Result;

use crate::cache::{AccessContext, CacheAffinity, CacheBuilder, ShardStats, ShardedCache};
use crate::hdfs::{
    classify, service_time, BlockId, BlockKind, BlockLocation, DataNodeId, ReadSource,
};
use crate::mapreduce::{AccessRequest, BlockRead, BlockService};
use crate::runtime::SvmBackend;
use crate::sim::{SimDuration, SimTime};
use crate::svm::features::{BlockStatsTracker, FeatureVec};
use crate::workload::{BlockRequest, Cluster};

use super::batcher::{BatcherConfig, BatcherPool, BatcherProbe};
use super::online::SnapshotCell;
use super::prefetcher::Prefetcher;
use super::training_pipeline::TrainingPipeline;

/// Coordinator operating mode.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CacheMode {
    /// H-NoCache baseline: every read goes to disk.
    NoCache,
    /// In-memory caching with the named replacement policy.
    Cached { policy: String },
}

/// Aggregated request-path statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct CoordinatorStats {
    /// Total block read requests.
    pub requests: u64,
    /// Requests served from a cache (local or remote).
    pub hits: u64,
    /// Requests served from disk.
    pub misses: u64,
    /// Total bytes requested.
    pub bytes_requested: u64,
    /// Bytes served from cache.
    pub bytes_from_cache: u64,
    /// Blocks evicted to make room.
    pub evictions: u64,
    /// Blocks inserted into a cache.
    pub insertions: u64,
}

impl CoordinatorStats {
    /// Fraction of requests served from cache (0.0 with no requests).
    pub fn hit_ratio(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.hits as f64 / self.requests as f64
        }
    }

    /// Fraction of requested bytes served from cache.
    pub fn byte_hit_ratio(&self) -> f64 {
        if self.bytes_requested == 0 {
            0.0
        } else {
            self.bytes_from_cache as f64 / self.bytes_requested as f64
        }
    }
}

/// A pending retrospective label: features at the time of an access.
#[derive(Debug, Clone, Copy)]
struct PendingLabel {
    features: FeatureVec,
    at: SimTime,
}

/// The coordinator.
pub struct CacheCoordinator {
    /// The simulated cluster (NameNode metadata + DataNode resources).
    pub cluster: Cluster,
    mode: CacheMode,
    /// One sharded cache per DataNode (`cfg.cache_shards` independently
    /// locked policy instances each); empty in NoCache mode.
    caches: Vec<ShardedCache>,
    backend: Option<Box<dyn SvmBackend>>,
    /// One bounded prediction batcher per cache shard (routed by the same
    /// hash as the shards): per-shard cold-query queues with
    /// `cfg.cache_batch_queue` / `cfg.cache_batch_deadline_ms` bounding
    /// the cold-query rate, and per-shard invalidation with pool-wide
    /// model-version fan-out.
    batchers: BatcherPool,
    /// Online training pipeline (label buffer + retrain cadence).
    pub pipeline: TrainingPipeline,
    /// Per-block access statistics feeding the SVM features.
    pub tracker: BlockStatsTracker,
    /// Request-path counters.
    pub stats: CoordinatorStats,
    /// Whether the active policy consumes SVM predictions.
    svm_enabled: bool,
    pending_labels: IdHashMap<BlockId, PendingLabel>,
    /// Reuse window for retrospective negative labels.
    label_window: SimDuration,
    requests_since_sweep: u64,
    app_ids: HashMap<String, u64>,
    /// Unique suffix for per-run shuffle file names.
    intermediate_seq: u64,
    /// Optional SVM-gated sequential prefetcher (paper §7 future work).
    prefetcher: Option<Prefetcher>,
    /// Snapshot publication point (`coordinator::online`). The
    /// single-threaded coordinator is a degenerate participant in the
    /// online protocol: every deployed model is exported here, so shard
    /// workers, tests and dashboards can consume exactly the classifier
    /// the coordinator batches its own predictions through.
    snapshots: Arc<SnapshotCell>,
}

impl CacheCoordinator {
    /// Create a coordinator. `backend` is required when the policy is
    /// "h-svm-lru" (or any predictor-consuming policy) and ignored for
    /// NoCache.
    pub fn new(
        cluster: Cluster,
        mode: CacheMode,
        backend: Option<Box<dyn SvmBackend>>,
    ) -> Result<Self> {
        let (caches, svm_enabled) = match &mode {
            CacheMode::NoCache => (Vec::new(), false),
            CacheMode::Cached { policy } => {
                let shards = cluster.cfg.cache_shards.max(1);
                let admission = cluster.cfg.cache_admission.as_str();
                let caches = (0..cluster.cfg.datanodes)
                    .map(|_| {
                        CacheBuilder::new()
                            .policy(policy)
                            .admission(admission)
                            .shards(shards)
                            .capacity(cluster.cfg.cache_capacity_per_node)
                            .recency(cluster.cfg.recency_config())
                            .build()
                            .map_err(anyhow::Error::from)
                    })
                    .collect::<Result<Vec<_>>>()?;
                // The SVM must score requests when either the eviction
                // policy or the admission layer consumes predictions.
                let uses_svm =
                    matches!(policy.as_str(), "h-svm-lru" | "autocache") || admission == "svm";
                (caches, uses_svm)
            }
        };
        if svm_enabled && backend.is_none() {
            anyhow::bail!("policy or admission requires an SVM backend but none was provided");
        }
        let block_size = cluster.cfg.block_size;
        let batcher_cfg = BatcherConfig {
            queue_depth: cluster.cfg.cache_batch_queue.max(1),
            // Simulated milliseconds: flush timing is driven by the
            // request clock, so seeded runs stay bit-for-bit reproducible.
            deadline: SimDuration::from_micros(
                cluster.cfg.cache_batch_deadline_ms.saturating_mul(1000),
            ),
            ..BatcherConfig::default()
        };
        let batcher_shards = cluster.cfg.cache_shards.max(1);
        Ok(CacheCoordinator {
            cluster,
            mode,
            caches,
            backend,
            batchers: BatcherPool::new(batcher_shards, batcher_cfg),
            pipeline: TrainingPipeline::new(32, 128),
            tracker: BlockStatsTracker::new(block_size),
            stats: CoordinatorStats::default(),
            svm_enabled,
            pending_labels: IdHashMap::default(),
            label_window: SimDuration::from_secs_f64(180.0),
            requests_since_sweep: 0,
            app_ids: HashMap::new(),
            intermediate_seq: 0,
            prefetcher: None,
            snapshots: Arc::new(SnapshotCell::new()),
        })
    }

    /// Enable sequential prefetching `depth` blocks ahead (classifier-gated
    /// when the policy is SVM-driven; unconditional otherwise).
    pub fn with_prefetch(mut self, depth: u32) -> Self {
        if !matches!(self.mode, CacheMode::NoCache) {
            self.prefetcher = Some(Prefetcher::new(depth));
        }
        self
    }

    /// Prefetcher telemetry, when prefetching is enabled.
    pub fn prefetch_stats(&self) -> Option<super::prefetcher::PrefetchStats> {
        self.prefetcher.as_ref().map(|p| p.stats)
    }

    /// The operating mode this coordinator was built with.
    pub fn mode(&self) -> &CacheMode {
        &self.mode
    }

    /// Active replacement-policy name ("no-cache" in NoCache mode).
    pub fn policy_name(&self) -> &str {
        match &self.mode {
            CacheMode::NoCache => "no-cache",
            CacheMode::Cached { policy } => policy,
        }
    }

    /// Name of the SVM backend ("none" when no classifier is attached).
    pub fn backend_name(&self) -> &'static str {
        self.backend.as_ref().map(|b| b.name()).unwrap_or("none")
    }

    /// Active admission policy ("none" in NoCache mode).
    pub fn admission_name(&self) -> &'static str {
        self.caches.first().map(|c| c.admission_name()).unwrap_or("none")
    }

    /// Class-cache telemetry merged across the per-shard batchers.
    pub fn batcher_stats(&self) -> super::batcher::BatcherStats {
        self.batchers.stats()
    }

    /// Cold-query queue counters (deferred / flush / drop / latency) of
    /// the per-shard batcher pool.
    pub fn batcher_probe(&self) -> BatcherProbe {
        self.batchers.probe()
    }

    /// Prediction batchers per DataNode cache (mirrors `cache_shards`).
    pub fn batcher_shards(&self) -> usize {
        self.batchers.n_shards()
    }

    fn app_id(&mut self, app: &str) -> u64 {
        let next = self.app_ids.len() as u64;
        *self.app_ids.entry(app.to_string()).or_insert(next)
    }

    /// SVM class for a block, or None when the classifier isn't ready.
    fn predict_class(
        &mut self,
        block: BlockId,
        features: FeatureVec,
        now: SimTime,
    ) -> Option<bool> {
        if !self.svm_enabled {
            return None;
        }
        let backend = self.backend.as_mut()?;
        if !backend.is_trained() {
            return None;
        }
        // Quantized feature stamp: the class cache stays valid while the
        // block's frequency bucket is unchanged (the log-scaled frequency
        // feature moves between buckets, recency rarely flips the class).
        // Re-scoring per access costs a PJRT call; per bucket it's ~free.
        let accesses = self.tracker.accesses(block);
        let stamp = if accesses < 4 { accesses } else { 63 - accesses.leading_zeros() as u64 + 4 };
        match self
            .batchers
            .predict(backend.as_mut(), block, stamp, features, now)
        {
            // `None` = the query was deferred into the shard's cold queue
            // (only with cache_batch_queue > 1): this access falls back to
            // unclassified-LRU behavior and the class lands in the cache
            // when the queue fills or the deadline lapses.
            Ok(class) => class,
            Err(e) => {
                log::warn!("prediction failed, falling back to LRU: {e:#}");
                None
            }
        }
    }

    /// Retrospective labeling: the current access proves the *previous*
    /// access's features led to reuse.
    fn observe_reuse(&mut self, block: BlockId, features: FeatureVec, now: SimTime) {
        if let Some(prev) = self.pending_labels.insert(block, PendingLabel { features, at: now })
        {
            self.pipeline.observe(prev.features, true);
        }
        self.requests_since_sweep += 1;
        if self.requests_since_sweep >= 64 {
            self.sweep_stale_labels(now);
        }
    }

    /// Expire pending observations: no reuse within the window = negative.
    /// Also sweeps the per-shard cold-query queues, so deferred
    /// predictions on shards the request stream stopped touching still
    /// flush by their deadline.
    pub fn sweep_stale_labels(&mut self, now: SimTime) {
        self.requests_since_sweep = 0;
        if let Some(backend) = self.backend.as_mut() {
            if backend.is_trained() {
                if let Err(e) = self.batchers.sweep(backend.as_mut(), now) {
                    log::warn!("cold-query deadline sweep failed: {e:#}");
                }
            }
        }
        let window = self.label_window;
        let expired: Vec<BlockId> = self
            .pending_labels
            .iter()
            .filter(|(_, p)| p.at.duration_until(now) >= window)
            .map(|(&b, _)| b)
            .collect();
        for b in expired {
            if let Some(p) = self.pending_labels.remove(&b) {
                self.pipeline.observe(p.features, false);
            }
        }
    }

    /// End-of-workload label flush: every block whose last observation was
    /// never followed by a re-access is a negative sample — Table 4 row 10
    /// ("job Succeeded -> not reused"); positives were already emitted on
    /// re-access. Used by the offline training pass of the experiments.
    pub fn flush_labels_as_negative(&mut self) {
        for (_, p) in std::mem::take(&mut self.pending_labels) {
            self.pipeline.observe(p.features, false);
        }
    }

    /// A new model was deployed: drop every stale cached class on every
    /// per-shard batcher and publish the model as an immutable snapshot
    /// (when the backend can export). The version broadcast reaches
    /// **every** shard batcher — a deployment invalidates the whole pool,
    /// not just the shard that happened to trigger the retrain.
    fn deploy_model(&mut self) {
        self.batchers.invalidate_all();
        if let Some(model) = self.backend.as_ref().and_then(|b| b.export_model()) {
            let version = self.snapshots.publish(model);
            self.batchers.note_model_version(version);
        }
    }

    /// Force a training round on everything observed so far (the paper's
    /// offline training on job history before evaluation).
    pub fn train_now(&mut self) -> Result<bool> {
        let Some(backend) = self.backend.as_mut() else {
            return Ok(false);
        };
        let trained = self.pipeline.train_now(backend.as_mut())?;
        if trained {
            self.deploy_model();
        }
        Ok(trained)
    }

    /// Retrain the classifier if due; invalidates cached classes when a new
    /// model is deployed.
    pub fn maybe_retrain(&mut self) -> Result<bool> {
        let Some(backend) = self.backend.as_mut() else {
            return Ok(false);
        };
        let trained = self.pipeline.maybe_train(backend.as_mut())?;
        if trained {
            self.deploy_model();
        }
        Ok(trained)
    }

    /// The snapshot cell this coordinator publishes deployed models to —
    /// the same type the concurrent online replay reads lock-free.
    pub fn snapshot_cell(&self) -> Arc<SnapshotCell> {
        Arc::clone(&self.snapshots)
    }

    /// Version of the last published classifier snapshot (0 = none yet).
    pub fn snapshot_version(&self) -> u64 {
        self.snapshots.version()
    }

    #[allow(clippy::too_many_arguments)] // mirrors the AccessContext fields
    fn build_ctx(
        &mut self,
        block: BlockId,
        size: u64,
        kind: BlockKind,
        affinity: CacheAffinity,
        req_file: u64,
        file_width: u32,
        file_complete: bool,
        recompute_cost: f64,
        now: SimTime,
    ) -> AccessContext {
        let features =
            self.tracker.features(block, kind, size, affinity, recompute_cost, now);
        let predicted = self.predict_class(block, features, now);
        AccessContext {
            time: now,
            size,
            kind,
            file: req_file,
            file_width,
            file_complete,
            affinity,
            predicted_reuse: predicted,
            recompute_cost,
        }
    }

    /// Core Algorithm 1 step for one request. Returns (source, serving DN).
    fn access(
        &mut self,
        block: BlockId,
        reader: DataNodeId,
        _now: SimTime,
        ctx: AccessContext,
    ) -> (ReadSource, DataNodeId) {
        self.stats.requests += 1;
        self.stats.bytes_requested += ctx.size;

        if matches!(self.mode, CacheMode::NoCache) {
            self.stats.misses += 1;
            let dn = self
                .cluster
                .namenode
                .replicas_of(block)
                .first()
                .copied()
                .unwrap_or(reader);
            let (source, dn) = classify(BlockLocation::OnDisk(dn), reader);
            return (source, dn);
        }

        match self.cluster.namenode.locate(block) {
            Some(BlockLocation::Cached(dn)) => {
                // ---- GetCache: cache hit ----
                self.stats.hits += 1;
                self.stats.bytes_from_cache += ctx.size;
                let outcome = self.caches[dn.0 as usize].access_or_insert(block, &ctx);
                debug_assert!(outcome.hit, "cache metadata said cached");
                classify(BlockLocation::Cached(dn), reader)
            }
            Some(BlockLocation::OnDisk(dn)) => {
                // ---- PutCache: cache miss ----
                self.stats.misses += 1;
                let evicted = self.caches[dn.0 as usize].insert(block, &ctx);
                for victim in &evicted {
                    self.stats.evictions += 1;
                    self.cluster.datanodes[dn.0 as usize].uncache_block(*victim);
                    self.cluster.namenode.note_uncached(*victim);
                    self.batchers.invalidate(*victim);
                }
                if self.caches[dn.0 as usize].contains(block) {
                    self.stats.insertions += 1;
                    let ok = self.cluster.datanodes[dn.0 as usize].cache_block(block, ctx.size);
                    debug_assert!(ok, "DataNode rejected a coordinated cache command");
                    self.cluster.namenode.note_cached(block, dn);
                }
                classify(BlockLocation::OnDisk(dn), reader)
            }
            None => {
                // Unknown block (not registered): treat as a remote disk read.
                self.stats.misses += 1;
                classify(BlockLocation::OnDisk(reader), reader)
            }
        }
    }

    /// Replay one trace request (Fig 3 / Table 7 path). Uses the trace's
    /// request-awareness ground truth for training labels. Returns hit?
    pub fn handle_trace_request(&mut self, req: &BlockRequest) -> Result<bool> {
        let features = self.tracker.features(
            req.block,
            req.kind,
            req.size,
            req.affinity,
            req.recompute_cost,
            req.time,
        );
        // Request-awareness scenario: the label is known at request time.
        self.pipeline.observe(features, req.reused_later);
        let ctx = self.build_ctx(
            req.block,
            req.size,
            req.kind,
            req.affinity,
            req.block.0, // trace blocks are their own files
            1,
            false,
            req.recompute_cost,
            req.time,
        );
        let reader = self
            .cluster
            .namenode
            .replicas_of(req.block)
            .first()
            .copied()
            .unwrap_or(DataNodeId(0));
        let (source, _) = self.access(req.block, reader, req.time, ctx);
        self.tracker.record_access(req.block, 0, req.time);
        self.maybe_retrain()?;
        Ok(source.is_cache())
    }

    /// Prefetch pass: propose the next sequential blocks of the file being
    /// scanned, admit them through the classifier, and stage them in the
    /// cache off the critical path (background disk reads).
    fn run_prefetch(&mut self, block: BlockId, req: &AccessRequest, now: SimTime) {
        if self.prefetcher.is_none() {
            return;
        }
        let Some(info) = self.cluster.namenode.block_info(block).cloned() else {
            return;
        };
        let file_blocks: Vec<BlockId> =
            self.cluster.namenode.files.blocks_of(info.file).to_vec();
        let proposals = self
            .prefetcher
            .as_mut()
            .expect("checked above")
            .observe(info.file, info.index);
        for idx in proposals {
            let Some(&next) = file_blocks.get(idx as usize) else { continue };
            if self.cluster.namenode.is_cached(next) {
                continue;
            }
            let size = self
                .cluster
                .namenode
                .block_info(next)
                .map(|b| b.size)
                .unwrap_or(self.cluster.cfg.block_size);
            let features =
                self.tracker.features(next, info.kind, size, req.affinity, 0.0, now);
            // Classifier gate: only stage blocks predicted to be reused.
            // Without a trained model, prefetch optimistically (sequential
            // scans are the common case the heuristic already filtered).
            if self.predict_class(next, features, now) == Some(false) {
                continue;
            }
            let Some(BlockLocation::OnDisk(dn)) = self.cluster.namenode.locate(next) else {
                continue;
            };
            let ctx = AccessContext {
                time: now,
                size,
                kind: info.kind,
                file: req.file,
                file_width: req.file_width,
                file_complete: false,
                affinity: req.affinity,
                predicted_reuse: Some(true),
                recompute_cost: 0.0,
            };
            let evicted = self.caches[dn.0 as usize].insert(next, &ctx);
            for victim in &evicted {
                self.stats.evictions += 1;
                self.cluster.datanodes[dn.0 as usize].uncache_block(*victim);
                self.cluster.namenode.note_uncached(*victim);
                self.batchers.invalidate(*victim);
                if let Some(pf) = self.prefetcher.as_mut() {
                    pf.note_evicted(*victim);
                }
            }
            if self.caches[dn.0 as usize].contains(next) {
                self.stats.insertions += 1;
                let ok = self.cluster.datanodes[dn.0 as usize].cache_block(next, size);
                debug_assert!(ok, "DataNode rejected prefetch cache command");
                self.cluster.namenode.note_cached(next, dn);
                // The staging read occupies the disk in the background
                // (off the requester's critical path).
                let pure = service_time(&self.cluster.cfg, ReadSource::DiskLocal, size);
                self.cluster.datanodes[dn.0 as usize].disk.acquire(now, pure);
                if let Some(pf) = self.prefetcher.as_mut() {
                    pf.note_inserted(next);
                }
            }
        }
    }

    /// DataNode heartbeat processing: reconcile cache reports (paper §4.1).
    pub fn process_cache_reports(&mut self) -> usize {
        let mut fixes = 0;
        for dn in &self.cluster.datanodes {
            let report = dn.cache_report();
            fixes += self.cluster.namenode.apply_cache_report(dn.id, &report);
        }
        fixes
    }

    /// Reset the caches and counters while keeping the trained classifier:
    /// the measurement pass of a two-pass experiment (offline training on
    /// history, then a cold-cache measured replay — the paper trains on
    /// ALOJA before measuring, §5.1/§6).
    pub fn reset_for_measurement(&mut self) {
        for (dn, cache) in self.cluster.datanodes.iter_mut().zip(&self.caches) {
            for block in cache.cached_blocks() {
                cache.remove(block);
                dn.uncache_block(block);
                self.cluster.namenode.note_uncached(block);
            }
            cache.reset_stats();
            dn.disk.reset();
            dn.nic.reset();
        }
        self.stats = CoordinatorStats::default();
        self.tracker.reset();
        self.pending_labels.clear();
        self.batchers.invalidate_all();
        self.requests_since_sweep = 0;
    }

    /// Total bytes currently cached across DataNodes.
    pub fn cached_bytes(&self) -> u64 {
        self.caches.iter().map(|c| c.used()).sum()
    }

    /// Total cached blocks across DataNodes.
    pub fn cached_blocks(&self) -> usize {
        self.caches.iter().map(|c| c.len()).sum()
    }

    /// Cache shards per DataNode (0 in NoCache mode).
    pub fn cache_shards(&self) -> usize {
        self.caches.first().map(|c| c.n_shards()).unwrap_or(0)
    }

    /// Shard-level access counters merged across every DataNode. Agrees
    /// with `stats` on hits/misses/evictions/insertions (modulo prefetch
    /// staging inserts and unknown-block misses, which only one side sees),
    /// but is accounted under the shard locks, so it stays correct when
    /// shards are driven from worker threads.
    pub fn cache_stats(&self) -> ShardStats {
        let mut acc = ShardStats::default();
        for cache in &self.caches {
            acc.merge(&cache.stats());
        }
        acc
    }
}

impl BlockService for CacheCoordinator {
    fn read_block(
        &mut self,
        block: BlockId,
        reader: DataNodeId,
        now: SimTime,
        req: &AccessRequest,
    ) -> BlockRead {
        let size = self.block_size(block);
        let features =
            self.tracker.features(block, req.kind, size, req.affinity, 0.0, now);
        // Label collection only matters when a classifier can consume it.
        if self.backend.is_some() {
            self.observe_reuse(block, features, now);
        }
        let ctx = self.build_ctx(
            block,
            size,
            req.kind,
            req.affinity,
            req.file,
            req.file_width,
            req.file_complete,
            0.0,
            now,
        );
        let (source, serving_dn) = self.access(block, reader, now, ctx);
        if source.is_cache() {
            if let Some(pf) = self.prefetcher.as_mut() {
                pf.note_hit(block);
            }
        }
        let app_id = self.app_id(&req.app);
        self.tracker.record_access(block, app_id, now);
        self.run_prefetch(block, req, now);
        if let Err(e) = self.maybe_retrain() {
            log::warn!("retraining failed: {e:#}");
        }

        // Service time with queueing on the serving node's resources.
        let pure = service_time(&self.cluster.cfg, source, size);
        let completion = match source {
            ReadSource::DiskLocal | ReadSource::DiskRemote => {
                let (_, end) =
                    self.cluster.datanodes[serving_dn.0 as usize].disk.acquire(now, pure);
                end
            }
            ReadSource::CacheRemote => {
                let (_, end) =
                    self.cluster.datanodes[serving_dn.0 as usize].nic.acquire(now, pure);
                end
            }
            ReadSource::CacheLocal => now + pure,
        };
        BlockRead { completion, source }
    }

    fn preferred_node(&self, block: BlockId) -> Option<DataNodeId> {
        match self.cluster.namenode.locate(block)? {
            BlockLocation::Cached(dn) | BlockLocation::OnDisk(dn) => Some(dn),
        }
    }

    fn replica_nodes(&self, block: BlockId) -> Vec<DataNodeId> {
        self.cluster.namenode.replicas_of(block).to_vec()
    }

    fn block_size(&self, block: BlockId) -> u64 {
        self.cluster
            .namenode
            .block_info(block)
            .map(|b| b.size)
            .unwrap_or(self.cluster.cfg.block_size)
    }

    fn register_intermediate(&mut self, job: crate::mapreduce::JobId, bytes: u64) -> Vec<BlockId> {
        if bytes == 0 {
            return Vec::new();
        }
        // Registered in every mode so all scenarios pay identical shuffle
        // I/O costs; only the *caching* of these blocks differs (H-NoCache
        // reads them from disk every time).
        self.intermediate_seq += 1;
        let name = format!("shuffle/{job}/{}", self.intermediate_seq);
        let fid = self.cluster.add_intermediate(&name, bytes);
        self.cluster.namenode.files.blocks_of(fid).to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::runtime::RustBackend;
    use crate::svm::KernelKind;
    use crate::util::bytes::{GB, MB};

    fn small_cluster(policy: &str, cache_blocks: u64) -> CacheCoordinator {
        let cfg = ClusterConfig {
            datanodes: 1,
            replication: 1,
            block_size: 128 * MB,
            cache_capacity_per_node: cache_blocks * 128 * MB,
            ..Default::default()
        };
        let mut cluster = Cluster::provision(&cfg);
        cluster.add_input("data", 2 * GB);
        let backend: Option<Box<dyn SvmBackend>> = if policy == "h-svm-lru" {
            Some(Box::new(RustBackend::new(KernelKind::Rbf)))
        } else {
            None
        };
        CacheCoordinator::new(
            cluster,
            CacheMode::Cached { policy: policy.to_string() },
            backend,
        )
        .unwrap()
    }

    #[test]
    fn miss_then_hit() {
        let mut c = small_cluster("lru", 4);
        let req = AccessRequest {
            app: "Grep".into(),
            affinity: CacheAffinity::High,
            kind: BlockKind::Input,
            file: 0,
            file_width: 4,
            file_complete: false,
        };
        let b = BlockId(0);
        let r1 = c.read_block(b, DataNodeId(0), SimTime(0), &req);
        assert!(!r1.source.is_cache());
        let r2 = c.read_block(b, DataNodeId(0), SimTime(1_000_000), &req);
        assert!(r2.source.is_cache());
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.misses, 1);
        assert!((c.stats.hit_ratio() - 0.5).abs() < 1e-12);
        // The DataNode actually holds the cached block; metadata agrees.
        assert!(c.cluster.datanodes[0].is_cached(b));
        assert!(c.cluster.namenode.is_cached(b));
        assert_eq!(c.process_cache_reports(), 0, "metadata already consistent");
    }

    #[test]
    fn eviction_updates_datanode_and_namenode() {
        let mut c = small_cluster("lru", 2);
        let req = AccessRequest {
            app: "Sort".into(),
            affinity: CacheAffinity::Low,
            kind: BlockKind::Input,
            file: 0,
            file_width: 4,
            file_complete: false,
        };
        for i in 0..3 {
            c.read_block(BlockId(i), DataNodeId(0), SimTime(i * 1000), &req);
        }
        // Capacity 2: the LRU victim (block 0) must be fully uncached.
        assert_eq!(c.stats.evictions, 1);
        assert!(!c.cluster.datanodes[0].is_cached(BlockId(0)));
        assert!(!c.cluster.namenode.is_cached(BlockId(0)));
        assert_eq!(c.cached_blocks(), 2);
        assert!(c.cached_bytes() <= c.cluster.cfg.cache_capacity_per_node);
    }

    #[test]
    fn nocache_mode_never_hits() {
        let cfg = ClusterConfig { datanodes: 2, replication: 1, ..Default::default() };
        let mut cluster = Cluster::provision(&cfg);
        cluster.add_input("data", GB);
        let mut c = CacheCoordinator::new(cluster, CacheMode::NoCache, None).unwrap();
        let req = AccessRequest {
            app: "WordCount".into(),
            affinity: CacheAffinity::Medium,
            kind: BlockKind::Input,
            file: 0,
            file_width: 1,
            file_complete: false,
        };
        for t in 0..10u64 {
            let r = c.read_block(BlockId(0), DataNodeId(0), SimTime(t * 100), &req);
            assert!(!r.source.is_cache());
        }
        assert_eq!(c.stats.hits, 0);
        assert_eq!(c.stats.hit_ratio(), 0.0);
    }

    #[test]
    fn hsvmlru_requires_backend() {
        let cfg = ClusterConfig::default();
        let cluster = Cluster::provision(&cfg);
        let r = CacheCoordinator::new(
            cluster,
            CacheMode::Cached { policy: "h-svm-lru".into() },
            None,
        );
        assert!(r.is_err());
    }

    #[test]
    fn trace_replay_trains_classifier() {
        let mut c = small_cluster("h-svm-lru", 4);
        let trace = crate::workload::fig3_trace(128 * MB, 11);
        for req in &trace {
            c.handle_trace_request(req).unwrap();
        }
        assert!(c.pipeline.trainings > 0, "classifier should have trained");
        assert!(c.stats.hits > 0);
        let bs = c.batcher_stats();
        assert!(bs.queries > 0);
        assert!(
            bs.class_cache_hits + bs.predictions_scored >= bs.queries,
            "every query answered"
        );
    }

    #[test]
    fn coordinator_publishes_consumable_snapshots() {
        use crate::coordinator::online::SnapshotReader;
        let mut c = small_cluster("h-svm-lru", 4);
        let cell = c.snapshot_cell();
        assert_eq!(cell.version(), 0, "nothing published before training");
        let trace = crate::workload::fig3_trace(128 * MB, 11);
        for req in &trace {
            c.handle_trace_request(req).unwrap();
        }
        assert!(c.pipeline.trainings > 0);
        assert_eq!(
            c.snapshot_version(),
            c.pipeline.trainings,
            "every deployed model is published (rust backend exports)"
        );
        // The published snapshot is the deployed classifier: it classifies,
        // and a reader sees the freshest version.
        let mut reader = SnapshotReader::new(cell);
        let snap = reader.current();
        assert!(snap.is_trained());
        assert_eq!(snap.version(), c.snapshot_version());
        let f = c.tracker.features(
            trace[0].block,
            trace[0].kind,
            trace[0].size,
            trace[0].affinity,
            trace[0].recompute_cost,
            trace[0].time,
        );
        assert!(reader.predict(&f).is_some());
    }

    #[test]
    fn sharded_coordinator_keeps_metadata_consistent() {
        let cfg = ClusterConfig {
            datanodes: 1,
            replication: 1,
            block_size: 128 * MB,
            cache_capacity_per_node: 4 * 128 * MB,
            cache_shards: 4,
            ..Default::default()
        };
        let mut cluster = Cluster::provision(&cfg);
        cluster.add_input("data", 2 * GB);
        let mut c = CacheCoordinator::new(
            cluster,
            CacheMode::Cached { policy: "lru".to_string() },
            None,
        )
        .unwrap();
        assert_eq!(c.cache_shards(), 4);
        let req = AccessRequest {
            app: "Grep".into(),
            affinity: CacheAffinity::High,
            kind: BlockKind::Input,
            file: 0,
            file_width: 4,
            file_complete: false,
        };
        for round in 0..2u64 {
            for i in 0..8u64 {
                c.read_block(BlockId(i), DataNodeId(0), SimTime(round * 10_000 + i), &req);
            }
        }
        // Shard-level accounting agrees with the coordinator's own counters
        // (no prefetcher and every block known, so both sides see the same
        // request stream).
        let cs = c.cache_stats();
        assert_eq!(cs.requests, c.stats.requests);
        assert_eq!(cs.hits, c.stats.hits);
        assert_eq!(cs.misses, c.stats.misses);
        assert_eq!(cs.evictions, c.stats.evictions);
        assert_eq!(cs.insertions, c.stats.insertions);
        assert!(c.stats.hits > 0, "second round must hit");
        assert!(c.cached_bytes() <= c.cluster.cfg.cache_capacity_per_node);
        assert_eq!(c.process_cache_reports(), 0, "sharding must not drift metadata");
        c.reset_for_measurement();
        assert_eq!(c.cache_stats(), ShardStats::default());
        assert_eq!(c.cached_blocks(), 0);
    }

    #[test]
    fn ghost_admission_keeps_metadata_consistent() {
        let cfg = ClusterConfig {
            datanodes: 1,
            replication: 1,
            block_size: 128 * MB,
            cache_capacity_per_node: 4 * 128 * MB,
            cache_admission: "ghost".into(),
            ..Default::default()
        };
        let mut cluster = Cluster::provision(&cfg);
        cluster.add_input("data", 2 * GB);
        let mut c = CacheCoordinator::new(
            cluster,
            CacheMode::Cached { policy: "lru".to_string() },
            None,
        )
        .unwrap();
        assert_eq!(c.admission_name(), "ghost");
        let req = AccessRequest {
            app: "Grep".into(),
            affinity: CacheAffinity::High,
            kind: BlockKind::Input,
            file: 0,
            file_width: 4,
            file_complete: false,
        };
        let b = BlockId(0);
        // 1st read: probation — the block must NOT be cached anywhere.
        let r1 = c.read_block(b, DataNodeId(0), SimTime(0), &req);
        assert!(!r1.source.is_cache());
        assert!(!c.cluster.datanodes[0].is_cached(b));
        assert!(!c.cluster.namenode.is_cached(b));
        // 2nd read: re-reference admits; 3rd read is a cache hit.
        let r2 = c.read_block(b, DataNodeId(0), SimTime(1_000), &req);
        assert!(!r2.source.is_cache());
        assert!(c.cluster.datanodes[0].is_cached(b));
        let r3 = c.read_block(b, DataNodeId(0), SimTime(2_000), &req);
        assert!(r3.source.is_cache());
        let cs = c.cache_stats();
        assert_eq!(cs.rejected, 1);
        assert_eq!(cs.admitted, 1);
        assert_eq!(c.process_cache_reports(), 0, "admission must not drift metadata");
    }

    #[test]
    fn svm_admission_requires_backend() {
        let cfg = ClusterConfig { cache_admission: "svm".into(), ..Default::default() };
        let cluster = Cluster::provision(&cfg);
        let r = CacheCoordinator::new(
            cluster,
            CacheMode::Cached { policy: "lru".into() },
            None,
        );
        assert!(r.is_err(), "svm admission without a backend must fail");
    }

    #[test]
    fn disk_reads_queue_on_the_spindle() {
        let mut c = small_cluster("lru", 2);
        let req = AccessRequest {
            app: "Sort".into(),
            affinity: CacheAffinity::Low,
            kind: BlockKind::Input,
            file: 0,
            file_width: 4,
            file_complete: false,
        };
        // Two distinct blocks at the same instant: second queues behind the
        // first on the single disk.
        let r1 = c.read_block(BlockId(0), DataNodeId(0), SimTime(0), &req);
        let r2 = c.read_block(BlockId(1), DataNodeId(0), SimTime(0), &req);
        assert!(r2.completion > r1.completion);
    }
}
