//! The paper's L3 contribution: centralized, SVM-informed cache
//! coordination on the NameNode.
//!
//! * `cache_coordinator` — Algorithm 1 (GetCache/PutCache) over the
//!   simulated cluster, implementing `mapreduce::BlockService` for the
//!   request path.
//! * `batcher` — per-block class caching + micro-batched PJRT predictions.
//! * `training_pipeline` — labeled-sample accumulation and periodic
//!   retraining (both §5.1 label scenarios).

pub mod batcher;
pub mod cache_coordinator;
pub mod prefetcher;
pub mod training_pipeline;

pub use batcher::{BatcherStats, PredictionBatcher};
pub use cache_coordinator::{CacheCoordinator, CacheMode, CoordinatorStats};
pub use prefetcher::{PrefetchStats, Prefetcher};
pub use training_pipeline::TrainingPipeline;
