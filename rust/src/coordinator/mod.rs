//! The paper's L3 contribution: centralized, SVM-informed cache
//! coordination on the NameNode.
//!
//! * `cache_coordinator` — Algorithm 1 (GetCache/PutCache) over the
//!   simulated cluster, implementing `mapreduce::BlockService` for the
//!   request path.
//! * `batcher` — per-block class caching + micro-batched PJRT
//!   predictions, one bounded [`batcher::ShardBatcher`] per cache shard
//!   behind a [`batcher::BatcherPool`] (cold-query queue + flush
//!   deadline, so a miss storm on one shard never stalls another).
//! * `training_pipeline` — labeled-sample accumulation and periodic
//!   retraining (both §5.1 label scenarios).
//! * `online` — concurrent online learning: immutable classifier
//!   snapshots behind an atomically swappable cell, a bounded sample
//!   channel and the background trainer loop that keeps the shard-parallel
//!   replay's classifier fresh mid-trace.

/// Class caching + micro-batched predictions (per-shard batchers).
pub mod batcher;
/// Algorithm 1 (GetCache/PutCache) over the simulated cluster.
pub mod cache_coordinator;
/// Concurrent online learning: snapshot cell, sample channel, trainer loop.
pub mod online;
/// Sequential-readahead prefetching into the cache.
pub mod prefetcher;
/// Labeled-sample accumulation and periodic retraining.
pub mod training_pipeline;

pub use batcher::{
    BatcherConfig, BatcherPool, BatcherProbe, BatcherStats, BreakerConfig, BreakerState,
    PredictionBatcher, ShardBatcher,
};
pub use cache_coordinator::{CacheCoordinator, CacheMode, CoordinatorStats};
pub use online::{
    sample_channel, trainer_loop, trainer_loop_resilient, ClassifierSnapshot, LabeledSample,
    SampleProbe, SampleSender, SnapshotBackend, SnapshotCell, SnapshotReader, TrainerConfig,
    TrainerReport,
};
pub use prefetcher::{PrefetchStats, Prefetcher};
pub use training_pipeline::TrainingPipeline;
