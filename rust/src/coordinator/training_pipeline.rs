//! Online training pipeline: labeled samples -> preprocessing -> backend
//! training -> classifier deployment (§5 of the paper, end to end).
//!
//! Two label sources are supported, matching §5.1's two scenarios:
//! * **request awareness** — the replayed trace knows each request's
//!   ground-truth future reuse (`BlockRequest::reused_later`); samples are
//!   (features-at-request-time, reused_later).
//! * **non-request awareness** — Table 4 labels derived from job-history
//!   records (`svm::labeling::label_record`), with features from the
//!   block-stats tracker at observation time.

use anyhow::Result;

use crate::runtime::SvmBackend;
use crate::svm::dataset::Dataset;
use crate::svm::features::FeatureVec;

/// Accumulates labeled samples and retrains the backend periodically.
pub struct TrainingPipeline {
    buffer: Dataset,
    /// Running positive count — `has_both_classes` must be O(1), it sits
    /// on the per-request path (see EXPERIMENTS.md §Perf).
    n_positive: usize,
    /// Sliding-window cap: beyond this the oldest half is dropped so the
    /// model tracks recent behaviour and memory stays bounded.
    max_samples: usize,
    /// First training at `min_samples`; retrain every `retrain_interval`
    /// new samples after that.
    min_samples: usize,
    retrain_interval: usize,
    /// Cadence anchor: observations since the last (re)training. A plain
    /// counter is immune to the sliding-window halving — the earlier
    /// buffer-length anchor (`samples_at_last_train`) was decremented by
    /// the drain and could make the next retrain fire immediately (anchor
    /// saturated to 0) or drift late after repeated halvings.
    observed_since_train: usize,
    /// Completed (re)trainings.
    pub trainings: u64,
}

impl TrainingPipeline {
    /// A pipeline that first trains at `min_samples` observations and
    /// retrains every `retrain_interval` observations after that.
    pub fn new(min_samples: usize, retrain_interval: usize) -> Self {
        TrainingPipeline {
            buffer: Dataset::new(),
            n_positive: 0,
            max_samples: 8192,
            min_samples: min_samples.max(2),
            retrain_interval: retrain_interval.max(1),
            observed_since_train: 0,
            trainings: 0,
        }
    }

    /// Override the sliding-window cap (tests and memory-tight deployments).
    pub fn with_max_samples(mut self, max_samples: usize) -> Self {
        self.max_samples = max_samples.max(2);
        self
    }

    /// Add one labeled observation.
    pub fn observe(&mut self, features: FeatureVec, reused: bool) {
        self.buffer.push(features, reused);
        self.n_positive += reused as usize;
        self.observed_since_train += 1;
        if self.buffer.len() > self.max_samples {
            // Drop the oldest half (sliding window over recent behaviour).
            // The cadence anchor is a counter of observations, not a buffer
            // position, so the drain must not touch it.
            let keep_from = self.buffer.len() / 2;
            self.n_positive = self.buffer.y[keep_from..]
                .iter()
                .filter(|&&y| y > 0.0)
                .count();
            self.buffer.x.drain(..keep_from);
            self.buffer.y.drain(..keep_from);
        }
    }

    /// Labeled samples currently buffered.
    pub fn n_samples(&self) -> usize {
        self.buffer.len()
    }

    /// Both classes present? (An SVM needs two classes to train.) O(1).
    pub fn has_both_classes(&self) -> bool {
        self.n_positive > 0 && self.n_positive < self.buffer.len()
    }

    /// Observations since the last (re)training — the cadence counter the
    /// background trainer uses to decide whether a final drain training is
    /// worthwhile.
    pub fn pending_since_train(&self) -> usize {
        self.observed_since_train
    }

    fn due(&self) -> bool {
        if !self.has_both_classes() {
            return false;
        }
        if self.trainings == 0 {
            self.buffer.len() >= self.min_samples
        } else {
            self.observed_since_train >= self.retrain_interval
        }
    }

    /// Train if due. Returns true when a (re)training happened.
    pub fn maybe_train(&mut self, backend: &mut dyn SvmBackend) -> Result<bool> {
        if !self.due() {
            return Ok(false);
        }
        let mut ds = self.buffer.clone();
        ds.preprocess();
        if ds.is_empty() {
            return Ok(false);
        }
        backend.train(&ds)?;
        self.trainings += 1;
        self.observed_since_train = 0;
        log::debug!(
            "svm retrained: samples={} positives={} trainings={}",
            ds.len(),
            ds.n_positive(),
            self.trainings
        );
        Ok(true)
    }

    /// Force a training round regardless of schedule (used by the CLI).
    pub fn train_now(&mut self, backend: &mut dyn SvmBackend) -> Result<bool> {
        if !self.has_both_classes() {
            return Ok(false);
        }
        let mut ds = self.buffer.clone();
        ds.preprocess();
        backend.train(&ds)?;
        self.trainings += 1;
        self.observed_since_train = 0;
        Ok(true)
    }

    /// The accumulated dataset (evaluation / Table 5 reuse).
    pub fn dataset(&self) -> &Dataset {
        &self.buffer
    }

    /// Drop every buffered sample and the cadence anchor — what an
    /// injected trainer crash costs: the in-flight window is lost and the
    /// restarted trainer accumulates from empty. `trainings` survives (it
    /// counts completed work, and the cadence gate `trainings == 0` must
    /// not re-arm the `min_samples` warm-up after a mid-run restart).
    pub fn reset(&mut self) {
        self.buffer = Dataset::new();
        self.n_positive = 0;
        self.observed_since_train = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::features::N_FEATURES;

    struct CountingBackend {
        trainings: u64,
    }

    impl SvmBackend for CountingBackend {
        fn name(&self) -> &'static str {
            "counting"
        }
        fn train(&mut self, ds: &Dataset) -> Result<()> {
            assert!(!ds.is_empty());
            self.trainings += 1;
            Ok(())
        }
        fn decision_batch(&mut self, q: &[FeatureVec]) -> Result<Vec<f32>> {
            Ok(vec![0.0; q.len()])
        }
        fn is_trained(&self) -> bool {
            self.trainings > 0
        }
    }

    fn fv(i: usize) -> FeatureVec {
        let mut f = [0.0f32; N_FEATURES];
        f[0] = (i % 10) as f32 / 10.0;
        f
    }

    #[test]
    fn first_training_waits_for_min_samples() {
        let mut be = CountingBackend { trainings: 0 };
        let mut tp = TrainingPipeline::new(10, 5);
        for i in 0..9 {
            tp.observe(fv(i), i % 2 == 0);
            assert!(!tp.maybe_train(&mut be).unwrap());
        }
        tp.observe(fv(9), false);
        assert!(tp.maybe_train(&mut be).unwrap());
        assert_eq!(be.trainings, 1);
    }

    #[test]
    fn retrains_on_interval() {
        let mut be = CountingBackend { trainings: 0 };
        let mut tp = TrainingPipeline::new(4, 6);
        for i in 0..4 {
            tp.observe(fv(i), i % 2 == 0);
        }
        assert!(tp.maybe_train(&mut be).unwrap());
        // 5 more samples: not due yet (interval 6).
        for i in 4..9 {
            tp.observe(fv(i), i % 2 == 0);
            assert!(!tp.maybe_train(&mut be).unwrap());
        }
        tp.observe(fv(9), true);
        assert!(tp.maybe_train(&mut be).unwrap());
        assert_eq!(be.trainings, 2);
        assert_eq!(tp.trainings, 2);
    }

    #[test]
    fn single_class_never_trains() {
        let mut be = CountingBackend { trainings: 0 };
        let mut tp = TrainingPipeline::new(2, 2);
        for i in 0..50 {
            tp.observe(fv(i), true);
        }
        assert!(!tp.maybe_train(&mut be).unwrap());
        assert!(!tp.train_now(&mut be).unwrap());
        assert_eq!(be.trainings, 0);
    }

    /// Property: the retrain cadence is exactly `retrain_interval` new
    /// observations, no matter how often the sliding window halves in
    /// between. (The old buffer-length anchor fired immediately — or
    /// drifted late — after a halving.)
    #[test]
    fn retrain_cadence_survives_window_halvings() {
        for (min, interval, max_samples) in [(4, 8, 16), (2, 5, 8), (6, 13, 20)] {
            let mut be = CountingBackend { trainings: 0 };
            let mut tp = TrainingPipeline::new(min, interval).with_max_samples(max_samples);
            let mut train_points = Vec::new();
            for i in 0..400usize {
                // Alternate classes so both are always present.
                tp.observe(fv(i), i % 2 == 0);
                if tp.maybe_train(&mut be).unwrap() {
                    train_points.push(i);
                }
            }
            assert!(
                train_points.len() >= 2,
                "cadence must fire repeatedly (min={min} interval={interval})"
            );
            assert_eq!(
                train_points[0] + 1,
                min.max(2),
                "first training at min_samples"
            );
            for w in train_points.windows(2) {
                assert_eq!(
                    w[1] - w[0],
                    interval,
                    "retrain gap must be exactly the interval across halvings \
                     (min={min} interval={interval} max={max_samples})"
                );
            }
            // The window itself stayed bounded the whole time.
            assert!(tp.n_samples() <= max_samples);
        }
    }

    #[test]
    fn reset_clears_buffer_but_keeps_training_count() {
        let mut be = CountingBackend { trainings: 0 };
        let mut tp = TrainingPipeline::new(4, 4);
        for i in 0..4 {
            tp.observe(fv(i), i % 2 == 0);
        }
        assert!(tp.maybe_train(&mut be).unwrap());
        tp.observe(fv(5), true);
        tp.reset();
        assert_eq!(tp.n_samples(), 0);
        assert_eq!(tp.pending_since_train(), 0);
        assert!(!tp.has_both_classes());
        assert_eq!(tp.trainings, 1, "completed trainings survive the crash");
        // The restarted pipeline retrains on the interval cadence (not the
        // min_samples warm-up) once both classes reappear.
        for i in 0..4 {
            tp.observe(fv(i), i % 2 == 0);
        }
        assert!(tp.maybe_train(&mut be).unwrap());
        assert_eq!(be.trainings, 2);
    }

    #[test]
    fn train_now_forces() {
        let mut be = CountingBackend { trainings: 0 };
        let mut tp = TrainingPipeline::new(1000, 1000);
        tp.observe(fv(0), true);
        tp.observe(fv(1), false);
        assert!(tp.train_now(&mut be).unwrap());
        assert_eq!(be.trainings, 1);
    }
}
