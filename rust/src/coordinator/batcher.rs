//! Prediction micro-batching + class caching.
//!
//! Algorithm 1 consults the SVM on *every* cache decision. Calling the
//! PJRT executable per block would put an artifact invocation on each
//! request; instead the coordinator:
//!
//! 1. caches the predicted class per block, invalidating when the block's
//!    feature state drifts (its access count changes — frequency and
//!    recency are the live features), and
//! 2. batches cold predictions: queries accumulate into the artifact's
//!    native batch width before one `decision_batch` call scores them all
//!    (the vLLM-router-style amortization; see DESIGN.md §8).

use std::collections::hash_map::Entry;

use crate::cache::order_list::{OrderHandle, OrderList};
use crate::util::fasthash::IdHashMap;

use anyhow::Result;

use crate::hdfs::BlockId;
use crate::runtime::SvmBackend;
use crate::svm::features::FeatureVec;

/// Default bound on the per-block class cache. Entries for evicted blocks
/// are dropped eagerly ([`PredictionBatcher::invalidate`]); the bound caps
/// whatever survives on long traces with huge keyspaces.
pub const DEFAULT_CLASS_CACHE_CAPACITY: usize = 4096;

/// Cached prediction: class + the access-count stamp it was computed at,
/// plus the block's live handle in the score-order list. (This replaces a
/// stamped-lazy-deletion `VecDeque` — invalidation now unlinks the order
/// entry in O(1) instead of leaving a stale id to be skipped later.)
#[derive(Debug, Clone, Copy)]
struct CachedClass {
    reused: bool,
    stamp: u64,
    handle: OrderHandle,
}

/// Batching predictor with a bounded per-block class cache.
pub struct PredictionBatcher {
    cache: IdHashMap<BlockId, CachedClass>,
    /// Score order of class-cache entries, oldest score at the front —
    /// the eviction order when the cache exceeds `capacity`. Re-scoring a
    /// resident block moves it to the back.
    order: OrderList<BlockId>,
    /// Class-cache bound: beyond it the oldest entries are dropped.
    capacity: usize,
    /// Version of the classifier snapshot the cached classes came from.
    model_version: u64,
    /// Pending cold queries (block, stamp, features).
    pending: Vec<(BlockId, u64, FeatureVec)>,
    /// Reused per-chunk query buffer for `flush` — one allocation for the
    /// batcher's lifetime instead of a fresh `Vec<FeatureVec>` per chunk.
    scratch: Vec<FeatureVec>,
    /// Flush threshold = artifact batch width.
    batch_width: usize,
    pub stats: BatcherStats,
}

/// Telemetry for the perf pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct BatcherStats {
    pub queries: u64,
    pub class_cache_hits: u64,
    pub backend_calls: u64,
    pub predictions_scored: u64,
}

impl PredictionBatcher {
    pub fn new(batch_width: usize) -> Self {
        Self::with_capacity(batch_width, DEFAULT_CLASS_CACHE_CAPACITY)
    }

    /// A batcher whose class cache holds at most `capacity` blocks.
    pub fn with_capacity(batch_width: usize, capacity: usize) -> Self {
        PredictionBatcher {
            cache: IdHashMap::default(),
            order: OrderList::new(),
            capacity: capacity.max(1),
            model_version: 0,
            pending: Vec::new(),
            scratch: Vec::new(),
            batch_width: batch_width.max(1),
            stats: BatcherStats::default(),
        }
    }

    /// Predict the class of one block, given its current feature vector and
    /// an access-count stamp. Uses the class cache when the stamp matches;
    /// otherwise queues the query and flushes a full batch through the
    /// backend synchronously (the caller needs the answer now — pending
    /// entries ride along in the same call).
    pub fn predict(
        &mut self,
        backend: &mut dyn SvmBackend,
        block: BlockId,
        stamp: u64,
        features: FeatureVec,
    ) -> Result<bool> {
        self.stats.queries += 1;
        if let Some(c) = self.cache.get(&block) {
            if c.stamp == stamp {
                self.stats.class_cache_hits += 1;
                return Ok(c.reused);
            }
        }
        self.pending.push((block, stamp, features));
        self.flush(backend)?;
        Ok(self.cache.get(&block).expect("flush populated cache").reused)
    }

    /// Score everything pending in batch_width chunks.
    pub fn flush(&mut self, backend: &mut dyn SvmBackend) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        let pending = std::mem::take(&mut self.pending);
        for chunk in pending.chunks(self.batch_width) {
            self.scratch.clear();
            self.scratch.extend(chunk.iter().map(|(_, _, f)| *f));
            let scores = backend.decision_batch(&self.scratch)?;
            self.stats.backend_calls += 1;
            self.stats.predictions_scored += scores.len() as u64;
            for ((block, stamp, _), score) in chunk.iter().zip(scores) {
                // Every score — fresh insert or stamp-refresh of a
                // resident block — lands at the order back. That keeps
                // just-scored entries out of reach of the capacity
                // eviction below: predict()'s own query is the last one
                // scored, so the entry it reads back is always the newest
                // and can never be the over-capacity victim.
                let reused = score > 0.0;
                match self.cache.entry(*block) {
                    Entry::Occupied(mut e) => {
                        let c = e.get_mut();
                        c.reused = reused;
                        c.stamp = *stamp;
                        self.order.move_to_back(c.handle);
                    }
                    Entry::Vacant(e) => {
                        let handle = self.order.push_back(*block);
                        e.insert(CachedClass { reused, stamp: *stamp, handle });
                    }
                }
            }
        }
        self.enforce_capacity();
        Ok(())
    }

    /// Drop oldest-scored class-cache entries past the bound. The order
    /// list holds exactly the cached blocks (invalidation unlinks), so
    /// every front entry is live.
    fn enforce_capacity(&mut self) {
        while self.cache.len() > self.capacity {
            let oldest = self.order.pop_front().expect("cached entries are ordered");
            self.cache.remove(&oldest);
        }
    }

    /// Queue a prediction without needing the answer immediately (prefetch
    /// for blocks we expect to decide on soon).
    pub fn prefetch(&mut self, block: BlockId, stamp: u64, features: FeatureVec) {
        let fresh = self
            .cache
            .get(&block)
            .map(|c| c.stamp == stamp)
            .unwrap_or(false);
        if !fresh && !self.pending.iter().any(|(b, s, _)| *b == block && *s == stamp) {
            self.pending.push((block, stamp, features));
        }
    }

    /// Invalidate one block's cached class — called from the eviction /
    /// uncache path so the class cache tracks the block population instead
    /// of growing monotonically over the trace.
    pub fn invalidate(&mut self, block: BlockId) {
        if let Some(c) = self.cache.remove(&block) {
            self.order.unlink(c.handle);
        }
        self.pending.retain(|(b, _, _)| *b != block);
    }

    /// Invalidate all cached classes (after retraining).
    pub fn invalidate_all(&mut self) {
        self.cache.clear();
        self.order.clear();
        self.pending.clear();
    }

    /// Note the classifier-snapshot version serving predictions. When it
    /// moves, every cached class came from a stale model and is dropped
    /// (pending queries are kept — they will be scored by the new model).
    pub fn note_model_version(&mut self, version: u64) {
        if version != self.model_version {
            self.model_version = version;
            self.cache.clear();
            self.order.clear();
        }
    }

    pub fn cached_len(&self) -> usize {
        self.cache.len()
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::features::N_FEATURES;

    /// A backend that classifies by feature[0] > 0.5 and counts calls.
    struct FakeBackend {
        calls: u64,
    }

    impl SvmBackend for FakeBackend {
        fn name(&self) -> &'static str {
            "fake"
        }
        fn train(&mut self, _ds: &crate::svm::Dataset) -> Result<()> {
            Ok(())
        }
        fn decision_batch(&mut self, q: &[FeatureVec]) -> Result<Vec<f32>> {
            self.calls += 1;
            Ok(q.iter().map(|f| f[0] - 0.5).collect())
        }
        fn is_trained(&self) -> bool {
            true
        }
    }

    fn fv(v: f32) -> FeatureVec {
        let mut f = [0.0f32; N_FEATURES];
        f[0] = v;
        f
    }

    #[test]
    fn class_cache_avoids_backend_calls() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::new(8);
        let b = BlockId(1);
        assert!(batcher.predict(&mut be, b, 0, fv(0.9)).unwrap());
        assert_eq!(be.calls, 1);
        // Same stamp: served from the class cache.
        for _ in 0..10 {
            assert!(batcher.predict(&mut be, b, 0, fv(0.9)).unwrap());
        }
        assert_eq!(be.calls, 1);
        assert_eq!(batcher.stats.class_cache_hits, 10);
        // New stamp: re-scored.
        assert!(!batcher.predict(&mut be, b, 1, fv(0.1)).unwrap());
        assert_eq!(be.calls, 2);
    }

    #[test]
    fn prefetch_batches_ride_along() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::new(8);
        for i in 0..5 {
            batcher.prefetch(BlockId(i), 0, fv(0.8));
        }
        assert_eq!(batcher.pending_len(), 5);
        // One predict triggers a single backend call scoring all 6.
        assert!(batcher.predict(&mut be, BlockId(9), 0, fv(0.7)).unwrap());
        assert_eq!(be.calls, 1);
        assert_eq!(batcher.stats.predictions_scored, 6);
        // The prefetched classes are now cached.
        assert!(batcher.predict(&mut be, BlockId(3), 0, fv(0.8)).unwrap());
        assert_eq!(be.calls, 1);
    }

    #[test]
    fn oversized_pending_splits_into_chunks() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::new(4);
        for i in 0..9 {
            batcher.prefetch(BlockId(i), 0, fv(0.6));
        }
        batcher.flush(&mut be).unwrap();
        assert_eq!(be.calls, 3, "9 queries / width 4 = 3 calls");
        assert_eq!(batcher.cached_len(), 9);
    }

    #[test]
    fn invalidate_clears_state() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::new(4);
        batcher.predict(&mut be, BlockId(0), 0, fv(0.9)).unwrap();
        batcher.prefetch(BlockId(1), 0, fv(0.9));
        batcher.invalidate_all();
        assert_eq!(batcher.cached_len(), 0);
        assert_eq!(batcher.pending_len(), 0);
    }

    #[test]
    fn invalidate_drops_one_block_only() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::new(8);
        batcher.predict(&mut be, BlockId(1), 0, fv(0.9)).unwrap();
        batcher.predict(&mut be, BlockId(2), 0, fv(0.9)).unwrap();
        assert_eq!(batcher.cached_len(), 2);
        batcher.invalidate(BlockId(1));
        assert_eq!(batcher.cached_len(), 1);
        // Block 1 must be re-scored; block 2 still serves from the cache.
        let calls_before = be.calls;
        batcher.predict(&mut be, BlockId(2), 0, fv(0.9)).unwrap();
        assert_eq!(be.calls, calls_before);
        batcher.predict(&mut be, BlockId(1), 0, fv(0.9)).unwrap();
        assert_eq!(be.calls, calls_before + 1);
        // Invalidate also drops any pending query for the block.
        batcher.prefetch(BlockId(7), 0, fv(0.5));
        batcher.invalidate(BlockId(7));
        assert_eq!(batcher.pending_len(), 0);
    }

    #[test]
    fn class_cache_is_bounded() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::with_capacity(8, 16);
        // A long trace over a huge keyspace must not grow the cache
        // without bound (the pre-fix leak).
        for i in 0..400u64 {
            batcher.predict(&mut be, BlockId(i), 0, fv(0.9)).unwrap();
            assert!(batcher.cached_len() <= 16, "leaked at block {i}");
        }
        // Oldest entries were the ones dropped: the newest still serves.
        let calls = be.calls;
        batcher.predict(&mut be, BlockId(399), 0, fv(0.9)).unwrap();
        assert_eq!(be.calls, calls, "newest entry retained");
        batcher.predict(&mut be, BlockId(0), 0, fv(0.9)).unwrap();
        assert_eq!(be.calls, calls + 1, "oldest entry was evicted");
    }

    /// Regression (from the stamped-lazy-deletion era, kept as a guard):
    /// after an invalidate + re-predict of the same block, capacity
    /// eviction must not remove the freshly re-inserted entry (the old
    /// stale-order-id bug panicked predict()'s "flush populated cache"
    /// expect). With the order list, invalidation unlinks eagerly, so no
    /// stale entry can exist at all.
    #[test]
    fn stale_order_entry_cannot_evict_a_reinserted_block() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::with_capacity(8, 4);
        batcher.predict(&mut be, BlockId(0), 0, fv(0.9)).unwrap();
        batcher.invalidate(BlockId(0));
        for i in 1..=4u64 {
            batcher.predict(&mut be, BlockId(i), 0, fv(0.9)).unwrap();
        }
        assert_eq!(batcher.cached_len(), 4);
        // Re-predict block 0: the flush inserts it newest and evicts past
        // the bound — the victim must be the oldest live entry, never the
        // entry the current flush just wrote.
        batcher.predict(&mut be, BlockId(0), 1, fv(0.9)).unwrap();
        assert_eq!(batcher.cached_len(), 4);
        let calls = be.calls;
        batcher.predict(&mut be, BlockId(0), 1, fv(0.9)).unwrap();
        assert_eq!(be.calls, calls, "re-inserted block survived the eviction");
        // FIFO still correct: block 1 (the oldest live entry) was evicted.
        batcher.predict(&mut be, BlockId(1), 0, fv(0.9)).unwrap();
        assert_eq!(be.calls, calls + 1, "oldest live entry was the victim");
    }

    /// Regression: a full class cache, a pending prefetch and a
    /// stamp-refresh of the *oldest* resident block in one flush — the
    /// re-scored block must end up newest, not be evicted by its own
    /// flush (which panicked predict()'s "flush populated cache" expect).
    #[test]
    fn rescoring_the_oldest_resident_survives_a_full_flush() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::with_capacity(8, 4);
        for i in 0..4u64 {
            batcher.predict(&mut be, BlockId(i), 0, fv(0.9)).unwrap();
        }
        assert_eq!(batcher.cached_len(), 4, "cache at capacity, block 0 oldest");
        batcher.prefetch(BlockId(9), 0, fv(0.9));
        // Block 0 with a new stamp: the flush scores the prefetched block
        // 9 (over capacity) and re-scores 0 — 0 is the freshest entry and
        // must survive the eviction.
        assert!(batcher.predict(&mut be, BlockId(0), 1, fv(0.9)).unwrap());
        assert_eq!(batcher.cached_len(), 4);
        let calls = be.calls;
        batcher.predict(&mut be, BlockId(0), 1, fv(0.9)).unwrap();
        assert_eq!(be.calls, calls, "re-scored block stayed cached");
        // Block 1 became the oldest live entry and was the victim.
        batcher.predict(&mut be, BlockId(1), 0, fv(0.9)).unwrap();
        assert_eq!(be.calls, calls + 1);
    }

    #[test]
    fn new_model_version_resets_cached_classes() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::new(8);
        batcher.predict(&mut be, BlockId(1), 0, fv(0.9)).unwrap();
        batcher.note_model_version(1);
        assert_eq!(batcher.cached_len(), 0, "stale classes dropped");
        batcher.predict(&mut be, BlockId(1), 0, fv(0.9)).unwrap();
        assert_eq!(be.calls, 2, "re-scored under the new model");
        // Same version again: no reset.
        batcher.note_model_version(1);
        assert_eq!(batcher.cached_len(), 1);
    }

    #[test]
    fn duplicate_prefetch_is_deduped() {
        let mut batcher = PredictionBatcher::new(4);
        batcher.prefetch(BlockId(1), 0, fv(0.5));
        batcher.prefetch(BlockId(1), 0, fv(0.5));
        assert_eq!(batcher.pending_len(), 1);
    }
}
