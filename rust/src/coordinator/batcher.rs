//! Prediction micro-batching + class caching, per shard.
//!
//! Algorithm 1 consults the SVM on *every* cache decision. Calling the
//! PJRT executable per block would put an artifact invocation on each
//! request; instead the coordinator:
//!
//! 1. caches the predicted class per block, invalidating when the block's
//!    feature state drifts (its access count changes — frequency and
//!    recency are the live features), and
//! 2. batches cold predictions: queries accumulate into the artifact's
//!    native batch width before one `decision_batch` call scores them all
//!    (the vLLM-router-style amortization; see DESIGN.md §8).
//!
//! Topology: the single global batcher of the early coordinator became
//! per-shard [`ShardBatcher`]s, routed by the same hash as the shards
//! themselves — a [`BatcherPool`] in the single-threaded coordinator, one
//! batcher *owned by each shard worker* on the concurrent replay path. A
//! miss storm on one shard flushes *that shard's* queue; workers on other
//! shards never wait behind the flush (the ROADMAP "batcher backpressure"
//! item). Each shard batcher holds a **bounded cold-query queue with a
//! flush deadline** (measured in simulated time, so seeded runs stay
//! deterministic): a cold query enqueues and either joins the in-flight
//! batch (deferred, answered by a later flush) or triggers a flush when
//! the queue fills or the oldest entry's deadline lapses. Drop / latency /
//! flush-size counters are surfaced through a cloneable [`BatcherProbe`],
//! exactly like the online sample channel's
//! [`SampleProbe`](super::online::SampleProbe).

use std::collections::hash_map::Entry;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::sync::atomic::{AtomicU64, Ordering};

use crate::cache::order_list::{OrderHandle, OrderList};
use crate::cache::sharded::shard_of;
use crate::obs::{HistHandle, MetricClass, MetricsRegistry};
use crate::sim::{SimDuration, SimTime};
use crate::util::fasthash::IdHashMap;

use anyhow::Result;

use crate::hdfs::BlockId;
use crate::runtime::SvmBackend;
use crate::svm::features::FeatureVec;

/// Default bound on the per-block class cache. Entries for evicted blocks
/// are dropped eagerly ([`PredictionBatcher::invalidate`]); the bound caps
/// whatever survives on long traces with huge keyspaces.
pub const DEFAULT_CLASS_CACHE_CAPACITY: usize = 4096;

/// Cached prediction: class + the access-count stamp it was computed at,
/// plus the block's live handle in the score-order list. (This replaces a
/// stamped-lazy-deletion `VecDeque` — invalidation now unlinks the order
/// entry in O(1) instead of leaving a stale id to be skipped later.)
#[derive(Debug, Clone, Copy)]
struct CachedClass {
    reused: bool,
    stamp: u64,
    handle: OrderHandle,
}

/// Batching predictor with a bounded per-block class cache.
pub struct PredictionBatcher {
    cache: IdHashMap<BlockId, CachedClass>,
    /// Score order of class-cache entries, oldest score at the front —
    /// the eviction order when the cache exceeds `capacity`. Re-scoring a
    /// resident block moves it to the back.
    order: OrderList<BlockId>,
    /// Class-cache bound: beyond it the oldest entries are dropped.
    capacity: usize,
    /// Version of the classifier snapshot the cached classes came from.
    model_version: u64,
    /// Pending cold queries (block, stamp, features).
    pending: Vec<(BlockId, u64, FeatureVec)>,
    /// Reused per-chunk query buffer for `flush` — one allocation for the
    /// batcher's lifetime instead of a fresh `Vec<FeatureVec>` per chunk.
    scratch: Vec<FeatureVec>,
    /// Flush threshold = artifact batch width.
    batch_width: usize,
    /// Telemetry counters (queries, cache hits, backend calls).
    pub stats: BatcherStats,
}

/// Telemetry for the perf pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct BatcherStats {
    /// Class lookups issued.
    pub queries: u64,
    /// Lookups answered from the per-block class cache.
    pub class_cache_hits: u64,
    /// Backend `decision_batch` invocations.
    pub backend_calls: u64,
    /// Individual predictions scored across those calls.
    pub predictions_scored: u64,
}

impl BatcherStats {
    /// Sum counters across per-shard batchers (the [`BatcherPool`] view).
    pub fn merge(&mut self, other: &BatcherStats) {
        self.queries += other.queries;
        self.class_cache_hits += other.class_cache_hits;
        self.backend_calls += other.backend_calls;
        self.predictions_scored += other.predictions_scored;
    }
}

impl PredictionBatcher {
    /// A batcher with the default class-cache capacity.
    pub fn new(batch_width: usize) -> Self {
        Self::with_capacity(batch_width, DEFAULT_CLASS_CACHE_CAPACITY)
    }

    /// A batcher whose class cache holds at most `capacity` blocks.
    pub fn with_capacity(batch_width: usize, capacity: usize) -> Self {
        PredictionBatcher {
            cache: IdHashMap::default(),
            order: OrderList::new(),
            capacity: capacity.max(1),
            model_version: 0,
            pending: Vec::new(),
            scratch: Vec::new(),
            batch_width: batch_width.max(1),
            stats: BatcherStats::default(),
        }
    }

    /// Class-cache lookup for one query (counted). `Some` only when the
    /// cached class was computed at the same feature stamp.
    pub fn lookup(&mut self, block: BlockId, stamp: u64) -> Option<bool> {
        self.stats.queries += 1;
        if let Some(c) = self.cache.get(&block) {
            if c.stamp == stamp {
                self.stats.class_cache_hits += 1;
                return Some(c.reused);
            }
        }
        None
    }

    /// The cached class of a block regardless of stamp (post-flush read;
    /// `None` when the block is not in the class cache).
    pub fn class_of(&self, block: BlockId) -> Option<bool> {
        self.cache.get(&block).map(|c| c.reused)
    }

    /// Predict the class of one block, given its current feature vector and
    /// an access-count stamp. Uses the class cache when the stamp matches;
    /// otherwise queues the query and flushes a full batch through the
    /// backend synchronously (the caller needs the answer now — pending
    /// entries ride along in the same call).
    pub fn predict(
        &mut self,
        backend: &mut dyn SvmBackend,
        block: BlockId,
        stamp: u64,
        features: FeatureVec,
    ) -> Result<bool> {
        if let Some(class) = self.lookup(block, stamp) {
            return Ok(class);
        }
        self.prefetch(block, stamp, features);
        self.flush(backend)?;
        Ok(self.class_of(block).expect("flush populated cache"))
    }

    /// Score everything pending in batch_width chunks.
    pub fn flush(&mut self, backend: &mut dyn SvmBackend) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        let pending = std::mem::take(&mut self.pending);
        for chunk in pending.chunks(self.batch_width) {
            self.scratch.clear();
            self.scratch.extend(chunk.iter().map(|(_, _, f)| *f));
            let scores = backend.decision_batch(&self.scratch)?;
            self.stats.backend_calls += 1;
            self.stats.predictions_scored += scores.len() as u64;
            for ((block, stamp, _), score) in chunk.iter().zip(scores) {
                // Every score — fresh insert or stamp-refresh of a
                // resident block — lands at the order back. That keeps
                // just-scored entries out of reach of the capacity
                // eviction below: predict()'s own query is the last one
                // scored, so the entry it reads back is always the newest
                // and can never be the over-capacity victim.
                let reused = score > 0.0;
                match self.cache.entry(*block) {
                    Entry::Occupied(mut e) => {
                        let c = e.get_mut();
                        c.reused = reused;
                        c.stamp = *stamp;
                        self.order.move_to_back(c.handle);
                    }
                    Entry::Vacant(e) => {
                        let handle = self.order.push_back(*block);
                        e.insert(CachedClass { reused, stamp: *stamp, handle });
                    }
                }
            }
        }
        self.enforce_capacity();
        Ok(())
    }

    /// Drop oldest-scored class-cache entries past the bound. The order
    /// list holds exactly the cached blocks (invalidation unlinks), so
    /// every front entry is live.
    fn enforce_capacity(&mut self) {
        while self.cache.len() > self.capacity {
            let oldest = self.order.pop_front().expect("cached entries are ordered");
            self.cache.remove(&oldest);
        }
    }

    /// Queue a prediction without needing the answer immediately (prefetch
    /// for blocks we expect to decide on soon). Deduplicates against the
    /// class cache (same stamp) and the pending queue.
    pub fn prefetch(&mut self, block: BlockId, stamp: u64, features: FeatureVec) {
        let fresh = self
            .cache
            .get(&block)
            .map(|c| c.stamp == stamp)
            .unwrap_or(false);
        if !fresh && !self.pending.iter().any(|(b, s, _)| *b == block && *s == stamp) {
            self.pending.push((block, stamp, features));
        }
    }

    /// Invalidate one block's cached class — called from the eviction /
    /// uncache path so the class cache tracks the block population instead
    /// of growing monotonically over the trace.
    pub fn invalidate(&mut self, block: BlockId) {
        if let Some(c) = self.cache.remove(&block) {
            self.order.unlink(c.handle);
        }
        self.pending.retain(|(b, _, _)| *b != block);
    }

    /// Invalidate all cached classes (after retraining).
    pub fn invalidate_all(&mut self) {
        self.cache.clear();
        self.order.clear();
        self.pending.clear();
    }

    /// Note the classifier-snapshot version serving predictions. When it
    /// moves, every cached class came from a stale model and is dropped
    /// (pending queries are kept — they will be scored by the new model).
    pub fn note_model_version(&mut self, version: u64) {
        if version != self.model_version {
            self.model_version = version;
            self.cache.clear();
            self.order.clear();
        }
    }

    /// Blocks with a cached class.
    pub fn cached_len(&self) -> usize {
        self.cache.len()
    }

    /// Cold queries awaiting a flush.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Drop every pending query without scoring it, returning how many were
    /// discarded. Used by the end-of-run flush when an open circuit breaker
    /// means the queue will never be scored — the entries are accounted as
    /// dropped instead of leaking from the conservation ledger.
    pub fn drop_pending(&mut self) -> usize {
        let n = self.pending.len();
        self.pending.clear();
        n
    }
}

// --------------------------------------------------- bounded shard batcher

/// Knobs of one shard's cold-query queue (see [`ShardBatcher`]).
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Backend batch width (= the artifact's native batch size).
    pub batch_width: usize,
    /// Per-shard class-cache bound (clamped up to `queue_depth` so a
    /// flush can never evict its own just-scored entries).
    pub class_cache_capacity: usize,
    /// Cold queries buffered on a shard before a flush is forced. `1`
    /// reproduces the legacy behavior exactly: every cold query flushes
    /// synchronously and the caller always gets its class.
    pub queue_depth: usize,
    /// Oldest-pending age — in **simulated** time, so seeded runs stay
    /// bit-for-bit reproducible — that forces a flush even below
    /// `queue_depth`, bounding how stale a deferred answer can get.
    pub deadline: SimDuration,
    /// Circuit breaker over the backend flush path. Disabled by default:
    /// the default config is behaviorally bit-identical to the
    /// pre-breaker batcher.
    pub breaker: BreakerConfig,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            batch_width: 64,
            class_cache_capacity: DEFAULT_CLASS_CACHE_CAPACITY,
            queue_depth: 1,
            deadline: SimDuration::from_micros(2_000),
            breaker: BreakerConfig::off(),
        }
    }
}

// ------------------------------------------------------- circuit breaker

/// Circuit-breaker knobs for one shard's backend flush path.
///
/// Closed → `failure_threshold` consecutive flush failures → **Open**
/// (every cold query falls back to unclassified, the policy's existing
/// cold-path semantics) → after `probe_after` of simulated time a single
/// probe flush is allowed (**HalfOpen**) → success closes the breaker,
/// failure re-opens it. Each backend call inside a flush additionally gets
/// `max_retries` bounded retries with `retry_backoff` of simulated backoff
/// charged to telemetry (time does not advance mid-flush, so the retry
/// schedule is deterministic).
///
/// All timing runs on the caller's request clock ([`SimTime`]); the state
/// lives in the owning [`ShardBatcher`] (no shared mutable state), so
/// seeded replays stay bit-for-bit reproducible at any shard count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BreakerConfig {
    /// Master switch; `false` short-circuits every breaker check.
    pub enabled: bool,
    /// Consecutive flush failures (from the Closed state) that open the
    /// breaker.
    pub failure_threshold: u32,
    /// Extra backend attempts per `decision_batch` call after the first
    /// fails.
    pub max_retries: u32,
    /// Simulated backoff charged per retry (telemetry only — see
    /// [`BatcherProbe::retry_backoff_us`]).
    pub retry_backoff: SimDuration,
    /// Open → HalfOpen probe cadence in simulated time.
    pub probe_after: SimDuration,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig {
            enabled: false,
            failure_threshold: 3,
            max_retries: 1,
            retry_backoff: SimDuration::from_micros(500),
            probe_after: SimDuration::from_micros(250_000),
        }
    }
}

impl BreakerConfig {
    /// The disabled breaker (the default).
    pub fn off() -> Self {
        Self::default()
    }

    /// The breaker with default thresholds, enabled.
    pub fn on() -> Self {
        BreakerConfig { enabled: true, ..Self::default() }
    }
}

/// The breaker's observable state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: flushes go to the backend.
    Closed,
    /// Tripped: cold queries fall back to unclassified without touching
    /// the backend.
    Open,
    /// Probe window: the next flush is allowed through; its outcome
    /// decides Closed vs. re-Open.
    HalfOpen,
}

/// Per-shard breaker state machine (owned by one [`ShardBatcher`] — not
/// shared, so no atomics and nothing for loom to model).
#[derive(Debug)]
struct Breaker {
    cfg: BreakerConfig,
    state: BreakerState,
    consecutive_failures: u32,
    /// Simulated instant of the last transition to Open.
    opened_at: SimTime,
    /// Latest request time observed — the transition stamp for flushes
    /// that carry no clock (end-of-run forced flushes).
    last_now: SimTime,
}

impl Breaker {
    fn new(cfg: BreakerConfig) -> Self {
        Breaker {
            cfg,
            state: BreakerState::Closed,
            consecutive_failures: 0,
            opened_at: SimTime::ZERO,
            last_now: SimTime::ZERO,
        }
    }

    fn active(&self) -> bool {
        self.cfg.enabled
    }

    fn state(&self) -> BreakerState {
        self.state
    }

    /// May the backend be called at `now`? Moves Open → HalfOpen when the
    /// probe cadence lapsed.
    fn allows(&mut self, now: SimTime) -> bool {
        self.last_now = self.last_now.max(now);
        match self.state {
            BreakerState::Closed | BreakerState::HalfOpen => true,
            BreakerState::Open => {
                if self.opened_at.duration_until(now) >= self.cfg.probe_after {
                    self.state = BreakerState::HalfOpen;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// A flush succeeded; returns true when this closed the breaker.
    fn on_success(&mut self) -> bool {
        self.consecutive_failures = 0;
        if self.state == BreakerState::Closed {
            false
        } else {
            self.state = BreakerState::Closed;
            true
        }
    }

    /// A flush failed at `now`; returns true when this opened (or
    /// re-opened) the breaker.
    fn on_failure(&mut self, now: SimTime) -> bool {
        self.consecutive_failures = self.consecutive_failures.saturating_add(1);
        let opens = match self.state {
            BreakerState::HalfOpen => true,
            BreakerState::Closed => self.consecutive_failures >= self.cfg.failure_threshold,
            BreakerState::Open => false,
        };
        if opens {
            self.state = BreakerState::Open;
            self.opened_at = now;
        }
        opens
    }
}

/// Bounded-retry adapter around one flush's backend: re-asks
/// `decision_batch` up to `budget` extra times on error, tallying each
/// retry. Time does not advance mid-flush, so during an injected outage
/// the budget deterministically exhausts — the backoff is charged to
/// telemetry, never the clock.
struct RetryBackend<'a> {
    inner: &'a mut dyn SvmBackend,
    budget: u32,
    retries: &'a mut u64,
}

impl SvmBackend for RetryBackend<'_> {
    fn name(&self) -> &'static str {
        "retry"
    }

    fn train(&mut self, ds: &crate::svm::Dataset) -> Result<()> {
        self.inner.train(ds)
    }

    fn decision_batch(&mut self, queries: &[FeatureVec]) -> Result<Vec<f32>> {
        let mut attempt = 0u32;
        loop {
            match self.inner.decision_batch(queries) {
                Ok(scores) => return Ok(scores),
                Err(e) => {
                    if attempt >= self.budget {
                        return Err(e);
                    }
                    attempt += 1;
                    *self.retries += 1;
                }
            }
        }
    }

    fn is_trained(&self) -> bool {
        self.inner.is_trained()
    }
}

/// Shared cold-path counters of one batcher topology (every
/// [`ShardBatcher`] constructed from the same [`BatcherProbe`] clone).
#[derive(Debug)]
struct ColdCounters {
    cold: AtomicU64,
    deferred: AtomicU64,
    flushes: AtomicU64,
    flush_fill: AtomicU64,
    flush_deadline: AtomicU64,
    flushed_queries: AtomicU64,
    flush_ns: AtomicU64,
    dropped: AtomicU64,
    breaker_opens: AtomicU64,
    breaker_closes: AtomicU64,
    breaker_fallbacks: AtomicU64,
    retries: AtomicU64,
    retry_backoff_us: AtomicU64,
}

impl Default for ColdCounters {
    // Spelled out (instead of derived) because loom atomics lack `Default`.
    fn default() -> Self {
        ColdCounters {
            cold: AtomicU64::new(0),
            deferred: AtomicU64::new(0),
            flushes: AtomicU64::new(0),
            flush_fill: AtomicU64::new(0),
            flush_deadline: AtomicU64::new(0),
            flushed_queries: AtomicU64::new(0),
            flush_ns: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            breaker_opens: AtomicU64::new(0),
            breaker_closes: AtomicU64::new(0),
            breaker_fallbacks: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            retry_backoff_us: AtomicU64::new(0),
        }
    }
}

/// Read-only, cloneable view of the cold-query counters — the
/// [`SampleProbe`](super::online::SampleProbe) pattern for the prediction
/// path. Cloning shares the counters; `BatcherProbe::new()` starts a
/// fresh set.
#[derive(Debug, Clone, Default)]
pub struct BatcherProbe {
    counters: Arc<ColdCounters>,
}

impl BatcherProbe {
    /// A probe with fresh zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Cold queries that missed the class cache and entered a queue.
    pub fn cold_queries(&self) -> u64 {
        self.counters.cold.load(Ordering::Relaxed)
    }

    /// Cold queries answered `None` (queued for a later flush instead of
    /// flushing inline).
    pub fn deferred(&self) -> u64 {
        self.counters.deferred.load(Ordering::Relaxed)
    }

    /// Backend flushes of the cold queue (fill + deadline + forced).
    pub fn flushes(&self) -> u64 {
        self.counters.flushes.load(Ordering::Relaxed)
    }

    /// Flushes triggered by the queue reaching `queue_depth`.
    pub fn flushes_by_fill(&self) -> u64 {
        self.counters.flush_fill.load(Ordering::Relaxed)
    }

    /// Flushes triggered by the oldest entry's deadline (or forced).
    pub fn flushes_by_deadline(&self) -> u64 {
        self.counters.flush_deadline.load(Ordering::Relaxed)
    }

    /// Cold queries scored across all flushes.
    pub fn flushed_queries(&self) -> u64 {
        self.counters.flushed_queries.load(Ordering::Relaxed)
    }

    /// Pending queries lost to invalidation or a failed flush.
    pub fn dropped(&self) -> u64 {
        self.counters.dropped.load(Ordering::Relaxed)
    }

    /// Closed/HalfOpen → Open breaker transitions across all shards.
    pub fn breaker_opens(&self) -> u64 {
        self.counters.breaker_opens.load(Ordering::Relaxed)
    }

    /// Open/HalfOpen → Closed (recovery) transitions across all shards.
    pub fn breaker_closes(&self) -> u64 {
        self.counters.breaker_closes.load(Ordering::Relaxed)
    }

    /// Cold queries answered `None` because the breaker was open (the
    /// caller fell back to unclassified plain-LRU placement).
    pub fn breaker_fallbacks(&self) -> u64 {
        self.counters.breaker_fallbacks.load(Ordering::Relaxed)
    }

    /// Bounded backend retries spent inside flushes.
    pub fn retries(&self) -> u64 {
        self.counters.retries.load(Ordering::Relaxed)
    }

    /// Total simulated backoff charged for those retries, in microseconds.
    pub fn retry_backoff_us(&self) -> u64 {
        self.counters.retry_backoff_us.load(Ordering::Relaxed)
    }

    /// Mean queries per flush (0 when nothing flushed yet).
    pub fn mean_flush_size(&self) -> f64 {
        let flushes = self.flushes();
        if flushes == 0 {
            0.0
        } else {
            self.flushed_queries() as f64 / flushes as f64
        }
    }

    /// Mean wall-clock backend latency per flush.
    pub fn mean_flush_latency(&self) -> Duration {
        let flushes = self.flushes();
        if flushes == 0 {
            Duration::ZERO
        } else {
            Duration::from_nanos(self.counters.flush_ns.load(Ordering::Relaxed) / flushes)
        }
    }

    /// Expose every cold-path counter as a `{prefix}.…` gauge so the
    /// JSONL export carries the same numbers the accessor API reports.
    /// The probe stays the programmatic view; the gauges are thin reads
    /// over the same shared cells, so they agree by construction.
    pub fn register_gauges(&self, registry: &MetricsRegistry, prefix: &str) {
        let gauge = |name: &str, read: fn(&ColdCounters) -> &AtomicU64| {
            let counters = Arc::clone(&self.counters);
            registry.gauge(&format!("{prefix}.{name}"), move || {
                read(&counters).load(Ordering::Relaxed)
            });
        };
        gauge("cold_queries", |c| &c.cold);
        gauge("deferred", |c| &c.deferred);
        gauge("flushes", |c| &c.flushes);
        gauge("flushes_by_fill", |c| &c.flush_fill);
        gauge("flushes_by_deadline", |c| &c.flush_deadline);
        gauge("flushed_queries", |c| &c.flushed_queries);
        gauge("dropped", |c| &c.dropped);
    }

    /// Expose the circuit-breaker counters as `{prefix}.…` gauges. Kept
    /// separate from [`register_gauges`](Self::register_gauges) so drivers
    /// that never enable the breaker export exactly the pre-breaker JSONL
    /// (the all-clear parity guarantee).
    pub fn register_breaker_gauges(&self, registry: &MetricsRegistry, prefix: &str) {
        let gauge = |name: &str, read: fn(&ColdCounters) -> &AtomicU64| {
            let counters = Arc::clone(&self.counters);
            registry.gauge(&format!("{prefix}.{name}"), move || {
                read(&counters).load(Ordering::Relaxed)
            });
        };
        gauge("breaker_opens", |c| &c.breaker_opens);
        gauge("breaker_closes", |c| &c.breaker_closes);
        gauge("breaker_fallbacks", |c| &c.breaker_fallbacks);
        gauge("retries", |c| &c.retries);
        gauge("retry_backoff_us", |c| &c.retry_backoff_us);
    }
}

/// Per-shard histogram recorders of one [`ShardBatcher`] — flush sizes and
/// simulated queue waits are [`MetricClass::Deterministic`] (exported),
/// backend wall-clock latency is [`MetricClass::Volatile`] (log-only).
/// `Default` is fully inert, as are handles from a disabled registry, so
/// the un-instrumented hot path pays one null check per flush.
#[derive(Debug, Clone, Default)]
pub struct BatcherObs {
    shard: usize,
    flush_size: HistHandle,
    queue_wait_us: HistHandle,
    flush_wall_ns: HistHandle,
}

impl BatcherObs {
    /// Recorder for shard `shard` of `shards`, registering the shared
    /// histograms on first use.
    pub fn register(registry: &MetricsRegistry, shards: usize, shard: usize) -> Self {
        BatcherObs {
            shard,
            flush_size: registry.histogram(
                "batcher.flush_size",
                MetricClass::Deterministic,
                shards,
            ),
            queue_wait_us: registry.histogram(
                "batcher.queue_wait_us",
                MetricClass::Deterministic,
                shards,
            ),
            flush_wall_ns: registry.histogram(
                "batcher.flush_wall_ns",
                MetricClass::Volatile,
                shards,
            ),
        }
    }
}

/// One shard's predictor: a [`PredictionBatcher`] behind a bounded
/// cold-query queue with a flush deadline.
///
/// [`ShardBatcher::predict`] returns `Ok(Some(class))` from the class
/// cache or an inline flush, and `Ok(None)` when the query was *deferred*
/// — enqueued to join the next batch. Callers treat a deferred query like
/// an untrained classifier (fall back to plain LRU behavior for that one
/// access); the answer lands in the class cache when the queue fills or
/// the deadline lapses.
pub struct ShardBatcher {
    inner: PredictionBatcher,
    queue_depth: usize,
    deadline: SimDuration,
    /// Simulated enqueue time of the oldest pending query (None = queue
    /// empty). Deadlines run on the caller-supplied [`SimTime`], never the
    /// wall clock, so flush timing is deterministic under a fixed seed.
    oldest: Option<SimTime>,
    counters: Arc<ColdCounters>,
    obs: BatcherObs,
    /// Circuit breaker over the backend flush path (inert unless
    /// [`BreakerConfig::enabled`]). Owned per shard — no shared state.
    breaker: Breaker,
}

impl ShardBatcher {
    /// A batcher with its own private telemetry counters.
    pub fn new(cfg: BatcherConfig) -> Self {
        Self::with_probe(cfg, BatcherProbe::new())
    }

    /// A batcher reporting into `probe`'s counters — how a pool (or a set
    /// of per-worker batchers) shares one telemetry surface.
    pub fn with_probe(cfg: BatcherConfig, probe: BatcherProbe) -> Self {
        let capacity = cfg.class_cache_capacity.max(cfg.queue_depth);
        ShardBatcher {
            inner: PredictionBatcher::with_capacity(cfg.batch_width, capacity),
            queue_depth: cfg.queue_depth.max(1),
            deadline: cfg.deadline,
            oldest: None,
            counters: probe.counters,
            obs: BatcherObs::default(),
            breaker: Breaker::new(cfg.breaker),
        }
    }

    /// Attach histogram recorders (inert by default — see [`BatcherObs`]).
    pub fn set_obs(&mut self, obs: BatcherObs) {
        self.obs = obs;
    }

    /// A probe sharing this batcher's counters.
    pub fn probe(&self) -> BatcherProbe {
        BatcherProbe { counters: Arc::clone(&self.counters) }
    }

    /// The wrapped batcher's telemetry counters.
    pub fn stats(&self) -> BatcherStats {
        self.inner.stats
    }

    /// Answer a query from the class cache, flush inline (queue full or
    /// deadline lapsed), or defer (`Ok(None)`). `now` is the caller's
    /// simulated clock (request time); within a shard it must be
    /// monotone, which trace order and the coordinator both guarantee.
    pub fn predict(
        &mut self,
        backend: &mut dyn SvmBackend,
        block: BlockId,
        stamp: u64,
        features: FeatureVec,
        now: SimTime,
    ) -> Result<Option<bool>> {
        if let Some(class) = self.inner.lookup(block, stamp) {
            // A class-cache hit must not starve the queue: an overdue
            // batch still flushes on this shard's traffic. A flush
            // failure must not discard the valid cached answer, though —
            // the drop is already counted, and the next cold query will
            // surface the backend error to the caller.
            let _ = self.maybe_flush(backend, now);
            return Ok(Some(class));
        }
        // Open breaker: the query never enters the queue — the caller
        // falls back to unclassified plain-LRU placement, the policy's
        // existing cold-path semantics. (An open breaker also means the
        // queue cannot grow unboundedly during an outage.) `allows` moves
        // Open → HalfOpen once the probe cadence lapses; a HalfOpen shard
        // forces the next cold query to flush inline as the probe.
        let mut probing = false;
        if self.breaker.active() {
            if !self.breaker.allows(now) {
                self.counters.breaker_fallbacks.fetch_add(1, Ordering::Relaxed);
                return Ok(None);
            }
            probing = self.breaker.state() == BreakerState::HalfOpen;
        }
        // `prefetch` dedupes against an already-pending (block, stamp):
        // only count queries that actually entered the queue as cold (and
        // as deferred below), so deferred <= cold_queries and
        // cold_queries == flushed_queries + dropped (+ still pending).
        let before = self.inner.pending_len();
        self.inner.prefetch(block, stamp, features);
        let enqueued = self.inner.pending_len() > before;
        if enqueued {
            self.counters.cold.fetch_add(1, Ordering::Relaxed);
        }
        let oldest = *self.oldest.get_or_insert(now);
        let fill = self.inner.pending_len() >= self.queue_depth;
        let late = oldest.duration_until(now) >= self.deadline;
        if !fill && !late && !probing {
            if enqueued {
                self.counters.deferred.fetch_add(1, Ordering::Relaxed);
            }
            return Ok(None);
        }
        match self.flush_now(backend, fill, Some(now)) {
            Ok(()) => {}
            // Degraded mode: the failure was tallied (and may have opened
            // the breaker); this caller falls back to unclassified instead
            // of surfacing the backend error up the serving path.
            Err(_) if self.breaker.active() => return Ok(None),
            Err(e) => return Err(e),
        }
        Ok(self.inner.class_of(block))
    }

    /// Enqueue without needing an answer (rides along with the next flush).
    pub fn prefetch(&mut self, block: BlockId, stamp: u64, features: FeatureVec, now: SimTime) {
        self.inner.prefetch(block, stamp, features);
        if self.inner.pending_len() > 0 {
            self.oldest.get_or_insert(now);
        }
    }

    /// Flush the queue if the oldest pending query outlived the deadline
    /// (the periodic sweep callers run between requests).
    pub fn maybe_flush(&mut self, backend: &mut dyn SvmBackend, now: SimTime) -> Result<()> {
        if let Some(oldest) = self.oldest {
            if oldest.duration_until(now) >= self.deadline {
                self.flush_now(backend, false, Some(now))?;
            }
        }
        Ok(())
    }

    /// Unconditional flush (end of run; counted as a deadline flush).
    pub fn flush(&mut self, backend: &mut dyn SvmBackend) -> Result<()> {
        self.flush_now(backend, false, None)
    }

    // Wall-clock exception: flush latency is a `MetricClass::Volatile`
    // metric (log-only, excluded from the deterministic export), so this
    // is one of the few vetted `Instant::now` call sites — see clippy.toml
    // and rust/tests/lint_invariants.rs.
    #[allow(clippy::disallowed_methods)]
    fn flush_now(
        &mut self,
        backend: &mut dyn SvmBackend,
        by_fill: bool,
        now: Option<SimTime>,
    ) -> Result<()> {
        // Open breaker: leave the queue pending (bounded by queue_depth —
        // predict() stops enqueueing while open) until the probe cadence
        // reopens the path. The end-of-run flush (`now == None`) instead
        // drops the queue and accounts it, keeping the conservation
        // invariant cold == flushed + dropped at exit.
        if self.breaker.active() {
            let at = now.unwrap_or(self.breaker.last_now);
            if !self.breaker.allows(at) {
                if now.is_none() {
                    let stranded = self.inner.drop_pending() as u64;
                    if stranded > 0 {
                        self.counters.dropped.fetch_add(stranded, Ordering::Relaxed);
                    }
                    self.oldest = None;
                }
                return Ok(());
            }
        }
        let n = self.inner.pending_len() as u64;
        // Simulated queue wait of the oldest pending query — deterministic
        // under a fixed seed, unlike the wall-clock flush latency below.
        // Forced end-of-run flushes pass no `now` and record no wait.
        if let (Some(now), Some(oldest), true) = (now, self.oldest, n > 0) {
            self.obs
                .queue_wait_us
                .record(self.obs.shard, oldest.duration_until(now).micros());
        }
        self.oldest = None;
        if n == 0 {
            return Ok(());
        }
        let scored_before = self.inner.stats.predictions_scored;
        let t0 = Instant::now();
        let result = if self.breaker.active() && self.breaker.cfg.max_retries > 0 {
            let mut retries = 0u64;
            let r = {
                let mut retry = RetryBackend {
                    inner: backend,
                    budget: self.breaker.cfg.max_retries,
                    retries: &mut retries,
                };
                self.inner.flush(&mut retry)
            };
            if retries > 0 {
                self.counters.retries.fetch_add(retries, Ordering::Relaxed);
                self.counters.retry_backoff_us.fetch_add(
                    retries * self.breaker.cfg.retry_backoff.micros(),
                    Ordering::Relaxed,
                );
            }
            r
        } else {
            self.inner.flush(backend)
        };
        // A multi-chunk flush can fail part-way: earlier chunks were
        // scored and cached (count them flushed), only the remainder was
        // taken-and-lost (count those dropped). On success scored == n.
        let scored = self.inner.stats.predictions_scored - scored_before;
        if scored > 0 {
            self.counters.flushes.fetch_add(1, Ordering::Relaxed);
            if by_fill {
                self.counters.flush_fill.fetch_add(1, Ordering::Relaxed);
            } else {
                self.counters.flush_deadline.fetch_add(1, Ordering::Relaxed);
            }
            self.counters.flushed_queries.fetch_add(scored, Ordering::Relaxed);
            let wall_ns = t0.elapsed().as_nanos() as u64;
            self.counters.flush_ns.fetch_add(wall_ns, Ordering::Relaxed);
            self.obs.flush_size.record(self.obs.shard, scored);
            self.obs.flush_wall_ns.record(self.obs.shard, wall_ns);
        }
        if scored < n {
            self.counters.dropped.fetch_add(n - scored, Ordering::Relaxed);
        }
        if self.breaker.active() {
            let at = now.unwrap_or(self.breaker.last_now);
            if result.is_ok() {
                if self.breaker.on_success() {
                    self.counters.breaker_closes.fetch_add(1, Ordering::Relaxed);
                }
            } else if self.breaker.on_failure(at) {
                self.counters.breaker_opens.fetch_add(1, Ordering::Relaxed);
            }
        }
        result
    }

    /// Invalidate one block (eviction / uncache); pending queries for it
    /// are dropped and counted.
    pub fn invalidate(&mut self, block: BlockId) {
        let before = self.inner.pending_len();
        self.inner.invalidate(block);
        let removed = (before - self.inner.pending_len()) as u64;
        if removed > 0 {
            self.counters.dropped.fetch_add(removed, Ordering::Relaxed);
        }
        if self.inner.pending_len() == 0 {
            self.oldest = None;
        }
    }

    /// Drop every cached class and pending query (counted as dropped).
    pub fn invalidate_all(&mut self) {
        let pending = self.inner.pending_len() as u64;
        if pending > 0 {
            self.counters.dropped.fetch_add(pending, Ordering::Relaxed);
        }
        self.inner.invalidate_all();
        self.oldest = None;
    }

    /// Classifier-snapshot invalidation: a moved version drops every
    /// cached class (pending queries survive — the new model scores them).
    pub fn note_model_version(&mut self, version: u64) {
        self.inner.note_model_version(version);
    }

    /// Blocks with a cached class.
    pub fn cached_len(&self) -> usize {
        self.inner.cached_len()
    }

    /// Cold queries awaiting a flush.
    pub fn pending_len(&self) -> usize {
        self.inner.pending_len()
    }

    /// Current circuit-breaker state, or `None` when the breaker is
    /// disabled (the default config).
    pub fn breaker_state(&self) -> Option<BreakerState> {
        if self.breaker.active() {
            Some(self.breaker.state())
        } else {
            None
        }
    }
}

// -------------------------------------------------------------- the pool

/// Per-shard [`ShardBatcher`]s behind one front, routed by the cache's
/// own [`shard_of`] hash — the single-threaded coordinator's batcher
/// topology. The pool gives each shard an independent queue and routes
/// invalidation per shard while `note_model_version` fans a deployment
/// out to every batcher. It is deliberately lock-free plumbing over
/// `&mut self`: the coordinator is single-threaded, so wrapping each
/// shard in a `Mutex` would be pure overhead. Concurrent consumers (the
/// online sharded replay) instead give each worker its *own*
/// [`ShardBatcher`] — that is where a miss storm on one shard stops
/// blocking the others (benchmarked in `bench_sharded`'s miss-storm
/// scenario).
pub struct BatcherPool {
    shards: Vec<ShardBatcher>,
    probe: BatcherProbe,
}

impl BatcherPool {
    /// A pool of `n_shards` batchers sharing one telemetry probe.
    pub fn new(n_shards: usize, cfg: BatcherConfig) -> Self {
        let probe = BatcherProbe::new();
        let shards = (0..n_shards.max(1))
            .map(|_| ShardBatcher::with_probe(cfg, probe.clone()))
            .collect();
        BatcherPool { shards, probe }
    }

    /// Number of per-shard batchers.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    fn shard(&mut self, block: BlockId) -> &mut ShardBatcher {
        let idx = shard_of(block, self.shards.len());
        &mut self.shards[idx]
    }

    /// Predict through the owning shard's batcher (see
    /// [`ShardBatcher::predict`] for the `Ok(None)` deferral contract).
    pub fn predict(
        &mut self,
        backend: &mut dyn SvmBackend,
        block: BlockId,
        stamp: u64,
        features: FeatureVec,
        now: SimTime,
    ) -> Result<Option<bool>> {
        self.shard(block).predict(backend, block, stamp, features, now)
    }

    /// Enqueue on the owning shard without needing an answer.
    pub fn prefetch(&mut self, block: BlockId, stamp: u64, features: FeatureVec, now: SimTime) {
        self.shard(block).prefetch(block, stamp, features, now);
    }

    /// Deadline sweep across every shard: flush any queue whose oldest
    /// pending query is overdue at `now`. Cheap when nothing is pending;
    /// the coordinator runs it on its label-sweep cadence so queues on
    /// quiet shards cannot hold deferred queries past the deadline.
    pub fn sweep(&mut self, backend: &mut dyn SvmBackend, now: SimTime) -> Result<()> {
        for shard in &mut self.shards {
            shard.maybe_flush(backend, now)?;
        }
        Ok(())
    }

    /// Invalidate one block on its owning shard only.
    pub fn invalidate(&mut self, block: BlockId) {
        self.shard(block).invalidate(block);
    }

    /// Drop every shard's cached classes and pending queries.
    pub fn invalidate_all(&mut self) {
        for shard in &mut self.shards {
            shard.invalidate_all();
        }
    }

    /// Broadcast a published snapshot version to **every** per-shard
    /// batcher — the invalidation fan-out a model deployment requires.
    pub fn note_model_version(&mut self, version: u64) {
        for shard in &mut self.shards {
            shard.note_model_version(version);
        }
    }

    /// Flush every shard's queue (end of run / measurement boundary).
    pub fn flush_all(&mut self, backend: &mut dyn SvmBackend) -> Result<()> {
        for shard in &mut self.shards {
            shard.flush(backend)?;
        }
        Ok(())
    }

    /// Attach per-shard histogram recorders and the `batcher.*` cold-path
    /// gauges to `registry` (a no-op against a disabled registry).
    pub fn attach_obs(&mut self, registry: &MetricsRegistry) {
        let n = self.shards.len();
        for (i, shard) in self.shards.iter_mut().enumerate() {
            shard.set_obs(BatcherObs::register(registry, n, i));
        }
        self.probe.register_gauges(registry, "batcher");
    }

    /// The shared cold-path counters of every shard batcher.
    pub fn probe(&self) -> BatcherProbe {
        self.probe.clone()
    }

    /// Class-cache telemetry merged across shards.
    pub fn stats(&self) -> BatcherStats {
        let mut acc = BatcherStats::default();
        for shard in &self.shards {
            acc.merge(&shard.stats());
        }
        acc
    }

    /// Blocks with a cached class, summed over shards.
    pub fn cached_len(&self) -> usize {
        self.shards.iter().map(|s| s.cached_len()).sum()
    }

    /// Cold queries awaiting a flush, summed over shards.
    pub fn pending_len(&self) -> usize {
        self.shards.iter().map(|s| s.pending_len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::features::N_FEATURES;

    /// A backend that classifies by feature[0] > 0.5 and counts calls.
    struct FakeBackend {
        calls: u64,
    }

    impl SvmBackend for FakeBackend {
        fn name(&self) -> &'static str {
            "fake"
        }
        fn train(&mut self, _ds: &crate::svm::Dataset) -> Result<()> {
            Ok(())
        }
        fn decision_batch(&mut self, q: &[FeatureVec]) -> Result<Vec<f32>> {
            self.calls += 1;
            Ok(q.iter().map(|f| f[0] - 0.5).collect())
        }
        fn is_trained(&self) -> bool {
            true
        }
    }

    /// A backend that always fails (drop accounting on failed flushes).
    struct BrokenBackend;

    impl SvmBackend for BrokenBackend {
        fn name(&self) -> &'static str {
            "broken"
        }
        fn train(&mut self, _ds: &crate::svm::Dataset) -> Result<()> {
            anyhow::bail!("broken")
        }
        fn decision_batch(&mut self, _q: &[FeatureVec]) -> Result<Vec<f32>> {
            anyhow::bail!("broken")
        }
        fn is_trained(&self) -> bool {
            true
        }
    }

    fn fv(v: f32) -> FeatureVec {
        let mut f = [0.0f32; N_FEATURES];
        f[0] = v;
        f
    }

    #[test]
    fn class_cache_avoids_backend_calls() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::new(8);
        let b = BlockId(1);
        assert!(batcher.predict(&mut be, b, 0, fv(0.9)).unwrap());
        assert_eq!(be.calls, 1);
        // Same stamp: served from the class cache.
        for _ in 0..10 {
            assert!(batcher.predict(&mut be, b, 0, fv(0.9)).unwrap());
        }
        assert_eq!(be.calls, 1);
        assert_eq!(batcher.stats.class_cache_hits, 10);
        // New stamp: re-scored.
        assert!(!batcher.predict(&mut be, b, 1, fv(0.1)).unwrap());
        assert_eq!(be.calls, 2);
    }

    #[test]
    fn prefetch_batches_ride_along() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::new(8);
        for i in 0..5 {
            batcher.prefetch(BlockId(i), 0, fv(0.8));
        }
        assert_eq!(batcher.pending_len(), 5);
        // One predict triggers a single backend call scoring all 6.
        assert!(batcher.predict(&mut be, BlockId(9), 0, fv(0.7)).unwrap());
        assert_eq!(be.calls, 1);
        assert_eq!(batcher.stats.predictions_scored, 6);
        // The prefetched classes are now cached.
        assert!(batcher.predict(&mut be, BlockId(3), 0, fv(0.8)).unwrap());
        assert_eq!(be.calls, 1);
    }

    #[test]
    fn oversized_pending_splits_into_chunks() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::new(4);
        for i in 0..9 {
            batcher.prefetch(BlockId(i), 0, fv(0.6));
        }
        batcher.flush(&mut be).unwrap();
        assert_eq!(be.calls, 3, "9 queries / width 4 = 3 calls");
        assert_eq!(batcher.cached_len(), 9);
    }

    #[test]
    fn invalidate_clears_state() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::new(4);
        batcher.predict(&mut be, BlockId(0), 0, fv(0.9)).unwrap();
        batcher.prefetch(BlockId(1), 0, fv(0.9));
        batcher.invalidate_all();
        assert_eq!(batcher.cached_len(), 0);
        assert_eq!(batcher.pending_len(), 0);
    }

    #[test]
    fn invalidate_drops_one_block_only() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::new(8);
        batcher.predict(&mut be, BlockId(1), 0, fv(0.9)).unwrap();
        batcher.predict(&mut be, BlockId(2), 0, fv(0.9)).unwrap();
        assert_eq!(batcher.cached_len(), 2);
        batcher.invalidate(BlockId(1));
        assert_eq!(batcher.cached_len(), 1);
        // Block 1 must be re-scored; block 2 still serves from the cache.
        let calls_before = be.calls;
        batcher.predict(&mut be, BlockId(2), 0, fv(0.9)).unwrap();
        assert_eq!(be.calls, calls_before);
        batcher.predict(&mut be, BlockId(1), 0, fv(0.9)).unwrap();
        assert_eq!(be.calls, calls_before + 1);
        // Invalidate also drops any pending query for the block.
        batcher.prefetch(BlockId(7), 0, fv(0.5));
        batcher.invalidate(BlockId(7));
        assert_eq!(batcher.pending_len(), 0);
    }

    #[test]
    fn class_cache_is_bounded() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::with_capacity(8, 16);
        // A long trace over a huge keyspace must not grow the cache
        // without bound (the pre-fix leak).
        for i in 0..400u64 {
            batcher.predict(&mut be, BlockId(i), 0, fv(0.9)).unwrap();
            assert!(batcher.cached_len() <= 16, "leaked at block {i}");
        }
        // Oldest entries were the ones dropped: the newest still serves.
        let calls = be.calls;
        batcher.predict(&mut be, BlockId(399), 0, fv(0.9)).unwrap();
        assert_eq!(be.calls, calls, "newest entry retained");
        batcher.predict(&mut be, BlockId(0), 0, fv(0.9)).unwrap();
        assert_eq!(be.calls, calls + 1, "oldest entry was evicted");
    }

    /// Regression (from the stamped-lazy-deletion era, kept as a guard):
    /// after an invalidate + re-predict of the same block, capacity
    /// eviction must not remove the freshly re-inserted entry (the old
    /// stale-order-id bug panicked predict()'s "flush populated cache"
    /// expect). With the order list, invalidation unlinks eagerly, so no
    /// stale entry can exist at all.
    #[test]
    fn stale_order_entry_cannot_evict_a_reinserted_block() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::with_capacity(8, 4);
        batcher.predict(&mut be, BlockId(0), 0, fv(0.9)).unwrap();
        batcher.invalidate(BlockId(0));
        for i in 1..=4u64 {
            batcher.predict(&mut be, BlockId(i), 0, fv(0.9)).unwrap();
        }
        assert_eq!(batcher.cached_len(), 4);
        // Re-predict block 0: the flush inserts it newest and evicts past
        // the bound — the victim must be the oldest live entry, never the
        // entry the current flush just wrote.
        batcher.predict(&mut be, BlockId(0), 1, fv(0.9)).unwrap();
        assert_eq!(batcher.cached_len(), 4);
        let calls = be.calls;
        batcher.predict(&mut be, BlockId(0), 1, fv(0.9)).unwrap();
        assert_eq!(be.calls, calls, "re-inserted block survived the eviction");
        // FIFO still correct: block 1 (the oldest live entry) was evicted.
        batcher.predict(&mut be, BlockId(1), 0, fv(0.9)).unwrap();
        assert_eq!(be.calls, calls + 1, "oldest live entry was the victim");
    }

    /// Regression: a full class cache, a pending prefetch and a
    /// stamp-refresh of the *oldest* resident block in one flush — the
    /// re-scored block must end up newest, not be evicted by its own
    /// flush (which panicked predict()'s "flush populated cache" expect).
    #[test]
    fn rescoring_the_oldest_resident_survives_a_full_flush() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::with_capacity(8, 4);
        for i in 0..4u64 {
            batcher.predict(&mut be, BlockId(i), 0, fv(0.9)).unwrap();
        }
        assert_eq!(batcher.cached_len(), 4, "cache at capacity, block 0 oldest");
        batcher.prefetch(BlockId(9), 0, fv(0.9));
        // Block 0 with a new stamp: the flush scores the prefetched block
        // 9 (over capacity) and re-scores 0 — 0 is the freshest entry and
        // must survive the eviction.
        assert!(batcher.predict(&mut be, BlockId(0), 1, fv(0.9)).unwrap());
        assert_eq!(batcher.cached_len(), 4);
        let calls = be.calls;
        batcher.predict(&mut be, BlockId(0), 1, fv(0.9)).unwrap();
        assert_eq!(be.calls, calls, "re-scored block stayed cached");
        // Block 1 became the oldest live entry and was the victim.
        batcher.predict(&mut be, BlockId(1), 0, fv(0.9)).unwrap();
        assert_eq!(be.calls, calls + 1);
    }

    #[test]
    fn new_model_version_resets_cached_classes() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::new(8);
        batcher.predict(&mut be, BlockId(1), 0, fv(0.9)).unwrap();
        batcher.note_model_version(1);
        assert_eq!(batcher.cached_len(), 0, "stale classes dropped");
        batcher.predict(&mut be, BlockId(1), 0, fv(0.9)).unwrap();
        assert_eq!(be.calls, 2, "re-scored under the new model");
        // Same version again: no reset.
        batcher.note_model_version(1);
        assert_eq!(batcher.cached_len(), 1);
    }

    #[test]
    fn duplicate_prefetch_is_deduped() {
        let mut batcher = PredictionBatcher::new(4);
        batcher.prefetch(BlockId(1), 0, fv(0.5));
        batcher.prefetch(BlockId(1), 0, fv(0.5));
        assert_eq!(batcher.pending_len(), 1);
    }

    // ------------------------------------------------- bounded shard queue

    /// `queue_depth = 1` is the legacy synchronous batcher: every cold
    /// query flushes inline and the caller always gets `Some`.
    #[test]
    fn depth_one_is_the_legacy_synchronous_path() {
        let mut be = FakeBackend { calls: 0 };
        let mut legacy = PredictionBatcher::new(8);
        let mut bounded = ShardBatcher::new(BatcherConfig::default());
        let mut be2 = FakeBackend { calls: 0 };
        for i in 0..50u64 {
            let block = BlockId(i % 7);
            let stamp = i / 7;
            let f = fv(if i % 2 == 0 { 0.9 } else { 0.1 });
            let a = legacy.predict(&mut be, block, stamp, f).unwrap();
            let b = bounded.predict(&mut be2, block, stamp, f, SimTime(i)).unwrap();
            assert_eq!(Some(a), b, "divergence at query {i}");
        }
        assert_eq!(be.calls, be2.calls, "same backend call count");
        let probe = bounded.probe();
        assert_eq!(probe.deferred(), 0, "depth 1 never defers");
        assert_eq!(probe.flushes(), probe.flushes_by_fill());
        assert_eq!(probe.cold_queries(), probe.flushed_queries());
    }

    #[test]
    fn deep_queue_defers_until_fill() {
        let mut be = FakeBackend { calls: 0 };
        let cfg = BatcherConfig {
            queue_depth: 4,
            deadline: SimDuration::from_secs_f64(3600.0), // never lapses in-test
            ..BatcherConfig::default()
        };
        let mut batcher = ShardBatcher::new(cfg);
        // Three cold queries: deferred, no backend call.
        for i in 0..3u64 {
            let r = batcher.predict(&mut be, BlockId(i), 0, fv(0.9), SimTime(i)).unwrap();
            assert_eq!(r, None, "query {i} must defer");
        }
        assert_eq!(be.calls, 0);
        assert_eq!(batcher.pending_len(), 3);
        // Fourth fills the queue: one flush scores all four.
        let r = batcher.predict(&mut be, BlockId(3), 0, fv(0.9), SimTime(3)).unwrap();
        assert_eq!(r, Some(true));
        assert_eq!(be.calls, 1);
        assert_eq!(batcher.pending_len(), 0);
        // The deferred queries' answers are now in the class cache.
        for i in 0..3u64 {
            let r = batcher.predict(&mut be, BlockId(i), 0, fv(0.9), SimTime(9)).unwrap();
            assert_eq!(r, Some(true));
        }
        assert_eq!(be.calls, 1, "deferred answers served from the cache");
        let probe = batcher.probe();
        assert_eq!(probe.cold_queries(), 4);
        assert_eq!(probe.deferred(), 3);
        assert_eq!(probe.flushes(), 1);
        assert_eq!(probe.flushes_by_fill(), 1);
        assert!((probe.mean_flush_size() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn zero_deadline_flushes_every_cold_query() {
        let mut be = FakeBackend { calls: 0 };
        let cfg = BatcherConfig {
            queue_depth: 64,
            deadline: SimDuration::ZERO,
            ..BatcherConfig::default()
        };
        let mut batcher = ShardBatcher::new(cfg);
        for i in 0..5u64 {
            let r = batcher.predict(&mut be, BlockId(i), 0, fv(0.1), SimTime(i)).unwrap();
            assert_eq!(r, Some(false), "zero deadline never defers");
        }
        assert_eq!(be.calls, 5);
        let probe = batcher.probe();
        assert_eq!(probe.flushes_by_deadline(), 5);
        assert_eq!(probe.flushes_by_fill(), 0);
    }

    #[test]
    fn maybe_flush_sweeps_an_overdue_queue() {
        let mut be = FakeBackend { calls: 0 };
        let cfg = BatcherConfig {
            queue_depth: 64,
            deadline: SimDuration::from_secs_f64(1.0),
            ..BatcherConfig::default()
        };
        let mut batcher = ShardBatcher::new(cfg);
        let t0 = SimTime(0);
        assert_eq!(batcher.predict(&mut be, BlockId(1), 0, fv(0.9), t0).unwrap(), None);
        // Not overdue: sweep is a no-op.
        batcher.maybe_flush(&mut be, SimTime(500_000)).unwrap();
        assert_eq!(be.calls, 0);
        // Overdue at t0 + 1s: the sweep flushes.
        batcher.maybe_flush(&mut be, SimTime(1_000_000)).unwrap();
        assert_eq!(be.calls, 1);
        assert_eq!(batcher.pending_len(), 0);
        assert_eq!(batcher.probe().flushes_by_deadline(), 1);
        // A class-cache hit on an overdue queue also sweeps it.
        assert_eq!(
            batcher.predict(&mut be, BlockId(2), 0, fv(0.9), SimTime(2_000_000)).unwrap(),
            None
        );
        let r = batcher
            .predict(&mut be, BlockId(1), 0, fv(0.9), SimTime(4_000_000))
            .unwrap();
        assert_eq!(r, Some(true), "block 1 still cached");
        assert_eq!(be.calls, 2, "hit-path sweep flushed the overdue block 2");
        // Forced flush on an empty queue is free.
        batcher.flush(&mut be).unwrap();
        assert_eq!(be.calls, 2);
    }

    #[test]
    fn deduped_requery_is_not_double_counted() {
        let mut be = FakeBackend { calls: 0 };
        let cfg = BatcherConfig {
            queue_depth: 8,
            deadline: SimDuration::from_secs_f64(3600.0),
            ..BatcherConfig::default()
        };
        let mut batcher = ShardBatcher::new(cfg);
        assert_eq!(batcher.predict(&mut be, BlockId(1), 0, fv(0.9), SimTime(0)).unwrap(), None);
        // Same (block, stamp) again before any flush: dedupes against the
        // pending entry — neither cold nor deferred may double-count.
        assert_eq!(batcher.predict(&mut be, BlockId(1), 0, fv(0.9), SimTime(1)).unwrap(), None);
        let probe = batcher.probe();
        assert_eq!(probe.cold_queries(), 1, "deduped re-query is not a new cold entry");
        assert_eq!(probe.deferred(), 1);
        assert_eq!(batcher.pending_len(), 1);
        batcher.flush(&mut be).unwrap();
        assert_eq!(probe.flushed_queries(), 1);
        assert_eq!(probe.cold_queries(), probe.flushed_queries() + probe.dropped());
    }

    #[test]
    fn invalidation_drops_pending_and_counts() {
        let mut be = FakeBackend { calls: 0 };
        let cfg = BatcherConfig {
            queue_depth: 8,
            deadline: SimDuration::from_secs_f64(3600.0),
            ..BatcherConfig::default()
        };
        let mut batcher = ShardBatcher::new(cfg);
        assert_eq!(batcher.predict(&mut be, BlockId(1), 0, fv(0.9), SimTime(0)).unwrap(), None);
        assert_eq!(batcher.predict(&mut be, BlockId(2), 0, fv(0.9), SimTime(1)).unwrap(), None);
        batcher.invalidate(BlockId(1));
        assert_eq!(batcher.pending_len(), 1);
        assert_eq!(batcher.probe().dropped(), 1);
        batcher.invalidate_all();
        assert_eq!(batcher.pending_len(), 0);
        assert_eq!(batcher.probe().dropped(), 2);
    }

    /// A multi-chunk flush that fails part-way: the chunks that were
    /// scored count as flushed (and stay served from the class cache);
    /// only the lost remainder counts as dropped.
    #[test]
    fn partial_flush_accounts_scored_and_dropped() {
        struct FlakyBackend {
            calls: u64,
        }
        impl SvmBackend for FlakyBackend {
            fn name(&self) -> &'static str {
                "flaky"
            }
            fn train(&mut self, _ds: &crate::svm::Dataset) -> Result<()> {
                Ok(())
            }
            fn decision_batch(&mut self, q: &[FeatureVec]) -> Result<Vec<f32>> {
                self.calls += 1;
                if self.calls > 1 {
                    anyhow::bail!("transient backend failure");
                }
                Ok(q.iter().map(|f| f[0] - 0.5).collect())
            }
            fn is_trained(&self) -> bool {
                true
            }
        }
        let mut be = FlakyBackend { calls: 0 };
        let cfg = BatcherConfig {
            batch_width: 4,
            queue_depth: 6,
            deadline: SimDuration::from_secs_f64(3600.0),
            ..BatcherConfig::default()
        };
        let mut batcher = ShardBatcher::new(cfg);
        for i in 0..5u64 {
            let r = batcher.predict(&mut be, BlockId(i), 0, fv(0.9), SimTime(i)).unwrap();
            assert_eq!(r, None, "query {i} defers below the fill bound");
        }
        // Sixth fills the queue: chunk 1 (blocks 0..4) scores, chunk 2
        // (blocks 4..6) hits the transient failure.
        let r = batcher.predict(&mut be, BlockId(5), 0, fv(0.9), SimTime(5));
        assert!(r.is_err(), "failing chunk propagates");
        let probe = batcher.probe();
        assert_eq!(probe.cold_queries(), 6);
        assert_eq!(probe.flushed_queries(), 4, "first chunk was scored");
        assert_eq!(probe.dropped(), 2, "only the failed chunk is dropped");
        assert_eq!(probe.flushes(), 1);
        assert_eq!(
            probe.cold_queries(),
            probe.flushed_queries() + probe.dropped(),
            "conservation holds through the partial failure"
        );
        // The scored chunk still serves from the class cache.
        let r = batcher.predict(&mut be, BlockId(2), 0, fv(0.9), SimTime(9)).unwrap();
        assert_eq!(r, Some(true));
        assert_eq!(be.calls, 2, "cache hit needs no backend");
    }

    #[test]
    fn failed_flush_counts_dropped_queries() {
        let mut be = BrokenBackend;
        let mut batcher = ShardBatcher::new(BatcherConfig::default());
        let r = batcher.predict(&mut be, BlockId(1), 0, fv(0.9), SimTime(0));
        assert!(r.is_err());
        assert_eq!(batcher.probe().dropped(), 1);
        assert_eq!(batcher.pending_len(), 0, "failed flush consumed the queue");
    }

    #[test]
    fn pool_routes_by_shard_and_merges_stats() {
        let mut be = FakeBackend { calls: 0 };
        let mut pool = BatcherPool::new(4, BatcherConfig::default());
        assert_eq!(pool.n_shards(), 4);
        for i in 0..32u64 {
            let r = pool.predict(&mut be, BlockId(i), 0, fv(0.9), SimTime(i)).unwrap();
            assert_eq!(r, Some(true));
        }
        assert_eq!(pool.cached_len(), 32);
        let stats = pool.stats();
        assert_eq!(stats.queries, 32);
        assert_eq!(stats.predictions_scored, 32);
        assert_eq!(pool.probe().cold_queries(), 32);
        // Same stamp again: every answer comes from a per-shard cache.
        let calls = be.calls;
        for i in 0..32u64 {
            let r = pool.predict(&mut be, BlockId(i), 0, fv(0.9), SimTime(40 + i)).unwrap();
            assert_eq!(r, Some(true));
        }
        assert_eq!(be.calls, calls);
        assert_eq!(pool.stats().class_cache_hits, 32);
    }

    /// The obs hook records flush sizes + simulated queue waits into the
    /// registry and mirrors the probe counters as `batcher.*` gauges,
    /// without disturbing the probe's own accounting.
    #[test]
    fn obs_hook_records_flushes_and_mirrors_probe_gauges() {
        let mut be = FakeBackend { calls: 0 };
        let registry = MetricsRegistry::new();
        let cfg = BatcherConfig {
            queue_depth: 3,
            deadline: SimDuration::from_secs_f64(3600.0),
            ..BatcherConfig::default()
        };
        let mut batcher = ShardBatcher::new(cfg);
        batcher.set_obs(BatcherObs::register(&registry, 1, 0));
        batcher.probe().register_gauges(&registry, "batcher");
        for i in 0..3u64 {
            batcher.predict(&mut be, BlockId(i), 0, fv(0.9), SimTime(10 * i)).unwrap();
        }
        assert_eq!(be.calls, 1, "third query fills the queue");
        let snaps = registry.hist_snapshots();
        let hist = |name: &str| {
            snaps.iter().find(|(n, _, _)| n == name).unwrap_or_else(|| panic!("{name}"))
        };
        let flush = hist("batcher.flush_size");
        assert_eq!(flush.2.count, 1);
        assert_eq!(flush.2.sum, 3, "one flush scored three queries");
        assert_eq!(flush.1, MetricClass::Deterministic);
        let wait = hist("batcher.queue_wait_us");
        assert_eq!(wait.2.count, 1);
        assert_eq!(wait.2.sum, 20, "oldest entry waited 20 simulated us");
        assert_eq!(hist("batcher.flush_wall_ns").1, MetricClass::Volatile);
        let gauges = registry.gauge_values();
        let gauge = |name: &str| {
            gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap()
        };
        let probe = batcher.probe();
        assert_eq!(gauge("batcher.cold_queries"), probe.cold_queries());
        assert_eq!(gauge("batcher.deferred"), probe.deferred());
        assert_eq!(gauge("batcher.flushes"), probe.flushes());
        assert_eq!(gauge("batcher.flushed_queries"), probe.flushed_queries());
        assert_eq!(gauge("batcher.dropped"), probe.dropped());
    }

    #[test]
    fn pool_attach_obs_covers_every_shard() {
        let mut be = FakeBackend { calls: 0 };
        let registry = MetricsRegistry::new();
        let mut pool = BatcherPool::new(2, BatcherConfig::default());
        pool.attach_obs(&registry);
        for i in 0..8u64 {
            pool.predict(&mut be, BlockId(i), 0, fv(0.9), SimTime(i)).unwrap();
        }
        let snaps = registry.hist_snapshots();
        let flush = snaps.iter().find(|(n, _, _)| n == "batcher.flush_size").unwrap();
        assert_eq!(flush.2.sum, 8, "every cold query shows in the merged histogram");
        assert_eq!(
            registry.gauge_values().iter().filter(|(n, _)| n.starts_with("batcher.")).count(),
            7
        );
    }

    #[test]
    fn pool_invalidation_routes_and_broadcasts() {
        let mut be = FakeBackend { calls: 0 };
        let mut pool = BatcherPool::new(2, BatcherConfig::default());
        for i in 0..8u64 {
            pool.predict(&mut be, BlockId(i), 0, fv(0.9), SimTime(i)).unwrap();
        }
        pool.invalidate(BlockId(3));
        assert_eq!(pool.cached_len(), 7, "one block invalidated on its shard");
        // A published model version reaches every shard batcher.
        pool.note_model_version(1);
        assert_eq!(pool.cached_len(), 0, "broadcast dropped every cached class");
        pool.predict(&mut be, BlockId(1), 0, fv(0.9), SimTime(9)).unwrap();
        pool.note_model_version(1);
        assert_eq!(pool.cached_len(), 1, "unchanged version is a no-op");
        pool.invalidate_all();
        assert_eq!(pool.cached_len(), 0);
        assert_eq!(pool.pending_len(), 0);
    }

    // ------------------------------------------------- circuit breaker

    /// A backend whose failure mode can be flipped mid-test (an outage
    /// that starts and ends on demand).
    struct SwitchBackend {
        failing: bool,
        calls: u64,
    }

    impl SvmBackend for SwitchBackend {
        fn name(&self) -> &'static str {
            "switch"
        }
        fn train(&mut self, _ds: &crate::svm::Dataset) -> Result<()> {
            Ok(())
        }
        fn decision_batch(&mut self, q: &[FeatureVec]) -> Result<Vec<f32>> {
            self.calls += 1;
            if self.failing {
                anyhow::bail!("simulated outage");
            }
            Ok(q.iter().map(|f| f[0] - 0.5).collect())
        }
        fn is_trained(&self) -> bool {
            true
        }
    }

    fn breaker_cfg(threshold: u32, retries: u32, probe_after_us: u64) -> BatcherConfig {
        BatcherConfig {
            breaker: BreakerConfig {
                enabled: true,
                failure_threshold: threshold,
                max_retries: retries,
                probe_after: SimDuration::from_micros(probe_after_us),
                ..BreakerConfig::default()
            },
            ..BatcherConfig::default()
        }
    }

    #[test]
    fn breaker_disabled_reports_none_and_keeps_error_semantics() {
        let mut batcher = ShardBatcher::new(BatcherConfig::default());
        assert_eq!(batcher.breaker_state(), None);
        // Pre-breaker semantics: a failing flush surfaces the Err.
        let mut be = BrokenBackend;
        assert!(batcher.predict(&mut be, BlockId(1), 0, fv(0.9), SimTime(0)).is_err());
    }

    #[test]
    fn breaker_lifecycle_open_fallback_probe_close() {
        let mut be = SwitchBackend { failing: true, calls: 0 };
        let mut batcher = ShardBatcher::new(breaker_cfg(2, 0, 1_000));
        // With the breaker active a failed flush degrades to `Ok(None)`
        // (unclassified fallback) instead of an error.
        assert_eq!(batcher.predict(&mut be, BlockId(1), 0, fv(0.9), SimTime(0)).unwrap(), None);
        assert_eq!(batcher.breaker_state(), Some(BreakerState::Closed));
        assert_eq!(batcher.predict(&mut be, BlockId(2), 0, fv(0.9), SimTime(10)).unwrap(), None);
        assert_eq!(batcher.breaker_state(), Some(BreakerState::Open));
        assert_eq!(batcher.probe().breaker_opens(), 1);
        // Open: callers fall back without any backend traffic.
        let calls = be.calls;
        assert_eq!(batcher.predict(&mut be, BlockId(3), 0, fv(0.9), SimTime(20)).unwrap(), None);
        assert_eq!(be.calls, calls, "open breaker never touches the backend");
        assert_eq!(batcher.probe().breaker_fallbacks(), 1);
        // Probe cadence lapses and the backend recovered: the HalfOpen
        // probe flushes inline, succeeds, and closes the breaker.
        be.failing = false;
        let r = batcher.predict(&mut be, BlockId(4), 0, fv(0.9), SimTime(1_010)).unwrap();
        assert_eq!(r, Some(true), "probe query is answered inline");
        assert_eq!(batcher.breaker_state(), Some(BreakerState::Closed));
        assert_eq!(batcher.probe().breaker_closes(), 1);
    }

    #[test]
    fn half_open_probe_failure_reopens() {
        let mut be = SwitchBackend { failing: true, calls: 0 };
        let mut batcher = ShardBatcher::new(breaker_cfg(1, 0, 1_000));
        assert_eq!(batcher.predict(&mut be, BlockId(1), 0, fv(0.9), SimTime(0)).unwrap(), None);
        assert_eq!(batcher.breaker_state(), Some(BreakerState::Open));
        // Probe at t=1_000 fails → immediate re-open, fresh probe window.
        assert_eq!(batcher.predict(&mut be, BlockId(2), 0, fv(0.9), SimTime(1_000)).unwrap(), None);
        assert_eq!(batcher.breaker_state(), Some(BreakerState::Open));
        assert_eq!(batcher.probe().breaker_opens(), 2);
        // Still inside the new probe window: pure fallback.
        let calls = be.calls;
        assert_eq!(batcher.predict(&mut be, BlockId(3), 0, fv(0.9), SimTime(1_500)).unwrap(), None);
        assert_eq!(be.calls, calls);
    }

    #[test]
    fn retry_budget_recovers_transient_failure() {
        /// Fails exactly its first call, then stays healthy.
        struct FlakyOnce {
            calls: u64,
        }
        impl SvmBackend for FlakyOnce {
            fn name(&self) -> &'static str {
                "flaky-once"
            }
            fn train(&mut self, _ds: &crate::svm::Dataset) -> Result<()> {
                Ok(())
            }
            fn decision_batch(&mut self, q: &[FeatureVec]) -> Result<Vec<f32>> {
                self.calls += 1;
                if self.calls == 1 {
                    anyhow::bail!("transient");
                }
                Ok(q.iter().map(|f| f[0] - 0.5).collect())
            }
            fn is_trained(&self) -> bool {
                true
            }
        }
        let mut be = FlakyOnce { calls: 0 };
        let mut batcher = ShardBatcher::new(breaker_cfg(3, 2, 1_000));
        let r = batcher.predict(&mut be, BlockId(1), 0, fv(0.9), SimTime(0)).unwrap();
        assert_eq!(r, Some(true), "one bounded retry absorbs the transient");
        let probe = batcher.probe();
        assert_eq!(probe.retries(), 1);
        assert_eq!(probe.retry_backoff_us(), 500, "default 500us backoff per retry");
        assert_eq!(probe.breaker_opens(), 0);
        assert_eq!(batcher.breaker_state(), Some(BreakerState::Closed));
        assert_eq!(probe.dropped(), 0, "retried flush loses nothing");
    }

    #[test]
    fn open_breaker_end_of_run_flush_drops_pending() {
        let mut be = SwitchBackend { failing: true, calls: 0 };
        let mut batcher = ShardBatcher::new(breaker_cfg(1, 0, 1_000_000));
        assert_eq!(batcher.predict(&mut be, BlockId(1), 0, fv(0.9), SimTime(0)).unwrap(), None);
        assert_eq!(batcher.breaker_state(), Some(BreakerState::Open));
        let dropped_before = batcher.probe().dropped();
        // Prefetch bypasses the breaker gate (no answer needed), so the
        // queue can hold entries when the run ends with the breaker open.
        batcher.prefetch(BlockId(2), 0, fv(0.9), SimTime(5));
        batcher.prefetch(BlockId(3), 0, fv(0.9), SimTime(6));
        assert_eq!(batcher.pending_len(), 2);
        batcher.flush(&mut be).unwrap();
        assert_eq!(batcher.pending_len(), 0, "stranded queue is cleared");
        assert_eq!(
            batcher.probe().dropped(),
            dropped_before + 2,
            "stranded entries are accounted as dropped"
        );
        let calls = be.calls;
        batcher.flush(&mut be).unwrap();
        assert_eq!(be.calls, calls, "open breaker blocks the backend even at end of run");
    }

    #[test]
    fn breaker_gauges_mirror_probe_accessors() {
        let registry = MetricsRegistry::new();
        let mut be = SwitchBackend { failing: true, calls: 0 };
        let mut batcher = ShardBatcher::new(breaker_cfg(1, 1, 1_000));
        batcher.probe().register_breaker_gauges(&registry, "breaker");
        assert_eq!(batcher.predict(&mut be, BlockId(1), 0, fv(0.9), SimTime(0)).unwrap(), None);
        assert_eq!(batcher.predict(&mut be, BlockId(2), 0, fv(0.9), SimTime(10)).unwrap(), None);
        let gauges = registry.gauge_values();
        let gauge = |name: &str| {
            gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap()
        };
        let probe = batcher.probe();
        assert_eq!(gauge("breaker.breaker_opens"), probe.breaker_opens());
        assert_eq!(gauge("breaker.breaker_fallbacks"), probe.breaker_fallbacks());
        assert_eq!(gauge("breaker.retries"), probe.retries());
        assert_eq!(gauge("breaker.retry_backoff_us"), probe.retry_backoff_us());
        assert!(probe.retries() >= 1, "the failing flush spent its retry budget");
    }
}
