//! Prediction micro-batching + class caching.
//!
//! Algorithm 1 consults the SVM on *every* cache decision. Calling the
//! PJRT executable per block would put an artifact invocation on each
//! request; instead the coordinator:
//!
//! 1. caches the predicted class per block, invalidating when the block's
//!    feature state drifts (its access count changes — frequency and
//!    recency are the live features), and
//! 2. batches cold predictions: queries accumulate into the artifact's
//!    native batch width before one `decision_batch` call scores them all
//!    (the vLLM-router-style amortization; see DESIGN.md §8).

use crate::util::fasthash::IdHashMap;

use anyhow::Result;

use crate::hdfs::BlockId;
use crate::runtime::SvmBackend;
use crate::svm::features::FeatureVec;

/// Cached prediction: class + the access-count stamp it was computed at.
#[derive(Debug, Clone, Copy)]
struct CachedClass {
    reused: bool,
    stamp: u64,
}

/// Batching predictor with a per-block class cache.
pub struct PredictionBatcher {
    cache: IdHashMap<BlockId, CachedClass>,
    /// Pending cold queries (block, stamp, features).
    pending: Vec<(BlockId, u64, FeatureVec)>,
    /// Flush threshold = artifact batch width.
    batch_width: usize,
    pub stats: BatcherStats,
}

/// Telemetry for the perf pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct BatcherStats {
    pub queries: u64,
    pub class_cache_hits: u64,
    pub backend_calls: u64,
    pub predictions_scored: u64,
}

impl PredictionBatcher {
    pub fn new(batch_width: usize) -> Self {
        PredictionBatcher {
            cache: IdHashMap::default(),
            pending: Vec::new(),
            batch_width: batch_width.max(1),
            stats: BatcherStats::default(),
        }
    }

    /// Predict the class of one block, given its current feature vector and
    /// an access-count stamp. Uses the class cache when the stamp matches;
    /// otherwise queues the query and flushes a full batch through the
    /// backend synchronously (the caller needs the answer now — pending
    /// entries ride along in the same call).
    pub fn predict(
        &mut self,
        backend: &mut dyn SvmBackend,
        block: BlockId,
        stamp: u64,
        features: FeatureVec,
    ) -> Result<bool> {
        self.stats.queries += 1;
        if let Some(c) = self.cache.get(&block) {
            if c.stamp == stamp {
                self.stats.class_cache_hits += 1;
                return Ok(c.reused);
            }
        }
        self.pending.push((block, stamp, features));
        self.flush(backend)?;
        Ok(self.cache.get(&block).expect("flush populated cache").reused)
    }

    /// Score everything pending in batch_width chunks.
    pub fn flush(&mut self, backend: &mut dyn SvmBackend) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        let pending = std::mem::take(&mut self.pending);
        for chunk in pending.chunks(self.batch_width) {
            let queries: Vec<FeatureVec> = chunk.iter().map(|(_, _, f)| *f).collect();
            let scores = backend.decision_batch(&queries)?;
            self.stats.backend_calls += 1;
            self.stats.predictions_scored += scores.len() as u64;
            for ((block, stamp, _), score) in chunk.iter().zip(scores) {
                self.cache
                    .insert(*block, CachedClass { reused: score > 0.0, stamp: *stamp });
            }
        }
        Ok(())
    }

    /// Queue a prediction without needing the answer immediately (prefetch
    /// for blocks we expect to decide on soon).
    pub fn prefetch(&mut self, block: BlockId, stamp: u64, features: FeatureVec) {
        let fresh = self
            .cache
            .get(&block)
            .map(|c| c.stamp == stamp)
            .unwrap_or(false);
        if !fresh && !self.pending.iter().any(|(b, s, _)| *b == block && *s == stamp) {
            self.pending.push((block, stamp, features));
        }
    }

    /// Invalidate all cached classes (after retraining).
    pub fn invalidate_all(&mut self) {
        self.cache.clear();
        self.pending.clear();
    }

    pub fn cached_len(&self) -> usize {
        self.cache.len()
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::features::N_FEATURES;

    /// A backend that classifies by feature[0] > 0.5 and counts calls.
    struct FakeBackend {
        calls: u64,
    }

    impl SvmBackend for FakeBackend {
        fn name(&self) -> &'static str {
            "fake"
        }
        fn train(&mut self, _ds: &crate::svm::Dataset) -> Result<()> {
            Ok(())
        }
        fn decision_batch(&mut self, q: &[FeatureVec]) -> Result<Vec<f32>> {
            self.calls += 1;
            Ok(q.iter().map(|f| f[0] - 0.5).collect())
        }
        fn is_trained(&self) -> bool {
            true
        }
    }

    fn fv(v: f32) -> FeatureVec {
        let mut f = [0.0f32; N_FEATURES];
        f[0] = v;
        f
    }

    #[test]
    fn class_cache_avoids_backend_calls() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::new(8);
        let b = BlockId(1);
        assert!(batcher.predict(&mut be, b, 0, fv(0.9)).unwrap());
        assert_eq!(be.calls, 1);
        // Same stamp: served from the class cache.
        for _ in 0..10 {
            assert!(batcher.predict(&mut be, b, 0, fv(0.9)).unwrap());
        }
        assert_eq!(be.calls, 1);
        assert_eq!(batcher.stats.class_cache_hits, 10);
        // New stamp: re-scored.
        assert!(!batcher.predict(&mut be, b, 1, fv(0.1)).unwrap());
        assert_eq!(be.calls, 2);
    }

    #[test]
    fn prefetch_batches_ride_along() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::new(8);
        for i in 0..5 {
            batcher.prefetch(BlockId(i), 0, fv(0.8));
        }
        assert_eq!(batcher.pending_len(), 5);
        // One predict triggers a single backend call scoring all 6.
        assert!(batcher.predict(&mut be, BlockId(9), 0, fv(0.7)).unwrap());
        assert_eq!(be.calls, 1);
        assert_eq!(batcher.stats.predictions_scored, 6);
        // The prefetched classes are now cached.
        assert!(batcher.predict(&mut be, BlockId(3), 0, fv(0.8)).unwrap());
        assert_eq!(be.calls, 1);
    }

    #[test]
    fn oversized_pending_splits_into_chunks() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::new(4);
        for i in 0..9 {
            batcher.prefetch(BlockId(i), 0, fv(0.6));
        }
        batcher.flush(&mut be).unwrap();
        assert_eq!(be.calls, 3, "9 queries / width 4 = 3 calls");
        assert_eq!(batcher.cached_len(), 9);
    }

    #[test]
    fn invalidate_clears_state() {
        let mut be = FakeBackend { calls: 0 };
        let mut batcher = PredictionBatcher::new(4);
        batcher.predict(&mut be, BlockId(0), 0, fv(0.9)).unwrap();
        batcher.prefetch(BlockId(1), 0, fv(0.9));
        batcher.invalidate_all();
        assert_eq!(batcher.cached_len(), 0);
        assert_eq!(batcher.pending_len(), 0);
    }

    #[test]
    fn duplicate_prefetch_is_deduped() {
        let mut batcher = PredictionBatcher::new(4);
        batcher.prefetch(BlockId(1), 0, fv(0.5));
        batcher.prefetch(BlockId(1), 0, fv(0.5));
        assert_eq!(batcher.pending_len(), 1);
    }
}
