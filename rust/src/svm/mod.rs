//! The SVM layer on the Rust side: feature extraction (Table 2/3), label
//! generation (Table 4), dataset handling, kernels, the pure-Rust SMO
//! reference trainer, and the evaluation metrics behind Table 5.
//!
//! The production classifier path runs through `crate::runtime` (AOT HLO
//! artifacts via PJRT); this module provides the shared types plus the
//! `rust` fallback backend.

pub mod dataset;
pub mod eval;
pub mod features;
pub mod kernel;
pub mod labeling;
pub mod smo;

pub use dataset::{pad, Dataset, PaddedDataset};
pub use eval::{cross_validate, evaluate, ConfusionMatrix};
pub use features::{BlockStatsTracker, FeatureVec, N_FEATURES};
pub use kernel::{KernelKind, KernelParams};
pub use labeling::{label, label_record, Labels};
pub use smo::{train as smo_train, SmoConfig, SmoModel};
