//! Pure-Rust SVM trained with simplified SMO (Platt's sequential minimal
//! optimization, simplified working-set selection).
//!
//! This is the reference/fallback classifier: it cross-validates the HLO
//! artifacts' numerics in integration tests and serves as the
//! `--svm-backend rust` implementation so every experiment runs even
//! without `make artifacts`.
//!
//! Inference hot path: [`SmoModel::new`] precomputes a fast path so
//! [`SmoModel::decision`] never walks `Vec<Vec<f32>>` rows. Linear kernels
//! collapse the whole dual sum into one weight vector (`w = Σ αᵢ yᵢ xᵢ`) —
//! a single dot product regardless of support-vector count. RBF/sigmoid
//! keep the kernel loop but run it over an SoA layout: the active
//! (`α ≠ 0`) support vectors packed support-vector-major into one
//! contiguous `Vec<f32>` with their `αᵢ·yᵢ` coefficients alongside, so the
//! batch path streams cache lines instead of chasing per-row heap
//! pointers (bit-identical scores to the row walk; `benches/
//! bench_hotpath.rs` records both paths).

use crate::util::rng::Pcg64;

use super::dataset::Dataset;
use super::kernel::{KernelKind, KernelParams};

/// Trained SVM model (dual form).
///
/// Construct through [`SmoModel::new`] — it derives the precomputed
/// inference fast path from the dual state. The public fields are read-only
/// by convention; mutating them after construction would desynchronize the
/// fast path.
#[derive(Debug, Clone)]
pub struct SmoModel {
    pub params: KernelParams,
    pub support_x: Vec<Vec<f32>>,
    pub support_y: Vec<f32>,
    pub alpha: Vec<f32>,
    pub bias: f32,
    fast: FastPath,
}

/// Precomputed inference state (derived from the dual form by
/// [`SmoModel::new`]).
#[derive(Debug, Clone, Default)]
struct FastPath {
    /// Linear kernel only: `w = Σ αᵢ yᵢ xᵢ` — decision is `w·x + b`.
    linear_w: Option<Vec<f32>>,
    /// Active (`α ≠ 0`) support vectors, support-vector-major contiguous
    /// (`coef.len() × dim`).
    sv_flat: Vec<f32>,
    /// `αᵢ·yᵢ` per active support vector, aligned with `sv_flat` rows.
    coef: Vec<f32>,
    /// Feature dimension of the support vectors.
    dim: usize,
}

impl FastPath {
    fn build(
        params: &KernelParams,
        support_x: &[Vec<f32>],
        support_y: &[f32],
        alpha: &[f32],
    ) -> Self {
        let dim = support_x.first().map(Vec::len).unwrap_or(0);
        let mut sv_flat = Vec::new();
        let mut coef = Vec::new();
        for ((sx, sy), a) in support_x.iter().zip(support_y).zip(alpha) {
            debug_assert_eq!(sx.len(), dim, "ragged support vectors");
            if *a != 0.0 {
                // `a * sy` first, matching the old `a * sy * k` product
                // order bit for bit.
                coef.push(a * sy);
                sv_flat.extend_from_slice(sx);
            }
        }
        if params.kind == KernelKind::Linear && dim > 0 {
            // Fold the slab into the weight vector and drop it: the linear
            // decision never reads the per-SV layout, so keeping it would
            // just triple every model clone (snapshot publishes).
            let mut w = vec![0.0f32; dim];
            for (c, sv) in coef.iter().zip(sv_flat.chunks_exact(dim)) {
                for (wk, xk) in w.iter_mut().zip(sv) {
                    *wk += c * xk;
                }
            }
            return FastPath { linear_w: Some(w), sv_flat: Vec::new(), coef: Vec::new(), dim };
        }
        FastPath { linear_w: None, sv_flat, coef, dim }
    }
}

impl SmoModel {
    /// Build a model from dual state, precomputing the inference fast path.
    pub fn new(
        params: KernelParams,
        support_x: Vec<Vec<f32>>,
        support_y: Vec<f32>,
        alpha: Vec<f32>,
        bias: f32,
    ) -> Self {
        let fast = FastPath::build(&params, &support_x, &support_y, &alpha);
        SmoModel { params, support_x, support_y, alpha, bias, fast }
    }

    /// Decision score; class "reused" iff score > 0.
    ///
    /// Linear kernels: one dot product against the precomputed weight
    /// vector — O(d), independent of the support-vector count. Other
    /// kernels: one pass over the contiguous active-SV slab.
    pub fn decision(&self, x: &[f32]) -> f32 {
        if let Some(w) = &self.fast.linear_w {
            let mut s = self.bias;
            for (wk, xk) in w.iter().zip(x) {
                s += wk * xk;
            }
            return s;
        }
        let mut s = self.bias;
        if self.fast.coef.is_empty() {
            return s;
        }
        let svs = self.fast.sv_flat.chunks_exact(self.fast.dim);
        for (c, sv) in self.fast.coef.iter().zip(svs) {
            s += c * self.params.eval(sv, x);
        }
        s
    }

    pub fn predict(&self, x: &[f32]) -> bool {
        self.decision(x) > 0.0
    }

    pub fn n_support(&self) -> usize {
        self.alpha.iter().filter(|&&a| a > 1e-7).count()
    }
}

/// SMO hyper-parameters.
#[derive(Debug, Clone)]
pub struct SmoConfig {
    pub c: f32,
    pub tol: f32,
    pub max_passes: usize,
    pub max_iters: usize,
    pub seed: u64,
}

impl Default for SmoConfig {
    fn default() -> Self {
        SmoConfig { c: 4.0, tol: 1e-3, max_passes: 8, max_iters: 20_000, seed: 7 }
    }
}

/// Train with simplified SMO.
///
/// The KKT-violation scan keeps an *error cache*: `err[k] = f(k) - y[k]`
/// for every training point, updated incrementally (in f64, to bound
/// drift) whenever an (αᵢ, αⱼ, b) step lands. The original implementation
/// re-summed the full dual expansion — O(n) — for every candidate `i` and
/// every random partner `j`, which made each outer pass O(n²) even when
/// nothing changed; with the cache a candidate costs O(1) and only a
/// successful step pays one O(n) sweep.
pub fn train(ds: &Dataset, params: KernelParams, cfg: &SmoConfig) -> SmoModel {
    let n = ds.len();
    assert!(n > 0, "empty training set");
    let x: Vec<Vec<f32>> = ds.x.iter().map(|v| v.to_vec()).collect();
    let y = ds.y.clone();
    // Precompute the Gram matrix (n <= a few hundred on our path).
    let mut k = vec![0.0f32; n * n];
    for i in 0..n {
        for j in i..n {
            let v = params.eval(&x[i], &x[j]);
            k[i * n + j] = v;
            k[j * n + i] = v;
        }
    }
    let mut alpha = vec![0.0f32; n];
    let mut b = 0.0f32;
    let mut rng = Pcg64::new(cfg.seed, 0x5A0);
    // α = 0 and b = 0 ⇒ f(k) = 0 ⇒ err[k] = -y[k].
    let mut err: Vec<f64> = y.iter().map(|&yi| -f64::from(yi)).collect();

    let mut passes = 0usize;
    let mut iters = 0usize;
    while passes < cfg.max_passes && iters < cfg.max_iters {
        let mut changed = 0usize;
        for i in 0..n {
            iters += 1;
            let ei = err[i] as f32;
            let violates = (y[i] * ei < -cfg.tol && alpha[i] < cfg.c)
                || (y[i] * ei > cfg.tol && alpha[i] > 0.0);
            if !violates {
                continue;
            }
            // Pick j != i at random (simplified SMO heuristic).
            let mut j = rng.gen_range(n as u64 - 1) as usize;
            if j >= i {
                j += 1;
            }
            let ej = err[j] as f32;
            let (ai_old, aj_old) = (alpha[i], alpha[j]);
            let (lo, hi) = if (y[i] - y[j]).abs() > 1e-6 {
                (
                    (aj_old - ai_old).max(0.0),
                    (cfg.c + aj_old - ai_old).min(cfg.c),
                )
            } else {
                (
                    (ai_old + aj_old - cfg.c).max(0.0),
                    (ai_old + aj_old).min(cfg.c),
                )
            };
            if hi - lo < 1e-9 {
                // Empty or degenerate box (float error can make hi < lo).
                continue;
            }
            let eta = 2.0 * k[i * n + j] - k[i * n + i] - k[j * n + j];
            if eta >= 0.0 {
                continue; // non-PSD direction (possible for sigmoid); skip
            }
            let mut aj = aj_old - y[j] * (ei - ej) / eta;
            aj = aj.clamp(lo, hi);
            if (aj - aj_old).abs() < 1e-6 {
                continue;
            }
            let ai = ai_old + y[i] * y[j] * (aj_old - aj);
            alpha[i] = ai;
            alpha[j] = aj;
            // Bias update (Platt's rules).
            let b1 = b - ei
                - y[i] * (ai - ai_old) * k[i * n + i]
                - y[j] * (aj - aj_old) * k[i * n + j];
            let b2 = b - ej
                - y[i] * (ai - ai_old) * k[i * n + j]
                - y[j] * (aj - aj_old) * k[j * n + j];
            let b_new = if ai > 0.0 && ai < cfg.c {
                b1
            } else if aj > 0.0 && aj < cfg.c {
                b2
            } else {
                0.5 * (b1 + b2)
            };
            // Incremental error-cache sweep: Δf(t) = Δαᵢyᵢ·K[i,t] +
            // Δαⱼyⱼ·K[j,t] + Δb for every t — the only O(n) work per
            // successful step.
            let dai = f64::from((ai - ai_old) * y[i]);
            let daj = f64::from((aj - aj_old) * y[j]);
            let db = f64::from(b_new - b);
            let (ki, kj) = (&k[i * n..i * n + n], &k[j * n..j * n + n]);
            for ((e, kit), kjt) in err.iter_mut().zip(ki).zip(kj) {
                *e += dai * f64::from(*kit) + daj * f64::from(*kjt) + db;
            }
            b = b_new;
            changed += 1;
        }
        if changed == 0 {
            passes += 1;
        } else {
            passes = 0;
        }
    }

    SmoModel::new(params, x, y, alpha, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::features::N_FEATURES;
    use crate::svm::kernel::KernelKind;

    fn blobs(n_per: usize, seed: u64) -> Dataset {
        let mut rng = Pcg64::new(seed, 0);
        let mut ds = Dataset::new();
        for _ in 0..n_per {
            let mut a = [0.0f32; N_FEATURES];
            let mut b = [0.0f32; N_FEATURES];
            for k in 0..N_FEATURES {
                a[k] = rng.gen_normal(0.25, 0.08) as f32;
                b[k] = rng.gen_normal(0.75, 0.08) as f32;
            }
            ds.push(a, true);
            ds.push(b, false);
        }
        ds
    }

    #[test]
    fn separable_blobs_rbf() {
        let ds = blobs(40, 1);
        let model = train(&ds, KernelParams::new(KernelKind::Rbf), &SmoConfig::default());
        let acc = ds
            .x
            .iter()
            .zip(&ds.y)
            .filter(|(x, &y)| model.predict(x.as_slice()) == (y > 0.0))
            .count() as f64
            / ds.len() as f64;
        assert!(acc >= 0.99, "acc={acc}");
        assert!(model.n_support() > 0);
    }

    #[test]
    fn separable_blobs_linear() {
        let ds = blobs(40, 2);
        let model = train(&ds, KernelParams::new(KernelKind::Linear), &SmoConfig::default());
        let acc = ds
            .x
            .iter()
            .zip(&ds.y)
            .filter(|(x, &y)| model.predict(x.as_slice()) == (y > 0.0))
            .count() as f64
            / ds.len() as f64;
        assert!(acc >= 0.95, "acc={acc}");
    }

    #[test]
    fn dual_feasibility() {
        let ds = blobs(30, 3);
        let cfg = SmoConfig::default();
        let model = train(&ds, KernelParams::new(KernelKind::Rbf), &cfg);
        for &a in &model.alpha {
            assert!((-1e-6..=cfg.c + 1e-6).contains(&a), "alpha {a} out of box");
        }
        // KKT complementary slackness (loosely): sum alpha_i y_i ~ 0
        let s: f32 = model
            .alpha
            .iter()
            .zip(&model.support_y)
            .map(|(a, y)| a * y)
            .sum();
        assert!(s.abs() < 1.0, "sum alpha*y = {s}");
    }

    #[test]
    fn single_class_degenerates_gracefully() {
        let mut ds = Dataset::new();
        for i in 0..10 {
            ds.push([0.1 * i as f32 / 10.0; N_FEATURES], true);
        }
        let model = train(&ds, KernelParams::new(KernelKind::Rbf), &SmoConfig::default());
        assert!(model.decision(&[0.05; N_FEATURES]).is_finite());
    }

    #[test]
    fn deterministic_for_seed() {
        let ds = blobs(20, 4);
        let m1 = train(&ds, KernelParams::new(KernelKind::Rbf), &SmoConfig::default());
        let m2 = train(&ds, KernelParams::new(KernelKind::Rbf), &SmoConfig::default());
        assert_eq!(m1.alpha, m2.alpha);
        assert_eq!(m1.bias, m2.bias);
    }

    /// The fast paths must agree with the textbook dual expansion.
    fn reference_decision(model: &SmoModel, x: &[f32]) -> f32 {
        let mut s = model.bias;
        for ((sx, sy), a) in model.support_x.iter().zip(&model.support_y).zip(&model.alpha) {
            if *a != 0.0 {
                s += a * sy * model.params.eval(sx, x);
            }
        }
        s
    }

    #[test]
    fn soa_fast_path_is_bit_identical_to_row_walk() {
        // RBF/sigmoid keep the kernel loop, just over the SoA slab — the
        // per-SV products and summation order are unchanged, so scores
        // must match bit for bit.
        for kind in [KernelKind::Rbf, KernelKind::Sigmoid] {
            let ds = blobs(25, 9);
            let model = train(&ds, KernelParams::new(kind), &SmoConfig::default());
            for x in ds.x.iter().take(20) {
                assert_eq!(model.decision(x), reference_decision(&model, x));
            }
        }
    }

    #[test]
    fn linear_weight_vector_matches_dual_expansion() {
        // The collapsed w·x + b reassociates the sum, so allow float slack.
        let ds = blobs(25, 10);
        let model = train(&ds, KernelParams::new(KernelKind::Linear), &SmoConfig::default());
        for x in ds.x.iter().take(20) {
            let fast = model.decision(x);
            let slow = reference_decision(&model, x);
            assert!(
                (fast - slow).abs() < 1e-3,
                "linear fast path diverged: {fast} vs {slow}"
            );
        }
    }

    #[test]
    fn empty_support_set_scores_the_bias() {
        let model = SmoModel::new(
            KernelParams::new(KernelKind::Linear),
            Vec::new(),
            Vec::new(),
            Vec::new(),
            0.75,
        );
        assert_eq!(model.decision(&[0.5; N_FEATURES]), 0.75);
        assert!(model.predict(&[0.5; N_FEATURES]));
    }
}
