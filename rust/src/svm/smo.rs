//! Pure-Rust SVM trained with simplified SMO (Platt's sequential minimal
//! optimization, simplified working-set selection).
//!
//! This is the reference/fallback classifier: it cross-validates the HLO
//! artifacts' numerics in integration tests and serves as the
//! `--svm-backend rust` implementation so every experiment runs even
//! without `make artifacts`.

use crate::util::rng::Pcg64;

use super::dataset::Dataset;
use super::kernel::KernelParams;

/// Trained SVM model (dual form).
#[derive(Debug, Clone)]
pub struct SmoModel {
    pub params: KernelParams,
    pub support_x: Vec<Vec<f32>>,
    pub support_y: Vec<f32>,
    pub alpha: Vec<f32>,
    pub bias: f32,
}

impl SmoModel {
    /// Decision score; class "reused" iff score > 0.
    pub fn decision(&self, x: &[f32]) -> f32 {
        let mut s = self.bias;
        for ((sx, sy), a) in self
            .support_x
            .iter()
            .zip(&self.support_y)
            .zip(&self.alpha)
        {
            if *a != 0.0 {
                s += a * sy * self.params.eval(sx, x);
            }
        }
        s
    }

    pub fn predict(&self, x: &[f32]) -> bool {
        self.decision(x) > 0.0
    }

    pub fn n_support(&self) -> usize {
        self.alpha.iter().filter(|&&a| a > 1e-7).count()
    }
}

/// SMO hyper-parameters.
#[derive(Debug, Clone)]
pub struct SmoConfig {
    pub c: f32,
    pub tol: f32,
    pub max_passes: usize,
    pub max_iters: usize,
    pub seed: u64,
}

impl Default for SmoConfig {
    fn default() -> Self {
        SmoConfig { c: 4.0, tol: 1e-3, max_passes: 8, max_iters: 20_000, seed: 7 }
    }
}

/// Train with simplified SMO.
pub fn train(ds: &Dataset, params: KernelParams, cfg: &SmoConfig) -> SmoModel {
    let n = ds.len();
    assert!(n > 0, "empty training set");
    let x: Vec<Vec<f32>> = ds.x.iter().map(|v| v.to_vec()).collect();
    let y = ds.y.clone();
    // Precompute the Gram matrix (n <= a few hundred on our path).
    let mut k = vec![0.0f32; n * n];
    for i in 0..n {
        for j in i..n {
            let v = params.eval(&x[i], &x[j]);
            k[i * n + j] = v;
            k[j * n + i] = v;
        }
    }
    let mut alpha = vec![0.0f32; n];
    let mut b = 0.0f32;
    let mut rng = Pcg64::new(cfg.seed, 0x5A0);
    let f = |alpha: &[f32], b: f32, k: &[f32], idx: usize| -> f32 {
        let mut s = b;
        for j in 0..n {
            if alpha[j] != 0.0 {
                s += alpha[j] * y[j] * k[idx * n + j];
            }
        }
        s
    };

    let mut passes = 0usize;
    let mut iters = 0usize;
    while passes < cfg.max_passes && iters < cfg.max_iters {
        let mut changed = 0usize;
        for i in 0..n {
            iters += 1;
            let ei = f(&alpha, b, &k, i) - y[i];
            let violates = (y[i] * ei < -cfg.tol && alpha[i] < cfg.c)
                || (y[i] * ei > cfg.tol && alpha[i] > 0.0);
            if !violates {
                continue;
            }
            // Pick j != i at random (simplified SMO heuristic).
            let mut j = rng.gen_range(n as u64 - 1) as usize;
            if j >= i {
                j += 1;
            }
            let ej = f(&alpha, b, &k, j) - y[j];
            let (ai_old, aj_old) = (alpha[i], alpha[j]);
            let (lo, hi) = if (y[i] - y[j]).abs() > 1e-6 {
                (
                    (aj_old - ai_old).max(0.0),
                    (cfg.c + aj_old - ai_old).min(cfg.c),
                )
            } else {
                (
                    (ai_old + aj_old - cfg.c).max(0.0),
                    (ai_old + aj_old).min(cfg.c),
                )
            };
            if hi - lo < 1e-9 {
                // Empty or degenerate box (float error can make hi < lo).
                continue;
            }
            let eta = 2.0 * k[i * n + j] - k[i * n + i] - k[j * n + j];
            if eta >= 0.0 {
                continue; // non-PSD direction (possible for sigmoid); skip
            }
            let mut aj = aj_old - y[j] * (ei - ej) / eta;
            aj = aj.clamp(lo, hi);
            if (aj - aj_old).abs() < 1e-6 {
                continue;
            }
            let ai = ai_old + y[i] * y[j] * (aj_old - aj);
            alpha[i] = ai;
            alpha[j] = aj;
            // Bias update (Platt's rules).
            let b1 = b - ei
                - y[i] * (ai - ai_old) * k[i * n + i]
                - y[j] * (aj - aj_old) * k[i * n + j];
            let b2 = b - ej
                - y[i] * (ai - ai_old) * k[i * n + j]
                - y[j] * (aj - aj_old) * k[j * n + j];
            b = if ai > 0.0 && ai < cfg.c {
                b1
            } else if aj > 0.0 && aj < cfg.c {
                b2
            } else {
                0.5 * (b1 + b2)
            };
            changed += 1;
        }
        if changed == 0 {
            passes += 1;
        } else {
            passes = 0;
        }
    }

    SmoModel { params, support_x: x, support_y: y, alpha, bias: b }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::features::N_FEATURES;
    use crate::svm::kernel::KernelKind;

    fn blobs(n_per: usize, seed: u64) -> Dataset {
        let mut rng = Pcg64::new(seed, 0);
        let mut ds = Dataset::new();
        for _ in 0..n_per {
            let mut a = [0.0f32; N_FEATURES];
            let mut b = [0.0f32; N_FEATURES];
            for k in 0..N_FEATURES {
                a[k] = rng.gen_normal(0.25, 0.08) as f32;
                b[k] = rng.gen_normal(0.75, 0.08) as f32;
            }
            ds.push(a, true);
            ds.push(b, false);
        }
        ds
    }

    #[test]
    fn separable_blobs_rbf() {
        let ds = blobs(40, 1);
        let model = train(&ds, KernelParams::new(KernelKind::Rbf), &SmoConfig::default());
        let acc = ds
            .x
            .iter()
            .zip(&ds.y)
            .filter(|(x, &y)| model.predict(x.as_slice()) == (y > 0.0))
            .count() as f64
            / ds.len() as f64;
        assert!(acc >= 0.99, "acc={acc}");
        assert!(model.n_support() > 0);
    }

    #[test]
    fn separable_blobs_linear() {
        let ds = blobs(40, 2);
        let model = train(&ds, KernelParams::new(KernelKind::Linear), &SmoConfig::default());
        let acc = ds
            .x
            .iter()
            .zip(&ds.y)
            .filter(|(x, &y)| model.predict(x.as_slice()) == (y > 0.0))
            .count() as f64
            / ds.len() as f64;
        assert!(acc >= 0.95, "acc={acc}");
    }

    #[test]
    fn dual_feasibility() {
        let ds = blobs(30, 3);
        let cfg = SmoConfig::default();
        let model = train(&ds, KernelParams::new(KernelKind::Rbf), &cfg);
        for &a in &model.alpha {
            assert!((-1e-6..=cfg.c + 1e-6).contains(&a), "alpha {a} out of box");
        }
        // KKT complementary slackness (loosely): sum alpha_i y_i ~ 0
        let s: f32 = model
            .alpha
            .iter()
            .zip(&model.support_y)
            .map(|(a, y)| a * y)
            .sum();
        assert!(s.abs() < 1.0, "sum alpha*y = {s}");
    }

    #[test]
    fn single_class_degenerates_gracefully() {
        let mut ds = Dataset::new();
        for i in 0..10 {
            ds.push([0.1 * i as f32 / 10.0; N_FEATURES], true);
        }
        let model = train(&ds, KernelParams::new(KernelKind::Rbf), &SmoConfig::default());
        assert!(model.decision(&[0.05; N_FEATURES]).is_finite());
    }

    #[test]
    fn deterministic_for_seed() {
        let ds = blobs(20, 4);
        let m1 = train(&ds, KernelParams::new(KernelKind::Rbf), &SmoConfig::default());
        let m2 = train(&ds, KernelParams::new(KernelKind::Rbf), &SmoConfig::default());
        assert_eq!(m1.alpha, m2.alpha);
        assert_eq!(m1.bias, m2.bias);
    }
}
