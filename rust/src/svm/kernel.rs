//! Kernel functions for the pure-Rust SVM (mirrors the L1 Pallas kernels —
//! same formulas, same hyper-parameter semantics; cross-validated against
//! the HLO artifacts in rust/tests/integration_runtime.rs).

/// Kernel function family (the paper evaluates these three in Table 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    Linear,
    Rbf,
    Sigmoid,
}

impl KernelKind {
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Linear => "linear",
            KernelKind::Rbf => "rbf",
            KernelKind::Sigmoid => "sigmoid",
        }
    }

    pub fn from_name(s: &str) -> Option<KernelKind> {
        match s.to_ascii_lowercase().as_str() {
            "linear" => Some(KernelKind::Linear),
            "rbf" => Some(KernelKind::Rbf),
            "sigmoid" => Some(KernelKind::Sigmoid),
            _ => None,
        }
    }
}

/// Kernel hyper-parameters (must match the values baked into the AOT
/// artifacts — `runtime::artifacts::Manifest` checks this at load time).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelParams {
    pub kind: KernelKind,
    pub gamma: f32,
    pub coef0: f32,
}

impl KernelParams {
    pub fn new(kind: KernelKind) -> Self {
        KernelParams { kind, gamma: 0.5, coef0: 0.0 }
    }

    /// k(x, z) for two feature vectors.
    #[inline]
    pub fn eval(&self, x: &[f32], z: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), z.len());
        match self.kind {
            KernelKind::Linear => dot(x, z),
            KernelKind::Rbf => {
                let mut sq = 0.0f32;
                for (a, b) in x.iter().zip(z) {
                    let d = a - b;
                    sq += d * d;
                }
                (-self.gamma * sq.max(0.0)).exp()
            }
            KernelKind::Sigmoid => (self.gamma * dot(x, z) + self.coef0).tanh(),
        }
    }
}

#[inline]
fn dot(x: &[f32], z: &[f32]) -> f32 {
    x.iter().zip(z).map(|(a, b)| a * b).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_is_dot() {
        let p = KernelParams::new(KernelKind::Linear);
        assert_eq!(p.eval(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }

    #[test]
    fn rbf_properties() {
        let p = KernelParams::new(KernelKind::Rbf);
        let x = [0.3, 0.7, 0.1];
        // k(x, x) = 1, symmetric, in (0, 1]
        assert!((p.eval(&x, &x) - 1.0).abs() < 1e-6);
        let z = [0.5, 0.2, 0.9];
        let kxz = p.eval(&x, &z);
        assert!((kxz - p.eval(&z, &x)).abs() < 1e-7);
        assert!(kxz > 0.0 && kxz < 1.0);
    }

    #[test]
    fn rbf_matches_hand_calc() {
        let p = KernelParams { kind: KernelKind::Rbf, gamma: 0.5, coef0: 0.0 };
        // ||x - z||^2 = 0.25 -> exp(-0.125)
        let k = p.eval(&[0.5], &[0.0]);
        assert!((k - (-0.125f32).exp()).abs() < 1e-6);
    }

    #[test]
    fn sigmoid_matches_hand_calc() {
        let p = KernelParams { kind: KernelKind::Sigmoid, gamma: 2.0, coef0: 0.5 };
        let k = p.eval(&[1.0, 0.0], &[0.5, 0.3]);
        assert!((k - (2.0f32 * 0.5 + 0.5).tanh()).abs() < 1e-6);
    }

    #[test]
    fn names_round_trip() {
        for kind in [KernelKind::Linear, KernelKind::Rbf, KernelKind::Sigmoid] {
            assert_eq!(KernelKind::from_name(kind.name()), Some(kind));
        }
        assert_eq!(KernelKind::from_name("poly"), None);
    }
}
