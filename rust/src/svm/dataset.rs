//! Labeled datasets: assembly, preprocessing, splits and padding to the
//! fixed shapes the AOT artifacts expect (§5.1 "Dataset preprocessing").

use crate::util::rng::Pcg64;

use super::features::{FeatureVec, N_FEATURES};

/// A labeled training set. Labels are +1 ("reused in the future") or -1.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub x: Vec<FeatureVec>,
    pub y: Vec<f32>,
}

impl Dataset {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: FeatureVec, reused: bool) {
        self.x.push(x);
        self.y.push(if reused { 1.0 } else { -1.0 });
    }

    pub fn len(&self) -> usize {
        self.x.len()
    }

    pub fn is_empty(&self) -> bool {
        self.x.is_empty()
    }

    pub fn n_positive(&self) -> usize {
        self.y.iter().filter(|&&v| v > 0.0).count()
    }

    /// Preprocessing per §5.1: drop rows with non-finite values (irrelevant
    /// data elimination) and clip features into [0, 1] (normalization).
    pub fn preprocess(&mut self) {
        let mut keep = Vec::with_capacity(self.len());
        for (x, y) in self.x.iter().zip(&self.y) {
            if x.iter().all(|v| v.is_finite()) && y.is_finite() {
                let mut clipped = *x;
                for v in clipped.iter_mut() {
                    *v = v.clamp(0.0, 1.0);
                }
                keep.push((clipped, *y));
            }
        }
        self.x = keep.iter().map(|(x, _)| *x).collect();
        self.y = keep.iter().map(|(_, y)| *y).collect();
    }

    /// Shuffled train/test split (the paper uses 75/25).
    pub fn split(&self, train_fraction: f64, rng: &mut Pcg64) -> (Dataset, Dataset) {
        let mut idx: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut idx);
        let n_train = ((self.len() as f64) * train_fraction).round() as usize;
        let mut train = Dataset::new();
        let mut test = Dataset::new();
        for (k, &i) in idx.iter().enumerate() {
            let target = if k < n_train { &mut train } else { &mut test };
            target.x.push(self.x[i]);
            target.y.push(self.y[i]);
        }
        (train, test)
    }

    /// `k`-fold cross-validation index sets: returns (train, test) pairs.
    pub fn k_folds(&self, k: usize, rng: &mut Pcg64) -> Vec<(Dataset, Dataset)> {
        assert!(k >= 2, "need at least 2 folds");
        let mut idx: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut idx);
        (0..k)
            .map(|fold| {
                let mut train = Dataset::new();
                let mut test = Dataset::new();
                for (pos, &i) in idx.iter().enumerate() {
                    let target = if pos % k == fold { &mut test } else { &mut train };
                    target.x.push(self.x[i]);
                    target.y.push(self.y[i]);
                }
                (train, test)
            })
            .collect()
    }

    /// Subsample down to `max` rows, keeping class balance where possible.
    pub fn truncate_balanced(&self, max: usize, rng: &mut Pcg64) -> Dataset {
        if self.len() <= max {
            return self.clone();
        }
        let pos: Vec<usize> = (0..self.len()).filter(|&i| self.y[i] > 0.0).collect();
        let neg: Vec<usize> = (0..self.len()).filter(|&i| self.y[i] <= 0.0).collect();
        let take_pos = (max / 2).min(pos.len());
        let take_neg = (max - take_pos).min(neg.len());
        let take_pos = (max - take_neg).min(pos.len()); // rebalance leftovers
        let mut chosen: Vec<usize> = Vec::with_capacity(max);
        let mut pos = pos;
        let mut neg = neg;
        rng.shuffle(&mut pos);
        rng.shuffle(&mut neg);
        chosen.extend(&pos[..take_pos]);
        chosen.extend(&neg[..take_neg]);
        chosen.sort_unstable();
        let mut out = Dataset::new();
        for i in chosen {
            out.x.push(self.x[i]);
            out.y.push(self.y[i]);
        }
        out
    }
}

/// A dataset padded to the artifact shape: N rows with a validity mask.
#[derive(Debug, Clone)]
pub struct PaddedDataset {
    /// Row-major [n_rows * N_FEATURES].
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub mask: Vec<f32>,
    pub n_rows: usize,
    pub n_real: usize,
}

/// Pad (or truncate) to exactly `n_rows` rows for the fixed-shape HLO.
pub fn pad(ds: &Dataset, n_rows: usize) -> PaddedDataset {
    let n_real = ds.len().min(n_rows);
    let mut x = vec![0.0f32; n_rows * N_FEATURES];
    let mut y = vec![0.0f32; n_rows];
    let mut mask = vec![0.0f32; n_rows];
    for i in 0..n_real {
        x[i * N_FEATURES..(i + 1) * N_FEATURES].copy_from_slice(&ds.x[i]);
        y[i] = ds.y[i];
        mask[i] = 1.0;
    }
    PaddedDataset { x, y, mask, n_rows, n_real }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n_pos: usize, n_neg: usize) -> Dataset {
        let mut ds = Dataset::new();
        for i in 0..n_pos {
            ds.push([0.2 + 0.001 * i as f32; N_FEATURES], true);
        }
        for i in 0..n_neg {
            ds.push([0.8 - 0.001 * i as f32; N_FEATURES], false);
        }
        ds
    }

    #[test]
    fn split_preserves_rows() {
        let ds = toy(30, 50);
        let (train, test) = ds.split(0.75, &mut Pcg64::new(1, 0));
        assert_eq!(train.len(), 60);
        assert_eq!(test.len(), 20);
        assert_eq!(train.n_positive() + test.n_positive(), 30);
    }

    #[test]
    fn preprocess_drops_bad_rows_and_clips() {
        let mut ds = toy(2, 2);
        ds.push([f32::NAN; N_FEATURES], true);
        let mut over = [1.7f32; N_FEATURES];
        over[0] = -0.5;
        ds.push(over, false);
        ds.preprocess();
        assert_eq!(ds.len(), 5, "NaN row dropped, clipped row kept");
        for x in &ds.x {
            assert!(x.iter().all(|v| (0.0..=1.0).contains(v)));
        }
    }

    #[test]
    fn k_folds_partition() {
        let ds = toy(20, 20);
        let folds = ds.k_folds(4, &mut Pcg64::new(2, 0));
        assert_eq!(folds.len(), 4);
        let total_test: usize = folds.iter().map(|(_, t)| t.len()).sum();
        assert_eq!(total_test, 40, "each row tested exactly once");
        for (train, test) in &folds {
            assert_eq!(train.len() + test.len(), 40);
        }
    }

    #[test]
    fn pad_shapes_and_mask() {
        let ds = toy(3, 2);
        let p = pad(&ds, 8);
        assert_eq!(p.x.len(), 8 * N_FEATURES);
        assert_eq!(p.mask, vec![1.0, 1.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0]);
        assert_eq!(p.n_real, 5);
        // padded labels are zero
        assert_eq!(p.y[5..], [0.0, 0.0, 0.0]);
    }

    #[test]
    fn pad_truncates_overlong() {
        let ds = toy(10, 10);
        let p = pad(&ds, 4);
        assert_eq!(p.n_real, 4);
        assert_eq!(p.mask.iter().sum::<f32>(), 4.0);
    }

    #[test]
    fn truncate_balanced_keeps_both_classes() {
        let ds = toy(100, 10);
        let out = ds.truncate_balanced(20, &mut Pcg64::new(3, 0));
        assert_eq!(out.len(), 20);
        assert!(out.n_positive() >= 10, "positives fill spare negative slots");
        assert!(out.len() - out.n_positive() == 10);
    }
}
