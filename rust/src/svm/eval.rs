//! Classifier evaluation: confusion matrix, precision/recall/F1/accuracy
//! (the §5.2 metrics behind Table 5) and k-fold cross-validation.

use crate::util::rng::Pcg64;

use super::dataset::Dataset;

/// Binary confusion matrix. "Positive" = class 1 = reused in the future.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ConfusionMatrix {
    pub tp: u64,
    pub fp: u64,
    pub tn: u64,
    pub fn_: u64,
}

impl ConfusionMatrix {
    pub fn record(&mut self, actual: bool, predicted: bool) {
        match (actual, predicted) {
            (true, true) => self.tp += 1,
            (false, true) => self.fp += 1,
            (false, false) => self.tn += 1,
            (true, false) => self.fn_ += 1,
        }
    }

    pub fn total(&self) -> u64 {
        self.tp + self.fp + self.tn + self.fn_
    }

    pub fn accuracy(&self) -> f64 {
        if self.total() == 0 {
            return 0.0;
        }
        (self.tp + self.tn) as f64 / self.total() as f64
    }

    /// Precision for the positive class (Table 5's "1" rows).
    pub fn precision_pos(&self) -> f64 {
        safe_div(self.tp as f64, (self.tp + self.fp) as f64)
    }

    pub fn recall_pos(&self) -> f64 {
        safe_div(self.tp as f64, (self.tp + self.fn_) as f64)
    }

    pub fn f1_pos(&self) -> f64 {
        harmonic(self.precision_pos(), self.recall_pos())
    }

    /// Precision for the negative class (Table 5's "0" rows).
    pub fn precision_neg(&self) -> f64 {
        safe_div(self.tn as f64, (self.tn + self.fn_) as f64)
    }

    pub fn recall_neg(&self) -> f64 {
        safe_div(self.tn as f64, (self.tn + self.fp) as f64)
    }

    pub fn f1_neg(&self) -> f64 {
        harmonic(self.precision_neg(), self.recall_neg())
    }
}

fn safe_div(a: f64, b: f64) -> f64 {
    if b == 0.0 {
        0.0
    } else {
        a / b
    }
}

fn harmonic(p: f64, r: f64) -> f64 {
    if p + r == 0.0 {
        0.0
    } else {
        2.0 * p * r / (p + r)
    }
}

/// Evaluate a predictor over a labeled dataset.
pub fn evaluate<F: FnMut(&[f32]) -> bool>(ds: &Dataset, mut predict: F) -> ConfusionMatrix {
    let mut cm = ConfusionMatrix::default();
    for (x, &y) in ds.x.iter().zip(&ds.y) {
        cm.record(y > 0.0, predict(x));
    }
    cm
}

/// k-fold cross-validated accuracy: `train_fn(train) -> predictor`.
pub fn cross_validate<M, F>(
    ds: &Dataset,
    k: usize,
    seed: u64,
    mut train_fn: M,
) -> f64
where
    M: FnMut(&Dataset) -> F,
    F: FnMut(&[f32]) -> bool,
{
    let folds = ds.k_folds(k, &mut Pcg64::new(seed, 0xCF));
    let mut correct = 0u64;
    let mut total = 0u64;
    for (train, test) in folds {
        let mut predictor = train_fn(&train);
        let cm = evaluate(&test, &mut predictor);
        correct += cm.tp + cm.tn;
        total += cm.total();
    }
    safe_div(correct as f64, total as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::features::N_FEATURES;

    #[test]
    fn confusion_matrix_counts() {
        let mut cm = ConfusionMatrix::default();
        // 3 TP, 1 FP, 4 TN, 2 FN
        for _ in 0..3 {
            cm.record(true, true);
        }
        cm.record(false, true);
        for _ in 0..4 {
            cm.record(false, false);
        }
        for _ in 0..2 {
            cm.record(true, false);
        }
        assert_eq!(cm.total(), 10);
        assert!((cm.accuracy() - 0.7).abs() < 1e-12);
        assert!((cm.precision_pos() - 0.75).abs() < 1e-12);
        assert!((cm.recall_pos() - 0.6).abs() < 1e-12);
        assert!((cm.f1_pos() - 2.0 * 0.75 * 0.6 / 1.35).abs() < 1e-12);
        assert!((cm.precision_neg() - 4.0 / 6.0).abs() < 1e-12);
        assert!((cm.recall_neg() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn degenerate_matrix_is_zero_not_nan() {
        let cm = ConfusionMatrix::default();
        assert_eq!(cm.accuracy(), 0.0);
        assert_eq!(cm.precision_pos(), 0.0);
        assert_eq!(cm.f1_pos(), 0.0);
    }

    #[test]
    fn evaluate_perfect_predictor() {
        let mut ds = Dataset::new();
        for i in 0..10 {
            ds.push([i as f32 / 10.0; N_FEATURES], i % 2 == 0);
        }
        let labels: Vec<bool> = ds.y.iter().map(|&y| y > 0.0).collect();
        let mut i = 0;
        let cm = evaluate(&ds, |_| {
            let r = labels[i];
            i += 1;
            r
        });
        assert_eq!(cm.accuracy(), 1.0);
    }

    #[test]
    fn cross_validation_runs_all_folds() {
        let mut ds = Dataset::new();
        for i in 0..40 {
            // Feature 0 alone decides the label: trivially learnable.
            let mut x = [0.0f32; N_FEATURES];
            x[0] = if i % 2 == 0 { 0.9 } else { 0.1 };
            ds.push(x, i % 2 == 0);
        }
        let acc = cross_validate(&ds, 4, 1, |_train| |x: &[f32]| x[0] > 0.5);
        assert_eq!(acc, 1.0);
    }
}
