//! Target-label generation for the non-request-awareness scenario —
//! Table 4's guidelines, implemented row by row.
//!
//! Given a job-status / map-task-status / reduce-task-status triple, the
//! rules decide whether the *input of the Map task* and the *input of the
//! Reduce task* (the map outputs) will be reused.

use crate::mapreduce::job::JobStatus;
use crate::mapreduce::task::TaskStatus;

/// Labels for one history observation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Labels {
    /// Will the Map task's input data be reused?
    pub map_input_reused: bool,
    /// Will the Reduce task's input (the map outputs) be reused?
    pub reduce_input_reused: bool,
}

impl Labels {
    const NOT: Labels = Labels { map_input_reused: false, reduce_input_reused: false };
}

/// Table 4, one arm per row. `reduce_status = None` encodes the "Waiting"
/// phase (reduces not yet schedulable).
pub fn label(
    job: JobStatus,
    map: TaskStatus,
    reduce: Option<TaskStatus>,
) -> Labels {
    use JobStatus as J;
    use TaskStatus as T;
    // Row 12: job-status has higher priority than task status.
    if matches!(job, J::Failed | J::Killed | J::Error) {
        return Labels::NOT;
    }
    match (job, map, reduce) {
        // Row 1: job waiting in the queue.
        (J::New, _, _) => Labels::NOT,
        // Row 2: scheduled maps, reduces waiting — map outputs not yet
        // generated, map inputs will be read.
        (J::Initiated, T::Scheduled | T::New, None) => {
            Labels { map_input_reused: true, reduce_input_reused: false }
        }
        (J::Initiated, _, _) => Labels::NOT,
        // Row 3: maps running, reduces waiting.
        (J::Running, T::Running, None) => {
            Labels { map_input_reused: true, reduce_input_reused: false }
        }
        // Rows 4/5: maps done, reduces scheduling/running — the reduce
        // input (map output) is what gets reused now.
        (J::Running, T::Succeeded, Some(T::Scheduled) | Some(T::Running) | Some(T::New)) => {
            Labels { map_input_reused: false, reduce_input_reused: true }
        }
        // Row 6: failed map cannot generate intermediate data.
        (J::Running, T::Failed, _) => Labels::NOT,
        // Row 7: reduce failed, the job cannot continue.
        (J::Running, T::Succeeded, Some(T::Failed)) => Labels::NOT,
        // Row 8: killed map may re-execute elsewhere (speculative) — its
        // input will be read again.
        (J::Running, T::Killed, None) => {
            Labels { map_input_reused: true, reduce_input_reused: false }
        }
        // Row 9: killed reduce may re-execute — map outputs reused.
        (J::Running, T::Succeeded, Some(T::Killed)) => {
            Labels { map_input_reused: false, reduce_input_reused: true }
        }
        // Anything else mid-run without clearer evidence: conservative.
        (J::Running, _, _) => Labels::NOT,
        // Row 10: completed job; repetitive-job relationships are out of
        // scope for the paper.
        (J::Succeeded, _, _) => Labels::NOT,
        // Terminal rows already handled above.
        (J::Failed | J::Killed | J::Error, _, _) => Labels::NOT,
    }
}

/// Convenience: label a history record (map vs reduce observation).
pub fn label_record(rec: &crate::mapreduce::HistoryRecord) -> Labels {
    use crate::mapreduce::task::TaskKind;
    match rec.task_kind {
        TaskKind::Map => {
            // Observation of the map phase: reduces are still waiting.
            label(rec.job_status, rec.task_status, None)
        }
        TaskKind::Reduce => label(rec.job_status, TaskStatus::Succeeded, Some(rec.task_status)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use JobStatus as J;
    use TaskStatus as T;

    #[test]
    fn table4_rows() {
        // Row 1: New/New/New -> not / not
        assert_eq!(label(J::New, T::New, None), Labels::NOT);
        // Row 2: Initiated/Scheduling/Waiting -> reused / not
        let l = label(J::Initiated, T::Scheduled, None);
        assert!(l.map_input_reused && !l.reduce_input_reused);
        // Row 3: Running/Running/Waiting -> reused / not
        let l = label(J::Running, T::Running, None);
        assert!(l.map_input_reused && !l.reduce_input_reused);
        // Row 4: Running/Succeeded/Scheduling -> not / reused
        let l = label(J::Running, T::Succeeded, Some(T::Scheduled));
        assert!(!l.map_input_reused && l.reduce_input_reused);
        // Row 5: Running/Succeeded/Running -> not / reused
        let l = label(J::Running, T::Succeeded, Some(T::Running));
        assert!(!l.map_input_reused && l.reduce_input_reused);
        // Row 6: Running/Failed/Waiting -> not / not
        assert_eq!(label(J::Running, T::Failed, None), Labels::NOT);
        // Row 7: Running/Succeeded/Failed -> not / not
        assert_eq!(label(J::Running, T::Succeeded, Some(T::Failed)), Labels::NOT);
        // Row 8: Running/Killed/Waiting -> reused / not (speculative)
        let l = label(J::Running, T::Killed, None);
        assert!(l.map_input_reused && !l.reduce_input_reused);
        // Row 9: Running/Succeeded/Killed -> not / reused (speculative)
        let l = label(J::Running, T::Succeeded, Some(T::Killed));
        assert!(!l.map_input_reused && l.reduce_input_reused);
        // Row 10: Succeeded -> not / not
        assert_eq!(label(J::Succeeded, T::Succeeded, Some(T::Succeeded)), Labels::NOT);
        // Row 11/12: Failed job dominates any task status.
        assert_eq!(label(J::Failed, T::Succeeded, Some(T::Running)), Labels::NOT);
        assert_eq!(label(J::Killed, T::Running, None), Labels::NOT);
    }

    #[test]
    fn job_status_priority_over_tasks() {
        // Even "promising" task states are overruled by a failed job.
        for map in [T::New, T::Scheduled, T::Running, T::Succeeded] {
            for reduce in [None, Some(T::Running), Some(T::Scheduled)] {
                assert_eq!(label(J::Error, map, reduce), Labels::NOT);
            }
        }
    }
}
