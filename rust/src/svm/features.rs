//! SVM feature extraction — the request-awareness scenario of §5.1/Table 2.
//!
//! Feature vector layout (D = 9, matches python/compile/model.N_FEATURES):
//!
//! | idx | feature                       | source            |
//! |-----|-------------------------------|-------------------|
//! | 0-2 | block type one-hot            | Table 2 "Type"    |
//! | 3   | size (normalized)             | Table 2 "Size"    |
//! | 4   | recency (decayed age)         | Table 2 "Recency" |
//! | 5   | frequency (log-scaled)        | Table 2 "Frequency" |
//! | 6   | requesting app cache affinity | Table 3 extension |
//! | 7   | share degree (distinct apps)  | §6.4.2 sharing    |
//! | 8   | recompute cost (log-scaled)   | DAG stage outputs (arXiv 1804.10563) |
//!
//! `BlockStatsTracker` maintains the per-block running state (last access,
//! access count, distinct requesting apps) the features are computed from.

use crate::util::fasthash::IdHashMap;

use crate::cache::CacheAffinity;
use crate::hdfs::{BlockId, BlockKind};
use crate::sim::SimTime;

/// Number of features (must equal the AOT artifacts' N_FEATURES).
pub const N_FEATURES: usize = 9;

/// A normalized feature vector.
pub type FeatureVec = [f32; N_FEATURES];

/// Distinct requesting apps tracked per block. The share-degree feature is
/// `min(len / MAX_TRACKED_APPS, 1)`, so it saturates exactly here — ids
/// beyond the cap cannot change any feature value.
const MAX_TRACKED_APPS: usize = 4;

/// Capped inline set of distinct app ids. Replaces the per-block
/// `HashSet<u64>` the tracker used to allocate for every block it ever
/// saw: the share-degree feature saturates at [`MAX_TRACKED_APPS`]
/// distinct apps, so a fixed-size probe array is exact and allocation-free.
#[derive(Debug, Clone, Copy, Default)]
struct AppSet {
    ids: [u64; MAX_TRACKED_APPS],
    len: u8,
}

impl AppSet {
    fn insert(&mut self, app: u64) {
        let n = self.len as usize;
        if n == MAX_TRACKED_APPS || self.ids[..n].contains(&app) {
            return; // saturated (feature already 1.0) or already tracked
        }
        self.ids[n] = app;
        self.len += 1;
    }

    fn len(&self) -> usize {
        self.len as usize
    }
}

/// Per-block running statistics.
#[derive(Debug, Clone)]
struct BlockStats {
    last_access: SimTime,
    accesses: u64,
    apps: AppSet,
}

/// Tracks block access statistics and derives normalized features.
#[derive(Debug)]
pub struct BlockStatsTracker {
    stats: IdHashMap<BlockId, BlockStats>,
    /// Normalization reference: block size considered "large" (1.0).
    pub max_block_size: u64,
    /// Recency half-life in seconds for the decayed-age feature.
    pub recency_half_life_s: f64,
    /// Frequency scale: log1p(freq) / log1p(freq_scale) saturates at 1.
    pub freq_scale: f64,
    /// Recompute-cost scale in seconds:
    /// `log1p(cost_s) / log1p(cost_scale_s)` saturates at 1. A stage
    /// output that takes `cost_scale_s` of CPU to regenerate is "maximally
    /// expensive" for the classifier.
    pub cost_scale_s: f64,
}

impl BlockStatsTracker {
    /// Build a tracker; `max_block_size` is the size-normalization
    /// reference (a block of that size gets size feature 1.0).
    pub fn new(max_block_size: u64) -> Self {
        BlockStatsTracker {
            stats: IdHashMap::default(),
            max_block_size: max_block_size.max(1),
            recency_half_life_s: 120.0,
            freq_scale: 32.0,
            cost_scale_s: 60.0,
        }
    }

    /// Record an access by `app_id` at `now`. Call *after* computing the
    /// pre-access features so the current request does not leak into them.
    pub fn record_access(&mut self, block: BlockId, app_id: u64, now: SimTime) {
        let e = self.stats.entry(block).or_insert(BlockStats {
            last_access: now,
            accesses: 0,
            apps: AppSet::default(),
        });
        e.last_access = now;
        e.accesses += 1;
        e.apps.insert(app_id);
    }

    /// Total recorded accesses of `block` (0 when never seen).
    pub fn accesses(&self, block: BlockId) -> u64 {
        self.stats.get(&block).map(|s| s.accesses).unwrap_or(0)
    }

    /// Build the (normalized) feature vector for a request.
    /// `recompute_cost_s` is the CPU seconds needed to regenerate the
    /// block when it has been evicted (0.0 for plain HDFS blocks that can
    /// always be re-read from disk).
    pub fn features(
        &self,
        block: BlockId,
        kind: BlockKind,
        size: u64,
        affinity: CacheAffinity,
        recompute_cost_s: f64,
        now: SimTime,
    ) -> FeatureVec {
        let one_hot = kind.one_hot();
        let size_f = (size as f64 / self.max_block_size as f64).min(1.0) as f32;
        let (recency, freq, share) = match self.stats.get(&block) {
            Some(s) => {
                let age = s.last_access.duration_until(now).as_secs_f64();
                let recency = 0.5f64.powf(age / self.recency_half_life_s) as f32;
                let freq = ((s.accesses as f64).ln_1p() / (self.freq_scale).ln_1p())
                    .min(1.0) as f32;
                let share = (s.apps.len() as f32 / MAX_TRACKED_APPS as f32).min(1.0);
                (recency, freq, share)
            }
            None => (0.0, 0.0, 0.0),
        };
        let cost = (recompute_cost_s.max(0.0).ln_1p() / self.cost_scale_s.ln_1p())
            .min(1.0) as f32;
        [
            one_hot[0],
            one_hot[1],
            one_hot[2],
            size_f,
            recency,
            freq,
            affinity.weight() as f32,
            share,
            cost,
        ]
    }

    /// Forget all per-block history (fresh measurement pass).
    pub fn reset(&mut self) {
        self.stats.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::MB;

    #[test]
    fn fresh_block_has_zero_history_features() {
        let tr = BlockStatsTracker::new(128 * MB);
        let f = tr.features(
            BlockId(1),
            BlockKind::Input,
            64 * MB,
            CacheAffinity::High,
            0.0,
            SimTime::ZERO,
        );
        assert_eq!(&f[0..3], &[1.0, 0.0, 0.0]);
        assert!((f[3] - 0.5).abs() < 1e-6); // 64/128
        assert_eq!(f[4], 0.0); // no recency
        assert_eq!(f[5], 0.0); // no frequency
        assert_eq!(f[6], 1.0); // high affinity
        assert_eq!(f[7], 0.0); // no sharing
        assert_eq!(f[8], 0.0); // free to recompute
    }

    #[test]
    fn features_respond_to_history() {
        let mut tr = BlockStatsTracker::new(128 * MB);
        let b = BlockId(2);
        for (t, app) in [(0.0, 1u64), (10.0, 2), (20.0, 3)] {
            tr.record_access(b, app, SimTime::from_secs_f64(t));
        }
        let f = tr.features(
            b,
            BlockKind::Intermediate,
            128 * MB,
            CacheAffinity::Low,
            0.0,
            SimTime::from_secs_f64(21.0),
        );
        assert!(f[4] > 0.9, "recent access -> recency near 1, got {}", f[4]);
        assert!(f[5] > 0.3, "3 accesses -> nonzero freq, got {}", f[5]);
        assert!((f[7] - 0.75).abs() < 1e-6, "3 distinct apps / 4");
        assert_eq!(tr.accesses(b), 3);
        // Features are bounded.
        for v in f {
            assert!((0.0..=1.0).contains(&v), "feature {v} out of range");
        }
    }

    #[test]
    fn recency_decays() {
        let mut tr = BlockStatsTracker::new(128 * MB);
        tr.record_access(BlockId(1), 0, SimTime::ZERO);
        let f_soon = tr.features(
            BlockId(1), BlockKind::Input, MB, CacheAffinity::Medium, 0.0,
            SimTime::from_secs_f64(1.0),
        );
        let f_late = tr.features(
            BlockId(1), BlockKind::Input, MB, CacheAffinity::Medium, 0.0,
            SimTime::from_secs_f64(1200.0),
        );
        assert!(f_soon[4] > f_late[4]);
        assert!(f_late[4] < 0.01);
    }

    #[test]
    fn share_degree_saturates_at_the_cap() {
        let mut tr = BlockStatsTracker::new(MB);
        let b = BlockId(3);
        // 10 distinct apps (each seen twice): the inline set caps at 4
        // tracked ids, and the feature saturates at exactly 1.0 — the same
        // value the unbounded HashSet produced.
        for app in 0..10u64 {
            tr.record_access(b, app, SimTime::from_secs_f64(app as f64));
            tr.record_access(b, app, SimTime::from_secs_f64(app as f64));
        }
        let f = tr.features(
            b,
            BlockKind::Input,
            MB,
            CacheAffinity::Medium,
            0.0,
            SimTime::from_secs_f64(10.0),
        );
        assert_eq!(f[7], 1.0);
        assert_eq!(tr.accesses(b), 20);
    }

    #[test]
    fn recompute_cost_is_log_scaled_and_bounded() {
        let tr = BlockStatsTracker::new(128 * MB);
        let at = |cost: f64| {
            tr.features(
                BlockId(9),
                BlockKind::Intermediate,
                64 * MB,
                CacheAffinity::Medium,
                cost,
                SimTime::ZERO,
            )[8]
        };
        assert_eq!(at(0.0), 0.0);
        assert!(at(1.0) > 0.0);
        assert!(at(10.0) > at(1.0), "more cost -> larger feature");
        assert_eq!(at(60.0), 1.0, "saturates at cost_scale_s");
        assert_eq!(at(1e9), 1.0, "clamped above the scale");
        assert_eq!(at(-5.0), 0.0, "negative cost clamps to free");
    }

    #[test]
    fn reset_clears_history() {
        let mut tr = BlockStatsTracker::new(MB);
        tr.record_access(BlockId(1), 0, SimTime::ZERO);
        tr.reset();
        assert_eq!(tr.accesses(BlockId(1)), 0);
    }
}
