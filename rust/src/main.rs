//! `repro` — the leader entrypoint: runs the paper's experiments over the
//! simulated cluster, executing the AOT-compiled JAX/Pallas SVM through
//! PJRT (or the pure-Rust SMO fallback with `--svm-backend rust`).

use anyhow::Result;

use h_svm_lru::cli::{Cli, HELP};
use h_svm_lru::experiments::{fig3, fig4, fig5, fig6, policies, table5, table7};
use h_svm_lru::util::logger;
use h_svm_lru::util::table::Table;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn emit(title: &str, table: &Table, csv: bool) {
    if csv {
        print!("{}", table.to_csv());
    } else {
        println!("\n=== {title} ===");
        print!("{}", table.render());
    }
}

fn run(args: &[String]) -> Result<()> {
    let cli = Cli::parse(args)?;
    match cli.flag("log-level").and_then(logger::parse_level) {
        Some(level) => logger::init(level),
        None => logger::init_from_env(),
    }
    let csv = cli.switch("csv");
    match cli.command.as_str() {
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        "quickstart" => quickstart(&cli),
        "fig3" => {
            let points = fig3::run(&cli.svm_config()?, cli.seed()?)?;
            emit("Fig 3: cache hit ratio vs cache size", &fig3::render(&points), csv);
            Ok(())
        }
        "table7" => {
            let points = table7::run(&cli.svm_config()?, cli.seed()?)?;
            emit(
                "Table 7: improvement ratio of H-SVM-LRU over LRU",
                &table7::render(&points),
                csv,
            );
            Ok(())
        }
        "fig4" => {
            let points = fig4::run(&cli.svm_config()?, cli.seed()?)?;
            emit("Fig 4: job execution time vs input size", &fig4::render(&points), csv);
            Ok(())
        }
        "fig5" => {
            let points = fig5::run(&cli.svm_config()?, cli.seed()?, cli.scale()?)?;
            emit("Fig 5: normalized run time per workload", &fig5::render(&points), csv);
            let (lru, svm, over) = fig5::summary(&points);
            println!(
                "\navg improvement vs H-NoCache: H-LRU {lru:.2}%  H-SVM-LRU {svm:.2}%  \
                 (H-SVM-LRU over H-LRU: {over:.2}%)"
            );
            println!("paper: H-LRU 11.33%, H-SVM-LRU 16.16% (4.83% over H-LRU)");
            Ok(())
        }
        "fig6" => {
            let points = fig6::run(&cli.svm_config()?, cli.seed()?, cli.scale()?)?;
            emit("Fig 6: per-app normalized run time (H-SVM-LRU)", &fig6::render(&points), csv);
            let mut t = Table::new(vec!["application", "mean normalized run time"]);
            for (app, norm) in fig6::per_app_means(&points) {
                t.add_row(vec![app, format!("{norm:.4}")]);
            }
            emit("Fig 6 summary: per-app means", &t, csv);
            Ok(())
        }
        "table5" => {
            let svm_cfg = cli.svm_config()?;
            let evals = table5::run(&svm_cfg, cli.seed()?)?;
            emit("Table 5: kernel-function evaluation", &table5::render(&evals), csv);
            if cli.switch("cv") {
                let acc = table5::cross_validated_accuracy(&svm_cfg, cli.seed()?, 4)?;
                println!("\n4-fold cross-validated accuracy (rbf): {acc:.3} (paper: ~0.83)");
            }
            Ok(())
        }
        "simulate" => {
            use h_svm_lru::experiments::simulate::{self, SimulateConfig};
            use h_svm_lru::experiments::Scenario;
            use h_svm_lru::mapreduce::FailureModel;
            let svm_cfg = cli.svm_config()?;
            let (mut cluster_cfg, _) = h_svm_lru::config::load(cli.flag("config"))?;
            let scenario = match cli.flag("policy") {
                Some("none") | Some("no-cache") => Scenario::NoCache,
                _ => match cli.policy("h-svm-lru")?.as_str() {
                    "h-svm-lru" => Scenario::SvmLru,
                    p => Scenario::Policy(p.to_string()),
                },
            };
            cluster_cfg.cache_shards = cli.shards(cluster_cfg.cache_shards)?;
            cluster_cfg.cache_batch_queue = cli.batch_queue(cluster_cfg.cache_batch_queue)?;
            cluster_cfg.cache_batch_deadline_ms =
                cli.batch_deadline_ms(cluster_cfg.cache_batch_deadline_ms)?;
            if let Some(adm) = cli.flag("admission") {
                cluster_cfg.cache_admission = adm.to_string();
            }
            cluster_cfg.validate()?;
            let mut sim = SimulateConfig { seed: cli.seed()?, ..Default::default() };
            if cli.switch("failures") {
                sim.failures = FailureModel::with_rates(0.08, 0.03, cli.seed()?);
            }
            if cli.switch("prefetch") {
                sim.prefetch_depth = 2;
            }
            let report = simulate::run(&cluster_cfg, &scenario, &svm_cfg, &sim)?;
            println!("\n=== cluster simulation ({}) ===", scenario.label());
            println!("cache shards       {}", cluster_cfg.cache_shards);
            if cluster_cfg.cache_admission != "always" {
                println!("cache admission    {}", cluster_cfg.cache_admission);
            }
            if cluster_cfg.cache_batch_queue > 1 {
                println!(
                    "batcher queue      {} (deadline {} ms)",
                    cluster_cfg.cache_batch_queue, cluster_cfg.cache_batch_deadline_ms
                );
            }
            println!("jobs completed     {}", report.completed.len());
            println!("sim time           {}", report.sim_end);
            println!("events fired       {}", report.events_fired);
            println!("hit ratio          {:.4}", report.hit_ratio);
            println!("byte hit ratio     {:.4}", report.byte_hit_ratio);
            println!("heartbeats         {}", report.heartbeats);
            println!("metadata fixes     {}", report.metadata_fixes);
            println!("svm trainings      {}", report.trainings);
            println!("failed attempts    {}", report.failed_attempts);
            println!("killed attempts    {}", report.killed_attempts);
            if let Some(u) = report.prefetch_useful {
                println!("prefetch useful    {:.2}%", u * 100.0);
            }
            let times: Vec<f64> = report
                .completed
                .iter()
                .map(|r| r.execution_time().as_secs_f64())
                .collect();
            // One sort serves both quantile reads (util::stats::Summary).
            let summary = h_svm_lru::util::stats::Summary::of(&times);
            println!(
                "job exec time      mean {:.1}s  p95 {:.1}s",
                summary.mean(),
                summary.percentile(95.0)
            );
            Ok(())
        }
        "sharded" => {
            use h_svm_lru::experiments::sharded_replay::{self, ReplayOptions};
            use h_svm_lru::util::bytes::MB;
            let max_shards = cli.shards(8)?;
            let blocks: u64 =
                cli.flag("cache-blocks").map(|s| s.parse()).transpose()?.unwrap_or(8);
            let policy = cli.policy("h-svm-lru")?;
            let recency = recency_config(&cli)?;
            let block_size = 64 * MB;
            let trace = h_svm_lru::workload::fig3_trace(block_size, cli.seed()?);
            let counts = doubling_shard_counts(max_shards);
            // Classify once for the sweep AND the optional reader arm —
            // predictions depend on neither the shard count nor readers.
            let classes =
                sharded_replay::classify_trace(&trace, h_svm_lru::svm::KernelKind::Rbf, 64)?;
            let opts = ReplayOptions::new().classes(&classes).recency(recency);
            let reports = counts
                .iter()
                .map(|&n| {
                    Ok(sharded_replay::replay(&policy, n, blocks * block_size, &trace, &opts)?
                        .report)
                })
                .collect::<Result<Vec<_>>>()?;
            emit(
                &format!(
                    "Shard-parallel replay ({policy}, {} requests, cache = {blocks} \
                     blocks of 64MB)",
                    trace.len()
                ),
                &sharded_replay::render(&reports),
                csv,
            );
            if let (Some(first), Some(last)) = (reports.first(), reports.last()) {
                println!(
                    "\nreplay speedup {}-shard over 1-shard: {:.2}x",
                    last.shards,
                    last.requests_per_sec() / first.requests_per_sec().max(1e-12)
                );
            }
            // Telemetry arm: one observed replay at the max shard count,
            // exported as deterministic JSONL.
            if let Some(path) = cli.flag("metrics-out") {
                use h_svm_lru::obs::{MetricsRegistry, ObsConfig};
                let registry = MetricsRegistry::new();
                let obs_cfg = ObsConfig::default();
                let out = sharded_replay::replay(
                    &policy,
                    max_shards,
                    blocks * block_size,
                    &trace,
                    &ReplayOptions::new()
                        .classify(h_svm_lru::svm::KernelKind::Rbf, 64)
                        .observe(&registry, obs_cfg)
                        .recency(recency),
                )?;
                let report = out.report;
                let obs = out
                    .observations
                    .ok_or_else(|| anyhow::anyhow!("observed replay produced no windows"))?;
                let mut doc = obs.into_doc(obs_cfg.window_us);
                doc.meta_str("cmd", "sharded");
                doc.meta_str("policy", policy.as_str());
                doc.meta_u64("shards", report.shards as u64);
                doc.meta_u64("seed", cli.seed()?);
                doc.meta_u64("requests", report.stats.requests);
                emit_metrics(path, &registry, doc)?;
            }
            // Reader-contention arm: replay once more at the max shard
            // count with N threads hammering the lock-free stats path.
            let readers = cli.readers(0)?;
            if readers > 0 {
                let out = sharded_replay::replay(
                    &policy,
                    max_shards,
                    blocks * block_size,
                    &trace,
                    &opts.readers(readers),
                )?;
                let rr = out.readers.unwrap_or_default();
                println!(
                    "\n{} stats reader(s) during the {max_shards}-shard replay: \
                     {} consistent snapshots, {} inconsistencies, replay wall {:.2} ms",
                    rr.readers,
                    rr.snapshots,
                    rr.inconsistencies,
                    out.report.wall.as_secs_f64() * 1e3,
                );
                anyhow::ensure!(
                    rr.inconsistencies == 0,
                    "lock-free stats readers observed a torn snapshot"
                );
            }
            Ok(())
        }
        "admission" => {
            use h_svm_lru::experiments::admission;
            use h_svm_lru::util::bytes::MB;
            let shards = cli.shards(1)?;
            let blocks: u64 =
                cli.flag("cache-blocks").map(|s| s.parse()).transpose()?.unwrap_or(8);
            let smoke = cli.switch("smoke");
            let seed = cli.seed()?;
            let block_size = 64 * MB;
            let policies = admission::default_policies(smoke);
            let admissions = admission::default_admissions();
            let traces = [
                ("fig3", h_svm_lru::workload::fig3_trace(block_size, seed)),
                ("scan-storm", h_svm_lru::workload::scan_storm_trace(block_size, seed)),
            ];
            for (name, trace) in &traces {
                let sweep = admission::run_matrix(
                    name,
                    &policies,
                    &admissions,
                    shards,
                    blocks * block_size,
                    trace,
                )?;
                emit(
                    &format!(
                        "Admission sweep on {name} ({} requests, cache = {blocks} blocks \
                         of 64MB, {shards} shard(s)) — hit ratios",
                        trace.len()
                    ),
                    &admission::render_hit_ratios(&sweep),
                    csv,
                );
                emit(
                    &format!("Admission sweep on {name} — rejected inserts"),
                    &admission::render_rejections(&sweep),
                    csv,
                );
                if *name == "scan-storm" {
                    if let Some(lru) = sweep.rows.iter().find(|r| r.policy == "lru") {
                        let always = lru.hit_ratio_of("always").unwrap_or(0.0);
                        let tinylfu = lru.hit_ratio_of("tinylfu").unwrap_or(0.0);
                        let svm = lru.hit_ratio_of("svm").unwrap_or(0.0);
                        println!(
                            "\nscan-storm, plain LRU: always {always:.4} -> tinylfu \
                             {tinylfu:.4}, svm {svm:.4} (pollution stopped at insert time)"
                        );
                    }
                }
            }
            Ok(())
        }
        "online" => {
            use h_svm_lru::coordinator::batcher::BatcherConfig;
            use h_svm_lru::coordinator::online::TrainerConfig;
            use h_svm_lru::experiments::online_sharded::{self, TrainerMode};
            use h_svm_lru::experiments::sharded_replay::{self, ReplayOptions};
            use h_svm_lru::svm::KernelKind;
            use h_svm_lru::util::bytes::MB;

            let svm_cfg = cli.svm_config()?;
            // The online trainer needs a Send backend that exports model
            // snapshots; the PJRT path offers neither. Reject rather than
            // silently substituting the rust backend for the one asked for.
            anyhow::ensure!(
                svm_cfg.backend == "rust",
                "`repro online` requires --svm-backend rust (the {} backend cannot \
                 export Send model snapshots for the background trainer)",
                svm_cfg.backend
            );
            let kernel = KernelKind::from_name(&svm_cfg.kernel)
                .ok_or_else(|| anyhow::anyhow!("bad kernel name {:?}", svm_cfg.kernel))?;
            let max_shards = cli.shards(8)?;
            let blocks: u64 =
                cli.flag("cache-blocks").map(|s| s.parse()).transpose()?.unwrap_or(8);
            let policy = cli.policy("h-svm-lru")?;
            let recency = recency_config(&cli)?;
            let smoke = cli.switch("smoke");
            let seed = cli.seed()?;
            let block_size = 64 * MB;
            let capacity = blocks * block_size;
            let trainer_cfg = TrainerConfig::default();
            let default_batcher = BatcherConfig::default();
            // Deadlines are simulated milliseconds (trace time), keeping
            // seeded replays deterministic regardless of host speed.
            let default_deadline_ms = default_batcher.deadline.micros() / 1000;
            let batcher_cfg = BatcherConfig {
                queue_depth: cli.batch_queue(default_batcher.queue_depth)?,
                deadline: h_svm_lru::sim::SimDuration::from_micros(
                    cli.batch_deadline_ms(default_deadline_ms)?.saturating_mul(1000),
                ),
                ..default_batcher
            };
            // The smoke parity assertion (frozen == classify-once) only
            // holds when every cold query is answered inline.
            if cli.switch("smoke") {
                anyhow::ensure!(
                    batcher_cfg.queue_depth == 1,
                    "--smoke parity requires --batch-queue 1 (deferred predictions \
                     intentionally diverge from the classify-once path)"
                );
            }

            // Smoke: just the requested policy at the full shard count
            // (the acceptance path). Full: an lru baseline next to the
            // requested policy, over a doubling shard sweep.
            let mut policies = vec![policy.as_str()];
            let mut counts = vec![max_shards];
            if !smoke {
                if policy != "lru" {
                    policies.insert(0, "lru");
                }
                counts = doubling_shard_counts(max_shards);
            }

            let traces = [
                ("fig3", h_svm_lru::workload::fig3_trace(block_size, seed)),
                ("scan-storm", h_svm_lru::workload::scan_storm_trace(block_size, seed)),
            ];
            for (name, trace) in &traces {
                let reports = online_sharded::run_matrix(
                    &policies,
                    &counts,
                    capacity,
                    trace,
                    kernel,
                    trainer_cfg,
                    batcher_cfg,
                    recency,
                )?;
                emit(
                    &format!(
                        "Online-learning replay on {name} ({} requests, cache = {blocks} \
                         blocks of 64MB)",
                        trace.len()
                    ),
                    &online_sharded::render(&reports),
                    csv,
                );
                let online = reports
                    .iter()
                    .find(|r| {
                        r.policy == policy
                            && r.mode == TrainerMode::Online
                            && r.shards == max_shards
                    })
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "online matrix is missing the {policy} x online x \
                             {max_shards}-shard cell"
                        )
                    })?;
                println!(
                    "\n{name}, {policy} @ {max_shards} shards online: {} snapshot \
                     publish(es), {} samples ({} dropped), {:.0} samples/s",
                    online.trainer.publishes,
                    online.samples_sent,
                    online.samples_dropped,
                    online.samples_per_sec(),
                );
                println!(
                    "cold path: {} cold queries, {} deferred, {} flushes \
                     (mean {:.1} queries/flush), {} dropped",
                    online.cold.cold_queries,
                    online.cold.deferred,
                    online.cold.flushes,
                    online.cold.mean_flush_size(),
                    online.cold.dropped,
                );
                // The acceptance criteria, enforced on the smoke path CI
                // runs: the live trainer must actually publish, and the
                // frozen arm must be bit-identical to the classify-once
                // `repro sharded` replay.
                if smoke {
                    anyhow::ensure!(
                        online.trainer.publishes >= 1,
                        "online replay on {name} never published a snapshot"
                    );
                    let classes = sharded_replay::classify_trace(trace, kernel, 64)?;
                    let baseline = sharded_replay::replay(
                        &policy,
                        max_shards,
                        capacity,
                        trace,
                        &ReplayOptions::new().classes(&classes).recency(recency),
                    )?
                    .report;
                    let frozen = reports
                        .iter()
                        .find(|r| {
                            r.policy == policy
                                && r.mode == TrainerMode::Frozen
                                && r.shards == max_shards
                        })
                        .ok_or_else(|| {
                            anyhow::anyhow!(
                                "online matrix is missing the {policy} x frozen x \
                                 {max_shards}-shard cell"
                            )
                        })?;
                    anyhow::ensure!(
                        frozen.stats == baseline.stats
                            && frozen.per_shard == baseline.per_shard,
                        "frozen online replay diverged from the classify-once path on \
                         {name}: {:?} vs {:?}",
                        frozen.stats,
                        baseline.stats
                    );
                    println!(
                        "smoke ok: frozen arm bit-identical to classify-once, \
                         {} publish(es) live",
                        online.trainer.publishes
                    );
                }
            }
            // Telemetry arm: one observed LIVE replay on the fig3 trace at
            // the max shard count (snapshot churn + batcher histograms).
            if let Some(path) = cli.flag("metrics-out") {
                use h_svm_lru::obs::{MetricsRegistry, ObsConfig};
                let registry = MetricsRegistry::new();
                let obs_cfg = ObsConfig::default();
                let (report, obs) = online_sharded::run_online_observed(
                    &policy,
                    max_shards,
                    capacity,
                    &traces[0].1,
                    TrainerMode::Online,
                    kernel,
                    trainer_cfg,
                    batcher_cfg,
                    recency,
                    &registry,
                    obs_cfg,
                )?;
                let mut doc = obs.into_doc(obs_cfg.window_us);
                doc.meta_str("cmd", "online");
                doc.meta_str("policy", policy.as_str());
                doc.meta_str("mode", "online");
                doc.meta_u64("shards", report.shards as u64);
                doc.meta_u64("seed", seed);
                doc.meta_u64("requests", report.stats.requests);
                emit_metrics(path, &registry, doc)?;
            }
            Ok(())
        }
        "dag" => {
            use h_svm_lru::experiments::dag_replay;
            use h_svm_lru::svm::KernelKind;
            use h_svm_lru::workload::diamond_suite;

            let svm_cfg = cli.svm_config()?;
            let kernel = KernelKind::from_name(&svm_cfg.kernel)
                .ok_or_else(|| anyhow::anyhow!("bad kernel name {:?}", svm_cfg.kernel))?;
            let (mut cluster_cfg, _) = h_svm_lru::config::load(cli.flag("config"))?;
            cluster_cfg.cache_recency_batch =
                cli.recency_batch(cluster_cfg.cache_recency_batch)?;
            cluster_cfg.cache_recency_drain_cadence_ms =
                cli.recency_drain_cadence_ms(cluster_cfg.cache_recency_drain_cadence_ms)?;
            cluster_cfg.validate()?;
            let seed = cli.seed()?;
            let shards = cli.shards(4)?;
            let smoke = cli.switch("smoke");
            let n_jobs = cli.jobs(3)?;
            let cache_blocks: u64 =
                cli.flag("cache-blocks").map(|s| s.parse()).transpose()?.unwrap_or(16);

            // Sweep dimensions: smoke runs the one acceptance cell, the
            // full sweep covers cost-aware policies x sizes x concurrency.
            let flag_policy = cli.policy("h-svm-lru")?;
            let mut policies: Vec<String> =
                vec!["lru".into(), "h-svm-lru".into(), "lru-cost".into(), "arc-cost".into()];
            if !policies.iter().any(|p| *p == flag_policy) {
                policies.push(flag_policy.clone());
            }
            if smoke {
                policies = vec!["lru".into(), "h-svm-lru".into()];
            }
            let cache_sizes: Vec<u64> =
                if smoke { vec![cache_blocks] } else { vec![cache_blocks / 2, cache_blocks, cache_blocks * 2] };
            let job_counts: Vec<usize> = if smoke { vec![n_jobs] } else { vec![1, n_jobs] };

            let mut reports = Vec::new();
            for &jobs in &job_counts {
                let suite = diamond_suite(jobs, 4, 8);
                for &blocks in &cache_sizes {
                    let capacity = blocks.max(1) * cluster_cfg.block_size;
                    for policy in &policies {
                        reports.push(dag_replay::run_dag(
                            policy, &cluster_cfg, shards, capacity, &suite, seed, kernel, 64,
                        )?);
                    }
                }
            }
            emit(
                &format!(
                    "DAG replay: diamond suite (sources=4, scans=8), {} shard(s), \
                     block size {} MB",
                    shards,
                    cluster_cfg.block_size / h_svm_lru::util::bytes::MB
                ),
                &dag_replay::render(&reports),
                csv,
            );

            // The acceptance check (CI smoke): cost-aware H-SVM-LRU beats
            // cost-blind LRU on total simulated job time for the same cell.
            if smoke {
                let cell = |name: &str| {
                    reports.iter().find(|r| r.policy == name).ok_or_else(|| {
                        anyhow::anyhow!("dag smoke sweep is missing the {name} cell")
                    })
                };
                let (lru, svm) = (cell("lru")?, cell("h-svm-lru")?);
                println!(
                    "\nsmoke: h-svm-lru {:.1}s vs lru {:.1}s total job time \
                     ({} vs {} recomputes)",
                    svm.total_job_time_s,
                    lru.total_job_time_s,
                    svm.recompute_events,
                    lru.recompute_events,
                );
                anyhow::ensure!(
                    svm.total_job_time_s < lru.total_job_time_s,
                    "cost-aware H-SVM-LRU must beat cost-blind LRU on the diamond \
                     suite: {:.2}s vs {:.2}s",
                    svm.total_job_time_s,
                    lru.total_job_time_s
                );
                println!("smoke ok: recompute-cost-aware eviction wins on job time");
            }
            // Telemetry arm: one observed replay of the requested cell,
            // with recompute charges in the windowed series.
            if let Some(path) = cli.flag("metrics-out") {
                use h_svm_lru::obs::{MetricsRegistry, ObsConfig};
                let registry = MetricsRegistry::new();
                let obs_cfg = ObsConfig::default();
                let suite = diamond_suite(n_jobs, 4, 8);
                let (report, obs) = dag_replay::run_dag_observed(
                    &flag_policy,
                    &cluster_cfg,
                    shards,
                    cache_blocks.max(1) * cluster_cfg.block_size,
                    &suite,
                    seed,
                    kernel,
                    64,
                    &registry,
                    obs_cfg,
                )?;
                let mut doc = obs.into_doc(obs_cfg.window_us);
                doc.meta_str("cmd", "dag");
                doc.meta_str("policy", flag_policy.as_str());
                doc.meta_u64("shards", shards as u64);
                doc.meta_u64("jobs", n_jobs as u64);
                doc.meta_u64("seed", seed);
                doc.meta_u64("requests", report.stats.requests);
                emit_metrics(path, &registry, doc)?;
            }
            Ok(())
        }
        "chaos" => {
            use h_svm_lru::coordinator::online::TrainerConfig;
            use h_svm_lru::experiments::{chaos, dag_replay};
            use h_svm_lru::mapreduce::FailureModel;
            use h_svm_lru::obs::{MetricsRegistry, RunObservations, DEFAULT_WINDOW_US};
            use h_svm_lru::sim::{FaultEvent, FaultInjector, FaultPlan, SimTime};
            use h_svm_lru::svm::KernelKind;
            use h_svm_lru::util::bytes::MB;
            use h_svm_lru::workload::diamond_suite;

            let svm_cfg = cli.svm_config()?;
            // Same constraint as `repro online`: the chaos arms pretrain
            // and (in the trainer arm) retrain through exported model
            // snapshots, which the PJRT path cannot provide.
            anyhow::ensure!(
                svm_cfg.backend == "rust",
                "`repro chaos` requires --svm-backend rust (the {} backend cannot \
                 export Send model snapshots)",
                svm_cfg.backend
            );
            let kernel = KernelKind::from_name(&svm_cfg.kernel)
                .ok_or_else(|| anyhow::anyhow!("bad kernel name {:?}", svm_cfg.kernel))?;
            let seed = cli.seed()?;
            let shards = cli.shards(4)?;
            let blocks: u64 =
                cli.flag("cache-blocks").map(|s| s.parse()).transpose()?.unwrap_or(8);
            let n_jobs = cli.jobs(2)?;
            let smoke = cli.switch("smoke");
            let policy = cli.policy("h-svm-lru")?;
            let block_size = 64 * MB;
            let capacity = blocks * block_size;
            let trace = h_svm_lru::workload::fig3_trace(block_size, seed);

            // Serving arm: scripted classifier outage + latency spike; the
            // per-shard circuit breaker degrades H-SVM-LRU to the
            // unclassified cold path and the probe closes it afterwards.
            // The LRU control replays the identical plan through its own
            // injector so the two tallies stay independent.
            let plan = chaos::default_serving_plan(&trace, seed);
            let breaker = chaos::breaker_for_trace(&trace);
            let registry = MetricsRegistry::with_enabled(cli.flag("metrics-out").is_some());
            let svm_injector = FaultInjector::new(plan.clone());
            svm_injector.register_gauges(&registry, "faults");
            let recency = recency_config(&cli)?;
            let svm = chaos::run_serving_chaos(
                &policy, shards, capacity, &trace, kernel, breaker, &svm_injector,
                &registry, DEFAULT_WINDOW_US, recency,
            )?;
            let lru_injector = FaultInjector::new(plan.clone());
            let lru = chaos::run_serving_chaos(
                "lru", shards, capacity, &trace, kernel, breaker, &lru_injector,
                &MetricsRegistry::disabled(), DEFAULT_WINDOW_US, recency,
            )?;
            let reports = [svm, lru];
            emit(
                &format!(
                    "Chaos replay on fig3 ({} requests, cache = {blocks} blocks of 64MB, \
                     {shards} shard(s), seed {seed})",
                    trace.len()
                ),
                &chaos::render(&reports),
                csv,
            );
            let [svm, lru] = reports;
            if let Some(o) = svm.outage {
                println!(
                    "\nscripted outage {} .. {}: {} injected failures, breaker opened \
                     {}x / closed {}x, {} fallback queries",
                    o.start, o.end, svm.backend_failures, svm.breaker_opens,
                    svm.breaker_closes, svm.breaker_fallbacks,
                );
            }
            match svm.recovered_after_windows {
                Some(w) => println!(
                    "recovery: hit ratio back within {:.0}pp of pre-outage {} window(s) \
                     after the outage end",
                    chaos::RECOVERY_GAP * 100.0, w
                ),
                None => println!("recovery: hit ratio never returned to the pre-outage band"),
            }

            // Trainer arm: one scripted crash mid-stream; the resilient
            // loop restarts (buffer lost, snapshot kept).
            let trainer_plan = FaultPlan::all_clear(seed).with_event(
                FaultEvent::TrainerCrash { after_samples: trace.len() as u64 / 2 },
            );
            let trainer_injector = FaultInjector::new(trainer_plan);
            let trainer = chaos::run_trainer_chaos(
                &policy, shards, capacity, &trace, kernel, TrainerConfig::default(),
                &trainer_injector, &registry,
            )?;
            println!(
                "\ntrainer arm: {} crash(es) injected, {} restart(s), {} train error(s), \
                 {} publish(es), {} samples stale at exit",
                trainer_injector.trainer_crashes(),
                trainer.trainer.restarts,
                trainer.trainer.train_errors,
                trainer.trainer.publishes,
                trainer.trainer.stale_samples,
            );

            // DAG arm: two DataNodes die at t=0 (replicas dark, cached
            // copies dropped at the wave boundary) plus seeded map-attempt
            // failures from the same plan seed.
            let (cluster_cfg, _) = h_svm_lru::config::load(cli.flag("config"))?;
            let suite = diamond_suite(n_jobs, 4, 8);
            let dag_capacity = blocks.max(1) * cluster_cfg.block_size;
            let clean = dag_replay::run_dag(
                &policy, &cluster_cfg, shards, dag_capacity, &suite, seed, kernel, 64,
            )?;
            let node_plan = FaultPlan::all_clear(seed)
                .with_event(FaultEvent::NodeDown { node: 0, at: SimTime::ZERO })
                .with_event(FaultEvent::NodeDown { node: 1, at: SimTime::ZERO });
            let dag_injector = FaultInjector::new(node_plan.clone());
            let dag_chaos = dag_replay::DagChaos {
                plan: &node_plan,
                injector: Some(&dag_injector),
                failures: FailureModel::with_rates(0.05, 0.02, node_plan.seed()),
            };
            let under = dag_replay::run_dag_chaos(
                &policy, &cluster_cfg, shards, dag_capacity, &suite, seed, kernel, 64,
                &dag_chaos,
            )?;
            println!(
                "\ndag arm: {} node death(s) applied, total job time {:.1}s under chaos \
                 vs {:.1}s clean ({} vs {} recomputes)",
                dag_injector.node_downs(),
                under.total_job_time_s,
                clean.total_job_time_s,
                under.recompute_events,
                clean.recompute_events,
            );

            // The acceptance checks (CI smoke): open -> fallback -> close,
            // bounded degradation vs plain LRU, recovery within the run,
            // trainer restart, and node death actually costing time.
            if smoke {
                anyhow::ensure!(svm.breaker_opens >= 1, "outage never opened the breaker");
                anyhow::ensure!(
                    svm.breaker_fallbacks >= 1,
                    "open breaker never served a fallback query"
                );
                anyhow::ensure!(
                    svm.breaker_closes >= 1,
                    "probe never closed the breaker after the outage"
                );
                anyhow::ensure!(
                    svm.outage_hit + 0.05 >= lru.outage_hit,
                    "degraded H-SVM-LRU must stay within 5pp of plain LRU under the \
                     identical outage: {:.4} vs {:.4}",
                    svm.outage_hit,
                    lru.outage_hit
                );
                anyhow::ensure!(
                    svm.recovered_after_windows.is_some(),
                    "hit ratio never recovered to within {:.0}pp of the pre-outage \
                     baseline after the breaker closed",
                    chaos::RECOVERY_GAP * 100.0
                );
                anyhow::ensure!(
                    trainer.trainer.restarts >= 1,
                    "injected trainer crash never restarted the resilient loop"
                );
                anyhow::ensure!(
                    dag_injector.node_downs() >= 1,
                    "scripted node deaths were never applied at a wave boundary"
                );
                anyhow::ensure!(
                    under.total_job_time_s >= clean.total_job_time_s,
                    "dead nodes and failed attempts cannot make jobs faster: \
                     {:.2}s vs {:.2}s",
                    under.total_job_time_s,
                    clean.total_job_time_s
                );
                println!(
                    "\nsmoke ok: breaker opened -> degraded within bound -> recovered; \
                     trainer restarted; node death charged"
                );
            }
            // Telemetry arm: the serving-arm windowed series plus every
            // registered gauge (injection tallies, breaker counters,
            // trainer facts) as deterministic JSONL.
            if let Some(path) = cli.flag("metrics-out") {
                let obs = RunObservations {
                    windows: svm.windows.clone(),
                    audit: Vec::new(),
                    audit_seen: 0,
                    audit_every: 1,
                };
                let mut doc = obs.into_doc(DEFAULT_WINDOW_US);
                doc.meta_str("cmd", "chaos");
                doc.meta_str("policy", policy.as_str());
                doc.meta_u64("shards", shards as u64);
                doc.meta_u64("seed", seed);
                doc.meta_u64("requests", svm.stats.requests);
                doc.meta_u64("breaker_opens", svm.breaker_opens);
                emit_metrics(path, &registry, doc)?;
            }
            Ok(())
        }
        "report" => {
            use anyhow::Context;
            let path = cli
                .operand
                .as_deref()
                .ok_or_else(|| anyhow::anyhow!("usage: repro report <metrics.jsonl>"))?;
            let content = std::fs::read_to_string(path)
                .with_context(|| format!("reading metrics file {path:?}"))?;
            print!("{}", h_svm_lru::obs::export::render_report(&content)?);
            Ok(())
        }
        "bench-gate" => {
            use anyhow::Context;
            use h_svm_lru::bench_support::compare::{gate_files, render_report};
            let baseline_dir = cli.flag("baseline").unwrap_or("BENCH_baseline");
            let current_dir = cli.flag("current").unwrap_or("rust");
            let tolerance: f64 = match cli.flag("tolerance") {
                Some(s) => {
                    let v: f64 = s.parse().context("bad --tolerance")?;
                    anyhow::ensure!(
                        v > 0.0 && v < 10.0,
                        "--tolerance must be a relative fraction in (0, 10)"
                    );
                    v
                }
                None => 0.15,
            };
            let mut failed = false;
            for suite in ["hotpath", "sharded", "online", "dag", "obs"] {
                let file = format!("BENCH_{suite}.json");
                let baseline = std::path::Path::new(baseline_dir).join(&file);
                let current = std::path::Path::new(current_dir).join(&file);
                let report = gate_files(&baseline, &current, tolerance)?;
                print!("{}", render_report(&report, tolerance));
                failed |= !report.passed();
            }
            anyhow::ensure!(
                !failed,
                "bench regression gate failed (rows above); if the slowdown is \
                 intended, refresh BENCH_baseline/ from the bench-gate artifacts"
            );
            println!("bench gate: every tracked metric within tolerance");
            Ok(())
        }
        "policies" => {
            let blocks: u64 = cli.flag("cache-blocks").map(|s| s.parse()).transpose()?.unwrap_or(8);
            let results = policies::run(&cli.svm_config()?, cli.seed()?, blocks)?;
            emit(
                &format!("Policy ablation (cache = {blocks} blocks of 64MB)"),
                &policies::render(&results),
                csv,
            );
            Ok(())
        }
        "all" => {
            for sub in ["fig3", "table7", "fig4", "fig5", "fig6", "table5", "policies"] {
                let mut sub_args = vec![sub.to_string()];
                sub_args.extend(args.iter().skip(1).cloned());
                run(&sub_args)?;
            }
            Ok(())
        }
        other => {
            anyhow::bail!("unknown subcommand {other:?}\n\n{HELP}");
        }
    }
}

/// Write the telemetry document + registry scalars to `path`
/// (`--metrics-out`), first logging the wall-clock (volatile) histograms
/// that the deterministic file deliberately excludes.
fn emit_metrics(
    path: &str,
    registry: &h_svm_lru::obs::MetricsRegistry,
    doc: h_svm_lru::obs::export::MetricsDoc,
) -> Result<()> {
    h_svm_lru::obs::export::log_volatile(registry);
    doc.write_jsonl(registry, path)?;
    println!("\nmetrics: wrote {path} (render with `repro report {path}`)");
    Ok(())
}

/// Replay-worker recency-buffer config from `--recency-batch` /
/// `--recency-drain-cadence-ms`. The defaults (batch 1, no cadence) keep
/// every access draining immediately — the bit-exact legacy behaviour —
/// and the cadence is simulated (request-clock) time, so seeded runs stay
/// deterministic. Shared by the `sharded` and `online` subcommands; `dag`
/// threads the same flags through its `ClusterConfig`.
fn recency_config(cli: &Cli) -> Result<h_svm_lru::cache::RecencyConfig> {
    Ok(h_svm_lru::cache::RecencyConfig::default()
        .with_batch(cli.recency_batch(1)?)
        .with_drain_cadence(h_svm_lru::sim::SimDuration::from_micros(
            cli.recency_drain_cadence_ms(0)?.saturating_mul(1000),
        )))
}

/// Doubling shard sweep, always ending on the requested count (so
/// `--shards 6` actually runs 1, 2, 4, 6) — shared by the `sharded` and
/// `online` subcommands.
fn doubling_shard_counts(max_shards: usize) -> Vec<usize> {
    let mut counts = Vec::new();
    let mut shards = 1usize;
    while shards < max_shards {
        counts.push(shards);
        shards *= 2;
    }
    counts.push(max_shards);
    counts
}

/// A 30-second tour: replay the Fig 3 trace at one cache size and print
/// LRU vs H-SVM-LRU hit ratios plus classifier stats.
fn quickstart(cli: &Cli) -> Result<()> {
    use h_svm_lru::experiments::{make_coordinator, replay_trace_two_pass, Scenario};
    use h_svm_lru::util::bytes::MB;
    use h_svm_lru::workload::fig3_trace;

    let svm_cfg = cli.svm_config()?;
    let seed = cli.seed()?;
    println!("h-svm-lru quickstart: 2GB input, 8-block cache, 64MB blocks");
    println!("svm backend: {} / kernel {}", svm_cfg.backend, svm_cfg.kernel);
    let trace = fig3_trace(64 * MB, seed);
    println!("trace: {} requests over 32 distinct blocks", trace.len());
    for scenario in [Scenario::Policy("lru".to_string()), Scenario::SvmLru] {
        let (_cfg, cluster) =
            h_svm_lru::experiments::common::provision_fig3_cluster(64 * MB, 8, seed);
        let mut coord = make_coordinator(cluster, &scenario, &svm_cfg)?;
        let hit_ratio = replay_trace_two_pass(&mut coord, &trace)?;
        println!(
            "{:<12} hit ratio {:.4}   (hits {} / misses {} / evictions {})",
            scenario.label(),
            hit_ratio,
            coord.stats.hits,
            coord.stats.misses,
            coord.stats.evictions,
        );
        if scenario == Scenario::SvmLru {
            let bs = coord.batcher_stats();
            println!(
                "  classifier: {} trainings, {} queries, {} class-cache hits, {} PJRT calls",
                coord.pipeline.trainings, bs.queries, bs.class_cache_hits, bs.backend_calls
            );
        }
    }
    Ok(())
}
