//! Policy registry: construct any implemented policy by name (CLI,
//! experiments and the policy-comparison ablation all go through here).

use crate::sim::SimDuration;

use super::affinity_aware::AffinityAware;
use super::arc::ModifiedArc;
use super::autocache::AutoCache;
use super::block_goodness::BlockGoodness;
use super::cost_aware::CostAware;
use super::exd::Exd;
use super::fifo::Fifo;
use super::hsvmlru::HSvmLru;
use super::life::Life;
use super::lfu::Lfu;
use super::lfu_f::LfuF;
use super::lru::Lru;
use super::slru_k::SlruK;
use super::wsclock::WsClock;
use super::CachePolicy;

/// All registered policy names, in presentation order.
pub const POLICY_NAMES: &[&str] = &[
    "lru",
    "h-svm-lru",
    "fifo",
    "lfu",
    "life",
    "lfu-f",
    "wsclock",
    "modified-arc",
    "slru-k",
    "exd",
    "block-goodness",
    "affinity-aware",
    "autocache",
    "lru-cost",
    "lfu-cost",
    "arc-cost",
];

/// Instantiate a policy by name with its default parameters.
pub fn make_policy(name: &str) -> Option<Box<dyn CachePolicy>> {
    let window = SimDuration::from_secs_f64(120.0);
    let tau = SimDuration::from_secs_f64(60.0);
    Some(match name {
        "lru" => Box::new(Lru::new()),
        "h-svm-lru" => Box::new(HSvmLru::new()),
        "fifo" => Box::new(Fifo::new()),
        "lfu" => Box::new(Lfu::new()),
        "life" => Box::new(Life::new(window)),
        "lfu-f" => Box::new(LfuF::new(window)),
        "wsclock" => Box::new(WsClock::new(tau)),
        "modified-arc" => Box::new(ModifiedArc::new(64)),
        "slru-k" => Box::new(SlruK::new(2)),
        "exd" => Box::new(Exd::new(0.01)),
        "block-goodness" => Box::new(BlockGoodness::new()),
        "affinity-aware" => Box::new(AffinityAware::new()),
        "autocache" => Box::new(AutoCache::new()),
        // Cost-aware variants: the base eviction order with a recompute-cost
        // tie-break over the front candidate window (workload::dag misses on
        // evicted intermediates charge that cost to job time).
        "lru-cost" => Box::new(CostAware::new(Box::new(Lru::new()), "lru-cost")),
        "lfu-cost" => Box::new(CostAware::new(Box::new(Lfu::new()), "lfu-cost")),
        "arc-cost" => Box::new(CostAware::new(Box::new(ModifiedArc::new(64)), "arc-cost")),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{AccessContext, BlockCache};
    use crate::hdfs::BlockId;
    use crate::sim::SimTime;

    #[test]
    fn every_registered_name_constructs() {
        for name in POLICY_NAMES {
            let p = make_policy(name).unwrap_or_else(|| panic!("{name} missing"));
            assert_eq!(&p.name(), name);
        }
        assert!(make_policy("nonsense").is_none());
    }

    #[test]
    fn every_policy_survives_a_generic_workout() {
        // 200 accesses over 50 blocks against a 10-block cache: the cache
        // invariants must hold for every policy.
        for name in POLICY_NAMES {
            let mut cache = BlockCache::new(make_policy(name).unwrap(), 10);
            for t in 0..200u64 {
                let b = BlockId((t * 7 + t * t % 13) % 50);
                let ctx = AccessContext::simple(SimTime(t), 1)
                    .with_prediction(t % 3 == 0);
                cache.access_or_insert(b, &ctx);
                assert!(cache.used() <= cache.capacity(), "{name} overflow");
                assert_eq!(cache.used(), cache.len() as u64, "{name} accounting");
            }
        }
    }
}
