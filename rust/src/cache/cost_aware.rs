//! Cost-aware victim tie-break: a wrapper that makes any base policy
//! prefer evicting cheap-to-recompute blocks.
//!
//! DAG stage outputs (workload::dag) are cache-only — a miss on an evicted
//! intermediate block re-runs part of the producing stage and charges its
//! recompute cost to simulated job time. A cost-blind policy treats a
//! 0-cost scan block and a 60-second-to-rebuild shuffle product as equal
//! victims. [`CostAware`] keeps the base policy's eviction order but, among
//! the first `k` candidates of that order ([`CachePolicy::victim_candidates`]),
//! picks the one with the lowest recorded recompute cost. With uniform
//! costs (e.g. a flat trace where every cost is 0.0) the first candidate
//! wins the min and the wrapper is bit-identical to the base policy.
//!
//! `choose_victim` must stay idempotent and non-mutating between evictions:
//! `BlockCache::insert` probes the victim lazily and may consult it again
//! before confirming with `on_evict` — re-ranking a read-only candidate
//! window preserves that contract as long as the base policy's
//! `victim_candidates` does (all in-tree overrides are pure reads).
//!
//! Registered as `lru-cost`, `lfu-cost` and `arc-cost` in
//! [`super::registry`].

use crate::hdfs::BlockId;
use crate::sim::SimTime;
use crate::util::fasthash::IdHashMap;

use super::{AccessContext, CachePolicy};

/// How many blocks of the base policy's eviction order the tie-break may
/// reorder. Small by design: the wrapper trades at most `k - 1` positions
/// of the base order for cost, so a hot block can never be sacrificed for
/// an arbitrarily cold expensive one.
pub const DEFAULT_CANDIDATE_WINDOW: usize = 4;

/// Wraps a base [`CachePolicy`] and re-ranks its victim window by
/// recompute cost (cheapest evicted first).
pub struct CostAware {
    inner: Box<dyn CachePolicy>,
    name: &'static str,
    /// Last recompute cost reported for each tracked block.
    costs: IdHashMap<BlockId, f64>,
    k: usize,
    /// Whether the latest `choose_victim` deviated from the base order
    /// (reported through [`CachePolicy::took_cost_tie_break`]).
    last_tie: bool,
}

impl CostAware {
    /// Wrap `inner`, reporting `name` (the registry key, e.g. "lru-cost").
    pub fn new(inner: Box<dyn CachePolicy>, name: &'static str) -> Self {
        CostAware {
            inner,
            name,
            costs: IdHashMap::default(),
            k: DEFAULT_CANDIDATE_WINDOW,
            last_tie: false,
        }
    }

    /// Override the candidate-window size (`k >= 1`).
    pub fn with_window(mut self, k: usize) -> Self {
        self.k = k.max(1);
        self
    }

    /// The recompute cost currently recorded for `block`.
    pub fn cost_of(&self, block: BlockId) -> Option<f64> {
        self.costs.get(&block).copied()
    }
}

impl CachePolicy for CostAware {
    fn name(&self) -> &'static str {
        self.name
    }

    fn on_hit(&mut self, block: BlockId, ctx: &AccessContext) {
        self.costs.insert(block, ctx.recompute_cost);
        self.inner.on_hit(block, ctx);
    }

    fn on_insert(&mut self, block: BlockId, ctx: &AccessContext) {
        self.costs.insert(block, ctx.recompute_cost);
        self.inner.on_insert(block, ctx);
    }

    fn choose_victim(&mut self, now: SimTime) -> Option<BlockId> {
        // Min cost over the candidate window; the window is ordered best
        // victim first, so strict `<` keeps the base policy's choice on
        // ties — uniform costs degrade to exactly the base policy.
        let mut best: Option<(BlockId, f64)> = None;
        let mut first: Option<BlockId> = None;
        for b in self.inner.victim_candidates(now, self.k) {
            first.get_or_insert(b);
            let cost = self.costs.get(&b).copied().unwrap_or(0.0);
            match best {
                Some((_, c)) if cost >= c => {}
                _ => best = Some((b, cost)),
            }
        }
        // The tie-break "fired" iff the pick differs from the base
        // policy's own head-of-order choice.
        self.last_tie = match (best, first) {
            (Some((b, _)), Some(f)) => b != f,
            _ => false,
        };
        best.map(|(b, _)| b)
    }

    fn victim_candidates(&mut self, now: SimTime, k: usize) -> Vec<BlockId> {
        // Expose the re-ranked window so stacked wrappers see the same
        // order this policy would actually evict in.
        let mut window = self.inner.victim_candidates(now, self.k.max(k));
        let costs = &self.costs;
        window.sort_by(|a, b| {
            let ca = costs.get(a).copied().unwrap_or(0.0);
            let cb = costs.get(b).copied().unwrap_or(0.0);
            ca.partial_cmp(&cb).unwrap_or(std::cmp::Ordering::Equal)
        });
        window.truncate(k);
        window
    }

    fn on_evict(&mut self, block: BlockId) {
        self.costs.remove(&block);
        self.inner.on_evict(block);
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn admits(&self, block: BlockId, ctx: &AccessContext) -> bool {
        self.inner.admits(block, ctx)
    }

    fn took_cost_tie_break(&self) -> bool {
        self.last_tie
    }
}

#[cfg(test)]
mod tests {
    use super::super::lru::Lru;
    use super::*;

    fn ctx(t: u64, cost: f64) -> AccessContext {
        AccessContext::simple(SimTime(t), 1).with_recompute_cost(cost)
    }

    #[test]
    fn uniform_costs_match_base_policy() {
        let mut base = Lru::new();
        let mut wrapped = CostAware::new(Box::new(Lru::new()), "lru-cost");
        for i in 0..8u64 {
            base.on_insert(BlockId(i), &ctx(i, 0.0));
            wrapped.on_insert(BlockId(i), &ctx(i, 0.0));
        }
        base.on_hit(BlockId(2), &ctx(10, 0.0));
        wrapped.on_hit(BlockId(2), &ctx(10, 0.0));
        for t in 11..17u64 {
            let want = base.choose_victim(SimTime(t));
            assert_eq!(wrapped.choose_victim(SimTime(t)), want);
            base.on_evict(want.unwrap());
            wrapped.on_evict(want.unwrap());
        }
    }

    #[test]
    fn cheap_block_evicted_before_expensive_older_one() {
        let mut p = CostAware::new(Box::new(Lru::new()), "lru-cost");
        p.on_insert(BlockId(1), &ctx(1, 45.0)); // LRU-oldest but expensive
        p.on_insert(BlockId(2), &ctx(2, 0.0));
        p.on_insert(BlockId(3), &ctx(3, 45.0));
        // Plain LRU would pick 1; the cost tie-break picks the free block.
        assert_eq!(p.choose_victim(SimTime(4)), Some(BlockId(2)));
        assert!(p.took_cost_tie_break(), "deviation from base order must be flagged");
        // Idempotent until the eviction is confirmed.
        assert_eq!(p.choose_victim(SimTime(5)), Some(BlockId(2)));
        p.on_evict(BlockId(2));
        // Only expensive blocks left: back to the base LRU order.
        assert_eq!(p.choose_victim(SimTime(6)), Some(BlockId(1)));
        assert!(!p.took_cost_tie_break(), "base-order pick must not be flagged");
    }

    #[test]
    fn window_bounds_the_reordering() {
        // The expensive block is protected only while it sits inside the
        // k-block window; beyond that the base order rules.
        let mut p = CostAware::new(Box::new(Lru::new()), "lru-cost").with_window(2);
        p.on_insert(BlockId(1), &ctx(1, 99.0));
        p.on_insert(BlockId(2), &ctx(2, 99.0));
        p.on_insert(BlockId(3), &ctx(3, 0.0)); // cheap, but outside k=2
        assert_eq!(p.choose_victim(SimTime(4)), Some(BlockId(1)));
    }

    #[test]
    fn candidate_window_is_cost_sorted() {
        let mut p = CostAware::new(Box::new(Lru::new()), "lru-cost");
        p.on_insert(BlockId(1), &ctx(1, 30.0));
        p.on_insert(BlockId(2), &ctx(2, 0.0));
        p.on_insert(BlockId(3), &ctx(3, 10.0));
        assert_eq!(
            p.victim_candidates(SimTime(4), 3),
            vec![BlockId(2), BlockId(3), BlockId(1)]
        );
        assert_eq!(p.cost_of(BlockId(3)), Some(10.0));
    }
}
