//! Lock-split shard statistics: a per-shard atomic counter block with
//! seqlock-consistent snapshots.
//!
//! Before this module existed, [`ShardStats`] lived *inside* the shard
//! behind the shard `Mutex`: every `stats()` / `used()` reader took every
//! shard lock and serialized against the replay writers. With the
//! per-access bookkeeping now O(1), that serialization was the dominant
//! cost of the concurrent replay (ROADMAP: "lock splitting on the shard
//! front").
//!
//! The split:
//!
//! * Writers (the shard hot path) still run under the shard `Mutex` — the
//!   lock already serializes cache mutations, so there is **exactly one
//!   stats writer per shard** at any time. They bump plain relaxed
//!   atomics inside a seqlock write section ([`AtomicShardStats::write`]).
//! * Readers never take a lock: [`AtomicShardStats::snapshot`] spins on
//!   the sequence word until it observes an even, unchanged value around
//!   the counter reads, yielding an **internally consistent** snapshot
//!   (`hits + misses == requests`, `used <= capacity`) even while the
//!   writer is mid-flight.
//!
//! Cross-shard merges stay consistent because each per-shard snapshot is
//! consistent and the merged invariants are linear (sums of per-shard
//! invariants) — property-tested in rust/tests/property_sharded.rs by
//! reader threads hammering `stats()` during a multi-threaded replay.

use crate::util::sync::atomic::{fence, AtomicU64, Ordering};
use crate::util::sync::hint;

/// Per-shard access counters; merged across shards (and across DataNodes
/// by the coordinator) with [`ShardStats::merge`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardStats {
    /// Accesses routed to the shard (`hits + misses`).
    pub requests: u64,
    /// Accesses that found the block cached.
    pub hits: u64,
    /// Accesses that did not.
    pub misses: u64,
    /// Blocks evicted to make room.
    pub evictions: u64,
    /// Blocks actually inserted.
    pub insertions: u64,
    /// Candidate inserts the admission layer allowed (see
    /// [`crate::cache::admission::AdmissionStats`]; always 0-rejected under
    /// the default `always` admission).
    pub admitted: u64,
    /// Candidate inserts the admission layer refused.
    pub rejected: u64,
}

impl ShardStats {
    /// Add `other`'s counters into `self` (shard -> node -> cluster rollup).
    pub fn merge(&mut self, other: &ShardStats) {
        self.requests += other.requests;
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
        self.insertions += other.insertions;
        self.admitted += other.admitted;
        self.rejected += other.rejected;
    }

    /// `hits / requests` (0 when no requests were made).
    pub fn hit_ratio(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.hits as f64 / self.requests as f64
        }
    }
}

/// One seqlock-consistent view of a shard: its access counters plus the
/// occupancy mirrors, all read in the same critical section so
/// `used <= capacity` and `hits + misses == requests` hold together.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardSnapshot {
    /// The shard's access counters.
    pub stats: ShardStats,
    /// Bytes cached on the shard (mirror of `BlockCache::used`).
    pub used: u64,
    /// Blocks cached on the shard (mirror of `BlockCache::len`).
    pub blocks: u64,
}

/// The lock-free stats block of one shard.
///
/// Aligned to two cache lines so adjacent shards' blocks never share a
/// line (the writers are per-shard hot paths; false sharing between them
/// would reintroduce the contention the split removes).
///
/// Single-writer discipline: a write section may only be opened by a
/// thread holding the owning shard's `Mutex`. Readers are unrestricted.
///
/// The seqlock protocol is modeled exhaustively by loom in
/// rust/tests/loom_protocols.rs (see docs/CONCURRENCY.md).
#[derive(Debug)]
#[repr(align(128))]
pub struct AtomicShardStats {
    /// Seqlock word: odd while a write section is open, bumped to the next
    /// even value when it closes. Readers retry until they bracket their
    /// counter reads with the same even value.
    seq: AtomicU64,
    requests: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    insertions: AtomicU64,
    admitted: AtomicU64,
    rejected: AtomicU64,
    used: AtomicU64,
    blocks: AtomicU64,
    /// Hits resolved on the lock-free read path (`cache::read_path`),
    /// counted at *read* time. Deliberately outside the seqlock's
    /// single-writer discipline: many reader threads bump it with a relaxed
    /// RMW, and [`AtomicShardStats::snapshot`] folds it into both `hits`
    /// and `requests`, preserving `hits + misses == requests` exactly.
    lockfree_hits: AtomicU64,
}

impl Default for AtomicShardStats {
    fn default() -> Self {
        Self::new()
    }
}

impl AtomicShardStats {
    /// Zeroed stats block.
    ///
    /// Spelled out field-by-field (instead of `#[derive(Default)]`)
    /// because loom's atomics do not implement `Default`.
    pub fn new() -> Self {
        Self {
            seq: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            used: AtomicU64::new(0),
            blocks: AtomicU64::new(0),
            lockfree_hits: AtomicU64::new(0),
        }
    }

    /// Count one hit resolved on the lock-free read path. Unlike every
    /// other mutator this needs **no** write section and no shard lock:
    /// the counter is a multi-writer relaxed RMW that snapshots fold into
    /// `hits`/`requests` at read time, so a buffered hit is visible in the
    /// merged totals the moment it happens — not when its recency update
    /// drains (property-tested in rust/tests/property_read_path.rs).
    pub fn record_lockfree_hit(&self) {
        self.lockfree_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Open a write section. The caller MUST hold the owning shard's lock
    /// (single writer); the section closes when the guard drops.
    pub fn write(&self) -> StatsWrite<'_> {
        // AcqRel: the Acquire half pins the section's (relaxed) counter
        // stores *after* the odd-store, so a reader that saw an even `seq`
        // cannot have raced an in-flight section; the Release half pairs
        // with the reader's Acquire load for the previous section's data.
        let prev = self.seq.fetch_add(1, Ordering::AcqRel);
        debug_assert_eq!(prev & 1, 0, "nested/concurrent stats write section");
        StatsWrite { stats: self }
    }

    /// A consistent snapshot of every counter — lock-free; spins only
    /// while a writer is inside its (non-blocking, constant-work) write
    /// section.
    pub fn snapshot(&self) -> ShardSnapshot {
        loop {
            // Acquire: pairs with the writer's Release close so the
            // counter loads below observe (at least) every store of the
            // section that published this even value.
            let s1 = self.seq.load(Ordering::Acquire);
            if s1 & 1 == 1 {
                hint::spin_loop();
                continue;
            }
            let mut snap = ShardSnapshot {
                stats: ShardStats {
                    requests: self.requests.load(Ordering::Relaxed),
                    hits: self.hits.load(Ordering::Relaxed),
                    misses: self.misses.load(Ordering::Relaxed),
                    evictions: self.evictions.load(Ordering::Relaxed),
                    insertions: self.insertions.load(Ordering::Relaxed),
                    admitted: self.admitted.load(Ordering::Relaxed),
                    rejected: self.rejected.load(Ordering::Relaxed),
                },
                used: self.used.load(Ordering::Relaxed),
                blocks: self.blocks.load(Ordering::Relaxed),
            };
            // Read-path hits live outside the seqlock (multi-writer RMW):
            // one load, folded into both sides of `hits + misses ==
            // requests`, so the invariant holds for any interleaving with
            // concurrent lock-free hits.
            let lf = self.lockfree_hits.load(Ordering::Relaxed);
            // Acquire fence: orders the counter loads before the `seq`
            // re-check — if no write section opened in between, the loads
            // all came from the same even-sequence state (the re-check
            // load itself can then be Relaxed).
            fence(Ordering::Acquire);
            if self.seq.load(Ordering::Relaxed) == s1 {
                snap.stats.hits += lf;
                snap.stats.requests += lf;
                return snap;
            }
            hint::spin_loop();
        }
    }

    /// The access counters only (one consistent snapshot).
    pub fn stats(&self) -> ShardStats {
        self.snapshot().stats
    }
}

/// RAII seqlock write section over an [`AtomicShardStats`]. All mutators
/// are relaxed stores — the seqlock fences on open/close publish them.
pub struct StatsWrite<'a> {
    stats: &'a AtomicShardStats,
}

impl StatsWrite<'_> {
    fn bump(counter: &AtomicU64, by: u64) {
        // Single writer: a plain load+store (not an RMW) is enough.
        counter.store(counter.load(Ordering::Relaxed) + by, Ordering::Relaxed);
    }

    /// Record one request: a hit, or a miss with `inserted`/`evicted`
    /// bookkeeping.
    pub fn record_request(&mut self, hit: bool, inserted: bool, evicted: u64) {
        Self::bump(&self.stats.requests, 1);
        if hit {
            Self::bump(&self.stats.hits, 1);
        } else {
            Self::bump(&self.stats.misses, 1);
            Self::bump(&self.stats.insertions, u64::from(inserted));
        }
        Self::bump(&self.stats.evictions, evicted);
    }

    /// Mirror the shard cache's admission counters (absolute values — the
    /// admission layer owns the running totals).
    pub fn set_admission(&mut self, admitted: u64, rejected: u64) {
        self.stats.admitted.store(admitted, Ordering::Relaxed);
        self.stats.rejected.store(rejected, Ordering::Relaxed);
    }

    /// Mirror the shard cache's occupancy (absolute values).
    pub fn set_occupancy(&mut self, used: u64, blocks: u64) {
        self.stats.used.store(used, Ordering::Relaxed);
        self.stats.blocks.store(blocks, Ordering::Relaxed);
    }

    /// Zero the access counters (occupancy mirrors are left alone — the
    /// cached contents survive a stats reset). Callers must be quiescent
    /// with respect to lock-free readers, exactly like every other stats
    /// reset: a read-path hit racing the reset may survive it.
    pub fn reset_counters(&mut self) {
        self.stats.requests.store(0, Ordering::Relaxed);
        self.stats.hits.store(0, Ordering::Relaxed);
        self.stats.misses.store(0, Ordering::Relaxed);
        self.stats.evictions.store(0, Ordering::Relaxed);
        self.stats.insertions.store(0, Ordering::Relaxed);
        self.stats.admitted.store(0, Ordering::Relaxed);
        self.stats.rejected.store(0, Ordering::Relaxed);
        self.stats.lockfree_hits.store(0, Ordering::Relaxed);
    }
}

impl Drop for StatsWrite<'_> {
    fn drop(&mut self) {
        // Release: publishes the section's counter stores before the even
        // `seq` value — a reader that brackets its loads with this value
        // (Acquire load + Acquire fence) sees the whole section or none.
        let prev = self.stats.seq.fetch_add(1, Ordering::Release);
        debug_assert_eq!(prev & 1, 1, "stats write section closed twice");
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::util::sync::atomic::AtomicBool;

    #[test]
    fn merge_and_hit_ratio() {
        let mut a = ShardStats { requests: 10, hits: 4, misses: 6, ..Default::default() };
        let b = ShardStats { requests: 2, hits: 2, misses: 0, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.requests, 12);
        assert_eq!(a.hits, 6);
        assert!((a.hit_ratio() - 0.5).abs() < 1e-12);
        assert_eq!(ShardStats::default().hit_ratio(), 0.0);
    }

    #[test]
    fn write_sections_accumulate_and_snapshot_consistently() {
        let block = AtomicShardStats::new();
        {
            let mut w = block.write();
            w.record_request(false, true, 0);
            w.set_occupancy(1, 1);
        }
        {
            let mut w = block.write();
            w.record_request(true, true, 0);
        }
        {
            let mut w = block.write();
            w.record_request(false, true, 1);
            w.set_occupancy(1, 1);
            w.set_admission(2, 1);
        }
        let snap = block.snapshot();
        assert_eq!(snap.stats.requests, 3);
        assert_eq!(snap.stats.hits, 1);
        assert_eq!(snap.stats.misses, 2);
        assert_eq!(snap.stats.insertions, 2);
        assert_eq!(snap.stats.evictions, 1);
        assert_eq!(snap.stats.admitted, 2);
        assert_eq!(snap.stats.rejected, 1);
        assert_eq!(snap.used, 1);
        assert_eq!(snap.blocks, 1);
        assert_eq!(block.stats(), snap.stats);
    }

    #[test]
    fn reset_keeps_occupancy_mirrors() {
        let block = AtomicShardStats::new();
        {
            let mut w = block.write();
            w.record_request(false, true, 0);
            w.set_occupancy(7, 3);
        }
        {
            let mut w = block.write();
            w.reset_counters();
        }
        let snap = block.snapshot();
        assert_eq!(snap.stats, ShardStats::default());
        assert_eq!(snap.used, 7, "reset must keep contents mirrors");
        assert_eq!(snap.blocks, 3);
    }

    #[test]
    fn lockfree_hits_fold_into_both_sides_of_the_invariant() {
        let block = AtomicShardStats::new();
        {
            let mut w = block.write();
            w.record_request(false, true, 0);
        }
        block.record_lockfree_hit();
        block.record_lockfree_hit();
        let s = block.stats();
        assert_eq!(s.requests, 3, "a read-path hit is a request at read time");
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits + s.misses, s.requests);
        {
            let mut w = block.write();
            w.reset_counters();
        }
        assert_eq!(block.stats(), ShardStats::default(), "reset clears read-path hits too");
    }

    /// One writer thread, many reader threads: every snapshot must be
    /// internally consistent even while writes are in flight.
    #[test]
    fn concurrent_readers_never_observe_torn_counters() {
        let block = AtomicShardStats::new();
        let writes: u64 = 20_000;
        let stop = AtomicBool::new(false);
        std::thread::scope(|scope| {
            let block = &block;
            let stop_ref = &stop;
            let readers: Vec<_> = (0..3)
                .map(|_| {
                    scope.spawn(move || {
                        let mut seen = 0u64;
                        let mut last_requests = 0u64;
                        // Acquire: pairs with the Release store below so
                        // the last iteration sees final writer state.
                        while !stop_ref.load(Ordering::Acquire) {
                            let s = block.snapshot();
                            assert_eq!(
                                s.stats.hits + s.stats.misses,
                                s.stats.requests,
                                "torn snapshot"
                            );
                            assert!(s.stats.requests >= last_requests, "requests went back");
                            assert_eq!(s.used, s.stats.requests % 5, "mirror out of section");
                            last_requests = s.stats.requests;
                            seen += 1;
                        }
                        seen
                    })
                })
                .collect();
            for i in 0..writes {
                let mut w = block.write();
                w.record_request(i % 3 == 0, true, 0);
                w.set_occupancy((i + 1) % 5, 1);
            }
            // Release: everything written above happens-before a reader
            // observing the stop flag.
            stop.store(true, Ordering::Release);
            for r in readers {
                assert!(r.join().unwrap() > 0, "reader never got a snapshot");
            }
        });
        let snap = block.snapshot();
        assert_eq!(snap.stats.requests, writes);
        assert_eq!(snap.stats.hits + snap.stats.misses, writes);
    }
}
