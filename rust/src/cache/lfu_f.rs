//! LFU-F (PacMan): frequency-based eviction aimed at cluster efficiency,
//! preferring incomplete files and using the same window-based aging pass
//! as LIFE to avoid cache pollution (paper §3.1 / [8]).

use std::collections::HashMap;

use crate::hdfs::BlockId;
use crate::sim::{SimDuration, SimTime};

use super::{AccessContext, CachePolicy};

#[derive(Debug, Clone)]
struct Entry {
    complete: bool,
    last_access: SimTime,
    accesses: u64,
}

/// LFU-F: frequency-based eviction that protects incomplete files
/// inside the aging window (all-or-nothing file caching pressure).
#[derive(Debug)]
pub struct LfuF {
    entries: HashMap<BlockId, Entry>,
    window: SimDuration,
}

impl LfuF {
    /// Policy with the given aging window.
    pub fn new(window: SimDuration) -> Self {
        LfuF { entries: HashMap::new(), window }
    }
}

impl CachePolicy for LfuF {
    fn name(&self) -> &'static str {
        "lfu-f"
    }

    fn on_hit(&mut self, block: BlockId, ctx: &AccessContext) {
        let e = self.entries.get_mut(&block).expect("hit on untracked block");
        e.accesses += 1;
        e.last_access = ctx.time;
        e.complete = ctx.file_complete;
    }

    fn on_insert(&mut self, block: BlockId, ctx: &AccessContext) {
        debug_assert!(!self.entries.contains_key(&block), "double insert");
        self.entries.insert(
            block,
            Entry { complete: ctx.file_complete, last_access: ctx.time, accesses: 1 },
        );
    }

    fn choose_victim(&mut self, now: SimTime) -> Option<BlockId> {
        if self.entries.is_empty() {
            return None;
        }
        // Window aging first (same anti-pollution pass as LIFE).
        let aged = self
            .entries
            .iter()
            .filter(|(_, e)| e.last_access.duration_until(now) >= self.window)
            .min_by_key(|(b, e)| (e.accesses, e.last_access, **b));
        if let Some((b, _)) = aged {
            return Some(*b);
        }
        // LFU-F proper: incomplete files first, then least frequent access.
        self.entries
            .iter()
            .min_by_key(|(b, e)| (e.complete, e.accesses, e.last_access, **b))
            .map(|(b, _)| *b)
    }

    fn on_evict(&mut self, block: BlockId) {
        self.entries.remove(&block);
    }

    fn len(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(t: u64, complete: bool) -> AccessContext {
        let mut c = AccessContext::simple(SimTime(t), 1);
        c.file_complete = complete;
        c
    }

    #[test]
    fn evicts_least_frequent() {
        let mut p = LfuF::new(SimDuration(1_000_000));
        p.on_insert(BlockId(1), &ctx(1, false));
        p.on_insert(BlockId(2), &ctx(2, false));
        p.on_hit(BlockId(1), &ctx(3, false));
        assert_eq!(p.choose_victim(SimTime(4)), Some(BlockId(2)));
    }

    #[test]
    fn incomplete_prioritized_over_frequency() {
        let mut p = LfuF::new(SimDuration(1_000_000));
        p.on_insert(BlockId(1), &ctx(1, true)); // complete, freq 1
        p.on_insert(BlockId(2), &ctx(2, false)); // incomplete, freq 3
        p.on_hit(BlockId(2), &ctx(3, false));
        p.on_hit(BlockId(2), &ctx(4, false));
        assert_eq!(p.choose_victim(SimTime(5)), Some(BlockId(2)));
    }

    #[test]
    fn aged_blocks_evicted_first() {
        let mut p = LfuF::new(SimDuration(100));
        p.on_insert(BlockId(1), &ctx(0, false));
        p.on_insert(BlockId(2), &ctx(0, false));
        for t in [50, 90, 130, 170] {
            p.on_hit(BlockId(2), &ctx(t, false));
        }
        p.on_hit(BlockId(1), &ctx(60, false));
        // At t=200, block 1 (last access 60) is outside the window.
        assert_eq!(p.choose_victim(SimTime(200)), Some(BlockId(1)));
    }
}
