//! Classic LRU — the paper's baseline (H-LRU scenario).
//!
//! Implemented as the "ordered dictionary" the paper describes (§4.2): an
//! intrusive [`OrderList`] (least recently used at the front) plus a
//! block → handle map. Every touch is an O(1) allocation-free
//! `move_to_back`; the BTreeMap re-keying the original implementation paid
//! per access is gone (parity property-tested in
//! rust/tests/property_orderlist.rs). Victim = the least recently used
//! block (the "top" of the paper's cache picture).

use crate::util::fasthash::IdHashMap;

use crate::hdfs::BlockId;
use crate::sim::SimTime;

use super::order_list::{OrderHandle, OrderList};
use super::{AccessContext, CachePolicy};

/// Classic least-recently-used replacement (the paper's H-LRU baseline).
#[derive(Debug, Default)]
pub struct Lru {
    /// Eviction order: front = least recently used.
    order: OrderList<BlockId>,
    /// block -> its live order handle.
    index: IdHashMap<BlockId, OrderHandle>,
}

impl Lru {
    /// Create an empty LRU policy.
    pub fn new() -> Self {
        Self::default()
    }

    fn touch(&mut self, block: BlockId) {
        if let Some(&handle) = self.index.get(&block) {
            self.order.move_to_back(handle);
        } else {
            let handle = self.order.push_back(block);
            self.index.insert(block, handle);
        }
    }

    /// Eviction order, least-recently-used first (test/diagnostic helper).
    pub fn eviction_order(&self) -> Vec<BlockId> {
        self.order.iter().collect()
    }
}

impl CachePolicy for Lru {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn on_hit(&mut self, block: BlockId, _ctx: &AccessContext) {
        debug_assert!(self.index.contains_key(&block), "hit on untracked block");
        self.touch(block);
    }

    fn on_insert(&mut self, block: BlockId, _ctx: &AccessContext) {
        debug_assert!(!self.index.contains_key(&block), "double insert");
        self.touch(block);
    }

    fn choose_victim(&mut self, _now: SimTime) -> Option<BlockId> {
        self.order.front()
    }

    fn victim_candidates(&mut self, _now: SimTime, k: usize) -> Vec<BlockId> {
        self.order.iter().take(k).collect()
    }

    fn on_evict(&mut self, block: BlockId) {
        if let Some(handle) = self.index.remove(&block) {
            self.order.unlink(handle);
        }
    }

    fn len(&self) -> usize {
        self.index.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(t: u64) -> AccessContext {
        AccessContext::simple(SimTime(t), 1)
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut lru = Lru::new();
        for i in 0..3 {
            lru.on_insert(BlockId(i), &ctx(i));
        }
        lru.on_hit(BlockId(0), &ctx(10)); // 0 becomes MRU
        assert_eq!(lru.choose_victim(SimTime(11)), Some(BlockId(1)));
        lru.on_evict(BlockId(1));
        assert_eq!(lru.choose_victim(SimTime(12)), Some(BlockId(2)));
        assert_eq!(lru.len(), 2);
    }

    #[test]
    fn eviction_order_tracks_recency() {
        let mut lru = Lru::new();
        for i in 0..4 {
            lru.on_insert(BlockId(i), &ctx(i));
        }
        lru.on_hit(BlockId(1), &ctx(5));
        assert_eq!(
            lru.eviction_order(),
            vec![BlockId(0), BlockId(2), BlockId(3), BlockId(1)]
        );
    }

    #[test]
    fn empty_has_no_victim() {
        let mut lru = Lru::new();
        assert_eq!(lru.choose_victim(SimTime(0)), None);
        assert!(lru.is_empty());
    }

    #[test]
    fn long_churn_is_allocation_free() {
        // Steady-state touch/insert/evict cycles must reuse slab slots.
        let mut lru = Lru::new();
        for i in 0..16u64 {
            lru.on_insert(BlockId(i), &ctx(i));
        }
        for t in 16..5_000u64 {
            let victim = lru.choose_victim(SimTime(t)).unwrap();
            lru.on_evict(victim);
            lru.on_insert(BlockId(t), &ctx(t));
            lru.on_hit(BlockId(t), &ctx(t));
        }
        assert_eq!(lru.len(), 16);
        assert_eq!(lru.order.slots(), 16, "churn must not grow the slab");
    }
}
