//! Classic LRU — the paper's baseline (H-LRU scenario).
//!
//! Implemented as the "ordered dictionary" the paper describes (§4.2): an
//! order index (monotone counter -> block) plus a reverse map. Victim = the
//! least recently used block (the "top" of the paper's cache picture).

use std::collections::BTreeMap;

use crate::util::fasthash::IdHashMap;

use crate::hdfs::BlockId;
use crate::sim::SimTime;

use super::{AccessContext, CachePolicy};

#[derive(Debug, Default)]
pub struct Lru {
    /// order key -> block, ascending = least recently used first.
    order: BTreeMap<i64, BlockId>,
    /// block -> its current order key.
    index: IdHashMap<BlockId, i64>,
    next: i64,
}

impl Lru {
    pub fn new() -> Self {
        Self::default()
    }

    fn touch(&mut self, block: BlockId) {
        if let Some(old) = self.index.remove(&block) {
            self.order.remove(&old);
        }
        let key = self.next;
        self.next += 1;
        self.order.insert(key, block);
        self.index.insert(block, key);
    }

    /// Eviction order, least-recently-used first (test/diagnostic helper).
    pub fn eviction_order(&self) -> Vec<BlockId> {
        self.order.values().copied().collect()
    }
}

impl CachePolicy for Lru {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn on_hit(&mut self, block: BlockId, _ctx: &AccessContext) {
        debug_assert!(self.index.contains_key(&block), "hit on untracked block");
        self.touch(block);
    }

    fn on_insert(&mut self, block: BlockId, _ctx: &AccessContext) {
        debug_assert!(!self.index.contains_key(&block), "double insert");
        self.touch(block);
    }

    fn choose_victim(&mut self, _now: SimTime) -> Option<BlockId> {
        self.order.values().next().copied()
    }

    fn on_evict(&mut self, block: BlockId) {
        if let Some(key) = self.index.remove(&block) {
            self.order.remove(&key);
        }
    }

    fn len(&self) -> usize {
        self.index.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(t: u64) -> AccessContext {
        AccessContext::simple(SimTime(t), 1)
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut lru = Lru::new();
        for i in 0..3 {
            lru.on_insert(BlockId(i), &ctx(i));
        }
        lru.on_hit(BlockId(0), &ctx(10)); // 0 becomes MRU
        assert_eq!(lru.choose_victim(SimTime(11)), Some(BlockId(1)));
        lru.on_evict(BlockId(1));
        assert_eq!(lru.choose_victim(SimTime(12)), Some(BlockId(2)));
        assert_eq!(lru.len(), 2);
    }

    #[test]
    fn eviction_order_tracks_recency() {
        let mut lru = Lru::new();
        for i in 0..4 {
            lru.on_insert(BlockId(i), &ctx(i));
        }
        lru.on_hit(BlockId(1), &ctx(5));
        assert_eq!(
            lru.eviction_order(),
            vec![BlockId(0), BlockId(2), BlockId(3), BlockId(1)]
        );
    }

    #[test]
    fn empty_has_no_victim() {
        let mut lru = Lru::new();
        assert_eq!(lru.choose_victim(SimTime(0)), None);
        assert!(lru.is_empty());
    }
}
