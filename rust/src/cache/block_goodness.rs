//! Block-goodness-aware replacement (paper §3.1 / [12]): each cached block
//! carries a *block goodness* (BG) value combining its access count with the
//! cache affinity of the MapReduce application(s) reading it, scaled by how
//! expensive the block is to regenerate (DAG stage outputs carry a nonzero
//! recompute cost; disk-backed blocks contribute a neutral factor of 1).
//! The victim is the block with the lowest BG; ties go to the oldest access
//! time.

use std::collections::HashMap;

use crate::hdfs::BlockId;
use crate::sim::SimTime;

use super::{AccessContext, CachePolicy};

#[derive(Debug, Clone, Copy)]
struct Entry {
    accesses: u64,
    /// Highest affinity weight among apps that touched the block.
    affinity: f64,
    /// Highest recompute cost (seconds) reported for the block.
    recompute_cost: f64,
    last_access: SimTime,
}

impl Entry {
    fn goodness(&self) -> f64 {
        // Zero-cost blocks keep the original accesses x affinity value, so
        // flat traces (which always report cost 0) are unaffected.
        self.accesses as f64 * self.affinity * (1.0 + self.recompute_cost)
    }
}

/// Block-goodness replacement: victim = lowest accesses x affinity x cost.
#[derive(Debug, Default)]
pub struct BlockGoodness {
    entries: HashMap<BlockId, Entry>,
}

impl BlockGoodness {
    /// Create an empty block-goodness policy.
    pub fn new() -> Self {
        Self::default()
    }

    /// Current BG value for `block` (None when untracked).
    pub fn goodness_of(&self, block: BlockId) -> Option<f64> {
        self.entries.get(&block).map(Entry::goodness)
    }
}

impl CachePolicy for BlockGoodness {
    fn name(&self) -> &'static str {
        "block-goodness"
    }

    fn on_hit(&mut self, block: BlockId, ctx: &AccessContext) {
        let e = self.entries.get_mut(&block).expect("hit on untracked block");
        e.accesses += 1;
        e.affinity = e.affinity.max(ctx.affinity.weight());
        e.recompute_cost = e.recompute_cost.max(ctx.recompute_cost);
        e.last_access = ctx.time;
    }

    fn on_insert(&mut self, block: BlockId, ctx: &AccessContext) {
        debug_assert!(!self.entries.contains_key(&block), "double insert");
        self.entries.insert(
            block,
            Entry {
                accesses: 1,
                affinity: ctx.affinity.weight(),
                recompute_cost: ctx.recompute_cost,
                last_access: ctx.time,
            },
        );
    }

    fn choose_victim(&mut self, _now: SimTime) -> Option<BlockId> {
        self.entries
            .iter()
            .min_by(|(ba, ea), (bb, eb)| {
                ea.goodness()
                    .partial_cmp(&eb.goodness())
                    .unwrap()
                    .then(ea.last_access.cmp(&eb.last_access))
                    .then(ba.cmp(bb))
            })
            .map(|(b, _)| *b)
    }

    fn on_evict(&mut self, block: BlockId) {
        self.entries.remove(&block);
    }

    fn len(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheAffinity;

    fn ctx(t: u64, aff: CacheAffinity) -> AccessContext {
        let mut c = AccessContext::simple(SimTime(t), 1);
        c.affinity = aff;
        c
    }

    #[test]
    fn lowest_goodness_is_victim() {
        let mut p = BlockGoodness::new();
        p.on_insert(BlockId(1), &ctx(1, CacheAffinity::High));
        p.on_insert(BlockId(2), &ctx(2, CacheAffinity::Low));
        p.on_insert(BlockId(3), &ctx(3, CacheAffinity::High));
        p.on_hit(BlockId(3), &ctx(4, CacheAffinity::High));
        // BG: 1 -> 1.0, 2 -> 0.25, 3 -> 2.0
        assert_eq!(p.choose_victim(SimTime(5)), Some(BlockId(2)));
        assert!((p.goodness_of(BlockId(3)).unwrap() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn tie_broken_by_oldest_access() {
        let mut p = BlockGoodness::new();
        p.on_insert(BlockId(1), &ctx(1, CacheAffinity::Medium));
        p.on_insert(BlockId(2), &ctx(2, CacheAffinity::Medium));
        // Equal BG -> the oldest access time (block 1) is discarded first,
        // exactly the paper's tiebreak.
        assert_eq!(p.choose_victim(SimTime(3)), Some(BlockId(1)));
    }

    #[test]
    fn recompute_cost_protects_expensive_blocks() {
        let mut p = BlockGoodness::new();
        let costly = |t: u64, cost: f64| {
            let mut c = ctx(t, CacheAffinity::Medium);
            c.recompute_cost = cost;
            c
        };
        // Same affinity and access count; block 2 is expensive to rebuild.
        p.on_insert(BlockId(1), &costly(1, 0.0));
        p.on_insert(BlockId(2), &costly(2, 30.0));
        p.on_insert(BlockId(3), &costly(3, 0.0));
        // BG: 1 -> 0.5, 2 -> 0.5 * 31, 3 -> 0.5; tie between 1 and 3 goes
        // to the oldest access, and 2 outlives both.
        assert_eq!(p.choose_victim(SimTime(4)), Some(BlockId(1)));
        p.on_evict(BlockId(1));
        assert_eq!(p.choose_victim(SimTime(5)), Some(BlockId(3)));
        assert!((p.goodness_of(BlockId(2)).unwrap() - 15.5).abs() < 1e-12);
    }

    #[test]
    fn affinity_upgrades_stick() {
        let mut p = BlockGoodness::new();
        p.on_insert(BlockId(1), &ctx(1, CacheAffinity::Low));
        p.on_hit(BlockId(1), &ctx(2, CacheAffinity::High));
        p.on_hit(BlockId(1), &ctx(3, CacheAffinity::Low));
        // affinity keeps the max seen (1.0); 3 accesses -> BG = 3.0
        assert!((p.goodness_of(BlockId(1)).unwrap() - 3.0).abs() < 1e-12);
    }
}
