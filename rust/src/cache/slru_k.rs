//! Selective LRU-K (Big SQL adaptive caching, paper §3.1 / [11]): keeps the
//! K last access times per block; the victim is the block with the oldest
//! K-th most recent access (classic LRU-K). *Selective insertion* declines
//! to cache blocks on their first sighting unless the cache has plenty of
//! free room — reducing the byte-insertion overhead the paper's authors
//! targeted.
//!
//! ### Victim index
//!
//! LRU-K is the one policy in this crate whose re-ordering is *not* a list
//! discipline: a hit moves a block's K-distance reference to its previously
//! second-oldest access, which can land anywhere in the middle of the
//! order, so an intrusive [`super::order_list::OrderList`] cannot express
//! it. Instead of the original O(n) full scan per `choose_victim`, the
//! victim order is maintained in a `BTreeSet` keyed by
//! `(complete, reference_time, block)`:
//!
//! * `complete = false` (fewer than K recorded accesses ⇒ infinite backward
//!   K-distance) sorts before any complete history — exactly the old
//!   `(complete, score)` tuple ordering;
//! * the old score `1 / (1 + age)` is strictly decreasing in the reference
//!   age, so ascending reference time reproduces ascending score;
//! * ties (equal reference times) fall back to the block id, as before.
//!
//! That makes `choose_victim` O(1) (first element) and each update
//! O(log n), and is access-for-access identical to the old scan for
//! monotone traces (property-tested against the scan implementation in
//! rust/tests/property_orderlist.rs).

use std::collections::{BTreeSet, VecDeque};

use crate::util::fasthash::IdHashMap;

use crate::hdfs::BlockId;
use crate::sim::SimTime;

use super::{AccessContext, CachePolicy};

/// Selective LRU-K: LRU on the K-th most recent access, admitting
/// first-touch blocks only while admissions still fit.
#[derive(Debug)]
pub struct SlruK {
    k: usize,
    /// Cached blocks: last-K access times (most recent at the back).
    entries: IdHashMap<BlockId, VecDeque<SimTime>>,
    /// Victim order: incomplete histories first, then oldest K-th-recent
    /// access; ties by block id (see the module docs).
    victim_order: BTreeSet<(bool, SimTime, BlockId)>,
    /// Access history for *all* blocks, cached or not (for selectivity).
    seen: IdHashMap<BlockId, u64>,
    /// Admit first-touch blocks only if this many admissions still fit.
    selective_threshold: u64,
}

impl SlruK {
    /// Policy tracking the last `k` access times per block (`k >= 1`).
    pub fn new(k: usize) -> Self {
        SlruK {
            k: k.max(1),
            entries: IdHashMap::default(),
            victim_order: BTreeSet::new(),
            seen: IdHashMap::default(),
            selective_threshold: 2,
        }
    }

    /// Victim-order key for a block's access history: incomplete histories
    /// (infinite backward K-distance) first, then the K-th most recent
    /// access time.
    fn order_key(k: usize, times: &VecDeque<SimTime>, block: BlockId) -> (bool, SimTime, BlockId) {
        let complete = times.len() >= k;
        let reference = if complete {
            times[times.len() - k]
        } else {
            *times.back().expect("empty access history")
        };
        (complete, reference, block)
    }
}

impl CachePolicy for SlruK {
    fn name(&self) -> &'static str {
        "slru-k"
    }

    fn on_hit(&mut self, block: BlockId, ctx: &AccessContext) {
        *self.seen.entry(block).or_insert(0) += 1;
        let k = self.k;
        let times = self.entries.get_mut(&block).expect("hit on untracked block");
        let old_key = Self::order_key(k, times, block);
        times.push_back(ctx.time);
        while times.len() > k {
            times.pop_front();
        }
        let new_key = Self::order_key(k, times, block);
        if new_key != old_key {
            self.victim_order.remove(&old_key);
            self.victim_order.insert(new_key);
        }
    }

    fn on_insert(&mut self, block: BlockId, ctx: &AccessContext) {
        debug_assert!(!self.entries.contains_key(&block), "double insert");
        *self.seen.entry(block).or_insert(0) += 1;
        let mut times = VecDeque::with_capacity(self.k);
        times.push_back(ctx.time);
        self.victim_order.insert(Self::order_key(self.k, &times, block));
        self.entries.insert(block, times);
    }

    fn admits(&self, block: BlockId, _ctx: &AccessContext) -> bool {
        // Selective insertion: blocks seen before are always admitted;
        // first-touch blocks are admitted only while the cache is small
        // (bootstrapping) — repeat visitors earn their slot.
        self.seen.contains_key(&block)
            || (self.entries.len() as u64) < self.selective_threshold
    }

    fn choose_victim(&mut self, _now: SimTime) -> Option<BlockId> {
        self.victim_order.first().map(|&(_, _, b)| b)
    }

    fn on_evict(&mut self, block: BlockId) {
        if let Some(times) = self.entries.remove(&block) {
            self.victim_order.remove(&Self::order_key(self.k, &times, block));
        }
    }

    fn len(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(t: u64) -> AccessContext {
        AccessContext::simple(SimTime(t), 1)
    }

    #[test]
    fn victim_is_oldest_kth_access() {
        let mut p = SlruK::new(2);
        p.on_insert(BlockId(1), &ctx(0));
        p.on_insert(BlockId(2), &ctx(1));
        // Block 1 gets a second access (K=2 satisfied, recent);
        // block 2 has only one access -> infinite K-distance -> victim.
        p.on_hit(BlockId(1), &ctx(100));
        assert_eq!(p.choose_victim(SimTime(101)), Some(BlockId(2)));
    }

    #[test]
    fn among_full_histories_older_kth_wins() {
        let mut p = SlruK::new(2);
        p.on_insert(BlockId(1), &ctx(0));
        p.on_hit(BlockId(1), &ctx(10)); // K-dist ref = t0
        p.on_insert(BlockId(2), &ctx(20));
        p.on_hit(BlockId(2), &ctx(30)); // K-dist ref = t20
        assert_eq!(p.choose_victim(SimTime(40)), Some(BlockId(1)));
    }

    #[test]
    fn selective_admission_rejects_cold_first_touch() {
        let mut p = SlruK::new(2);
        // Bootstrap: first two inserts admitted unconditionally.
        p.on_insert(BlockId(1), &ctx(0));
        p.on_insert(BlockId(2), &ctx(1));
        // A brand-new block is declined while the cache is warm...
        assert!(!p.admits(BlockId(3), &ctx(2)));
        // ...but a block we've seen before is admitted.
        assert!(p.admits(BlockId(1), &ctx(3)));
    }

    #[test]
    fn history_caps_at_k() {
        let mut p = SlruK::new(3);
        p.on_insert(BlockId(1), &ctx(0));
        for t in 1..10 {
            p.on_hit(BlockId(1), &ctx(t));
        }
        assert_eq!(p.entries[&BlockId(1)].len(), 3);
    }

    #[test]
    fn victim_index_tracks_population() {
        let mut p = SlruK::new(2);
        for i in 0..8u64 {
            p.on_insert(BlockId(i), &ctx(i));
        }
        for t in 0..20u64 {
            p.on_hit(BlockId(t % 8), &ctx(100 + t));
        }
        assert_eq!(p.victim_order.len(), p.len());
        while let Some(v) = p.choose_victim(SimTime(1000)) {
            p.on_evict(v);
            assert_eq!(p.victim_order.len(), p.len());
        }
        assert!(p.is_empty());
    }

    #[test]
    fn equal_reference_times_tie_break_by_id() {
        let mut p = SlruK::new(1);
        p.on_insert(BlockId(7), &ctx(5));
        p.on_insert(BlockId(3), &ctx(5));
        assert_eq!(p.choose_victim(SimTime(6)), Some(BlockId(3)));
    }
}
