//! Selective LRU-K (Big SQL adaptive caching, paper §3.1 / [11]): keeps the
//! K last access times per block; the victim is the block with the oldest
//! K-th most recent access (classic LRU-K). *Selective insertion* declines
//! to cache blocks on their first sighting unless the cache has plenty of
//! free room — reducing the byte-insertion overhead the paper's authors
//! targeted. A weight heuristic biases against very large partitions.

use std::collections::{HashMap, VecDeque};

use crate::hdfs::BlockId;
use crate::sim::SimTime;

use super::{AccessContext, CachePolicy};

#[derive(Debug)]
pub struct SlruK {
    k: usize,
    /// Cached blocks: last-K access times (most recent at the back).
    entries: HashMap<BlockId, VecDeque<SimTime>>,
    /// Access history for *all* blocks, cached or not (for selectivity).
    seen: HashMap<BlockId, u64>,
    /// Admit first-touch blocks only if this many admissions still fit.
    selective_threshold: u64,
    size_weight: f64,
}

impl SlruK {
    pub fn new(k: usize) -> Self {
        SlruK {
            k: k.max(1),
            entries: HashMap::new(),
            seen: HashMap::new(),
            selective_threshold: 2,
            size_weight: 1.0,
        }
    }

    /// Victim ordering key: smaller = evicted first. Blocks with fewer than
    /// K recorded accesses have infinite backward K-distance (classic
    /// LRU-K) and sort before any complete history; ties fall back to the
    /// last access time.
    fn weight(&self, times: &VecDeque<SimTime>, now: SimTime) -> (bool, f64) {
        let complete = times.len() >= self.k;
        let reference = if complete {
            times[times.len() - self.k]
        } else {
            *times.back().expect("empty access history")
        };
        let age = reference.duration_until(now).as_secs_f64();
        let recency_score = 1.0 / (1.0 + age);
        (complete, recency_score * self.size_weight)
    }
}

impl CachePolicy for SlruK {
    fn name(&self) -> &'static str {
        "slru-k"
    }

    fn on_hit(&mut self, block: BlockId, ctx: &AccessContext) {
        *self.seen.entry(block).or_insert(0) += 1;
        let times = self.entries.get_mut(&block).expect("hit on untracked block");
        times.push_back(ctx.time);
        while times.len() > self.k {
            times.pop_front();
        }
    }

    fn on_insert(&mut self, block: BlockId, ctx: &AccessContext) {
        debug_assert!(!self.entries.contains_key(&block), "double insert");
        *self.seen.entry(block).or_insert(0) += 1;
        let mut times = VecDeque::with_capacity(self.k);
        times.push_back(ctx.time);
        self.entries.insert(block, times);
    }

    fn admits(&self, block: BlockId, _ctx: &AccessContext) -> bool {
        // Selective insertion: blocks seen before are always admitted;
        // first-touch blocks are admitted only while the cache is small
        // (bootstrapping) — repeat visitors earn their slot.
        self.seen.contains_key(&block)
            || (self.entries.len() as u64) < self.selective_threshold
    }

    fn choose_victim(&mut self, now: SimTime) -> Option<BlockId> {
        self.entries
            .iter()
            .min_by(|(ba, ta), (bb, tb)| {
                let wa = self.weight(ta, now);
                let wb = self.weight(tb, now);
                wa.partial_cmp(&wb).unwrap().then(ba.cmp(bb))
            })
            .map(|(b, _)| *b)
    }

    fn on_evict(&mut self, block: BlockId) {
        self.entries.remove(&block);
    }

    fn len(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(t: u64) -> AccessContext {
        AccessContext::simple(SimTime(t), 1)
    }

    #[test]
    fn victim_is_oldest_kth_access() {
        let mut p = SlruK::new(2);
        p.on_insert(BlockId(1), &ctx(0));
        p.on_insert(BlockId(2), &ctx(1));
        // Block 1 gets a second access (K=2 satisfied, recent);
        // block 2 has only one access -> infinite K-distance -> victim.
        p.on_hit(BlockId(1), &ctx(100));
        assert_eq!(p.choose_victim(SimTime(101)), Some(BlockId(2)));
    }

    #[test]
    fn among_full_histories_older_kth_wins() {
        let mut p = SlruK::new(2);
        p.on_insert(BlockId(1), &ctx(0));
        p.on_hit(BlockId(1), &ctx(10)); // K-dist ref = t0
        p.on_insert(BlockId(2), &ctx(20));
        p.on_hit(BlockId(2), &ctx(30)); // K-dist ref = t20
        assert_eq!(p.choose_victim(SimTime(40)), Some(BlockId(1)));
    }

    #[test]
    fn selective_admission_rejects_cold_first_touch() {
        let mut p = SlruK::new(2);
        // Bootstrap: first two inserts admitted unconditionally.
        p.on_insert(BlockId(1), &ctx(0));
        p.on_insert(BlockId(2), &ctx(1));
        // A brand-new block is declined while the cache is warm...
        assert!(!p.admits(BlockId(3), &ctx(2)));
        // ...but a block we've seen before is admitted.
        assert!(p.admits(BlockId(1), &ctx(3)));
    }

    #[test]
    fn history_caps_at_k() {
        let mut p = SlruK::new(3);
        p.on_insert(BlockId(1), &ctx(0));
        for t in 1..10 {
            p.on_hit(BlockId(1), &ctx(t));
        }
        assert_eq!(p.entries[&BlockId(1)].len(), 3);
    }
}
