//! Cache replacement policies.
//!
//! `CachePolicy` is the pluggable eviction-order interface; `BlockCache`
//! owns capacity accounting and drives a policy. Implemented policies (the
//! paper's Table 1 survey plus the contribution itself):
//!
//! | module            | strategy |
//! |-------------------|----------|
//! | `lru`             | classic LRU (the paper's baseline) |
//! | `hsvmlru`         | **H-SVM-LRU** — Algorithm 1, class-aware LRU |
//! | `fifo`            | insertion order (sanity baseline) |
//! | `lfu`             | least frequently used |
//! | `life`            | PacMan LIFE: largest wave-width first |
//! | `lfu_f`           | PacMan LFU-F: window-aged frequency |
//! | `wsclock`         | EDACHE WSClock: ref-bit clock with age threshold |
//! | `arc`             | Modified ARC: recent/frequent + ghost histories |
//! | `slru_k`          | Selective LRU-K |
//! | `exd`             | Exponential-Decay score |
//! | `block_goodness`  | block-goodness (affinity x access count) |
//! | `affinity_aware`  | cache-affinity-aware caching benefit |
//! | `autocache`       | AutoCache-style probability score + watermarks |

pub mod affinity_aware;
pub mod arc;
pub mod autocache;
pub mod block_goodness;
pub mod exd;
pub mod fifo;
pub mod hsvmlru;
pub mod life;
pub mod lfu;
pub mod lfu_f;
pub mod lru;
pub mod registry;
pub mod sharded;
pub mod slru_k;
pub mod wsclock;

pub use sharded::{shard_of, ShardStats, ShardedCache};

use crate::util::fasthash::IdHashMap;

use crate::hdfs::{BlockId, BlockKind};
use crate::sim::SimTime;

/// Cache affinity of the requesting application (paper §6.4.2, from [12]):
/// how much the application benefits from cached data.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum CacheAffinity {
    Low,
    Medium,
    High,
}

impl CacheAffinity {
    /// Numeric weight used by affinity-driven policies and the SVM features.
    pub fn weight(self) -> f64 {
        match self {
            CacheAffinity::Low => 0.25,
            CacheAffinity::Medium => 0.5,
            CacheAffinity::High => 1.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CacheAffinity::Low => "low",
            CacheAffinity::Medium => "medium",
            CacheAffinity::High => "high",
        }
    }
}

/// Per-access context handed to policies (the features different strategies
/// key on; unneeded fields are ignored by simpler policies).
#[derive(Debug, Clone)]
pub struct AccessContext {
    pub time: SimTime,
    pub size: u64,
    pub kind: BlockKind,
    /// Owning file and its "wave width" (blocks processed concurrently —
    /// LIFE/LFU-F eviction criterion).
    pub file: u64,
    pub file_width: u32,
    /// Whether all tasks reading this file have completed.
    pub file_complete: bool,
    /// Cache affinity of the application issuing the access.
    pub affinity: CacheAffinity,
    /// SVM-predicted class: Some(true) = "reused in the future".
    /// Filled by the coordinator for H-SVM-LRU (and AutoCache's score).
    pub predicted_reuse: Option<bool>,
}

impl AccessContext {
    /// A minimal context for unit tests and trace replay.
    pub fn simple(time: SimTime, size: u64) -> Self {
        AccessContext {
            time,
            size,
            kind: BlockKind::Input,
            file: 0,
            file_width: 1,
            file_complete: false,
            affinity: CacheAffinity::Medium,
            predicted_reuse: None,
        }
    }

    pub fn with_prediction(mut self, reuse: bool) -> Self {
        self.predicted_reuse = Some(reuse);
        self
    }
}

/// Eviction-order policy. The `BlockCache` guarantees the call protocol:
/// `on_insert` for blocks not present, `on_hit` for present blocks,
/// `choose_victim`/`on_evict` pairs while space is needed.
pub trait CachePolicy: Send {
    fn name(&self) -> &'static str;

    /// A cached block was accessed again.
    fn on_hit(&mut self, block: BlockId, ctx: &AccessContext);

    /// A block was inserted into the cache.
    fn on_insert(&mut self, block: BlockId, ctx: &AccessContext);

    /// Pick the next victim (must be a currently tracked block). The policy
    /// must NOT forget the block yet — `on_evict` confirms.
    fn choose_victim(&mut self, now: SimTime) -> Option<BlockId>;

    /// The chosen victim (or an externally uncached block) left the cache.
    fn on_evict(&mut self, block: BlockId);

    /// Number of tracked blocks (must equal the cache's block count).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether the policy admits this block at all (selective insertion —
    /// SLRU-K/AutoCache decline some inserts). Default: admit everything.
    fn admits(&self, _block: BlockId, _ctx: &AccessContext) -> bool {
        true
    }
}

/// Outcome of a cache access through `BlockCache::access_or_insert`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AccessOutcome {
    pub hit: bool,
    /// Blocks evicted to make room (empty on hits).
    pub evicted: Vec<BlockId>,
    /// Whether the block is cached after the access (false when the policy
    /// declined admission or the block exceeds capacity).
    pub inserted: bool,
}

/// Capacity-accounted cache driving a `CachePolicy`.
pub struct BlockCache {
    policy: Box<dyn CachePolicy>,
    capacity: u64,
    used: u64,
    sizes: IdHashMap<BlockId, u64>,
}

impl BlockCache {
    pub fn new(policy: Box<dyn CachePolicy>, capacity: u64) -> Self {
        BlockCache { policy, capacity, used: 0, sizes: IdHashMap::default() }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn free(&self) -> u64 {
        self.capacity - self.used
    }

    pub fn len(&self) -> usize {
        self.sizes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sizes.is_empty()
    }

    pub fn contains(&self, block: BlockId) -> bool {
        self.sizes.contains_key(&block)
    }

    pub fn cached_blocks(&self) -> Vec<BlockId> {
        let mut v: Vec<BlockId> = self.sizes.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// The full access path: hit (policy notified) or miss + insertion with
    /// evictions as needed. Mirrors GetCache/PutCache at the cache level.
    pub fn access_or_insert(&mut self, block: BlockId, ctx: &AccessContext) -> AccessOutcome {
        if self.sizes.contains_key(&block) {
            self.policy.on_hit(block, ctx);
            debug_assert_eq!(self.policy.len(), self.sizes.len());
            return AccessOutcome { hit: true, evicted: Vec::new(), inserted: true };
        }
        let evicted = self.insert(block, ctx);
        let inserted = self.sizes.contains_key(&block);
        AccessOutcome { hit: false, evicted, inserted }
    }

    /// Insert a missing block, evicting per policy until it fits. Returns
    /// the evicted blocks. Oversized or policy-declined blocks are skipped.
    pub fn insert(&mut self, block: BlockId, ctx: &AccessContext) -> Vec<BlockId> {
        assert!(!self.sizes.contains_key(&block), "insert of cached block");
        let mut evicted = Vec::new();
        if ctx.size > self.capacity || !self.policy.admits(block, ctx) {
            return evicted;
        }
        while self.used + ctx.size > self.capacity {
            match self.policy.choose_victim(ctx.time) {
                Some(victim) => {
                    self.policy.on_evict(victim);
                    let size = self.sizes.remove(&victim).expect("victim not in cache");
                    self.used -= size;
                    evicted.push(victim);
                }
                None => return evicted, // policy refuses to evict
            }
        }
        self.policy.on_insert(block, ctx);
        self.sizes.insert(block, ctx.size);
        self.used += ctx.size;
        debug_assert_eq!(self.policy.len(), self.sizes.len());
        evicted
    }

    /// Externally remove a block (user uncache directive).
    pub fn remove(&mut self, block: BlockId) -> bool {
        match self.sizes.remove(&block) {
            Some(size) => {
                self.used -= size;
                self.policy.on_evict(block);
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::lru::Lru;
    use super::*;

    fn ctx(t: u64, size: u64) -> AccessContext {
        AccessContext::simple(SimTime(t), size)
    }

    #[test]
    fn hit_miss_and_eviction_accounting() {
        let mut cache = BlockCache::new(Box::new(Lru::new()), 300);
        let o = cache.access_or_insert(BlockId(1), &ctx(1, 100));
        assert!(!o.hit && o.inserted && o.evicted.is_empty());
        let o = cache.access_or_insert(BlockId(2), &ctx(2, 100));
        assert!(!o.hit);
        let o = cache.access_or_insert(BlockId(1), &ctx(3, 100));
        assert!(o.hit);
        // 3rd distinct block fits exactly; 4th forces the LRU victim (2).
        cache.access_or_insert(BlockId(3), &ctx(4, 100));
        let o = cache.access_or_insert(BlockId(4), &ctx(5, 100));
        assert_eq!(o.evicted, vec![BlockId(2)]);
        assert_eq!(cache.used(), 300);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn oversized_block_is_not_cached() {
        let mut cache = BlockCache::new(Box::new(Lru::new()), 100);
        let o = cache.access_or_insert(BlockId(1), &ctx(1, 500));
        assert!(!o.hit && !o.inserted);
        assert_eq!(cache.used(), 0);
    }

    #[test]
    fn remove_frees_space() {
        let mut cache = BlockCache::new(Box::new(Lru::new()), 100);
        cache.access_or_insert(BlockId(1), &ctx(1, 60));
        assert!(cache.remove(BlockId(1)));
        assert!(!cache.remove(BlockId(1)));
        assert_eq!(cache.used(), 0);
        assert!(cache.is_empty());
    }

    #[test]
    fn affinity_weights_ordered() {
        assert!(CacheAffinity::High.weight() > CacheAffinity::Medium.weight());
        assert!(CacheAffinity::Medium.weight() > CacheAffinity::Low.weight());
    }
}
