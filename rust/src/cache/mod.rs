//! Cache replacement policies.
//!
//! `CachePolicy` is the pluggable eviction-order interface; `BlockCache`
//! owns capacity accounting and drives a policy. Implemented policies (the
//! paper's Table 1 survey plus the contribution itself):
//!
//! | module            | strategy |
//! |-------------------|----------|
//! | `lru`             | classic LRU (the paper's baseline) |
//! | `hsvmlru`         | **H-SVM-LRU** — Algorithm 1, class-aware LRU |
//! | `fifo`            | insertion order (sanity baseline) |
//! | `lfu`             | least frequently used |
//! | `life`            | PacMan LIFE: largest wave-width first |
//! | `lfu_f`           | PacMan LFU-F: window-aged frequency |
//! | `wsclock`         | EDACHE WSClock: ref-bit clock with age threshold |
//! | `arc`             | Modified ARC: recent/frequent + ghost histories |
//! | `slru_k`          | Selective LRU-K |
//! | `exd`             | Exponential-Decay score |
//! | `block_goodness`  | block-goodness (affinity x access count x recompute cost) |
//! | `affinity_aware`  | cache-affinity-aware caching benefit |
//! | `autocache`       | AutoCache-style probability score + watermarks |
//! | `cost_aware`      | recompute-cost re-ranking wrapper (`lru-cost`, `lfu-cost`, `arc-cost`) |
//!
//! In front of any policy sits an [`admission`] layer
//! ([`admission::AdmissionPolicy`]): insert-time pollution control that can
//! refuse to cache a block at all (`always` / `tinylfu` / `ghost` / `svm`).
//! The default `always` admits everything and is bit-identical to a cache
//! without the layer.
//!
//! The list-ordered policies (`lru`, `hsvmlru`, `fifo`, `arc`, the
//! admission ghost) keep their eviction order in
//! [`order_list::OrderList`], a slab-backed intrusive doubly-linked list:
//! O(1) allocation-free touch/insert/evict on the replay hot path.
//! `lfu` runs on O(1) frequency buckets built from the same list (an
//! ordered chain of per-frequency `OrderList`s).

/// Insert-time admission policies (pollution control in front of eviction).
pub mod admission;
/// Cache-affinity-aware caching benefit policy.
pub mod affinity_aware;
/// One-stop cache construction (`CacheBuilder`) replacing the constructor
/// sprawl on `BlockCache`/`ShardedCache`.
pub mod builder;
/// Modified ARC: recent/frequent lists with ghost histories.
pub mod arc;
/// AutoCache-style probability score with high/low watermarks.
pub mod autocache;
/// Block-goodness score: affinity × access count × recompute cost.
pub mod block_goodness;
/// Recompute-cost re-ranking wrapper around any base policy.
pub mod cost_aware;
/// Exponential-decay score policy.
pub mod exd;
/// Insertion-order FIFO baseline.
pub mod fifo;
/// H-SVM-LRU — the paper's Algorithm 1 (class-aware two-region LRU).
pub mod hsvmlru;
/// PacMan LIFE: largest wave-width first.
pub mod life;
/// Least-frequently-used with O(1) frequency buckets.
pub mod lfu;
/// PacMan LFU-F: window-aged frequency.
pub mod lfu_f;
/// Classic LRU (the paper's baseline).
pub mod lru;
/// Slab-backed intrusive doubly-linked list used by the ordered policies.
pub mod order_list;
/// Lock-free membership read path + recency batching (seqlock read-view).
pub mod read_path;
/// Name → policy constructor registry (`POLICY_NAMES` / `make_policy`).
pub mod registry;
/// Lock-free per-shard statistics (seqlock snapshots).
pub mod shard_stats;
/// Hash-sharded concurrent cache front over per-shard `BlockCache`s.
pub mod sharded;
/// Selective LRU-K.
pub mod slru_k;
/// EDACHE WSClock: reference-bit clock with an age threshold.
pub mod wsclock;

pub use admission::{AdmissionPolicy, AdmissionStats, AlwaysAdmit};
pub use builder::{CacheBuildError, CacheBuilder};
pub use read_path::{Probe, ReadView, RecencyConfig};
pub use shard_stats::{AtomicShardStats, ShardSnapshot};
pub use sharded::{shard_of, ReadHandle, ShardStats, ShardedCache};

use crate::util::fasthash::IdHashMap;

use crate::hdfs::{BlockId, BlockKind};
use crate::sim::SimTime;

/// Cache affinity of the requesting application (paper §6.4.2, from [12]):
/// how much the application benefits from cached data.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum CacheAffinity {
    /// Little benefit from caching (I/O-bound single-pass apps like Sort).
    Low,
    /// Moderate benefit (WordCount, Join).
    Medium,
    /// High benefit (Grep, Aggregation re-read their inputs).
    High,
}

impl CacheAffinity {
    /// Numeric weight used by affinity-driven policies and the SVM features.
    pub fn weight(self) -> f64 {
        match self {
            CacheAffinity::Low => 0.25,
            CacheAffinity::Medium => 0.5,
            CacheAffinity::High => 1.0,
        }
    }

    /// Lower-case display name ("low" / "medium" / "high").
    pub fn name(self) -> &'static str {
        match self {
            CacheAffinity::Low => "low",
            CacheAffinity::Medium => "medium",
            CacheAffinity::High => "high",
        }
    }
}

/// Per-access context handed to policies (the features different strategies
/// key on; unneeded fields are ignored by simpler policies).
#[derive(Debug, Clone)]
pub struct AccessContext {
    /// Simulated time of the access.
    pub time: SimTime,
    /// Block size in bytes.
    pub size: u64,
    /// Block type (input / intermediate / output).
    pub kind: BlockKind,
    /// Owning file (grouping key for the LIFE/LFU-F wave criterion).
    pub file: u64,
    /// The file's "wave width": blocks processed concurrently.
    pub file_width: u32,
    /// Whether all tasks reading this file have completed.
    pub file_complete: bool,
    /// Cache affinity of the application issuing the access.
    pub affinity: CacheAffinity,
    /// SVM-predicted class: Some(true) = "reused in the future".
    /// Filled by the coordinator for H-SVM-LRU (and AutoCache's score).
    pub predicted_reuse: Option<bool>,
    /// CPU seconds needed to regenerate this block if it is evicted and
    /// requested again (DAG stage outputs — arXiv 1804.10563). 0.0 for
    /// blocks that persist on disk and never need recomputation.
    pub recompute_cost: f64,
}

impl AccessContext {
    /// A minimal context for unit tests and trace replay.
    pub fn simple(time: SimTime, size: u64) -> Self {
        AccessContext {
            time,
            size,
            kind: BlockKind::Input,
            file: 0,
            file_width: 1,
            file_complete: false,
            affinity: CacheAffinity::Medium,
            predicted_reuse: None,
            recompute_cost: 0.0,
        }
    }

    /// Attach an SVM prediction (builder style, for tests and replay).
    pub fn with_prediction(mut self, reuse: bool) -> Self {
        self.predicted_reuse = Some(reuse);
        self
    }

    /// Attach a recompute cost in seconds (builder style).
    pub fn with_recompute_cost(mut self, cost_s: f64) -> Self {
        self.recompute_cost = cost_s;
        self
    }
}

/// Why a victim was evicted — the per-eviction breakdown the
/// observability layer ([`crate::obs`]) aggregates per time window.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EvictCause {
    /// Pure capacity pressure: the policy's own order picked the victim
    /// and no other mechanism intervened.
    Capacity,
    /// The admission layer dueled the newcomer against this victim and
    /// the newcomer won (e.g. TinyLFU's frequency duel).
    AdmissionDuel,
    /// A cost-aware wrapper re-ranked the base policy's candidate window
    /// and picked a cheaper-to-recompute victim than the base order would
    /// have.
    CostTieBreak,
}

impl EvictCause {
    /// Stable lowercase name (used by the metrics export).
    pub fn name(self) -> &'static str {
        match self {
            EvictCause::Capacity => "capacity",
            EvictCause::AdmissionDuel => "admission",
            EvictCause::CostTieBreak => "cost_tie",
        }
    }
}

/// Eviction-order policy. The `BlockCache` guarantees the call protocol:
/// `on_insert` for blocks not present, `on_hit` for present blocks,
/// `choose_victim`/`on_evict` pairs while space is needed.
pub trait CachePolicy: Send {
    /// Registry name of the policy (e.g. "lru", "h-svm-lru").
    fn name(&self) -> &'static str;

    /// A cached block was accessed again.
    fn on_hit(&mut self, block: BlockId, ctx: &AccessContext);

    /// A block was inserted into the cache.
    fn on_insert(&mut self, block: BlockId, ctx: &AccessContext);

    /// Pick the next victim (must be a currently tracked block). The policy
    /// must NOT forget the block yet — `on_evict` confirms.
    fn choose_victim(&mut self, now: SimTime) -> Option<BlockId>;

    /// The first `k` blocks of the policy's eviction order, best victim
    /// first. Wrappers like [`cost_aware::CostAware`] re-rank this window
    /// (e.g. by recompute cost) without touching the policy's internals.
    /// The default is the single-candidate window — exactly
    /// [`CachePolicy::choose_victim`] — so only policies with a cheaply
    /// enumerable order need to override it. Like `choose_victim`, this
    /// must not mutate the eviction order.
    fn victim_candidates(&mut self, now: SimTime, _k: usize) -> Vec<BlockId> {
        self.choose_victim(now).into_iter().collect()
    }

    /// The chosen victim (or an externally uncached block) left the cache.
    fn on_evict(&mut self, block: BlockId);

    /// Number of tracked blocks (must equal the cache's block count).
    fn len(&self) -> usize;

    /// Whether the policy tracks no blocks.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether the policy admits this block at all (selective insertion —
    /// SLRU-K/AutoCache decline some inserts). Default: admit everything.
    fn admits(&self, _block: BlockId, _ctx: &AccessContext) -> bool {
        true
    }

    /// Whether the most recent [`CachePolicy::choose_victim`] call broke
    /// the base order's tie toward a cheaper victim (overridden by
    /// [`cost_aware::CostAware`]). Observability only — never consulted
    /// for eviction decisions.
    fn took_cost_tie_break(&self) -> bool {
        false
    }
}

/// Outcome of a cache access through `BlockCache::access_or_insert`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AccessOutcome {
    /// Whether the block was already cached.
    pub hit: bool,
    /// Blocks evicted to make room (empty on hits).
    pub evicted: Vec<BlockId>,
    /// Why each victim in `evicted` went (parallel to `evicted`).
    pub causes: Vec<EvictCause>,
    /// Eviction-loop iterations this access performed (victim selections
    /// — the "eviction scan work" the obs layer histograms).
    pub scan_steps: u32,
    /// Whether the block is cached after the access (false when the policy
    /// declined admission or the block exceeds capacity).
    pub inserted: bool,
}

/// Capacity-accounted cache driving a `CachePolicy`, guarded by an
/// [`AdmissionPolicy`] (default [`AlwaysAdmit`], which is bit-identical to
/// having no admission layer at all).
pub struct BlockCache {
    policy: Box<dyn CachePolicy>,
    admission: Box<dyn AdmissionPolicy>,
    admission_stats: AdmissionStats,
    capacity: u64,
    used: u64,
    sizes: IdHashMap<BlockId, u64>,
}

impl BlockCache {
    /// A cache of `capacity` bytes with the default admit-everything gate.
    pub fn new(policy: Box<dyn CachePolicy>, capacity: u64) -> Self {
        Self::assemble(policy, Box::new(AlwaysAdmit), capacity)
    }

    /// A cache whose inserts are gated by `admission`.
    #[deprecated(
        since = "0.10.0",
        note = "use cache::CacheBuilder::new().policy_with(..).admission_with(..).build_block_cache() instead"
    )]
    pub fn with_admission(
        policy: Box<dyn CachePolicy>,
        admission: Box<dyn AdmissionPolicy>,
        capacity: u64,
    ) -> Self {
        Self::assemble(policy, admission, capacity)
    }

    /// Non-deprecated assembly point shared by [`BlockCache::new`], the
    /// deprecated shims and [`builder::CacheBuilder`].
    pub(crate) fn assemble(
        policy: Box<dyn CachePolicy>,
        admission: Box<dyn AdmissionPolicy>,
        capacity: u64,
    ) -> Self {
        BlockCache {
            policy,
            admission,
            admission_stats: AdmissionStats::default(),
            capacity,
            used: 0,
            sizes: IdHashMap::default(),
        }
    }

    /// Registry name of the eviction policy.
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Registry name of the admission policy ("always" = no gate).
    pub fn admission_name(&self) -> &'static str {
        self.admission.name()
    }

    /// Admission decisions made so far (admitted vs rejected inserts).
    pub fn admission_stats(&self) -> AdmissionStats {
        self.admission_stats
    }

    /// Zero the admission counters (measurement-pass reset).
    pub fn reset_admission_stats(&mut self) {
        self.admission_stats = AdmissionStats::default();
    }

    /// Total capacity in bytes.
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Bytes currently cached.
    pub fn used(&self) -> u64 {
        self.used
    }

    /// Remaining free bytes.
    pub fn free(&self) -> u64 {
        self.capacity - self.used
    }

    /// Number of cached blocks.
    pub fn len(&self) -> usize {
        self.sizes.len()
    }

    /// Whether the cache holds no blocks.
    pub fn is_empty(&self) -> bool {
        self.sizes.is_empty()
    }

    /// Whether `block` is currently cached.
    pub fn contains(&self, block: BlockId) -> bool {
        self.sizes.contains_key(&block)
    }

    /// All cached block ids, sorted (stable test/debug output).
    pub fn cached_blocks(&self) -> Vec<BlockId> {
        let mut v: Vec<BlockId> = self.sizes.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// All cached block ids in hash order — the allocation-light feed for
    /// read-view rebuilds (`cache::read_path`), which do not care about
    /// order. Diagnostics should prefer [`BlockCache::cached_blocks`].
    pub fn blocks_unordered(&self) -> impl Iterator<Item = BlockId> + '_ {
        self.sizes.keys().copied()
    }

    /// Apply one *buffered* access to a block that resolved as a hit on
    /// the lock-free read path: the recency/admission bookkeeping of
    /// [`BlockCache::access_or_insert`]'s hit arm, decoupled from hit
    /// counting (which already happened at read time — see
    /// [`shard_stats::AtomicShardStats::record_lockfree_hit`]).
    ///
    /// Returns false (and does nothing) when the block is no longer
    /// resident — a concurrent mutator evicted it between the probe and
    /// this drain, so the stale recency update is dropped.
    pub fn touch(&mut self, block: BlockId, ctx: &AccessContext) -> bool {
        if !self.sizes.contains_key(&block) {
            return false;
        }
        self.admission.on_access(block, ctx);
        self.policy.on_hit(block, ctx);
        debug_assert_eq!(self.policy.len(), self.sizes.len());
        true
    }

    /// The full access path: hit (policy notified) or miss + insertion with
    /// evictions as needed. Mirrors GetCache/PutCache at the cache level.
    pub fn access_or_insert(&mut self, block: BlockId, ctx: &AccessContext) -> AccessOutcome {
        if self.sizes.contains_key(&block) {
            self.admission.on_access(block, ctx);
            self.policy.on_hit(block, ctx);
            debug_assert_eq!(self.policy.len(), self.sizes.len());
            return AccessOutcome {
                hit: true,
                evicted: Vec::new(),
                causes: Vec::new(),
                scan_steps: 0,
                inserted: true,
            };
        }
        let mut causes = Vec::new();
        let mut scan_steps = 0u32;
        let evicted = self.insert_classified(block, ctx, &mut causes, &mut scan_steps);
        let inserted = self.sizes.contains_key(&block);
        AccessOutcome { hit: false, evicted, causes, scan_steps, inserted }
    }

    /// Insert a missing block, evicting per policy until it fits. Returns
    /// the evicted blocks. Oversized, policy-declined or admission-refused
    /// blocks are skipped.
    pub fn insert(&mut self, block: BlockId, ctx: &AccessContext) -> Vec<BlockId> {
        let mut causes = Vec::new();
        let mut scan_steps = 0u32;
        self.insert_classified(block, ctx, &mut causes, &mut scan_steps)
    }

    /// [`BlockCache::insert`] plus per-victim [`EvictCause`] classification
    /// and scan-step counting. The classification reads flags the eviction
    /// path sets anyway, so the uninstrumented behavior is untouched.
    fn insert_classified(
        &mut self,
        block: BlockId,
        ctx: &AccessContext,
        causes: &mut Vec<EvictCause>,
        scan_steps: &mut u32,
    ) -> Vec<BlockId> {
        assert!(!self.sizes.contains_key(&block), "insert of cached block");
        self.admission.on_access(block, ctx);
        let mut evicted = Vec::new();
        if ctx.size > self.capacity || !self.policy.admits(block, ctx) {
            return evicted;
        }
        // Admission gate. The victim probe is lazy: it only runs (and only
        // advances the policy's victim-selection state) when the admission
        // policy actually compares against a victim, and only when the
        // insert would displace someone — `AlwaysAdmit` never triggers it,
        // keeping the default path bit-identical to the pre-admission cache.
        let mut peeked: Option<BlockId> = None;
        let needs_evict = self.used + ctx.size > self.capacity;
        {
            let policy = &mut self.policy;
            let peeked = &mut peeked;
            let mut probe = move || {
                if !needs_evict {
                    return None;
                }
                if peeked.is_none() {
                    *peeked = policy.choose_victim(ctx.time);
                }
                *peeked
            };
            if !self.admission.admit(block, ctx, &mut probe) {
                self.admission_stats.rejected += 1;
                return evicted;
            }
        }
        while self.used + ctx.size > self.capacity {
            *scan_steps += 1;
            // Consume the admission probe's victim first so the policy is
            // asked exactly once per eviction; it was already dueled inside
            // `admit`. Every further victim gets its own duel — a
            // multi-eviction insert must beat each block it displaces.
            let (victim, dueled) = match peeked.take() {
                // The probe only runs when the admission policy compares
                // the newcomer against a victim, so a consumed peek means
                // a duel already happened inside `admit`.
                Some(victim) => (victim, true),
                None => match self.policy.choose_victim(ctx.time) {
                    Some(victim) => {
                        if !self.admission.admit_over(block, ctx, victim) {
                            self.admission_stats.rejected += 1;
                            return evicted;
                        }
                        (victim, self.admission.duels())
                    }
                    None => return evicted, // policy refuses to evict
                },
            };
            causes.push(if self.policy.took_cost_tie_break() {
                EvictCause::CostTieBreak
            } else if dueled {
                EvictCause::AdmissionDuel
            } else {
                EvictCause::Capacity
            });
            self.policy.on_evict(victim);
            self.admission.on_evict(victim);
            let size = self.sizes.remove(&victim).expect("victim not in cache");
            self.used -= size;
            evicted.push(victim);
        }
        self.admission_stats.admitted += 1;
        self.policy.on_insert(block, ctx);
        self.sizes.insert(block, ctx.size);
        self.used += ctx.size;
        debug_assert_eq!(self.policy.len(), self.sizes.len());
        evicted
    }

    /// Externally remove a block (user uncache directive).
    pub fn remove(&mut self, block: BlockId) -> bool {
        match self.sizes.remove(&block) {
            Some(size) => {
                self.used -= size;
                self.policy.on_evict(block);
                self.admission.on_evict(block);
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::lru::Lru;
    use super::*;

    fn ctx(t: u64, size: u64) -> AccessContext {
        AccessContext::simple(SimTime(t), size)
    }

    /// LRU behind the named admission gate, via the builder (the
    /// non-deprecated construction path).
    fn gated_lru(admission: &str, capacity: u64) -> BlockCache {
        CacheBuilder::new()
            .policy_with(|| Box::new(Lru::new()))
            .admission(admission)
            .capacity(capacity)
            .build_block_cache()
            .unwrap()
    }

    #[test]
    fn hit_miss_and_eviction_accounting() {
        let mut cache = BlockCache::new(Box::new(Lru::new()), 300);
        let o = cache.access_or_insert(BlockId(1), &ctx(1, 100));
        assert!(!o.hit && o.inserted && o.evicted.is_empty());
        let o = cache.access_or_insert(BlockId(2), &ctx(2, 100));
        assert!(!o.hit);
        let o = cache.access_or_insert(BlockId(1), &ctx(3, 100));
        assert!(o.hit);
        // 3rd distinct block fits exactly; 4th forces the LRU victim (2).
        cache.access_or_insert(BlockId(3), &ctx(4, 100));
        let o = cache.access_or_insert(BlockId(4), &ctx(5, 100));
        assert_eq!(o.evicted, vec![BlockId(2)]);
        assert_eq!(cache.used(), 300);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn oversized_block_is_not_cached() {
        let mut cache = BlockCache::new(Box::new(Lru::new()), 100);
        let o = cache.access_or_insert(BlockId(1), &ctx(1, 500));
        assert!(!o.hit && !o.inserted);
        assert_eq!(cache.used(), 0);
    }

    #[test]
    fn remove_frees_space() {
        let mut cache = BlockCache::new(Box::new(Lru::new()), 100);
        cache.access_or_insert(BlockId(1), &ctx(1, 60));
        assert!(cache.remove(BlockId(1)));
        assert!(!cache.remove(BlockId(1)));
        assert_eq!(cache.used(), 0);
        assert!(cache.is_empty());
    }

    #[test]
    fn admission_gate_refuses_and_counts() {
        let mut cache = gated_lru("ghost", 300);
        assert_eq!(cache.admission_name(), "ghost");
        // First sighting: probation, not cached.
        let o = cache.access_or_insert(BlockId(1), &ctx(1, 100));
        assert!(!o.hit && !o.inserted);
        assert_eq!(cache.admission_stats(), AdmissionStats { admitted: 0, rejected: 1 });
        // Re-reference: admitted and cached.
        let o = cache.access_or_insert(BlockId(1), &ctx(2, 100));
        assert!(!o.hit && o.inserted);
        assert_eq!(cache.admission_stats(), AdmissionStats { admitted: 1, rejected: 1 });
        assert!(cache.access_or_insert(BlockId(1), &ctx(3, 100)).hit);
        cache.reset_admission_stats();
        assert_eq!(cache.admission_stats(), AdmissionStats::default());
    }

    #[test]
    fn tinylfu_duel_protects_the_hot_set() {
        let mut cache = gated_lru("tinylfu", 2);
        // Two hot blocks, re-accessed: high estimated frequency.
        for t in 0..6u64 {
            cache.access_or_insert(BlockId(t % 2), &ctx(t, 1));
        }
        assert_eq!(cache.len(), 2);
        // A one-pass scan block loses the frequency duel with the victim.
        let o = cache.access_or_insert(BlockId(99), &ctx(10, 1));
        assert!(!o.inserted && o.evicted.is_empty(), "scan must not displace hot");
        assert!(cache.contains(BlockId(0)) && cache.contains(BlockId(1)));
        assert_eq!(cache.admission_stats().rejected, 1);
    }

    #[test]
    fn tinylfu_duels_every_victim_of_a_multi_eviction_insert() {
        let mut cache = gated_lru("tinylfu", 4);
        // X: hot, size 2 (insert + 3 more accesses). Y: cold, size 2.
        cache.access_or_insert(BlockId(1), &ctx(1, 2)); // X
        cache.access_or_insert(BlockId(2), &ctx(2, 2)); // Y
        for t in 3..6u64 {
            cache.access_or_insert(BlockId(1), &ctx(t, 2)); // X hits
        }
        // Candidate C (size 4, seen twice) beats cold Y but must ALSO beat
        // hot X to displace both — it loses that second duel, so the
        // insert aborts and the hot block survives.
        cache.access_or_insert(BlockId(3), &ctx(10, 4)); // C: estimate -> 1, gate-rejected
        let o = cache.access_or_insert(BlockId(3), &ctx(11, 4)); // C: estimate 2 > Y's 1
        assert!(!o.inserted, "C must not displace the hot block");
        assert_eq!(o.evicted, vec![BlockId(2)], "C won only the duel against Y");
        assert!(cache.contains(BlockId(1)), "hot block survives the second duel");
        assert!(!cache.contains(BlockId(3)));
        // X and Y's own inserts were admitted; C was vetoed twice.
        assert_eq!(cache.admission_stats(), AdmissionStats { admitted: 2, rejected: 2 });
    }

    #[test]
    fn eviction_causes_classify_capacity_vs_duel() {
        // Plain LRU + AlwaysAdmit: every eviction is pure capacity.
        let mut cache = BlockCache::new(Box::new(Lru::new()), 200);
        cache.access_or_insert(BlockId(1), &ctx(1, 100));
        cache.access_or_insert(BlockId(2), &ctx(2, 100));
        let o = cache.access_or_insert(BlockId(3), &ctx(3, 200));
        assert_eq!(o.evicted, vec![BlockId(1), BlockId(2)]);
        assert_eq!(o.causes, vec![EvictCause::Capacity, EvictCause::Capacity]);
        assert_eq!(o.scan_steps, 2);
        assert_eq!(cache.access_or_insert(BlockId(3), &ctx(4, 200)).scan_steps, 0);

        // TinyLFU: the victim the newcomer dueled (and beat) is an
        // admission-duel eviction.
        let mut cache = gated_lru("tinylfu", 1);
        cache.access_or_insert(BlockId(1), &ctx(1, 1));
        // Seen twice -> estimate 2 beats the resident's 1.
        cache.access_or_insert(BlockId(9), &ctx(2, 1));
        let o = cache.access_or_insert(BlockId(9), &ctx(3, 1));
        assert!(o.inserted);
        assert_eq!(o.evicted, vec![BlockId(1)]);
        assert_eq!(o.causes, vec![EvictCause::AdmissionDuel]);
    }

    #[test]
    fn affinity_weights_ordered() {
        assert!(CacheAffinity::High.weight() > CacheAffinity::Medium.weight());
        assert!(CacheAffinity::Medium.weight() > CacheAffinity::Low.weight());
    }
}
