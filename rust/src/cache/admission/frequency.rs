//! Approximate frequency estimation for TinyLFU admission: a 4-bit
//! Count-Min sketch with periodic halving (the "aging" that keeps the
//! estimate tracking *recent* popularity) and a doorkeeper Bloom filter
//! that absorbs the long tail of once-seen blocks so they never occupy
//! sketch counters.
//!
//! Both structures hash the raw block id with the same Fibonacci
//! multiplicative mix the rest of the crate uses
//! ([`crate::util::fasthash`]), re-seeded per row/probe, so the estimate is
//! deterministic for a given request stream — experiment runs stay
//! bit-for-bit reproducible.

use crate::hdfs::BlockId;

/// Per-row hash seeds (odd constants; splitmix64-style increments).
const ROW_SEEDS: [u64; 4] = [
    0x9E37_79B9_7F4A_7C15,
    0xBF58_476D_1CE4_E5B9,
    0x94D0_49BB_1331_11EB,
    0xD6E8_FEB8_6659_FD93,
];

#[inline]
fn mix(id: u64, seed: u64) -> u64 {
    let mut h = id.wrapping_add(seed).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    h ^= h >> 32;
    h = h.wrapping_mul(0xD6E8_FEB8_6659_FD93);
    h ^ (h >> 29)
}

/// A Count-Min sketch of 4-bit saturating counters, 4 rows deep.
///
/// Counters saturate at 15; when the number of recorded increments reaches
/// the sample period every counter is halved (and the caller is told, so it
/// can reset its doorkeeper). Until a halving happens the estimate never
/// underestimates the true count below saturation — property-tested in
/// rust/tests/property_admission.rs.
#[derive(Debug, Clone)]
pub struct FrequencySketch {
    /// 4 rows × `width` 4-bit counters, 16 counters per word.
    table: Vec<u64>,
    /// Counters per row (power of two).
    width: usize,
    /// Increments recorded since the last halving.
    additions: u64,
    /// Halve all counters once `additions` reaches this.
    sample_size: u64,
}

impl FrequencySketch {
    /// Sketch sized for roughly `capacity` distinct hot blocks. Width is
    /// rounded up to a power of two; the sample period is 10× the width
    /// (the TinyLFU paper's W = 10·C).
    pub fn with_capacity(capacity: usize) -> Self {
        let width = capacity.max(16).next_power_of_two();
        FrequencySketch {
            table: vec![0u64; (4 * width).div_ceil(16)],
            width,
            additions: 0,
            sample_size: 10 * width as u64,
        }
    }

    /// Counter index of `id` in `row`.
    #[inline]
    fn index(&self, id: u64, row: usize) -> usize {
        let h = mix(id, ROW_SEEDS[row]) as usize;
        row * self.width + (h & (self.width - 1))
    }

    #[inline]
    fn get(&self, counter: usize) -> u8 {
        let word = self.table[counter / 16];
        ((word >> ((counter % 16) * 4)) & 0xF) as u8
    }

    #[inline]
    fn bump(&mut self, counter: usize) {
        let shift = (counter % 16) * 4;
        let word = &mut self.table[counter / 16];
        if ((*word >> shift) & 0xF) < 15 {
            *word += 1u64 << shift;
        }
    }

    /// Record one access. Returns `true` when the record triggered the
    /// periodic halving (callers reset their doorkeeper on that signal).
    pub fn increment(&mut self, block: BlockId) -> bool {
        for row in 0..4 {
            let idx = self.index(block.0, row);
            self.bump(idx);
        }
        self.additions += 1;
        if self.additions >= self.sample_size {
            self.halve();
            true
        } else {
            false
        }
    }

    /// Estimated access count of `block` (min over rows; ≤ 15).
    pub fn estimate(&self, block: BlockId) -> u32 {
        (0..4)
            .map(|row| self.get(self.index(block.0, row)) as u32)
            .min()
            .expect("4 rows")
    }

    /// Halve every counter in place — the aging step. Shifting the packed
    /// word right by one moves each counter's low bit into its neighbour's
    /// top bit; masking with 0x7777… clears those borrowed bits.
    pub fn halve(&mut self) {
        for word in &mut self.table {
            *word = (*word >> 1) & 0x7777_7777_7777_7777;
        }
        self.additions = 0;
    }

    /// Increments recorded since the last halving.
    pub fn additions(&self) -> u64 {
        self.additions
    }

    /// Counters per row.
    pub fn width(&self) -> usize {
        self.width
    }
}

/// A small Bloom filter guarding the sketch: the first access of a block
/// only sets doorkeeper bits, so one-hit wonders (the pollution stream
/// itself) never consume sketch counters. Cleared on every sketch halving.
///
/// Bloom guarantees: no false negatives ever; false positives possible.
/// After [`Doorkeeper::clear`] the filter is empty, so it cannot carry
/// stale admissions across a reset (property-tested).
#[derive(Debug, Clone)]
pub struct Doorkeeper {
    bits: Vec<u64>,
    /// Bit-index mask (power-of-two bit count - 1).
    mask: u64,
}

impl Doorkeeper {
    /// Filter with roughly `capacity` expected members (8 bits per member,
    /// 3 probes: ~3% false-positive rate at full load).
    pub fn with_capacity(capacity: usize) -> Self {
        let bits = (8 * capacity.max(16)).next_power_of_two();
        Doorkeeper { bits: vec![0u64; bits / 64], mask: bits as u64 - 1 }
    }

    #[inline]
    fn probes(&self, id: u64) -> [u64; 3] {
        [
            mix(id, ROW_SEEDS[0]) & self.mask,
            mix(id, ROW_SEEDS[1]) & self.mask,
            mix(id, ROW_SEEDS[2]) & self.mask,
        ]
    }

    /// Insert `block`; returns `true` if it was not already present (i.e.
    /// at least one probe bit was newly set).
    pub fn insert(&mut self, block: BlockId) -> bool {
        let mut newly = false;
        for bit in self.probes(block.0) {
            let word = &mut self.bits[(bit / 64) as usize];
            let mask = 1u64 << (bit % 64);
            newly |= *word & mask == 0;
            *word |= mask;
        }
        newly
    }

    /// Whether the doorkeeper has (probabilistically) seen `block`.
    pub fn contains(&self, block: BlockId) -> bool {
        self.probes(block.0)
            .iter()
            .all(|&bit| self.bits[(bit / 64) as usize] & (1u64 << (bit % 64)) != 0)
    }

    /// Forget everything (paired with the sketch's halving).
    pub fn clear(&mut self) {
        self.bits.fill(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sketch_counts_and_saturates() {
        let mut s = FrequencySketch::with_capacity(64);
        assert_eq!(s.estimate(BlockId(1)), 0);
        for _ in 0..5 {
            s.increment(BlockId(1));
        }
        assert!(s.estimate(BlockId(1)) >= 5);
        for _ in 0..100 {
            s.increment(BlockId(2));
        }
        assert_eq!(s.estimate(BlockId(2)), 15, "counters saturate at 15");
    }

    #[test]
    fn halving_ages_counters() {
        let mut s = FrequencySketch::with_capacity(64);
        for _ in 0..8 {
            s.increment(BlockId(3));
        }
        let before = s.estimate(BlockId(3));
        s.halve();
        assert_eq!(s.estimate(BlockId(3)), before / 2);
        assert_eq!(s.additions(), 0);
    }

    #[test]
    fn sample_period_triggers_reset() {
        let mut s = FrequencySketch::with_capacity(16);
        let period = 10 * s.width() as u64;
        let mut resets = 0;
        for i in 0..2 * period {
            if s.increment(BlockId(i % 7)) {
                resets += 1;
            }
        }
        assert_eq!(resets, 2, "one halving per full sample period");
    }

    #[test]
    fn doorkeeper_has_no_false_negatives_and_clears() {
        let mut d = Doorkeeper::with_capacity(128);
        for id in 0..100u64 {
            assert!(d.insert(BlockId(id)) || d.contains(BlockId(id)));
        }
        for id in 0..100u64 {
            assert!(d.contains(BlockId(id)), "false negative for {id}");
        }
        d.clear();
        for id in 0..100u64 {
            assert!(!d.contains(BlockId(id)), "stale bit for {id} after clear");
        }
    }

    #[test]
    fn doorkeeper_insert_reports_novelty() {
        let mut d = Doorkeeper::with_capacity(128);
        assert!(d.insert(BlockId(42)));
        assert!(!d.insert(BlockId(42)), "second insert is not novel");
    }
}
