//! TinyLFU admission: admit a candidate only if its estimated access
//! frequency beats the eviction victim it would displace.
//!
//! The estimator is the 4-bit Count-Min sketch + doorkeeper of
//! [`frequency`](super::frequency); every request feeds it (first sighting
//! goes to the doorkeeper, repeats into the sketch), and the periodic
//! sketch halving clears the doorkeeper so the whole estimate ages
//! together. A scan flood therefore shows up as estimate ≈ 1 while the
//! resident working set accumulates higher counts — the flood loses every
//! admission duel and the working set stays cached.

use crate::hdfs::BlockId;

use super::super::AccessContext;
use super::frequency::{Doorkeeper, FrequencySketch};
use super::AdmissionPolicy;

/// TinyLFU frequency-duel admission.
pub struct TinyLfu {
    sketch: FrequencySketch,
    doorkeeper: Doorkeeper,
}

impl TinyLfu {
    /// Estimator sized for roughly `capacity` distinct hot blocks.
    pub fn with_capacity(capacity: usize) -> Self {
        TinyLfu {
            sketch: FrequencySketch::with_capacity(capacity),
            doorkeeper: Doorkeeper::with_capacity(capacity),
        }
    }

    /// Combined frequency estimate: sketch count plus the doorkeeper bit.
    pub fn estimate(&self, block: BlockId) -> u32 {
        self.sketch.estimate(block) + u32::from(self.doorkeeper.contains(block))
    }
}

impl AdmissionPolicy for TinyLfu {
    fn name(&self) -> &'static str {
        "tinylfu"
    }

    fn on_access(&mut self, block: BlockId, _ctx: &AccessContext) {
        // First sighting stops at the doorkeeper; repeats count in the
        // sketch, whose periodic halving also resets the doorkeeper.
        if !self.doorkeeper.insert(block) && self.sketch.increment(block) {
            self.doorkeeper.clear();
        }
    }

    fn admit(
        &mut self,
        candidate: BlockId,
        _ctx: &AccessContext,
        victim: &mut dyn FnMut() -> Option<BlockId>,
    ) -> bool {
        match victim() {
            // Room available (or the policy refuses to evict): nobody is
            // displaced, so there is no duel to lose.
            None => true,
            Some(v) => self.estimate(candidate) > self.estimate(v),
        }
    }

    fn admit_over(&mut self, candidate: BlockId, _ctx: &AccessContext, victim: BlockId) -> bool {
        // A multi-eviction insert must beat EVERY block it displaces, not
        // just the first — otherwise a mid-frequency candidate could ride
        // one cheap victory into evicting a hot block duel-free.
        self.estimate(candidate) > self.estimate(victim)
    }

    fn on_evict(&mut self, _block: BlockId) {}

    fn duels(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;

    fn ctx() -> AccessContext {
        AccessContext::simple(SimTime(0), 1)
    }

    #[test]
    fn frequent_candidate_beats_rare_victim() {
        let mut t = TinyLfu::with_capacity(64);
        for _ in 0..4 {
            t.on_access(BlockId(1), &ctx());
        }
        t.on_access(BlockId(2), &ctx());
        assert!(t.estimate(BlockId(1)) > t.estimate(BlockId(2)));
        let mut victim = || Some(BlockId(2));
        assert!(t.admit(BlockId(1), &ctx(), &mut victim));
        let mut victim = || Some(BlockId(1));
        assert!(!t.admit(BlockId(2), &ctx(), &mut victim), "rare loses the duel");
    }

    #[test]
    fn equal_frequency_rejects_the_candidate() {
        // Ties keep the incumbent: churn needs strict evidence.
        let mut t = TinyLfu::with_capacity(64);
        t.on_access(BlockId(1), &ctx());
        t.on_access(BlockId(2), &ctx());
        let mut victim = || Some(BlockId(1));
        assert!(!t.admit(BlockId(2), &ctx(), &mut victim));
    }

    #[test]
    fn admits_freely_while_there_is_room() {
        let mut t = TinyLfu::with_capacity(64);
        let mut no_victim = || None::<BlockId>;
        assert!(t.admit(BlockId(99), &ctx(), &mut no_victim));
    }

    #[test]
    fn first_access_lands_in_doorkeeper_only() {
        let mut t = TinyLfu::with_capacity(64);
        t.on_access(BlockId(5), &ctx());
        assert_eq!(t.sketch.estimate(BlockId(5)), 0, "first hit is doorkeeper-only");
        assert_eq!(t.estimate(BlockId(5)), 1);
        t.on_access(BlockId(5), &ctx());
        assert_eq!(t.estimate(BlockId(5)), 2);
    }
}
