//! SVM-predicted admission: the trained classifier's "reused in the
//! future" decision, applied at insert time instead of (or in addition to)
//! eviction time.
//!
//! The coordinator already batch-scores every request and stamps the class
//! into [`AccessContext::predicted_reuse`] before the cache sees it (the
//! same deployment the H-SVM-LRU eviction policy consumes, batched through
//! `coordinator::batcher` and retrained by `coordinator::training_pipeline`)
//! — so this policy is a pure read of that prediction. A block the model
//! expects never to be re-read is refused outright; while no model is
//! deployed yet (`predicted_reuse == None`) everything is admitted, which
//! keeps cold-start behaviour identical to `always`.

use crate::hdfs::BlockId;

use super::super::AccessContext;
use super::AdmissionPolicy;

/// Admit iff the deployed classifier does not predict "no future reuse".
#[derive(Debug, Clone, Copy, Default)]
pub struct SvmAdmit;

impl AdmissionPolicy for SvmAdmit {
    fn name(&self) -> &'static str {
        "svm"
    }

    fn on_access(&mut self, _block: BlockId, _ctx: &AccessContext) {}

    fn admit(
        &mut self,
        _candidate: BlockId,
        ctx: &AccessContext,
        _victim: &mut dyn FnMut() -> Option<BlockId>,
    ) -> bool {
        ctx.predicted_reuse != Some(false)
    }

    fn on_evict(&mut self, _block: BlockId) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;

    fn admit_with(prediction: Option<bool>) -> bool {
        let mut ctx = AccessContext::simple(SimTime(0), 1);
        ctx.predicted_reuse = prediction;
        let mut no_victim = || None::<BlockId>;
        SvmAdmit.admit(BlockId(1), &ctx, &mut no_victim)
    }

    #[test]
    fn follows_the_classifier() {
        assert!(admit_with(Some(true)), "predicted reuse is admitted");
        assert!(!admit_with(Some(false)), "predicted pollution is refused");
        assert!(admit_with(None), "no deployed model admits everything");
    }
}
