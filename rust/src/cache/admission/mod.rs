//! Cache admission control — stop pollution *before* it costs an eviction.
//!
//! The paper's §4 defines cache pollution as single-pass blocks ("data
//! without further use", e.g. MapReduce intermediate/shuffle spills) pushing
//! blocks with future reuse out of the limited off-heap cache. H-SVM-LRU
//! attacks pollution at *eviction* time by keeping predicted-reuse blocks
//! out of the victim pool; this module attacks it one step earlier, at
//! *insert* time: a pluggable [`AdmissionPolicy`] sits in front of every
//! replacement policy and may refuse to cache a missing block at all, so a
//! scan flood never displaces the working set in the first place.
//!
//! Implemented admission strategies (constructible by name through
//! [`make_admission`]):
//!
//! | name      | strategy |
//! |-----------|----------|
//! | `always`  | [`AlwaysAdmit`] — admit everything (the pre-admission behaviour, bit-identical default) |
//! | `tinylfu` | [`TinyLfu`] — 4-bit Count-Min frequency sketch + doorkeeper Bloom filter; admit only if the candidate's estimated frequency beats the eviction victim's |
//! | `ghost`   | [`GhostProbation`] — ghost LRU of recently seen/evicted ids; admit on re-reference |
//! | `svm`     | [`SvmAdmit`] — the deployed SVM classifier's reuse prediction, consulted at insert time |
//!
//! The cache layer guarantees the call protocol: [`AdmissionPolicy::on_access`]
//! once per request (hit or miss), [`AdmissionPolicy::admit`] once per
//! candidate insert that passed the capacity/policy pre-checks, and
//! [`AdmissionPolicy::on_evict`] whenever a block leaves the cache. Every
//! shard of a [`ShardedCache`](crate::cache::ShardedCache) owns its own
//! instance, so admission state is updated under the shard lock the access
//! already holds and the hot path stays lock-free across shards.

/// Count-Min frequency sketch + doorkeeper Bloom filter.
pub mod frequency;
/// Ghost-LRU probation admission.
pub mod ghost;
/// SVM-prediction admission.
pub mod svm_admit;
/// W-TinyLFU-style frequency-duel admission.
pub mod tinylfu;

pub use frequency::{Doorkeeper, FrequencySketch};
pub use ghost::GhostProbation;
pub use svm_admit::SvmAdmit;
pub use tinylfu::TinyLfu;

use crate::hdfs::BlockId;

use super::AccessContext;

/// Insert-time admission decision layer in front of a replacement policy.
///
/// Implementations must be cheap: `on_access` sits on the per-request hot
/// path of every shard.
///
/// ```
/// use h_svm_lru::cache::admission::{AdmissionPolicy, AlwaysAdmit};
/// use h_svm_lru::cache::AccessContext;
/// use h_svm_lru::hdfs::BlockId;
/// use h_svm_lru::sim::SimTime;
///
/// let mut gate: Box<dyn AdmissionPolicy> = Box::new(AlwaysAdmit);
/// let ctx = AccessContext::simple(SimTime(0), 64);
/// gate.on_access(BlockId(1), &ctx);
/// // `always` admits without ever probing the victim it would displace.
/// assert!(gate.admit(BlockId(1), &ctx, &mut || None));
/// ```
pub trait AdmissionPolicy: Send {
    /// Registry name of the policy (e.g. `"tinylfu"`).
    fn name(&self) -> &'static str;

    /// Every cache request for `block` — hit, miss or prefetch staging —
    /// exactly once. Frequency-learning admissions build their estimate
    /// here; stateless ones ignore it.
    fn on_access(&mut self, block: BlockId, ctx: &AccessContext);

    /// Decide whether a missing `candidate` may enter the cache. `victim`
    /// lazily peeks the eviction victim the insert would displace: it
    /// returns `None` when the cache still has room (nobody is displaced),
    /// and calling it may advance the wrapped policy's victim-selection
    /// state — implementations that don't compare against the victim MUST
    /// NOT call it, which is what keeps [`AlwaysAdmit`] bit-identical to the
    /// pre-admission cache.
    fn admit(
        &mut self,
        candidate: BlockId,
        ctx: &AccessContext,
        victim: &mut dyn FnMut() -> Option<BlockId>,
    ) -> bool;

    /// When one insert must displace *several* blocks, every victim past
    /// the first is offered here before it is evicted: may `candidate`
    /// displace `victim` too? Must be a pure comparison (no admission
    /// bookkeeping — [`AdmissionPolicy::admit`] already ran for this
    /// candidate). Returning `false` aborts the insert, keeping `victim`
    /// cached. Default: yes, evict freely — only frequency-duel admissions
    /// compare per victim.
    fn admit_over(&mut self, _candidate: BlockId, _ctx: &AccessContext, _victim: BlockId) -> bool {
        true
    }

    /// `block` left the cache (policy eviction or external uncache).
    fn on_evict(&mut self, block: BlockId);

    /// Whether this policy's admit/admit_over decisions actually compare
    /// the candidate against the victim (a frequency duel). Observability
    /// only — the eviction-cause classifier
    /// ([`crate::cache::EvictCause::AdmissionDuel`]) uses it to tell a
    /// dueled eviction from a rubber-stamped one; never consulted for
    /// admission decisions. Default: no duel.
    fn duels(&self) -> bool {
        false
    }
}

/// Admission counters kept by the owning cache. `admitted` counts inserts
/// the admission layer allowed end to end (through every per-victim duel);
/// `rejected` counts candidates it vetoed — at the gate or against a later
/// victim. Oversized blocks, inserts the replacement policy itself declined
/// and inserts the policy refused to make room for are counted in neither
/// bucket.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmissionStats {
    /// Inserts the admission layer allowed end to end.
    pub admitted: u64,
    /// Candidates it vetoed.
    pub rejected: u64,
}

impl AdmissionStats {
    /// Add `other`'s counters into `self`.
    pub fn merge(&mut self, other: &AdmissionStats) {
        self.admitted += other.admitted;
        self.rejected += other.rejected;
    }

    /// Fraction of admission decisions that were rejections.
    pub fn reject_ratio(&self) -> f64 {
        let total = self.admitted + self.rejected;
        if total == 0 {
            0.0
        } else {
            self.rejected as f64 / total as f64
        }
    }
}

/// Admit everything — the exact pre-admission behaviour. `on_access` and
/// `on_evict` are no-ops and `admit` never touches the victim probe, so a
/// cache built with this policy is bit-identical to one built before the
/// admission layer existed (property-tested in
/// rust/tests/property_admission.rs).
#[derive(Debug, Clone, Copy, Default)]
pub struct AlwaysAdmit;

impl AdmissionPolicy for AlwaysAdmit {
    fn name(&self) -> &'static str {
        "always"
    }

    fn on_access(&mut self, _block: BlockId, _ctx: &AccessContext) {}

    fn admit(
        &mut self,
        _candidate: BlockId,
        _ctx: &AccessContext,
        _victim: &mut dyn FnMut() -> Option<BlockId>,
    ) -> bool {
        true
    }

    fn on_evict(&mut self, _block: BlockId) {}
}

/// All registered admission-policy names, in presentation order.
pub const ADMISSION_NAMES: &[&str] = &["always", "tinylfu", "ghost", "svm"];

/// Instantiate an admission policy by name with its default parameters.
pub fn make_admission(name: &str) -> Option<Box<dyn AdmissionPolicy>> {
    Some(match name {
        "always" => Box::new(AlwaysAdmit),
        "tinylfu" => Box::new(TinyLfu::with_capacity(1024)),
        "ghost" => Box::new(GhostProbation::new(1024)),
        "svm" => Box::new(SvmAdmit),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;

    #[test]
    fn every_registered_name_constructs() {
        for name in ADMISSION_NAMES {
            let a = make_admission(name).unwrap_or_else(|| panic!("{name} missing"));
            assert_eq!(&a.name(), name);
        }
        assert!(make_admission("nonsense").is_none());
    }

    #[test]
    fn always_admits_without_probing_the_victim() {
        let mut a = AlwaysAdmit;
        let ctx = AccessContext::simple(SimTime(0), 1);
        let mut probed = false;
        let mut probe = || {
            probed = true;
            Some(BlockId(7))
        };
        assert!(a.admit(BlockId(1), &ctx, &mut probe));
        assert!(!probed, "always must never consult the victim");
    }

    #[test]
    fn stats_merge_and_ratio() {
        let mut a = AdmissionStats { admitted: 3, rejected: 1 };
        let b = AdmissionStats { admitted: 1, rejected: 3 };
        a.merge(&b);
        assert_eq!(a, AdmissionStats { admitted: 4, rejected: 4 });
        assert!((a.reject_ratio() - 0.5).abs() < 1e-12);
        assert_eq!(AdmissionStats::default().reject_ratio(), 0.0);
    }
}
