//! Ghost-cache probation admission: a block must prove reuse before it may
//! occupy real capacity.
//!
//! A bounded LRU "ghost" holds only block *ids* — candidates the admission
//! layer turned away and victims the replacement policy evicted. A miss
//! whose id is still in the ghost is a re-reference within the observation
//! window and is admitted (and leaves the ghost); a first sighting is
//! recorded and rejected. Single-pass pollution never re-references, so it
//! never graduates out of the ghost — the 2Q/ARC ghost-history idea applied
//! as pure admission control.

use std::collections::VecDeque;

use crate::hdfs::BlockId;
use crate::util::fasthash::IdHashMap;

use super::super::AccessContext;
use super::AdmissionPolicy;

/// Bounded LRU set of block ids with O(1) touch via stamped lazy deletion:
/// the map holds each member's latest stamp, the queue holds (id, stamp)
/// entries in insertion order, and entries whose stamp is stale are dropped
/// when they surface at the front.
#[derive(Debug, Default)]
struct GhostLru {
    stamps: IdHashMap<BlockId, u64>,
    queue: VecDeque<(BlockId, u64)>,
    seq: u64,
    capacity: usize,
}

impl GhostLru {
    fn new(capacity: usize) -> Self {
        GhostLru { capacity: capacity.max(1), ..Default::default() }
    }

    /// Insert or refresh `block` as most-recently-seen, evicting the least
    /// recently seen member when over capacity.
    fn record(&mut self, block: BlockId) {
        self.seq += 1;
        self.stamps.insert(block, self.seq);
        self.queue.push_back((block, self.seq));
        while self.stamps.len() > self.capacity {
            let (b, s) = self.queue.pop_front().expect("members imply queue entries");
            if self.stamps.get(&b) == Some(&s) {
                self.stamps.remove(&b);
            }
        }
        // Drain stale fronts eagerly so the queue stays near `len()`.
        while let Some(&(b, s)) = self.queue.front() {
            if self.stamps.get(&b) == Some(&s) {
                break;
            }
            self.queue.pop_front();
        }
        // A live front entry can shield stale entries behind it from the
        // drain above (e.g. one never-re-referenced probation member while
        // admissions keep removing stamps mid-queue). Compact whenever
        // stale entries dominate: `retain` keeps order and runs at most
        // once per `capacity` pushes, so it amortizes to O(1) per record.
        if self.queue.len() > 2 * self.capacity {
            let stamps = &self.stamps;
            self.queue.retain(|(b, s)| stamps.get(b) == Some(s));
        }
    }

    /// Remove `block`; true if it was a member.
    fn remove(&mut self, block: BlockId) -> bool {
        self.stamps.remove(&block).is_some()
    }

    fn contains(&self, block: BlockId) -> bool {
        self.stamps.contains_key(&block)
    }

    fn len(&self) -> usize {
        self.stamps.len()
    }
}

/// Ghost-LRU probation admission.
pub struct GhostProbation {
    ghost: GhostLru,
}

impl GhostProbation {
    /// Ghost history of at most `capacity` block ids.
    pub fn new(capacity: usize) -> Self {
        GhostProbation { ghost: GhostLru::new(capacity) }
    }

    /// Current ghost members (ids on probation or recently evicted).
    pub fn len(&self) -> usize {
        self.ghost.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ghost.len() == 0
    }

    /// Maximum ghost members — `len() <= capacity()` always holds
    /// (property-tested in rust/tests/property_admission.rs).
    pub fn capacity(&self) -> usize {
        self.ghost.capacity
    }

    pub fn contains(&self, block: BlockId) -> bool {
        self.ghost.contains(block)
    }
}

impl AdmissionPolicy for GhostProbation {
    fn name(&self) -> &'static str {
        "ghost"
    }

    fn on_access(&mut self, _block: BlockId, _ctx: &AccessContext) {}

    fn admit(
        &mut self,
        candidate: BlockId,
        _ctx: &AccessContext,
        _victim: &mut dyn FnMut() -> Option<BlockId>,
    ) -> bool {
        if self.ghost.remove(candidate) {
            // Re-referenced while remembered: proven reuse, admit.
            true
        } else {
            // First sighting: put it on probation instead of in the cache.
            self.ghost.record(candidate);
            false
        }
    }

    fn on_evict(&mut self, block: BlockId) {
        self.ghost.record(block);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;

    fn ctx() -> AccessContext {
        AccessContext::simple(SimTime(0), 1)
    }

    fn admit(g: &mut GhostProbation, id: u64) -> bool {
        let mut no_victim = || None::<BlockId>;
        g.admit(BlockId(id), &ctx(), &mut no_victim)
    }

    #[test]
    fn first_sighting_rejected_re_reference_admitted() {
        let mut g = GhostProbation::new(8);
        assert!(!admit(&mut g, 1), "probation first");
        assert!(g.contains(BlockId(1)));
        assert!(admit(&mut g, 1), "re-reference admits");
        assert!(!g.contains(BlockId(1)), "admission consumes the ghost entry");
    }

    #[test]
    fn evicted_blocks_get_a_second_chance() {
        let mut g = GhostProbation::new(8);
        g.on_evict(BlockId(9));
        assert!(admit(&mut g, 9));
    }

    #[test]
    fn ghost_capacity_is_bounded_lru() {
        let mut g = GhostProbation::new(3);
        for id in 0..10u64 {
            assert!(!admit(&mut g, id));
            assert!(g.len() <= g.capacity());
        }
        // Only the 3 most recent survive; old probation entries expired.
        assert!(!g.contains(BlockId(0)));
        assert!(g.contains(BlockId(9)));
        assert!(!admit(&mut g, 0), "expired probation restarts");
    }

    #[test]
    fn stale_queue_entries_are_compacted() {
        // One never-re-referenced probation member sits live at the queue
        // front while admission pairs keep stranding stale entries behind
        // it; compaction must keep the queue bounded by the capacity.
        let mut g = GhostProbation::new(8);
        assert!(!admit(&mut g, 999_999));
        for id in 0..10_000u64 {
            assert!(!admit(&mut g, id), "first sighting rejected");
            assert!(admit(&mut g, id), "re-reference admitted");
        }
        assert!(g.len() <= g.capacity());
        assert!(
            g.ghost.queue.len() <= 2 * g.capacity(),
            "queue grew to {} entries for {} members",
            g.ghost.queue.len(),
            g.len()
        );
    }

    #[test]
    fn touching_refreshes_recency() {
        let mut g = GhostProbation::new(2);
        assert!(!admit(&mut g, 1));
        assert!(!admit(&mut g, 2));
        g.on_evict(BlockId(1)); // refresh 1 as most recent
        assert!(!admit(&mut g, 3)); // evicts 2, not 1
        assert!(g.contains(BlockId(1)));
        assert!(!g.contains(BlockId(2)));
    }
}
