//! Ghost-cache probation admission: a block must prove reuse before it may
//! occupy real capacity.
//!
//! A bounded LRU "ghost" holds only block *ids* — candidates the admission
//! layer turned away and victims the replacement policy evicted. A miss
//! whose id is still in the ghost is a re-reference within the observation
//! window and is admitted (and leaves the ghost); a first sighting is
//! recorded and rejected. Single-pass pollution never re-references, so it
//! never graduates out of the ghost — the 2Q/ARC ghost-history idea applied
//! as pure admission control.

use crate::hdfs::BlockId;

use super::super::order_list::LruSet;
use super::super::AccessContext;
use super::AdmissionPolicy;

/// Ghost-LRU probation admission. The ghost is a bounded [`LruSet`] —
/// O(1) allocation-free touch/insert/remove/trim. (The previous
/// implementation emulated O(1) removal with stamped lazy deletion over a
/// `VecDeque` plus periodic compaction; the handle unlink makes all of
/// that machinery unnecessary.)
pub struct GhostProbation {
    ghost: LruSet<BlockId>,
    capacity: usize,
}

impl GhostProbation {
    /// Ghost history of at most `capacity` block ids.
    pub fn new(capacity: usize) -> Self {
        GhostProbation { ghost: LruSet::new(), capacity: capacity.max(1) }
    }

    /// Insert or refresh `block` as most-recently-seen, evicting the least
    /// recently seen member when over capacity.
    fn record(&mut self, block: BlockId) {
        self.ghost.touch_or_insert(block);
        self.ghost.trim_to(self.capacity);
    }

    /// Current ghost members (ids on probation or recently evicted).
    pub fn len(&self) -> usize {
        self.ghost.len()
    }

    /// Whether the ghost list is empty.
    pub fn is_empty(&self) -> bool {
        self.ghost.is_empty()
    }

    /// Maximum ghost members — `len() <= capacity()` always holds
    /// (property-tested in rust/tests/property_admission.rs).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Whether `block` is on ghost probation.
    pub fn contains(&self, block: BlockId) -> bool {
        self.ghost.contains(block)
    }
}

impl AdmissionPolicy for GhostProbation {
    fn name(&self) -> &'static str {
        "ghost"
    }

    fn on_access(&mut self, _block: BlockId, _ctx: &AccessContext) {}

    fn admit(
        &mut self,
        candidate: BlockId,
        _ctx: &AccessContext,
        _victim: &mut dyn FnMut() -> Option<BlockId>,
    ) -> bool {
        if self.ghost.remove(candidate) {
            // Re-referenced while remembered: proven reuse, admit.
            true
        } else {
            // First sighting: put it on probation instead of in the cache.
            self.record(candidate);
            false
        }
    }

    fn on_evict(&mut self, block: BlockId) {
        self.record(block);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;

    fn ctx() -> AccessContext {
        AccessContext::simple(SimTime(0), 1)
    }

    fn admit(g: &mut GhostProbation, id: u64) -> bool {
        let mut no_victim = || None::<BlockId>;
        g.admit(BlockId(id), &ctx(), &mut no_victim)
    }

    #[test]
    fn first_sighting_rejected_re_reference_admitted() {
        let mut g = GhostProbation::new(8);
        assert!(!admit(&mut g, 1), "probation first");
        assert!(g.contains(BlockId(1)));
        assert!(admit(&mut g, 1), "re-reference admits");
        assert!(!g.contains(BlockId(1)), "admission consumes the ghost entry");
    }

    #[test]
    fn evicted_blocks_get_a_second_chance() {
        let mut g = GhostProbation::new(8);
        g.on_evict(BlockId(9));
        assert!(admit(&mut g, 9));
    }

    #[test]
    fn ghost_capacity_is_bounded_lru() {
        let mut g = GhostProbation::new(3);
        for id in 0..10u64 {
            assert!(!admit(&mut g, id));
            assert!(g.len() <= g.capacity());
        }
        // Only the 3 most recent survive; old probation entries expired.
        assert!(!g.contains(BlockId(0)));
        assert!(g.contains(BlockId(9)));
        assert!(!admit(&mut g, 0), "expired probation restarts");
    }

    #[test]
    fn churn_reuses_slab_slots() {
        // One never-re-referenced probation member plus thousands of
        // probation/admission pairs: the list slab must stay bounded by
        // the peak live membership (no stale entries, no compaction debt).
        let mut g = GhostProbation::new(8);
        assert!(!admit(&mut g, 999_999));
        for id in 0..10_000u64 {
            assert!(!admit(&mut g, id), "first sighting rejected");
            assert!(admit(&mut g, id), "re-reference admitted");
        }
        assert!(g.len() <= g.capacity());
        assert!(
            g.ghost.slots() <= g.capacity(),
            "slab grew to {} slots for {} members",
            g.ghost.slots(),
            g.len()
        );
    }

    #[test]
    fn touching_refreshes_recency() {
        let mut g = GhostProbation::new(2);
        assert!(!admit(&mut g, 1));
        assert!(!admit(&mut g, 2));
        g.on_evict(BlockId(1)); // refresh 1 as most recent
        assert!(!admit(&mut g, 3)); // evicts 2, not 1
        assert!(g.contains(BlockId(1)));
        assert!(!g.contains(BlockId(2)));
    }
}
