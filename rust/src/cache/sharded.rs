//! Sharded concurrent cache front.
//!
//! A `ShardedCache` partitions the block id space across N independently
//! locked shards, each a full [`BlockCache`] wrapping its own
//! [`CachePolicy`] instance from the registry (LRU, H-SVM-LRU, ARC, LFU,
//! …). Blocks are routed with the same Fibonacci-mix hash the rest of the
//! crate uses for id keys ([`crate::util::fasthash`]), so the sequential
//! ids the NameNode hands out spread uniformly.
//!
//! Design rules:
//!
//! * **shards = 1 is the identity.** Every block maps to shard 0 and the
//!   wrapped policy sees exactly the request stream a bare `BlockCache`
//!   would — hit/miss/eviction parity is property-tested in
//!   rust/tests/property_sharded.rs.
//! * **No cross-shard locking.** Each access touches exactly one shard's
//!   `Mutex`; per-shard [`ShardStats`] accumulate under that same lock and
//!   are merged on demand, so shard workers on `std::thread::scope` never
//!   contend on a shared counter (see `sim::parallel` and
//!   `experiments::sharded_replay`).
//! * **Exact capacity split.** Total capacity divides across shards with
//!   the remainder going to the first shards, so the shard capacities sum
//!   to the configured total and the multi-shard occupancy invariant
//!   `used() <= capacity()` holds by construction.

use std::hash::Hasher;
use std::sync::Mutex;

use crate::hdfs::BlockId;
use crate::util::fasthash::IdHasher;

use super::admission::{make_admission, AdmissionPolicy, AlwaysAdmit};
use super::registry::make_policy;
use super::{AccessContext, AccessOutcome, BlockCache, CachePolicy};

/// Route a block to its shard: high bits of the Fibonacci id mix, so
/// sequential NameNode ids land on different shards than a plain modulo
/// would give and the distribution stays uniform for any shard count.
pub fn shard_of(block: BlockId, n_shards: usize) -> usize {
    if n_shards <= 1 {
        return 0;
    }
    let mut h = IdHasher::default();
    h.write_u64(block.0);
    ((h.finish() >> 32) as usize) % n_shards
}

/// Per-shard access counters; merged across shards (and across DataNodes by
/// the coordinator) with [`ShardStats::merge`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardStats {
    pub requests: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub insertions: u64,
    /// Candidate inserts the admission layer allowed (see
    /// [`crate::cache::admission::AdmissionStats`]; always 0-rejected under
    /// the default `always` admission).
    pub admitted: u64,
    /// Candidate inserts the admission layer refused.
    pub rejected: u64,
}

impl ShardStats {
    pub fn merge(&mut self, other: &ShardStats) {
        self.requests += other.requests;
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
        self.insertions += other.insertions;
        self.admitted += other.admitted;
        self.rejected += other.rejected;
    }

    pub fn hit_ratio(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.hits as f64 / self.requests as f64
        }
    }
}

struct Shard {
    cache: BlockCache,
    stats: ShardStats,
}

/// N independently locked [`BlockCache`] shards behind one front.
///
/// All methods take `&self`: the per-shard `Mutex` provides interior
/// mutability, which is what lets trace replay share one `ShardedCache`
/// across scoped worker threads without `unsafe`.
pub struct ShardedCache {
    shards: Vec<Mutex<Shard>>,
    capacity: u64,
    /// Captured at construction (every shard wraps the same policy /
    /// admission type) so the name getters never take a shard lock.
    policy_name: &'static str,
    admission_name: &'static str,
}

impl ShardedCache {
    /// Build from one policy instance per shard (the shard count is
    /// `policies.len()`). Total capacity is split evenly with the remainder
    /// on the first shards so the per-shard capacities sum exactly.
    pub fn new(policies: Vec<Box<dyn CachePolicy>>, total_capacity: u64) -> Self {
        let admissions = policies
            .iter()
            .map(|_| Box::new(AlwaysAdmit) as Box<dyn AdmissionPolicy>)
            .collect();
        Self::with_admission(policies, admissions, total_capacity)
    }

    /// Build with one admission-policy instance per shard (paired with
    /// `policies` by index). Per-shard admission state lives behind the
    /// shard's own lock, so the hot path stays lock-free across shards.
    pub fn with_admission(
        policies: Vec<Box<dyn CachePolicy>>,
        admissions: Vec<Box<dyn AdmissionPolicy>>,
        total_capacity: u64,
    ) -> Self {
        assert!(!policies.is_empty(), "sharded cache needs at least one shard");
        assert_eq!(
            policies.len(),
            admissions.len(),
            "one admission policy per shard"
        );
        let policy_name = policies[0].name();
        let admission_name = admissions[0].name();
        let n = policies.len() as u64;
        let base = total_capacity / n;
        let rem = total_capacity % n;
        let shards = policies
            .into_iter()
            .zip(admissions)
            .enumerate()
            .map(|(i, (policy, admission))| {
                let cap = base + u64::from((i as u64) < rem);
                Mutex::new(Shard {
                    cache: BlockCache::with_admission(policy, admission, cap),
                    stats: ShardStats::default(),
                })
            })
            .collect();
        ShardedCache { shards, capacity: total_capacity, policy_name, admission_name }
    }

    /// Build `n_shards` shards of the registry policy `name` (None for an
    /// unknown policy name).
    pub fn from_registry(name: &str, n_shards: usize, total_capacity: u64) -> Option<Self> {
        Self::from_registry_with_admission(name, "always", n_shards, total_capacity)
    }

    /// Build `n_shards` shards of the registry policy `name`, each guarded
    /// by its own instance of the registry admission policy `admission`
    /// (None when either name is unknown).
    pub fn from_registry_with_admission(
        name: &str,
        admission: &str,
        n_shards: usize,
        total_capacity: u64,
    ) -> Option<Self> {
        let n = n_shards.max(1);
        let policies = (0..n).map(|_| make_policy(name)).collect::<Option<Vec<_>>>()?;
        let admissions = (0..n)
            .map(|_| make_admission(admission))
            .collect::<Option<Vec<_>>>()?;
        Some(Self::with_admission(policies, admissions, total_capacity))
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Shard index this block routes to.
    pub fn shard_of(&self, block: BlockId) -> usize {
        shard_of(block, self.shards.len())
    }

    /// Wrapped policy name, captured at construction — lock-free, callable
    /// from reporting paths while shard workers hold the locks.
    pub fn policy_name(&self) -> &'static str {
        self.policy_name
    }

    /// Admission policy name, captured at construction — lock-free.
    pub fn admission_name(&self) -> &'static str {
        self.admission_name
    }

    /// The full access path on the owning shard: hit (policy notified) or
    /// miss + insertion with evictions as needed. Stats accumulate on the
    /// same shard under the same lock.
    pub fn access_or_insert(&self, block: BlockId, ctx: &AccessContext) -> AccessOutcome {
        let mut shard = self.shard(block);
        let outcome = shard.cache.access_or_insert(block, ctx);
        shard.stats.requests += 1;
        if outcome.hit {
            shard.stats.hits += 1;
        } else {
            shard.stats.misses += 1;
            shard.stats.insertions += u64::from(outcome.inserted);
        }
        shard.stats.evictions += outcome.evicted.len() as u64;
        Self::sync_admission(&mut shard);
        outcome
    }

    /// Insert a missing block on its shard, evicting per policy until it
    /// fits. Returns the evicted blocks (all from the same shard). Counts
    /// as a missed request, so `stats().hit_ratio()` stays meaningful for
    /// callers (like the coordinator) that route misses here instead of
    /// through `access_or_insert`.
    pub fn insert(&self, block: BlockId, ctx: &AccessContext) -> Vec<BlockId> {
        let mut shard = self.shard(block);
        let evicted = shard.cache.insert(block, ctx);
        shard.stats.requests += 1;
        shard.stats.misses += 1;
        shard.stats.evictions += evicted.len() as u64;
        shard.stats.insertions += u64::from(shard.cache.contains(block));
        Self::sync_admission(&mut shard);
        evicted
    }

    /// Mirror the shard cache's admission counters into the shard stats so
    /// per-shard and merged stats always carry them.
    fn sync_admission(shard: &mut Shard) {
        let a = shard.cache.admission_stats();
        shard.stats.admitted = a.admitted;
        shard.stats.rejected = a.rejected;
    }

    /// Externally remove a block (user uncache directive).
    pub fn remove(&self, block: BlockId) -> bool {
        self.shard(block).cache.remove(block)
    }

    pub fn contains(&self, block: BlockId) -> bool {
        self.shard(block).cache.contains(block)
    }

    /// Bytes cached across all shards.
    pub fn used(&self) -> u64 {
        self.fold(0u64, |acc, s| acc + s.cache.used())
    }

    pub fn free(&self) -> u64 {
        self.capacity - self.used()
    }

    /// Blocks cached across all shards.
    pub fn len(&self) -> usize {
        self.fold(0usize, |acc, s| acc + s.cache.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All cached blocks, merged across shards and sorted by id.
    pub fn cached_blocks(&self) -> Vec<BlockId> {
        let mut all = self.fold(Vec::new(), |mut acc, s| {
            acc.extend(s.cache.cached_blocks());
            acc
        });
        all.sort_unstable();
        all
    }

    /// Merged access counters across all shards.
    pub fn stats(&self) -> ShardStats {
        self.fold(ShardStats::default(), |mut acc, s| {
            acc.merge(&s.stats);
            acc
        })
    }

    /// Hit ratio computed from the merged counters — THE hit-ratio of a
    /// sharded replay (callers must not recompute it from per-shard parts).
    pub fn hit_ratio(&self) -> f64 {
        self.stats().hit_ratio()
    }

    /// Per-shard counters, in shard order.
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.shards
            .iter()
            .map(|s| s.lock().expect("shard poisoned").stats)
            .collect()
    }

    /// Counters of one shard.
    pub fn stats_of(&self, shard: usize) -> ShardStats {
        self.shards[shard].lock().expect("shard poisoned").stats
    }

    /// Zero the access counters on every shard (cached contents and learned
    /// admission state stay).
    pub fn reset_stats(&self) {
        for s in &self.shards {
            let mut shard = s.lock().expect("shard poisoned");
            shard.stats = ShardStats::default();
            shard.cache.reset_admission_stats();
        }
    }

    fn shard(&self, block: BlockId) -> std::sync::MutexGuard<'_, Shard> {
        self.shards[self.shard_of(block)].lock().expect("shard poisoned")
    }

    fn fold<T, F: FnMut(T, &Shard) -> T>(&self, init: T, mut f: F) -> T {
        let mut acc = init;
        for s in &self.shards {
            let guard = s.lock().expect("shard poisoned");
            acc = f(acc, &guard);
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::super::lru::Lru;
    use super::*;
    use crate::sim::SimTime;

    fn ctx(t: u64, size: u64) -> AccessContext {
        AccessContext::simple(SimTime(t), size)
    }

    fn lru_shards(n: usize) -> Vec<Box<dyn CachePolicy>> {
        (0..n).map(|_| Box::new(Lru::new()) as Box<dyn CachePolicy>).collect()
    }

    #[test]
    fn single_shard_matches_bare_block_cache() {
        let mut bare = BlockCache::new(Box::new(Lru::new()), 3);
        let sharded = ShardedCache::new(lru_shards(1), 3);
        for t in 0..200u64 {
            let b = BlockId((t * 7 + t % 5) % 11);
            let c = ctx(t, 1);
            let a = bare.access_or_insert(b, &c);
            let s = sharded.access_or_insert(b, &c);
            assert_eq!(a, s, "divergence at t={t}");
        }
        assert_eq!(bare.cached_blocks(), sharded.cached_blocks());
        assert_eq!(bare.used(), sharded.used());
    }

    #[test]
    fn capacity_splits_exactly() {
        let sharded = ShardedCache::new(lru_shards(3), 10);
        assert_eq!(sharded.capacity(), 10);
        // Fill the whole keyspace; occupancy can never exceed the total.
        for t in 0..500u64 {
            sharded.access_or_insert(BlockId(t), &ctx(t, 1));
            assert!(sharded.used() <= sharded.capacity());
        }
        let stats = sharded.stats();
        assert_eq!(stats.requests, 500);
        assert_eq!(stats.hits + stats.misses, stats.requests);
        // Conservation: what came in and never left is still cached.
        assert_eq!(stats.insertions - stats.evictions, sharded.len() as u64);
    }

    #[test]
    fn routing_is_stable_and_partitioned() {
        let sharded = ShardedCache::new(lru_shards(4), 64);
        for id in 0..256u64 {
            let b = BlockId(id);
            let s = sharded.shard_of(b);
            assert_eq!(s, shard_of(b, 4));
            assert!(s < 4);
            sharded.access_or_insert(b, &ctx(id, 1));
        }
        // Fibonacci mix must actually spread sequential ids.
        let per_shard = sharded.shard_stats();
        assert!(per_shard.iter().all(|s| s.requests > 0), "{per_shard:?}");
    }

    #[test]
    fn stats_merge_counts_all_shards() {
        let sharded = ShardedCache::new(lru_shards(2), 4);
        for t in 0..10u64 {
            sharded.access_or_insert(BlockId(t % 3), &ctx(t, 1));
        }
        let merged = sharded.stats();
        let by_hand = sharded
            .shard_stats()
            .iter()
            .fold(ShardStats::default(), |mut acc, s| {
                acc.merge(s);
                acc
            });
        assert_eq!(merged, by_hand);
        sharded.reset_stats();
        assert_eq!(sharded.stats(), ShardStats::default());
        assert!(!sharded.is_empty(), "reset_stats must keep contents");
    }

    #[test]
    fn remove_and_contains_route_consistently() {
        let sharded = ShardedCache::new(lru_shards(4), 16);
        sharded.access_or_insert(BlockId(9), &ctx(0, 1));
        assert!(sharded.contains(BlockId(9)));
        assert!(sharded.remove(BlockId(9)));
        assert!(!sharded.remove(BlockId(9)));
        assert!(!sharded.contains(BlockId(9)));
        assert_eq!(sharded.used(), 0);
    }

    #[test]
    fn registry_constructor_rejects_unknown_policy() {
        assert!(ShardedCache::from_registry("nonsense", 2, 8).is_none());
        let c = ShardedCache::from_registry("h-svm-lru", 2, 8).unwrap();
        assert_eq!(c.n_shards(), 2);
        assert_eq!(c.policy_name(), "h-svm-lru");
        assert_eq!(c.admission_name(), "always");
    }

    #[test]
    fn registry_constructor_rejects_unknown_admission() {
        assert!(ShardedCache::from_registry_with_admission("lru", "nonsense", 2, 8).is_none());
        let c = ShardedCache::from_registry_with_admission("lru", "tinylfu", 2, 8).unwrap();
        assert_eq!(c.admission_name(), "tinylfu");
    }

    #[test]
    fn admission_counters_flow_into_merged_stats() {
        // Ghost probation: every first sighting is refused, the second
        // admits — both outcomes must show up in the merged counters.
        let c = ShardedCache::from_registry_with_admission("lru", "ghost", 2, 8).unwrap();
        for round in 0..2u64 {
            for id in 0..6u64 {
                c.access_or_insert(BlockId(id), &ctx(round * 6 + id, 1));
            }
        }
        let stats = c.stats();
        assert_eq!(stats.rejected, 6, "first sightings on probation");
        assert_eq!(stats.admitted, 6, "re-references admitted");
        assert_eq!(stats.insertions, 6);
        let by_hand = c.shard_stats().iter().fold(ShardStats::default(), |mut acc, s| {
            acc.merge(s);
            acc
        });
        assert_eq!(stats, by_hand, "per-shard admission counters must merge");
        assert_eq!(c.hit_ratio(), stats.hit_ratio());
        c.reset_stats();
        assert_eq!(c.stats(), ShardStats::default());
    }

    #[test]
    fn name_getters_are_lock_free() {
        // The names are captured at construction: they must be readable
        // even while every shard lock (including shard 0's) is held — the
        // pre-fix implementation deadlocked here.
        let c = ShardedCache::from_registry_with_admission("h-svm-lru", "tinylfu", 2, 8).unwrap();
        let guards: Vec<_> = c.shards.iter().map(|s| s.lock().unwrap()).collect();
        assert_eq!(c.policy_name(), "h-svm-lru");
        assert_eq!(c.admission_name(), "tinylfu");
        drop(guards);
    }

    #[test]
    fn concurrent_shard_workers_do_not_interfere() {
        // Each worker replays only blocks that route to its shard; totals
        // must equal the sequential sum (the no-data-races smoke test).
        let n = 4usize;
        let sharded = ShardedCache::new(lru_shards(n), 8 * n as u64);
        let ids: Vec<BlockId> = (0..400u64).map(BlockId).collect();
        std::thread::scope(|scope| {
            for w in 0..n {
                let sharded = &sharded;
                let ids = &ids;
                scope.spawn(move || {
                    for (t, &b) in ids.iter().enumerate() {
                        if shard_of(b, n) == w {
                            sharded.access_or_insert(b, &ctx(t as u64, 1));
                        }
                    }
                });
            }
        });
        let stats = sharded.stats();
        assert_eq!(stats.requests, 400);
        assert_eq!(stats.hits + stats.misses, 400);
        assert!(sharded.used() <= sharded.capacity());
    }
}
