//! Sharded concurrent cache front.
//!
//! A `ShardedCache` partitions the block id space across N independently
//! locked shards, each a full [`BlockCache`] wrapping its own
//! [`CachePolicy`] instance from the registry (LRU, H-SVM-LRU, ARC, LFU,
//! …). Blocks are routed with the same Fibonacci-mix hash the rest of the
//! crate uses for id keys ([`crate::util::fasthash`]), so the sequential
//! ids the NameNode hands out spread uniformly.
//!
//! Design rules:
//!
//! * **shards = 1 is the identity.** Every block maps to shard 0 and the
//!   wrapped policy sees exactly the request stream a bare `BlockCache`
//!   would — hit/miss/eviction parity is property-tested in
//!   rust/tests/property_sharded.rs.
//! * **No cross-shard locking.** Each access touches exactly one shard's
//!   `Mutex`, so shard workers on `std::thread::scope` never contend (see
//!   `sim::parallel` and `experiments::sharded_replay`).
//! * **Lock-free stats reads.** Per-shard counters live in a
//!   [`AtomicShardStats`] seqlock block *outside* the shard `Mutex`
//!   (written under the lock, read without it): `stats()`, `stats_of()`,
//!   `used()`, `len()` and `hit_ratio()` never acquire a shard lock and
//!   never serialize the replay writers (see `cache::shard_stats`).
//! * **Exact capacity split.** Total capacity divides across shards with
//!   the remainder going to the first shards, so the shard capacities sum
//!   to the configured total and the multi-shard occupancy invariant
//!   `used() <= capacity()` holds by construction.

use std::hash::Hasher;
use std::sync::Mutex;

use crate::hdfs::BlockId;
use crate::util::fasthash::IdHasher;

use super::admission::{make_admission, AdmissionPolicy, AlwaysAdmit};
use super::registry::make_policy;
pub use super::shard_stats::{AtomicShardStats, ShardSnapshot, ShardStats};
use super::{AccessContext, AccessOutcome, BlockCache, CachePolicy};

/// Route a block to its shard: high bits of the Fibonacci id mix, so
/// sequential NameNode ids land on different shards than a plain modulo
/// would give and the distribution stays uniform for any shard count.
pub fn shard_of(block: BlockId, n_shards: usize) -> usize {
    if n_shards <= 1 {
        return 0;
    }
    let mut h = IdHasher::default();
    h.write_u64(block.0);
    ((h.finish() >> 32) as usize) % n_shards
}

/// N independently locked [`BlockCache`] shards behind one front.
///
/// All methods take `&self`: the per-shard `Mutex` provides interior
/// mutability, which is what lets trace replay share one `ShardedCache`
/// across scoped worker threads without `unsafe`. Counters live beside
/// (not under) each lock in an [`AtomicShardStats`] block, so the stats
/// read path is entirely lock-free.
pub struct ShardedCache {
    shards: Vec<Mutex<BlockCache>>,
    /// One seqlock stats block per shard, indexed like `shards`. Written
    /// only while holding the same index's `Mutex` (the single-writer
    /// discipline the seqlock requires); read from anywhere, lock-free.
    stats: Vec<AtomicShardStats>,
    capacity: u64,
    /// Captured at construction (every shard wraps the same policy /
    /// admission type) so the name getters never take a shard lock.
    policy_name: &'static str,
    admission_name: &'static str,
}

impl ShardedCache {
    /// Build from one policy instance per shard (the shard count is
    /// `policies.len()`). Total capacity is split evenly with the remainder
    /// on the first shards so the per-shard capacities sum exactly.
    pub fn new(policies: Vec<Box<dyn CachePolicy>>, total_capacity: u64) -> Self {
        let admissions = policies
            .iter()
            .map(|_| Box::new(AlwaysAdmit) as Box<dyn AdmissionPolicy>)
            .collect();
        Self::with_admission(policies, admissions, total_capacity)
    }

    /// Build with one admission-policy instance per shard (paired with
    /// `policies` by index). Per-shard admission state lives behind the
    /// shard's own lock, so the hot path stays lock-free across shards.
    pub fn with_admission(
        policies: Vec<Box<dyn CachePolicy>>,
        admissions: Vec<Box<dyn AdmissionPolicy>>,
        total_capacity: u64,
    ) -> Self {
        assert!(!policies.is_empty(), "sharded cache needs at least one shard");
        assert_eq!(
            policies.len(),
            admissions.len(),
            "one admission policy per shard"
        );
        let policy_name = policies[0].name();
        let admission_name = admissions[0].name();
        let n = policies.len() as u64;
        let base = total_capacity / n;
        let rem = total_capacity % n;
        let stats = (0..policies.len()).map(|_| AtomicShardStats::new()).collect();
        let shards = policies
            .into_iter()
            .zip(admissions)
            .enumerate()
            .map(|(i, (policy, admission))| {
                let cap = base + u64::from((i as u64) < rem);
                Mutex::new(BlockCache::with_admission(policy, admission, cap))
            })
            .collect();
        ShardedCache { shards, stats, capacity: total_capacity, policy_name, admission_name }
    }

    /// Build `n_shards` shards of the registry policy `name` (None for an
    /// unknown policy name).
    pub fn from_registry(name: &str, n_shards: usize, total_capacity: u64) -> Option<Self> {
        Self::from_registry_with_admission(name, "always", n_shards, total_capacity)
    }

    /// Build `n_shards` shards of the registry policy `name`, each guarded
    /// by its own instance of the registry admission policy `admission`
    /// (None when either name is unknown).
    pub fn from_registry_with_admission(
        name: &str,
        admission: &str,
        n_shards: usize,
        total_capacity: u64,
    ) -> Option<Self> {
        let n = n_shards.max(1);
        let policies = (0..n).map(|_| make_policy(name)).collect::<Option<Vec<_>>>()?;
        let admissions = (0..n)
            .map(|_| make_admission(admission))
            .collect::<Option<Vec<_>>>()?;
        Some(Self::with_admission(policies, admissions, total_capacity))
    }

    /// Number of shards (policy instances).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total capacity in bytes across all shards.
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Shard index this block routes to.
    pub fn shard_of(&self, block: BlockId) -> usize {
        shard_of(block, self.shards.len())
    }

    /// Wrapped policy name, captured at construction — lock-free, callable
    /// from reporting paths while shard workers hold the locks.
    pub fn policy_name(&self) -> &'static str {
        self.policy_name
    }

    /// Admission policy name, captured at construction — lock-free.
    pub fn admission_name(&self) -> &'static str {
        self.admission_name
    }

    /// The full access path on the owning shard: hit (policy notified) or
    /// miss + insertion with evictions as needed. Stats land in the
    /// shard's atomic block inside one seqlock write section, while the
    /// shard lock is still held (the single-writer guarantee).
    pub fn access_or_insert(&self, block: BlockId, ctx: &AccessContext) -> AccessOutcome {
        let idx = self.shard_of(block);
        let mut cache = self.lock_shard(idx);
        let outcome = cache.access_or_insert(block, ctx);
        let a = cache.admission_stats();
        let mut w = self.stats[idx].write();
        w.record_request(outcome.hit, outcome.inserted, outcome.evicted.len() as u64);
        w.set_admission(a.admitted, a.rejected);
        w.set_occupancy(cache.used(), cache.len() as u64);
        outcome
    }

    /// Insert a missing block on its shard, evicting per policy until it
    /// fits. Returns the evicted blocks (all from the same shard). Counts
    /// as a missed request, so `stats().hit_ratio()` stays meaningful for
    /// callers (like the coordinator) that route misses here instead of
    /// through `access_or_insert`.
    pub fn insert(&self, block: BlockId, ctx: &AccessContext) -> Vec<BlockId> {
        let idx = self.shard_of(block);
        let mut cache = self.lock_shard(idx);
        let evicted = cache.insert(block, ctx);
        let inserted = cache.contains(block);
        let a = cache.admission_stats();
        let mut w = self.stats[idx].write();
        w.record_request(false, inserted, evicted.len() as u64);
        w.set_admission(a.admitted, a.rejected);
        w.set_occupancy(cache.used(), cache.len() as u64);
        drop(w);
        drop(cache);
        evicted
    }

    /// Externally remove a block (user uncache directive).
    pub fn remove(&self, block: BlockId) -> bool {
        let idx = self.shard_of(block);
        let mut cache = self.lock_shard(idx);
        let removed = cache.remove(block);
        if removed {
            let mut w = self.stats[idx].write();
            w.set_occupancy(cache.used(), cache.len() as u64);
        }
        removed
    }

    /// Whether `block` is currently cached (locks only its shard).
    pub fn contains(&self, block: BlockId) -> bool {
        self.lock_shard(self.shard_of(block)).contains(block)
    }

    /// Bytes cached across all shards — lock-free (occupancy mirrors in
    /// the atomic stats blocks).
    pub fn used(&self) -> u64 {
        self.stats.iter().map(|s| s.snapshot().used).sum()
    }

    /// Unused capacity in bytes (`capacity - used`).
    pub fn free(&self) -> u64 {
        self.capacity.saturating_sub(self.used())
    }

    /// Blocks cached across all shards — lock-free.
    pub fn len(&self) -> usize {
        self.stats.iter().map(|s| s.snapshot().blocks).sum::<u64>() as usize
    }

    /// Whether no shard holds any block.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All cached blocks, merged across shards and sorted by id. (Reads
    /// cache contents, so this one does take the shard locks — it is a
    /// diagnostics path, not a counter read.)
    pub fn cached_blocks(&self) -> Vec<BlockId> {
        let mut all = Vec::new();
        for shard in &self.shards {
            all.extend(shard.lock().expect("shard poisoned").cached_blocks());
        }
        all.sort_unstable();
        all
    }

    /// Merged access counters across all shards — lock-free; each
    /// per-shard snapshot is seqlock-consistent and the merged invariants
    /// (`hits + misses == requests`) are sums of per-shard ones.
    pub fn stats(&self) -> ShardStats {
        let mut acc = ShardStats::default();
        for s in &self.stats {
            acc.merge(&s.stats());
        }
        acc
    }

    /// Hit ratio computed from the merged counters — THE hit-ratio of a
    /// sharded replay (callers must not recompute it from per-shard parts).
    pub fn hit_ratio(&self) -> f64 {
        self.stats().hit_ratio()
    }

    /// Per-shard counters, in shard order — lock-free.
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.stats.iter().map(|s| s.stats()).collect()
    }

    /// Counters of one shard — lock-free.
    pub fn stats_of(&self, shard: usize) -> ShardStats {
        self.stats[shard].stats()
    }

    /// One consistent (counters + occupancy) view of one shard —
    /// lock-free.
    pub fn snapshot_of(&self, shard: usize) -> ShardSnapshot {
        self.stats[shard].snapshot()
    }

    /// Zero the access counters on every shard (cached contents and learned
    /// admission state stay).
    pub fn reset_stats(&self) {
        for (idx, shard) in self.shards.iter().enumerate() {
            let mut cache = shard.lock().expect("shard poisoned");
            cache.reset_admission_stats();
            let mut w = self.stats[idx].write();
            w.reset_counters();
            // Occupancy mirrors stay: reset_stats keeps the contents.
            w.set_occupancy(cache.used(), cache.len() as u64);
        }
    }

    fn lock_shard(&self, idx: usize) -> std::sync::MutexGuard<'_, BlockCache> {
        self.shards[idx].lock().expect("shard poisoned")
    }
}

#[cfg(test)]
mod tests {
    use super::super::lru::Lru;
    use super::*;
    use crate::sim::SimTime;

    fn ctx(t: u64, size: u64) -> AccessContext {
        AccessContext::simple(SimTime(t), size)
    }

    fn lru_shards(n: usize) -> Vec<Box<dyn CachePolicy>> {
        (0..n).map(|_| Box::new(Lru::new()) as Box<dyn CachePolicy>).collect()
    }

    #[test]
    fn single_shard_matches_bare_block_cache() {
        let mut bare = BlockCache::new(Box::new(Lru::new()), 3);
        let sharded = ShardedCache::new(lru_shards(1), 3);
        for t in 0..200u64 {
            let b = BlockId((t * 7 + t % 5) % 11);
            let c = ctx(t, 1);
            let a = bare.access_or_insert(b, &c);
            let s = sharded.access_or_insert(b, &c);
            assert_eq!(a, s, "divergence at t={t}");
        }
        assert_eq!(bare.cached_blocks(), sharded.cached_blocks());
        assert_eq!(bare.used(), sharded.used());
    }

    #[test]
    fn capacity_splits_exactly() {
        let sharded = ShardedCache::new(lru_shards(3), 10);
        assert_eq!(sharded.capacity(), 10);
        // Fill the whole keyspace; occupancy can never exceed the total.
        for t in 0..500u64 {
            sharded.access_or_insert(BlockId(t), &ctx(t, 1));
            assert!(sharded.used() <= sharded.capacity());
        }
        let stats = sharded.stats();
        assert_eq!(stats.requests, 500);
        assert_eq!(stats.hits + stats.misses, stats.requests);
        // Conservation: what came in and never left is still cached.
        assert_eq!(stats.insertions - stats.evictions, sharded.len() as u64);
    }

    #[test]
    fn routing_is_stable_and_partitioned() {
        let sharded = ShardedCache::new(lru_shards(4), 64);
        for id in 0..256u64 {
            let b = BlockId(id);
            let s = sharded.shard_of(b);
            assert_eq!(s, shard_of(b, 4));
            assert!(s < 4);
            sharded.access_or_insert(b, &ctx(id, 1));
        }
        // Fibonacci mix must actually spread sequential ids.
        let per_shard = sharded.shard_stats();
        assert!(per_shard.iter().all(|s| s.requests > 0), "{per_shard:?}");
    }

    #[test]
    fn stats_merge_counts_all_shards() {
        let sharded = ShardedCache::new(lru_shards(2), 4);
        for t in 0..10u64 {
            sharded.access_or_insert(BlockId(t % 3), &ctx(t, 1));
        }
        let merged = sharded.stats();
        let by_hand = sharded
            .shard_stats()
            .iter()
            .fold(ShardStats::default(), |mut acc, s| {
                acc.merge(s);
                acc
            });
        assert_eq!(merged, by_hand);
        sharded.reset_stats();
        assert_eq!(sharded.stats(), ShardStats::default());
        assert!(!sharded.is_empty(), "reset_stats must keep contents");
    }

    #[test]
    fn remove_and_contains_route_consistently() {
        let sharded = ShardedCache::new(lru_shards(4), 16);
        sharded.access_or_insert(BlockId(9), &ctx(0, 1));
        assert!(sharded.contains(BlockId(9)));
        assert!(sharded.remove(BlockId(9)));
        assert!(!sharded.remove(BlockId(9)));
        assert!(!sharded.contains(BlockId(9)));
        assert_eq!(sharded.used(), 0);
    }

    #[test]
    fn registry_constructor_rejects_unknown_policy() {
        assert!(ShardedCache::from_registry("nonsense", 2, 8).is_none());
        let c = ShardedCache::from_registry("h-svm-lru", 2, 8).unwrap();
        assert_eq!(c.n_shards(), 2);
        assert_eq!(c.policy_name(), "h-svm-lru");
        assert_eq!(c.admission_name(), "always");
    }

    #[test]
    fn registry_constructor_rejects_unknown_admission() {
        assert!(ShardedCache::from_registry_with_admission("lru", "nonsense", 2, 8).is_none());
        let c = ShardedCache::from_registry_with_admission("lru", "tinylfu", 2, 8).unwrap();
        assert_eq!(c.admission_name(), "tinylfu");
    }

    #[test]
    fn admission_counters_flow_into_merged_stats() {
        // Ghost probation: every first sighting is refused, the second
        // admits — both outcomes must show up in the merged counters.
        let c = ShardedCache::from_registry_with_admission("lru", "ghost", 2, 8).unwrap();
        for round in 0..2u64 {
            for id in 0..6u64 {
                c.access_or_insert(BlockId(id), &ctx(round * 6 + id, 1));
            }
        }
        let stats = c.stats();
        assert_eq!(stats.rejected, 6, "first sightings on probation");
        assert_eq!(stats.admitted, 6, "re-references admitted");
        assert_eq!(stats.insertions, 6);
        let by_hand = c.shard_stats().iter().fold(ShardStats::default(), |mut acc, s| {
            acc.merge(s);
            acc
        });
        assert_eq!(stats, by_hand, "per-shard admission counters must merge");
        assert_eq!(c.hit_ratio(), stats.hit_ratio());
        c.reset_stats();
        assert_eq!(c.stats(), ShardStats::default());
    }

    #[test]
    fn name_getters_are_lock_free() {
        // The names are captured at construction: they must be readable
        // even while every shard lock (including shard 0's) is held — the
        // pre-fix implementation deadlocked here.
        let c = ShardedCache::from_registry_with_admission("h-svm-lru", "tinylfu", 2, 8).unwrap();
        let guards: Vec<_> = c.shards.iter().map(|s| s.lock().unwrap()).collect();
        assert_eq!(c.policy_name(), "h-svm-lru");
        assert_eq!(c.admission_name(), "tinylfu");
        drop(guards);
    }

    #[test]
    fn stats_reads_never_take_a_shard_lock() {
        // The acceptance criterion of the lock split: every counter read
        // must work while every shard Mutex is held by someone else. The
        // pre-split implementation deadlocked on the first stats() call.
        let c = ShardedCache::from_registry("lru", 4, 16).unwrap();
        for t in 0..32u64 {
            c.access_or_insert(BlockId(t % 8), &ctx(t, 1));
        }
        let expected = c.stats();
        let expected_used = c.used();
        let guards: Vec<_> = c.shards.iter().map(|s| s.lock().unwrap()).collect();
        assert_eq!(c.stats(), expected);
        let per_shard: u64 = (0..4).map(|i| c.stats_of(i).requests).sum();
        assert_eq!(per_shard, expected.requests);
        assert_eq!(c.shard_stats().len(), 4);
        assert_eq!(c.used(), expected_used);
        assert_eq!(c.len() as u64, expected_used, "unit blocks: len == used");
        assert_eq!(c.hit_ratio(), expected.hit_ratio());
        let snap = c.snapshot_of(0);
        assert_eq!(snap.stats.hits + snap.stats.misses, snap.stats.requests);
        drop(guards);
    }

    #[test]
    fn snapshot_couples_counters_and_occupancy() {
        let c = ShardedCache::from_registry("lru", 1, 4).unwrap();
        for t in 0..6u64 {
            c.access_or_insert(BlockId(t), &ctx(t, 1));
        }
        let snap = c.snapshot_of(0);
        assert_eq!(snap.stats.requests, 6);
        assert_eq!(snap.used, 4, "at capacity");
        assert_eq!(snap.blocks, 4);
        assert_eq!(
            snap.stats.insertions - snap.stats.evictions,
            snap.blocks,
            "conservation inside one snapshot"
        );
    }

    #[test]
    fn concurrent_shard_workers_do_not_interfere() {
        // Each worker replays only blocks that route to its shard; totals
        // must equal the sequential sum (the no-data-races smoke test).
        let n = 4usize;
        let sharded = ShardedCache::new(lru_shards(n), 8 * n as u64);
        let ids: Vec<BlockId> = (0..400u64).map(BlockId).collect();
        std::thread::scope(|scope| {
            for w in 0..n {
                let sharded = &sharded;
                let ids = &ids;
                scope.spawn(move || {
                    for (t, &b) in ids.iter().enumerate() {
                        if shard_of(b, n) == w {
                            sharded.access_or_insert(b, &ctx(t as u64, 1));
                        }
                    }
                });
            }
        });
        let stats = sharded.stats();
        assert_eq!(stats.requests, 400);
        assert_eq!(stats.hits + stats.misses, 400);
        assert!(sharded.used() <= sharded.capacity());
    }
}
