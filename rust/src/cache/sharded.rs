//! Sharded concurrent cache front.
//!
//! A `ShardedCache` partitions the block id space across N independently
//! locked shards, each a full [`BlockCache`] wrapping its own
//! [`CachePolicy`] instance from the registry (LRU, H-SVM-LRU, ARC, LFU,
//! …). Blocks are routed with the same Fibonacci-mix hash the rest of the
//! crate uses for id keys ([`crate::util::fasthash`]), so the sequential
//! ids the NameNode hands out spread uniformly.
//!
//! Design rules:
//!
//! * **shards = 1 is the identity.** Every block maps to shard 0 and the
//!   wrapped policy sees exactly the request stream a bare `BlockCache`
//!   would — hit/miss/eviction parity is property-tested in
//!   rust/tests/property_sharded.rs.
//! * **No cross-shard locking.** Each access touches exactly one shard's
//!   `Mutex`, so shard workers on `std::thread::scope` never contend (see
//!   `sim::parallel` and `experiments::sharded_replay`).
//! * **Lock-free stats reads.** Per-shard counters live in a
//!   [`AtomicShardStats`] seqlock block *outside* the shard `Mutex`
//!   (written under the lock, read without it): `stats()`, `stats_of()`,
//!   `used()`, `len()` and `hit_ratio()` never acquire a shard lock and
//!   never serialize the replay writers (see `cache::shard_stats`).
//! * **Lock-free hit path.** Each shard also carries a [`ReadView`] —
//!   a seqlock-bracketed mirror of its entry table, maintained by the
//!   mutators under the shard lock. A [`ReadHandle`] resolves hits
//!   against the view without locking, counts them at read time
//!   ([`AtomicShardStats::record_lockfree_hit`]) and buffers the recency
//!   updates per [`RecencyConfig`]; drains apply them in batches under
//!   the lock (see `cache::read_path` and docs/CONCURRENCY.md). The
//!   default config (batch 1, immediate drain) is bit-identical to the
//!   fully locked path.
//! * **Exact capacity split.** Total capacity divides across shards with
//!   the remainder going to the first shards, so the shard capacities sum
//!   to the configured total and the multi-shard occupancy invariant
//!   `used() <= capacity()` holds by construction.
//!
//! Construction goes through [`super::builder::CacheBuilder`]; the direct
//! constructors below survive one PR as `#[deprecated]` shims.

use std::hash::Hasher;
use std::sync::Mutex;

use crate::hdfs::BlockId;
use crate::sim::{SimDuration, SimTime};
use crate::util::fasthash::IdHasher;

use super::admission::{make_admission, AdmissionPolicy, AlwaysAdmit};
use super::read_path::{Probe, ReadView, RecencyConfig};
use super::registry::make_policy;
pub use super::shard_stats::{AtomicShardStats, ShardSnapshot, ShardStats};
use super::{AccessContext, AccessOutcome, BlockCache, CachePolicy};

/// Route a block to its shard: high bits of the Fibonacci id mix, so
/// sequential NameNode ids land on different shards than a plain modulo
/// would give and the distribution stays uniform for any shard count.
pub fn shard_of(block: BlockId, n_shards: usize) -> usize {
    if n_shards <= 1 {
        return 0;
    }
    let mut h = IdHasher::default();
    h.write_u64(block.0);
    ((h.finish() >> 32) as usize) % n_shards
}

/// N independently locked [`BlockCache`] shards behind one front.
///
/// All methods take `&self`: the per-shard `Mutex` provides interior
/// mutability, which is what lets trace replay share one `ShardedCache`
/// across scoped worker threads without `unsafe`. Counters live beside
/// (not under) each lock in an [`AtomicShardStats`] block, and residency
/// beside it in a [`ReadView`], so both the stats read path and the hit
/// membership probe are entirely lock-free.
pub struct ShardedCache {
    shards: Vec<Mutex<BlockCache>>,
    /// One seqlock stats block per shard, indexed like `shards`. Written
    /// only while holding the same index's `Mutex` (the single-writer
    /// discipline the seqlock requires); read from anywhere, lock-free.
    /// Exception: the read-path hit counter inside is a multi-writer
    /// relaxed RMW (see [`AtomicShardStats::record_lockfree_hit`]).
    stats: Vec<AtomicShardStats>,
    /// One lock-free membership view per shard, same indexing and the
    /// same single-writer discipline as `stats`: mutated only under the
    /// shard `Mutex`, probed from anywhere.
    views: Vec<ReadView>,
    /// Recency-batching knobs handed to [`ShardedCache::read_handle`].
    recency: RecencyConfig,
    capacity: u64,
    /// Captured at construction (every shard wraps the same policy /
    /// admission type) so the name getters never take a shard lock.
    policy_name: &'static str,
    admission_name: &'static str,
}

impl ShardedCache {
    /// Build from one policy instance per shard (the shard count is
    /// `policies.len()`). Total capacity is split evenly with the remainder
    /// on the first shards so the per-shard capacities sum exactly.
    #[deprecated(
        since = "0.10.0",
        note = "use cache::CacheBuilder::new().policy_with(..).shards(..).capacity(..).build()"
    )]
    pub fn new(policies: Vec<Box<dyn CachePolicy>>, total_capacity: u64) -> Self {
        let admissions = policies
            .iter()
            .map(|_| Box::new(AlwaysAdmit) as Box<dyn AdmissionPolicy>)
            .collect();
        Self::assemble(policies, admissions, total_capacity, RecencyConfig::default())
    }

    /// Build with one admission-policy instance per shard (paired with
    /// `policies` by index). Per-shard admission state lives behind the
    /// shard's own lock, so the hot path stays lock-free across shards.
    #[deprecated(
        since = "0.10.0",
        note = "use cache::CacheBuilder::new().policy_with(..).admission_with(..).build()"
    )]
    pub fn with_admission(
        policies: Vec<Box<dyn CachePolicy>>,
        admissions: Vec<Box<dyn AdmissionPolicy>>,
        total_capacity: u64,
    ) -> Self {
        Self::assemble(policies, admissions, total_capacity, RecencyConfig::default())
    }

    /// Build `n_shards` shards of the registry policy `name` (None for an
    /// unknown policy name).
    #[deprecated(
        since = "0.10.0",
        note = "use cache::CacheBuilder::new().policy(name).shards(..).capacity(..).build()"
    )]
    pub fn from_registry(name: &str, n_shards: usize, total_capacity: u64) -> Option<Self> {
        Self::from_registry_with_admission(name, "always", n_shards, total_capacity)
    }

    /// Build `n_shards` shards of the registry policy `name`, each guarded
    /// by its own instance of the registry admission policy `admission`
    /// (None when either name is unknown).
    #[deprecated(
        since = "0.10.0",
        note = "use cache::CacheBuilder::new().policy(name).admission(name).build()"
    )]
    pub fn from_registry_with_admission(
        name: &str,
        admission: &str,
        n_shards: usize,
        total_capacity: u64,
    ) -> Option<Self> {
        let n = n_shards.max(1);
        let policies = (0..n).map(|_| make_policy(name)).collect::<Option<Vec<_>>>()?;
        let admissions = (0..n)
            .map(|_| make_admission(admission))
            .collect::<Option<Vec<_>>>()?;
        Some(Self::assemble(policies, admissions, total_capacity, RecencyConfig::default()))
    }

    /// Non-deprecated assembly point shared by the deprecated shims and
    /// [`super::builder::CacheBuilder`].
    pub(crate) fn assemble(
        policies: Vec<Box<dyn CachePolicy>>,
        admissions: Vec<Box<dyn AdmissionPolicy>>,
        total_capacity: u64,
        recency: RecencyConfig,
    ) -> Self {
        assert!(!policies.is_empty(), "sharded cache needs at least one shard");
        assert_eq!(
            policies.len(),
            admissions.len(),
            "one admission policy per shard"
        );
        assert!(recency.batch >= 1, "recency batch must be >= 1");
        let policy_name = policies[0].name();
        let admission_name = admissions[0].name();
        let n = policies.len() as u64;
        let base = total_capacity / n;
        let rem = total_capacity % n;
        let stats = (0..policies.len()).map(|_| AtomicShardStats::new()).collect();
        let mut views = Vec::with_capacity(policies.len());
        let shards = policies
            .into_iter()
            .zip(admissions)
            .enumerate()
            .map(|(i, (policy, admission))| {
                let cap = base + u64::from((i as u64) < rem);
                views.push(ReadView::with_slots(ReadView::slots_for_capacity(cap)));
                Mutex::new(BlockCache::assemble(policy, admission, cap))
            })
            .collect();
        ShardedCache {
            shards,
            stats,
            views,
            recency,
            capacity: total_capacity,
            policy_name,
            admission_name,
        }
    }

    /// Number of shards (policy instances).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total capacity in bytes across all shards.
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Shard index this block routes to.
    pub fn shard_of(&self, block: BlockId) -> usize {
        shard_of(block, self.shards.len())
    }

    /// Wrapped policy name, captured at construction — lock-free, callable
    /// from reporting paths while shard workers hold the locks.
    pub fn policy_name(&self) -> &'static str {
        self.policy_name
    }

    /// Admission policy name, captured at construction — lock-free.
    pub fn admission_name(&self) -> &'static str {
        self.admission_name
    }

    /// The recency-batching knobs this cache was built with (the config
    /// [`ShardedCache::read_handle`] hands to new handles).
    pub fn recency_config(&self) -> RecencyConfig {
        self.recency
    }

    /// A per-thread handle onto the lock-free hit path, configured with
    /// the cache's [`RecencyConfig`]. One handle per replay worker; the
    /// handle drains its buffered accesses on drop.
    pub fn read_handle(&self) -> ReadHandle<'_> {
        ReadHandle::new(self, self.recency)
    }

    /// Mirror one mutation's residency changes into the shard's
    /// [`ReadView`] (caller holds the shard lock — the view's
    /// single-writer discipline). Also runs the maintenance heuristics:
    /// tombstone compaction, and saturation recovery with hysteresis
    /// (rebuild only when the true population is back under half the
    /// table, well below the 3/4 saturation bound, so a population
    /// hovering at the threshold cannot thrash rebuilds).
    fn sync_view(
        &self,
        idx: usize,
        cache: &BlockCache,
        inserted: Option<BlockId>,
        evicted: &[BlockId],
    ) {
        let view = &self.views[idx];
        if view.is_saturated() {
            if (cache.len() + 1) * 2 <= view.slots() {
                view.rebuild(cache.blocks_unordered());
            }
            return;
        }
        for &b in evicted {
            view.remove(b);
        }
        if let Some(b) = inserted {
            view.insert(b);
        }
        if view.needs_rebuild() {
            view.rebuild(cache.blocks_unordered());
        }
    }

    /// The full access path on the owning shard: hit (policy notified) or
    /// miss + insertion with evictions as needed. Stats land in the
    /// shard's atomic block inside one seqlock write section, while the
    /// shard lock is still held (the single-writer guarantee).
    pub fn access_or_insert(&self, block: BlockId, ctx: &AccessContext) -> AccessOutcome {
        let idx = self.shard_of(block);
        let mut cache = self.lock_shard(idx);
        let outcome = cache.access_or_insert(block, ctx);
        if !outcome.hit {
            let inserted = outcome.inserted.then_some(block);
            self.sync_view(idx, &cache, inserted, &outcome.evicted);
        }
        let a = cache.admission_stats();
        let mut w = self.stats[idx].write();
        w.record_request(outcome.hit, outcome.inserted, outcome.evicted.len() as u64);
        w.set_admission(a.admitted, a.rejected);
        w.set_occupancy(cache.used(), cache.len() as u64);
        outcome
    }

    /// Insert a missing block on its shard, evicting per policy until it
    /// fits. Returns the evicted blocks (all from the same shard). Counts
    /// as a missed request, so `stats().hit_ratio()` stays meaningful for
    /// callers (like the coordinator) that route misses here instead of
    /// through `access_or_insert`.
    pub fn insert(&self, block: BlockId, ctx: &AccessContext) -> Vec<BlockId> {
        let idx = self.shard_of(block);
        let mut cache = self.lock_shard(idx);
        let evicted = cache.insert(block, ctx);
        let inserted = cache.contains(block);
        self.sync_view(idx, &cache, inserted.then_some(block), &evicted);
        let a = cache.admission_stats();
        let mut w = self.stats[idx].write();
        w.record_request(false, inserted, evicted.len() as u64);
        w.set_admission(a.admitted, a.rejected);
        w.set_occupancy(cache.used(), cache.len() as u64);
        drop(w);
        drop(cache);
        evicted
    }

    /// Externally remove a block (user uncache directive).
    pub fn remove(&self, block: BlockId) -> bool {
        let idx = self.shard_of(block);
        let mut cache = self.lock_shard(idx);
        let removed = cache.remove(block);
        if removed {
            self.sync_view(idx, &cache, None, &[block]);
            let mut w = self.stats[idx].write();
            w.set_occupancy(cache.used(), cache.len() as u64);
        }
        removed
    }

    /// Whether `block` is currently cached (locks only its shard).
    pub fn contains(&self, block: BlockId) -> bool {
        self.lock_shard(self.shard_of(block)).contains(block)
    }

    /// Lock-free membership probe against the shard's [`ReadView`] —
    /// [`Probe::Hit`] only when the view can prove residency; anything
    /// else must take the (exact) locked path.
    pub fn probe(&self, block: BlockId) -> Probe {
        self.views[self.shard_of(block)].probe(block)
    }

    /// Bytes cached across all shards — lock-free (occupancy mirrors in
    /// the atomic stats blocks).
    pub fn used(&self) -> u64 {
        self.stats.iter().map(|s| s.snapshot().used).sum()
    }

    /// Unused capacity in bytes (`capacity - used`).
    pub fn free(&self) -> u64 {
        self.capacity.saturating_sub(self.used())
    }

    /// Blocks cached across all shards — lock-free.
    pub fn len(&self) -> usize {
        self.stats.iter().map(|s| s.snapshot().blocks).sum::<u64>() as usize
    }

    /// Whether no shard holds any block.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All cached blocks, merged across shards and sorted by id. (Reads
    /// cache contents, so this one does take the shard locks — it is a
    /// diagnostics path, not a counter read.)
    pub fn cached_blocks(&self) -> Vec<BlockId> {
        let mut all = Vec::new();
        for shard in &self.shards {
            all.extend(shard.lock().expect("shard poisoned").cached_blocks());
        }
        all.sort_unstable();
        all
    }

    /// Merged access counters across all shards — lock-free; each
    /// per-shard snapshot is seqlock-consistent and the merged invariants
    /// (`hits + misses == requests`) are sums of per-shard ones.
    pub fn stats(&self) -> ShardStats {
        let mut acc = ShardStats::default();
        for s in &self.stats {
            acc.merge(&s.stats());
        }
        acc
    }

    /// Hit ratio computed from the merged counters — THE hit-ratio of a
    /// sharded replay (callers must not recompute it from per-shard parts).
    pub fn hit_ratio(&self) -> f64 {
        self.stats().hit_ratio()
    }

    /// Per-shard counters, in shard order — lock-free.
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.stats.iter().map(|s| s.stats()).collect()
    }

    /// Counters of one shard — lock-free.
    pub fn stats_of(&self, shard: usize) -> ShardStats {
        self.stats[shard].stats()
    }

    /// One consistent (counters + occupancy) view of one shard —
    /// lock-free.
    pub fn snapshot_of(&self, shard: usize) -> ShardSnapshot {
        self.stats[shard].snapshot()
    }

    /// Zero the access counters on every shard (cached contents and learned
    /// admission state stay).
    pub fn reset_stats(&self) {
        for (idx, shard) in self.shards.iter().enumerate() {
            let mut cache = shard.lock().expect("shard poisoned");
            cache.reset_admission_stats();
            let mut w = self.stats[idx].write();
            w.reset_counters();
            // Occupancy mirrors stay: reset_stats keeps the contents.
            w.set_occupancy(cache.used(), cache.len() as u64);
        }
    }

    fn lock_shard(&self, idx: usize) -> std::sync::MutexGuard<'_, BlockCache> {
        self.shards[idx].lock().expect("shard poisoned")
    }
}

/// A per-thread handle onto the lock-free hit path of a [`ShardedCache`].
///
/// `access_or_insert` first probes the shard's [`ReadView`]: a proven hit
/// is counted at read time (exact merged stats, no lock) and its recency
/// update is pushed into a per-shard bounded buffer. The buffer drains —
/// applying [`BlockCache::touch`] for each entry under the shard lock —
/// on three triggers:
///
/// 1. **fill**: the shard's buffer reached [`RecencyConfig::batch`];
/// 2. **mutation**: any access that must take the locked path (miss,
///    fallback, explicit insert/remove) drains that shard first, so the
///    policy observes this handle's accesses in program order;
/// 3. **cadence**: an incoming access at least
///    [`RecencyConfig::drain_cadence`] of simulated time past the shard's
///    last drain forces one (bounds recency staleness on hit-only runs).
///
/// Dropping (or [`ReadHandle::flush`]ing) the handle drains everything.
/// With the default config (batch 1) every access drains immediately and
/// the handle is bit-identical to calling the cache directly. When a
/// shard is driven by exactly one handle (the replay-worker topology),
/// the drained event sequence equals the unbatched one for *any* batch
/// size — drains preserve per-handle program order and nothing else
/// touches the shard (property-tested in rust/tests/property_read_path.rs).
pub struct ReadHandle<'a> {
    cache: &'a ShardedCache,
    cfg: RecencyConfig,
    /// Per-shard buffered hit accesses, applied on drain.
    buffers: Vec<Vec<(BlockId, AccessContext)>>,
    /// Per-shard simulated time of the last drain (cadence trigger).
    last_drain: Vec<SimTime>,
}

impl<'a> ReadHandle<'a> {
    fn new(cache: &'a ShardedCache, cfg: RecencyConfig) -> Self {
        assert!(cfg.batch >= 1, "recency batch must be >= 1");
        let n = cache.n_shards();
        ReadHandle {
            cache,
            cfg,
            buffers: (0..n).map(|_| Vec::with_capacity(cfg.batch)).collect(),
            last_drain: vec![SimTime::ZERO; n],
        }
    }

    /// The recency configuration this handle drains under.
    pub fn config(&self) -> RecencyConfig {
        self.cfg
    }

    /// Buffered (not yet drained) accesses across all shards.
    pub fn pending(&self) -> usize {
        self.buffers.iter().map(Vec::len).sum()
    }

    /// The full access path, resolving hits lock-free where the view
    /// allows. Outcome-compatible with [`ShardedCache::access_or_insert`].
    pub fn access_or_insert(&mut self, block: BlockId, ctx: &AccessContext) -> AccessOutcome {
        let idx = self.cache.shard_of(block);
        if self.cfg.drain_cadence > SimDuration::ZERO
            && !self.buffers[idx].is_empty()
            && self.last_drain[idx] + self.cfg.drain_cadence <= ctx.time
        {
            self.drain_shard(idx);
        }
        if self.cache.views[idx].probe(block) == Probe::Hit {
            // Count the hit NOW — snapshots fold it into hits+requests —
            // and buffer only the recency bookkeeping.
            self.cache.stats[idx].record_lockfree_hit();
            self.buffers[idx].push((block, ctx.clone()));
            if self.buffers[idx].len() >= self.cfg.batch {
                self.drain_shard(idx);
            }
            return AccessOutcome {
                hit: true,
                evicted: Vec::new(),
                causes: Vec::new(),
                scan_steps: 0,
                inserted: true,
            };
        }
        // Miss or fallback: drain first (program order for the policy),
        // then take the exact locked path.
        self.drain_shard(idx);
        self.cache.access_or_insert(block, ctx)
    }

    /// Drain every shard's buffer (also runs on drop).
    pub fn flush(&mut self) {
        for idx in 0..self.buffers.len() {
            self.drain_shard(idx);
        }
    }

    /// Apply one shard's buffered accesses to the policy, in buffer
    /// (= program) order, under the shard lock. Entries whose block was
    /// evicted since the probe are dropped by [`BlockCache::touch`] —
    /// their hit was already counted at read time, where it linearized.
    fn drain_shard(&mut self, idx: usize) {
        if self.buffers[idx].is_empty() {
            return;
        }
        let mut cache = self.cache.lock_shard(idx);
        let mut latest = self.last_drain[idx];
        for (block, ctx) in self.buffers[idx].drain(..) {
            if ctx.time > latest {
                latest = ctx.time;
            }
            cache.touch(block, &ctx);
        }
        self.last_drain[idx] = latest;
    }
}

impl Drop for ReadHandle<'_> {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::super::builder::CacheBuilder;
    use super::super::lru::Lru;
    use super::*;
    use crate::sim::SimTime;

    fn ctx(t: u64, size: u64) -> AccessContext {
        AccessContext::simple(SimTime(t), size)
    }

    /// `n` LRU shards of `cap` total bytes, via the builder.
    fn lru_cache(n: usize, cap: u64) -> ShardedCache {
        CacheBuilder::new()
            .policy_with(|| Box::new(Lru::new()))
            .shards(n)
            .capacity(cap)
            .build()
            .unwrap()
    }

    #[test]
    fn single_shard_matches_bare_block_cache() {
        let mut bare = BlockCache::new(Box::new(Lru::new()), 3);
        let sharded = lru_cache(1, 3);
        for t in 0..200u64 {
            let b = BlockId((t * 7 + t % 5) % 11);
            let c = ctx(t, 1);
            let a = bare.access_or_insert(b, &c);
            let s = sharded.access_or_insert(b, &c);
            assert_eq!(a, s, "divergence at t={t}");
        }
        assert_eq!(bare.cached_blocks(), sharded.cached_blocks());
        assert_eq!(bare.used(), sharded.used());
    }

    #[test]
    fn capacity_splits_exactly() {
        let sharded = lru_cache(3, 10);
        assert_eq!(sharded.capacity(), 10);
        // Fill the whole keyspace; occupancy can never exceed the total.
        for t in 0..500u64 {
            sharded.access_or_insert(BlockId(t), &ctx(t, 1));
            assert!(sharded.used() <= sharded.capacity());
        }
        let stats = sharded.stats();
        assert_eq!(stats.requests, 500);
        assert_eq!(stats.hits + stats.misses, stats.requests);
        // Conservation: what came in and never left is still cached.
        assert_eq!(stats.insertions - stats.evictions, sharded.len() as u64);
    }

    #[test]
    fn routing_is_stable_and_partitioned() {
        let sharded = lru_cache(4, 64);
        for id in 0..256u64 {
            let b = BlockId(id);
            let s = sharded.shard_of(b);
            assert_eq!(s, shard_of(b, 4));
            assert!(s < 4);
            sharded.access_or_insert(b, &ctx(id, 1));
        }
        // Fibonacci mix must actually spread sequential ids.
        let per_shard = sharded.shard_stats();
        assert!(per_shard.iter().all(|s| s.requests > 0), "{per_shard:?}");
    }

    #[test]
    fn stats_merge_counts_all_shards() {
        let sharded = lru_cache(2, 4);
        for t in 0..10u64 {
            sharded.access_or_insert(BlockId(t % 3), &ctx(t, 1));
        }
        let merged = sharded.stats();
        let by_hand = sharded
            .shard_stats()
            .iter()
            .fold(ShardStats::default(), |mut acc, s| {
                acc.merge(s);
                acc
            });
        assert_eq!(merged, by_hand);
        sharded.reset_stats();
        assert_eq!(sharded.stats(), ShardStats::default());
        assert!(!sharded.is_empty(), "reset_stats must keep contents");
    }

    #[test]
    fn remove_and_contains_route_consistently() {
        let sharded = lru_cache(4, 16);
        sharded.access_or_insert(BlockId(9), &ctx(0, 1));
        assert!(sharded.contains(BlockId(9)));
        assert_eq!(sharded.probe(BlockId(9)), Probe::Hit);
        assert!(sharded.remove(BlockId(9)));
        assert!(!sharded.remove(BlockId(9)));
        assert!(!sharded.contains(BlockId(9)));
        assert_eq!(sharded.probe(BlockId(9)), Probe::Miss);
        assert_eq!(sharded.used(), 0);
    }

    #[test]
    fn admission_counters_flow_into_merged_stats() {
        // Ghost probation: every first sighting is refused, the second
        // admits — both outcomes must show up in the merged counters.
        let c = CacheBuilder::new()
            .policy("lru")
            .admission("ghost")
            .shards(2)
            .capacity(8)
            .build()
            .unwrap();
        for round in 0..2u64 {
            for id in 0..6u64 {
                c.access_or_insert(BlockId(id), &ctx(round * 6 + id, 1));
            }
        }
        let stats = c.stats();
        assert_eq!(stats.rejected, 6, "first sightings on probation");
        assert_eq!(stats.admitted, 6, "re-references admitted");
        assert_eq!(stats.insertions, 6);
        let by_hand = c.shard_stats().iter().fold(ShardStats::default(), |mut acc, s| {
            acc.merge(s);
            acc
        });
        assert_eq!(stats, by_hand, "per-shard admission counters must merge");
        assert_eq!(c.hit_ratio(), stats.hit_ratio());
        c.reset_stats();
        assert_eq!(c.stats(), ShardStats::default());
    }

    #[test]
    fn name_getters_are_lock_free() {
        // The names are captured at construction: they must be readable
        // even while every shard lock (including shard 0's) is held — the
        // pre-fix implementation deadlocked here.
        let c = CacheBuilder::new()
            .policy("h-svm-lru")
            .admission("tinylfu")
            .shards(2)
            .capacity(8)
            .build()
            .unwrap();
        let guards: Vec<_> = c.shards.iter().map(|s| s.lock().unwrap()).collect();
        assert_eq!(c.policy_name(), "h-svm-lru");
        assert_eq!(c.admission_name(), "tinylfu");
        drop(guards);
    }

    #[test]
    fn stats_reads_never_take_a_shard_lock() {
        // The acceptance criterion of the lock split: every counter read
        // must work while every shard Mutex is held by someone else. The
        // pre-split implementation deadlocked on the first stats() call.
        let c = lru_cache(4, 16);
        for t in 0..32u64 {
            c.access_or_insert(BlockId(t % 8), &ctx(t, 1));
        }
        let expected = c.stats();
        let expected_used = c.used();
        let guards: Vec<_> = c.shards.iter().map(|s| s.lock().unwrap()).collect();
        assert_eq!(c.stats(), expected);
        let per_shard: u64 = (0..4).map(|i| c.stats_of(i).requests).sum();
        assert_eq!(per_shard, expected.requests);
        assert_eq!(c.shard_stats().len(), 4);
        assert_eq!(c.used(), expected_used);
        assert_eq!(c.len() as u64, expected_used, "unit blocks: len == used");
        assert_eq!(c.hit_ratio(), expected.hit_ratio());
        let snap = c.snapshot_of(0);
        assert_eq!(snap.stats.hits + snap.stats.misses, snap.stats.requests);
        drop(guards);
    }

    #[test]
    fn membership_probe_never_takes_a_shard_lock() {
        // Same criterion for the read path: the probe must answer while
        // every shard Mutex is held.
        let c = lru_cache(4, 16);
        c.access_or_insert(BlockId(3), &ctx(0, 1));
        let guards: Vec<_> = c.shards.iter().map(|s| s.lock().unwrap()).collect();
        assert_eq!(c.probe(BlockId(3)), Probe::Hit);
        assert_eq!(c.probe(BlockId(99)), Probe::Miss);
        drop(guards);
    }

    #[test]
    fn snapshot_couples_counters_and_occupancy() {
        let c = lru_cache(1, 4);
        for t in 0..6u64 {
            c.access_or_insert(BlockId(t), &ctx(t, 1));
        }
        let snap = c.snapshot_of(0);
        assert_eq!(snap.stats.requests, 6);
        assert_eq!(snap.used, 4, "at capacity");
        assert_eq!(snap.blocks, 4);
        assert_eq!(
            snap.stats.insertions - snap.stats.evictions,
            snap.blocks,
            "conservation inside one snapshot"
        );
    }

    #[test]
    fn concurrent_shard_workers_do_not_interfere() {
        // Each worker replays only blocks that route to its shard; totals
        // must equal the sequential sum (the no-data-races smoke test).
        let n = 4usize;
        let sharded = lru_cache(n, 8 * n as u64);
        let ids: Vec<BlockId> = (0..400u64).map(BlockId).collect();
        std::thread::scope(|scope| {
            for w in 0..n {
                let sharded = &sharded;
                let ids = &ids;
                scope.spawn(move || {
                    for (t, &b) in ids.iter().enumerate() {
                        if shard_of(b, n) == w {
                            sharded.access_or_insert(b, &ctx(t as u64, 1));
                        }
                    }
                });
            }
        });
        let stats = sharded.stats();
        assert_eq!(stats.requests, 400);
        assert_eq!(stats.hits + stats.misses, 400);
        assert!(sharded.used() <= sharded.capacity());
    }

    #[test]
    fn view_tracks_residency_through_evictions() {
        let c = lru_cache(1, 3);
        for t in 0..3u64 {
            c.access_or_insert(BlockId(t), &ctx(t, 1));
        }
        // Block 3 evicts the LRU block 0; the view must follow.
        c.access_or_insert(BlockId(3), &ctx(3, 1));
        assert_eq!(c.probe(BlockId(0)), Probe::Miss);
        for id in 1..4u64 {
            assert_eq!(c.probe(BlockId(id)), Probe::Hit, "block {id}");
        }
    }

    #[test]
    fn read_handle_batch_1_is_bit_identical_to_direct_calls() {
        let direct = lru_cache(2, 6);
        let handled = lru_cache(2, 6);
        let mut handle = handled.read_handle();
        assert_eq!(handle.config(), RecencyConfig::default());
        for t in 0..400u64 {
            let b = BlockId((t * 13 + t % 7) % 17);
            let c = ctx(t, 1);
            let a = direct.access_or_insert(b, &c);
            let h = handle.access_or_insert(b, &c);
            assert_eq!(a, h, "outcome divergence at t={t}");
            assert_eq!(handle.pending(), 0, "batch=1 must drain immediately");
        }
        drop(handle);
        assert_eq!(direct.stats(), handled.stats());
        assert_eq!(direct.cached_blocks(), handled.cached_blocks());
    }

    #[test]
    fn read_handle_batched_buffers_hits_and_counts_them_at_read_time() {
        let c = CacheBuilder::new()
            .policy_with(|| Box::new(Lru::new()))
            .capacity(4)
            .recency(RecencyConfig::default().with_batch(64))
            .build()
            .unwrap();
        let mut handle = c.read_handle();
        for t in 0..4u64 {
            handle.access_or_insert(BlockId(t), &ctx(t, 1));
        }
        // Three hits: buffered (no drain at batch 64) yet counted already.
        for t in 4..7u64 {
            let o = handle.access_or_insert(BlockId(t - 4), &ctx(t, 1));
            assert!(o.hit);
        }
        assert_eq!(handle.pending(), 3, "hits buffered, not drained");
        let s = c.stats();
        assert_eq!(s.hits, 3, "buffered hits count at read time");
        assert_eq!(s.requests, 7);
        assert_eq!(s.hits + s.misses, s.requests);
        // A miss on the same shard drains before mutating.
        handle.access_or_insert(BlockId(100), &ctx(7, 1));
        assert_eq!(handle.pending(), 0);
        drop(handle);
        assert_eq!(c.stats().requests, 8);
    }

    #[test]
    fn read_handle_cadence_drains_on_simulated_time() {
        let c = CacheBuilder::new()
            .policy_with(|| Box::new(Lru::new()))
            .capacity(4)
            .recency(
                RecencyConfig::default()
                    .with_batch(1_000)
                    .with_drain_cadence(SimDuration::from_micros(10)),
            )
            .build()
            .unwrap();
        let mut handle = c.read_handle();
        handle.access_or_insert(BlockId(0), &ctx(0, 1)); // miss: inserts
        assert!(handle.access_or_insert(BlockId(0), &ctx(1, 1)).hit);
        assert_eq!(handle.pending(), 1);
        // Two micros later: within cadence, still buffered.
        assert!(handle.access_or_insert(BlockId(0), &ctx(3, 1)).hit);
        assert_eq!(handle.pending(), 2);
        // Past the cadence: the incoming access drains the stale buffer
        // first, then buffers itself.
        assert!(handle.access_or_insert(BlockId(0), &ctx(30, 1)).hit);
        assert_eq!(handle.pending(), 1);
        handle.flush();
        assert_eq!(handle.pending(), 0);
        assert_eq!(c.stats().hits, 3);
    }

    #[test]
    fn read_handle_equivalent_to_unbatched_for_any_batch_when_single_threaded() {
        // One handle drives the whole cache (the one-worker-per-shard
        // replay topology collapsed to one thread): for ANY batch size the
        // drained event order equals program order, so contents and stats
        // match the unbatched run exactly.
        for batch in [1usize, 4, 32, 1_024] {
            let reference = lru_cache(2, 6);
            let batched = CacheBuilder::new()
                .policy_with(|| Box::new(Lru::new()))
                .shards(2)
                .capacity(6)
                .recency(RecencyConfig::default().with_batch(batch))
                .build()
                .unwrap();
            let mut handle = batched.read_handle();
            for t in 0..600u64 {
                let b = BlockId((t * 13 + t % 7) % 17);
                let c = ctx(t, 1);
                reference.access_or_insert(b, &c);
                handle.access_or_insert(b, &c);
            }
            drop(handle);
            assert_eq!(reference.stats(), batched.stats(), "batch={batch}");
            assert_eq!(
                reference.cached_blocks(),
                batched.cached_blocks(),
                "batch={batch}"
            );
        }
    }

    #[test]
    fn saturated_view_falls_back_to_the_exact_locked_path() {
        // A capacity far past the table clamp saturates the view (the
        // locked path stays exact); the handle must keep working and the
        // view must answer Fallback, never a wrong verdict.
        let c = CacheBuilder::new()
            .policy_with(|| Box::new(Lru::new()))
            .capacity(200_000)
            .build()
            .unwrap();
        let mut handle = c.read_handle();
        for t in 0..120_000u64 {
            handle.access_or_insert(BlockId(t % 110_000), &ctx(t, 1));
        }
        drop(handle);
        assert!(c.views[0].is_saturated(), "population over the table clamp");
        assert_eq!(c.probe(BlockId(0)), Probe::Fallback);
        let s = c.stats();
        assert_eq!(s.requests, 120_000);
        assert_eq!(s.hits + s.misses, s.requests);
        assert_eq!(s.hits, 10_000, "second pass over 0..10_000 hits via the locked path");
    }
}
