//! FIFO — insertion order, no recency update. Sanity baseline for the
//! policy-comparison ablation (not in the paper's survey, but the natural
//! lower bound for ordered policies). Insertion order lives in an intrusive
//! [`OrderList`]: O(1) allocation-free insert and evict.

use crate::util::fasthash::IdHashMap;

use crate::hdfs::BlockId;
use crate::sim::SimTime;

use super::order_list::{OrderHandle, OrderList};
use super::{AccessContext, CachePolicy};

/// First-in-first-out: victim = oldest insertion; hits never re-order.
#[derive(Debug, Default)]
pub struct Fifo {
    order: OrderList<BlockId>,
    index: IdHashMap<BlockId, OrderHandle>,
}

impl Fifo {
    /// Empty policy state.
    pub fn new() -> Self {
        Self::default()
    }
}

impl CachePolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn on_hit(&mut self, _block: BlockId, _ctx: &AccessContext) {
        // FIFO ignores recency.
    }

    fn on_insert(&mut self, block: BlockId, _ctx: &AccessContext) {
        debug_assert!(!self.index.contains_key(&block), "double insert");
        let handle = self.order.push_back(block);
        self.index.insert(block, handle);
    }

    fn choose_victim(&mut self, _now: SimTime) -> Option<BlockId> {
        self.order.front()
    }

    fn on_evict(&mut self, block: BlockId) {
        if let Some(handle) = self.index.remove(&block) {
            self.order.unlink(handle);
        }
    }

    fn len(&self) -> usize {
        self.index.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_in_insertion_order_despite_hits() {
        let mut p = Fifo::new();
        let c = AccessContext::simple(SimTime(0), 1);
        for i in 0..3 {
            p.on_insert(BlockId(i), &c);
        }
        p.on_hit(BlockId(0), &c); // no effect
        assert_eq!(p.choose_victim(SimTime(1)), Some(BlockId(0)));
        p.on_evict(BlockId(0));
        assert_eq!(p.choose_victim(SimTime(2)), Some(BlockId(1)));
        assert_eq!(p.len(), 2);
    }
}
