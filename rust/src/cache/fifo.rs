//! FIFO — insertion order, no recency update. Sanity baseline for the
//! policy-comparison ablation (not in the paper's survey, but the natural
//! lower bound for ordered policies).

use std::collections::{BTreeMap, HashMap};

use crate::hdfs::BlockId;
use crate::sim::SimTime;

use super::{AccessContext, CachePolicy};

#[derive(Debug, Default)]
pub struct Fifo {
    order: BTreeMap<i64, BlockId>,
    index: HashMap<BlockId, i64>,
    next: i64,
}

impl Fifo {
    pub fn new() -> Self {
        Self::default()
    }
}

impl CachePolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn on_hit(&mut self, _block: BlockId, _ctx: &AccessContext) {
        // FIFO ignores recency.
    }

    fn on_insert(&mut self, block: BlockId, _ctx: &AccessContext) {
        debug_assert!(!self.index.contains_key(&block), "double insert");
        let key = self.next;
        self.next += 1;
        self.order.insert(key, block);
        self.index.insert(block, key);
    }

    fn choose_victim(&mut self, _now: SimTime) -> Option<BlockId> {
        self.order.values().next().copied()
    }

    fn on_evict(&mut self, block: BlockId) {
        if let Some(key) = self.index.remove(&block) {
            self.order.remove(&key);
        }
    }

    fn len(&self) -> usize {
        self.index.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_in_insertion_order_despite_hits() {
        let mut p = Fifo::new();
        let c = AccessContext::simple(SimTime(0), 1);
        for i in 0..3 {
            p.on_insert(BlockId(i), &c);
        }
        p.on_hit(BlockId(0), &c); // no effect
        assert_eq!(p.choose_victim(SimTime(1)), Some(BlockId(0)));
        p.on_evict(BlockId(0));
        assert_eq!(p.choose_victim(SimTime(2)), Some(BlockId(1)));
        assert_eq!(p.len(), 2);
    }
}
