//! LIFE (PacMan): evict blocks of the file with the *largest wave-width*,
//! preferring incomplete files, with a window-based aging pass to curb
//! cache pollution. Reduces average completion time for parallel jobs with
//! the all-or-nothing property (paper §3.1 / [8]).

use std::collections::HashMap;

use crate::hdfs::BlockId;
use crate::sim::{SimDuration, SimTime};

use super::{AccessContext, CachePolicy};

#[derive(Debug, Clone)]
struct Entry {
    file: u64,
    width: u32,
    complete: bool,
    last_access: SimTime,
    accesses: u64,
}

/// LIFE (PacMan): evict from the widest incomplete wave first, with an
/// aging window against pollution.
#[derive(Debug)]
pub struct Life {
    entries: HashMap<BlockId, Entry>,
    /// Aging window: blocks untouched for longer are eviction candidates
    /// regardless of wave-width (the PacMan anti-pollution mechanism).
    window: SimDuration,
}

impl Life {
    /// Policy with the given aging window.
    pub fn new(window: SimDuration) -> Self {
        Life { entries: HashMap::new(), window }
    }

    fn record(&mut self, block: BlockId, ctx: &AccessContext, fresh: bool) {
        let e = self.entries.entry(block).or_insert(Entry {
            file: ctx.file,
            width: ctx.file_width,
            complete: ctx.file_complete,
            last_access: ctx.time,
            accesses: 0,
        });
        e.file = ctx.file;
        e.width = ctx.file_width;
        e.complete = ctx.file_complete;
        e.last_access = ctx.time;
        if fresh {
            e.accesses = 1;
        } else {
            e.accesses += 1;
        }
    }
}

impl CachePolicy for Life {
    fn name(&self) -> &'static str {
        "life"
    }

    fn on_hit(&mut self, block: BlockId, ctx: &AccessContext) {
        self.record(block, ctx, false);
    }

    fn on_insert(&mut self, block: BlockId, ctx: &AccessContext) {
        debug_assert!(!self.entries.contains_key(&block), "double insert");
        self.record(block, ctx, true);
    }

    fn choose_victim(&mut self, now: SimTime) -> Option<BlockId> {
        if self.entries.is_empty() {
            return None;
        }
        // Aging pass first: among blocks outside the access window pick the
        // least-accessed one ("the one with the least number of accesses").
        let aged = self
            .entries
            .iter()
            .filter(|(_, e)| e.last_access.duration_until(now) >= self.window)
            .min_by_key(|(b, e)| (e.accesses, e.last_access, **b));
        if let Some((b, _)) = aged {
            return Some(*b);
        }
        // Otherwise LIFE proper: incomplete files first, then the file with
        // the largest wave-width; oldest access breaks ties.
        self.entries
            .iter()
            .min_by_key(|(b, e)| (e.complete, std::cmp::Reverse(e.width), e.last_access, **b))
            .map(|(b, _)| *b)
    }

    fn on_evict(&mut self, block: BlockId) {
        self.entries.remove(&block);
    }

    fn len(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(t: u64, file: u64, width: u32, complete: bool) -> AccessContext {
        let mut c = AccessContext::simple(SimTime(t), 1);
        c.file = file;
        c.file_width = width;
        c.file_complete = complete;
        c
    }

    fn policy() -> Life {
        Life::new(SimDuration(1000))
    }

    #[test]
    fn evicts_largest_wave_width() {
        let mut p = policy();
        p.on_insert(BlockId(1), &ctx(1, 10, 2, false));
        p.on_insert(BlockId(2), &ctx(2, 20, 8, false));
        p.on_insert(BlockId(3), &ctx(3, 30, 4, false));
        assert_eq!(p.choose_victim(SimTime(10)), Some(BlockId(2)));
    }

    #[test]
    fn incomplete_files_evicted_before_complete() {
        let mut p = policy();
        p.on_insert(BlockId(1), &ctx(1, 10, 8, true));
        p.on_insert(BlockId(2), &ctx(2, 20, 2, false));
        // Despite the smaller width, the incomplete file goes first.
        assert_eq!(p.choose_victim(SimTime(10)), Some(BlockId(2)));
    }

    #[test]
    fn window_aging_overrides_width() {
        let mut p = policy();
        p.on_insert(BlockId(1), &ctx(0, 10, 8, false));
        p.on_insert(BlockId(2), &ctx(0, 20, 2, false));
        p.on_hit(BlockId(1), &ctx(2000, 10, 8, false));
        // Block 2 fell out of the window -> evicted first even though
        // block 1's file has the larger wave-width.
        assert_eq!(p.choose_victim(SimTime(2100)), Some(BlockId(2)));
    }

    #[test]
    fn evict_removes_tracking() {
        let mut p = policy();
        p.on_insert(BlockId(1), &ctx(1, 1, 1, false));
        p.on_evict(BlockId(1));
        assert_eq!(p.len(), 0);
        assert_eq!(p.choose_victim(SimTime(2)), None);
    }
}
