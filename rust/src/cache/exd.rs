//! EXD — Exponential-Decay scoring (Big SQL adaptive caching, §3.1 / [11]):
//! each block keeps a single score updated at access time as
//! `score = 1 + score_old * exp(-beta * (t - t_last))`. Only the last access
//! time is stored. `beta` trades frequency (small beta) against recency
//! (large beta); the victim is the block with the lowest current score.

use std::collections::HashMap;

use crate::hdfs::BlockId;
use crate::sim::SimTime;

use super::{AccessContext, CachePolicy};

#[derive(Debug, Clone, Copy)]
struct Entry {
    score: f64,
    last: SimTime,
}

/// Exponential-decay (EXD) scoring: each hit adds 1 to a score that
/// decays as `exp(-beta * dt)`; victim = lowest decayed score.
#[derive(Debug)]
pub struct Exd {
    beta: f64,
    entries: HashMap<BlockId, Entry>,
}

impl Exd {
    /// `beta` in 1/seconds; EXD's adaptive variant tunes this online, here
    /// it is a constructor parameter (the ablation bench sweeps it).
    pub fn new(beta: f64) -> Self {
        assert!(beta > 0.0, "beta must be positive");
        Exd { beta, entries: HashMap::new() }
    }

    fn decayed_score(&self, e: &Entry, now: SimTime) -> f64 {
        let dt = e.last.duration_until(now).as_secs_f64();
        e.score * (-self.beta * dt).exp()
    }

    /// The block's decayed score at `now`.
    pub fn score_of(&self, block: BlockId, now: SimTime) -> Option<f64> {
        self.entries.get(&block).map(|e| self.decayed_score(e, now))
    }
}

impl CachePolicy for Exd {
    fn name(&self) -> &'static str {
        "exd"
    }

    fn on_hit(&mut self, block: BlockId, ctx: &AccessContext) {
        let beta = self.beta;
        let e = self.entries.get_mut(&block).expect("hit on untracked block");
        let dt = e.last.duration_until(ctx.time).as_secs_f64();
        e.score = 1.0 + e.score * (-beta * dt).exp();
        e.last = ctx.time;
    }

    fn on_insert(&mut self, block: BlockId, ctx: &AccessContext) {
        debug_assert!(!self.entries.contains_key(&block), "double insert");
        self.entries.insert(block, Entry { score: 1.0, last: ctx.time });
    }

    fn choose_victim(&mut self, now: SimTime) -> Option<BlockId> {
        self.entries
            .iter()
            .min_by(|(ba, ea), (bb, eb)| {
                self.decayed_score(ea, now)
                    .partial_cmp(&self.decayed_score(eb, now))
                    .unwrap()
                    .then(ba.cmp(bb))
            })
            .map(|(b, _)| *b)
    }

    fn on_evict(&mut self, block: BlockId) {
        self.entries.remove(&block);
    }

    fn len(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(t_secs: f64) -> AccessContext {
        AccessContext::simple(SimTime::from_secs_f64(t_secs), 1)
    }

    #[test]
    fn frequent_block_outscores_single_access() {
        let mut p = Exd::new(0.01);
        p.on_insert(BlockId(1), &ctx(0.0));
        p.on_insert(BlockId(2), &ctx(0.0));
        for t in [1.0, 2.0, 3.0] {
            p.on_hit(BlockId(1), &ctx(t));
        }
        assert_eq!(p.choose_victim(SimTime::from_secs_f64(4.0)), Some(BlockId(2)));
        let s1 = p.score_of(BlockId(1), SimTime::from_secs_f64(4.0)).unwrap();
        let s2 = p.score_of(BlockId(2), SimTime::from_secs_f64(4.0)).unwrap();
        assert!(s1 > 3.0 && s2 < 1.0, "s1={s1} s2={s2}");
    }

    #[test]
    fn large_beta_decays_to_pure_recency() {
        let mut p = Exd::new(100.0);
        p.on_insert(BlockId(1), &ctx(0.0));
        for t in [0.1, 0.2, 0.3] {
            p.on_hit(BlockId(1), &ctx(t));
        }
        p.on_insert(BlockId(2), &ctx(5.0));
        // With aggressive decay, old frequency is worthless: block 1's
        // score at t=10 is ~0 while block 2's is larger.
        let s1 = p.score_of(BlockId(1), SimTime::from_secs_f64(10.0)).unwrap();
        let s2 = p.score_of(BlockId(2), SimTime::from_secs_f64(10.0)).unwrap();
        assert!(s2 > s1);
        assert_eq!(p.choose_victim(SimTime::from_secs_f64(10.0)), Some(BlockId(1)));
    }

    #[test]
    fn score_is_time_invariant_in_ranking_for_equal_last() {
        // Two blocks last touched at the same time keep their order as the
        // clock advances (decay is monotone).
        let mut p = Exd::new(0.5);
        p.on_insert(BlockId(1), &ctx(0.0));
        p.on_insert(BlockId(2), &ctx(0.0));
        p.on_hit(BlockId(1), &ctx(1.0));
        p.on_hit(BlockId(2), &ctx(1.0));
        p.on_hit(BlockId(1), &ctx(2.0));
        for t in [3.0, 10.0, 100.0] {
            assert_eq!(p.choose_victim(SimTime::from_secs_f64(t)), Some(BlockId(2)));
        }
    }
}
