//! Lock-free membership view of a shard's entry table, plus the
//! recency-batching configuration and drain discipline built on it.
//!
//! This generalizes the seqlock split of [`super::shard_stats`] from
//! counters to *membership*: a hit can resolve "is this block resident?"
//! without touching the shard `Mutex`, push its access into a per-handle
//! bounded recency buffer, and let a later drain pass apply the buffered
//! [`CachePolicy::on_hit`](super::CachePolicy::on_hit) updates to the
//! `OrderList` in batches under the lock. The read-mostly workloads the
//! paper targets (hot HDFS blocks re-read across MapReduce waves) stop
//! serializing on the shard lock for recency bookkeeping.
//!
//! ## The protocol
//!
//! [`ReadView`] is a fixed-size power-of-two open-addressing table of
//! `AtomicU64` slots (no `unsafe`, facade atomics only — the repo lint
//! keeps it that way). Encoding per slot: `0` = empty, `1` = tombstone,
//! anything else is `block.0 + 2`. Writers — always the thread holding the
//! owning shard's `Mutex`, the same single-writer discipline the stats
//! seqlock uses — mirror every residency change:
//!
//! * **insert**: store the code into the first empty-or-tombstone slot of
//!   the block's probe chain. A single-slot publish; no seqlock bump.
//! * **remove**: overwrite the block's slot with the tombstone. Probe
//!   chains stay intact because an empty slot is never created in place —
//!   readers skip tombstones.
//! * **rebuild** (tombstone compaction / saturation exit): the only
//!   multi-slot write, bracketed by the seqlock word exactly like a stats
//!   write section. Readers that overlap a rebuild retry.
//!
//! Readers bracket a bounded probe with the seqlock word: an even,
//! unchanged `seq` around the probe means no rebuild raced it; the
//! individual slot loads are relaxed and rely on per-location coherence.
//! A racy single-slot publish can make a reader miss a block inserted
//! concurrently (or see one removed concurrently) — both linearize to a
//! legal point inside the overlap, and a "miss" verdict only ever demotes
//! the access to the exact locked path, so the view can be conservative
//! but never corrupting. When the resident set outgrows the table the view
//! sets a `saturated` flag and every probe answers [`Probe::Fallback`]
//! until a rebuild finds the population small enough again.
//!
//! The full protocol is modeled by loom in rust/tests/loom_protocols.rs
//! and documented in docs/CONCURRENCY.md.

use std::hash::Hasher;

use crate::hdfs::BlockId;
use crate::sim::{SimDuration, SimTime};
use crate::util::fasthash::IdHasher;
use crate::util::sync::atomic::{fence, AtomicBool, AtomicU64, Ordering};
use crate::util::sync::hint;

/// Slot value of a never-used slot (probe chains end here).
const EMPTY: u64 = 0;
/// Slot value of a removed entry (probe chains continue through it).
const TOMBSTONE: u64 = 1;
/// Slot codes are `block.0 + CODE_BASE`.
const CODE_BASE: u64 = 2;

/// Recency-batching knobs for the lock-free read path.
///
/// The default — batch size 1, no cadence — drains every buffered access
/// immediately and is bit-identical to the fully locked hit path: the
/// policy sees the exact same event sequence, and the merged stats are
/// equal (property-tested in rust/tests/property_read_path.rs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecencyConfig {
    /// Buffered accesses per shard before a drain is forced (>= 1).
    pub batch: usize,
    /// Simulated-time drain cadence: a non-zero duration drains a shard's
    /// buffer whenever the incoming access is at least this much newer
    /// than the shard's last drain. Zero disables the cadence trigger.
    pub drain_cadence: SimDuration,
}

impl Default for RecencyConfig {
    fn default() -> Self {
        RecencyConfig { batch: 1, drain_cadence: SimDuration::ZERO }
    }
}

impl RecencyConfig {
    /// Behavior-preserving default: drain every access immediately.
    pub fn immediate() -> Self {
        Self::default()
    }

    /// Buffer up to `batch` accesses per shard (builder style).
    pub fn with_batch(mut self, batch: usize) -> Self {
        assert!(batch >= 1, "recency batch must be >= 1");
        self.batch = batch;
        self
    }

    /// Drain on a simulated-time cadence (builder style).
    pub fn with_drain_cadence(mut self, cadence: SimDuration) -> Self {
        self.drain_cadence = cadence;
        self
    }

    /// Whether this configuration ever leaves an access buffered.
    pub fn is_buffered(&self) -> bool {
        self.batch > 1
    }
}

/// Verdict of a lock-free membership probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Probe {
    /// The block is resident; the access may take the lock-free hit path.
    Hit,
    /// The block is not resident; take the locked miss path.
    Miss,
    /// The view cannot answer (table saturated); take the locked path.
    Fallback,
}

/// Home slot of a block: full Fibonacci id mix, masked to the table.
/// Distinct from [`super::sharded::shard_of`]'s high bits, so blocks that
/// collide on a shard still spread across that shard's view.
fn home_of(block: BlockId, mask: usize) -> usize {
    let mut h = IdHasher::default();
    h.write_u64(block.0);
    (h.finish() as usize) & mask
}

/// Lock-free membership view of one shard's entry table.
///
/// Single-writer discipline: every mutator (`insert` / `remove` /
/// `rebuild`) may only be called by a thread holding the owning shard's
/// `Mutex`. Probes are unrestricted.
#[derive(Debug)]
pub struct ReadView {
    /// Seqlock word bracketing rebuilds (the only multi-slot writes).
    seq: AtomicU64,
    /// Open-addressing table; length is a power of two.
    slots: Vec<AtomicU64>,
    mask: usize,
    /// Live entries — single-writer, read by the maintenance heuristics.
    resident: AtomicU64,
    /// Tombstoned slots awaiting compaction — single-writer.
    tombstones: AtomicU64,
    /// When set, probes answer [`Probe::Fallback`]: the resident set does
    /// not fit the table with a sane load factor, so the locked path (which
    /// is always exact) serves every access. Cleared by a rebuild that
    /// finds the population back under the threshold.
    saturated: AtomicBool,
}

impl ReadView {
    /// A view with at least `min_slots` slots (rounded up to a power of
    /// two, floor 16).
    pub fn with_slots(min_slots: usize) -> Self {
        let n = min_slots.max(16).next_power_of_two();
        ReadView {
            seq: AtomicU64::new(0),
            slots: (0..n).map(|_| AtomicU64::new(EMPTY)).collect(),
            mask: n - 1,
            resident: AtomicU64::new(0),
            tombstones: AtomicU64::new(0),
            saturated: AtomicBool::new(false),
        }
    }

    /// Table size for a shard of `capacity_bytes`. Unit-size blocks (the
    /// replay traces) fill at most `capacity` entries, so double that for
    /// probe headroom; clamp so byte-denominated capacities (where block
    /// counts are far below byte counts) cannot demand absurd tables —
    /// overflow just saturates into the exact locked path.
    pub fn slots_for_capacity(capacity_bytes: u64) -> usize {
        let want = capacity_bytes.saturating_mul(2).clamp(16, 65_536);
        (want as usize).next_power_of_two()
    }

    /// Number of slots (always a power of two).
    pub fn slots(&self) -> usize {
        self.slots.len()
    }

    /// Whether probes currently answer [`Probe::Fallback`].
    pub fn is_saturated(&self) -> bool {
        self.saturated.load(Ordering::Relaxed)
    }

    /// Lock-free membership probe. Never takes a lock; spins only while a
    /// rebuild (constant-bounded work under the shard lock) is in flight.
    pub fn probe(&self, block: BlockId) -> Probe {
        let code = block.0.wrapping_add(CODE_BASE);
        if code < CODE_BASE {
            return Probe::Fallback; // id collides with a sentinel code
        }
        loop {
            // Acquire: pairs with the rebuild's Release close, so the slot
            // loads below observe every store of the rebuild that
            // published this even value.
            let s1 = self.seq.load(Ordering::Acquire);
            if s1 & 1 == 1 {
                hint::spin_loop();
                continue;
            }
            if self.saturated.load(Ordering::Relaxed) {
                return Probe::Fallback;
            }
            let home = home_of(block, self.mask);
            let mut verdict = Probe::Miss;
            for i in 0..self.slots.len() {
                let v = self.slots[(home + i) & self.mask].load(Ordering::Relaxed);
                if v == EMPTY {
                    break;
                }
                if v == code {
                    verdict = Probe::Hit;
                    break;
                }
                // Tombstone or another block: keep probing.
            }
            // Acquire fence: orders the slot loads before the `seq`
            // re-check — if no rebuild opened in between, every load came
            // from a table no rebuild was mutating.
            fence(Ordering::Acquire);
            if self.seq.load(Ordering::Relaxed) == s1 {
                return verdict;
            }
            hint::spin_loop();
        }
    }

    /// Mirror a residency insert (caller holds the shard lock; `block`
    /// must not already be in the view). No-op once saturated.
    pub fn insert(&self, block: BlockId) {
        if self.is_saturated() {
            return;
        }
        let code = block.0.wrapping_add(CODE_BASE);
        let resident = self.resident.load(Ordering::Relaxed);
        // Saturate before the table gets slow or full: live entries past
        // 3/4 load leave too little empty-slot headroom for probes.
        if code < CODE_BASE || (resident + 1) * 4 > self.slots.len() as u64 * 3 {
            self.saturated.store(true, Ordering::Relaxed);
            return;
        }
        let home = home_of(block, self.mask);
        for i in 0..self.slots.len() {
            let slot = &self.slots[(home + i) & self.mask];
            let v = slot.load(Ordering::Relaxed);
            debug_assert_ne!(v, code, "read-view insert of a present block");
            if v == EMPTY || v == TOMBSTONE {
                if v == TOMBSTONE {
                    self.tombstones.fetch_sub(1, Ordering::Relaxed);
                }
                // Release: a reader that observes the code also observes
                // everything the locked mutation published before it.
                slot.store(code, Ordering::Release);
                self.resident.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        // No reusable slot on the whole chain (tombstone-free full table
        // is excluded by the load check, so this is unreachable in
        // practice) — fail safe.
        self.saturated.store(true, Ordering::Relaxed);
    }

    /// Mirror a residency removal (caller holds the shard lock). No-op
    /// once saturated or when `block` is not in the view.
    pub fn remove(&self, block: BlockId) {
        if self.is_saturated() {
            return;
        }
        let code = block.0.wrapping_add(CODE_BASE);
        if code < CODE_BASE {
            return;
        }
        let home = home_of(block, self.mask);
        for i in 0..self.slots.len() {
            let slot = &self.slots[(home + i) & self.mask];
            let v = slot.load(Ordering::Relaxed);
            if v == EMPTY {
                return; // not present (saturation may have skipped it)
            }
            if v == code {
                // Tombstone, not empty: probe chains through this slot
                // must keep walking, so readers skip it but never stop.
                slot.store(TOMBSTONE, Ordering::Release);
                self.resident.fetch_sub(1, Ordering::Relaxed);
                self.tombstones.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
    }

    /// Whether tombstones have accumulated enough to warrant a rebuild
    /// (they lengthen every probe chain), or the view is saturated and a
    /// compaction might fit the population again.
    pub fn needs_rebuild(&self) -> bool {
        let tombstones = self.tombstones.load(Ordering::Relaxed);
        tombstones * 4 > self.slots.len() as u64 || self.is_saturated()
    }

    /// Rebuild the table from the true resident set (caller holds the
    /// shard lock). The only multi-slot write: bracketed by the seqlock
    /// word, so overlapping probes retry instead of observing a
    /// half-compacted table. Clears saturation when the population fits.
    pub fn rebuild(&self, blocks: impl Iterator<Item = BlockId>) {
        // AcqRel open: pins the slot stores below after the odd store —
        // a reader that saw an even `seq` cannot have raced this rebuild.
        let prev = self.seq.fetch_add(1, Ordering::AcqRel);
        debug_assert_eq!(prev & 1, 0, "nested/concurrent read-view rebuild");
        for slot in &self.slots {
            slot.store(EMPTY, Ordering::Relaxed);
        }
        let mut count = 0u64;
        let mut fits = true;
        for block in blocks {
            let code = block.0.wrapping_add(CODE_BASE);
            if code < CODE_BASE || (count + 1) * 4 > self.slots.len() as u64 * 3 {
                fits = false;
                break;
            }
            let home = home_of(block, self.mask);
            for i in 0..self.slots.len() {
                let slot = &self.slots[(home + i) & self.mask];
                if slot.load(Ordering::Relaxed) == EMPTY {
                    slot.store(code, Ordering::Relaxed);
                    count += 1;
                    break;
                }
            }
        }
        self.resident.store(count, Ordering::Relaxed);
        self.tombstones.store(0, Ordering::Relaxed);
        self.saturated.store(!fits, Ordering::Relaxed);
        // Release close: publishes every slot store before the even value.
        let prev = self.seq.fetch_add(1, Ordering::Release);
        debug_assert_eq!(prev & 1, 1, "read-view rebuild closed twice");
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn probe_hits_inserted_and_misses_removed() {
        let v = ReadView::with_slots(16);
        assert_eq!(v.probe(BlockId(7)), Probe::Miss);
        v.insert(BlockId(7));
        v.insert(BlockId(23)); // likely chains with 7 on small tables
        assert_eq!(v.probe(BlockId(7)), Probe::Hit);
        assert_eq!(v.probe(BlockId(23)), Probe::Hit);
        assert_eq!(v.probe(BlockId(8)), Probe::Miss);
        v.remove(BlockId(7));
        assert_eq!(v.probe(BlockId(7)), Probe::Miss);
        assert_eq!(v.probe(BlockId(23)), Probe::Hit, "chains walk through tombstones");
    }

    #[test]
    fn sentinel_colliding_ids_fall_back() {
        let v = ReadView::with_slots(16);
        // u64::MAX - 1 and u64::MAX encode onto the sentinels; the view
        // must refuse to answer rather than corrupt the table.
        v.insert(BlockId(u64::MAX));
        assert_eq!(v.probe(BlockId(u64::MAX)), Probe::Fallback);
        assert!(v.is_saturated());
    }

    #[test]
    fn saturation_falls_back_and_rebuild_recovers() {
        let v = ReadView::with_slots(16);
        for i in 0..13u64 {
            v.insert(BlockId(i)); // 13 of 16 slots crosses 3/4 load
        }
        assert!(v.is_saturated());
        assert_eq!(v.probe(BlockId(0)), Probe::Fallback);
        assert!(v.needs_rebuild());
        // The true resident set shrank (evictions went through the locked
        // path while saturated): a rebuild fits again.
        v.rebuild((0..4u64).map(BlockId));
        assert!(!v.is_saturated());
        assert_eq!(v.probe(BlockId(3)), Probe::Hit);
        assert_eq!(v.probe(BlockId(9)), Probe::Miss);
    }

    #[test]
    fn churn_accumulates_tombstones_then_rebuild_compacts() {
        let v = ReadView::with_slots(32);
        for i in 0..200u64 {
            v.insert(BlockId(i));
            v.remove(BlockId(i));
            if v.needs_rebuild() {
                v.rebuild(std::iter::empty());
            }
            assert!(!v.is_saturated(), "constant population must never saturate (i={i})");
        }
        assert_eq!(v.probe(BlockId(199)), Probe::Miss);
    }

    #[test]
    fn slots_for_capacity_is_clamped_and_pow2() {
        assert_eq!(ReadView::slots_for_capacity(0), 16);
        assert_eq!(ReadView::slots_for_capacity(64), 128);
        assert_eq!(ReadView::slots_for_capacity(u64::MAX), 65_536);
        let v = ReadView::with_slots(ReadView::slots_for_capacity(100));
        assert_eq!(v.slots(), 256);
    }

    #[test]
    fn recency_config_defaults_are_immediate() {
        let cfg = RecencyConfig::default();
        assert_eq!(cfg.batch, 1);
        assert_eq!(cfg.drain_cadence, SimDuration::ZERO);
        assert!(!cfg.is_buffered());
        assert_eq!(cfg, RecencyConfig::immediate());
        let cfg = cfg.with_batch(8).with_drain_cadence(SimDuration::from_micros(2_000));
        assert!(cfg.is_buffered());
        assert_eq!(cfg.batch, 8);
    }

    #[test]
    #[should_panic(expected = "recency batch must be >= 1")]
    fn zero_batch_is_rejected() {
        let _ = RecencyConfig::default().with_batch(0);
    }

    /// Real-thread stress: one mutator (lock-holder stand-in) churns while
    /// readers probe. Readers must never deadlock, never observe a torn
    /// rebuild (asserted inside `probe` by construction), and a block that
    /// is resident for the whole run must always probe Hit-or-Fallback.
    #[test]
    fn concurrent_probes_survive_churn_and_rebuilds() {
        let v = ReadView::with_slots(64);
        v.insert(BlockId(1_000)); // pinned resident for the whole run
        let stop = AtomicBool::new(false);
        std::thread::scope(|scope| {
            let v = &v;
            let stop = &stop;
            let readers: Vec<_> = (0..3)
                .map(|_| {
                    scope.spawn(move || {
                        let mut probes = 0u64;
                        while !stop.load(Ordering::Acquire) {
                            assert_ne!(
                                v.probe(BlockId(1_000)),
                                Probe::Miss,
                                "pinned resident block reported missing"
                            );
                            let _ = v.probe(BlockId(2));
                            probes += 1;
                        }
                        probes
                    })
                })
                .collect();
            for round in 0..2_000u64 {
                let b = BlockId(round % 40);
                v.insert(b);
                v.remove(b);
                if v.needs_rebuild() {
                    v.rebuild(std::iter::once(BlockId(1_000)));
                }
            }
            stop.store(true, Ordering::Release);
            for r in readers {
                assert!(r.join().unwrap() > 0);
            }
        });
        assert_eq!(v.probe(BlockId(1_000)), Probe::Hit);
    }
}
