//! Slab-backed intrusive order list — the O(1) zero-allocation backbone of
//! every list-ordered replacement structure.
//!
//! The previous implementations kept eviction order in a
//! `BTreeMap<i64, BlockId>` keyed by a monotone counter: every touch,
//! insert and evict re-keyed the tree (node allocation + O(log n) pointer
//! chasing), which dominated the replay hot path long before the policy
//! logic mattered. `OrderList` replaces that with a doubly-linked list
//! whose nodes live in one `Vec` slab:
//!
//! * **O(1)** `push_front`/`push_back`/`move_to_front`/`move_to_back`/
//!   `unlink`/`pop_front` — neighbour pointers are slab indices, not heap
//!   pointers.
//! * **Zero steady-state allocation** — unlinked slots go on an index
//!   free-list and are reused by later pushes; the slab only grows while
//!   the peak live population grows.
//! * **Stable handles** — an [`OrderHandle`] is the node's slab index. It
//!   stays valid (and keeps addressing the same element) across any number
//!   of operations on *other* elements, so callers keep it in the same
//!   `IdHashMap` they already maintain per block and get O(1) re-ordering
//!   without a search. A handle dies when its element is unlinked; using
//!   it afterwards is caller error (caught by `debug_assert` in debug
//!   builds).
//!
//! Used by `Lru`, `HSvmLru` (two regions = two lists), `Fifo`, the four
//! `ModifiedArc` queues and the admission-ghost LRU; property-tested
//! against the original BTreeMap/VecDeque implementations in
//! rust/tests/property_orderlist.rs.

use std::hash::Hash;

use crate::util::fasthash::IdHashMap;

/// End-of-list sentinel.
const NIL: u32 = u32::MAX;
/// `prev` marker of a slot on the free list (never a valid index).
const FREE: u32 = u32::MAX - 1;

/// Stable reference to a live element (its slab index).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct OrderHandle(u32);

#[derive(Debug, Clone)]
struct Node<T> {
    item: T,
    prev: u32,
    next: u32,
}

/// Doubly-linked order list over a `Vec` slab with an index free-list.
///
/// The backbone of every O(1) recency structure in the crate: front =
/// next victim, back = most recently used.
///
/// ```
/// use h_svm_lru::cache::order_list::OrderList;
///
/// let mut list = OrderList::new();
/// let a = list.push_back(1u64);
/// let _b = list.push_back(2u64);
/// assert_eq!(list.front(), Some(1)); // oldest first
/// list.move_to_back(a);              // touch: 1 becomes most recent
/// assert_eq!(list.front(), Some(2));
/// assert_eq!(list.pop_front(), Some(2));
/// assert_eq!(list.len(), 1);
/// ```
#[derive(Debug, Clone)]
pub struct OrderList<T> {
    nodes: Vec<Node<T>>,
    head: u32,
    tail: u32,
    /// Head of the free-slot chain (threaded through `next`).
    free: u32,
    len: usize,
}

impl<T: Copy> OrderList<T> {
    /// Empty list.
    pub fn new() -> Self {
        OrderList { nodes: Vec::new(), head: NIL, tail: NIL, free: NIL, len: 0 }
    }

    /// Empty list with slab space for `n` elements.
    pub fn with_capacity(n: usize) -> Self {
        OrderList { nodes: Vec::with_capacity(n), ..Self::new() }
    }

    /// Live elements in the list.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the list has no live elements.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Slab slots ever allocated (= peak live population; free-list reuse
    /// keeps this from growing under churn — asserted in the property
    /// tests).
    pub fn slots(&self) -> usize {
        self.nodes.len()
    }

    /// Grab a slot off the free list or grow the slab.
    fn alloc(&mut self, item: T) -> u32 {
        if self.free != NIL {
            let idx = self.free;
            let node = &mut self.nodes[idx as usize];
            debug_assert_eq!(node.prev, FREE, "free-list corruption");
            self.free = node.next;
            node.item = item;
            idx
        } else {
            assert!(self.nodes.len() < FREE as usize, "order list slab full");
            self.nodes.push(Node { item, prev: NIL, next: NIL });
            (self.nodes.len() - 1) as u32
        }
    }

    /// Splice `idx` in as the new tail (node must be detached).
    fn link_back(&mut self, idx: u32) {
        self.nodes[idx as usize].prev = self.tail;
        self.nodes[idx as usize].next = NIL;
        if self.tail != NIL {
            self.nodes[self.tail as usize].next = idx;
        } else {
            self.head = idx;
        }
        self.tail = idx;
    }

    /// Splice `idx` in as the new head (node must be detached).
    fn link_front(&mut self, idx: u32) {
        self.nodes[idx as usize].prev = NIL;
        self.nodes[idx as usize].next = self.head;
        if self.head != NIL {
            self.nodes[self.head as usize].prev = idx;
        } else {
            self.tail = idx;
        }
        self.head = idx;
    }

    /// Unhook `idx` from its neighbours without freeing the slot.
    fn detach(&mut self, idx: u32) {
        let (prev, next) = {
            let node = &self.nodes[idx as usize];
            debug_assert_ne!(node.prev, FREE, "stale OrderHandle");
            (node.prev, node.next)
        };
        if prev != NIL {
            self.nodes[prev as usize].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.nodes[next as usize].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    /// Append at the eviction-last end. O(1); allocation-free when a freed
    /// slot is available.
    pub fn push_back(&mut self, item: T) -> OrderHandle {
        let idx = self.alloc(item);
        self.link_back(idx);
        self.len += 1;
        OrderHandle(idx)
    }

    /// Prepend at the eviction-first end. O(1).
    pub fn push_front(&mut self, item: T) -> OrderHandle {
        let idx = self.alloc(item);
        self.link_front(idx);
        self.len += 1;
        OrderHandle(idx)
    }

    /// Splice a new element immediately after a live `after`. O(1) — the
    /// primitive the LFU frequency-bucket chain needs to create the
    /// `f + 1` bucket next to the `f` bucket without a search.
    pub fn insert_after(&mut self, after: OrderHandle, item: T) -> OrderHandle {
        debug_assert_ne!(self.nodes[after.0 as usize].prev, FREE, "stale OrderHandle");
        let idx = self.alloc(item);
        let next = self.nodes[after.0 as usize].next;
        self.nodes[idx as usize].prev = after.0;
        self.nodes[idx as usize].next = next;
        self.nodes[after.0 as usize].next = idx;
        if next != NIL {
            self.nodes[next as usize].prev = idx;
        } else {
            self.tail = idx;
        }
        self.len += 1;
        OrderHandle(idx)
    }

    /// The live handle following `handle` in front-to-back order, if any.
    pub fn next_of(&self, handle: OrderHandle) -> Option<OrderHandle> {
        let node = &self.nodes[handle.0 as usize];
        debug_assert_ne!(node.prev, FREE, "stale OrderHandle");
        if node.next == NIL {
            None
        } else {
            Some(OrderHandle(node.next))
        }
    }

    /// Handle of the eviction-first element, if any.
    pub fn front_handle(&self) -> Option<OrderHandle> {
        if self.head == NIL {
            None
        } else {
            Some(OrderHandle(self.head))
        }
    }

    /// Remove the element behind `handle`, returning it. The handle is dead
    /// afterwards; its slot goes on the free list. O(1).
    pub fn unlink(&mut self, handle: OrderHandle) -> T {
        let idx = handle.0;
        self.detach(idx);
        self.len -= 1;
        let node = &mut self.nodes[idx as usize];
        let item = node.item;
        node.prev = FREE;
        node.next = self.free;
        self.free = idx;
        item
    }

    /// Re-order an element to the tail (most-recently-used end). O(1).
    pub fn move_to_back(&mut self, handle: OrderHandle) {
        if self.tail != handle.0 {
            self.detach(handle.0);
            self.link_back(handle.0);
        }
    }

    /// Re-order an element to the head (eviction-first end). O(1).
    pub fn move_to_front(&mut self, handle: OrderHandle) {
        if self.head != handle.0 {
            self.detach(handle.0);
            self.link_front(handle.0);
        }
    }

    /// The eviction-first element, if any.
    pub fn front(&self) -> Option<T> {
        if self.head == NIL {
            None
        } else {
            Some(self.nodes[self.head as usize].item)
        }
    }

    /// The most-recently-ordered element, if any.
    pub fn back(&self) -> Option<T> {
        if self.tail == NIL {
            None
        } else {
            Some(self.nodes[self.tail as usize].item)
        }
    }

    /// Unlink and return the eviction-first element.
    pub fn pop_front(&mut self) -> Option<T> {
        if self.head == NIL {
            None
        } else {
            Some(self.unlink(OrderHandle(self.head)))
        }
    }

    /// The element behind a live handle.
    pub fn get(&self, handle: OrderHandle) -> T {
        let node = &self.nodes[handle.0 as usize];
        debug_assert_ne!(node.prev, FREE, "stale OrderHandle");
        node.item
    }

    /// Replace the element behind a live handle (its position is kept),
    /// returning the previous value.
    pub fn set(&mut self, handle: OrderHandle, item: T) -> T {
        let node = &mut self.nodes[handle.0 as usize];
        debug_assert_ne!(node.prev, FREE, "stale OrderHandle");
        std::mem::replace(&mut node.item, item)
    }

    /// Iterate front (eviction-first) to back. O(n) — diagnostics and
    /// `eviction_order` helpers only, never the hot path.
    pub fn iter(&self) -> Iter<'_, T> {
        Iter { list: self, cur: self.head }
    }

    /// Drop every element (slab space is released too).
    pub fn clear(&mut self) {
        self.nodes.clear();
        self.head = NIL;
        self.tail = NIL;
        self.free = NIL;
        self.len = 0;
    }
}

impl<T: Copy> Default for OrderList<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Recency-ordered set of ids over an [`OrderList`] plus a handle map:
/// O(1) touch/insert/remove and an O(1)-per-drop capacity trim, all
/// allocation-free in steady state. One implementation for every bounded
/// "ghost"-style history in the crate (the ARC B1/B2 lists, the admission
/// ghost) — keeps the unlink/trim invariants in a single place.
#[derive(Debug, Clone)]
pub struct LruSet<T> {
    index: IdHashMap<T, OrderHandle>,
    order: OrderList<T>,
}

impl<T: Copy + Eq + Hash> LruSet<T> {
    /// Empty set.
    pub fn new() -> Self {
        LruSet { index: IdHashMap::default(), order: OrderList::new() }
    }

    /// Insert `item` as most-recently-seen, or refresh its recency if
    /// already a member.
    pub fn touch_or_insert(&mut self, item: T) {
        if let Some(&handle) = self.index.get(&item) {
            self.order.move_to_back(handle);
        } else {
            let handle = self.order.push_back(item);
            self.index.insert(item, handle);
        }
    }

    /// Drop least-recently-seen members until `len() <= cap`.
    pub fn trim_to(&mut self, cap: usize) {
        while self.order.len() > cap {
            let oldest = self.order.pop_front().expect("len > cap implies members");
            self.index.remove(&oldest);
        }
    }

    /// Remove `item`; true if it was a member.
    pub fn remove(&mut self, item: T) -> bool {
        match self.index.remove(&item) {
            Some(handle) => {
                self.order.unlink(handle);
                true
            }
            None => false,
        }
    }

    /// Whether `item` is a member.
    pub fn contains(&self, item: T) -> bool {
        self.index.contains_key(&item)
    }

    /// Member count.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// Whether the set has no members.
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Slab slots of the backing list (see [`OrderList::slots`]).
    pub fn slots(&self) -> usize {
        self.order.slots()
    }
}

impl<T: Copy + Eq + Hash> Default for LruSet<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Front-to-back iterator over an [`OrderList`].
pub struct Iter<'a, T> {
    list: &'a OrderList<T>,
    cur: u32,
}

impl<T: Copy> Iterator for Iter<'_, T> {
    type Item = T;

    fn next(&mut self) -> Option<T> {
        if self.cur == NIL {
            return None;
        }
        let node = &self.list.nodes[self.cur as usize];
        self.cur = node.next;
        Some(node.item)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collect(list: &OrderList<u64>) -> Vec<u64> {
        list.iter().collect()
    }

    #[test]
    fn push_move_unlink_order() {
        let mut l = OrderList::new();
        let a = l.push_back(1u64);
        let b = l.push_back(2);
        let c = l.push_back(3);
        assert_eq!(collect(&l), vec![1, 2, 3]);
        l.move_to_back(a);
        assert_eq!(collect(&l), vec![2, 3, 1]);
        l.move_to_front(c);
        assert_eq!(collect(&l), vec![3, 2, 1]);
        assert_eq!(l.unlink(b), 2);
        assert_eq!(collect(&l), vec![3, 1]);
        assert_eq!(l.front(), Some(3));
        assert_eq!(l.back(), Some(1));
        assert_eq!(l.len(), 2);
    }

    #[test]
    fn push_front_orders_before_head() {
        let mut l = OrderList::new();
        l.push_back(2u64);
        l.push_front(1);
        l.push_front(0);
        assert_eq!(collect(&l), vec![0, 1, 2]);
        assert_eq!(l.pop_front(), Some(0));
        assert_eq!(l.pop_front(), Some(1));
        assert_eq!(l.pop_front(), Some(2));
        assert_eq!(l.pop_front(), None);
        assert!(l.is_empty());
    }

    #[test]
    fn free_list_reuses_slots() {
        let mut l = OrderList::new();
        for i in 0..8u64 {
            l.push_back(i);
        }
        assert_eq!(l.slots(), 8);
        // Heavy churn at constant population: the slab must not grow.
        for i in 8..10_000u64 {
            let front = l.pop_front().unwrap();
            assert_eq!(front, i - 8);
            l.push_back(i);
        }
        assert_eq!(l.len(), 8);
        assert_eq!(l.slots(), 8, "steady-state churn must not allocate");
    }

    #[test]
    fn handles_stay_stable_across_other_ops() {
        let mut l = OrderList::new();
        let handles: Vec<(u64, OrderHandle)> =
            (0..32u64).map(|i| (i, l.push_back(i))).collect();
        // Unlink every odd element; even handles must still resolve.
        for (i, h) in &handles {
            if i % 2 == 1 {
                assert_eq!(l.unlink(*h), *i);
            }
        }
        for (i, h) in &handles {
            if i % 2 == 0 {
                assert_eq!(l.get(*h), *i, "handle {i} moved");
            }
        }
        // New pushes reuse freed slots without disturbing live handles.
        for i in 100..116u64 {
            l.push_back(i);
        }
        assert_eq!(l.slots(), 32, "pushes reuse the 16 freed slots");
        for (i, h) in &handles {
            if i % 2 == 0 {
                assert_eq!(l.get(*h), *i);
            }
        }
    }

    #[test]
    fn insert_after_splices_in_place() {
        let mut l = OrderList::new();
        let a = l.push_back(1u64);
        let c = l.push_back(3);
        let b = l.insert_after(a, 2);
        assert_eq!(collect(&l), vec![1, 2, 3]);
        assert_eq!(l.len(), 3);
        // After the tail: becomes the new tail.
        let d = l.insert_after(c, 4);
        assert_eq!(collect(&l), vec![1, 2, 3, 4]);
        assert_eq!(l.back(), Some(4));
        // Handles walk the chain in order.
        assert_eq!(l.front_handle(), Some(a));
        assert_eq!(l.next_of(a), Some(b));
        assert_eq!(l.next_of(b), Some(c));
        assert_eq!(l.next_of(c), Some(d));
        assert_eq!(l.next_of(d), None);
        // Splicing reuses freed slots like any other alloc.
        l.unlink(b);
        let b2 = l.insert_after(a, 9);
        assert_eq!(collect(&l), vec![1, 9, 3, 4]);
        assert_eq!(l.get(b2), 9);
        assert_eq!(l.slots(), 4, "freed slot reused");
        // set replaces in place without reordering.
        assert_eq!(l.set(b2, 7), 9);
        assert_eq!(collect(&l), vec![1, 7, 3, 4]);
    }

    #[test]
    fn move_is_noop_at_its_end() {
        let mut l = OrderList::new();
        let a = l.push_back(1u64);
        let b = l.push_back(2);
        l.move_to_back(b);
        l.move_to_front(a);
        assert_eq!(collect(&l), vec![1, 2]);
        // Singleton: both moves are no-ops.
        l.unlink(b);
        l.move_to_back(a);
        l.move_to_front(a);
        assert_eq!(collect(&l), vec![1]);
    }

    #[test]
    fn lru_set_touch_trim_remove() {
        let mut s: LruSet<u64> = LruSet::default();
        for i in 0..4u64 {
            s.touch_or_insert(i);
        }
        s.touch_or_insert(0); // refresh: 0 becomes most recent
        s.trim_to(2);
        assert_eq!(s.len(), 2);
        assert!(s.contains(3) && s.contains(0), "LRU members trimmed first");
        assert!(!s.contains(1) && !s.contains(2));
        assert!(s.remove(3));
        assert!(!s.remove(3));
        assert!(!s.is_empty());
        // Churn at constant population reuses slots.
        for i in 100..1_000u64 {
            s.touch_or_insert(i);
            s.trim_to(4);
        }
        assert!(s.slots() <= 5, "trimmed churn grew the slab to {}", s.slots());
    }

    #[test]
    fn clear_resets_everything() {
        let mut l = OrderList::new();
        for i in 0..4u64 {
            l.push_back(i);
        }
        l.clear();
        assert!(l.is_empty());
        assert_eq!(l.slots(), 0);
        assert_eq!(l.front(), None);
        let h = l.push_back(9);
        assert_eq!(l.get(h), 9);
    }
}
