//! One-stop cache construction.
//!
//! `CacheBuilder` replaces the constructor sprawl that accreted on the
//! cache front — `ShardedCache::{new, with_admission, from_registry,
//! from_registry_with_admission}` and `BlockCache::with_admission` — with
//! a single builder covering every axis those constructors hard-coded:
//!
//! * eviction policy, by registry name or by factory closure;
//! * admission policy, by registry name or by factory closure;
//! * shard count and total capacity;
//! * an optional recompute-cost tie-break wrapper ([`CostAware`]);
//! * an optional [`MetricsRegistry`] hookup (construction-time gauges);
//! * the recency-batching knobs of the lock-free read path
//!   ([`RecencyConfig`], `cache::read_path`).
//!
//! The old constructors survive one PR as `#[deprecated]` shims; the
//! parity tests in rust/tests/property_sharded.rs pin them to the builder
//! under `#[allow(deprecated)]`.
//!
//! ```
//! use h_svm_lru::cache::CacheBuilder;
//!
//! let cache = CacheBuilder::new()
//!     .policy("h-svm-lru")
//!     .admission("tinylfu")
//!     .shards(8)
//!     .capacity(1 << 20)
//!     .build()
//!     .unwrap();
//! assert_eq!(cache.n_shards(), 8);
//! assert_eq!(cache.policy_name(), "h-svm-lru");
//! ```

use crate::obs::MetricsRegistry;

use super::admission::{make_admission, AdmissionPolicy};
use super::cost_aware::CostAware;
use super::read_path::RecencyConfig;
use super::registry::make_policy;
use super::{BlockCache, CachePolicy, ShardedCache};

/// What can go wrong assembling a cache from builder state.
#[derive(Debug, PartialEq, Eq, thiserror::Error)]
pub enum CacheBuildError {
    /// Neither [`CacheBuilder::policy`] nor [`CacheBuilder::policy_with`]
    /// was called.
    #[error("no eviction policy configured (call policy() or policy_with())")]
    MissingPolicy,
    /// The policy name is not in the registry.
    #[error("unknown eviction policy {0:?}")]
    UnknownPolicy(String),
    /// The admission name is not in the registry.
    #[error("unknown admission policy {0:?}")]
    UnknownAdmission(String),
    /// The shard count was set to zero.
    #[error("cache needs at least one shard")]
    ZeroShards,
    /// The recency batch was set to zero (a drain could never trigger).
    #[error("recency batch must be >= 1")]
    ZeroRecencyBatch,
    /// [`CacheBuilder::build_block_cache`] with a multi-shard config.
    #[error("build_block_cache requires exactly one shard (got {0})")]
    MultiShardBlockCache(usize),
}

enum PolicySource {
    Name(String),
    Factory(Box<dyn Fn() -> Box<dyn CachePolicy>>),
}

enum AdmissionSource {
    Name(String),
    Factory(Box<dyn Fn() -> Box<dyn AdmissionPolicy>>),
}

/// Builder for [`BlockCache`] and [`ShardedCache`] — see the module docs.
///
/// The lifetime ties an optional borrowed [`MetricsRegistry`] to the
/// builder; plain constructions (`CacheBuilder::new()...build()`) never
/// notice it.
pub struct CacheBuilder<'a> {
    policy: Option<PolicySource>,
    admission: AdmissionSource,
    shards: usize,
    capacity: u64,
    cost_window: Option<usize>,
    recency: RecencyConfig,
    metrics: Option<&'a MetricsRegistry>,
}

impl Default for CacheBuilder<'_> {
    fn default() -> Self {
        Self::new()
    }
}

impl<'a> CacheBuilder<'a> {
    /// A builder with the behavior-preserving defaults: 1 shard, capacity
    /// 0, `always` admission, no cost wrapper, immediate recency drains.
    pub fn new() -> Self {
        CacheBuilder {
            policy: None,
            admission: AdmissionSource::Name("always".to_string()),
            shards: 1,
            capacity: 0,
            cost_window: None,
            recency: RecencyConfig::default(),
            metrics: None,
        }
    }

    /// Eviction policy by registry name (e.g. "lru", "h-svm-lru").
    pub fn policy(mut self, name: &str) -> Self {
        self.policy = Some(PolicySource::Name(name.to_string()));
        self
    }

    /// Eviction policy by factory — called once per shard, for policies
    /// that need non-registry construction (custom windows, test doubles).
    pub fn policy_with(mut self, make: impl Fn() -> Box<dyn CachePolicy> + 'static) -> Self {
        self.policy = Some(PolicySource::Factory(Box::new(make)));
        self
    }

    /// Admission policy by registry name ("always" / "tinylfu" / "ghost" /
    /// "svm"). The default is "always" (no gate).
    pub fn admission(mut self, name: &str) -> Self {
        self.admission = AdmissionSource::Name(name.to_string());
        self
    }

    /// Admission policy by factory — called once per shard.
    pub fn admission_with(
        mut self,
        make: impl Fn() -> Box<dyn AdmissionPolicy> + 'static,
    ) -> Self {
        self.admission = AdmissionSource::Factory(Box::new(make));
        self
    }

    /// Number of independently locked shards (>= 1; default 1).
    pub fn shards(mut self, n: usize) -> Self {
        self.shards = n;
        self
    }

    /// Total capacity in bytes, split across shards.
    pub fn capacity(mut self, bytes: u64) -> Self {
        self.capacity = bytes;
        self
    }

    /// Wrap every shard's policy in the recompute-cost tie-break
    /// ([`CostAware`]) with candidate window `k` (>= 1). With uniform
    /// costs the wrapper is bit-identical to the base policy.
    pub fn cost_aware(mut self, k: usize) -> Self {
        self.cost_window = Some(k.max(1));
        self
    }

    /// Recency-batching knobs for the lock-free read path. The default
    /// ([`RecencyConfig::immediate`]) is bit-identical to the locked path.
    pub fn recency(mut self, cfg: RecencyConfig) -> Self {
        self.recency = cfg;
        self
    }

    /// Export construction facts (capacity, shard count, recency knobs) as
    /// gauges on `registry` at build time. A disabled registry is a no-op.
    pub fn metrics(mut self, registry: &'a MetricsRegistry) -> Self {
        self.metrics = Some(registry);
        self
    }

    fn validate(&self) -> Result<(), CacheBuildError> {
        if self.policy.is_none() {
            return Err(CacheBuildError::MissingPolicy);
        }
        if self.shards == 0 {
            return Err(CacheBuildError::ZeroShards);
        }
        if self.recency.batch == 0 {
            return Err(CacheBuildError::ZeroRecencyBatch);
        }
        Ok(())
    }

    fn make_policy(&self) -> Result<Box<dyn CachePolicy>, CacheBuildError> {
        let base = match self.policy.as_ref().expect("validated") {
            PolicySource::Name(name) => make_policy(name)
                .ok_or_else(|| CacheBuildError::UnknownPolicy(name.clone()))?,
            PolicySource::Factory(make) => make(),
        };
        Ok(match self.cost_window {
            Some(k) => Box::new(CostAware::new(base, "cost-aware").with_window(k)),
            None => base,
        })
    }

    fn make_admission(&self) -> Result<Box<dyn AdmissionPolicy>, CacheBuildError> {
        match &self.admission {
            AdmissionSource::Name(name) => make_admission(name)
                .ok_or_else(|| CacheBuildError::UnknownAdmission(name.clone())),
            AdmissionSource::Factory(make) => Ok(make()),
        }
    }

    fn export_gauges(&self) {
        if let Some(registry) = self.metrics {
            let v = self.capacity;
            registry.gauge("cache_capacity_bytes", move || v);
            let v = self.shards as u64;
            registry.gauge("cache_shards", move || v);
            let v = self.recency.batch as u64;
            registry.gauge("cache_recency_batch", move || v);
            let v = self.recency.drain_cadence.micros();
            registry.gauge("cache_recency_drain_cadence_us", move || v);
        }
    }

    /// Assemble a [`ShardedCache`].
    pub fn build(self) -> Result<ShardedCache, CacheBuildError> {
        self.validate()?;
        let policies = (0..self.shards)
            .map(|_| self.make_policy())
            .collect::<Result<Vec<_>, _>>()?;
        let admissions = (0..self.shards)
            .map(|_| self.make_admission())
            .collect::<Result<Vec<_>, _>>()?;
        self.export_gauges();
        Ok(ShardedCache::assemble(policies, admissions, self.capacity, self.recency))
    }

    /// Assemble a bare single-shard [`BlockCache`] (unit tests, hot-path
    /// benches, per-node caches that do their own locking).
    pub fn build_block_cache(self) -> Result<BlockCache, CacheBuildError> {
        self.validate()?;
        if self.shards != 1 {
            return Err(CacheBuildError::MultiShardBlockCache(self.shards));
        }
        let policy = self.make_policy()?;
        let admission = self.make_admission()?;
        self.export_gauges();
        Ok(BlockCache::assemble(policy, admission, self.capacity))
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::super::lru::Lru;
    use super::*;
    use crate::cache::admission::AlwaysAdmit;
    use crate::cache::AccessContext;
    use crate::hdfs::BlockId;
    use crate::sim::{SimDuration, SimTime};

    #[test]
    fn builds_from_registry_names() {
        let cache = CacheBuilder::new()
            .policy("h-svm-lru")
            .admission("tinylfu")
            .shards(2)
            .capacity(8)
            .build()
            .unwrap();
        assert_eq!(cache.n_shards(), 2);
        assert_eq!(cache.capacity(), 8);
        assert_eq!(cache.policy_name(), "h-svm-lru");
        assert_eq!(cache.admission_name(), "tinylfu");
    }

    #[test]
    fn builds_from_factories() {
        let cache = CacheBuilder::new()
            .policy_with(|| Box::new(Lru::new()))
            .admission_with(|| Box::new(AlwaysAdmit))
            .shards(3)
            .capacity(9)
            .build()
            .unwrap();
        assert_eq!(cache.n_shards(), 3);
        assert_eq!(cache.policy_name(), "lru");
        assert_eq!(cache.admission_name(), "always");
    }

    #[test]
    fn rejects_unknown_names_and_bad_knobs() {
        let err = CacheBuilder::new().policy("nonsense").capacity(8).build().unwrap_err();
        assert_eq!(err, CacheBuildError::UnknownPolicy("nonsense".to_string()));
        let err = CacheBuilder::new()
            .policy("lru")
            .admission("nonsense")
            .capacity(8)
            .build()
            .unwrap_err();
        assert_eq!(err, CacheBuildError::UnknownAdmission("nonsense".to_string()));
        let err = CacheBuilder::new().capacity(8).build().unwrap_err();
        assert_eq!(err, CacheBuildError::MissingPolicy);
        let err = CacheBuilder::new().policy("lru").shards(0).build().unwrap_err();
        assert_eq!(err, CacheBuildError::ZeroShards);
        let err = CacheBuilder::new()
            .policy("lru")
            .recency(RecencyConfig { batch: 0, drain_cadence: SimDuration::ZERO })
            .build()
            .unwrap_err();
        assert_eq!(err, CacheBuildError::ZeroRecencyBatch);
        let err =
            CacheBuilder::new().policy("lru").shards(2).build_block_cache().unwrap_err();
        assert_eq!(err, CacheBuildError::MultiShardBlockCache(2));
        assert!(err.to_string().contains("exactly one shard"));
    }

    #[test]
    fn block_cache_variant_matches_sharded_single_shard() {
        let mut bare = CacheBuilder::new()
            .policy("lru")
            .capacity(3)
            .build_block_cache()
            .unwrap();
        let sharded = CacheBuilder::new().policy("lru").capacity(3).build().unwrap();
        for t in 0..100u64 {
            let b = BlockId((t * 7 + t % 5) % 9);
            let ctx = AccessContext::simple(SimTime(t), 1);
            assert_eq!(bare.access_or_insert(b, &ctx), sharded.access_or_insert(b, &ctx));
        }
        assert_eq!(bare.cached_blocks(), sharded.cached_blocks());
    }

    #[test]
    fn cost_wrapper_knob_prefers_cheap_victims() {
        let mut cache = CacheBuilder::new()
            .policy("lru")
            .cost_aware(4)
            .capacity(3)
            .build_block_cache()
            .unwrap();
        assert_eq!(cache.policy_name(), "cost-aware");
        let ctx = |t: u64, cost: f64| {
            AccessContext::simple(SimTime(t), 1).with_recompute_cost(cost)
        };
        cache.access_or_insert(BlockId(1), &ctx(1, 45.0));
        cache.access_or_insert(BlockId(2), &ctx(2, 0.0));
        cache.access_or_insert(BlockId(3), &ctx(3, 45.0));
        let o = cache.access_or_insert(BlockId(4), &ctx(4, 45.0));
        assert_eq!(o.evicted, vec![BlockId(2)], "cheap block evicted before older ones");
    }

    #[test]
    fn metrics_knob_exports_construction_gauges() {
        let registry = MetricsRegistry::new();
        let _cache = CacheBuilder::new()
            .policy("lru")
            .shards(4)
            .capacity(64)
            .recency(RecencyConfig::default().with_batch(8))
            .metrics(&registry)
            .build()
            .unwrap();
        let gauges = registry.gauge_values();
        let get = |name: &str| {
            gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap()
        };
        assert_eq!(get("cache_capacity_bytes"), 64);
        assert_eq!(get("cache_shards"), 4);
        assert_eq!(get("cache_recency_batch"), 8);
        assert_eq!(get("cache_recency_drain_cadence_us"), 0);
    }

    #[test]
    fn recency_knob_threads_into_the_cache() {
        let cache = CacheBuilder::new()
            .policy("lru")
            .capacity(4)
            .recency(RecencyConfig::default().with_batch(16))
            .build()
            .unwrap();
        assert_eq!(cache.recency_config().batch, 16);
        let default = CacheBuilder::new().policy("lru").capacity(4).build().unwrap();
        assert_eq!(default.recency_config(), RecencyConfig::default());
    }
}
