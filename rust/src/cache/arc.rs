//! Modified ARC (collaborative caching, paper §3.1 / [10]): the cache is
//! split into a *recent* list (T1, seen once) and a *frequent* list (T2,
//! seen again), each shadowed by a ghost history (B1/B2) holding references
//! to evicted blocks. A hit in a ghost list adapts the target size `p` of
//! the recent region and promotes the block on re-insertion.
//!
//! All four queues are intrusive: T1/T2 are [`OrderList`]s with handles in
//! the residency map, B1/B2 are [`LruSet`]s (the shared OrderList-backed
//! ghost history), so every promotion, ghost hit and ghost trim is an O(1)
//! allocation-free splice — the original `VecDeque`s paid an O(n) position
//! scan per removal. Order semantics are unchanged (property-tested
//! against the VecDeque implementation in
//! rust/tests/property_orderlist.rs).

use crate::util::fasthash::IdHashMap;

use crate::hdfs::BlockId;
use crate::sim::SimTime;

use super::order_list::{LruSet, OrderHandle, OrderList};
use super::{AccessContext, CachePolicy};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum List {
    Recent,   // T1
    Frequent, // T2
}

/// Modified ARC: adaptive recent/frequent lists with ghost histories.
#[derive(Debug)]
pub struct ModifiedArc {
    t1: OrderList<BlockId>,
    t2: OrderList<BlockId>,
    where_is: IdHashMap<BlockId, (List, OrderHandle)>,
    /// Ghost histories (most recent at the back), bounded by `ghost_cap`.
    b1: LruSet<BlockId>,
    b2: LruSet<BlockId>,
    ghost_cap: usize,
    /// Adaptive target for |T1| (in blocks).
    p: f64,
}

impl ModifiedArc {
    /// Create an empty policy; `ghost_cap` bounds each ghost history.
    pub fn new(ghost_cap: usize) -> Self {
        ModifiedArc {
            t1: OrderList::new(),
            t2: OrderList::new(),
            where_is: IdHashMap::default(),
            b1: LruSet::new(),
            b2: LruSet::new(),
            ghost_cap: ghost_cap.max(1),
            p: 0.0,
        }
    }

    /// A block leaves the cache: remember it in the ghost history. Cached
    /// blocks are never ghost members (re-insertion consumes the entry),
    /// so this is a pure append + trim.
    fn ghost_push(ghost: &mut LruSet<BlockId>, cap: usize, block: BlockId) {
        debug_assert!(!ghost.contains(block), "duplicate ghost entry");
        ghost.touch_or_insert(block);
        ghost.trim_to(cap);
    }

    /// Number of blocks in the recent (T1) list.
    pub fn recent_len(&self) -> usize {
        self.t1.len()
    }

    /// Number of blocks in the frequent (T2) list.
    pub fn frequent_len(&self) -> usize {
        self.t2.len()
    }

    /// Current adaptive target size for the recent list, in blocks.
    pub fn target_recent(&self) -> f64 {
        self.p
    }
}

impl CachePolicy for ModifiedArc {
    fn name(&self) -> &'static str {
        "modified-arc"
    }

    fn on_hit(&mut self, block: BlockId, _ctx: &AccessContext) {
        // Any cache hit promotes to the MRU end of the frequent list.
        match self.where_is.get(&block) {
            Some(&(List::Recent, handle)) => {
                self.t1.unlink(handle);
            }
            Some(&(List::Frequent, handle)) => {
                self.t2.unlink(handle);
            }
            None => panic!("hit on untracked block"),
        }
        let handle = self.t2.push_back(block);
        self.where_is.insert(block, (List::Frequent, handle));
    }

    fn on_insert(&mut self, block: BlockId, _ctx: &AccessContext) {
        debug_assert!(!self.where_is.contains_key(&block), "double insert");
        let total = (self.t1.len() + self.t2.len()).max(1) as f64;
        // Ghost hits adapt p and steer the block into the frequent list.
        if self.b1.remove(block) {
            let delta = (self.b2.len().max(1) as f64 / self.b1.len().max(1) as f64).max(1.0);
            self.p = (self.p + delta).min(total);
            let handle = self.t2.push_back(block);
            self.where_is.insert(block, (List::Frequent, handle));
        } else if self.b2.remove(block) {
            let delta = (self.b1.len().max(1) as f64 / self.b2.len().max(1) as f64).max(1.0);
            self.p = (self.p - delta).max(0.0);
            let handle = self.t2.push_back(block);
            self.where_is.insert(block, (List::Frequent, handle));
        } else {
            let handle = self.t1.push_back(block);
            self.where_is.insert(block, (List::Recent, handle));
        }
    }

    fn choose_victim(&mut self, _now: SimTime) -> Option<BlockId> {
        // Evict from T1 while it exceeds the target p, otherwise from T2;
        // victims are the LRU (front) entries.
        if !self.t1.is_empty() && (self.t1.len() as f64 > self.p || self.t2.is_empty()) {
            self.t1.front()
        } else {
            self.t2.front().or_else(|| self.t1.front())
        }
    }

    fn victim_candidates(&mut self, _now: SimTime, k: usize) -> Vec<BlockId> {
        // Same list preference as `choose_victim`, extended to a window:
        // drain the preferred list front-to-back, then the other.
        let prefer_recent =
            !self.t1.is_empty() && (self.t1.len() as f64 > self.p || self.t2.is_empty());
        let (first, second) =
            if prefer_recent { (&self.t1, &self.t2) } else { (&self.t2, &self.t1) };
        first.iter().chain(second.iter()).take(k).collect()
    }

    fn on_evict(&mut self, block: BlockId) {
        match self.where_is.remove(&block) {
            Some((List::Recent, handle)) => {
                self.t1.unlink(handle);
                Self::ghost_push(&mut self.b1, self.ghost_cap, block);
            }
            Some((List::Frequent, handle)) => {
                self.t2.unlink(handle);
                Self::ghost_push(&mut self.b2, self.ghost_cap, block);
            }
            None => {}
        }
    }

    fn len(&self) -> usize {
        self.where_is.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> AccessContext {
        AccessContext::simple(SimTime(0), 1)
    }

    #[test]
    fn hit_promotes_to_frequent() {
        let mut p = ModifiedArc::new(16);
        p.on_insert(BlockId(1), &ctx());
        assert_eq!(p.recent_len(), 1);
        p.on_hit(BlockId(1), &ctx());
        assert_eq!(p.recent_len(), 0);
        assert_eq!(p.frequent_len(), 1);
    }

    #[test]
    fn victim_prefers_recent_list() {
        let mut p = ModifiedArc::new(16);
        p.on_insert(BlockId(1), &ctx());
        p.on_insert(BlockId(2), &ctx());
        p.on_hit(BlockId(1), &ctx()); // 1 -> T2
        assert_eq!(p.choose_victim(SimTime(0)), Some(BlockId(2)));
    }

    #[test]
    fn ghost_hit_adapts_and_promotes() {
        let mut p = ModifiedArc::new(16);
        p.on_insert(BlockId(1), &ctx());
        p.on_evict(BlockId(1)); // 1 lands in B1
        let p_before = p.target_recent();
        p.on_insert(BlockId(1), &ctx()); // ghost hit in B1
        assert!(p.target_recent() > p_before, "p should grow on B1 hit");
        assert_eq!(p.frequent_len(), 1, "ghost hit goes straight to T2");
    }

    #[test]
    fn ghost_lists_are_bounded() {
        let mut p = ModifiedArc::new(4);
        for i in 0..20 {
            p.on_insert(BlockId(i), &ctx());
            p.on_evict(BlockId(i));
        }
        assert_eq!(p.len(), 0);
        assert!(p.b1.len() <= 4);
        // Bounded churn must also bound the slab, not just the length.
        assert!(p.b1.slots() <= 5, "ghost churn must reuse slots");
    }

    #[test]
    fn drain_all() {
        let mut p = ModifiedArc::new(8);
        for i in 0..6 {
            p.on_insert(BlockId(i), &ctx());
        }
        p.on_hit(BlockId(0), &ctx());
        p.on_hit(BlockId(3), &ctx());
        let mut evicted = Vec::new();
        while let Some(v) = p.choose_victim(SimTime(0)) {
            p.on_evict(v);
            evicted.push(v);
        }
        assert_eq!(evicted.len(), 6);
        assert_eq!(p.len(), 0);
    }
}
