//! WSClock (EDACHE [9]): cached items on a circular list with a clock hand.
//! On a victim scan: a set reference bit is cleared (second chance, last-use
//! updated); an unset bit with age > tau evicts the item. If a full sweep
//! finds no candidate, the oldest unreferenced item is evicted anyway
//! (bounded scan — the EDACHE "long search" disadvantage is modeled but
//! terminates).

use std::collections::HashMap;

use crate::hdfs::BlockId;
use crate::sim::{SimDuration, SimTime};

use super::{AccessContext, CachePolicy};

#[derive(Debug, Clone)]
struct Slot {
    block: BlockId,
    referenced: bool,
    last_used: SimTime,
}

/// WSClock: a clock ring where the hand skips referenced-or-young slots
/// (working-set approximation of LRU).
#[derive(Debug)]
pub struct WsClock {
    ring: Vec<Slot>,
    hand: usize,
    index: HashMap<BlockId, usize>,
    /// Age threshold tau: unreferenced items older than this are evictable.
    tau: SimDuration,
}

impl WsClock {
    /// Policy with age threshold `tau`.
    pub fn new(tau: SimDuration) -> Self {
        WsClock { ring: Vec::new(), hand: 0, index: HashMap::new(), tau }
    }

    fn remove_at(&mut self, pos: usize) -> BlockId {
        let slot = self.ring.swap_remove(pos);
        self.index.remove(&slot.block);
        // swap_remove moved the tail into `pos`: fix its index entry.
        if pos < self.ring.len() {
            let moved = self.ring[pos].block;
            self.index.insert(moved, pos);
        }
        if self.hand >= self.ring.len() {
            self.hand = 0;
        }
        slot.block
    }
}

impl CachePolicy for WsClock {
    fn name(&self) -> &'static str {
        "wsclock"
    }

    fn on_hit(&mut self, block: BlockId, ctx: &AccessContext) {
        let &pos = self.index.get(&block).expect("hit on untracked block");
        self.ring[pos].referenced = true;
        self.ring[pos].last_used = ctx.time;
    }

    fn on_insert(&mut self, block: BlockId, ctx: &AccessContext) {
        debug_assert!(!self.index.contains_key(&block), "double insert");
        self.index.insert(block, self.ring.len());
        self.ring.push(Slot { block, referenced: true, last_used: ctx.time });
    }

    fn choose_victim(&mut self, now: SimTime) -> Option<BlockId> {
        if self.ring.is_empty() {
            return None;
        }
        // One full sweep: clear reference bits, return the first old
        // unreferenced item.
        for _ in 0..self.ring.len() {
            let pos = self.hand;
            self.hand = (self.hand + 1) % self.ring.len();
            let slot = &mut self.ring[pos];
            if slot.referenced {
                // Second chance: clear the bit, refresh the use time.
                slot.referenced = false;
                slot.last_used = now;
                continue;
            }
            if slot.last_used.duration_until(now) >= self.tau {
                return Some(slot.block);
            }
        }
        // No aged item: fall back to the oldest unreferenced (or plain
        // oldest) item so eviction always terminates.
        self.ring
            .iter()
            .min_by_key(|s| (s.referenced, s.last_used, s.block))
            .map(|s| s.block)
    }

    fn on_evict(&mut self, block: BlockId) {
        if let Some(&pos) = self.index.get(&block) {
            self.remove_at(pos);
        }
    }

    fn len(&self) -> usize {
        self.ring.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(t: u64) -> AccessContext {
        AccessContext::simple(SimTime(t), 1)
    }

    #[test]
    fn second_chance_spares_referenced_items() {
        let mut p = WsClock::new(SimDuration(10));
        p.on_insert(BlockId(1), &ctx(0));
        p.on_insert(BlockId(2), &ctx(0));
        // Both referenced; first sweep clears bits, fallback picks oldest.
        let v1 = p.choose_victim(SimTime(100)).unwrap();
        // Now hit block 1: its bit is set again -> victim must be block 2.
        p.on_hit(BlockId(1), &ctx(101));
        let v2 = p.choose_victim(SimTime(200)).unwrap();
        assert_eq!(v2, BlockId(2));
        let _ = v1;
    }

    #[test]
    fn aged_unreferenced_item_is_victim() {
        let mut p = WsClock::new(SimDuration(10));
        p.on_insert(BlockId(1), &ctx(0));
        p.on_insert(BlockId(2), &ctx(0));
        // First victim call clears both bits (time 5 -> not aged yet,
        // fallback used). Second call at t=50: both unreferenced and aged.
        p.choose_victim(SimTime(5));
        let v = p.choose_victim(SimTime(50));
        assert!(v.is_some());
    }

    #[test]
    fn evict_maintains_ring_integrity() {
        let mut p = WsClock::new(SimDuration(10));
        for i in 0..5 {
            p.on_insert(BlockId(i), &ctx(i));
        }
        p.on_evict(BlockId(2));
        assert_eq!(p.len(), 4);
        // All remaining blocks still reachable via on_hit without panic.
        for i in [0u64, 1, 3, 4] {
            p.on_hit(BlockId(i), &ctx(10 + i));
        }
        // Evict everything; victims must be distinct and tracked.
        let mut victims = Vec::new();
        while let Some(v) = p.choose_victim(SimTime(1000)) {
            p.on_evict(v);
            victims.push(v);
        }
        victims.sort();
        victims.dedup();
        assert_eq!(victims.len(), 4);
        assert_eq!(p.len(), 0);
    }
}
