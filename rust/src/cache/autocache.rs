//! AutoCache-style replacement (paper §3.1 / [14]): a lightweight learned
//! model scores each file/block with a *probability of future access*; the
//! eviction pass starts when free space drops below a low watermark (10%)
//! and keeps evicting until usage falls below a high watermark (85%).
//!
//! The original uses XGBoost over file-access features. Offline we model it
//! with an online logistic scorer over the same feature intuition
//! (recency, frequency, affinity) updated by observed reuse — the paper
//! itself only requires "a probability score used by the replacement
//! policy". The SVM prediction (when present in the context) is folded in,
//! making this a useful ablation against H-SVM-LRU.

use std::collections::HashMap;

use crate::hdfs::BlockId;
use crate::sim::SimTime;

use super::{AccessContext, CachePolicy};

#[derive(Debug, Clone, Copy)]
struct Entry {
    accesses: u64,
    last_access: SimTime,
    affinity: f64,
    predicted_reuse: Option<bool>,
}

/// Logistic-scored eviction over frequency/recency/affinity/SVM-hint
/// features; victim = lowest predicted re-reference probability.
#[derive(Debug)]
pub struct AutoCache {
    entries: HashMap<BlockId, Entry>,
    /// Logistic weights: [bias, log1p(freq), recency_decay, affinity, svm].
    weights: [f64; 5],
    /// Recency half-life in seconds for the decay feature.
    half_life_s: f64,
}

impl Default for AutoCache {
    fn default() -> Self {
        Self::new()
    }
}

impl AutoCache {
    /// Policy with the default prior weights.
    pub fn new() -> Self {
        AutoCache {
            entries: HashMap::new(),
            // Sensible prior: frequency and recency dominate, affinity and
            // the SVM hint contribute.
            weights: [-1.0, 1.2, 1.5, 0.8, 1.0],
            half_life_s: 60.0,
        }
    }

    fn features(&self, e: &Entry, now: SimTime) -> [f64; 5] {
        let age = e.last_access.duration_until(now).as_secs_f64();
        let decay = 0.5f64.powf(age / self.half_life_s);
        let svm = match e.predicted_reuse {
            Some(true) => 1.0,
            Some(false) => -1.0,
            None => 0.0,
        };
        [1.0, ((e.accesses as f64).ln_1p()), decay, e.affinity, svm]
    }

    /// Probability of future access in [0, 1].
    pub fn probability(&self, block: BlockId, now: SimTime) -> Option<f64> {
        let e = self.entries.get(&block)?;
        let x = self.features(e, now);
        let z: f64 = x.iter().zip(&self.weights).map(|(a, w)| a * w).sum();
        Some(1.0 / (1.0 + (-z).exp()))
    }

    /// Online update: a re-access is a positive example for the block's
    /// pre-access state (one SGD step on the logistic loss).
    fn learn(&mut self, e: &Entry, now: SimTime, label: f64) {
        let x = self.features(e, now);
        let z: f64 = x.iter().zip(&self.weights).map(|(a, w)| a * w).sum();
        let p = 1.0 / (1.0 + (-z).exp());
        let lr = 0.05;
        for (w, xi) in self.weights.iter_mut().zip(&x) {
            *w += lr * (label - p) * xi;
        }
    }
}

impl CachePolicy for AutoCache {
    fn name(&self) -> &'static str {
        "autocache"
    }

    fn on_hit(&mut self, block: BlockId, ctx: &AccessContext) {
        let e = *self.entries.get(&block).expect("hit on untracked block");
        // The hit proves the block was worth caching: positive example.
        self.learn(&e, ctx.time, 1.0);
        let e = self.entries.get_mut(&block).unwrap();
        e.accesses += 1;
        e.last_access = ctx.time;
        e.affinity = e.affinity.max(ctx.affinity.weight());
        e.predicted_reuse = ctx.predicted_reuse.or(e.predicted_reuse);
    }

    fn on_insert(&mut self, block: BlockId, ctx: &AccessContext) {
        debug_assert!(!self.entries.contains_key(&block), "double insert");
        self.entries.insert(
            block,
            Entry {
                accesses: 1,
                last_access: ctx.time,
                affinity: ctx.affinity.weight(),
                predicted_reuse: ctx.predicted_reuse,
            },
        );
    }

    fn choose_victim(&mut self, now: SimTime) -> Option<BlockId> {
        let victim = self
            .entries
            .iter()
            .map(|(b, e)| {
                let x = self.features(e, now);
                let z: f64 = x.iter().zip(&self.weights).map(|(a, w)| a * w).sum();
                (*b, z)
            })
            .min_by(|(ba, za), (bb, zb)| za.partial_cmp(zb).unwrap().then(ba.cmp(bb)))
            .map(|(b, _)| b);
        // The eviction is a negative example for the victim's state.
        if let Some(b) = victim {
            if let Some(e) = self.entries.get(&b).copied() {
                self.learn(&e, now, 0.0);
            }
        }
        victim
    }

    fn on_evict(&mut self, block: BlockId) {
        self.entries.remove(&block);
    }

    fn len(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheAffinity;

    fn ctx(t_secs: f64, aff: CacheAffinity) -> AccessContext {
        let mut c = AccessContext::simple(SimTime::from_secs_f64(t_secs), 1);
        c.affinity = aff;
        c
    }

    #[test]
    fn hot_block_outscores_cold() {
        let mut p = AutoCache::new();
        p.on_insert(BlockId(1), &ctx(0.0, CacheAffinity::High));
        p.on_insert(BlockId(2), &ctx(0.0, CacheAffinity::Low));
        for t in [10.0, 20.0, 30.0] {
            p.on_hit(BlockId(1), &ctx(t, CacheAffinity::High));
        }
        let now = SimTime::from_secs_f64(31.0);
        let p1 = p.probability(BlockId(1), now).unwrap();
        let p2 = p.probability(BlockId(2), now).unwrap();
        assert!(p1 > p2, "hot {p1} vs cold {p2}");
        assert_eq!(p.choose_victim(now), Some(BlockId(2)));
    }

    #[test]
    fn svm_hint_shifts_probability() {
        let mut p = AutoCache::new();
        p.on_insert(BlockId(1), &ctx(0.0, CacheAffinity::Medium).with_prediction(true));
        p.on_insert(BlockId(2), &ctx(0.0, CacheAffinity::Medium).with_prediction(false));
        let now = SimTime::from_secs_f64(1.0);
        assert!(p.probability(BlockId(1), now) > p.probability(BlockId(2), now));
    }

    #[test]
    fn probabilities_are_valid() {
        let mut p = AutoCache::new();
        for i in 0..10 {
            p.on_insert(BlockId(i), &ctx(i as f64, CacheAffinity::Medium));
        }
        let now = SimTime::from_secs_f64(100.0);
        for i in 0..10 {
            let prob = p.probability(BlockId(i), now).unwrap();
            assert!((0.0..=1.0).contains(&prob));
        }
    }

    #[test]
    fn online_learning_moves_weights() {
        let mut p = AutoCache::new();
        let w0 = p.weights;
        p.on_insert(BlockId(1), &ctx(0.0, CacheAffinity::High));
        for t in 1..20 {
            p.on_hit(BlockId(1), &ctx(t as f64, CacheAffinity::High));
        }
        assert!(p.weights.iter().zip(&w0).any(|(a, b)| (a - b).abs() > 1e-6));
    }
}
