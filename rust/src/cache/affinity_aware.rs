//! Cache-affinity-aware replacement (paper §3.1 / [13]): the caching
//! *benefit* of a block is the product of the application's cache affinity
//! and the block's access frequency. The block with the lowest benefit is
//! evicted; ties fall back to LRU — exactly the strategy's description.

use std::collections::HashMap;

use crate::hdfs::BlockId;
use crate::sim::SimTime;

use super::{AccessContext, CachePolicy};

#[derive(Debug, Clone, Copy)]
struct Entry {
    frequency: u64,
    affinity: f64,
    /// LRU sequence for the tiebreak.
    lru_seq: u64,
}

/// Affinity-weighted LFU: victim = lowest `affinity x frequency`, LRU
/// tie-break.
#[derive(Debug, Default)]
pub struct AffinityAware {
    entries: HashMap<BlockId, Entry>,
    seq: u64,
}

impl AffinityAware {
    /// Empty policy state.
    pub fn new() -> Self {
        Self::default()
    }

    fn benefit(e: &Entry) -> f64 {
        e.affinity * e.frequency as f64
    }

    /// Current `affinity x frequency` benefit of a tracked block.
    pub fn benefit_of(&self, block: BlockId) -> Option<f64> {
        self.entries.get(&block).map(Self::benefit)
    }
}

impl CachePolicy for AffinityAware {
    fn name(&self) -> &'static str {
        "affinity-aware"
    }

    fn on_hit(&mut self, block: BlockId, ctx: &AccessContext) {
        self.seq += 1;
        let seq = self.seq;
        let e = self.entries.get_mut(&block).expect("hit on untracked block");
        e.frequency += 1;
        // The benefit reflects the affinity of the latest requesting app.
        e.affinity = ctx.affinity.weight();
        e.lru_seq = seq;
    }

    fn on_insert(&mut self, block: BlockId, ctx: &AccessContext) {
        debug_assert!(!self.entries.contains_key(&block), "double insert");
        self.seq += 1;
        self.entries.insert(
            block,
            Entry { frequency: 1, affinity: ctx.affinity.weight(), lru_seq: self.seq },
        );
    }

    fn choose_victim(&mut self, _now: SimTime) -> Option<BlockId> {
        self.entries
            .iter()
            .min_by(|(ba, ea), (bb, eb)| {
                Self::benefit(ea)
                    .partial_cmp(&Self::benefit(eb))
                    .unwrap()
                    .then(ea.lru_seq.cmp(&eb.lru_seq))
                    .then(ba.cmp(bb))
            })
            .map(|(b, _)| *b)
    }

    fn on_evict(&mut self, block: BlockId) {
        self.entries.remove(&block);
    }

    fn len(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheAffinity;

    fn ctx(aff: CacheAffinity) -> AccessContext {
        let mut c = AccessContext::simple(SimTime(0), 1);
        c.affinity = aff;
        c
    }

    #[test]
    fn low_affinity_low_frequency_evicted_first() {
        let mut p = AffinityAware::new();
        p.on_insert(BlockId(1), &ctx(CacheAffinity::High));
        p.on_insert(BlockId(2), &ctx(CacheAffinity::Low));
        p.on_insert(BlockId(3), &ctx(CacheAffinity::High));
        p.on_hit(BlockId(1), &ctx(CacheAffinity::High));
        // benefits: 1 -> 2.0, 2 -> 0.25, 3 -> 1.0
        assert_eq!(p.choose_victim(SimTime(1)), Some(BlockId(2)));
    }

    #[test]
    fn equal_benefit_falls_back_to_lru() {
        let mut p = AffinityAware::new();
        p.on_insert(BlockId(1), &ctx(CacheAffinity::Medium));
        p.on_insert(BlockId(2), &ctx(CacheAffinity::Medium));
        p.on_hit(BlockId(1), &ctx(CacheAffinity::Medium));
        p.on_hit(BlockId(2), &ctx(CacheAffinity::Medium));
        // Equal benefit (2 accesses, medium) -> LRU: block 1 is older.
        assert_eq!(p.choose_victim(SimTime(1)), Some(BlockId(1)));
    }

    #[test]
    fn frequency_raises_benefit() {
        let mut p = AffinityAware::new();
        p.on_insert(BlockId(1), &ctx(CacheAffinity::Low));
        for _ in 0..10 {
            p.on_hit(BlockId(1), &ctx(CacheAffinity::Low));
        }
        p.on_insert(BlockId(2), &ctx(CacheAffinity::Medium));
        // 1: 11 * 0.25 = 2.75 vs 2: 1 * 0.5 = 0.5
        assert_eq!(p.choose_victim(SimTime(1)), Some(BlockId(2)));
        assert!((p.benefit_of(BlockId(1)).unwrap() - 2.75).abs() < 1e-12);
    }
}
