//! H-SVM-LRU — the paper's contribution (Algorithm 1).
//!
//! The LRU stack is split into two regions. The *unused region* sits at the
//! top (eviction end) and holds blocks the SVM classified as "not reused in
//! the future"; the *reused region* sits at the bottom and holds predicted-
//! reused blocks in LRU order. Semantics, straight from Algorithm 1:
//!
//! * GetCache (hit): class 1 -> move to the bottom of the cache;
//!   class 0 -> move to the *top* ("to remove it immediately").
//! * PutCache (miss): evict from the top when full; class 1 -> insert at the
//!   bottom; class 0 -> insert at the *end of the unused data list* (or the
//!   top when no unused blocks exist).
//! * When every block has the same (reused) class the policy degenerates to
//!   plain LRU — the paper's own consistency claim, property-tested in
//!   rust/tests/property_cache.rs.
//!
//! Each region is an intrusive [`OrderList`] (two regions = two lists), so
//! every hit/insert/evict is an O(1) allocation-free splice — identical
//! order semantics to the original two-BTreeMap layout, property-tested in
//! rust/tests/property_orderlist.rs.
//!
//! The SVM prediction arrives via `AccessContext::predicted_reuse`, filled
//! by the coordinator (HLO-artifact predictor or the Rust SMO fallback).
//! An absent prediction (classifier not yet trained) behaves like class 1,
//! i.e. plain LRU.

use crate::util::fasthash::IdHashMap;

use crate::hdfs::BlockId;
use crate::sim::SimTime;

use super::order_list::{OrderHandle, OrderList};
use super::{AccessContext, CachePolicy};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Region {
    /// Predicted not-reused: the top of the cache, evicted first.
    Unused,
    /// Predicted reused: the bottom, LRU-ordered, protected.
    Reused,
}

/// The paper's two-region SVM-guided LRU (Algorithm 1).
#[derive(Debug, Default)]
pub struct HSvmLru {
    unused: OrderList<BlockId>,
    reused: OrderList<BlockId>,
    index: IdHashMap<BlockId, (Region, OrderHandle)>,
}

impl HSvmLru {
    /// Create an empty H-SVM-LRU policy.
    pub fn new() -> Self {
        Self::default()
    }

    fn detach(&mut self, block: BlockId) {
        if let Some((region, handle)) = self.index.remove(&block) {
            match region {
                Region::Unused => self.unused.unlink(handle),
                Region::Reused => self.reused.unlink(handle),
            };
        }
    }

    fn push_back(&mut self, region: Region, block: BlockId) {
        let handle = match region {
            Region::Unused => self.unused.push_back(block),
            Region::Reused => self.reused.push_back(block),
        };
        self.index.insert(block, (region, handle));
    }

    fn push_front_unused(&mut self, block: BlockId) {
        let handle = self.unused.push_front(block);
        self.index.insert(block, (Region::Unused, handle));
    }

    fn classify(ctx: &AccessContext) -> bool {
        // None = classifier not deployed yet -> treat as reused (plain LRU).
        ctx.predicted_reuse.unwrap_or(true)
    }

    /// Eviction order (first = next victim): whole unused region, then the
    /// reused region in LRU order. Diagnostic/test helper.
    pub fn eviction_order(&self) -> Vec<BlockId> {
        self.unused.iter().chain(self.reused.iter()).collect()
    }

    /// Number of blocks currently in the unused (evict-first) region.
    pub fn n_unused(&self) -> usize {
        self.unused.len()
    }

    /// Number of blocks currently in the protected reused region.
    pub fn n_reused(&self) -> usize {
        self.reused.len()
    }
}

impl CachePolicy for HSvmLru {
    fn name(&self) -> &'static str {
        "h-svm-lru"
    }

    fn on_hit(&mut self, block: BlockId, ctx: &AccessContext) {
        debug_assert!(self.index.contains_key(&block), "hit on untracked block");
        self.detach(block);
        if Self::classify(ctx) {
            // Reused class: move to the bottom of the cache.
            self.push_back(Region::Reused, block);
        } else {
            // Unused class: move to the top for immediate removal.
            self.push_front_unused(block);
        }
    }

    fn on_insert(&mut self, block: BlockId, ctx: &AccessContext) {
        debug_assert!(!self.index.contains_key(&block), "double insert");
        if Self::classify(ctx) {
            self.push_back(Region::Reused, block);
        } else {
            // "insert at the end of the unused data list"; with no unused
            // blocks this lands at the top of the cache, as in Algorithm 1.
            self.push_back(Region::Unused, block);
        }
    }

    fn choose_victim(&mut self, _now: SimTime) -> Option<BlockId> {
        // Victim = top of the cache: the unused region drains first.
        self.unused.front().or_else(|| self.reused.front())
    }

    fn victim_candidates(&mut self, _now: SimTime, k: usize) -> Vec<BlockId> {
        self.unused.iter().chain(self.reused.iter()).take(k).collect()
    }

    fn on_evict(&mut self, block: BlockId) {
        self.detach(block);
    }

    fn len(&self) -> usize {
        self.index.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(t: u64, reuse: bool) -> AccessContext {
        AccessContext::simple(SimTime(t), 1).with_prediction(reuse)
    }

    #[test]
    fn unused_class_evicted_before_reused() {
        let mut p = HSvmLru::new();
        p.on_insert(BlockId(1), &ctx(1, true));
        p.on_insert(BlockId(2), &ctx(2, false));
        p.on_insert(BlockId(3), &ctx(3, true));
        // 2 is the only unused block -> first victim despite being newer.
        assert_eq!(p.choose_victim(SimTime(4)), Some(BlockId(2)));
        p.on_evict(BlockId(2));
        // then the LRU of the reused region.
        assert_eq!(p.choose_victim(SimTime(5)), Some(BlockId(1)));
    }

    #[test]
    fn hit_with_class0_moves_to_top() {
        let mut p = HSvmLru::new();
        p.on_insert(BlockId(1), &ctx(1, false));
        p.on_insert(BlockId(2), &ctx(2, false));
        // Hit on 2 reclassified unused: moves to the very top, ahead of 1.
        p.on_hit(BlockId(2), &ctx(3, false));
        assert_eq!(p.choose_victim(SimTime(4)), Some(BlockId(2)));
    }

    #[test]
    fn insert_class0_goes_to_end_of_unused_list() {
        let mut p = HSvmLru::new();
        p.on_insert(BlockId(1), &ctx(1, false));
        p.on_insert(BlockId(2), &ctx(2, false));
        p.on_insert(BlockId(3), &ctx(3, true));
        // Eviction order: old unused (1), newer unused (2), then reused (3).
        assert_eq!(
            p.eviction_order(),
            vec![BlockId(1), BlockId(2), BlockId(3)]
        );
    }

    #[test]
    fn all_reused_degenerates_to_lru() {
        let mut p = HSvmLru::new();
        for i in 0..4 {
            p.on_insert(BlockId(i), &ctx(i, true));
        }
        p.on_hit(BlockId(0), &ctx(10, true));
        assert_eq!(
            p.eviction_order(),
            vec![BlockId(1), BlockId(2), BlockId(3), BlockId(0)]
        );
        assert_eq!(p.n_unused(), 0);
    }

    #[test]
    fn missing_prediction_behaves_like_lru() {
        let mut p = HSvmLru::new();
        let plain = |t: u64| AccessContext::simple(SimTime(t), 1);
        p.on_insert(BlockId(1), &plain(1));
        p.on_insert(BlockId(2), &plain(2));
        p.on_hit(BlockId(1), &plain(3));
        assert_eq!(p.choose_victim(SimTime(4)), Some(BlockId(2)));
        assert_eq!(p.n_reused(), 2);
    }

    #[test]
    fn region_flips_reuse_freed_slots() {
        // A block bouncing between regions must not grow either slab.
        let mut p = HSvmLru::new();
        p.on_insert(BlockId(1), &ctx(0, true));
        for t in 1..2_000u64 {
            p.on_hit(BlockId(1), &ctx(t, t % 2 == 0));
        }
        assert_eq!(p.len(), 1);
        assert_eq!(p.unused.slots(), 1);
        assert_eq!(p.reused.slots(), 1);
    }

    #[test]
    fn paper_fig2_worked_example() {
        // The Fig 2 request sequence with classes:
        // (DB1,0)(DB2,1)(DB3,1)(DB4,1)(DB5,0)(DB6,0)(DB7,0)(DB2,0)(DB8,1)(DB3,1)
        // Capacity: 5 equal blocks. LRU evicts DB2 and DB3 before their
        // reuse; H-SVM-LRU must keep both cached (the paper's point).
        use super::super::{lru::Lru, BlockCache};
        let seq: [(u64, bool); 10] = [
            (1, false),
            (2, true),
            (3, true),
            (4, true),
            (5, false),
            (6, false),
            (7, false),
            (2, false),
            (8, true),
            (3, true),
        ];
        let run = |policy: Box<dyn CachePolicy>| -> (u32, Vec<bool>) {
            let mut cache = BlockCache::new(policy, 5);
            let mut hits = 0;
            let mut hit_seq = Vec::new();
            for (t, (b, class)) in seq.iter().enumerate() {
                let c = ctx(t as u64, *class);
                let o = cache.access_or_insert(BlockId(*b), &c);
                hits += o.hit as u32;
                hit_seq.push(o.hit);
            }
            (hits, hit_seq)
        };
        let (lru_hits, _) = run(Box::new(Lru::new()));
        let (svm_hits, svm_seq) = run(Box::new(HSvmLru::new()));
        // LRU: DB2 and DB3 already evicted when re-requested -> both miss.
        assert_eq!(lru_hits, 0);
        // H-SVM-LRU: the reused-class blocks survive -> both re-requests hit.
        assert_eq!(svm_hits, 2);
        assert!(svm_seq[7], "DB2 re-request must hit");
        assert!(svm_seq[9], "DB3 re-request must hit");
    }
}
