//! LFU — least frequently used, ties broken by least-recent access.
//!
//! O(1) frequency buckets on [`OrderList`]: the previous implementation
//! re-keyed a `BTreeMap<(freq, seq), BlockId>` on *every* access (node
//! allocation + O(log n) pointer chasing per touch — the last per-access
//! BTreeMap in the crate after PR 4 ported the list-ordered policies).
//! Here the classic constant-time LFU shape replaces it:
//!
//! * `bucket_order` — an `OrderList` of bucket slab indices in ascending
//!   frequency order (front = lowest live frequency);
//! * each bucket holds its members in their own `OrderList`, least
//!   recently bumped at the front (the recency tie-break);
//! * a block bump moves it from bucket `f` to the adjacent `f + 1`
//!   bucket — found (or spliced in) via [`OrderList::insert_after`] in
//!   O(1), never searched;
//! * the victim is the front member of the front bucket: O(1) peek.
//!
//! Emptied buckets are unlinked and their slots (including their member
//! list's slab) recycled, so steady-state churn allocates nothing once
//! the working set's bucket population has been seen. Access-for-access
//! parity with the original BTreeMap implementation is differential-
//! tested in rust/tests/property_orderlist.rs (`RefLfu`).

use crate::hdfs::BlockId;
use crate::sim::SimTime;
use crate::util::fasthash::IdHashMap;

use super::order_list::{OrderHandle, OrderList};
use super::{AccessContext, CachePolicy};

/// One live frequency bucket.
#[derive(Debug)]
struct Bucket {
    freq: u64,
    /// Members at this frequency, least recently bumped at the front.
    members: OrderList<BlockId>,
    /// This bucket's handle in `bucket_order`.
    handle: OrderHandle,
}

/// Where one block lives: its bucket slab index + its member handle.
#[derive(Debug, Clone, Copy)]
struct BlockSlot {
    bucket: u32,
    member: OrderHandle,
}

/// Least-frequently-used replacement with O(1) frequency buckets.
#[derive(Debug, Default)]
pub struct Lfu {
    /// Live bucket slab indices in ascending frequency order.
    bucket_order: OrderList<u32>,
    /// Bucket slab; freed slots on `free_buckets` (their member lists keep
    /// their allocation for reuse).
    buckets: Vec<Bucket>,
    free_buckets: Vec<u32>,
    index: IdHashMap<BlockId, BlockSlot>,
}

impl Lfu {
    /// Create an empty LFU policy.
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate (or reuse) a bucket slot for `freq`, already linked into
    /// `bucket_order` at `handle`.
    fn alloc_bucket(&mut self, freq: u64, handle: OrderHandle) -> u32 {
        if let Some(idx) = self.free_buckets.pop() {
            let b = &mut self.buckets[idx as usize];
            debug_assert!(b.members.is_empty(), "freed bucket kept members");
            b.freq = freq;
            b.handle = handle;
            idx
        } else {
            self.buckets.push(Bucket { freq, members: OrderList::new(), handle });
            (self.buckets.len() - 1) as u32
        }
    }

    /// Unlink an emptied bucket and recycle its slot.
    fn release_if_empty(&mut self, bucket: u32) {
        if self.buckets[bucket as usize].members.is_empty() {
            let handle = self.buckets[bucket as usize].handle;
            self.bucket_order.unlink(handle);
            self.free_buckets.push(bucket);
        }
    }

    /// Move `block` into the bucket of `freq`, positioned right after
    /// `prev` in the frequency chain (`None` = new lowest frequency, goes
    /// to the front). The target bucket is created if absent. O(1).
    fn enter_bucket(&mut self, block: BlockId, freq: u64, prev: Option<OrderHandle>) {
        // The candidate neighbour: the bucket following `prev` (or the
        // current front when inserting at the low end).
        let next = match prev {
            Some(p) => self.bucket_order.next_of(p),
            None => self.bucket_order.front_handle(),
        };
        let target = match next {
            Some(h) => {
                let idx = self.bucket_order.get(h);
                if self.buckets[idx as usize].freq == freq {
                    Some(idx)
                } else {
                    debug_assert!(
                        self.buckets[idx as usize].freq > freq,
                        "bucket chain out of order"
                    );
                    None
                }
            }
            None => None,
        };
        let bucket = match target {
            Some(idx) => idx,
            None => {
                // Splice a fresh bucket between `prev` and `next`. Two
                // steps because the bucket slab index must be known to be
                // stored as the order item: reserve the slot first.
                let handle = match prev {
                    Some(p) => self.bucket_order.insert_after(p, u32::MAX),
                    None => self.bucket_order.push_front(u32::MAX),
                };
                let idx = self.alloc_bucket(freq, handle);
                self.bucket_order.set(handle, idx);
                idx
            }
        };
        let member = self.buckets[bucket as usize].members.push_back(block);
        self.index.insert(block, BlockSlot { bucket, member });
    }

    /// Count one access: move the block from frequency `f` to `f + 1`
    /// (inserting at frequency 1 when untracked). O(1).
    fn bump(&mut self, block: BlockId) {
        match self.index.get(&block).copied() {
            Some(slot) => {
                let freq = self.buckets[slot.bucket as usize].freq;
                let prev = self.buckets[slot.bucket as usize].handle;
                self.buckets[slot.bucket as usize].members.unlink(slot.member);
                self.enter_bucket(block, freq + 1, Some(prev));
                self.release_if_empty(slot.bucket);
            }
            None => self.enter_bucket(block, 1, None),
        }
    }

    /// Access count the policy holds for `block` (0 when untracked).
    pub fn frequency(&self, block: BlockId) -> u64 {
        self.index
            .get(&block)
            .map(|slot| self.buckets[slot.bucket as usize].freq)
            .unwrap_or(0)
    }
}

impl CachePolicy for Lfu {
    fn name(&self) -> &'static str {
        "lfu"
    }

    fn on_hit(&mut self, block: BlockId, _ctx: &AccessContext) {
        debug_assert!(self.index.contains_key(&block));
        self.bump(block);
    }

    fn on_insert(&mut self, block: BlockId, _ctx: &AccessContext) {
        debug_assert!(!self.index.contains_key(&block), "double insert");
        self.bump(block);
    }

    fn choose_victim(&mut self, _now: SimTime) -> Option<BlockId> {
        let front = self.bucket_order.front()?;
        self.buckets[front as usize].members.front()
    }

    fn victim_candidates(&mut self, _now: SimTime, k: usize) -> Vec<BlockId> {
        // Ascending frequency, then least recently bumped within a bucket —
        // the exact order repeated `choose_victim`/`on_evict` would produce.
        let mut out = Vec::with_capacity(k.min(self.index.len()));
        for idx in self.bucket_order.iter() {
            for b in self.buckets[idx as usize].members.iter() {
                if out.len() == k {
                    return out;
                }
                out.push(b);
            }
        }
        out
    }

    fn on_evict(&mut self, block: BlockId) {
        if let Some(slot) = self.index.remove(&block) {
            self.buckets[slot.bucket as usize].members.unlink(slot.member);
            self.release_if_empty(slot.bucket);
        }
    }

    fn len(&self) -> usize {
        self.index.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c() -> AccessContext {
        AccessContext::simple(SimTime(0), 1)
    }

    #[test]
    fn evicts_least_frequent() {
        let mut p = Lfu::new();
        for i in 0..3 {
            p.on_insert(BlockId(i), &c());
        }
        p.on_hit(BlockId(0), &c());
        p.on_hit(BlockId(0), &c());
        p.on_hit(BlockId(2), &c());
        assert_eq!(p.frequency(BlockId(0)), 3);
        assert_eq!(p.choose_victim(SimTime(1)), Some(BlockId(1)));
    }

    #[test]
    fn tie_broken_by_recency() {
        let mut p = Lfu::new();
        p.on_insert(BlockId(1), &c());
        p.on_insert(BlockId(2), &c());
        // Both freq 1; block 1 was touched longer ago.
        assert_eq!(p.choose_victim(SimTime(1)), Some(BlockId(1)));
        p.on_hit(BlockId(1), &c());
        p.on_hit(BlockId(2), &c());
        // Now both freq 2, block 1 again older.
        assert_eq!(p.choose_victim(SimTime(2)), Some(BlockId(1)));
    }

    #[test]
    fn evict_then_reinsert_resets_frequency() {
        let mut p = Lfu::new();
        p.on_insert(BlockId(1), &c());
        p.on_hit(BlockId(1), &c());
        p.on_evict(BlockId(1));
        assert_eq!(p.len(), 0);
        p.on_insert(BlockId(1), &c());
        assert_eq!(p.frequency(BlockId(1)), 1);
    }

    #[test]
    fn buckets_merge_and_recycle() {
        let mut p = Lfu::new();
        // Two blocks climbing in lockstep share one bucket per level.
        p.on_insert(BlockId(1), &c());
        p.on_insert(BlockId(2), &c());
        for _ in 0..5 {
            p.on_hit(BlockId(1), &c());
            p.on_hit(BlockId(2), &c());
        }
        assert_eq!(p.frequency(BlockId(1)), 6);
        assert_eq!(p.frequency(BlockId(2)), 6);
        assert_eq!(p.bucket_order.len(), 1, "lockstep blocks share one bucket");
        // Heavy churn at constant population must not grow the bucket slab.
        for i in 10..1_000u64 {
            p.on_insert(BlockId(i), &c());
            let victim = p.choose_victim(SimTime(i)).unwrap();
            assert_eq!(victim, BlockId(i), "fresh freq-1 block is the victim");
            p.on_evict(victim);
        }
        assert!(
            p.buckets.len() <= 4,
            "bucket slab grew to {} under churn",
            p.buckets.len()
        );
        assert_eq!(p.len(), 2);
    }

    /// The frequency chain stays strictly ascending front-to-back across
    /// interleaved bumps and evictions (the structural invariant every
    /// O(1) step relies on).
    #[test]
    fn bucket_chain_stays_sorted() {
        let mut p = Lfu::new();
        let mut state = 0x2545F4914F6CDD1Du64;
        let mut rng = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for t in 0..2_000u64 {
            let block = BlockId(rng() % 24);
            if p.index.contains_key(&block) {
                if rng() % 8 == 0 {
                    p.on_evict(block);
                } else {
                    p.on_hit(block, &c());
                }
            } else {
                p.on_insert(block, &c());
            }
            let freqs: Vec<u64> = p
                .bucket_order
                .iter()
                .map(|idx| p.buckets[idx as usize].freq)
                .collect();
            assert!(
                freqs.windows(2).all(|w| w[0] < w[1]),
                "chain out of order at t={t}: {freqs:?}"
            );
            let members: usize = p
                .bucket_order
                .iter()
                .map(|idx| p.buckets[idx as usize].members.len())
                .sum();
            assert_eq!(members, p.len(), "member count drift at t={t}");
        }
    }
}
