//! LFU — least frequently used, ties broken by least-recent access.

use std::collections::{BTreeMap, HashMap};

use crate::hdfs::BlockId;
use crate::sim::SimTime;

use super::{AccessContext, CachePolicy};

#[derive(Debug, Default)]
pub struct Lfu {
    /// (frequency, last-access seq) -> block; victim = first entry.
    order: BTreeMap<(u64, i64), BlockId>,
    index: HashMap<BlockId, (u64, i64)>,
    seq: i64,
}

impl Lfu {
    pub fn new() -> Self {
        Self::default()
    }

    fn bump(&mut self, block: BlockId, add: u64) {
        let (freq, old_seq) = self.index.remove(&block).unwrap_or((0, 0));
        if freq > 0 || old_seq != 0 {
            self.order.remove(&(freq, old_seq));
        }
        let seq = self.seq;
        self.seq += 1;
        let entry = (freq + add, seq);
        self.order.insert(entry, block);
        self.index.insert(block, entry);
    }

    pub fn frequency(&self, block: BlockId) -> u64 {
        self.index.get(&block).map(|(f, _)| *f).unwrap_or(0)
    }
}

impl CachePolicy for Lfu {
    fn name(&self) -> &'static str {
        "lfu"
    }

    fn on_hit(&mut self, block: BlockId, _ctx: &AccessContext) {
        debug_assert!(self.index.contains_key(&block));
        self.bump(block, 1);
    }

    fn on_insert(&mut self, block: BlockId, _ctx: &AccessContext) {
        debug_assert!(!self.index.contains_key(&block), "double insert");
        self.bump(block, 1);
    }

    fn choose_victim(&mut self, _now: SimTime) -> Option<BlockId> {
        self.order.values().next().copied()
    }

    fn on_evict(&mut self, block: BlockId) {
        if let Some(entry) = self.index.remove(&block) {
            self.order.remove(&entry);
        }
    }

    fn len(&self) -> usize {
        self.index.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c() -> AccessContext {
        AccessContext::simple(SimTime(0), 1)
    }

    #[test]
    fn evicts_least_frequent() {
        let mut p = Lfu::new();
        for i in 0..3 {
            p.on_insert(BlockId(i), &c());
        }
        p.on_hit(BlockId(0), &c());
        p.on_hit(BlockId(0), &c());
        p.on_hit(BlockId(2), &c());
        assert_eq!(p.frequency(BlockId(0)), 3);
        assert_eq!(p.choose_victim(SimTime(1)), Some(BlockId(1)));
    }

    #[test]
    fn tie_broken_by_recency() {
        let mut p = Lfu::new();
        p.on_insert(BlockId(1), &c());
        p.on_insert(BlockId(2), &c());
        // Both freq 1; block 1 was touched longer ago.
        assert_eq!(p.choose_victim(SimTime(1)), Some(BlockId(1)));
        p.on_hit(BlockId(1), &c());
        p.on_hit(BlockId(2), &c());
        // Now both freq 2, block 1 again older.
        assert_eq!(p.choose_victim(SimTime(2)), Some(BlockId(1)));
    }

    #[test]
    fn evict_then_reinsert_resets_frequency() {
        let mut p = Lfu::new();
        p.on_insert(BlockId(1), &c());
        p.on_hit(BlockId(1), &c());
        p.on_evict(BlockId(1));
        assert_eq!(p.len(), 0);
        p.on_insert(BlockId(1), &c());
        assert_eq!(p.frequency(BlockId(1)), 1);
    }
}
