//! Plain-text table rendering for experiment reports (paper-style rows).

/// A simple column-aligned text table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn add_row<S: Into<String>>(&mut self, row: Vec<S>) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.header.len(), "row arity != header arity");
        self.rows.push(row);
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with column alignment, a separator under the header.
    pub fn render(&self) -> String {
        let n = self.header.len();
        let mut widths = vec![0usize; n];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.len();
        }
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, cell) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", cell, width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (n - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (for downstream plotting).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self.header.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals — experiments use this everywhere so
/// table diffs are stable across runs.
pub fn fmt_f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Format a ratio as a percentage string ("63.64%").
pub fn fmt_pct(v: f64) -> String {
    format!("{:.2}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["cache", "hit ratio"]);
        t.add_row(vec!["6", "0.25"]);
        t.add_row(vec!["12", "0.5"]);
        let s = t.render();
        assert!(s.contains("cache  hit ratio"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.add_row(vec!["only-one"]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(vec!["name", "v"]);
        t.add_row(vec!["a,b", "1"]);
        assert!(t.to_csv().contains("\"a,b\",1"));
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_f(1.23456, 2), "1.23");
        assert_eq!(fmt_pct(0.6364), "63.64%");
    }
}
