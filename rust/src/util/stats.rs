//! Small statistics helpers shared by metrics, benches and experiments.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator); 0.0 for n < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile via linear interpolation; p in [0, 100]. Clones and sorts
/// per call — for repeated queries over the same data build a [`Summary`]
/// once instead.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    Summary::of(xs).percentile(p)
}

/// Sort-once summary of a sample: build it one time, then read min / max /
/// mean / std-dev / any number of percentiles without re-sorting. Replaces
/// the clone-and-sort-per-call pattern `percentile` has on repeated
/// queries (the bench harness asks for min, p50 and p95 of every sample
/// set — three sorts before this type existed).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    sorted: Vec<f64>,
}

impl Summary {
    /// Summarize `xs` (one clone + one sort).
    pub fn of(xs: &[f64]) -> Self {
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary { sorted }
    }

    /// Sample count.
    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    /// Whether the sample is empty.
    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// Smallest sample (0.0 when empty).
    pub fn min(&self) -> f64 {
        self.sorted.first().copied().unwrap_or(0.0)
    }

    /// Largest sample (0.0 when empty).
    pub fn max(&self) -> f64 {
        self.sorted.last().copied().unwrap_or(0.0)
    }

    /// Arithmetic mean (0.0 when empty).
    pub fn mean(&self) -> f64 {
        mean(&self.sorted)
    }

    /// Sample standard deviation (0.0 for n < 2).
    pub fn std_dev(&self) -> f64 {
        std_dev(&self.sorted)
    }

    /// Percentile via linear interpolation on the pre-sorted data; `p` in
    /// [0, 100]. No allocation, no re-sort.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        let rank = (p / 100.0) * (self.sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.sorted[lo]
        } else {
            let frac = rank - lo as f64;
            self.sorted[lo] * (1.0 - frac) + self.sorted[hi] * frac
        }
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Geometric mean (used for normalized-run-time aggregation); 0 if any x <= 0.
pub fn geo_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() || xs.iter().any(|&x| x <= 0.0) {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Online mean/variance accumulator (Welford). Used by the bench harness.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935299395).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(geo_mean(&[]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geo_mean_matches_hand_calc() {
        let xs = [1.0, 4.0, 16.0];
        assert!((geo_mean(&xs) - 4.0).abs() < 1e-12);
        assert_eq!(geo_mean(&[1.0, -1.0]), 0.0);
    }

    #[test]
    fn summary_matches_free_functions() {
        let xs = [5.0, 1.0, 4.0, 2.0, 3.0];
        let s = Summary::of(&xs);
        assert_eq!(s.len(), 5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.mean() - mean(&xs)).abs() < 1e-12);
        assert!((s.std_dev() - std_dev(&xs)).abs() < 1e-12);
        for p in [0.0, 25.0, 50.0, 95.0, 100.0] {
            assert_eq!(s.percentile(p), percentile(&xs, p), "p{p}");
        }
        let empty = Summary::of(&[]);
        assert!(empty.is_empty());
        assert_eq!(empty.min(), 0.0);
        assert_eq!(empty.percentile(50.0), 0.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std_dev() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), 8);
    }
}
