//! Fast hashing for small integer keys (BlockId etc.).
//!
//! The std `HashMap` defaults to SipHash-1-3, which showed up in the
//! request-path profile (see EXPERIMENTS.md §Perf). Block ids are
//! sequential u64s handed out by the NameNode, so a multiplicative mix of
//! the raw id is collision-safe and ~5× cheaper. No `fxhash`/`ahash`
//! offline — this is the classic Fibonacci-hash finisher.

use std::hash::{BuildHasherDefault, Hasher};

/// Hasher for keys that write exactly one `u64`/`u32` (ids).
#[derive(Debug, Default, Clone)]
pub struct IdHasher {
    state: u64,
}

impl Hasher for IdHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // Fallback for composite keys: FNV-1a over the bytes.
        let mut h = 0xcbf2_9ce4_8422_2325u64 ^ self.state;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        self.state = h;
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        // Fibonacci multiplicative mix: spreads sequential ids across the
        // whole table.
        self.state = i.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.write_u64(i as u64);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.write_u64(i as u64);
    }
}

pub type BuildIdHasher = BuildHasherDefault<IdHasher>;

/// `HashMap` keyed by small integer ids.
pub type IdHashMap<K, V> = std::collections::HashMap<K, V, BuildIdHasher>;

/// `HashSet` of small integer ids.
pub type IdHashSet<K> = std::collections::HashSet<K, BuildIdHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_roundtrip() {
        let mut m: IdHashMap<u64, u64> = IdHashMap::default();
        for i in 0..10_000u64 {
            m.insert(i, i * 2);
        }
        for i in 0..10_000u64 {
            assert_eq!(m.get(&i), Some(&(i * 2)));
        }
        assert_eq!(m.len(), 10_000);
    }

    #[test]
    fn sequential_ids_spread() {
        // Adjacent ids must land in different buckets (mix works).
        let mut h1 = IdHasher::default();
        h1.write_u64(1);
        let mut h2 = IdHasher::default();
        h2.write_u64(2);
        assert_ne!(h1.finish() >> 56, h2.finish() >> 56, "high bits should differ");
    }

    #[test]
    fn composite_keys_fall_back_to_fnv() {
        let mut m: IdHashMap<(u64, u64), u32> = IdHashMap::default();
        m.insert((1, 2), 3);
        m.insert((2, 1), 4);
        assert_eq!(m[&(1, 2)], 3);
        assert_eq!(m[&(2, 1)], 4);
    }
}
