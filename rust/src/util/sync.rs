//! Switchable concurrency primitives: `std::sync` in normal builds, the
//! [loom](https://docs.rs/loom) model-checker's equivalents under
//! `--cfg loom`.
//!
//! Every module that participates in a lock-free protocol (the seqlock
//! stats block, the histogram slots, `SnapshotCell`, the batcher/sample
//! probes) imports its atomics, mutexes and spin hints from here instead
//! of `std` directly. Normal builds see exactly the `std` types (the
//! re-exports are zero-cost), while `RUSTFLAGS="--cfg loom"` swaps in
//! loom's instrumented versions so `rust/tests/loom_protocols.rs` can
//! exhaustively enumerate interleavings of those protocols. See
//! docs/CONCURRENCY.md for the protocol table and what the loom suite
//! proves.
//!
//! The repo-invariant lint (`rust/tests/lint_invariants.rs`) enforces the
//! discipline: importing `std::sync::atomic` anywhere outside this facade
//! (and the vetted exception list it documents) fails the test suite.
//!
//! `std::sync::Arc` is deliberately **not** switched: loom's `Arc` models
//! reference-count ordering bugs, but swapping it would force every
//! unported consumer of `Arc<ClassifierSnapshot>` etc. onto the facade
//! type. Plain `Arc` works inside loom models (it is refcount-only; the
//! protocols we check do not rely on `Arc`'s release/acquire edge).

/// Atomic integer/bool types, memory orderings and fences.
///
/// Mirrors the `std::sync::atomic` (resp. `loom::sync::atomic`) surface
/// that the crate actually uses; extend the re-export list as protocols
/// grow rather than importing from `std` directly.
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{fence, AtomicBool, AtomicU64, Ordering};

    #[cfg(loom)]
    pub use loom::sync::atomic::{fence, AtomicBool, AtomicU64, Ordering};
}

/// Spin-loop hint: `std::hint::spin_loop`, or loom's yield point.
///
/// Under loom a busy-wait **must** call [`hint::spin_loop`](spin_loop) so
/// the scheduler can switch to the writer thread; a raw loop would spin
/// forever inside the model.
pub mod hint {
    #[cfg(not(loom))]
    pub use std::hint::spin_loop;

    #[cfg(loom)]
    pub use loom::hint::spin_loop;
}

#[cfg(not(loom))]
pub use std::sync::{Mutex, MutexGuard};

#[cfg(loom)]
pub use loom::sync::{Mutex, MutexGuard};

#[cfg(all(test, not(loom)))]
mod tests {
    #[test]
    fn facade_reexports_are_std_types_in_normal_builds() {
        // A facade `AtomicU64` must be the `std` type (same canonical
        // path), so unported code interoperates with ported code freely.
        let a: super::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(7);
        assert_eq!(a.load(super::atomic::Ordering::Relaxed), 7);
        let m: super::Mutex<u32> = std::sync::Mutex::new(3);
        assert_eq!(*m.lock().unwrap(), 3);
        super::hint::spin_loop();
    }
}
