//! Byte-size parsing/formatting ("64MB", "1.5GB") for configs and reports.

pub const KB: u64 = 1024;
pub const MB: u64 = 1024 * KB;
pub const GB: u64 = 1024 * MB;
pub const TB: u64 = 1024 * GB;

/// Parse a human byte size: optional fraction + unit (B/KB/MB/GB/TB, case
/// insensitive, optional 'iB'). Bare numbers are bytes.
pub fn parse_bytes(s: &str) -> Option<u64> {
    let s = s.trim();
    let split = s
        .find(|c: char| !(c.is_ascii_digit() || c == '.'))
        .unwrap_or(s.len());
    let (num, unit) = s.split_at(split);
    let value: f64 = num.parse().ok()?;
    if !value.is_finite() || value < 0.0 {
        return None;
    }
    let unit = unit.trim().to_ascii_uppercase();
    let mult = match unit.as_str() {
        "" | "B" => 1,
        "K" | "KB" | "KIB" => KB,
        "M" | "MB" | "MIB" => MB,
        "G" | "GB" | "GIB" => GB,
        "T" | "TB" | "TIB" => TB,
        _ => return None,
    };
    Some((value * mult as f64).round() as u64)
}

/// Format bytes with a binary unit and 2 significant decimals.
pub fn format_bytes(n: u64) -> String {
    let (value, unit) = if n >= TB {
        (n as f64 / TB as f64, "TB")
    } else if n >= GB {
        (n as f64 / GB as f64, "GB")
    } else if n >= MB {
        (n as f64 / MB as f64, "MB")
    } else if n >= KB {
        (n as f64 / KB as f64, "KB")
    } else {
        (n as f64, "B")
    };
    if (value - value.round()).abs() < 1e-9 {
        format!("{}{}", value.round() as u64, unit)
    } else {
        format!("{value:.2}{unit}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_units() {
        assert_eq!(parse_bytes("64MB"), Some(64 * MB));
        assert_eq!(parse_bytes("128 mb"), Some(128 * MB));
        assert_eq!(parse_bytes("1.5GB"), Some((1.5 * GB as f64) as u64));
        assert_eq!(parse_bytes("2GiB"), Some(2 * GB));
        assert_eq!(parse_bytes("512"), Some(512));
        assert_eq!(parse_bytes("0B"), Some(0));
    }

    #[test]
    fn rejects_garbage() {
        assert_eq!(parse_bytes("MB"), None);
        assert_eq!(parse_bytes("12XB"), None);
        assert_eq!(parse_bytes("-5MB"), None);
        assert_eq!(parse_bytes(""), None);
    }

    #[test]
    fn formats_round_trip() {
        assert_eq!(format_bytes(64 * MB), "64MB");
        assert_eq!(format_bytes(3 * GB / 2), "1.50GB");
        assert_eq!(format_bytes(12), "12B");
    }
}
