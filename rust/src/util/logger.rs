//! Minimal `log` facade backend (the offline cache has `log` but no
//! env_logger/tracing). Level comes from `RUST_LOG` (error|warn|info|debug|
//! trace) or the CLI `--log-level` flag.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};

use log::{Level, LevelFilter, Log, Metadata, Record};

static LOGGER: SimpleLogger = SimpleLogger;
static MAX_LEVEL: AtomicU8 = AtomicU8::new(3); // Info

struct SimpleLogger;

fn level_to_u8(level: Level) -> u8 {
    match level {
        Level::Error => 1,
        Level::Warn => 2,
        Level::Info => 3,
        Level::Debug => 4,
        Level::Trace => 5,
    }
}

impl Log for SimpleLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        level_to_u8(metadata.level()) <= MAX_LEVEL.load(Ordering::Relaxed)
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let mut stderr = std::io::stderr().lock();
        let _ = writeln!(
            stderr,
            "[{:5}] {}: {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Parse a level name; `None` for unknown names.
pub fn parse_level(s: &str) -> Option<LevelFilter> {
    match s.to_ascii_lowercase().as_str() {
        "off" => Some(LevelFilter::Off),
        "error" => Some(LevelFilter::Error),
        "warn" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

/// Install the logger (idempotent) and set the level.
pub fn init(level: LevelFilter) {
    let as_u8 = match level {
        LevelFilter::Off => 0,
        LevelFilter::Error => 1,
        LevelFilter::Warn => 2,
        LevelFilter::Info => 3,
        LevelFilter::Debug => 4,
        LevelFilter::Trace => 5,
    };
    MAX_LEVEL.store(as_u8, Ordering::Relaxed);
    // set_logger fails when called twice; that's fine.
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

/// Init from RUST_LOG if present, else Info.
pub fn init_from_env() {
    let level = std::env::var("RUST_LOG")
        .ok()
        .and_then(|v| parse_level(&v))
        .unwrap_or(LevelFilter::Info);
    init(level);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_levels() {
        assert_eq!(parse_level("info"), Some(LevelFilter::Info));
        assert_eq!(parse_level("TRACE"), Some(LevelFilter::Trace));
        assert_eq!(parse_level("bogus"), None);
    }

    #[test]
    fn init_is_idempotent() {
        init(LevelFilter::Warn);
        init(LevelFilter::Info);
        log::info!("logger smoke test");
    }
}
