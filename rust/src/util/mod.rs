//! Shared utilities: deterministic RNG, statistics, logging, byte-size
//! parsing, and plain-text table rendering.
//!
//! The offline crate registry only ships `xla`/`anyhow`/`thiserror`/`log`
//! and friends, so the pieces a production service would usually pull from
//! `rand`, `env_logger`, `humansize` or `comfy-table` live here instead.

pub mod bytes;
pub mod fasthash;
pub mod logger;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod table;
