//! Deterministic PRNG + distributions for the simulator.
//!
//! The offline crate cache ships `rand_core` but not `rand`, so the generator
//! and the distributions the workloads need (uniform, normal, zipf,
//! exponential) are implemented here. PCG64 (XSL-RR 128/64) — small, fast,
//! and statistically solid; every simulation component owns a seeded stream
//! so experiment runs are exactly reproducible.

/// PCG XSL-RR 128/64 generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Distinct stream ids
    /// yield statistically independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let initseq = ((stream as u128) << 64) | 0xda3e_39cb_94b9_5bdb;
        let mut rng = Pcg64 { state: 0, inc: (initseq << 1) | 1 };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Derive a child generator (used to give each component its own stream).
    pub fn fork(&mut self, salt: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), salt)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self, mean: f64, std_dev: f64) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std_dev * z
    }

    /// Exponential with rate `lambda`.
    pub fn gen_exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.next_f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Bernoulli with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }
}

/// Zipf(s) sampler over {0, .., n-1} by inverse-CDF over precomputed weights.
///
/// Block-popularity skew in the request traces comes from here: MapReduce
/// inputs shared between applications follow a heavy-tailed reuse pattern.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "Zipf over empty support");
        let mut cdf = Vec::with_capacity(n);
        let mut total = 0.0;
        for k in 1..=n {
            total += 1.0 / (k as f64).powf(s);
            cdf.push(total);
        }
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        // binary search for the first cdf entry >= u
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    pub fn support(&self) -> usize {
        self.cdf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_range_bounds() {
        let mut rng = Pcg64::new(7, 0);
        for _ in 0..10_000 {
            let v = rng.gen_range(13);
            assert!(v < 13);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::new(9, 0);
        for _ in 0..10_000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(3, 0);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gen_normal(5.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut rng = Pcg64::new(11, 0);
        let z = Zipf::new(100, 1.1);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[50] * 5, "head {} tail {}", counts[0], counts[50]);
        assert_eq!(counts.iter().sum::<usize>(), 20_000);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(5, 0);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn exp_mean() {
        let mut rng = Pcg64::new(17, 0);
        let n = 50_000;
        let m = (0..n).map(|_| rng.gen_exp(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "mean={m}");
    }
}
