//! Command-line interface (no `clap` offline — a small hand-rolled parser).
//!
//! ```text
//! repro <subcommand> [flags]
//!
//! Subcommands:
//!   quickstart          tiny end-to-end demo
//!   fig3                hit ratio vs cache size (Fig 3)
//!   table7              improvement ratios (Table 7)
//!   fig4                exec time vs input size (Fig 4)
//!   fig5                workload normalized run times (Fig 5)
//!   fig6                per-app normalized run times (Fig 6)
//!   table5 [--cv]       kernel-function comparison (Table 5)
//!   policies            all-policy ablation on the Fig 3 trace
//!   all                 run every experiment in sequence
//!
//! Common flags:
//!   --svm-backend hlo|rust     classifier backend (default hlo)
//!   --artifacts DIR            AOT artifacts directory (default artifacts)
//!   --kernel linear|rbf|sigmoid（default rbf)
//!   --seed N                   simulation seed
//!   --scale F                  workload scale for fig5/fig6 (default 0.05)
//!   --csv                      emit CSV instead of aligned tables
//!   --config FILE              TOML config file
//!   --log-level LEVEL          off|error|warn|info|debug|trace
//! ```

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::config::SvmConfig;

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Cli {
    pub command: String,
    /// One positional operand after the command (only `report` takes one:
    /// the metrics JSONL path).
    pub operand: Option<String>,
    pub flags: HashMap<String, String>,
    pub switches: Vec<String>,
}

impl Cli {
    /// Parse `args` (without argv[0]). Flags take a value (`--seed 7`),
    /// switches don't (`--csv`).
    pub fn parse(args: &[String]) -> Result<Cli> {
        let mut command = String::new();
        let mut operand = None;
        let mut flags = HashMap::new();
        let mut switches = Vec::new();
        let valued = [
            "--svm-backend",
            "--artifacts",
            "--kernel",
            "--seed",
            "--scale",
            "--config",
            "--log-level",
            "--cache-blocks",
            "--workload",
            "--policy",
            "--repetitions",
            "--input-gb",
            "--shards",
            "--admission",
            "--batch-queue",
            "--batch-deadline-ms",
            "--recency-batch",
            "--recency-drain-cadence-ms",
            "--readers",
            "--jobs",
            "--baseline",
            "--current",
            "--tolerance",
            "--metrics-out",
        ];
        // Commands taking one positional operand after the command word.
        let takes_operand = ["report"];
        // Known valueless switches. Anything else starting with `--` is a
        // typo and must exit non-zero — previously it was collected as a
        // never-read switch and the run silently proceeded without it.
        let known_switches = ["--csv", "--cv", "--failures", "--prefetch", "--smoke", "--help"];
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(name) = a.strip_prefix("--") {
                if valued.contains(&a.as_str()) {
                    let v = args
                        .get(i + 1)
                        .with_context(|| format!("flag {a} needs a value"))?;
                    flags.insert(name.to_string(), v.clone());
                    i += 2;
                } else if known_switches.contains(&a.as_str()) {
                    switches.push(name.to_string());
                    i += 1;
                } else {
                    bail!("unknown flag {a:?} (see `repro help`)");
                }
            } else if command.is_empty() {
                command = a.clone();
                i += 1;
            } else if operand.is_none() && takes_operand.contains(&command.as_str()) {
                operand = Some(a.clone());
                i += 1;
            } else {
                bail!("unexpected argument {a:?}");
            }
        }
        if command.is_empty() {
            command = "help".to_string();
        }
        Ok(Cli { command, operand, flags, switches })
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn seed(&self) -> Result<u64> {
        match self.flag("seed") {
            Some(s) => s.parse().context("bad --seed"),
            None => Ok(20230101),
        }
    }

    /// Cache shard count (`--shards`), defaulting to `fallback`. Bounded:
    /// each shard is a policy instance and (during replay) a worker thread.
    pub fn shards(&self, fallback: usize) -> Result<usize> {
        const MAX_SHARDS: usize = 1024;
        match self.flag("shards") {
            Some(s) => {
                let v: usize = s.parse().context("bad --shards")?;
                if !(1..=MAX_SHARDS).contains(&v) {
                    bail!("--shards must be in 1..={MAX_SHARDS}, got {v}");
                }
                Ok(v)
            }
            None => Ok(fallback),
        }
    }

    /// Cold-query queue depth of the per-shard prediction batchers
    /// (`--batch-queue`, default `fallback`). 1 = flush every cold query
    /// synchronously (the legacy behaviour).
    pub fn batch_queue(&self, fallback: usize) -> Result<usize> {
        match self.flag("batch-queue") {
            Some(s) => {
                let v: usize = s.parse().context("bad --batch-queue")?;
                if v == 0 {
                    bail!("--batch-queue must be >= 1");
                }
                Ok(v)
            }
            None => Ok(fallback),
        }
    }

    /// Flush deadline of the cold-query queue in milliseconds
    /// (`--batch-deadline-ms`, default `fallback`).
    pub fn batch_deadline_ms(&self, fallback: u64) -> Result<u64> {
        match self.flag("batch-deadline-ms") {
            Some(s) => s.parse().context("bad --batch-deadline-ms"),
            None => Ok(fallback),
        }
    }

    /// Recency updates buffered per replay worker before a batched drain
    /// under the shard lock (`--recency-batch`, default `fallback`).
    /// 1 = drain every access immediately (the legacy, bit-exact
    /// behaviour).
    pub fn recency_batch(&self, fallback: usize) -> Result<usize> {
        match self.flag("recency-batch") {
            Some(s) => {
                let v: usize = s.parse().context("bad --recency-batch")?;
                if v == 0 {
                    bail!("--recency-batch must be >= 1");
                }
                Ok(v)
            }
            None => Ok(fallback),
        }
    }

    /// Drain cadence of the recency buffers in simulated (request-clock)
    /// milliseconds (`--recency-drain-cadence-ms`, default `fallback`;
    /// 0 = no cadence-triggered drains).
    pub fn recency_drain_cadence_ms(&self, fallback: u64) -> Result<u64> {
        match self.flag("recency-drain-cadence-ms") {
            Some(s) => s.parse().context("bad --recency-drain-cadence-ms"),
            None => Ok(fallback),
        }
    }

    /// Concurrent `stats()` reader threads for the sharded replay
    /// (`--readers`, default `fallback`).
    pub fn readers(&self, fallback: usize) -> Result<usize> {
        const MAX_READERS: usize = 64;
        match self.flag("readers") {
            Some(s) => {
                let v: usize = s.parse().context("bad --readers")?;
                if v > MAX_READERS {
                    bail!("--readers must be <= {MAX_READERS}, got {v}");
                }
                Ok(v)
            }
            None => Ok(fallback),
        }
    }

    /// Concurrent DAG job count (`--jobs`, default `fallback`). Bounded:
    /// every job adds stages to each scheduler wave.
    pub fn jobs(&self, fallback: usize) -> Result<usize> {
        const MAX_JOBS: usize = 256;
        match self.flag("jobs") {
            Some(s) => {
                let v: usize = s.parse().context("bad --jobs")?;
                if !(1..=MAX_JOBS).contains(&v) {
                    bail!("--jobs must be in 1..={MAX_JOBS}, got {v}");
                }
                Ok(v)
            }
            None => Ok(fallback),
        }
    }

    /// The `--policy` flag (defaulting to `fallback`), validated against
    /// the policy registry — a typo'd name exits non-zero up front instead
    /// of silently falling through to a later (or no) failure.
    pub fn policy(&self, fallback: &str) -> Result<String> {
        let name = self.flag("policy").unwrap_or(fallback);
        if crate::cache::registry::make_policy(name).is_none() {
            bail!(
                "unknown policy {name:?}; known policies: {}",
                crate::cache::registry::POLICY_NAMES.join(", ")
            );
        }
        Ok(name.to_string())
    }

    pub fn scale(&self) -> Result<f64> {
        match self.flag("scale") {
            Some(s) => {
                let v: f64 = s.parse().context("bad --scale")?;
                if v <= 0.0 {
                    bail!("--scale must be positive");
                }
                Ok(v)
            }
            None => Ok(crate::experiments::fig5::DEFAULT_SCALE),
        }
    }

    /// Build the SVM config from flags (+ optional config file).
    pub fn svm_config(&self) -> Result<SvmConfig> {
        let mut cfg = SvmConfig::default();
        if let Some(path) = self.flag("config") {
            let (_cluster, svm) = crate::config::load(Some(path))?;
            cfg = svm;
        }
        if let Some(b) = self.flag("svm-backend") {
            cfg.backend = b.to_string();
        }
        if let Some(d) = self.flag("artifacts") {
            cfg.artifacts_dir = d.to_string();
        }
        if let Some(k) = self.flag("kernel") {
            cfg.kernel = k.to_string();
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

pub const HELP: &str = "\
h-svm-lru repro — Hadoop-oriented SVM-LRU cache replacement (cs.DC 2023)

USAGE: repro <subcommand> [flags]

SUBCOMMANDS
  quickstart   tiny end-to-end demo (trace replay, LRU vs H-SVM-LRU)
  fig3         cache hit ratio vs cache size            (paper Fig 3)
  table7       improvement ratio of H-SVM-LRU over LRU  (paper Table 7)
  fig4         WordCount exec time vs input size        (paper Fig 4)
  fig5         normalized run time of workloads W1-W6   (paper Fig 5)
  fig6         per-app normalized run time              (paper Fig 6)
  table5       SVM kernel comparison [--cv for k-fold]  (paper Table 5)
  policies     all-policy ablation over the Fig 3 trace (Table 1 survey)
  simulate     DES cluster simulation: Poisson arrivals, heartbeats,
               [--policy P] [--failures] [--prefetch] [--shards N]
  sharded      shard-parallel trace replay sweep (1..N shards on scoped
               threads) [--policy P] [--shards N] [--cache-blocks N]
               [--readers N  concurrent lock-free stats() readers]
  admission    eviction × admission sweep over the Fig 3 trace and the
               scan-storm pollution adversary [--smoke] [--shards N]
               [--cache-blocks N]
  online       frozen vs. online-learning shard-parallel replay: shard
               workers stream labeled samples to a background trainer
               that publishes classifier snapshots mid-trace
               [--policy P] [--shards N] [--cache-blocks N] [--smoke]
               [--batch-queue N] [--batch-deadline-ms MS]
  dag          multi-stage DAG replay: diamond-DAG jobs through the
               MapReduce scheduler with recompute-cost charging for
               evicted intermediates; sweeps policies x cache sizes x
               job concurrency [--policy P] [--jobs N] [--shards N]
               [--cache-blocks N] [--smoke  assert cost-aware
               H-SVM-LRU beats cost-blind LRU on total job time]
  chaos        fault-injected replay: scripted classifier outage + latency
               spike over the Fig 3 trace (circuit breaker degrades
               H-SVM-LRU to the unclassified cold path and recovers),
               a trainer-crash arm, and a DAG node-death arm
               [--policy P] [--shards N] [--cache-blocks N] [--jobs N]
               [--smoke  assert open -> fallback -> recover and a
               bounded degradation gap vs plain LRU]
  report FILE  render a --metrics-out JSONL file as windowed tables:
               per-window hit ratio, eviction-cause breakdown, occupancy,
               classifier confusion counts, plus scalars and histograms
  bench-gate   compare --current bench JSONs against --baseline records,
               failing on any tracked-metric regression beyond
               --tolerance (default 0.15); the CI regression gate
  all          every experiment in sequence

FLAGS
  --svm-backend hlo|rust   classifier backend (default: hlo; rust = SMO)
  --artifacts DIR          AOT artifact dir (default: artifacts)
  --kernel K               linear|rbf|sigmoid (default: rbf)
  --seed N                 simulation seed
  --scale F                workload scale for fig5/fig6 (default 0.05)
  --cache-blocks N         cache size for `policies`/`sharded` (default 8)
  --shards N               cache shards per node / replay workers
  --admission A            always|tinylfu|ghost|svm admission for `simulate`
  --batch-queue N          cold SVM queries buffered per shard batcher
                           before a forced flush (default 1 = legacy
                           synchronous flush; `simulate`/`online`)
  --batch-deadline-ms MS   flush deadline of the cold-query queue, in
                           simulated (request-clock) milliseconds
                           (default 2; `simulate`/`online`)
  --recency-batch N        recency updates buffered per replay worker
                           before a batched drain under the shard lock
                           (default 1 = immediate, bit-exact legacy
                           behaviour; `sharded`/`online`/`dag`)
  --recency-drain-cadence-ms MS
                           drain cadence of the recency buffers, in
                           simulated (request-clock) milliseconds
                           (default 0 = fill-triggered drains only;
                           `sharded`/`online`/`dag`)
  --readers N              concurrent stats() reader threads during the
                           `sharded` replay (default 0)
  --jobs N                 concurrent DAG jobs for `dag` (default 3)
  --metrics-out FILE       `sharded`/`online`/`dag`: write the telemetry
                           layer's windowed series, eviction audit and
                           registry scalars as JSONL (render with
                           `repro report FILE`)
  --baseline DIR           `bench-gate`: committed BENCH_baseline dir
  --current DIR            `bench-gate`: dir with freshly written JSONs
  --tolerance F            `bench-gate`: allowed relative regression
  --smoke                  `admission`/`online`/`dag`/`chaos`: reduced CI
                           sweep with parity/degradation assertions
  --csv                    CSV output
  --config FILE            TOML config file
  --log-level L            off|error|warn|info|debug|trace
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Cli {
        Cli::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_command_flags_switches() {
        let cli = parse(&["fig3", "--seed", "7", "--csv", "--svm-backend", "rust"]);
        assert_eq!(cli.command, "fig3");
        assert_eq!(cli.seed().unwrap(), 7);
        assert!(cli.switch("csv"));
        assert_eq!(cli.flag("svm-backend"), Some("rust"));
    }

    #[test]
    fn svm_config_from_flags() {
        let cli = parse(&["fig3", "--svm-backend", "rust", "--kernel", "linear"]);
        let cfg = cli.svm_config().unwrap();
        assert_eq!(cfg.backend, "rust");
        assert_eq!(cfg.kernel, "linear");
    }

    #[test]
    fn missing_value_errors() {
        let r = Cli::parse(&["fig3".to_string(), "--seed".to_string()]);
        assert!(r.is_err());
    }

    #[test]
    fn bad_scale_rejected() {
        let cli = parse(&["fig5", "--scale", "-1"]);
        assert!(cli.scale().is_err());
        let cli = parse(&["fig5"]);
        assert!(cli.scale().unwrap() > 0.0);
    }

    #[test]
    fn admission_flag_is_valued_and_smoke_is_a_switch() {
        let cli = parse(&["simulate", "--admission", "tinylfu"]);
        assert_eq!(cli.flag("admission"), Some("tinylfu"));
        let cli = parse(&["admission", "--smoke"]);
        assert_eq!(cli.command, "admission");
        assert!(cli.switch("smoke"));
        assert!(Cli::parse(&["simulate".into(), "--admission".into()]).is_err());
    }

    #[test]
    fn shards_flag_parses_and_validates() {
        let cli = parse(&["sharded", "--shards", "8"]);
        assert_eq!(cli.shards(1).unwrap(), 8);
        assert_eq!(parse(&["sharded"]).shards(4).unwrap(), 4);
        assert!(parse(&["sharded", "--shards", "0"]).shards(1).is_err());
        assert!(parse(&["sharded", "--shards", "x"]).shards(1).is_err());
        assert!(parse(&["sharded", "--shards", "200000"]).shards(1).is_err());
    }

    #[test]
    fn empty_args_is_help() {
        let cli = Cli::parse(&[]).unwrap();
        assert_eq!(cli.command, "help");
    }

    #[test]
    fn unknown_switch_is_rejected() {
        let r = Cli::parse(&["sharded".to_string(), "--smok".to_string()]);
        assert!(r.is_err(), "typo'd switch must not be silently swallowed");
        let r = Cli::parse(&["fig3".to_string(), "--verbose".to_string()]);
        assert!(r.is_err());
        // Known switches still parse.
        assert!(Cli::parse(&["fig3".to_string(), "--csv".to_string()]).is_ok());
    }

    #[test]
    fn batcher_flags_parse_and_validate() {
        let cli = parse(&["online", "--batch-queue", "16", "--batch-deadline-ms", "5"]);
        assert_eq!(cli.batch_queue(1).unwrap(), 16);
        assert_eq!(cli.batch_deadline_ms(2).unwrap(), 5);
        assert_eq!(parse(&["online"]).batch_queue(1).unwrap(), 1);
        assert_eq!(parse(&["online"]).batch_deadline_ms(2).unwrap(), 2);
        assert!(parse(&["online", "--batch-queue", "0"]).batch_queue(1).is_err());
        assert!(parse(&["online", "--batch-queue", "x"]).batch_queue(1).is_err());
        assert!(parse(&["online", "--batch-deadline-ms", "-1"]).batch_deadline_ms(2).is_err());
    }

    #[test]
    fn recency_flags_parse_and_validate() {
        let cli = parse(&["sharded", "--recency-batch", "64", "--recency-drain-cadence-ms", "5"]);
        assert_eq!(cli.recency_batch(1).unwrap(), 64);
        assert_eq!(cli.recency_drain_cadence_ms(0).unwrap(), 5);
        assert_eq!(parse(&["sharded"]).recency_batch(1).unwrap(), 1);
        assert_eq!(parse(&["sharded"]).recency_drain_cadence_ms(0).unwrap(), 0);
        assert!(parse(&["sharded", "--recency-batch", "0"]).recency_batch(1).is_err());
        assert!(parse(&["sharded", "--recency-batch", "x"]).recency_batch(1).is_err());
        assert!(parse(&["sharded", "--recency-drain-cadence-ms", "-1"])
            .recency_drain_cadence_ms(0)
            .is_err());
    }

    #[test]
    fn readers_flag_parses_and_validates() {
        assert_eq!(parse(&["sharded", "--readers", "4"]).readers(0).unwrap(), 4);
        assert_eq!(parse(&["sharded"]).readers(0).unwrap(), 0);
        assert!(parse(&["sharded", "--readers", "1000"]).readers(0).is_err());
    }

    #[test]
    fn jobs_flag_parses_and_validates() {
        assert_eq!(parse(&["dag", "--jobs", "6"]).jobs(3).unwrap(), 6);
        assert_eq!(parse(&["dag"]).jobs(3).unwrap(), 3);
        assert!(parse(&["dag", "--jobs", "0"]).jobs(3).is_err());
        assert!(parse(&["dag", "--jobs", "9999"]).jobs(3).is_err());
        assert!(parse(&["dag", "--jobs", "x"]).jobs(3).is_err());
    }

    #[test]
    fn bench_gate_flags_are_valued() {
        let cli = parse(&["bench-gate", "--baseline", "BENCH_baseline", "--current", "rust"]);
        assert_eq!(cli.flag("baseline"), Some("BENCH_baseline"));
        assert_eq!(cli.flag("current"), Some("rust"));
        assert!(Cli::parse(&["bench-gate".into(), "--baseline".into()]).is_err());
    }

    #[test]
    fn metrics_out_is_valued() {
        let cli = parse(&["sharded", "--metrics-out", "m.jsonl"]);
        assert_eq!(cli.flag("metrics-out"), Some("m.jsonl"));
        assert!(Cli::parse(&["sharded".into(), "--metrics-out".into()]).is_err());
    }

    #[test]
    fn report_takes_one_positional_operand() {
        let cli = parse(&["report", "metrics.jsonl"]);
        assert_eq!(cli.command, "report");
        assert_eq!(cli.operand.as_deref(), Some("metrics.jsonl"));
        // A second positional is still rejected…
        assert!(Cli::parse(&[
            "report".into(),
            "a.jsonl".into(),
            "b.jsonl".into()
        ])
        .is_err());
        // …and other commands take none at all.
        assert!(Cli::parse(&["sharded".into(), "stray".into()]).is_err());
        assert_eq!(parse(&["report"]).operand, None);
    }

    #[test]
    fn policy_flag_is_validated() {
        let cli = parse(&["sharded", "--policy", "h-svm-lru"]);
        assert_eq!(cli.policy("lru").unwrap(), "h-svm-lru");
        assert_eq!(parse(&["sharded"]).policy("lru").unwrap(), "lru");
        let cli = parse(&["sharded", "--policy", "lr"]);
        let err = cli.policy("lru").unwrap_err().to_string();
        assert!(err.contains("unknown policy"), "{err}");
        assert!(err.contains("h-svm-lru"), "error lists known names: {err}");
    }
}
