//! Windowed time-series recorder keyed on **simulated** time.
//!
//! Every accumulator field is integer and every window boundary is a
//! `sim::time` microsecond index, so two same-seed runs produce identical
//! series byte for byte — wall-clock never enters this module (wall-clock
//! observations belong in `Volatile`-class histograms, which the JSONL
//! export excludes; see [`crate::obs`]).
//!
//! Hot-path cost: one division + one branch per observation
//! ([`WindowSeries::at`]). A window only materializes in the `done` list
//! when the clock crosses its boundary, so idle windows cost nothing.

use std::collections::BTreeMap;

use crate::sim::SimTime;

/// Default window width: one simulated second.
pub const DEFAULT_WINDOW_US: u64 = 1_000_000;

/// Per-window accumulator. Everything is a saturating-free plain `u64`
/// count (or microsecond total), merged across shards by field-wise
/// addition.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WindowAccum {
    /// Accesses observed in the window.
    pub requests: u64,
    /// Accesses that hit.
    pub hits: u64,
    /// Blocks inserted.
    pub insertions: u64,
    /// Evictions forced purely by capacity pressure.
    pub evict_capacity: u64,
    /// Evictions where the admission layer dueled the victim and the
    /// newcomer won.
    pub evict_admission: u64,
    /// Evictions where a cost-aware wrapper broke the base policy's tie
    /// toward a cheaper victim.
    pub evict_cost_tie: u64,
    /// Blocks resident at the end of the window (summed across shards).
    pub occupancy_end: u64,
    /// Classifier snapshot version changes observed by workers.
    pub snapshot_publishes: u64,
    /// Recompute cost charged by the DAG replay, in simulated microseconds.
    pub recompute_cost_us: u64,
    /// Evicted with predicted-reuse=true that WAS requested again.
    pub tp: u64,
    /// Evicted with predicted-reuse=true that was NOT requested again.
    pub fp: u64,
    /// Evicted with predicted-reuse=false that was NOT requested again.
    pub tn: u64,
    /// Evicted with predicted-reuse=false that WAS requested again.
    pub fn_: u64,
}

impl WindowAccum {
    /// Field-wise add `other` into `self` (shard → run rollup).
    pub fn merge(&mut self, other: &WindowAccum) {
        self.requests += other.requests;
        self.hits += other.hits;
        self.insertions += other.insertions;
        self.evict_capacity += other.evict_capacity;
        self.evict_admission += other.evict_admission;
        self.evict_cost_tie += other.evict_cost_tie;
        self.occupancy_end += other.occupancy_end;
        self.snapshot_publishes += other.snapshot_publishes;
        self.recompute_cost_us += other.recompute_cost_us;
        self.tp += other.tp;
        self.fp += other.fp;
        self.tn += other.tn;
        self.fn_ += other.fn_;
    }

    /// Total evictions in the window, over all causes.
    pub fn evictions(&self) -> u64 {
        self.evict_capacity + self.evict_admission + self.evict_cost_tie
    }

    /// Evictions that carried a classifier prediction (the population the
    /// confusion counts partition).
    pub fn labeled_evictions(&self) -> u64 {
        self.tp + self.fp + self.tn + self.fn_
    }

    /// `hits / requests` for the window (0 when idle).
    pub fn hit_ratio(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.hits as f64 / self.requests as f64
        }
    }
}

/// One worker's (or one shard's) window series: a current accumulator plus
/// the list of completed `(window_index, accum)` pairs.
#[derive(Debug)]
pub struct WindowSeries {
    width_us: u64,
    cur_idx: Option<u64>,
    cur: WindowAccum,
    done: Vec<(u64, WindowAccum)>,
}

impl WindowSeries {
    /// A series with the given window width in simulated microseconds
    /// (must be nonzero).
    pub fn new(width_us: u64) -> Self {
        assert!(width_us > 0, "window width must be nonzero");
        WindowSeries { width_us, cur_idx: None, cur: WindowAccum::default(), done: Vec::new() }
    }

    /// Window width in simulated microseconds.
    pub fn width_us(&self) -> u64 {
        self.width_us
    }

    /// The accumulator for the window containing `now`, rotating the
    /// previous window out when the boundary is crossed. O(1); the caller
    /// bumps fields directly on the returned accumulator.
    #[inline]
    pub fn at(&mut self, now: SimTime) -> &mut WindowAccum {
        let idx = now.micros() / self.width_us;
        if self.cur_idx != Some(idx) {
            self.rotate(idx);
        }
        &mut self.cur
    }

    #[cold]
    fn rotate(&mut self, idx: u64) {
        if let Some(prev) = self.cur_idx {
            self.done.push((prev, std::mem::take(&mut self.cur)));
        }
        self.cur_idx = Some(idx);
    }

    /// Close the current window and return every completed window, in
    /// observation order (merge with [`merge_series`] for a sorted,
    /// deduplicated rollup).
    pub fn finish(mut self) -> Vec<(u64, WindowAccum)> {
        if let Some(idx) = self.cur_idx.take() {
            self.done.push((idx, self.cur));
        }
        self.done
    }
}

/// Merge many per-worker window lists into one series sorted by window
/// index, folding duplicate indices field-wise. Deterministic for any
/// input order (addition is commutative).
pub fn merge_series(parts: Vec<Vec<(u64, WindowAccum)>>) -> Vec<(u64, WindowAccum)> {
    let mut merged: BTreeMap<u64, WindowAccum> = BTreeMap::new();
    for part in parts {
        for (idx, accum) in part {
            merged.entry(idx).or_default().merge(&accum);
        }
    }
    merged.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rotates_on_boundary_crossings() {
        let mut s = WindowSeries::new(1_000_000);
        s.at(SimTime(0)).requests += 1;
        s.at(SimTime(999_999)).requests += 1;
        s.at(SimTime(1_000_000)).requests += 1;
        s.at(SimTime(3_500_000)).hits += 1;
        let done = s.finish();
        assert_eq!(done.len(), 3);
        assert_eq!(done[0].0, 0);
        assert_eq!(done[0].1.requests, 2);
        assert_eq!(done[1].0, 1);
        assert_eq!(done[1].1.requests, 1);
        assert_eq!(done[2].0, 3, "idle window 2 must not materialize");
        assert_eq!(done[2].1.hits, 1);
    }

    #[test]
    fn merge_folds_duplicate_windows_sorted() {
        let a = vec![(1u64, WindowAccum { requests: 2, hits: 1, ..Default::default() })];
        let b = vec![
            (0u64, WindowAccum { requests: 5, ..Default::default() }),
            (1u64, WindowAccum { requests: 3, hits: 3, ..Default::default() }),
        ];
        let ab = merge_series(vec![a.clone(), b.clone()]);
        let ba = merge_series(vec![b, a]);
        assert_eq!(ab, ba, "merge must be order-independent");
        assert_eq!(ab.len(), 2);
        assert_eq!(ab[0].0, 0);
        assert_eq!(ab[1].1.requests, 5);
        assert_eq!(ab[1].1.hits, 4);
    }

    #[test]
    fn accum_invariants() {
        let w = WindowAccum {
            requests: 10,
            hits: 4,
            evict_capacity: 1,
            evict_admission: 2,
            evict_cost_tie: 3,
            tp: 1,
            fn_: 1,
            ..Default::default()
        };
        assert_eq!(w.evictions(), 6);
        assert_eq!(w.labeled_evictions(), 2);
        assert!((w.hit_ratio() - 0.4).abs() < 1e-12);
    }
}
