//! Lock-free log-bucket histograms, one instance per shard, merged on read.
//!
//! Same split as [`crate::cache::shard_stats`]: the recording side runs on
//! a hot path that is already single-writer per shard (the shard `Mutex`,
//! or a replay worker that owns its shard outright), so writes are plain
//! relaxed stores inside a seqlock write section; readers spin on the
//! sequence word and never block the writer.
//!
//! Buckets are powers of two: bucket 0 holds the value 0, bucket `i` holds
//! values whose highest set bit is `i - 1`, i.e. the range
//! `[2^(i-1), 2^i - 1]`. 65 buckets cover the whole `u64` domain, so
//! `record` never clamps and a merged snapshot is lossless — element-wise
//! addition of bucket counts is associative and commutative, which is what
//! makes per-shard instances mergeable in any order (property-tested in
//! rust/tests/property_obs.rs).

use crate::util::sync::atomic::{fence, AtomicU64, Ordering};
use crate::util::sync::hint;

/// Number of log2 buckets: one for zero plus one per possible
/// highest-set-bit position of a `u64`.
pub const BUCKETS: usize = 65;

/// Bucket index of a value: 0 for 0, else `64 - leading_zeros` (the
/// 1-based position of the highest set bit).
#[inline]
pub fn bucket_index(value: u64) -> usize {
    if value == 0 {
        0
    } else {
        64 - value.leading_zeros() as usize
    }
}

/// Inclusive upper bound of bucket `i` (the largest value it can hold).
pub fn bucket_bound(i: usize) -> u64 {
    debug_assert!(i < BUCKETS);
    if i == 0 {
        0
    } else if i == 64 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// A lock-free log2-bucket histogram.
///
/// Aligned like [`crate::cache::shard_stats::AtomicShardStats`] so adjacent
/// per-shard instances never share a cache line.
///
/// Single-writer discipline: `record` may only be called by the one thread
/// that owns this instance (the shard's lock holder or the replay worker
/// the shard is pinned to). `snapshot` is unrestricted.
#[repr(align(128))]
pub struct LogHistogram {
    /// Seqlock word: odd while a record is in flight, even otherwise.
    seq: AtomicU64,
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; BUCKETS],
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for LogHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let snap = self.snapshot();
        f.debug_struct("LogHistogram")
            .field("count", &snap.count)
            .field("sum", &snap.sum)
            .finish()
    }
}

impl LogHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        LogHistogram {
            seq: AtomicU64::new(0),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    #[inline]
    fn bump(counter: &AtomicU64, by: u64) {
        // Single writer: a plain load+store (not an RMW) is enough.
        counter.store(counter.load(Ordering::Relaxed).wrapping_add(by), Ordering::Relaxed);
    }

    /// Record one observation. Caller must be this instance's single
    /// writer; constant work, no allocation, no lock.
    #[inline]
    pub fn record(&self, value: u64) {
        // AcqRel open / Release close: same seqlock protocol as
        // `AtomicShardStats` — the Acquire half of the open keeps the
        // relaxed bumps after the odd-store, the Release close publishes
        // them before the even-store (loom-modeled in
        // rust/tests/loom_protocols.rs).
        let prev = self.seq.fetch_add(1, Ordering::AcqRel);
        debug_assert_eq!(prev & 1, 0, "concurrent LogHistogram writers");
        Self::bump(&self.count, 1);
        Self::bump(&self.sum, value);
        Self::bump(&self.buckets[bucket_index(value)], 1);
        let prev = self.seq.fetch_add(1, Ordering::Release);
        debug_assert_eq!(prev & 1, 1, "LogHistogram write section closed twice");
    }

    /// A consistent snapshot — lock-free; spins only while a (constant
    /// work) record is in flight.
    pub fn snapshot(&self) -> HistSnapshot {
        loop {
            // Acquire: pairs with the writer's Release close (see
            // `AtomicShardStats::snapshot`).
            let s1 = self.seq.load(Ordering::Acquire);
            if s1 & 1 == 1 {
                hint::spin_loop();
                continue;
            }
            let snap = HistSnapshot {
                count: self.count.load(Ordering::Relaxed),
                sum: self.sum.load(Ordering::Relaxed),
                buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            };
            // Acquire fence: orders the bucket loads before the re-check
            // (see AtomicShardStats::snapshot for the reasoning).
            fence(Ordering::Acquire);
            if self.seq.load(Ordering::Relaxed) == s1 {
                return snap;
            }
            hint::spin_loop();
        }
    }
}

/// An owned, mergeable copy of a [`LogHistogram`]'s state.
#[derive(Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    /// Total observations recorded.
    pub count: u64,
    /// Sum of all observed values.
    pub sum: u64,
    /// Per-bucket observation counts (see [`bucket_index`]).
    pub buckets: [u64; BUCKETS],
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot { count: 0, sum: 0, buckets: [0; BUCKETS] }
    }
}

impl std::fmt::Debug for HistSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HistSnapshot")
            .field("count", &self.count)
            .field("sum", &self.sum)
            .field("mean", &self.mean())
            .finish()
    }
}

impl HistSnapshot {
    /// Element-wise add `other` into `self`. Associative and lossless:
    /// merging per-shard snapshots in any order yields the same totals as
    /// recording every observation into one histogram.
    pub fn merge(&mut self, other: &HistSnapshot) {
        self.count += other.count;
        self.sum += other.sum;
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
    }

    /// Mean observed value (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Upper bound of the bucket containing the `q`-quantile observation
    /// (`q` in `[0, 1]`); 0 when empty. Deterministic — it walks the
    /// cumulative bucket counts, so identical snapshots give identical
    /// answers regardless of merge order.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_bound(i);
            }
        }
        bucket_bound(BUCKETS - 1)
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::util::sync::atomic::AtomicBool;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(u64::MAX), 64);
        assert_eq!(bucket_bound(0), 0);
        assert_eq!(bucket_bound(1), 1);
        assert_eq!(bucket_bound(2), 3);
        assert_eq!(bucket_bound(64), u64::MAX);
        for v in [0u64, 1, 2, 3, 4, 5, 1023, 1024, u64::MAX] {
            let i = bucket_index(v);
            assert!(v <= bucket_bound(i));
            if i > 0 {
                assert!(v > bucket_bound(i - 1));
            }
        }
    }

    #[test]
    fn records_and_summarizes() {
        let h = LogHistogram::new();
        for v in [0u64, 1, 2, 3, 100, 100, 1000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 7);
        assert_eq!(s.sum, 1206);
        assert_eq!(s.buckets[0], 1);
        assert_eq!(s.buckets[1], 1);
        assert_eq!(s.buckets[2], 2);
        assert!((s.mean() - 1206.0 / 7.0).abs() < 1e-9);
        assert_eq!(s.quantile(0.0), 0);
        assert_eq!(s.quantile(1.0), bucket_bound(bucket_index(1000)));
    }

    #[test]
    fn quantile_of_empty_is_zero() {
        assert_eq!(HistSnapshot::default().quantile(0.5), 0);
    }

    #[test]
    fn concurrent_readers_never_observe_torn_buckets() {
        let h = LogHistogram::new();
        let writes: u64 = 20_000;
        let stop = AtomicBool::new(false);
        std::thread::scope(|scope| {
            let h = &h;
            let stop_ref = &stop;
            let readers: Vec<_> = (0..3)
                .map(|_| {
                    scope.spawn(move || {
                        let mut seen = 0u64;
                        // Acquire: pairs with the Release store below so
                        // the last iteration sees final writer state.
                        while !stop_ref.load(Ordering::Acquire) {
                            let s = h.snapshot();
                            let total: u64 = s.buckets.iter().sum();
                            assert_eq!(total, s.count, "torn histogram snapshot");
                            seen += 1;
                        }
                        seen
                    })
                })
                .collect();
            for i in 0..writes {
                h.record(i % 1024);
            }
            // Release: all records above happen-before a reader observing
            // the stop flag.
            stop.store(true, Ordering::Release);
            for r in readers {
                assert!(r.join().unwrap() > 0);
            }
        });
        assert_eq!(h.snapshot().count, writes);
    }
}
