//! JSONL export (`--metrics-out`) and the `repro report` renderer.
//!
//! Hand-rolled like the rest of the crate's JSON (no serde): one object
//! per line, fixed field order, counters/gauges/histograms sorted by name
//! and windows by index — so two same-seed runs write **byte-identical**
//! files (property-tested in rust/tests/property_obs.rs). Only
//! [`MetricClass::Deterministic`] metrics are exported; `Volatile`
//! (wall-clock) histograms go to the log via [`log_volatile`] instead.
//!
//! The reader side ([`render_report`]) parses just the fields it renders
//! with the same minimal scanning approach as
//! `bench_support::compare` — it only ever reads files this module wrote.

use std::io::Write as _;

use anyhow::{bail, Context, Result};

use super::audit::AuditEntry;
use super::window::WindowAccum;
use super::{MetricClass, MetricsRegistry};
use crate::util::table::{fmt_f, fmt_pct, Table};

/// A value in the run-meta line.
#[derive(Debug, Clone)]
pub enum MetaVal {
    /// JSON string.
    Str(String),
    /// JSON integer.
    U64(u64),
}

/// Everything one run exports besides the registry: identity, windows and
/// the audit ring.
#[derive(Debug, Default)]
pub struct MetricsDoc {
    /// Run identity fields for the `meta` line (command, policy, seed…).
    pub meta: Vec<(String, MetaVal)>,
    /// Window width in simulated microseconds.
    pub window_us: u64,
    /// Completed windows, sorted by index.
    pub windows: Vec<(u64, WindowAccum)>,
    /// Evictions observed by the audit ring (sampled or not).
    pub audit_seen: u64,
    /// Audit sampling period.
    pub audit_every: u64,
    /// Sampled audit entries, sorted by `(time, block)`.
    pub audit: Vec<AuditEntry>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl MetricsDoc {
    /// Add a string meta field.
    pub fn meta_str(&mut self, key: &str, value: impl Into<String>) {
        self.meta.push((key.to_string(), MetaVal::Str(value.into())));
    }

    /// Add an integer meta field.
    pub fn meta_u64(&mut self, key: &str, value: u64) {
        self.meta.push((key.to_string(), MetaVal::U64(value)));
    }

    /// Serialize the document plus the registry's deterministic metrics as
    /// JSONL.
    pub fn to_jsonl(&self, registry: &MetricsRegistry) -> String {
        let mut out = String::new();
        // meta line
        out.push_str("{\"type\":\"meta\"");
        for (k, v) in &self.meta {
            match v {
                MetaVal::Str(s) => {
                    out.push_str(&format!(",\"{}\":\"{}\"", json_escape(k), json_escape(s)))
                }
                MetaVal::U64(n) => out.push_str(&format!(",\"{}\":{n}", json_escape(k))),
            }
        }
        out.push_str(&format!(",\"window_us\":{}}}\n", self.window_us));

        for (idx, w) in &self.windows {
            out.push_str(&format!(
                "{{\"type\":\"window\",\"idx\":{idx},\"start_us\":{start},\
                 \"requests\":{},\"hits\":{},\"insertions\":{},\
                 \"evict_capacity\":{},\"evict_admission\":{},\"evict_cost_tie\":{},\
                 \"occupancy\":{},\"snapshot_publishes\":{},\"recompute_us\":{},\
                 \"tp\":{},\"fp\":{},\"tn\":{},\"fn\":{}}}\n",
                w.requests,
                w.hits,
                w.insertions,
                w.evict_capacity,
                w.evict_admission,
                w.evict_cost_tie,
                w.occupancy_end,
                w.snapshot_publishes,
                w.recompute_cost_us,
                w.tp,
                w.fp,
                w.tn,
                w.fn_,
                start = idx * self.window_us,
            ));
        }

        for (name, value) in registry.counter_values() {
            out.push_str(&format!(
                "{{\"type\":\"counter\",\"name\":\"{}\",\"value\":{value}}}\n",
                json_escape(&name)
            ));
        }
        for (name, value) in registry.gauge_values() {
            out.push_str(&format!(
                "{{\"type\":\"gauge\",\"name\":\"{}\",\"value\":{value}}}\n",
                json_escape(&name)
            ));
        }
        for (name, class, snap) in registry.hist_snapshots() {
            if class != MetricClass::Deterministic {
                continue;
            }
            let buckets: Vec<String> = snap
                .buckets
                .iter()
                .enumerate()
                .filter(|(_, &c)| c > 0)
                .map(|(i, &c)| format!("[{},{c}]", super::histogram::bucket_bound(i)))
                .collect();
            out.push_str(&format!(
                "{{\"type\":\"hist\",\"name\":\"{}\",\"count\":{},\"sum\":{},\
                 \"p50\":{},\"p95\":{},\"buckets\":[{}]}}\n",
                json_escape(&name),
                snap.count,
                snap.sum,
                snap.quantile(0.50),
                snap.quantile(0.95),
                buckets.join(",")
            ));
        }

        out.push_str(&format!(
            "{{\"type\":\"audit_meta\",\"seen\":{},\"every\":{},\"sampled\":{}}}\n",
            self.audit_seen,
            self.audit_every,
            self.audit.len()
        ));
        for e in &self.audit {
            let predicted = match e.predicted {
                Some(true) => "true",
                Some(false) => "false",
                None => "null",
            };
            let features: Vec<String> = e.features.iter().map(|f| format!("{f}")).collect();
            out.push_str(&format!(
                "{{\"type\":\"audit\",\"at_us\":{},\"block\":{},\"cause\":\"{}\",\
                 \"score\":{},\"predicted\":{predicted},\"actual\":{},\"features\":[{}]}}\n",
                e.at.micros(),
                e.block.0,
                e.cause.name(),
                e.score,
                e.actual,
                features.join(",")
            ));
        }
        out
    }

    /// Serialize and write to `path`.
    pub fn write_jsonl(&self, registry: &MetricsRegistry, path: &str) -> Result<()> {
        let content = self.to_jsonl(registry);
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating metrics file {path:?}"))?;
        f.write_all(content.as_bytes())
            .with_context(|| format!("writing metrics file {path:?}"))?;
        Ok(())
    }
}

/// Log every `Volatile`-class histogram (the wall-clock metrics the JSONL
/// deliberately leaves out) at info level.
pub fn log_volatile(registry: &MetricsRegistry) {
    for (name, class, snap) in registry.hist_snapshots() {
        if class == MetricClass::Volatile && snap.count > 0 {
            log::info!(
                "volatile hist {name}: n={} mean={:.0} p50<={} p95<={}",
                snap.count,
                snap.mean(),
                snap.quantile(0.50),
                snap.quantile(0.95)
            );
        }
    }
}

// ---------------------------------------------------------------------------
// repro report: minimal field scanners over our own JSONL.

fn field_raw<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = if rest.starts_with('"') {
        // String value: scan to the closing quote (no escapes in the
        // fields report reads).
        rest[1..].find('"').map(|i| i + 2)?
    } else if rest.starts_with('[') {
        rest.find(']').map(|i| i + 1)?
    } else {
        rest.find([',', '}'])?
    };
    Some(&rest[..end])
}

fn field_u64(line: &str, key: &str) -> Option<u64> {
    field_raw(line, key)?.parse().ok()
}

fn field_str(line: &str, key: &str) -> Option<String> {
    let raw = field_raw(line, key)?;
    Some(raw.trim_matches('"').to_string())
}

/// Render a `metrics.jsonl` file's contents as the `repro report` tables.
pub fn render_report(content: &str) -> Result<String> {
    let mut out = String::new();
    let mut windows = Table::new(vec![
        "window", "t_start", "requests", "hit%", "evict cap", "evict adm", "evict tie",
        "occupancy", "publishes", "recompute_s", "tp", "fp", "tn", "fn",
    ]);
    let mut scalars = Table::new(vec!["kind", "name", "value"]);
    let mut hists = Table::new(vec!["histogram", "count", "mean", "p50<=", "p95<="]);
    let mut n_meta = 0usize;

    for line in content.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Some(ty) = field_str(line, "type") else {
            bail!("not a metrics line (no \"type\" field): {line:?}");
        };
        match ty.as_str() {
            "meta" => {
                n_meta += 1;
                out.push_str(&format!("run: {}\n", line));
            }
            "window" => {
                let g = |k: &str| field_u64(line, k).unwrap_or(0);
                let requests = g("requests");
                let hit_pct = if requests == 0 {
                    "-".to_string()
                } else {
                    fmt_pct(g("hits") as f64 / requests as f64)
                };
                windows.add_row(vec![
                    g("idx").to_string(),
                    fmt_f(g("start_us") as f64 / 1e6, 1),
                    requests.to_string(),
                    hit_pct,
                    g("evict_capacity").to_string(),
                    g("evict_admission").to_string(),
                    g("evict_cost_tie").to_string(),
                    g("occupancy").to_string(),
                    g("snapshot_publishes").to_string(),
                    fmt_f(g("recompute_us") as f64 / 1e6, 2),
                    g("tp").to_string(),
                    g("fp").to_string(),
                    g("tn").to_string(),
                    g("fn").to_string(),
                ]);
            }
            "counter" | "gauge" => {
                scalars.add_row(vec![
                    ty.clone(),
                    field_str(line, "name").unwrap_or_default(),
                    field_u64(line, "value").unwrap_or(0).to_string(),
                ]);
            }
            "hist" => {
                let count = field_u64(line, "count").unwrap_or(0);
                let sum = field_u64(line, "sum").unwrap_or(0);
                let mean =
                    if count == 0 { 0.0 } else { sum as f64 / count as f64 };
                hists.add_row(vec![
                    field_str(line, "name").unwrap_or_default(),
                    count.to_string(),
                    fmt_f(mean, 1),
                    field_u64(line, "p50").unwrap_or(0).to_string(),
                    field_u64(line, "p95").unwrap_or(0).to_string(),
                ]);
            }
            "audit_meta" => {
                out.push_str(&format!(
                    "audit: {} evictions seen, every {} sampled, {} recorded\n",
                    field_u64(line, "seen").unwrap_or(0),
                    field_u64(line, "every").unwrap_or(0),
                    field_u64(line, "sampled").unwrap_or(0),
                ));
            }
            "audit" => {} // summarized by audit_meta; raw rows stay in the file
            other => bail!("unknown metrics line type {other:?}"),
        }
    }
    if n_meta == 0 {
        bail!("no meta line — not a repro metrics.jsonl file");
    }
    if !windows.is_empty() {
        out.push('\n');
        out.push_str(&windows.render());
    }
    if !scalars.is_empty() {
        out.push('\n');
        out.push_str(&scalars.render());
    }
    if !hists.is_empty() {
        out.push('\n');
        out.push_str(&hists.render());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::EvictCause;
    use crate::hdfs::BlockId;
    use crate::sim::SimTime;

    fn sample_doc() -> MetricsDoc {
        let mut doc = MetricsDoc {
            window_us: 1_000_000,
            windows: vec![
                (0, WindowAccum { requests: 10, hits: 4, evict_capacity: 2, ..Default::default() }),
                (2, WindowAccum { requests: 5, hits: 5, tp: 1, fn_: 1, ..Default::default() }),
            ],
            audit_seen: 2,
            audit_every: 1,
            audit: vec![AuditEntry {
                at: SimTime(17),
                block: BlockId(3),
                cause: EvictCause::Capacity,
                features: Default::default(),
                score: -0.5,
                predicted: Some(false),
                actual: true,
            }],
            ..Default::default()
        };
        doc.meta_str("cmd", "sharded");
        doc.meta_str("policy", "h-svm-lru");
        doc.meta_u64("seed", 7);
        doc
    }

    #[test]
    fn jsonl_round_trips_through_report() {
        let reg = MetricsRegistry::new();
        reg.counter("batcher.cold").add(3);
        reg.gauge("samples.sent", || 11);
        let h = reg.histogram("evict.scan_steps", MetricClass::Deterministic, 1);
        h.record(0, 1);
        h.record(0, 5);
        let wall = reg.histogram("flush.wall_ns", MetricClass::Volatile, 1);
        wall.record(0, 123_456);

        let doc = sample_doc();
        let jsonl = doc.to_jsonl(&reg);
        assert!(jsonl.contains("\"type\":\"meta\""));
        assert!(jsonl.contains("\"seed\":7"));
        assert!(jsonl.contains("\"type\":\"window\",\"idx\":2"));
        assert!(jsonl.contains("\"name\":\"batcher.cold\",\"value\":3"));
        assert!(!jsonl.contains("flush.wall_ns"), "volatile hist must not be exported");
        assert!(jsonl.contains("\"cause\":\"capacity\""));

        let report = render_report(&jsonl).expect("report renders");
        assert!(report.contains("requests"));
        assert!(report.contains("40.00%"));
        assert!(report.contains("evict.scan_steps"));
        assert!(report.contains("2 evictions seen"));
    }

    #[test]
    fn export_is_deterministic_across_registration_order() {
        let doc = sample_doc();
        let a = MetricsRegistry::new();
        a.counter("x").add(1);
        a.counter("a").add(2);
        let b = MetricsRegistry::new();
        b.counter("a").add(2);
        b.counter("x").add(1);
        assert_eq!(doc.to_jsonl(&a), doc.to_jsonl(&b));
    }

    #[test]
    fn report_rejects_garbage() {
        assert!(render_report("not json at all\n").is_err());
        assert!(render_report("").is_err());
    }

    #[test]
    fn escapes_strings() {
        assert_eq!(json_escape("a\"b\\c\n"), "a\\\"b\\\\c\\n");
    }
}
