//! Sampled eviction audit ring — "why was this block evicted?".
//!
//! Recording every eviction would dominate the run's memory on adversarial
//! traces, so the ring keeps every Nth eviction up to a byte-bounded cap:
//! `entries.len() <= min(cap, ceil(seen / every))` always holds
//! (property-tested). Entry construction is deferred behind a closure so a
//! skipped eviction costs one increment and one branch.
//!
//! Entries carry the evicted block's feature vector, SVM decision score
//! and predicted-vs-eventual reuse, which is exactly the row a confusion
//! tracker needs — the drivers fold each audited (and unaudited) labeled
//! eviction into the per-window TP/FP/TN/FN counts of
//! [`crate::obs::window::WindowAccum`].

use crate::cache::EvictCause;
use crate::hdfs::BlockId;
use crate::sim::SimTime;
use crate::svm::features::FeatureVec;

/// One audited eviction.
#[derive(Debug, Clone, PartialEq)]
pub struct AuditEntry {
    /// Simulated time of the access that forced the eviction.
    pub at: SimTime,
    /// The evicted block.
    pub block: BlockId,
    /// Why the policy let it go.
    pub cause: EvictCause,
    /// The block's feature vector at its last access (zeroed when the run
    /// has no feature pipeline, e.g. plain LRU).
    pub features: FeatureVec,
    /// Raw SVM decision score at the last access (0.0 when unclassified).
    pub score: f32,
    /// The classifier's reuse prediction (`None` when unclassified).
    pub predicted: Option<bool>,
    /// Ground truth: was the block requested again after this eviction?
    pub actual: bool,
}

/// The sampling ring: every `every`-th eviction is recorded until `cap`
/// entries exist.
#[derive(Debug)]
pub struct EvictionAudit {
    every: u64,
    cap: usize,
    seen: u64,
    entries: Vec<AuditEntry>,
}

/// Default sampling period.
pub const DEFAULT_AUDIT_EVERY: u64 = 8;
/// Default ring capacity.
pub const DEFAULT_AUDIT_CAP: usize = 256;

impl EvictionAudit {
    /// A ring sampling every `every`-th eviction (min 1) up to `cap`
    /// entries.
    pub fn new(every: u64, cap: usize) -> Self {
        EvictionAudit { every: every.max(1), cap, seen: 0, entries: Vec::new() }
    }

    /// Observe one eviction; `make` runs only when this eviction is
    /// sampled.
    #[inline]
    pub fn observe(&mut self, make: impl FnOnce() -> AuditEntry) {
        let sampled = self.seen % self.every == 0 && self.entries.len() < self.cap;
        self.seen += 1;
        if sampled {
            self.entries.push(make());
        }
    }

    /// Evictions observed (sampled or not).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Sampling period.
    pub fn every(&self) -> u64 {
        self.every
    }

    /// The sampled entries, in observation order.
    pub fn entries(&self) -> &[AuditEntry] {
        &self.entries
    }

    /// Consume the ring.
    pub fn into_entries(self) -> Vec<AuditEntry> {
        self.entries
    }
}

/// Merge per-worker audit rings into one deterministic list: entries
/// sorted by `(time, block)`, total seen summed. Worker scheduling order
/// never shows in the result because each block is pinned to one shard
/// (so `(time, block)` collisions across workers cannot happen for
/// distinct streams with distinct blocks).
pub fn merge_audits(parts: Vec<EvictionAudit>) -> (Vec<AuditEntry>, u64) {
    let mut seen = 0u64;
    let mut entries = Vec::new();
    for part in parts {
        seen += part.seen;
        entries.extend(part.entries);
    }
    entries.sort_by_key(|e| (e.at, e.block.0));
    (entries, seen)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(at: u64, block: u64) -> AuditEntry {
        AuditEntry {
            at: SimTime(at),
            block: BlockId(block),
            cause: EvictCause::Capacity,
            features: FeatureVec::default(),
            score: 0.0,
            predicted: None,
            actual: false,
        }
    }

    #[test]
    fn sampling_bound_holds() {
        let mut ring = EvictionAudit::new(4, 5);
        for i in 0..100u64 {
            ring.observe(|| entry(i, i));
        }
        assert_eq!(ring.seen(), 100);
        let bound = (ring.seen().div_ceil(ring.every()) as usize).min(5);
        assert_eq!(ring.entries().len(), bound);
        // Every 4th eviction, starting at the first.
        assert_eq!(ring.entries()[0].at, SimTime(0));
        assert_eq!(ring.entries()[1].at, SimTime(4));
    }

    #[test]
    fn skipped_evictions_never_run_the_closure() {
        let mut ring = EvictionAudit::new(2, 100);
        let mut built = 0u32;
        for i in 0..10u64 {
            ring.observe(|| {
                built += 1;
                entry(i, i)
            });
        }
        assert_eq!(built, 5);
    }

    #[test]
    fn merge_is_order_independent() {
        let mut a = EvictionAudit::new(1, 16);
        let mut b = EvictionAudit::new(1, 16);
        a.observe(|| entry(5, 1));
        a.observe(|| entry(1, 2));
        b.observe(|| entry(3, 3));
        let (ab, seen) = merge_audits(vec![a, b]);
        assert_eq!(seen, 3);
        assert_eq!(ab.iter().map(|e| e.at.0).collect::<Vec<_>>(), vec![1, 3, 5]);
    }
}
