//! Observability: one metrics registry in front of lock-free histograms, a
//! windowed time-series recorder, and a sampled eviction audit ring.
//!
//! The paper's claims are about *behavior over time* — pollution forming,
//! classifier drift during online retraining, tail latency on the
//! prediction path — not end-of-run scalars. This layer records that
//! behavior without perturbing it:
//!
//! * [`MetricsRegistry`] hands out [`CounterHandle`] / [`HistHandle`]
//!   recorders and closure-backed gauges. A **disabled** registry hands
//!   out empty handles whose `record`/`add` is a null check — the O(1)
//!   hot path stays O(1) and allocation-free (held within 5% by
//!   `benches/bench_obs.rs` in the CI bench gate).
//! * [`histogram::LogHistogram`] is a per-shard seqlock block (same
//!   discipline as [`crate::cache::shard_stats`]): single writer under the
//!   shard's ownership, lock-free mergeable readers.
//! * [`window::WindowSeries`] buckets observations by **simulated** time,
//!   so same-seed runs emit bit-identical series.
//! * [`audit::EvictionAudit`] samples every Nth eviction with the feature
//!   vector, SVM score and predicted-vs-eventual reuse, feeding the
//!   per-window confusion counts.
//! * [`export`] writes the whole thing as JSONL (`--metrics-out`) and
//!   `repro report` renders it back as windowed tables.
//!
//! Determinism contract: metrics are either [`MetricClass::Deterministic`]
//! (simulated-time or count domains — exported) or
//! [`MetricClass::Volatile`] (wall-clock domains — reported to the log,
//! **excluded** from the JSONL so two same-seed runs produce byte-identical
//! files; property-tested in rust/tests/property_obs.rs).

pub mod audit;
pub mod export;
pub mod histogram;
pub mod window;

use std::sync::Arc;

use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::Mutex;

pub use audit::{merge_audits, AuditEntry, EvictionAudit, DEFAULT_AUDIT_CAP, DEFAULT_AUDIT_EVERY};
pub use histogram::{HistSnapshot, LogHistogram};
pub use window::{merge_series, WindowAccum, WindowSeries, DEFAULT_WINDOW_US};

/// Knobs of one observed run: window width and audit sampling.
#[derive(Debug, Clone, Copy)]
pub struct ObsConfig {
    /// Time-series window width in simulated microseconds.
    pub window_us: u64,
    /// Audit every Nth eviction.
    pub audit_every: u64,
    /// Audit ring capacity (entries per worker).
    pub audit_cap: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            window_us: DEFAULT_WINDOW_US,
            audit_every: DEFAULT_AUDIT_EVERY,
            audit_cap: DEFAULT_AUDIT_CAP,
        }
    }
}

/// One run's deterministic observations, merged across shard workers —
/// what a driver hands to [`export::MetricsDoc`] next to the registry.
#[derive(Debug, Clone, Default)]
pub struct RunObservations {
    /// Merged windowed series, sorted by window index.
    pub windows: Vec<(u64, WindowAccum)>,
    /// Merged audit entries, sorted by `(time, block)`.
    pub audit: Vec<AuditEntry>,
    /// Evictions the audit rings observed (sampled or not).
    pub audit_seen: u64,
    /// Audit sampling period.
    pub audit_every: u64,
}

impl RunObservations {
    /// Move the observations into an export document with the given
    /// window width (meta fields are the caller's to fill).
    pub fn into_doc(self, window_us: u64) -> export::MetricsDoc {
        export::MetricsDoc {
            meta: Vec::new(),
            window_us,
            windows: self.windows,
            audit_seen: self.audit_seen,
            audit_every: self.audit_every,
            audit: self.audit,
        }
    }
}

/// Whether a metric's value domain is reproducible across same-seed runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricClass {
    /// Counts and simulated-time quantities: included in the JSONL export.
    Deterministic,
    /// Wall-clock quantities (flush latency, prediction-path nanoseconds):
    /// logged at end of run, excluded from the deterministic export.
    Volatile,
}

impl MetricClass {
    /// Stable lowercase name (used by the JSONL export).
    pub fn name(self) -> &'static str {
        match self {
            MetricClass::Deterministic => "deterministic",
            MetricClass::Volatile => "volatile",
        }
    }
}

type GaugeFn = Box<dyn Fn() -> u64 + Send>;

#[derive(Default)]
struct RegistryInner {
    counters: Vec<(String, Arc<AtomicU64>)>,
    hists: Vec<(String, MetricClass, Arc<Vec<LogHistogram>>)>,
    gauges: Vec<(String, GaugeFn)>,
}

/// The registry: named counters, per-shard histograms and closure gauges.
///
/// Registration takes a `Mutex` (setup path); recording through the
/// returned handles is lock-free. A registry built with
/// [`MetricsRegistry::disabled`] returns inert handles and drops gauge
/// closures — instrumented code needs no `if enabled` branches of its own.
pub struct MetricsRegistry {
    enabled: bool,
    inner: Mutex<RegistryInner>,
}

impl std::fmt::Debug for MetricsRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MetricsRegistry").field("enabled", &self.enabled).finish()
    }
}

impl MetricsRegistry {
    /// An active registry.
    pub fn new() -> Self {
        MetricsRegistry { enabled: true, inner: Mutex::new(RegistryInner::default()) }
    }

    /// A no-op registry: every handle it returns is inert.
    pub fn disabled() -> Self {
        MetricsRegistry { enabled: false, inner: Mutex::new(RegistryInner::default()) }
    }

    /// Active or disabled, as requested (CLI convenience).
    pub fn with_enabled(enabled: bool) -> Self {
        if enabled {
            Self::new()
        } else {
            Self::disabled()
        }
    }

    /// Whether handles record anything.
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// The counter named `name`, registering it on first use (handles for
    /// the same name share one cell).
    pub fn counter(&self, name: &str) -> CounterHandle {
        if !self.enabled {
            return CounterHandle(None);
        }
        let mut inner = self.inner.lock().expect("metrics registry poisoned");
        if let Some((_, cell)) = inner.counters.iter().find(|(n, _)| n == name) {
            return CounterHandle(Some(Arc::clone(cell)));
        }
        let cell = Arc::new(AtomicU64::new(0));
        inner.counters.push((name.to_string(), Arc::clone(&cell)));
        CounterHandle(Some(cell))
    }

    /// The per-shard histogram named `name` with `shards` independent
    /// single-writer instances, registering it on first use. Re-requesting
    /// an existing name returns the existing instances (the shard count
    /// must match).
    pub fn histogram(&self, name: &str, class: MetricClass, shards: usize) -> HistHandle {
        if !self.enabled {
            return HistHandle(None);
        }
        let mut inner = self.inner.lock().expect("metrics registry poisoned");
        if let Some((_, _, slots)) = inner.hists.iter().find(|(n, _, _)| n == name) {
            assert_eq!(slots.len(), shards, "histogram {name:?} re-registered with a different shard count");
            return HistHandle(Some(Arc::clone(slots)));
        }
        let slots = Arc::new((0..shards.max(1)).map(|_| LogHistogram::new()).collect::<Vec<_>>());
        inner.hists.push((name.to_string(), class, Arc::clone(&slots)));
        HistHandle(Some(slots))
    }

    /// Register (or replace) the gauge named `name`; `read` is called at
    /// export time.
    pub fn gauge(&self, name: &str, read: impl Fn() -> u64 + Send + 'static) {
        if !self.enabled {
            return;
        }
        let mut inner = self.inner.lock().expect("metrics registry poisoned");
        if let Some(slot) = inner.gauges.iter_mut().find(|(n, _)| n == name) {
            slot.1 = Box::new(read);
        } else {
            inner.gauges.push((name.to_string(), Box::new(read)));
        }
    }

    /// Current counter values, sorted by name.
    pub fn counter_values(&self) -> Vec<(String, u64)> {
        let inner = self.inner.lock().expect("metrics registry poisoned");
        let mut out: Vec<_> = inner
            .counters
            .iter()
            .map(|(n, c)| (n.clone(), c.load(Ordering::Relaxed)))
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Current gauge readings, sorted by name.
    pub fn gauge_values(&self) -> Vec<(String, u64)> {
        let inner = self.inner.lock().expect("metrics registry poisoned");
        let mut out: Vec<_> = inner.gauges.iter().map(|(n, f)| (n.clone(), f())).collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Cross-shard merged snapshots of every histogram, sorted by name.
    pub fn hist_snapshots(&self) -> Vec<(String, MetricClass, HistSnapshot)> {
        let inner = self.inner.lock().expect("metrics registry poisoned");
        let mut out: Vec<_> = inner
            .hists
            .iter()
            .map(|(n, class, slots)| {
                let mut merged = HistSnapshot::default();
                for h in slots.iter() {
                    merged.merge(&h.snapshot());
                }
                (n.clone(), *class, merged)
            })
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

/// A recorder for one named counter; inert when the registry is disabled.
#[derive(Clone, Default)]
pub struct CounterHandle(Option<Arc<AtomicU64>>);

impl CounterHandle {
    /// Add `by` (multi-writer safe).
    #[inline]
    pub fn add(&self, by: u64) {
        if let Some(cell) = &self.0 {
            cell.fetch_add(by, Ordering::Relaxed);
        }
    }

    /// Add one.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value (0 when inert).
    pub fn value(&self) -> u64 {
        self.0.as_ref().map_or(0, |c| c.load(Ordering::Relaxed))
    }
}

impl std::fmt::Debug for CounterHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("CounterHandle").field(&self.value()).finish()
    }
}

/// A recorder for one named per-shard histogram; inert when the registry
/// is disabled. `record(shard, v)` must respect the per-shard
/// single-writer discipline of [`LogHistogram`].
#[derive(Clone, Default, Debug)]
pub struct HistHandle(Option<Arc<Vec<LogHistogram>>>);

impl HistHandle {
    /// Record `value` into shard `shard`'s instance.
    #[inline]
    pub fn record(&self, shard: usize, value: u64) {
        if let Some(slots) = &self.0 {
            slots[shard % slots.len()].record(value);
        }
    }

    /// Whether this handle records anything (for skipping observation
    /// computation that is itself costly).
    #[inline]
    pub fn is_active(&self) -> bool {
        self.0.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_registry_hands_out_inert_handles() {
        let reg = MetricsRegistry::disabled();
        let c = reg.counter("x");
        c.add(5);
        assert_eq!(c.value(), 0);
        let h = reg.histogram("h", MetricClass::Deterministic, 4);
        assert!(!h.is_active());
        h.record(0, 7);
        reg.gauge("g", || 3);
        assert!(reg.counter_values().is_empty());
        assert!(reg.gauge_values().is_empty());
        assert!(reg.hist_snapshots().is_empty());
    }

    #[test]
    fn counters_dedup_by_name() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("requests");
        let b = reg.counter("requests");
        a.add(2);
        b.inc();
        assert_eq!(reg.counter_values(), vec![("requests".to_string(), 3)]);
    }

    #[test]
    fn histograms_merge_across_shards() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("scan", MetricClass::Deterministic, 2);
        h.record(0, 1);
        h.record(1, 1);
        h.record(1, 100);
        let snaps = reg.hist_snapshots();
        assert_eq!(snaps.len(), 1);
        assert_eq!(snaps[0].0, "scan");
        assert_eq!(snaps[0].2.count, 3);
        assert_eq!(snaps[0].2.sum, 102);
    }

    #[test]
    fn gauges_read_latest_and_replace() {
        let reg = MetricsRegistry::new();
        let cell = Arc::new(AtomicU64::new(1));
        let view = Arc::clone(&cell);
        reg.gauge("probe.sent", move || view.load(Ordering::Relaxed));
        cell.store(9, Ordering::Relaxed);
        assert_eq!(reg.gauge_values(), vec![("probe.sent".to_string(), 9)]);
        reg.gauge("probe.sent", || 42);
        assert_eq!(reg.gauge_values(), vec![("probe.sent".to_string(), 42)]);
    }
}
