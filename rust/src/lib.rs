//! # h-svm-lru
//!
//! A reproduction of *"Hadoop-Oriented SVM-LRU (H-SVM-LRU): An Intelligent
//! Cache Replacement Algorithm to Improve MapReduce Performance"* (Ghazali,
//! Adabi, Rezaee, Down, Movaghar — cs.DC 2023) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: a discrete-event simulated
//!   HDFS + MapReduce cluster with centralized cache management, 13 cache
//!   replacement policies (the paper's contribution plus its whole related-
//!   work table) behind a sharded concurrent cache front
//!   ([`cache::ShardedCache`]), the SVM training pipeline, and the
//!   experiment/bench drivers that regenerate every table and figure of
//!   the paper.
//! * **L2 (python/compile/model.py)** — the SVM train/predict compute graph
//!   in JAX, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — the Gram-matrix Pallas kernel the L2
//!   model calls.
//!
//! At runtime the Rust coordinator executes the AOT artifacts through the
//! PJRT CPU client (`runtime`); Python never runs on the request path.
//!
//! See DESIGN.md for the architecture and the per-experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod cache;
pub mod config;
pub mod hdfs;
pub mod sim;
pub mod util;
pub mod mapreduce;
pub mod workload;
pub mod runtime;
pub mod svm;
pub mod coordinator;
pub mod experiments;
pub mod cli;
pub mod bench_support;
pub mod testkit;
