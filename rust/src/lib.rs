//! # h-svm-lru
//!
//! A reproduction of *"Hadoop-Oriented SVM-LRU (H-SVM-LRU): An Intelligent
//! Cache Replacement Algorithm to Improve MapReduce Performance"* (Ghazali,
//! Adabi, Rezaee, Down, Movaghar — cs.DC 2023) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: a discrete-event simulated
//!   HDFS + MapReduce cluster with centralized cache management, 13 cache
//!   replacement policies (the paper's contribution plus its whole related-
//!   work table) behind a sharded concurrent cache front
//!   ([`cache::ShardedCache`]), the SVM training pipeline, and the
//!   experiment/bench drivers that regenerate every table and figure of
//!   the paper.
//! * **L2 (python/compile/model.py)** — the SVM train/predict compute graph
//!   in JAX, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — the Gram-matrix Pallas kernel the L2
//!   model calls.
//!
//! At runtime the Rust coordinator executes the AOT artifacts through the
//! PJRT CPU client (`runtime`); Python never runs on the request path.
//!
//! See docs/ARCHITECTURE.md for the layer map and the CI-enforced
//! invariants at each seam, docs/CONCURRENCY.md for the memory-ordering
//! protocols and what the loom/Miri/TSan jobs prove about them, and the
//! root README.md for the experiment command index.
//!
//! The crate is `#![forbid(unsafe_code)]`: every concurrent structure is
//! safe Rust over `std::sync` primitives (via the [`util::sync`] facade,
//! which swaps in loom's instrumented equivalents under `--cfg loom`).
#![forbid(unsafe_code)]
#![warn(missing_docs)]

// Coverage debt: the modules below carry `allow(missing_docs)` until their
// public items are documented to the standard of cache/, coordinator/ and
// workload/ (which are lint-clean — keep them that way; rustdoc runs with
// `-D warnings` in CI, so removing an `allow` here makes the docs job
// enforce full coverage for that module).

/// Replacement policies, admission control and the sharded cache front.
pub mod cache;
/// Cluster + SVM configuration (TOML loading, validation).
#[allow(missing_docs)]
pub mod config;
/// Simulated HDFS: blocks, placement, datanodes, read service times.
#[allow(missing_docs)]
pub mod hdfs;
/// Discrete-event simulation core: time, events, scoped parallelism.
#[allow(missing_docs)]
pub mod sim;
/// Small support crates-within-the-crate: hashing, rng, stats, tables.
#[allow(missing_docs)]
pub mod util;
/// MapReduce job model and the slot-based scheduler.
#[allow(missing_docs)]
pub mod mapreduce;
/// Workload models: apps, traces, suites and multi-stage DAG jobs.
pub mod workload;
/// SVM backends: PJRT-executed AOT artifacts and the pure-Rust SMO.
#[allow(missing_docs)]
pub mod runtime;
/// SVM math: features, kernels, SMO training, evaluation.
#[allow(missing_docs)]
pub mod svm;
/// NameNode-side cache coordination: Algorithm 1, batching, online learning.
pub mod coordinator;
/// Observability: metrics registry, lock-free histograms, windowed
/// time-series, eviction audit ring and the JSONL export behind
/// `--metrics-out` / `repro report`.
pub mod obs;
/// Experiment drivers regenerating the paper's tables and figures.
pub mod experiments;
/// The hand-rolled `repro` command-line parser.
#[allow(missing_docs)]
pub mod cli;
/// Bench harness + the bench-gate comparison logic.
#[allow(missing_docs)]
pub mod bench_support;
/// Shared test fixtures.
#[allow(missing_docs)]
pub mod testkit;
