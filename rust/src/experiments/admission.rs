//! Admission-control sweep: every eviction policy crossed with every
//! admission policy, replayed over the Fig 3 trace and the scan-storm
//! pollution adversary — the `repro admission` driver.
//!
//! The classifier pass runs once per trace (predictions depend on neither
//! the eviction policy nor the admission policy), then each (policy,
//! admission) cell replays the identical request stream on a fresh cache.
//! The `always` column is the pre-admission behaviour, so any improvement
//! in the other columns is attributable to admission control alone.

use anyhow::Result;

use crate::cache::admission::ADMISSION_NAMES;
use crate::cache::registry::POLICY_NAMES;
use crate::svm::KernelKind;
use crate::util::table::{fmt_f, Table};
use crate::workload::BlockRequest;

use super::sharded_replay::{classify_trace, replay, ReplayOptions, ShardedReplayReport};

/// One eviction policy's replays across every admission policy, in
/// [`AdmissionSweep::admissions`] order.
#[derive(Debug, Clone)]
pub struct AdmissionRow {
    /// Eviction policy of this row (registry name).
    pub policy: String,
    /// One replay per admission policy, in sweep order.
    pub cells: Vec<ShardedReplayReport>,
}

impl AdmissionRow {
    /// Hit-ratio gain of the best admission policy over `always`.
    pub fn best_gain(&self) -> f64 {
        let always = self.hit_ratio_of("always").unwrap_or(0.0);
        self.cells
            .iter()
            .map(|c| c.hit_ratio() - always)
            .fold(0.0, f64::max)
    }

    /// Hit ratio of the cell replayed under `admission`, if present.
    pub fn hit_ratio_of(&self, admission: &str) -> Option<f64> {
        self.cells
            .iter()
            .find(|c| c.admission == admission)
            .map(|c| c.hit_ratio())
    }
}

/// The full policy × admission matrix for one trace.
#[derive(Debug, Clone)]
pub struct AdmissionSweep {
    /// Trace label ("fig3" / "scan-storm").
    pub trace: String,
    /// Admission policies swept (the matrix columns), in order.
    pub admissions: Vec<String>,
    /// One row per eviction policy.
    pub rows: Vec<AdmissionRow>,
}

/// Replay `trace` for every (policy, admission) pair. The classifier pass
/// runs once; every cell replays the identical stream with the identical
/// predictions on a fresh `shards`-way cache of `capacity` bytes.
pub fn run_matrix(
    trace_name: &str,
    policies: &[&str],
    admissions: &[&str],
    shards: usize,
    capacity: u64,
    trace: &[BlockRequest],
) -> Result<AdmissionSweep> {
    let classes = classify_trace(trace, KernelKind::Rbf, 64)?;
    let mut rows = Vec::with_capacity(policies.len());
    for &policy in policies {
        let cells = admissions
            .iter()
            .map(|&adm| {
                let opts = ReplayOptions::new().admission(adm).classes(&classes);
                Ok(replay(policy, shards, capacity, trace, &opts)?.report)
            })
            .collect::<Result<Vec<_>>>()?;
        rows.push(AdmissionRow { policy: policy.to_string(), cells });
    }
    Ok(AdmissionSweep {
        trace: trace_name.to_string(),
        admissions: admissions.iter().map(|s| s.to_string()).collect(),
        rows,
    })
}

/// The default full sweep: all 13 eviction policies × all 4 admission
/// policies; `smoke` restricts to lru + h-svm-lru (the CI entry point).
pub fn default_policies(smoke: bool) -> Vec<&'static str> {
    if smoke {
        vec!["lru", "h-svm-lru"]
    } else {
        POLICY_NAMES.to_vec()
    }
}

/// All registered admission policies, in presentation order.
pub fn default_admissions() -> Vec<&'static str> {
    ADMISSION_NAMES.to_vec()
}

/// Hit-ratio matrix: one row per eviction policy, one column per admission
/// policy, plus the best gain over `always`.
pub fn render_hit_ratios(sweep: &AdmissionSweep) -> Table {
    let mut header = vec!["policy".to_string()];
    header.extend(sweep.admissions.iter().cloned());
    header.push("best gain".to_string());
    let mut t = Table::new(header);
    for row in &sweep.rows {
        let mut cells = vec![row.policy.clone()];
        cells.extend(row.cells.iter().map(|c| fmt_f(c.hit_ratio(), 4)));
        cells.push(format!("{:+.4}", row.best_gain()));
        t.add_row(cells);
    }
    t
}

/// Admission-decision matrix: rejected inserts per (policy, admission)
/// cell — how aggressively each admission policy filtered the stream.
pub fn render_rejections(sweep: &AdmissionSweep) -> Table {
    let mut header = vec!["policy".to_string()];
    header.extend(sweep.admissions.iter().cloned());
    let mut t = Table::new(header);
    for row in &sweep.rows {
        let mut cells = vec![row.policy.clone()];
        cells.extend(row.cells.iter().map(|c| c.stats.rejected.to_string()));
        t.add_row(cells);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::MB;
    use crate::workload::{fig3_trace, scan_storm_trace};

    const BLOCK: u64 = 64 * MB;

    #[test]
    fn matrix_covers_every_cell() {
        let trace = scan_storm_trace(BLOCK, 7);
        let sweep = run_matrix(
            "scan-storm",
            &["lru", "fifo"],
            &default_admissions(),
            2,
            8 * BLOCK,
            &trace,
        )
        .unwrap();
        assert_eq!(sweep.rows.len(), 2);
        for row in &sweep.rows {
            assert_eq!(row.cells.len(), ADMISSION_NAMES.len());
            for cell in &row.cells {
                assert_eq!(cell.stats.requests, trace.len() as u64);
                assert_eq!(cell.stats.hits + cell.stats.misses, cell.stats.requests);
            }
        }
        let t = render_hit_ratios(&sweep);
        assert_eq!(t.n_rows(), 2);
        assert_eq!(render_rejections(&sweep).n_rows(), 2);
    }

    /// The acceptance criterion of the subsystem: on the scan-storm trace,
    /// frequency- and SVM-gated admission beat admit-everything for plain
    /// LRU (pollution stopped at insert time, not eviction time).
    #[test]
    fn admission_beats_always_on_scan_storm_for_lru() {
        let trace = scan_storm_trace(BLOCK, 11);
        let sweep = run_matrix(
            "scan-storm",
            &["lru"],
            &default_admissions(),
            1,
            8 * BLOCK,
            &trace,
        )
        .unwrap();
        let row = &sweep.rows[0];
        let always = row.hit_ratio_of("always").unwrap();
        let tinylfu = row.hit_ratio_of("tinylfu").unwrap();
        let ghost = row.hit_ratio_of("ghost").unwrap();
        let svm = row.hit_ratio_of("svm").unwrap();
        assert!(
            tinylfu > always,
            "tinylfu {tinylfu:.4} must beat always {always:.4}"
        );
        assert!(ghost > always, "ghost {ghost:.4} must beat always {always:.4}");
        assert!(svm > always, "svm {svm:.4} must beat always {always:.4}");
        // The flood must actually be filtered, not just reordered.
        let rejected = row
            .cells
            .iter()
            .find(|c| c.admission == "tinylfu")
            .unwrap()
            .stats
            .rejected;
        assert!(rejected > 0, "tinylfu must reject part of the flood");
    }

    /// `always` must be bit-identical to the pre-admission replay path.
    #[test]
    fn always_column_matches_plain_replay() {
        let trace = fig3_trace(BLOCK, 5);
        let classes = classify_trace(&trace, KernelKind::Rbf, 64).unwrap();
        let plain = replay(
            "lru",
            2,
            8 * BLOCK,
            &trace,
            &ReplayOptions::new().classes(&classes),
        )
        .unwrap()
        .report;
        let sweep =
            run_matrix("fig3", &["lru"], &["always"], 2, 8 * BLOCK, &trace).unwrap();
        let cell = &sweep.rows[0].cells[0];
        assert_eq!(cell.stats, plain.stats);
        assert_eq!(cell.per_shard, plain.per_shard);
        assert_eq!(cell.stats.rejected, 0, "always never rejects");
        assert_eq!(cell.stats.admitted, cell.stats.insertions);
    }
}
