//! Experiment drivers — one module per paper artifact (see DESIGN.md §5):
//!
//! | module    | regenerates |
//! |-----------|-------------|
//! | `fig3`    | Fig 3: hit ratio vs cache size (LRU vs H-SVM-LRU) |
//! | `table7`  | Table 7: improvement ratios from the Fig 3 series |
//! | `fig4`    | Fig 4: WordCount exec time vs input size, 3 scenarios |
//! | `fig5`    | Fig 5: normalized run time of workloads W1–W6 |
//! | `fig6`    | Fig 6: per-app normalized run time under H-SVM-LRU |
//! | `table5`  | Table 5: kernel-function confusion-matrix comparison |
//! | `policies`| Table 1 ablation: all 13 policies on one trace |
//! | `sharded_replay` | shard-parallel trace replay on scoped workers |
//! | `simulate`| DES cluster scenario: arrivals, heartbeats, retraining |
//! | `admission` | eviction-policy × admission-policy sweep (pollution control) |
//! | `online_sharded` | frozen vs. online-learning shard-parallel replay matrix |
//! | `dag_replay` | multi-stage DAG jobs with recompute-cost charging |
//! | `chaos`   | fault-injected replays: breaker degradation, trainer crashes, node death |

pub mod admission;
pub mod chaos;
pub mod common;
pub mod dag_replay;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod online_sharded;
pub mod policies;
pub mod sharded_replay;
pub mod simulate;
pub mod table5;
pub mod table7;

pub use chaos::{
    breaker_for_trace, default_serving_plan, run_serving_chaos, run_trainer_chaos,
    ServingChaosReport, TrainerChaosReport,
};
pub use common::{make_coordinator, replay_trace_two_pass, run_repeated_job, run_workload, Scenario, WorkloadRun};
pub use dag_replay::{run_dag, run_dag_chaos, run_dag_pass, run_dag_pass_chaos, DagChaos, DagReport};
