//! DAG replay: drive multi-stage jobs (`workload::dag`) through the
//! MapReduce scheduler onto a [`ShardedCache`], charging recompute costs
//! for evicted intermediates.
//!
//! Stage outputs are *cache-only* blocks: they have no HDFS replicas, so a
//! miss on one means the producing stage's work is partially re-run — the
//! read completes after the block's recompute cost (`workload::dag::
//! stage_recompute_cost_s`, pro-rated per block) instead of a disk read.
//! That cost is also what the eviction layer sees: every access carries it
//! in `AccessContext::recompute_cost`, feeding the `block-goodness` BG
//! term, the `*-cost` victim tie-break and SVM feature 8.
//!
//! Execution is wave-by-wave: stages at DAG level `w` (across all
//! concurrent jobs) run in one [`Scheduler::run_jobs`] batch sharing the
//! cluster's slots, with replica-aware read placement via
//! `hdfs::topology`-placed inputs; the next wave starts when the slowest
//! stage of the current wave finishes. At each wave boundary the finished
//! stages' outputs are materialized into the cache.
//!
//! Classification reuses the classify-once discipline of
//! `sharded_replay`: the scheduler's block-read ORDER is timing-independent
//! (maps dispatch round-robin over the wave's stages; shuffle is analytic),
//! so pass A records the access sequence with ground-truth reuse labels,
//! `classify_trace` trains the SMO fallback and scores every access, and
//! pass B replays consuming one prediction per access index. Both passes
//! are single-threaded and fully deterministic under (`seed`, shard
//! count) — property-tested in rust/tests/property_dag.rs.

use std::collections::{HashMap, HashSet};

use anyhow::{Context, Result};

use crate::cache::sharded::{shard_of, ShardStats, ShardedCache};
use crate::cache::{AccessContext, CacheAffinity, CacheBuilder, EvictCause};
use crate::config::ClusterConfig;
use crate::hdfs::topology::Placement;
use crate::hdfs::{reader, BlockId, BlockKind, DataNodeId, ReadSource};
use crate::mapreduce::job::JobId;
use crate::mapreduce::scheduler::{
    AccessRequest, BlockRead, BlockService, FailureModel, Scheduler,
};
use crate::obs::{
    merge_audits, merge_series, AuditEntry, EvictionAudit, HistHandle, MetricClass,
    MetricsRegistry, ObsConfig, RunObservations, WindowSeries,
};
use crate::sim::{FaultInjector, FaultPlan, SimDuration, SimTime};
use crate::svm::kernel::KernelKind;
use crate::util::fasthash::IdHashMap;
use crate::util::rng::Pcg64;
use crate::workload::dag::{self, DagJob};
use crate::workload::BlockRequest;

use super::sharded_replay::{classify_trace, classify_trace_scored};

/// Stage-output block ids start here — far above any suite's input range.
const OUTPUT_BLOCK_BASE: u64 = 1 << 40;

/// Chaos wiring of one DAG replay: the shared [`FaultPlan`] (its node
/// down/up events are applied at wave boundaries), an optional
/// [`FaultInjector`] tallying the applied transitions, and the scheduler's
/// attempt-level [`FailureModel`] — one cause, one seed, so node death and
/// task-attempt failures replay together deterministically.
#[derive(Clone)]
pub struct DagChaos<'p> {
    /// The scripted faults (only node events apply to the DAG replay).
    pub plan: &'p FaultPlan,
    /// Tally sink for applied node transitions (optional).
    pub injector: Option<&'p FaultInjector>,
    /// Map-attempt failure injection for [`Scheduler::run_jobs`].
    pub failures: FailureModel,
}

/// What one DAG replay measured.
#[derive(Debug, Clone)]
pub struct DagReport {
    /// Replacement policy name (registry key).
    pub policy: String,
    /// Shard count of the cache.
    pub shards: usize,
    /// Total cache capacity in bytes (split across shards).
    pub capacity: u64,
    /// Number of DAG jobs replayed.
    pub n_jobs: usize,
    /// Sum over jobs of (last sink finish - submission at t=0), seconds.
    pub total_job_time_s: f64,
    /// Finish time of the final wave, seconds.
    pub makespan_s: f64,
    /// Merged cache counters.
    pub stats: ShardStats,
    /// Misses on evicted cache-only intermediates (each charged).
    pub recompute_events: u64,
    /// Total recompute seconds charged to job time.
    pub recompute_seconds: f64,
    /// Cache accesses issued (reads + materializations).
    pub accesses: usize,
    /// Whether a trained classifier drove this result (pass B ran).
    pub trained: bool,
}

#[derive(Debug, Clone)]
struct BlockMeta {
    size: u64,
    kind: BlockKind,
    /// Seconds to regenerate the block on an evicted-intermediate miss;
    /// 0.0 for disk-backed inputs.
    recompute_s: f64,
    /// File-grouping key for policy features (stage id for outputs).
    file: u64,
    /// HDFS replica nodes; empty for cache-only stage outputs.
    replicas: Vec<DataNodeId>,
}

/// An eviction seen mid-replay whose ground-truth reuse is only knowable
/// after the pass log is complete — [`run_dag_observed`] resolves them
/// against the labeled log once the replay ends.
#[derive(Debug, Clone, Copy)]
struct PendingEvict {
    /// Simulated time of the evicting access.
    at: SimTime,
    /// Log index of the victim's most recent access (its prediction and,
    /// post-labeling, its `reused_later` ground truth).
    log_idx: usize,
    cause: EvictCause,
    block: BlockId,
}

/// In-replay observation state of a [`DagBlockService`] (single-threaded:
/// the scheduler drives the whole cache from one thread, so one window
/// series and one running occupancy counter suffice).
#[derive(Debug)]
struct DagObs {
    windows: WindowSeries,
    /// Victim's-last-access index per resident block.
    last: IdHashMap<BlockId, usize>,
    pending: Vec<PendingEvict>,
    /// Blocks resident across ALL shards (insertions − evictions).
    resident: u64,
    scan_hist: HistHandle,
}

/// [`BlockService`] over one [`ShardedCache`]: inputs are disk-backed with
/// placed replicas, stage outputs are cache-only with recompute charges.
pub struct DagBlockService<'a> {
    cfg: &'a ClusterConfig,
    cache: ShardedCache,
    meta: IdHashMap<BlockId, BlockMeta>,
    /// Precomputed per-access predictions (empty = classifier-less pass).
    classes: Vec<Option<bool>>,
    cursor: usize,
    /// Pass log: one entry per cache access, in order.
    log: Vec<BlockRequest>,
    recompute_events: u64,
    recompute_seconds: f64,
    /// DataNodes currently down (scripted [`FaultEvent::NodeDown`]
    /// (`crate::sim::FaultEvent`) applied at wave boundaries). Empty on
    /// fault-free replays, in which case every liveness check below is
    /// vacuously true and behavior is identical to the pre-chaos service.
    dead: HashSet<u32>,
    /// Cached blocks dropped because their cache node died.
    dead_cache_drops: u64,
    /// Telemetry, present only on observed passes (see [`run_dag_observed`]).
    obs: Option<DagObs>,
}

impl<'a> DagBlockService<'a> {
    /// Build over a fresh cache; `classes` may be empty (all-None pass).
    pub fn new(cfg: &'a ClusterConfig, cache: ShardedCache, classes: Vec<Option<bool>>) -> Self {
        DagBlockService {
            cfg,
            cache,
            meta: IdHashMap::default(),
            classes,
            cursor: 0,
            log: Vec::new(),
            recompute_events: 0,
            recompute_seconds: 0.0,
            dead: HashSet::new(),
            dead_cache_drops: 0,
            obs: None,
        }
    }

    /// Attach the telemetry layer: windowed series, eviction bookkeeping
    /// for the post-run audit, and the eviction scan-work histogram (one
    /// slot — this service is single-threaded).
    fn enable_obs(&mut self, registry: &MetricsRegistry, cfg: ObsConfig) {
        self.obs = Some(DagObs {
            windows: WindowSeries::new(cfg.window_us),
            last: IdHashMap::default(),
            pending: Vec::new(),
            resident: 0,
            scan_hist: registry.histogram("evict.scan_steps", MetricClass::Deterministic, 1),
        });
    }

    /// Detach and return the observation state (None on unobserved passes).
    fn take_obs(&mut self) -> Option<(WindowSeries, Vec<PendingEvict>)> {
        self.obs.take().map(|o| (o.windows, o.pending))
    }

    /// Register a disk-backed input block with its HDFS replicas.
    pub fn register_input(&mut self, block: BlockId, size: u64, replicas: Vec<DataNodeId>) {
        self.meta.insert(
            block,
            BlockMeta { size, kind: BlockKind::Input, recompute_s: 0.0, file: block.0, replicas },
        );
    }

    /// Register a cache-only stage-output block carrying its pro-rated
    /// recompute cost.
    pub fn register_output(&mut self, block: BlockId, size: u64, recompute_s: f64, file: u64) {
        self.meta.insert(
            block,
            BlockMeta {
                size,
                kind: BlockKind::Intermediate,
                recompute_s,
                file,
                replicas: Vec::new(),
            },
        );
    }

    /// Simulated node holding the cached copy of `block` (stable hash of
    /// the block over the cluster, mirroring the shard routing).
    fn cache_node(&self, block: BlockId) -> DataNodeId {
        DataNodeId(shard_of(block, self.cfg.datanodes) as u32)
    }

    /// One cache access: consumes the next precomputed class, logs the
    /// request and returns whether it hit.
    pub fn access(&mut self, block: BlockId, now: SimTime, affinity: CacheAffinity) -> bool {
        let m = self.meta.get(&block).expect("access to unregistered block").clone();
        let class = self.classes.get(self.cursor).copied().flatten();
        self.cursor += 1;
        self.log.push(BlockRequest {
            time: now,
            block,
            size: m.size,
            kind: m.kind,
            affinity,
            reused_later: false, // filled by ground_truth_labels()
            recompute_cost: m.recompute_s,
        });
        let ctx = AccessContext {
            time: now,
            size: m.size,
            kind: m.kind,
            file: m.file,
            file_width: 1,
            file_complete: false,
            affinity,
            predicted_reuse: class,
            recompute_cost: m.recompute_s,
        };
        let outcome = self.cache.access_or_insert(block, &ctx);
        if let Some(obs) = &mut self.obs {
            if !outcome.hit {
                obs.scan_hist.record(0, u64::from(outcome.scan_steps));
            }
            obs.resident += u64::from(outcome.inserted);
            obs.resident -= outcome.evicted.len() as u64;
            let log_idx = self.log.len() - 1;
            let win = obs.windows.at(now);
            win.requests += 1;
            win.hits += u64::from(outcome.hit);
            win.insertions += u64::from(outcome.inserted);
            win.occupancy_end = obs.resident;
            for (victim, cause) in outcome.evicted.iter().zip(&outcome.causes) {
                match cause {
                    EvictCause::Capacity => win.evict_capacity += 1,
                    EvictCause::AdmissionDuel => win.evict_admission += 1,
                    EvictCause::CostTieBreak => win.evict_cost_tie += 1,
                }
                if let Some(li) = obs.last.remove(victim) {
                    obs.pending.push(PendingEvict {
                        at: now,
                        log_idx: li,
                        cause: *cause,
                        block: *victim,
                    });
                }
            }
            obs.last.insert(block, log_idx);
        }
        outcome.hit
    }

    /// Recompute charges accrued so far: (events, seconds).
    pub fn recompute_charges(&self) -> (u64, f64) {
        (self.recompute_events, self.recompute_seconds)
    }

    /// Apply one scripted node transition. A death drops every cached
    /// block whose cache node is the dying one (its memory is gone) — in
    /// ascending block order, so replays stay deterministic — and hides
    /// the node's disk replicas from [`read_block`](BlockService); a
    /// revival restores replica visibility (the cache restarts cold).
    /// Returns how many cached blocks were dropped. Idempotent per state.
    pub fn apply_node_event(&mut self, node: u32, down: bool) -> u64 {
        if !down {
            self.dead.remove(&node);
            return 0;
        }
        if !self.dead.insert(node) {
            return 0;
        }
        let mut doomed: Vec<BlockId> = self
            .meta
            .keys()
            .copied()
            .filter(|&b| self.cache_node(b).0 == node && self.cache.contains(b))
            .collect();
        doomed.sort_unstable_by_key(|b| b.0);
        let mut dropped = 0u64;
        for b in doomed {
            if self.cache.remove(b) {
                dropped += 1;
            }
        }
        self.dead_cache_drops += dropped;
        if let Some(obs) = &mut self.obs {
            // Keep the occupancy series truthful; node losses are not
            // policy evictions, so the cause counters stay untouched (the
            // injector's node_downs gauge carries the event itself).
            obs.resident = obs.resident.saturating_sub(dropped);
        }
        dropped
    }

    /// Cached blocks lost to node deaths so far.
    pub fn dead_cache_drops(&self) -> u64 {
        self.dead_cache_drops
    }
}

impl BlockService for DagBlockService<'_> {
    fn read_block(
        &mut self,
        block: BlockId,
        reader_node: DataNodeId,
        now: SimTime,
        req: &AccessRequest,
    ) -> BlockRead {
        // Liveness-aware replica view: replicas on dead nodes are
        // unreachable. With no scripted node faults `dead` is empty and
        // this reduces exactly to the pre-chaos computation.
        let (size, recompute_s, local_replica, any_live_replica, has_replicas) = {
            let m = self.meta.get(&block).expect("read of unregistered block");
            let live = |dn: &DataNodeId| !self.dead.contains(&dn.0);
            (
                m.size,
                m.recompute_s,
                m.replicas.iter().any(|dn| *dn == reader_node && live(dn)),
                m.replicas.iter().any(live),
                !m.replicas.is_empty(),
            )
        };
        let hit = self.access(block, now, req.affinity);
        let (source, service) = if hit {
            let src = if reader_node == self.cache_node(block) {
                ReadSource::CacheLocal
            } else {
                ReadSource::CacheRemote
            };
            (src, reader::service_time(self.cfg, src, size))
        } else if !has_replicas {
            // Cache-only intermediate evicted before this read — by the
            // replacement policy or with a dead cache node: the producing
            // stage's work is re-run — the full recompute cost lands on
            // the read's completion time (and the re-inserted block was
            // already handled by `access`).
            self.recompute_events += 1;
            self.recompute_seconds += recompute_s;
            let service = SimDuration::from_secs_f64(recompute_s);
            if let Some(obs) = &mut self.obs {
                obs.windows.at(now).recompute_cost_us += service.micros();
            }
            (ReadSource::DiskLocal, service)
        } else if !any_live_replica {
            // Disk-backed input whose every replica is on a dead node:
            // model the NameNode-driven re-replication fetch as a remote
            // disk read (the data still exists outside the dead set).
            (ReadSource::DiskRemote, reader::service_time(self.cfg, ReadSource::DiskRemote, size))
        } else {
            let src = if local_replica { ReadSource::DiskLocal } else { ReadSource::DiskRemote };
            (src, reader::service_time(self.cfg, src, size))
        };
        BlockRead { completion: now + service, source }
    }

    fn preferred_node(&self, block: BlockId) -> Option<DataNodeId> {
        if self.cache.contains(block) {
            Some(self.cache_node(block))
        } else {
            self.meta
                .get(&block)
                .and_then(|m| m.replicas.iter().find(|dn| !self.dead.contains(&dn.0)).copied())
        }
    }

    fn replica_nodes(&self, block: BlockId) -> Vec<DataNodeId> {
        self.meta
            .get(&block)
            .map(|m| {
                m.replicas
                    .iter()
                    .copied()
                    .filter(|dn| !self.dead.contains(&dn.0))
                    .collect()
            })
            .unwrap_or_default()
    }

    fn block_size(&self, block: BlockId) -> u64 {
        self.meta.get(&block).map(|m| m.size).unwrap_or(self.cfg.block_size)
    }
}

/// One classifier-less (or precomputed-classes) replay pass. Public so
/// tests and sweeps can replay without the training pass; most callers
/// want [`run_dag`].
pub fn run_dag_pass(
    policy: &str,
    cfg: &ClusterConfig,
    shards: usize,
    capacity: u64,
    jobs: &[DagJob],
    seed: u64,
    classes: &[Option<bool>],
) -> Result<(DagReport, Vec<BlockRequest>)> {
    let (report, log, _) =
        run_dag_pass_inner(policy, cfg, shards, capacity, jobs, seed, classes, None, None)?;
    Ok((report, log))
}

/// [`run_dag_pass`] under a chaos script: the plan's node down/up events
/// are applied at wave boundaries (cached copies die with their node,
/// replicas go dark), and the scheduler injects map-attempt failures from
/// the same seed. An all-clear plan with [`FailureModel::none`] is
/// bit-identical to [`run_dag_pass`].
#[allow(clippy::too_many_arguments)] // run_dag_pass's knobs + the chaos wiring
pub fn run_dag_pass_chaos(
    policy: &str,
    cfg: &ClusterConfig,
    shards: usize,
    capacity: u64,
    jobs: &[DagJob],
    seed: u64,
    classes: &[Option<bool>],
    chaos: &DagChaos<'_>,
) -> Result<(DagReport, Vec<BlockRequest>)> {
    let (report, log, _) = run_dag_pass_inner(
        policy,
        cfg,
        shards,
        capacity,
        jobs,
        seed,
        classes,
        None,
        Some(chaos),
    )?;
    Ok((report, log))
}

/// Classify-once DAG replay under a chaos script ([`run_dag`]'s chaos
/// twin): both passes replay under the same plan and failure model, so
/// the training log is index-aligned with the classified pass.
#[allow(clippy::too_many_arguments)] // run_dag's knobs + the chaos wiring
pub fn run_dag_chaos(
    policy: &str,
    cfg: &ClusterConfig,
    shards: usize,
    capacity: u64,
    jobs: &[DagJob],
    seed: u64,
    kernel: KernelKind,
    batch: usize,
    chaos: &DagChaos<'_>,
) -> Result<DagReport> {
    let (report_a, mut trace) =
        run_dag_pass_chaos(policy, cfg, shards, capacity, jobs, seed, &[], chaos)?;
    ground_truth_labels(&mut trace);
    let classes = classify_trace(&trace, kernel, batch)?;
    if classes.iter().all(|c| c.is_none()) {
        return Ok(report_a);
    }
    let (mut report, _) =
        run_dag_pass_chaos(policy, cfg, shards, capacity, jobs, seed, &classes, chaos)?;
    report.trained = true;
    Ok(report)
}

/// [`run_dag_pass`] with optional telemetry attached to the service; the
/// raw observation state comes back for [`run_dag_observed`]'s post-run
/// ground-truth fix-up.
#[allow(clippy::too_many_arguments, clippy::type_complexity)] // internal plumbing
fn run_dag_pass_inner(
    policy: &str,
    cfg: &ClusterConfig,
    shards: usize,
    capacity: u64,
    jobs: &[DagJob],
    seed: u64,
    classes: &[Option<bool>],
    observe: Option<(&MetricsRegistry, ObsConfig)>,
    chaos: Option<&DagChaos<'_>>,
) -> Result<(DagReport, Vec<BlockRequest>, Option<(WindowSeries, Vec<PendingEvict>)>)> {
    let cache = CacheBuilder::new()
        .policy(policy)
        .shards(shards.max(1))
        .capacity(capacity)
        .recency(cfg.recency_config())
        .build()
        .with_context(|| format!("building {shards}-shard {policy:?} cache"))?;
    let mut svc = DagBlockService::new(cfg, cache, classes.to_vec());
    if let Some((registry, obs_cfg)) = observe {
        svc.enable_obs(registry, obs_cfg);
    }

    // Replica placement for every disk-backed input, in deterministic
    // job/stage order under the seed.
    let mut placement = Placement::new(cfg.datanodes, cfg.replication, Pcg64::new(seed, 0xDA6));
    for job in jobs {
        for b in job.input_blocks() {
            svc.register_input(b, cfg.block_size, placement.place());
        }
    }

    let levels: Vec<Vec<usize>> = jobs.iter().map(|j| j.levels()).collect();
    let max_level = levels.iter().flat_map(|l| l.iter().copied()).max().unwrap_or(0);
    let mut scheduler = Scheduler::new(cfg);
    if let Some(c) = chaos {
        scheduler = scheduler.with_failures(c.failures);
    }
    // Scripted node transitions, applied at wave boundaries in (at, node)
    // order once the wave clock passes them.
    let node_events = chaos.map(|c| c.plan.node_events()).unwrap_or_default();
    let mut next_node_event = 0usize;

    let mut outputs: HashMap<(usize, usize), Vec<BlockId>> = HashMap::new();
    let mut stage_finish: HashMap<(usize, usize), SimTime> = HashMap::new();
    let mut next_output_block = OUTPUT_BLOCK_BASE;
    let mut next_spec_id = 0u64;
    let mut wave_start = SimTime::ZERO;

    for wave in 0..=max_level {
        // Apply every node transition the wave clock has passed. Wave
        // granularity keeps the replay deterministic: the event lands at
        // the same boundary no matter how the previous wave's attempts
        // interleaved.
        while next_node_event < node_events.len() && node_events[next_node_event].0 <= wave_start {
            let (_, node, down) = node_events[next_node_event];
            next_node_event += 1;
            svc.apply_node_event(node, down);
            if let Some(inj) = chaos.and_then(|c| c.injector) {
                inj.note_node_event(down);
            }
        }
        // Collect this wave's runnable stages across all jobs.
        let mut specs = Vec::new();
        let mut owners: Vec<(usize, usize)> = Vec::new();
        for (ji, job) in jobs.iter().enumerate() {
            for (si, stage) in job.stages.iter().enumerate() {
                if levels[ji][si] != wave {
                    continue;
                }
                // Fresh scans first, dependency outputs after — see
                // workload::dag::DagStage::input_blocks.
                let mut inputs = stage.input_blocks.clone();
                for &d in &stage.deps {
                    inputs.extend(
                        outputs.get(&(ji, d)).expect("dep ran in an earlier wave").iter(),
                    );
                }
                specs.push(stage.app.job(JobId(next_spec_id), inputs));
                next_spec_id += 1;
                owners.push((ji, si));
            }
        }
        if specs.is_empty() {
            continue;
        }

        let runs = scheduler.run_jobs(&specs, &mut svc, wave_start);
        let mut wave_end = wave_start;
        for r in &runs {
            wave_end = wave_end.max(r.finish);
        }

        // Materialize consumed stages' outputs at the wave boundary.
        for (run, &(ji, si)) in runs.iter().zip(&owners) {
            stage_finish.insert((ji, si), run.finish);
            if !jobs[ji].has_consumer(si) {
                continue; // sink output goes to HDFS, not the cache
            }
            let app = jobs[ji].stages[si].app;
            let in_bytes: u64 = run.spec.input_blocks.iter().map(|&b| svc.block_size(b)).sum();
            let out_bytes = dag::stage_output_bytes(app, in_bytes);
            let n_out = ((out_bytes + cfg.block_size - 1) / cfg.block_size).max(1);
            let per_block = (out_bytes / n_out).max(1);
            let cost_per_block = dag::stage_recompute_cost_s(app, in_bytes) / n_out as f64;
            let file = OUTPUT_BLOCK_BASE + (ji as u64) * 1000 + si as u64;
            let mut blocks = Vec::with_capacity(n_out as usize);
            for _ in 0..n_out {
                let b = BlockId(next_output_block);
                next_output_block += 1;
                svc.register_output(b, per_block, cost_per_block, file);
                blocks.push(b);
            }
            for &b in &blocks {
                svc.access(b, wave_end, app.affinity());
            }
            outputs.insert((ji, si), blocks);
        }
        wave_start = wave_end;
    }

    let mut total_job_time_s = 0.0;
    for (ji, job) in jobs.iter().enumerate() {
        let finish = job
            .sinks()
            .iter()
            .map(|&s| stage_finish[&(ji, s)])
            .max()
            .expect("job without sinks");
        total_job_time_s += finish.as_secs_f64();
    }

    let (recompute_events, recompute_seconds) = svc.recompute_charges();
    let report = DagReport {
        policy: policy.to_string(),
        shards,
        capacity,
        n_jobs: jobs.len(),
        total_job_time_s,
        makespan_s: wave_start.as_secs_f64(),
        stats: svc.cache.stats(),
        recompute_events,
        recompute_seconds,
        accesses: svc.log.len(),
        trained: false,
    };
    let obs = svc.take_obs();
    Ok((report, svc.log, obs))
}

/// Fill ground-truth reuse labels into a pass log: an access is
/// "reused later" iff its block appears again later in the log.
pub fn ground_truth_labels(trace: &mut [BlockRequest]) {
    let mut seen: HashSet<BlockId> = HashSet::new();
    for req in trace.iter_mut().rev() {
        req.reused_later = seen.contains(&req.block);
        seen.insert(req.block);
    }
}

/// Full classify-once DAG replay: pass A records the access log, the SMO
/// fallback trains on its ground-truth labels, pass B replays with one
/// prediction per access. Single-class logs (classifier untrainable)
/// return the pass-A result unchanged — prediction-less, exactly how
/// prediction-blind policies run either way.
pub fn run_dag(
    policy: &str,
    cfg: &ClusterConfig,
    shards: usize,
    capacity: u64,
    jobs: &[DagJob],
    seed: u64,
    kernel: KernelKind,
    batch: usize,
) -> Result<DagReport> {
    let (report_a, mut trace) = run_dag_pass(policy, cfg, shards, capacity, jobs, seed, &[])?;
    ground_truth_labels(&mut trace);
    let classes = classify_trace(&trace, kernel, batch)?;
    if classes.iter().all(|c| c.is_none()) {
        return Ok(report_a);
    }
    let (mut report, _) = run_dag_pass(policy, cfg, shards, capacity, jobs, seed, &classes)?;
    report.trained = true;
    Ok(report)
}

/// [`run_dag`] with the telemetry layer on the *final* arm (pass B when
/// the classifier trains, the prediction-less replay otherwise): windowed
/// hit/eviction/recompute series, eviction scan-work histogram, and the
/// sampled audit ring with real decision scores.
///
/// The audit's ground truth needs the complete pass log, so evictions are
/// collected as [`PendingEvict`]s mid-replay and resolved here once
/// [`ground_truth_labels`] has labeled the observed pass's own log —
/// `reused_later` of the victim's last access is exactly "was it
/// requested again after this eviction". Everything recorded is keyed on
/// simulated time, so same-(seed, shards) runs produce identical series.
#[allow(clippy::too_many_arguments)] // run_dag's knobs + the telemetry pair
pub fn run_dag_observed(
    policy: &str,
    cfg: &ClusterConfig,
    shards: usize,
    capacity: u64,
    jobs: &[DagJob],
    seed: u64,
    kernel: KernelKind,
    batch: usize,
    registry: &MetricsRegistry,
    obs_cfg: ObsConfig,
) -> Result<(DagReport, RunObservations)> {
    // Pass A (unobserved) exists only to produce the labeled training log.
    let (_, mut trace) = run_dag_pass(policy, cfg, shards, capacity, jobs, seed, &[])?;
    ground_truth_labels(&mut trace);
    let (features, scores) = classify_trace_scored(&trace, kernel, batch)?;
    let trained = scores.iter().any(|s| s.is_some());
    let classes: Vec<Option<bool>> = scores.iter().map(|s| s.map(|v| v > 0.0)).collect();
    let used: &[Option<bool>] = if trained { &classes } else { &[] };
    let (mut report, log, obs_raw) = run_dag_pass_inner(
        policy,
        cfg,
        shards,
        capacity,
        jobs,
        seed,
        used,
        Some((registry, obs_cfg)),
        None,
    )?;
    report.trained = trained;
    let (mut windows, pending) = obs_raw.expect("observed pass returns its state");

    // The scheduler's access order is timing-independent, so the observed
    // log is index-aligned with the training log (and with `scores`) —
    // label it to resolve each pending eviction's eventual reuse.
    let mut labeled = log;
    ground_truth_labels(&mut labeled);
    let mut audit = EvictionAudit::new(obs_cfg.audit_every, obs_cfg.audit_cap);
    for p in &pending {
        let actual = labeled[p.log_idx].reused_later;
        let predicted = if trained {
            scores.get(p.log_idx).copied().flatten().map(|v| v > 0.0)
        } else {
            None
        };
        // Re-opening a past window yields a fresh accumulator; the
        // merge_series rollup below folds it into the original by index.
        let win = windows.at(p.at);
        match predicted {
            Some(true) if actual => win.tp += 1,
            Some(true) => win.fp += 1,
            Some(false) if actual => win.fn_ += 1,
            Some(false) => win.tn += 1,
            None => {}
        }
        audit.observe(|| AuditEntry {
            at: p.at,
            block: p.block,
            cause: p.cause,
            features: features.get(p.log_idx).copied().unwrap_or_default(),
            score: scores.get(p.log_idx).copied().flatten().unwrap_or(0.0),
            predicted,
            actual,
        });
    }

    // End-of-run recompute totals, readable at export time (simulated-time
    // quantities: deterministic under the seed).
    let events = report.recompute_events;
    let charged_us = SimDuration::from_secs_f64(report.recompute_seconds).micros();
    registry.gauge("dag.recompute_events", move || events);
    registry.gauge("dag.recompute_us", move || charged_us);

    let (audit_entries, audit_seen) = merge_audits(vec![audit]);
    Ok((
        report,
        RunObservations {
            windows: merge_series(vec![windows.finish()]),
            audit: audit_entries,
            audit_seen,
            audit_every: obs_cfg.audit_every.max(1),
        },
    ))
}

/// Render a sweep of DAG reports as an aligned table (one row per run).
pub fn render(reports: &[DagReport]) -> crate::util::table::Table {
    use crate::util::bytes::MB;
    let mut t = crate::util::table::Table::new(vec![
        "policy",
        "cache MB",
        "jobs",
        "hit ratio",
        "recomputes",
        "recompute s",
        "job time s",
        "makespan s",
        "trained",
    ]);
    for r in reports {
        t.add_row(vec![
            r.policy.clone(),
            format!("{}", r.capacity / MB),
            format!("{}", r.n_jobs),
            format!("{:.4}", r.stats.hit_ratio()),
            format!("{}", r.recompute_events),
            format!("{:.1}", r.recompute_seconds),
            format!("{:.1}", r.total_job_time_s),
            format!("{:.1}", r.makespan_s),
            if r.trained { "yes" } else { "no" }.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::{GB, MB};
    use crate::workload::dag::{chain_suite, diamond_suite};

    fn small_cfg() -> ClusterConfig {
        ClusterConfig { datanodes: 5, replication: 2, ..Default::default() }
    }

    #[test]
    fn infinite_cache_never_recomputes() {
        let cfg = small_cfg();
        let jobs = diamond_suite(2, 2, 4);
        let (report, log) =
            run_dag_pass("lru", &cfg, 1, 1024 * GB, &jobs, 7, &[]).unwrap();
        assert_eq!(report.recompute_events, 0);
        assert_eq!(report.n_jobs, 2);
        assert!(report.total_job_time_s > 0.0);
        assert!(report.makespan_s > 0.0);
        assert!(!log.is_empty());
        assert_eq!(report.accesses, log.len());
        // Every job's time is bounded by the makespan.
        assert!(report.total_job_time_s <= report.makespan_s * report.n_jobs as f64 + 1e-9);
    }

    #[test]
    fn tight_cache_charges_recomputes_and_costs_time() {
        let cfg = small_cfg();
        let jobs = diamond_suite(2, 3, 10);
        let (infinite, _) =
            run_dag_pass("lru", &cfg, 1, 1024 * GB, &jobs, 7, &[]).unwrap();
        let (tight, _) =
            run_dag_pass("lru", &cfg, 1, 6 * cfg.block_size, &jobs, 7, &[]).unwrap();
        assert!(tight.recompute_events > 0, "tight cache must evict intermediates");
        assert!(tight.recompute_seconds > 0.0);
        assert!(
            tight.total_job_time_s > infinite.total_job_time_s,
            "recompute charges must cost job time: tight {} vs infinite {}",
            tight.total_job_time_s,
            infinite.total_job_time_s
        );
    }

    #[test]
    fn labels_mark_rereads() {
        let mut trace = vec![
            BlockRequest {
                time: SimTime(0),
                block: BlockId(1),
                size: MB,
                kind: BlockKind::Input,
                affinity: CacheAffinity::Medium,
                reused_later: false,
                recompute_cost: 0.0,
            };
            3
        ];
        trace[1].block = BlockId(2);
        ground_truth_labels(&mut trace);
        assert!(trace[0].reused_later, "block 1 reappears at index 2");
        assert!(!trace[1].reused_later);
        assert!(!trace[2].reused_later);
    }

    #[test]
    fn classified_run_trains_on_two_class_log() {
        let cfg = small_cfg();
        let jobs = diamond_suite(1, 2, 4);
        let report = run_dag(
            "h-svm-lru",
            &cfg,
            2,
            8 * cfg.block_size,
            &jobs,
            7,
            KernelKind::Rbf,
            64,
        )
        .unwrap();
        assert!(report.trained, "diamond log has both classes");
        assert!(report.stats.requests > 0);
    }

    /// Observed DAG replay: parity with [`run_dag`], window sums matching
    /// the merged counters, recompute charges landing in the series, and
    /// a resolved (ground-truthed) audit ring.
    #[test]
    fn observed_dag_matches_run_dag_and_charges_windows() {
        let cfg = small_cfg();
        let jobs = diamond_suite(2, 3, 10);
        let plain = run_dag(
            "h-svm-lru",
            &cfg,
            2,
            6 * cfg.block_size,
            &jobs,
            7,
            KernelKind::Rbf,
            64,
        )
        .unwrap();
        let registry = MetricsRegistry::new();
        let (report, obs) = run_dag_observed(
            "h-svm-lru",
            &cfg,
            2,
            6 * cfg.block_size,
            &jobs,
            7,
            KernelKind::Rbf,
            64,
            &registry,
            ObsConfig { audit_every: 1, ..ObsConfig::default() },
        )
        .unwrap();
        assert_eq!(report.stats, plain.stats, "observation must not perturb the replay");
        assert_eq!(report.recompute_events, plain.recompute_events);
        assert_eq!(report.trained, plain.trained);

        let requests: u64 = obs.windows.iter().map(|(_, w)| w.requests).sum();
        let evictions: u64 = obs.windows.iter().map(|(_, w)| w.evictions()).sum();
        let recompute_us: u64 = obs.windows.iter().map(|(_, w)| w.recompute_cost_us).sum();
        assert_eq!(requests, report.stats.requests);
        assert_eq!(evictions, report.stats.evictions);
        assert!(report.recompute_events > 0, "tight cache must recompute");
        assert!(recompute_us > 0, "recompute charges must land in windows");
        assert!(obs.windows.windows(2).all(|p| p[0].0 < p[1].0), "sorted series");

        // Every eviction whose victim had been accessed is audited
        // (audit_every=1) with resolved ground truth, up to ring capacity.
        assert_eq!(
            obs.audit.len() as u64,
            obs.audit_seen.min(crate::obs::DEFAULT_AUDIT_CAP as u64)
        );
        assert!(!obs.audit.is_empty());
        let labeled: u64 = obs.windows.iter().map(|(_, w)| w.labeled_evictions()).sum();
        assert!(labeled <= evictions);
        if report.trained {
            assert!(labeled > 0, "trained replay must label evictions");
        }

        // The gauges expose the recompute totals the report carries.
        let gauges = registry.gauge_values();
        assert!(gauges
            .iter()
            .any(|(n, v)| n == "dag.recompute_events" && *v == report.recompute_events));
    }

    #[test]
    fn all_clear_chaos_is_bit_identical_to_plain_pass() {
        let cfg = small_cfg();
        let jobs = diamond_suite(2, 3, 10);
        let plan = FaultPlan::all_clear(7);
        let chaos = DagChaos { plan: &plan, injector: None, failures: FailureModel::none() };
        let (plain, plain_log) =
            run_dag_pass("h-svm-lru", &cfg, 2, 6 * cfg.block_size, &jobs, 7, &[]).unwrap();
        let (under, under_log) =
            run_dag_pass_chaos("h-svm-lru", &cfg, 2, 6 * cfg.block_size, &jobs, 7, &[], &chaos)
                .unwrap();
        assert_eq!(plain.stats, under.stats);
        assert_eq!(plain.recompute_events, under.recompute_events);
        assert_eq!(plain.total_job_time_s, under.total_job_time_s);
        assert_eq!(plain.makespan_s, under.makespan_s);
        assert_eq!(format!("{plain_log:?}"), format!("{under_log:?}"), "identical access logs");
    }

    #[test]
    fn node_death_drops_replicas_and_costs_time() {
        use crate::sim::FaultEvent;
        let cfg = small_cfg();
        let jobs = diamond_suite(2, 3, 10);
        let capacity = 64 * cfg.block_size;
        let (baseline, _) = run_dag_pass("lru", &cfg, 2, capacity, &jobs, 7, &[]).unwrap();
        // Kill two nodes at t=0 (applied at the very first wave boundary:
        // the event clock is `at <= wave_start`, and wave 0 starts at
        // SimTime::ZERO) so input replicas on them go dark for the whole
        // replay and every intermediate cached on them is dropped.
        let plan = FaultPlan::all_clear(7)
            .with_event(FaultEvent::NodeDown { node: 0, at: SimTime::ZERO })
            .with_event(FaultEvent::NodeDown { node: 1, at: SimTime::ZERO });
        let injector = FaultInjector::new(plan.clone());
        let chaos =
            DagChaos { plan: &plan, injector: Some(&injector), failures: FailureModel::none() };
        let (under, _) =
            run_dag_pass_chaos("lru", &cfg, 2, capacity, &jobs, 7, &[], &chaos).unwrap();
        assert_eq!(injector.node_downs(), 2, "both deaths applied at a wave boundary");
        assert!(
            under.total_job_time_s >= baseline.total_job_time_s,
            "dead nodes cannot make jobs faster: {} vs {}",
            under.total_job_time_s,
            baseline.total_job_time_s
        );
        // The same chaos pass replays bit-identically (shared seed).
        let (again, _) =
            run_dag_pass_chaos("lru", &cfg, 2, capacity, &jobs, 7, &[], &chaos).unwrap();
        assert_eq!(under.stats, again.stats);
        assert_eq!(under.recompute_events, again.recompute_events);
        assert_eq!(under.total_job_time_s, again.total_job_time_s);
    }

    #[test]
    fn scheduler_failures_share_the_plan_seed_and_stay_deterministic() {
        let cfg = small_cfg();
        let jobs = diamond_suite(2, 3, 10);
        let plan = FaultPlan::all_clear(0xFA11);
        let failures = FailureModel::with_rates(0.35, 0.1, plan.seed());
        let chaos = DagChaos { plan: &plan, injector: None, failures };
        let (a, _) =
            run_dag_pass_chaos("lru", &cfg, 1, 8 * cfg.block_size, &jobs, 3, &[], &chaos).unwrap();
        let (b, _) =
            run_dag_pass_chaos("lru", &cfg, 1, 8 * cfg.block_size, &jobs, 3, &[], &chaos).unwrap();
        assert_eq!(a.stats, b.stats);
        assert_eq!(a.total_job_time_s, b.total_job_time_s);
        let (clean, _) =
            run_dag_pass("lru", &cfg, 1, 8 * cfg.block_size, &jobs, 3, &[]).unwrap();
        assert!(
            a.total_job_time_s > clean.total_job_time_s,
            "injected attempt failures must cost time: {} vs {}",
            a.total_job_time_s,
            clean.total_job_time_s
        );
    }

    #[test]
    fn chain_replay_runs_every_stage() {
        let cfg = small_cfg();
        let jobs = chain_suite(2, 3);
        let (report, log) =
            run_dag_pass("lfu-cost", &cfg, 2, 8 * cfg.block_size, &jobs, 11, &[]).unwrap();
        // 2 jobs x 3 stages: sources read 3 inputs each; later stages read
        // materialized outputs; every access was logged.
        assert!(report.accesses >= 2 * 3 + 2);
        assert_eq!(report.accesses, log.len());
        assert!(report.total_job_time_s > 0.0);
    }
}
