//! Chaos replay: the serving stack under a scripted [`FaultPlan`] — the
//! `repro chaos` driver.
//!
//! Three arms, all on the simulated clock so every run is seeded and
//! byte-reproducible:
//!
//! * **Serving** ([`run_serving_chaos`]) — the frozen shard-parallel
//!   replay of [`super::online_sharded`] with every worker's
//!   [`SnapshotBackend`] wrapped in a [`FaultyBackend`]: scripted backend
//!   outages surface as prediction errors, the per-shard circuit breaker
//!   ([`BreakerConfig`]) absorbs them, and the windowed series splits the
//!   run into pre/outage/post phases to measure the degradation gap and
//!   the recovery lag. The headline invariant: with the breaker open,
//!   H-SVM-LRU degrades to the *unclassified* cold path — plain-LRU
//!   placement — so its hit ratio stays within a bounded gap of an LRU
//!   run under the identical plan, and recovers once the probe closes the
//!   breaker.
//! * **Trainer** ([`run_trainer_chaos`]) — the online arm with
//!   [`trainer_loop_resilient`]: scripted trainer crashes lose the sample
//!   buffer but never the published snapshot; workers keep serving the
//!   last model while the trainer restarts.
//! * **DAG** — [`super::dag_replay::run_dag_chaos`] (re-exported through
//!   [`super::super::experiments`]): node death at wave boundaries +
//!   seeded map-attempt failures from the same plan seed.
//!
//! An all-clear plan with the breaker disabled is bit-identical to the
//! fault-free frozen replay — property-tested in
//! rust/tests/property_faults.rs and smoke-checked by `repro chaos
//! --smoke` in CI.

use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::cache::sharded::{shard_of, ShardStats, ShardedCache};
use crate::cache::{AccessContext, CacheBuilder, RecencyConfig};
use crate::coordinator::batcher::{BatcherConfig, BatcherProbe, BreakerConfig, ShardBatcher};
use crate::coordinator::online::{
    sample_channel, trainer_loop_resilient, SampleSender, SnapshotBackend, SnapshotCell,
    TrainerConfig, TrainerReport,
};
use crate::coordinator::TrainingPipeline;
use crate::obs::{merge_series, MetricsRegistry, WindowAccum, WindowSeries};
use crate::runtime::{RustBackend, SvmBackend};
use crate::sim::parallel::{run_fanout, FanoutOptions, FanoutReport};
use crate::sim::{FaultEvent, FaultInjector, FaultPlan, FaultWindow, FaultyBackend, SimDuration};
use crate::svm::features::BlockStatsTracker;
use crate::svm::KernelKind;
use crate::util::table::{fmt_f, Table};
use crate::workload::BlockRequest;

use super::online_sharded::{pretrain_model, SAMPLE_CHANNEL_BOUND};

/// Recovery criterion: the first post-outage window whose hit ratio is
/// back within this absolute gap of the pre-outage hit ratio counts as
/// recovered.
pub const RECOVERY_GAP: f64 = 0.10;

/// Cache construction of both chaos arms: registry policy, no admission,
/// the caller's recency batching (the serving arm threads its `recency`
/// knob here; the trainer arm faults the classifier path only, so its
/// cache front stays at the behavior-preserving default).
fn chaos_cache(
    policy: &str,
    shards: usize,
    capacity: u64,
    recency: RecencyConfig,
) -> Result<ShardedCache> {
    CacheBuilder::new()
        .policy(policy)
        .shards(shards.max(1))
        .capacity(capacity)
        .recency(recency)
        .build()
        .with_context(|| format!("building {shards}-shard {policy:?} cache"))
}

/// The default chaos script for a serving replay over `trace`: one
/// classifier outage across 30–55% of the trace's simulated span and one
/// latency spike (500 simulated µs per call) across 60–70%.
pub fn default_serving_plan(trace: &[BlockRequest], seed: u64) -> FaultPlan {
    let span = trace.last().map(|r| r.time.micros()).unwrap_or(0).max(1);
    let at = |f: f64| crate::sim::SimTime((span as f64 * f) as u64);
    FaultPlan::all_clear(seed)
        .with_event(FaultEvent::BackendOutage(FaultWindow::new(at(0.30), at(0.55))))
        .with_event(FaultEvent::BackendSlow {
            window: FaultWindow::new(at(0.60), at(0.70)),
            extra: SimDuration::from_micros(500),
        })
}

/// A breaker tuned to the trace's simulated span: default thresholds,
/// probe cadence at 1/50th of the span so an outage ending mid-trace
/// leaves room for several probes before the replay ends.
pub fn breaker_for_trace(trace: &[BlockRequest]) -> BreakerConfig {
    let span = trace.last().map(|r| r.time.micros()).unwrap_or(0);
    BreakerConfig {
        probe_after: SimDuration::from_micros((span / 50).max(1)),
        ..BreakerConfig::on()
    }
}

/// What one serving-arm chaos replay measured.
#[derive(Debug, Clone)]
pub struct ServingChaosReport {
    /// Replacement policy replayed (registry name).
    pub policy: String,
    /// Shard count of the cache.
    pub shards: usize,
    /// Merged cache counters of the whole replay.
    pub stats: ShardStats,
    /// Windowed request/hit series (merged over shards, sorted by index).
    pub windows: Vec<(u64, WindowAccum)>,
    /// Window width used for the series and the phase split, micros.
    pub window_us: u64,
    /// Breaker transitions to Open across all shard batchers.
    pub breaker_opens: u64,
    /// Breaker transitions back to Closed.
    pub breaker_closes: u64,
    /// Cold queries answered by open-breaker fallback (unclassified).
    pub breaker_fallbacks: u64,
    /// Bounded backend retries spent inside flushes.
    pub retries: u64,
    /// Pending queries dropped (failed flushes + end-of-run strandings).
    pub dropped: u64,
    /// Backend calls failed by injection (the injector's tally).
    pub backend_failures: u64,
    /// The plan's first scripted outage window, if any — the phase split
    /// below is relative to it.
    pub outage: Option<FaultWindow>,
    /// Hit ratio of the windows strictly before the outage.
    pub pre_hit: f64,
    /// Hit ratio of the windows overlapping the outage.
    pub outage_hit: f64,
    /// Hit ratio of the windows strictly after the outage.
    pub post_hit: f64,
    /// Windows after the outage until the hit ratio returned to within
    /// [`RECOVERY_GAP`] of `pre_hit` (`None`: never recovered, or no
    /// outage scripted).
    pub recovered_after_windows: Option<u64>,
}

impl ServingChaosReport {
    /// Whole-replay hit ratio.
    pub fn hit_ratio(&self) -> f64 {
        self.stats.hit_ratio()
    }
}

fn phase_hit(windows: &[(u64, WindowAccum)], mut keep: impl FnMut(u64) -> bool) -> f64 {
    let (mut hits, mut requests) = (0u64, 0u64);
    for (idx, w) in windows {
        if keep(*idx) {
            hits += w.hits;
            requests += w.requests;
        }
    }
    if requests == 0 {
        0.0
    } else {
        hits as f64 / requests as f64
    }
}

/// Replay `trace` frozen (one pretrained snapshot) on a `shards`-way
/// cache of `policy`, with every worker's backend wrapped under
/// `injector`'s plan and the given circuit breaker on each shard's cold
/// path. Phase metrics are split around the plan's first outage window.
///
/// With an all-clear plan and the breaker disabled this is bit-identical
/// to the fault-free frozen replay ([`super::online_sharded::run_online`]).
/// `recency` sets the cache's lock-free hit batching: merged hit/miss
/// totals are exact for any batch (hits count at read time), so a chaos
/// replay under buffered recency reports the same stats as the immediate
/// one — property-tested in rust/tests/property_read_path.rs.
#[allow(clippy::too_many_arguments)] // the chaos replay's full knob surface
pub fn run_serving_chaos(
    policy: &str,
    shards: usize,
    capacity: u64,
    trace: &[BlockRequest],
    kernel: KernelKind,
    breaker: BreakerConfig,
    injector: &FaultInjector,
    registry: &MetricsRegistry,
    window_us: u64,
    recency: RecencyConfig,
) -> Result<ServingChaosReport> {
    let model = pretrain_model(trace, kernel)?
        .context("chaos serving arm needs a two-class trace to pretrain the classifier")?;
    let cache = chaos_cache(policy, shards, capacity, recency)?;
    let n = cache.n_shards();
    let mut partitions: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, req) in trace.iter().enumerate() {
        partitions[shard_of(req.block, n)].push(i);
    }
    let block_size = trace.iter().map(|r| r.size).max().unwrap_or(1);
    let cell = Arc::new(SnapshotCell::new());
    cell.publish(model);

    let batch_probe = BatcherProbe::new();
    batch_probe.register_gauges(registry, "batcher");
    batch_probe.register_breaker_gauges(registry, "batcher");
    let batcher_cfg = BatcherConfig { breaker, ..BatcherConfig::default() };

    let worker = |w: usize| {
        let mut tracker = BlockStatsTracker::new(block_size);
        // The fault-injected prediction front: same per-shard batcher and
        // snapshot view as the online replay, with the injector deciding
        // each backend call's fate at the current request time. Injected
        // failures trip this shard's breaker; open-breaker queries fall
        // back to unclassified (plain-LRU placement).
        let mut backend =
            FaultyBackend::new(SnapshotBackend::new(Arc::clone(&cell)), injector.clone());
        let mut shard_batcher = ShardBatcher::with_probe(batcher_cfg, batch_probe.clone());
        let mut windows = WindowSeries::new(window_us);
        // Lock-free hit front: membership resolves against the shard's
        // read view, recency updates drain in batches per `recency`.
        let mut handle = cache.read_handle();
        for &i in &partitions[w] {
            let req = &trace[i];
            let features = tracker.features(
                req.block,
                req.kind,
                req.size,
                req.affinity,
                req.recompute_cost,
                req.time,
            );
            backend.set_now(req.time);
            shard_batcher.note_model_version(backend.inner_mut().version());
            let predicted = if backend.is_trained() {
                let stamp = tracker.accesses(req.block);
                shard_batcher
                    .predict(&mut backend, req.block, stamp, features, req.time)
                    .unwrap_or_default()
            } else {
                None
            };
            let ctx = AccessContext {
                time: req.time,
                size: req.size,
                kind: req.kind,
                file: req.block.0, // trace blocks are their own files
                file_width: 1,
                file_complete: false,
                affinity: req.affinity,
                predicted_reuse: predicted,
                recompute_cost: req.recompute_cost,
            };
            let outcome = handle.access_or_insert(req.block, &ctx);
            tracker.record_access(req.block, 0, req.time);
            let win = windows.at(req.time);
            win.requests += 1;
            win.hits += u64::from(outcome.hit);
            win.insertions += u64::from(outcome.inserted);
        }
        // Drain: with an open breaker the end-of-run flush drops the
        // stranded queue and accounts it, keeping the conservation
        // invariant cold == flushed + dropped.
        let _ = shard_batcher.flush(&mut backend);
        // Flush buffered recency before reading this shard's final state.
        drop(handle);
        (cache.stats_of(w), windows.finish())
    };
    let per_worker = run_fanout(n, worker, FanoutOptions::new()).into_workers();

    let mut stats = ShardStats::default();
    let mut window_parts = Vec::with_capacity(per_worker.len());
    for (shard_stats, windows) in per_worker {
        stats.merge(&shard_stats);
        window_parts.push(windows);
    }
    let windows = merge_series(window_parts);

    // Phase split around the first scripted outage: `pre` is the healthy
    // baseline, `outage` the degraded plateau, `post` the recovery.
    let outage = injector.plan().outage_windows().first().copied();
    let (mut pre_hit, mut outage_hit, mut post_hit) = (0.0, 0.0, 0.0);
    let mut recovered_after_windows = None;
    if let Some(o) = outage {
        let start_idx = o.start.micros() / window_us;
        let end_idx = o.end.micros() / window_us;
        pre_hit = phase_hit(&windows, |idx| idx < start_idx);
        outage_hit = phase_hit(&windows, |idx| (start_idx..=end_idx).contains(&idx));
        post_hit = phase_hit(&windows, |idx| idx > end_idx);
        recovered_after_windows = windows
            .iter()
            .filter(|(idx, w)| *idx > end_idx && w.requests > 0)
            .find(|(_, w)| w.hit_ratio() >= pre_hit - RECOVERY_GAP)
            .map(|(idx, _)| idx - end_idx);
    } else {
        pre_hit = stats.hit_ratio();
    }

    Ok(ServingChaosReport {
        policy: policy.to_string(),
        shards: n,
        stats,
        windows,
        window_us,
        breaker_opens: batch_probe.breaker_opens(),
        breaker_closes: batch_probe.breaker_closes(),
        breaker_fallbacks: batch_probe.breaker_fallbacks(),
        retries: batch_probe.retries(),
        dropped: batch_probe.dropped(),
        backend_failures: injector.backend_failures(),
        outage,
        pre_hit,
        outage_hit,
        post_hit,
        recovered_after_windows,
    })
}

/// What one trainer-arm chaos replay measured.
#[derive(Debug, Clone)]
pub struct TrainerChaosReport {
    /// Merged cache counters of the replay.
    pub stats: ShardStats,
    /// What the resilient trainer did (restarts, train errors, staleness).
    pub trainer: TrainerReport,
    /// Samples accepted into the channel across all workers.
    pub samples_sent: u64,
    /// Samples dropped because the trainer fell behind.
    pub samples_dropped: u64,
}

/// The online replay of [`super::online_sharded`] with the crash-surviving
/// [`trainer_loop_resilient`] as the background trainer: scripted
/// [`FaultEvent::TrainerCrash`] points lose the in-flight sample buffer
/// (the pipeline resets) while workers keep serving the last published
/// snapshot. End-of-run trainer facts land in `registry` as
/// `trainer.restarts`, `trainer.train_errors` and
/// `trainer.stale_snapshot_age` (samples consumed after the last publish).
#[allow(clippy::too_many_arguments)] // mirrors run_online's knob surface
pub fn run_trainer_chaos(
    policy: &str,
    shards: usize,
    capacity: u64,
    trace: &[BlockRequest],
    kernel: KernelKind,
    cfg: TrainerConfig,
    injector: &FaultInjector,
    registry: &MetricsRegistry,
) -> Result<TrainerChaosReport> {
    let cache = chaos_cache(policy, shards, capacity, RecencyConfig::default())?;
    let n = cache.n_shards();
    let mut partitions: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, req) in trace.iter().enumerate() {
        partitions[shard_of(req.block, n)].push(i);
    }
    let block_size = trace.iter().map(|r| r.size).max().unwrap_or(1);
    let cell = Arc::new(SnapshotCell::new());
    let (sender, rx) = sample_channel(SAMPLE_CHANNEL_BOUND);
    let probe = sender.probe();
    let master: Mutex<Option<SampleSender>> = Mutex::new(Some(sender));
    let batch_probe = BatcherProbe::new();

    let worker = |w: usize| {
        let tx = master.lock().expect("sender mutex poisoned").as_ref().cloned();
        let mut tracker = BlockStatsTracker::new(block_size);
        let mut backend = SnapshotBackend::new(Arc::clone(&cell));
        let mut shard_batcher =
            ShardBatcher::with_probe(BatcherConfig::default(), batch_probe.clone());
        for &i in &partitions[w] {
            let req = &trace[i];
            let features = tracker.features(
                req.block,
                req.kind,
                req.size,
                req.affinity,
                req.recompute_cost,
                req.time,
            );
            if let Some(tx) = &tx {
                tx.emit(features, req.reused_later);
            }
            shard_batcher.note_model_version(backend.version());
            let predicted = if backend.is_trained() {
                let stamp = tracker.accesses(req.block);
                shard_batcher
                    .predict(&mut backend, req.block, stamp, features, req.time)
                    .unwrap_or_default()
            } else {
                None
            };
            let ctx = AccessContext {
                time: req.time,
                size: req.size,
                kind: req.kind,
                file: req.block.0, // trace blocks are their own files
                file_width: 1,
                file_complete: false,
                affinity: req.affinity,
                predicted_reuse: predicted,
                recompute_cost: req.recompute_cost,
            };
            cache.access_or_insert(req.block, &ctx);
            tracker.record_access(req.block, 0, req.time);
        }
        if backend.is_trained() {
            let _ = shard_batcher.flush(&mut backend);
        }
        cache.stats_of(w)
    };

    let trainer_cell = Arc::clone(&cell);
    let trainer_injector = injector.clone();
    let FanoutReport { workers, background, .. } = run_fanout(
        n,
        worker,
        FanoutOptions::new()
            .background(
                move || {
                    let mut backend = RustBackend::new(kernel);
                    let mut pipeline =
                        TrainingPipeline::new(cfg.min_samples, cfg.retrain_interval);
                    trainer_loop_resilient(
                        rx,
                        &mut backend,
                        &mut pipeline,
                        &trainer_cell,
                        Some(&trainer_injector),
                    )
                },
                || {
                    master.lock().expect("sender mutex poisoned").take();
                },
            ),
    );
    let trainer = background
        .expect("background configured")
        .context("resilient background trainer failed")?;

    let mut stats = ShardStats::default();
    for shard_stats in workers {
        let shard_stats = shard_stats.expect("panicked worker in a non-resilient run");
        stats.merge(&shard_stats);
    }
    // End-of-run trainer facts, readable at export time. The staleness
    // gauge is in samples: how far behind the published snapshot the
    // trainer's consumed stream ended up.
    let (restarts, train_errors, stale) =
        (trainer.restarts, trainer.train_errors, trainer.stale_samples);
    registry.gauge("trainer.restarts", move || restarts);
    registry.gauge("trainer.train_errors", move || train_errors);
    registry.gauge("trainer.stale_snapshot_age", move || stale);

    Ok(TrainerChaosReport {
        stats,
        trainer,
        samples_sent: probe.sent(),
        samples_dropped: probe.dropped(),
    })
}

/// Render serving-arm chaos reports as a table (the `repro chaos` output).
pub fn render(reports: &[ServingChaosReport]) -> Table {
    let mut t = Table::new(vec![
        "policy",
        "shards",
        "hit ratio",
        "pre",
        "outage",
        "post",
        "recovered (w)",
        "opens",
        "closes",
        "fallbacks",
        "retries",
        "dropped",
        "inj fails",
    ]);
    for r in reports {
        t.add_row(vec![
            r.policy.clone(),
            r.shards.to_string(),
            fmt_f(r.hit_ratio(), 4),
            fmt_f(r.pre_hit, 4),
            fmt_f(r.outage_hit, 4),
            fmt_f(r.post_hit, 4),
            r.recovered_after_windows.map_or_else(|| "-".to_string(), |w| w.to_string()),
            r.breaker_opens.to_string(),
            r.breaker_closes.to_string(),
            r.breaker_fallbacks.to_string(),
            r.retries.to_string(),
            r.dropped.to_string(),
            r.backend_failures.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::online::TrainerConfig;
    use crate::experiments::online_sharded::{run_online, TrainerMode};
    use crate::obs::DEFAULT_WINDOW_US;
    use crate::util::bytes::MB;
    use crate::workload::fig3_trace;

    const BLOCK: u64 = 64 * MB;

    #[test]
    fn all_clear_breaker_off_matches_fault_free_frozen_replay() {
        let trace = fig3_trace(BLOCK, 5);
        for shards in [1usize, 4] {
            let baseline = run_online(
                "h-svm-lru",
                shards,
                8 * BLOCK,
                &trace,
                TrainerMode::Frozen,
                KernelKind::Rbf,
                TrainerConfig::default(),
                BatcherConfig::default(),
                crate::cache::RecencyConfig::default(),
            )
            .unwrap();
            let injector = FaultInjector::new(FaultPlan::all_clear(5));
            let chaos = run_serving_chaos(
                "h-svm-lru",
                shards,
                8 * BLOCK,
                &trace,
                KernelKind::Rbf,
                BreakerConfig::off(),
                &injector,
                &MetricsRegistry::disabled(),
                DEFAULT_WINDOW_US,
                crate::cache::RecencyConfig::default(),
            )
            .unwrap();
            assert_eq!(chaos.stats, baseline.stats, "{shards}-shard all-clear parity");
            assert_eq!(chaos.breaker_opens, 0);
            assert_eq!(chaos.breaker_fallbacks, 0);
            assert_eq!(chaos.backend_failures, 0);
            assert_eq!(chaos.outage, None);
        }
    }

    #[test]
    fn outage_opens_breaker_falls_back_and_recovers() {
        let trace = fig3_trace(BLOCK, 7);
        let plan = default_serving_plan(&trace, 7);
        let run = || {
            let injector = FaultInjector::new(plan.clone());
            run_serving_chaos(
                "h-svm-lru",
                4,
                8 * BLOCK,
                &trace,
                KernelKind::Rbf,
                breaker_for_trace(&trace),
                &injector,
                &MetricsRegistry::disabled(),
                DEFAULT_WINDOW_US,
                crate::cache::RecencyConfig::default(),
            )
            .unwrap()
        };
        let r = run();
        assert_eq!(r.stats.requests, trace.len() as u64, "every request replayed");
        assert!(r.backend_failures >= 1, "outage injected: {r:?}");
        assert!(r.breaker_opens >= 1, "breaker tripped: {r:?}");
        assert!(r.breaker_fallbacks >= 1, "open breaker served fallbacks: {r:?}");
        assert!(r.breaker_closes >= 1, "probe closed the breaker after the outage: {r:?}");
        assert!(
            r.recovered_after_windows.is_some(),
            "hit ratio must return to within {RECOVERY_GAP} of pre-outage: {r:?}"
        );
        // Same plan, same seed: byte-identical rerun.
        let again = run();
        assert_eq!(r.stats, again.stats);
        assert_eq!(r.windows, again.windows);
        assert_eq!(r.breaker_opens, again.breaker_opens);
        assert_eq!(r.breaker_fallbacks, again.breaker_fallbacks);
    }

    #[test]
    fn degraded_hit_ratio_stays_within_gap_of_plain_lru() {
        let trace = fig3_trace(BLOCK, 7);
        let plan = default_serving_plan(&trace, 7);
        let svm_injector = FaultInjector::new(plan.clone());
        let svm = run_serving_chaos(
            "h-svm-lru",
            4,
            8 * BLOCK,
            &trace,
            KernelKind::Rbf,
            breaker_for_trace(&trace),
            &svm_injector,
            &MetricsRegistry::disabled(),
            DEFAULT_WINDOW_US,
            crate::cache::RecencyConfig::default(),
        )
        .unwrap();
        let lru_injector = FaultInjector::new(plan);
        let lru = run_serving_chaos(
            "lru",
            4,
            8 * BLOCK,
            &trace,
            KernelKind::Rbf,
            breaker_for_trace(&trace),
            &lru_injector,
            &MetricsRegistry::disabled(),
            DEFAULT_WINDOW_US,
            crate::cache::RecencyConfig::default(),
        )
        .unwrap();
        // Under classifier outage H-SVM-LRU degrades to the unclassified
        // cold path, so it must stay within a bounded gap of plain LRU.
        assert!(
            svm.outage_hit + 0.05 >= lru.outage_hit,
            "degraded H-SVM-LRU within 5pp of LRU: {} vs {}",
            svm.outage_hit,
            lru.outage_hit
        );
    }

    #[test]
    fn trainer_chaos_restarts_and_keeps_serving() {
        let trace = fig3_trace(BLOCK, 7);
        let plan = FaultPlan::all_clear(7)
            .with_event(FaultEvent::TrainerCrash { after_samples: trace.len() as u64 / 2 });
        let injector = FaultInjector::new(plan);
        let registry = MetricsRegistry::new();
        let report = run_trainer_chaos(
            "h-svm-lru",
            4,
            8 * BLOCK,
            &trace,
            KernelKind::Rbf,
            TrainerConfig::default(),
            &injector,
            &registry,
        )
        .unwrap();
        assert_eq!(report.stats.requests, trace.len() as u64);
        assert_eq!(report.trainer.restarts, 1, "{:?}", report.trainer);
        assert_eq!(injector.trainer_crashes(), 1);
        assert_eq!(report.samples_sent, trace.len() as u64);
        let gauges = registry.gauge_values();
        let gauge = |name: &str| {
            gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap()
        };
        assert_eq!(gauge("trainer.restarts"), 1);
        assert_eq!(gauge("trainer.stale_snapshot_age"), report.trainer.stale_samples);
    }
}
