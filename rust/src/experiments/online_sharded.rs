//! True online H-SVM-LRU on the concurrent path: the shard-parallel
//! replay of [`super::sharded_replay`] with a **live background trainer**
//! instead of a classify-once pass — the `repro online` driver.
//!
//! Every shard worker walks its shard's slice of the trace in original
//! order, computing features from a *per-shard* [`BlockStatsTracker`]
//! (block → shard routing is stable, so a block's whole history lives on
//! one shard and the features are bit-identical to the single-threaded
//! pass — see [`super::sharded_replay::trace_dataset`]). Each request:
//!
//! 1. emits its (features, `reused_later`) request-awareness sample into
//!    the bounded channel (never blocking; drops are counted),
//! 2. predicts through its **own per-shard [`ShardBatcher`]** over a
//!    [`SnapshotBackend`] (a lock-free view of the latest published
//!    classifier): cold queries enter a bounded queue and flush when it
//!    fills or the deadline lapses — no worker ever waits behind another
//!    shard's flush, and every published snapshot invalidates the shard's
//!    cached classes, and
//! 3. replays the access against the shared [`ShardedCache`].
//!
//! The background trainer drains the channel into a
//! [`TrainingPipeline`], retrains on cadence, and publishes every fresh
//! model to the [`SnapshotCell`] the workers read — the paper's §5 online
//! loop, running as wide as the hardware allows.
//!
//! [`TrainerMode::Frozen`] is the control arm: the identical worker path
//! with the trainer disabled and a single pre-trained snapshot published
//! up front. It is bit-identical to the classify-once replay
//! ([`super::sharded_replay::replay`]) — the parity is property-tested in
//! rust/tests/property_online.rs and smoke-checked by `repro online
//! --smoke` in CI.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cache::sharded::{shard_of, ShardStats, ShardedCache};
use crate::cache::{AccessContext, CacheBuilder, EvictCause, RecencyConfig};
use crate::coordinator::batcher::{BatcherConfig, BatcherObs, BatcherProbe, ShardBatcher};
use crate::coordinator::online::{
    sample_channel, trainer_loop, SampleSender, SnapshotBackend, SnapshotCell, TrainerConfig,
    TrainerReport,
};
use crate::coordinator::TrainingPipeline;
use crate::hdfs::BlockId;
use crate::obs::{
    merge_audits, merge_series, AuditEntry, EvictionAudit, MetricClass, MetricsRegistry,
    ObsConfig, RunObservations, WindowSeries,
};
use crate::runtime::{RustBackend, SvmBackend};
use crate::sim::parallel::{run_fanout, FanoutOptions, FanoutReport};
use crate::svm::features::{BlockStatsTracker, FeatureVec};
use crate::svm::smo::SmoModel;
use crate::svm::KernelKind;
use crate::util::fasthash::IdHashMap;
use crate::util::table::{fmt_f, Table};
use crate::workload::BlockRequest;

use super::sharded_replay::trace_dataset;

/// Backpressure bound of the worker → trainer sample channel. Larger than
/// the experiment traces, so the built-in sweeps never drop a sample and
/// the trainer is guaranteed to see (and publish from) the full stream.
pub const SAMPLE_CHANNEL_BOUND: usize = 8192;

/// Classifier arm of the replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainerMode {
    /// One snapshot pre-trained on the whole trace, never updated — the
    /// classify-once control, bit-identical to `repro sharded`.
    Frozen,
    /// Background trainer consuming the live sample stream and publishing
    /// snapshots mid-trace.
    Online,
}

impl TrainerMode {
    /// Short name used in table rows and JSONL export (`"frozen"` /
    /// `"online"`).
    pub fn label(self) -> &'static str {
        match self {
            TrainerMode::Frozen => "frozen",
            TrainerMode::Online => "online",
        }
    }
}

/// Outcome of one online (or frozen-control) shard-parallel replay.
#[derive(Debug, Clone)]
pub struct OnlineReplayReport {
    /// Replacement policy replayed (registry name, e.g. `"h-svm-lru"`).
    pub policy: String,
    /// Which classifier arm ran (frozen control or live trainer).
    pub mode: TrainerMode,
    /// Shard count of the cache the trace was replayed against.
    pub shards: usize,
    /// Merged counters (the hit ratio of the whole replay).
    pub stats: ShardStats,
    /// Per-shard counters, in shard order.
    pub per_shard: Vec<ShardStats>,
    /// Wall-clock time of the replay phase (trainer included — it runs
    /// concurrently and ends with the workers' sample stream).
    pub wall: Duration,
    /// What the background trainer did (all-zero in frozen mode).
    pub trainer: TrainerReport,
    /// Samples accepted into the channel across all workers.
    pub samples_sent: u64,
    /// Samples dropped because the trainer fell behind.
    pub samples_dropped: u64,
    /// Newly published snapshots observed by workers mid-replay, summed
    /// over workers (0 when every worker finished before the first
    /// publish — the trainer still drains and publishes afterwards).
    pub snapshot_refreshes: u64,
    /// Cold-query queue counters of the per-shard prediction batchers
    /// (every worker predicts through its own [`ShardBatcher`] over a
    /// [`SnapshotBackend`]).
    pub cold: ColdPathReport,
}

/// Snapshot of a [`BatcherProbe`] at the end of a replay.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ColdPathReport {
    /// Cold queries (class-cache misses) across all shard batchers.
    pub cold_queries: u64,
    /// Cold queries deferred into a queue (answered by a later flush).
    pub deferred: u64,
    /// Queue flushes (fill- or deadline-triggered).
    pub flushes: u64,
    /// Cold queries scored across all flushes.
    pub flushed_queries: u64,
    /// Pending queries lost to invalidation or failed flushes.
    pub dropped: u64,
}

impl ColdPathReport {
    fn from_probe(probe: &BatcherProbe) -> Self {
        ColdPathReport {
            cold_queries: probe.cold_queries(),
            deferred: probe.deferred(),
            flushes: probe.flushes(),
            flushed_queries: probe.flushed_queries(),
            dropped: probe.dropped(),
        }
    }

    /// Mean queries per flush (the batching amortization actually won).
    pub fn mean_flush_size(&self) -> f64 {
        if self.flushes == 0 {
            0.0
        } else {
            self.flushed_queries as f64 / self.flushes as f64
        }
    }
}

impl OnlineReplayReport {
    /// Whole-replay hit ratio (merged over shards).
    pub fn hit_ratio(&self) -> f64 {
        self.stats.hit_ratio()
    }

    /// Replay throughput: requests over the replay phase's wall time.
    pub fn requests_per_sec(&self) -> f64 {
        self.stats.requests as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Samples the trainer consumed per second of replay wall time.
    pub fn samples_per_sec(&self) -> f64 {
        self.trainer.samples as f64 / self.wall.as_secs_f64().max(1e-12)
    }
}

/// Train one model on the whole trace exactly like the classify-once pass
/// ([`super::sharded_replay::classify_trace`]) trains its backend: same
/// dataset construction, same `RustBackend` training path. `None` when
/// the trace is single-class — then the frozen arm replays unclassified,
/// matching classify-once's all-`None` predictions.
/// Shared cache construction of both online drivers: registry policy, no
/// admission, the caller's recency batching.
fn build_cache(
    policy: &str,
    shards: usize,
    capacity: u64,
    recency: RecencyConfig,
) -> Result<ShardedCache> {
    CacheBuilder::new()
        .policy(policy)
        .shards(shards.max(1))
        .capacity(capacity)
        .recency(recency)
        .build()
        .with_context(|| format!("building {shards}-shard {policy:?} cache"))
}

pub fn pretrain_model(trace: &[BlockRequest], kernel: KernelKind) -> Result<Option<SmoModel>> {
    let (_, dataset) = trace_dataset(trace);
    if dataset.n_positive() == 0 || dataset.n_positive() == dataset.len() {
        return Ok(None);
    }
    let mut backend = RustBackend::new(kernel);
    backend.train(&dataset).context("pretraining frozen snapshot")?;
    Ok(backend.export_model())
}

/// Replay `trace` on a fresh `shards`-way cache of `policy`, with the
/// classifier arm selected by `mode` (see module docs for the worker
/// protocol). `cfg` sets the online trainer's cadence; ignored when
/// frozen. `batcher` bounds each worker's cold-query queue — the default
/// (`queue_depth` 1) flushes every cold query inline and keeps the frozen
/// arm bit-identical to the classify-once path. `recency` sets the cache's
/// lock-free hit batching ([`RecencyConfig`]); the default (batch 1,
/// immediate drain) is behavior-preserving.
#[allow(clippy::too_many_arguments)] // the replay's full knob surface
pub fn run_online(
    policy: &str,
    shards: usize,
    capacity: u64,
    trace: &[BlockRequest],
    mode: TrainerMode,
    kernel: KernelKind,
    cfg: TrainerConfig,
    batcher: BatcherConfig,
    recency: RecencyConfig,
) -> Result<OnlineReplayReport> {
    let pretrained = match mode {
        TrainerMode::Frozen => pretrain_model(trace, kernel)?,
        TrainerMode::Online => None,
    };
    run_online_with(
        policy, shards, capacity, trace, mode, kernel, cfg, batcher, recency, pretrained,
    )
}

/// [`run_online`] with the frozen arm's pretrained model supplied by the
/// caller — the model depends only on (trace, kernel), so sweeps train it
/// once instead of once per cell (mirroring `run_sweep`'s hoisted
/// classify pass in `sharded_replay`).
// disallowed_methods: replay wall time is reporting-only (Volatile class) —
// see clippy.toml and rust/tests/lint_invariants.rs.
#[allow(clippy::too_many_arguments, clippy::disallowed_methods)]
fn run_online_with(
    policy: &str,
    shards: usize,
    capacity: u64,
    trace: &[BlockRequest],
    mode: TrainerMode,
    kernel: KernelKind,
    cfg: TrainerConfig,
    batcher: BatcherConfig,
    recency: RecencyConfig,
    pretrained: Option<SmoModel>,
) -> Result<OnlineReplayReport> {
    let cache = build_cache(policy, shards, capacity, recency)?;
    let n = cache.n_shards();
    let mut partitions: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, req) in trace.iter().enumerate() {
        partitions[shard_of(req.block, n)].push(i);
    }
    let block_size = trace.iter().map(|r| r.size).max().unwrap_or(1);
    let cell = Arc::new(SnapshotCell::new());

    // The master sender lives in a mutex-held Option: each worker clones
    // it on entry, and the `finish` hook takes it once every worker has
    // joined — the disconnect that tells the trainer to drain and exit.
    // In frozen mode it is `None` and workers never emit.
    let (sender, rx) = sample_channel(SAMPLE_CHANNEL_BOUND);
    let probe = sender.probe();
    let master: Mutex<Option<SampleSender>> = match mode {
        TrainerMode::Online => Mutex::new(Some(sender)),
        TrainerMode::Frozen => {
            drop(sender);
            if let Some(model) = pretrained {
                cell.publish(model);
            }
            Mutex::new(None)
        }
    };

    // Shared cold-path telemetry of every worker's per-shard batcher.
    let batch_probe = BatcherProbe::new();

    let worker = |w: usize| {
        let tx = master.lock().expect("sender mutex poisoned").as_ref().cloned();
        let mut tracker = BlockStatsTracker::new(block_size);
        // Per-shard prediction front: a read-only backend over the latest
        // published snapshot + this shard's own bounded cold-query queue.
        // No lock is shared with any other worker — a flush here can never
        // stall another shard (the miss-storm fix).
        let mut backend = SnapshotBackend::new(Arc::clone(&cell));
        let mut shard_batcher = ShardBatcher::with_probe(batcher, batch_probe.clone());
        // Lock-free hit front: membership resolves against the shard's
        // read view, recency updates drain in batches per `recency`.
        let mut handle = cache.read_handle();
        for &i in &partitions[w] {
            let req = &trace[i];
            let features = tracker.features(
                req.block,
                req.kind,
                req.size,
                req.affinity,
                req.recompute_cost,
                req.time,
            );
            if let Some(tx) = &tx {
                tx.emit(features, req.reused_later);
            }
            // Snapshot invalidation must reach every per-shard batcher: a
            // freshly published version drops this shard's cached classes
            // before the next prediction.
            shard_batcher.note_model_version(backend.version());
            let predicted = if backend.is_trained() {
                // Exact per-access stamp: every access re-scores, exactly
                // like the classify-once pass scores every request (the
                // class cache only answers repeat queries at one stamp).
                let stamp = tracker.accesses(req.block);
                shard_batcher
                    .predict(&mut backend, req.block, stamp, features, req.time)
                    .unwrap_or_default()
            } else {
                None
            };
            let ctx = AccessContext {
                time: req.time,
                size: req.size,
                kind: req.kind,
                file: req.block.0, // trace blocks are their own files
                file_width: 1,
                file_complete: false,
                affinity: req.affinity,
                predicted_reuse: predicted,
                recompute_cost: req.recompute_cost,
            };
            handle.access_or_insert(req.block, &ctx);
            tracker.record_access(req.block, 0, req.time);
        }
        // Drain whatever the deadline never reached, so every cold query
        // is accounted as flushed (or dropped) by the end of the replay.
        if backend.is_trained() {
            let _ = shard_batcher.flush(&mut backend);
        }
        // Flush buffered recency before reading this shard's final state.
        drop(handle);
        (cache.stats_of(w), backend.refreshes())
    };

    let t0 = Instant::now();
    let (per_worker, trainer) = match mode {
        TrainerMode::Frozen => {
            drop(rx);
            let per_worker = run_fanout(n, worker, FanoutOptions::new()).into_workers();
            let trainer =
                TrainerReport { final_version: cell.version(), ..TrainerReport::default() };
            (per_worker, trainer)
        }
        TrainerMode::Online => {
            let trainer_cell = Arc::clone(&cell);
            let FanoutReport { workers, background, .. } = run_fanout(
                n,
                worker,
                FanoutOptions::new()
                    .background(
                        move || {
                            let mut backend = RustBackend::new(kernel);
                            let mut pipeline =
                                TrainingPipeline::new(cfg.min_samples, cfg.retrain_interval);
                            trainer_loop(rx, &mut backend, &mut pipeline, &trainer_cell)
                        },
                        || {
                            master.lock().expect("sender mutex poisoned").take();
                        },
                    ),
            );
            let per_worker: Vec<_> = workers
                .into_iter()
                .map(|r| r.expect("panicked worker in a non-resilient run"))
                .collect();
            let trainer = background.expect("background configured");
            (per_worker, trainer.context("background trainer failed")?)
        }
    };
    let wall = t0.elapsed();

    let mut stats = ShardStats::default();
    let mut per_shard = Vec::with_capacity(per_worker.len());
    let mut snapshot_refreshes = 0u64;
    for (shard_stats, refreshes) in per_worker {
        stats.merge(&shard_stats);
        per_shard.push(shard_stats);
        snapshot_refreshes += refreshes;
    }
    Ok(OnlineReplayReport {
        policy: policy.to_string(),
        mode,
        shards: n,
        stats,
        per_shard,
        wall,
        trainer,
        samples_sent: probe.sent(),
        samples_dropped: probe.dropped(),
        snapshot_refreshes,
        cold: ColdPathReport::from_probe(&batch_probe),
    })
}

/// [`run_online`] with the telemetry layer attached: per-worker windowed
/// series + eviction audit ring (merged deterministically at the end),
/// per-shard batcher histograms ([`BatcherObs`]), prediction-path latency,
/// and every probe counter surfaced as a registry gauge. The worker
/// protocol is identical to [`run_online`] — observation only reads what
/// the replay already computes, so the frozen arm keeps its classify-once
/// parity.
///
/// Snapshot-version churn lands in the window where a worker first *saw*
/// the fresh version, which is the moment it affects that shard's
/// predictions. The audit ring's `score` is 0.0 on this path: the batcher
/// front answers classes, not margins (the classify-once path of
/// [`super::sharded_replay::drive`] records real decision scores).
// disallowed_methods: wall time + prediction latency are Volatile (log-only)
// metrics — see clippy.toml and rust/tests/lint_invariants.rs.
#[allow(clippy::too_many_arguments, clippy::disallowed_methods)]
pub fn run_online_observed(
    policy: &str,
    shards: usize,
    capacity: u64,
    trace: &[BlockRequest],
    mode: TrainerMode,
    kernel: KernelKind,
    cfg: TrainerConfig,
    batcher: BatcherConfig,
    recency: RecencyConfig,
    registry: &MetricsRegistry,
    obs_cfg: ObsConfig,
) -> Result<(OnlineReplayReport, RunObservations)> {
    let pretrained = match mode {
        TrainerMode::Frozen => pretrain_model(trace, kernel)?,
        TrainerMode::Online => None,
    };
    let cache = build_cache(policy, shards, capacity, recency)?;
    let n = cache.n_shards();
    let mut partitions: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, req) in trace.iter().enumerate() {
        partitions[shard_of(req.block, n)].push(i);
    }
    let block_size = trace.iter().map(|r| r.size).max().unwrap_or(1);
    let cell = Arc::new(SnapshotCell::new());
    let (sender, rx) = sample_channel(SAMPLE_CHANNEL_BOUND);
    let probe = sender.probe();
    let master: Mutex<Option<SampleSender>> = match mode {
        TrainerMode::Online => Mutex::new(Some(sender)),
        TrainerMode::Frozen => {
            drop(sender);
            if let Some(model) = pretrained {
                cell.publish(model);
            }
            Mutex::new(None)
        }
    };
    let batch_probe = BatcherProbe::new();
    probe.register_gauges(registry, "samples");
    batch_probe.register_gauges(registry, "batcher");
    let predict_ns = registry.histogram("predict.ns", MetricClass::Volatile, n);
    let scan_hist = registry.histogram("evict.scan_steps", MetricClass::Deterministic, n);

    let worker = |w: usize| {
        let tx = master.lock().expect("sender mutex poisoned").as_ref().cloned();
        let mut tracker = BlockStatsTracker::new(block_size);
        let mut backend = SnapshotBackend::new(Arc::clone(&cell));
        let mut shard_batcher = ShardBatcher::with_probe(batcher, batch_probe.clone());
        shard_batcher.set_obs(BatcherObs::register(registry, n, w));
        // Lock-free hit front, exactly as in the unobserved driver.
        let mut handle = cache.read_handle();
        let mut windows = WindowSeries::new(obs_cfg.window_us);
        let mut audit = EvictionAudit::new(obs_cfg.audit_every, obs_cfg.audit_cap);
        // Victim ground truth: the victim's most recent request on this
        // shard — (features, prediction, reused_later) at that access.
        let mut last: IdHashMap<BlockId, (FeatureVec, Option<bool>, bool)> =
            IdHashMap::default();
        let mut seen_version = backend.version();
        for &i in &partitions[w] {
            let req = &trace[i];
            let features = tracker.features(
                req.block,
                req.kind,
                req.size,
                req.affinity,
                req.recompute_cost,
                req.time,
            );
            if let Some(tx) = &tx {
                tx.emit(features, req.reused_later);
            }
            let version = backend.version();
            if version != seen_version {
                windows.at(req.time).snapshot_publishes += version - seen_version;
                seen_version = version;
            }
            shard_batcher.note_model_version(version);
            let predicted = if backend.is_trained() {
                let stamp = tracker.accesses(req.block);
                let t0 = predict_ns.is_active().then(Instant::now);
                let p = shard_batcher
                    .predict(&mut backend, req.block, stamp, features, req.time)
                    .unwrap_or_default();
                if let Some(t0) = t0 {
                    predict_ns.record(w, t0.elapsed().as_nanos() as u64);
                }
                p
            } else {
                None
            };
            let ctx = AccessContext {
                time: req.time,
                size: req.size,
                kind: req.kind,
                file: req.block.0, // trace blocks are their own files
                file_width: 1,
                file_complete: false,
                affinity: req.affinity,
                predicted_reuse: predicted,
                recompute_cost: req.recompute_cost,
            };
            let outcome = handle.access_or_insert(req.block, &ctx);
            tracker.record_access(req.block, 0, req.time);
            if !outcome.hit {
                scan_hist.record(w, u64::from(outcome.scan_steps));
            }
            let occupancy = cache.snapshot_of(w).blocks;
            let win = windows.at(req.time);
            win.requests += 1;
            win.hits += u64::from(outcome.hit);
            win.insertions += u64::from(outcome.inserted);
            win.occupancy_end = occupancy;
            for (victim, cause) in outcome.evicted.iter().zip(&outcome.causes) {
                match cause {
                    EvictCause::Capacity => win.evict_capacity += 1,
                    EvictCause::AdmissionDuel => win.evict_admission += 1,
                    EvictCause::CostTieBreak => win.evict_cost_tie += 1,
                }
                if let Some((vf, vp, actual)) = last.remove(victim) {
                    match vp {
                        Some(true) if actual => win.tp += 1,
                        Some(true) => win.fp += 1,
                        Some(false) if actual => win.fn_ += 1,
                        Some(false) => win.tn += 1,
                        None => {}
                    }
                    audit.observe(|| AuditEntry {
                        at: req.time,
                        block: *victim,
                        cause: *cause,
                        features: vf,
                        score: 0.0,
                        predicted: vp,
                        actual,
                    });
                }
            }
            last.insert(req.block, (features, predicted, req.reused_later));
        }
        if backend.is_trained() {
            let _ = shard_batcher.flush(&mut backend);
        }
        // Flush buffered recency before reading this shard's final state.
        drop(handle);
        (cache.stats_of(w), backend.refreshes(), windows.finish(), audit)
    };

    let t0 = Instant::now();
    let (per_worker, trainer) = match mode {
        TrainerMode::Frozen => {
            drop(rx);
            let per_worker = run_fanout(n, worker, FanoutOptions::new()).into_workers();
            let trainer =
                TrainerReport { final_version: cell.version(), ..TrainerReport::default() };
            (per_worker, trainer)
        }
        TrainerMode::Online => {
            let trainer_cell = Arc::clone(&cell);
            let FanoutReport { workers, background, .. } = run_fanout(
                n,
                worker,
                FanoutOptions::new()
                    .background(
                        move || {
                            let mut backend = RustBackend::new(kernel);
                            let mut pipeline =
                                TrainingPipeline::new(cfg.min_samples, cfg.retrain_interval);
                            trainer_loop(rx, &mut backend, &mut pipeline, &trainer_cell)
                        },
                        || {
                            master.lock().expect("sender mutex poisoned").take();
                        },
                    ),
            );
            let per_worker: Vec<_> = workers
                .into_iter()
                .map(|r| r.expect("panicked worker in a non-resilient run"))
                .collect();
            let trainer = background.expect("background configured");
            (per_worker, trainer.context("background trainer failed")?)
        }
    };
    let wall = t0.elapsed();

    let mut stats = ShardStats::default();
    let mut per_shard = Vec::with_capacity(per_worker.len());
    let mut snapshot_refreshes = 0u64;
    let mut window_parts = Vec::with_capacity(per_worker.len());
    let mut audit_parts = Vec::with_capacity(per_worker.len());
    for (shard_stats, refreshes, windows, audit) in per_worker {
        stats.merge(&shard_stats);
        per_shard.push(shard_stats);
        snapshot_refreshes += refreshes;
        window_parts.push(windows);
        audit_parts.push(audit);
    }
    // End-of-run trainer facts, readable at export time.
    let (trainings, publishes, samples) = (trainer.trainings, trainer.publishes, trainer.samples);
    registry.gauge("trainer.trainings", move || trainings);
    registry.gauge("trainer.publishes", move || publishes);
    registry.gauge("trainer.samples", move || samples);
    registry.gauge("snapshot.refreshes", move || snapshot_refreshes);
    let (audit, audit_seen) = merge_audits(audit_parts);
    Ok((
        OnlineReplayReport {
            policy: policy.to_string(),
            mode,
            shards: n,
            stats,
            per_shard,
            wall,
            trainer,
            samples_sent: probe.sent(),
            samples_dropped: probe.dropped(),
            snapshot_refreshes,
            cold: ColdPathReport::from_probe(&batch_probe),
        },
        RunObservations {
            windows: merge_series(window_parts),
            audit,
            audit_seen,
            audit_every: obs_cfg.audit_every.max(1),
        },
    ))
}

/// The frozen × online matrix over `policies` and `shard_counts`, one
/// replay per cell, all on the identical trace.
#[allow(clippy::too_many_arguments)] // the sweep mirrors run_online's knobs
pub fn run_matrix(
    policies: &[&str],
    shard_counts: &[usize],
    capacity: u64,
    trace: &[BlockRequest],
    kernel: KernelKind,
    cfg: TrainerConfig,
    batcher: BatcherConfig,
    recency: RecencyConfig,
) -> Result<Vec<OnlineReplayReport>> {
    // The frozen model depends only on (trace, kernel): train it once for
    // the whole matrix instead of once per frozen cell.
    let pretrained = pretrain_model(trace, kernel)?;
    let mut reports = Vec::with_capacity(policies.len() * shard_counts.len() * 2);
    for &policy in policies {
        for &shards in shard_counts {
            for mode in [TrainerMode::Frozen, TrainerMode::Online] {
                let model = match mode {
                    TrainerMode::Frozen => pretrained.clone(),
                    TrainerMode::Online => None,
                };
                reports.push(run_online_with(
                    policy, shards, capacity, trace, mode, kernel, cfg, batcher, recency, model,
                )?);
            }
        }
    }
    Ok(reports)
}

/// Render a matrix run as a table (the `repro online` output).
pub fn render(reports: &[OnlineReplayReport]) -> Table {
    let mut t = Table::new(vec![
        "policy",
        "mode",
        "shards",
        "hit ratio",
        "publishes",
        "trainings",
        "samples",
        "dropped",
        "refreshes",
        "deferred",
        "flushes",
        "replay wall (ms)",
        "req/s",
    ]);
    for r in reports {
        t.add_row(vec![
            r.policy.clone(),
            r.mode.label().to_string(),
            r.shards.to_string(),
            fmt_f(r.hit_ratio(), 4),
            r.trainer.publishes.to_string(),
            r.trainer.trainings.to_string(),
            r.samples_sent.to_string(),
            r.samples_dropped.to_string(),
            r.snapshot_refreshes.to_string(),
            r.cold.deferred.to_string(),
            r.cold.flushes.to_string(),
            fmt_f(r.wall.as_secs_f64() * 1e3, 2),
            format!("{:.0}", r.requests_per_sec()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::sharded_replay::{classify_trace, replay, ReplayOptions};
    use crate::util::bytes::MB;
    use crate::workload::fig3_trace;

    const BLOCK: u64 = 64 * MB;

    /// The acceptance criterion's control arm: frozen-mode replay is
    /// bit-identical to the classify-once path, for 1 and 8 shards —
    /// including through the per-shard batcher front (default depth 1
    /// flushes every cold query inline) and under buffered recency
    /// (one worker per shard keeps drained order = program order).
    #[test]
    fn frozen_matches_classify_once() {
        let trace = fig3_trace(BLOCK, 5);
        let classes = classify_trace(&trace, KernelKind::Rbf, 64).unwrap();
        for shards in [1usize, 8] {
            let baseline = replay(
                "h-svm-lru",
                shards,
                8 * BLOCK,
                &trace,
                &ReplayOptions::new().classes(&classes),
            )
            .unwrap()
            .report;
            for recency in
                [RecencyConfig::default(), RecencyConfig::default().with_batch(16)]
            {
                let frozen = run_online(
                    "h-svm-lru",
                    shards,
                    8 * BLOCK,
                    &trace,
                    TrainerMode::Frozen,
                    KernelKind::Rbf,
                    TrainerConfig::default(),
                    BatcherConfig::default(),
                    recency,
                )
                .unwrap();
                assert_eq!(frozen.stats, baseline.stats, "{shards}-shard frozen parity");
                assert_eq!(frozen.per_shard, baseline.per_shard);
                assert_eq!(frozen.samples_sent, 0, "frozen workers never emit");
                assert_eq!(frozen.trainer.publishes, 0);
                assert_eq!(frozen.trainer.final_version, 1, "one pretrained snapshot");
                assert_eq!(frozen.cold.deferred, 0, "depth 1 never defers");
                assert!(frozen.cold.flushes > 0, "predictions ran through the batchers");
            }
        }
    }

    #[test]
    fn online_replay_trains_and_publishes_live() {
        let trace = fig3_trace(BLOCK, 7);
        let report = run_online(
            "h-svm-lru",
            8,
            8 * BLOCK,
            &trace,
            TrainerMode::Online,
            KernelKind::Rbf,
            TrainerConfig::default(),
            BatcherConfig::default(),
            RecencyConfig::default(),
        )
        .unwrap();
        assert_eq!(report.stats.requests, trace.len() as u64);
        assert_eq!(report.stats.hits + report.stats.misses, report.stats.requests);
        assert_eq!(report.shards, 8);
        // The channel is wider than the trace: every sample reaches the
        // trainer, so at least one (re)training + publish is guaranteed.
        assert_eq!(report.samples_dropped, 0);
        assert_eq!(report.samples_sent, trace.len() as u64);
        assert_eq!(report.trainer.samples, trace.len() as u64);
        assert!(report.trainer.trainings >= 1, "{:?}", report.trainer);
        assert!(report.trainer.publishes >= 1, "{:?}", report.trainer);
        assert_eq!(report.trainer.final_version, report.trainer.publishes);
    }

    /// A deep cold-query queue defers predictions instead of flushing
    /// inline; every deferred query is accounted, and the replay stays
    /// well-formed (the deferred accesses just run unclassified).
    #[test]
    fn deep_queue_defers_and_accounts() {
        let trace = fig3_trace(BLOCK, 5);
        let batcher = BatcherConfig {
            queue_depth: 8,
            // Never lapses in-test: deferral is driven purely by fill.
            deadline: crate::sim::SimDuration::from_secs_f64(1e9),
            ..BatcherConfig::default()
        };
        let report = run_online(
            "h-svm-lru",
            4,
            8 * BLOCK,
            &trace,
            TrainerMode::Frozen,
            KernelKind::Rbf,
            TrainerConfig::default(),
            batcher,
            RecencyConfig::default(),
        )
        .unwrap();
        assert_eq!(report.stats.requests, trace.len() as u64);
        assert!(report.cold.deferred > 0, "deep queue must defer: {:?}", report.cold);
        // Per-access stamps never dedupe, and the worker drains its queue
        // at the end: every cold query ends up flushed (or dropped).
        assert_eq!(
            report.cold.cold_queries,
            report.cold.flushed_queries + report.cold.dropped,
            "cold-query conservation: {:?}",
            report.cold
        );
        assert!(report.cold.mean_flush_size() > 1.0, "batching actually amortized");
    }

    /// Observed frozen replay: parity with the plain frozen replay, window
    /// sums matching the merged counters, probe counts visible as gauges.
    #[test]
    fn observed_frozen_keeps_parity_and_sums() {
        let trace = fig3_trace(BLOCK, 5);
        let plain = run_online(
            "h-svm-lru",
            4,
            8 * BLOCK,
            &trace,
            TrainerMode::Frozen,
            KernelKind::Rbf,
            TrainerConfig::default(),
            BatcherConfig::default(),
            RecencyConfig::default(),
        )
        .unwrap();
        let registry = MetricsRegistry::new();
        let (report, obs) = run_online_observed(
            "h-svm-lru",
            4,
            8 * BLOCK,
            &trace,
            TrainerMode::Frozen,
            KernelKind::Rbf,
            TrainerConfig::default(),
            BatcherConfig::default(),
            RecencyConfig::default(),
            &registry,
            ObsConfig::default(),
        )
        .unwrap();
        assert_eq!(report.stats, plain.stats, "observation must not perturb the replay");
        assert_eq!(report.per_shard, plain.per_shard);
        assert_eq!(report.cold, plain.cold);

        let requests: u64 = obs.windows.iter().map(|(_, w)| w.requests).sum();
        let evictions: u64 = obs.windows.iter().map(|(_, w)| w.evictions()).sum();
        let churn: u64 = obs.windows.iter().map(|(_, w)| w.snapshot_publishes).sum();
        assert_eq!(requests, report.stats.requests);
        assert_eq!(evictions, report.stats.evictions);
        assert_eq!(churn, 0, "frozen publishes before the workers start");

        let gauges = registry.gauge_values();
        let gauge = |name: &str| {
            gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap_or_else(|| {
                panic!("gauge {name:?} missing from {gauges:?}")
            })
        };
        assert_eq!(gauge("batcher.cold_queries"), report.cold.cold_queries);
        assert_eq!(gauge("batcher.flushes"), report.cold.flushes);
        assert_eq!(gauge("samples.sent"), 0);
        assert_eq!(gauge("trainer.publishes"), 0);
        assert_eq!(gauge("snapshot.refreshes"), report.snapshot_refreshes);

        // Per-shard batcher histograms merged across the 4 workers.
        let hists = registry.hist_snapshots();
        let flush_size = hists
            .iter()
            .find(|(n, _, _)| n == "batcher.flush_size")
            .expect("batcher histogram registered");
        assert_eq!(flush_size.2.sum, report.cold.flushed_queries);
        assert_eq!(flush_size.2.count, report.cold.flushes);
    }

    #[test]
    fn matrix_covers_modes_policies_and_shards() {
        let trace = fig3_trace(BLOCK, 3);
        let reports = run_matrix(
            &["lru", "h-svm-lru"],
            &[1, 4],
            8 * BLOCK,
            &trace,
            KernelKind::Rbf,
            TrainerConfig::default(),
            BatcherConfig::default(),
            RecencyConfig::default(),
        )
        .unwrap();
        assert_eq!(reports.len(), 2 * 2 * 2);
        for r in &reports {
            assert_eq!(r.stats.requests, trace.len() as u64);
        }
        let t = render(&reports);
        assert_eq!(t.n_rows(), 8);
    }

    #[test]
    fn unknown_policy_errors() {
        let trace = fig3_trace(BLOCK, 3);
        let r = run_online(
            "nonsense",
            2,
            8 * BLOCK,
            &trace,
            TrainerMode::Frozen,
            KernelKind::Rbf,
            TrainerConfig::default(),
            BatcherConfig::default(),
            RecencyConfig::default(),
        );
        assert!(r.is_err());
    }
}
