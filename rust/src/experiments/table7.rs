//! Table 7 — improvement ratio (IR) of H-SVM-LRU over LRU per cache size,
//! derived from the Fig 3 sweep (the paper derives it the same way).

use anyhow::Result;

use crate::config::SvmConfig;
use crate::util::bytes::MB;
use crate::util::table::{fmt_pct, Table};

use super::fig3::{self, HitRatioPoint};

/// Run (or reuse) the Fig 3 sweep and render Table 7.
pub fn run(svm_cfg: &SvmConfig, seed: u64) -> Result<Vec<HitRatioPoint>> {
    fig3::run(svm_cfg, seed)
}

/// Paper layout: one row per cache size, IR columns for 64 MB and 128 MB.
pub fn render(points: &[HitRatioPoint]) -> Table {
    let mut t = Table::new(vec![
        "Cache size",
        "IR (64 MB blocks)",
        "IR (128 MB blocks)",
    ]);
    let sizes: Vec<u64> = {
        let mut v: Vec<u64> = points.iter().map(|p| p.cache_blocks).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    for size in sizes {
        let ir = |bs: u64| -> String {
            points
                .iter()
                .find(|p| p.block_size == bs && p.cache_blocks == size)
                .map(|p| fmt_pct(p.improvement_ratio()))
                .unwrap_or_else(|| "N/A".to_string())
        };
        t.add_row(vec![size.to_string(), ir(64 * MB), ir(128 * MB)]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_includes_na_for_missing_128mb_sizes() {
        let points = vec![
            HitRatioPoint { block_size: 64 * MB, cache_blocks: 6, lru: 0.2, svm_lru: 0.3 },
            HitRatioPoint { block_size: 64 * MB, cache_blocks: 14, lru: 0.4, svm_lru: 0.5 },
            HitRatioPoint { block_size: 128 * MB, cache_blocks: 6, lru: 0.3, svm_lru: 0.4 },
        ];
        let s = table7::render(&points).render();
        assert!(s.contains("N/A"), "cache size 14 has no 128MB point:\n{s}");
        assert!(s.contains("50.00%"), "IR 0.2->0.3 is 50%:\n{s}");
    }

    use super::super::table7;
}
