//! Fig 4 — WordCount job execution time vs input data size under
//! H-NoCache / H-LRU / H-SVM-LRU, for 64 MB and 128 MB blocks.
//!
//! Protocol per §6.2: each configuration runs the application five times
//! and reports the average execution time (later repetitions benefit from
//! the warmed cache, as on the paper's testbed).

use anyhow::Result;

use crate::config::{ClusterConfig, SvmConfig};
use crate::util::bytes::{format_bytes, GB, MB};
use crate::util::stats::mean;
use crate::util::table::{fmt_f, Table};
use crate::workload::App;

use super::common::{run_repeated_job, Scenario};

/// One measured point: mean exec time (s) per scenario.
#[derive(Debug, Clone)]
pub struct ExecTimePoint {
    /// HDFS block size of the swept configuration (64 MB or 128 MB).
    pub block_size: u64,
    /// WordCount input size (the Fig 4 x-axis).
    pub input_bytes: u64,
    /// Mean execution time without caching, in simulated seconds.
    pub nocache_s: f64,
    /// Mean execution time under H-LRU, in simulated seconds.
    pub lru_s: f64,
    /// Mean execution time under H-SVM-LRU, in simulated seconds.
    pub svm_lru_s: f64,
}

/// Input sizes swept (the interesting regime brackets the 13.5 GB total
/// cache capacity of the paper's cluster: 9 x 1.5 GB).
pub fn input_sizes() -> Vec<u64> {
    vec![2 * GB, 4 * GB, 8 * GB, 16 * GB, 24 * GB]
}

/// Back-to-back runs per configuration (§6.2: "run each application five
/// times" — later repetitions hit the warmed cache).
pub const REPETITIONS: usize = 5;

/// Run the Fig 4 sweep.
pub fn run(svm_cfg: &SvmConfig, seed: u64) -> Result<Vec<ExecTimePoint>> {
    let mut points = Vec::new();
    for block_size in [64 * MB, 128 * MB] {
        for input in input_sizes() {
            let mut times = [0.0f64; 3];
            // Average over placement seeds as well as the five in-run
            // repetitions (the paper's protocol).
            const SEEDS: u64 = 3;
            for s in 0..SEEDS {
                let cfg = ClusterConfig { block_size, seed: seed + s, ..Default::default() };
                for (i, scenario) in [
                    Scenario::NoCache,
                    Scenario::Policy("lru".to_string()),
                    Scenario::SvmLru,
                ]
                .iter()
                .enumerate()
                {
                    let reps = run_repeated_job(
                        App::WordCount,
                        input,
                        &cfg,
                        scenario,
                        svm_cfg,
                        REPETITIONS,
                    )?;
                    times[i] += mean(&reps) / SEEDS as f64;
                }
            }
            points.push(ExecTimePoint {
                block_size,
                input_bytes: input,
                nocache_s: times[0],
                lru_s: times[1],
                svm_lru_s: times[2],
            });
        }
    }
    Ok(points)
}

/// Render the Fig 4 series as a table.
pub fn render(points: &[ExecTimePoint]) -> Table {
    let mut t = Table::new(vec![
        "block size",
        "input size",
        "H-NoCache (s)",
        "H-LRU (s)",
        "H-SVM-LRU (s)",
        "SVM-LRU vs LRU",
    ]);
    for p in points {
        let delta = if p.lru_s > 0.0 {
            format!("{:+.2}%", (p.svm_lru_s - p.lru_s) / p.lru_s * 100.0)
        } else {
            "N/A".to_string()
        };
        t.add_row(vec![
            format_bytes(p.block_size),
            format_bytes(p.input_bytes),
            fmt_f(p.nocache_s, 1),
            fmt_f(p.lru_s, 1),
            fmt_f(p.svm_lru_s, 1),
            delta,
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_sizes_bracket_cache_capacity() {
        let total_cache = 9.0 * 1.5 * GB as f64;
        let sizes = input_sizes();
        assert!(sizes.iter().any(|&s| (s as f64) < total_cache));
        assert!(sizes.iter().any(|&s| (s as f64) > total_cache));
    }
}
