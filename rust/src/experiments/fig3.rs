//! Fig 3 — cache hit ratio vs cache size, LRU vs H-SVM-LRU, for 64 MB and
//! 128 MB blocks over a 2 GB input (and Table 7's improvement ratios,
//! derived from the same series).

use anyhow::Result;

use crate::config::SvmConfig;
use crate::util::bytes::MB;
use crate::util::table::{fmt_f, fmt_pct, Table};
use crate::workload::fig3_trace;

use super::common::{make_coordinator, replay_trace_two_pass, Scenario};

/// One measured point.
#[derive(Debug, Clone)]
pub struct HitRatioPoint {
    /// HDFS block size of the swept configuration (64 MB or 128 MB).
    pub block_size: u64,
    /// Cache capacity in blocks (the Fig 3 x-axis).
    pub cache_blocks: u64,
    /// Measured H-LRU hit ratio.
    pub lru: f64,
    /// Measured H-SVM-LRU hit ratio.
    pub svm_lru: f64,
}

impl HitRatioPoint {
    /// Table 7's IR: relative improvement of H-SVM-LRU over LRU.
    pub fn improvement_ratio(&self) -> f64 {
        if self.lru == 0.0 {
            0.0
        } else {
            (self.svm_lru - self.lru) / self.lru
        }
    }
}

/// Cache sizes the paper sweeps per block size (Fig 3): 6–24 blocks for
/// 64 MB, 6–12 for 128 MB.
pub fn cache_sizes_for(block_size: u64) -> Vec<u64> {
    if block_size >= 128 * MB {
        (6..=12).step_by(2).collect()
    } else {
        (6..=24).step_by(2).collect()
    }
}

/// Run the full Fig 3 sweep.
pub fn run(svm_cfg: &SvmConfig, seed: u64) -> Result<Vec<HitRatioPoint>> {
    let mut points = Vec::new();
    for block_size in [64 * MB, 128 * MB] {
        let trace = fig3_trace(block_size, seed);
        for cache_blocks in cache_sizes_for(block_size) {
            let mut ratios = [0.0f64; 2];
            for (i, scenario) in [
                Scenario::Policy("lru".to_string()),
                Scenario::SvmLru,
            ]
            .iter()
            .enumerate()
            {
                let (_cfg, cluster) =
                    super::common::provision_fig3_cluster(block_size, cache_blocks, seed);
                let mut coord = make_coordinator(cluster, scenario, svm_cfg)?;
                ratios[i] = replay_trace_two_pass(&mut coord, &trace)?;
            }
            points.push(HitRatioPoint {
                block_size,
                cache_blocks,
                lru: ratios[0],
                svm_lru: ratios[1],
            });
        }
    }
    Ok(points)
}

/// Render the Fig 3 series as a table.
pub fn render(points: &[HitRatioPoint]) -> Table {
    let mut t = Table::new(vec![
        "block size",
        "cache size (blocks)",
        "LRU hit ratio",
        "H-SVM-LRU hit ratio",
        "IR",
    ]);
    for p in points {
        t.add_row(vec![
            crate::util::bytes::format_bytes(p.block_size),
            p.cache_blocks.to_string(),
            fmt_f(p.lru, 4),
            fmt_f(p.svm_lru, 4),
            fmt_pct(p.improvement_ratio()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_ranges_match_paper() {
        assert_eq!(cache_sizes_for(64 * MB), vec![6, 8, 10, 12, 14, 16, 18, 20, 22, 24]);
        assert_eq!(cache_sizes_for(128 * MB), vec![6, 8, 10, 12]);
    }

    #[test]
    fn improvement_ratio_math() {
        let p = HitRatioPoint { block_size: 64 * MB, cache_blocks: 6, lru: 0.22, svm_lru: 0.36 };
        assert!((p.improvement_ratio() - (0.36 - 0.22) / 0.22).abs() < 1e-12);
        let z = HitRatioPoint { block_size: 64 * MB, cache_blocks: 6, lru: 0.0, svm_lru: 0.1 };
        assert_eq!(z.improvement_ratio(), 0.0);
    }
}
