//! Table 5 — SVM kernel-function selection: precision / recall / F1 per
//! class plus accuracy for linear, RBF and sigmoid kernels; and the §5.2
//! cross-validated accuracy (the paper reports 83%, RBF winning with 0.85
//! test accuracy and sigmoid collapsing to F1 = 0 on class 1).
//!
//! The dataset is the classifier's real operating distribution: features
//! and request-awareness labels collected by replaying the Fig 3 trace
//! through the coordinator (the ALOJA substitution, DESIGN.md §2),
//! split 75/25 like the paper.

use anyhow::Result;

use crate::config::SvmConfig;
use crate::coordinator::CacheMode;
use crate::runtime::{make_backend, SvmBackend};
use crate::svm::dataset::Dataset;
use crate::svm::eval::{evaluate, ConfusionMatrix};
use crate::svm::KernelKind;
use crate::util::bytes::MB;
use crate::util::rng::Pcg64;
use crate::util::table::{fmt_f, Table};

use super::common::make_coordinator;

/// One kernel's Table 5 row block.
#[derive(Debug, Clone)]
pub struct KernelEval {
    /// Kernel function evaluated.
    pub kernel: KernelKind,
    /// Test-split confusion matrix (precision/recall/F1 derive from it).
    pub cm: ConfusionMatrix,
    /// Accuracy on the held-out 25% split.
    pub test_accuracy: f64,
}

/// Assemble the operating dataset from *both* §5.1 scenarios:
///
/// 1. request awareness — the Fig 3 trace replay with its ground-truth
///    labels (clean), and
/// 2. non-request awareness — retrospective labels collected while running
///    Table 8 workloads (noisy: the label derives from observed job/task
///    fate per Table 4, not from an oracle).
///
/// The mix reflects the paper's ALOJA-derived dataset, where labels are
/// imperfect and the kernel choice matters.
pub fn build_dataset(svm_cfg: &SvmConfig, seed: u64) -> Result<Dataset> {
    let collector_cfg = SvmConfig { backend: "rust".into(), ..svm_cfg.clone() };

    // Scenario 1: trace replay with request-awareness labels.
    let (_cfg, cluster) = super::common::provision_fig3_cluster(64 * MB, 12, seed);
    let mut coord = make_coordinator(
        cluster,
        &super::common::Scenario::SvmLru,
        &collector_cfg,
    )?;
    debug_assert!(matches!(coord.mode(), CacheMode::Cached { .. }));
    for req in crate::workload::fig3_trace(64 * MB, seed) {
        coord.handle_trace_request(&req)?;
    }
    let mut ds = coord.pipeline.dataset().clone();

    // Scenario 2: workload runs with retrospective (Table 4) labels.
    for (i, def) in crate::workload::WORKLOADS.iter().enumerate().take(3) {
        let cfg = crate::config::ClusterConfig {
            seed: seed + i as u64,
            ..Default::default()
        };
        let mut cluster = crate::workload::Cluster::provision(&cfg);
        let jobs = crate::workload::instantiate(def, &mut cluster, 0.02, 0);
        let mut coord = make_coordinator(
            cluster,
            &super::common::Scenario::SvmLru,
            &collector_cfg,
        )?;
        let cfg_ref = coord.cluster.cfg.clone();
        let scheduler = crate::mapreduce::Scheduler::new(&cfg_ref);
        scheduler.run_jobs(&jobs, &mut coord, crate::sim::SimTime::ZERO);
        coord.flush_labels_as_negative();
        let wds = coord.pipeline.dataset().clone();
        ds.x.extend(wds.x);
        ds.y.extend(wds.y);
    }
    ds.preprocess();
    Ok(ds)
}

/// Evaluate all three kernels on a 75/25 split of the dataset.
pub fn run(svm_cfg: &SvmConfig, seed: u64) -> Result<Vec<KernelEval>> {
    let ds = build_dataset(svm_cfg, seed)?;
    let (train, test) = ds.split(0.75, &mut Pcg64::new(seed, 0x7AB5));
    let mut out = Vec::new();
    for kind in [KernelKind::Linear, KernelKind::Rbf, KernelKind::Sigmoid] {
        let mut backend = backend_for(svm_cfg, kind)?;
        backend.train(&train)?;
        let scores = backend.decision_batch(&test.x)?;
        let mut i = 0;
        let cm = evaluate(&test, |_| {
            let c = scores[i] > 0.0;
            i += 1;
            c
        });
        out.push(KernelEval { kernel: kind, cm, test_accuracy: cm.accuracy() });
    }
    Ok(out)
}

/// §5.2 cross-validated accuracy for the chosen (RBF) kernel.
pub fn cross_validated_accuracy(svm_cfg: &SvmConfig, seed: u64, k: usize) -> Result<f64> {
    let ds = build_dataset(svm_cfg, seed)?;
    let folds = ds.k_folds(k, &mut Pcg64::new(seed, 0xCF));
    let mut correct = 0u64;
    let mut total = 0u64;
    for (train, test) in folds {
        let mut backend = backend_for(svm_cfg, KernelKind::Rbf)?;
        backend.train(&train)?;
        let scores = backend.decision_batch(&test.x)?;
        for (s, &y) in scores.iter().zip(&test.y) {
            correct += ((*s > 0.0) == (y > 0.0)) as u64;
            total += 1;
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}

fn backend_for(svm_cfg: &SvmConfig, kind: KernelKind) -> Result<Box<dyn SvmBackend>> {
    let cfg = SvmConfig { kernel: kind.name().to_string(), ..svm_cfg.clone() };
    make_backend(&cfg)
}

/// Paper layout: per kernel, class-0 and class-1 rows.
pub fn render(evals: &[KernelEval]) -> Table {
    let mut t = Table::new(vec![
        "Kernel function",
        "class",
        "Precision",
        "Recall",
        "F1-score",
        "Accuracy",
    ]);
    for e in evals {
        let name = e.kernel.name();
        t.add_row(vec![
            name.to_string(),
            "0".to_string(),
            fmt_f(e.cm.precision_neg(), 2),
            fmt_f(e.cm.recall_neg(), 2),
            fmt_f(e.cm.f1_neg(), 2),
            fmt_f(e.test_accuracy, 2),
        ]);
        t.add_row(vec![
            String::new(),
            "1".to_string(),
            fmt_f(e.cm.precision_pos(), 2),
            fmt_f(e.cm.recall_pos(), 2),
            fmt_f(e.cm.f1_pos(), 2),
            String::new(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_has_both_classes_and_volume() {
        let svm_cfg = SvmConfig { backend: "rust".into(), ..Default::default() };
        let ds = build_dataset(&svm_cfg, 5).unwrap();
        assert!(ds.len() > 100, "dataset too small: {}", ds.len());
        let pos = ds.n_positive();
        assert!(pos > 0 && pos < ds.len(), "one-class dataset");
    }

    #[test]
    fn rbf_beats_sigmoid_like_the_paper() {
        let svm_cfg = SvmConfig { backend: "rust".into(), ..Default::default() };
        let evals = run(&svm_cfg, 5).unwrap();
        let get = |k: KernelKind| evals.iter().find(|e| e.kernel == k).unwrap();
        let rbf = get(KernelKind::Rbf).test_accuracy;
        let sig = get(KernelKind::Sigmoid).test_accuracy;
        assert!(rbf >= sig, "rbf {rbf} should be >= sigmoid {sig}");
        assert!(rbf > 0.6, "rbf accuracy too low: {rbf}");
    }
}
