//! Shared experiment plumbing: scenario construction and workload runners.

use anyhow::Result;

use crate::config::{ClusterConfig, SvmConfig};
use crate::coordinator::{CacheCoordinator, CacheMode};
use crate::mapreduce::{JobRun, Scheduler};
use crate::runtime::{make_backend, RustBackend, SvmBackend};
use crate::sim::SimTime;
use crate::svm::KernelKind;
use crate::workload::{instantiate, BlockRequest, Cluster, WorkloadDef};

/// The paper's three §6.4 scenarios plus arbitrary policies for ablations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Scenario {
    /// H-NoCache.
    NoCache,
    /// H-LRU (or any other non-learned policy by name).
    Policy(String),
    /// H-SVM-LRU with the configured backend.
    SvmLru,
}

impl Scenario {
    /// Display name in the paper's `H-*` convention (e.g. `"H-SVM-LRU"`).
    pub fn label(&self) -> String {
        match self {
            Scenario::NoCache => "H-NoCache".to_string(),
            Scenario::Policy(p) if p == "lru" => "H-LRU".to_string(),
            Scenario::Policy(p) => format!("H-{}", p.to_uppercase()),
            Scenario::SvmLru => "H-SVM-LRU".to_string(),
        }
    }
}

/// Build a coordinator for a scenario over a provisioned cluster.
pub fn make_coordinator(
    cluster: Cluster,
    scenario: &Scenario,
    svm_cfg: &SvmConfig,
) -> Result<CacheCoordinator> {
    match scenario {
        Scenario::NoCache => CacheCoordinator::new(cluster, CacheMode::NoCache, None),
        Scenario::Policy(p) => {
            // SVM admission scores requests like H-SVM-LRU does, so it gets
            // the *configured* backend; predictor-consuming non-SVM policies
            // (autocache) keep the fallback so they run without artifacts.
            let backend: Option<Box<dyn SvmBackend>> =
                if cluster.cfg.cache_admission == "svm" {
                    Some(make_backend(svm_cfg)?)
                } else if p == "autocache" {
                    Some(Box::new(RustBackend::new(KernelKind::Rbf)))
                } else {
                    None
                };
            CacheCoordinator::new(cluster, CacheMode::Cached { policy: p.clone() }, backend)
        }
        Scenario::SvmLru => {
            let backend = make_backend(svm_cfg)?;
            CacheCoordinator::new(
                cluster,
                CacheMode::Cached { policy: "h-svm-lru".to_string() },
                Some(backend),
            )
        }
    }
}

/// Provision the Fig 3 single-node cluster: the 2 GB shared input (hot
/// blocks, ids 0..N) plus the intermediate pollution stream the trace
/// references (ids N..). Cache capacity is `cache_blocks` equal blocks.
pub fn provision_fig3_cluster(
    block_size: u64,
    cache_blocks: u64,
    seed: u64,
) -> (ClusterConfig, Cluster) {
    let cfg = ClusterConfig {
        datanodes: 1,
        replication: 1,
        block_size,
        cache_capacity_per_node: cache_blocks * block_size,
        seed,
        ..Default::default()
    };
    let mut cluster = Cluster::provision(&cfg);
    let hot_bytes = 2 * crate::util::bytes::GB;
    cluster.add_input("fig3/input", hot_bytes);
    // The pollution stream: one single-pass intermediate block per possible
    // cold request (fig3_trace emits hot_blocks * 12 requests total).
    let n_requests = (hot_bytes / block_size) * 12;
    cluster.add_intermediate("fig3/shuffle", n_requests * block_size);
    (cfg, cluster)
}

/// Replay a trace through a coordinator twice: a training pass (classifier
/// learns from request-aware labels), then a cold-cache measured pass.
/// Returns the measured hit ratio.
pub fn replay_trace_two_pass(
    coord: &mut CacheCoordinator,
    trace: &[BlockRequest],
) -> Result<f64> {
    for req in trace {
        coord.handle_trace_request(req)?;
    }
    // Ensure at least one training round happened before measuring.
    if let CacheMode::Cached { .. } = coord.mode() {
        let _ = coord.pipeline.trainings;
    }
    coord.reset_for_measurement();
    for req in trace {
        coord.handle_trace_request(req)?;
    }
    Ok(coord.stats.hit_ratio())
}

/// Result of one workload-scenario run.
#[derive(Debug, Clone)]
pub struct WorkloadRun {
    /// Scenario label ([`Scenario::label`]).
    pub scenario: String,
    /// Per-job results of the measured (second) round.
    pub runs: Vec<JobRun>,
    /// Wall time of the measured round in simulated seconds (max finish
    /// minus round start).
    pub makespan_s: f64,
    /// Cache hit ratio over the measured round.
    pub hit_ratio: f64,
}

/// Run a Table 8 workload (4 concurrent jobs) under a scenario.
pub fn run_workload(
    def: &WorkloadDef,
    cfg: &ClusterConfig,
    scenario: &Scenario,
    svm_cfg: &SvmConfig,
    scale: f64,
) -> Result<WorkloadRun> {
    let mut cluster = Cluster::provision(cfg);
    let jobs = instantiate(def, &mut cluster, scale, 0);
    let mut coord = make_coordinator(cluster, scenario, svm_cfg)?;
    let cfg_ref = coord.cluster.cfg.clone();
    let scheduler = Scheduler::new(&cfg_ref);
    if matches!(scenario, Scenario::SvmLru) {
        // Offline training pass (the paper trains on job history before
        // evaluating): run the workload once, label the history
        // retrospectively (Table 4 row 10 at completion), train, and
        // measure on a cold cache.
        scheduler.run_jobs(&jobs, &mut coord, SimTime::ZERO);
        coord.flush_labels_as_negative();
        coord.train_now()?;
        coord.reset_for_measurement();
    }
    // Two rounds, measure the steady-state second one: production Hadoop
    // workloads recur, and only in the recurring regime does replacement
    // policy matter (round 2's input re-reads contend with round 1's
    // intermediate-data pollution).
    let warm = scheduler.run_jobs(&jobs, &mut coord, SimTime::ZERO);
    let round2_start = warm
        .iter()
        .map(|r| r.finish)
        .max()
        .unwrap_or(SimTime::ZERO);
    let runs = scheduler.run_jobs(&jobs, &mut coord, round2_start);
    let makespan = runs
        .iter()
        .map(|r| (r.finish - round2_start).as_secs_f64())
        .fold(0.0f64, f64::max);
    Ok(WorkloadRun {
        scenario: scenario.label(),
        runs,
        makespan_s: makespan,
        hit_ratio: coord.stats.hit_ratio(),
    })
}

/// Run one application `repetitions` times back-to-back on the same input
/// (the paper's §6.2 "run each application five times" protocol). Returns
/// the per-repetition execution times in seconds.
pub fn run_repeated_job(
    app: crate::workload::App,
    input_bytes: u64,
    cfg: &ClusterConfig,
    scenario: &Scenario,
    svm_cfg: &SvmConfig,
    repetitions: usize,
) -> Result<Vec<f64>> {
    let mut cluster = Cluster::provision(cfg);
    let fid = cluster.add_input("input", input_bytes);
    let blocks: Vec<_> = cluster.namenode.files.blocks_of(fid).to_vec();
    let mut coord = make_coordinator(cluster, scenario, svm_cfg)?;
    let cfg_ref = coord.cluster.cfg.clone();
    let scheduler = Scheduler::new(&cfg_ref);
    let run_all = |coord: &mut CacheCoordinator, base: u64| -> Vec<f64> {
        let mut times = Vec::with_capacity(repetitions);
        let mut t = SimTime::ZERO;
        for rep in 0..repetitions {
            let job = app.job(crate::mapreduce::JobId(base + rep as u64), blocks.clone());
            let run = &scheduler.run_jobs(&[job], coord, t)[0];
            times.push(run.execution_time().as_secs_f64());
            t = run.finish;
            coord.process_cache_reports();
        }
        times
    };
    if matches!(scenario, Scenario::SvmLru) {
        // Offline training pass over the full repetition protocol.
        run_all(&mut coord, 0);
        coord.flush_labels_as_negative();
        coord.train_now()?;
        coord.reset_for_measurement();
    }
    Ok(run_all(&mut coord, 1000))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::{GB, MB};
    use crate::workload::{App, WORKLOADS};

    fn svm_rust() -> SvmConfig {
        SvmConfig { backend: "rust".into(), ..Default::default() }
    }

    #[test]
    fn scenario_labels() {
        assert_eq!(Scenario::NoCache.label(), "H-NoCache");
        assert_eq!(Scenario::Policy("lru".into()).label(), "H-LRU");
        assert_eq!(Scenario::SvmLru.label(), "H-SVM-LRU");
    }

    #[test]
    fn workload_runs_all_scenarios() {
        let cfg = ClusterConfig { datanodes: 3, replication: 2, ..Default::default() };
        for scenario in [
            Scenario::NoCache,
            Scenario::Policy("lru".into()),
            Scenario::SvmLru,
        ] {
            let run = run_workload(&WORKLOADS[4], &cfg, &scenario, &svm_rust(), 0.005)
                .unwrap_or_else(|e| panic!("{scenario:?}: {e:#}"));
            assert_eq!(run.runs.len(), 4);
            assert!(run.makespan_s > 0.0);
            if scenario == Scenario::NoCache {
                assert_eq!(run.hit_ratio, 0.0);
            }
        }
    }

    #[test]
    fn cached_workload_beats_nocache() {
        let cfg = ClusterConfig { datanodes: 3, replication: 2, ..Default::default() };
        let nocache =
            run_workload(&WORKLOADS[4], &cfg, &Scenario::NoCache, &svm_rust(), 0.01).unwrap();
        let lru = run_workload(
            &WORKLOADS[4],
            &cfg,
            &Scenario::Policy("lru".into()),
            &svm_rust(),
            0.01,
        )
        .unwrap();
        assert!(
            lru.makespan_s < nocache.makespan_s,
            "lru {} vs nocache {}",
            lru.makespan_s,
            nocache.makespan_s
        );
        assert!(lru.hit_ratio > 0.0);
    }

    #[test]
    fn repeated_jobs_speed_up_with_cache() {
        let cfg = ClusterConfig { datanodes: 3, replication: 2, ..Default::default() };
        let times = run_repeated_job(
            App::Grep,
            2 * GB,
            &cfg,
            &Scenario::Policy("lru".into()),
            &svm_rust(),
            3,
        )
        .unwrap();
        assert_eq!(times.len(), 3);
        // Later repetitions hit the cache and run faster.
        assert!(times[2] < times[0], "{times:?}");
    }

    #[test]
    fn two_pass_replay_produces_hit_ratio() {
        let (_cfg, cluster) = provision_fig3_cluster(128 * MB, 8, 3);
        let mut coord =
            make_coordinator(cluster, &Scenario::SvmLru, &svm_rust()).unwrap();
        let trace = crate::workload::fig3_trace(128 * MB, 3);
        let hr = replay_trace_two_pass(&mut coord, &trace).unwrap();
        assert!(hr > 0.0 && hr < 1.0, "hit ratio {hr}");
    }
}
