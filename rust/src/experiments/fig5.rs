//! Fig 5 — normalized run time of the Table 8 workloads (4 concurrent
//! apps each) under H-LRU and H-SVM-LRU, normalized to H-NoCache.
//!
//! Paper numbers: H-LRU improves 11.33% on average, H-SVM-LRU 16.16%
//! (4.83% over H-LRU); W3 and W5 improve most (high-affinity apps, most
//! shared data).

use anyhow::Result;

use crate::config::{ClusterConfig, SvmConfig};
use crate::util::stats::mean;
use crate::util::table::{fmt_f, Table};
use crate::workload::{WorkloadDef, WORKLOADS};

use super::common::{run_workload, Scenario};

/// Normalized run times for one workload.
#[derive(Debug, Clone)]
pub struct WorkloadPoint {
    /// Workload name ("W1".."W6").
    pub name: &'static str,
    /// Mean H-NoCache makespan in simulated seconds (the baseline).
    pub nocache_s: f64,
    /// Mean H-LRU makespan normalized to H-NoCache.
    pub lru_norm: f64,
    /// Mean H-SVM-LRU makespan normalized to H-NoCache.
    pub svm_lru_norm: f64,
    /// Mean cache hit ratio of the H-LRU runs.
    pub lru_hit_ratio: f64,
    /// Mean cache hit ratio of the H-SVM-LRU runs.
    pub svm_hit_ratio: f64,
}

/// Default scale: paper inputs are 254–447 GB; 0.05 keeps the input-to-
/// cache-capacity ratio in the regime where replacement policy matters
/// while finishing in seconds.
pub const DEFAULT_SCALE: f64 = 0.05;

/// Run all six workloads under the three scenarios.
pub fn run(svm_cfg: &SvmConfig, seed: u64, scale: f64) -> Result<Vec<WorkloadPoint>> {
    WORKLOADS
        .iter()
        .map(|def| run_one(def, svm_cfg, seed, scale))
        .collect()
}

/// Repetitions per configuration (the paper averages five runs).
pub const RUNS_PER_POINT: u64 = 5;

/// Run one workload under all three scenarios, averaged over
/// [`RUNS_PER_POINT`] placement seeds.
pub fn run_one(
    def: &WorkloadDef,
    svm_cfg: &SvmConfig,
    seed: u64,
    scale: f64,
) -> Result<WorkloadPoint> {
    // Average over seeds: replica/shuffle placement is randomized per run
    // (like the paper's five repetitions per configuration).
    let mut nocache_s = Vec::new();
    let mut lru_n = Vec::new();
    let mut svm_n = Vec::new();
    let mut lru_hr = Vec::new();
    let mut svm_hr = Vec::new();
    for s in 0..RUNS_PER_POINT {
        let cfg = ClusterConfig { seed: seed + s, ..Default::default() };
        let nocache = run_workload(def, &cfg, &Scenario::NoCache, svm_cfg, scale)?;
        let lru =
            run_workload(def, &cfg, &Scenario::Policy("lru".to_string()), svm_cfg, scale)?;
        let svm = run_workload(def, &cfg, &Scenario::SvmLru, svm_cfg, scale)?;
        let base = nocache.makespan_s.max(1e-9);
        nocache_s.push(nocache.makespan_s);
        lru_n.push(lru.makespan_s / base);
        svm_n.push(svm.makespan_s / base);
        lru_hr.push(lru.hit_ratio);
        svm_hr.push(svm.hit_ratio);
    }
    Ok(WorkloadPoint {
        name: def.name,
        nocache_s: mean(&nocache_s),
        lru_norm: mean(&lru_n),
        svm_lru_norm: mean(&svm_n),
        lru_hit_ratio: mean(&lru_hr),
        svm_hit_ratio: mean(&svm_hr),
    })
}

/// Average improvement percentages (the paper's headline numbers).
pub fn summary(points: &[WorkloadPoint]) -> (f64, f64, f64) {
    let lru_avg = mean(&points.iter().map(|p| p.lru_norm).collect::<Vec<_>>());
    let svm_avg = mean(&points.iter().map(|p| p.svm_lru_norm).collect::<Vec<_>>());
    let lru_impr = (1.0 - lru_avg) * 100.0;
    let svm_impr = (1.0 - svm_avg) * 100.0;
    let svm_over_lru = if lru_avg > 0.0 {
        (lru_avg - svm_avg) / lru_avg * 100.0
    } else {
        0.0
    };
    (lru_impr, svm_impr, svm_over_lru)
}

/// Render the Fig 5 series as a table.
pub fn render(points: &[WorkloadPoint]) -> Table {
    let mut t = Table::new(vec![
        "workload",
        "H-NoCache (s)",
        "H-LRU (norm)",
        "H-SVM-LRU (norm)",
        "LRU hits",
        "SVM-LRU hits",
    ]);
    for p in points {
        t.add_row(vec![
            p.name.to_string(),
            fmt_f(p.nocache_s, 1),
            fmt_f(p.lru_norm, 4),
            fmt_f(p.svm_lru_norm, 4),
            fmt_f(p.lru_hit_ratio, 3),
            fmt_f(p.svm_hit_ratio, 3),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_math() {
        let points = vec![
            WorkloadPoint {
                name: "W1",
                nocache_s: 100.0,
                lru_norm: 0.9,
                svm_lru_norm: 0.8,
                lru_hit_ratio: 0.3,
                svm_hit_ratio: 0.4,
            },
            WorkloadPoint {
                name: "W2",
                nocache_s: 100.0,
                lru_norm: 0.86,
                svm_lru_norm: 0.88,
                lru_hit_ratio: 0.3,
                svm_hit_ratio: 0.4,
            },
        ];
        let (lru_impr, svm_impr, over) = summary(&points);
        assert!((lru_impr - 12.0).abs() < 1e-9);
        assert!((svm_impr - 16.0).abs() < 1e-9);
        assert!(over > 4.0 && over < 5.0);
    }
}
