//! Long-running cluster simulation on the discrete-event engine: Poisson
//! job arrivals over shared datasets, periodic DataNode heartbeats with
//! cache reports, online retraining, optional failure injection and
//! prefetching — the "operate it like a cluster" driver behind
//! `repro simulate`.

use anyhow::Result;

use crate::config::{ClusterConfig, SvmConfig};
use crate::coordinator::CacheCoordinator;
use crate::mapreduce::{FailureModel, HistoryServer, JobId, JobRun, Scheduler};
use crate::sim::{Engine, SimDuration, SimTime};
use crate::util::bytes::GB;
use crate::util::rng::Pcg64;
use crate::workload::{Cluster, ALL_APPS};

use super::common::{make_coordinator, Scenario};

/// Simulation scenario parameters.
#[derive(Debug, Clone)]
pub struct SimulateConfig {
    /// Jobs to run before stopping.
    pub n_jobs: usize,
    /// Mean seconds between job arrivals (Poisson process).
    pub mean_interarrival_s: f64,
    /// Shared datasets jobs draw their inputs from.
    pub n_datasets: usize,
    /// Bytes per dataset.
    pub dataset_bytes: u64,
    /// Task/node failure injection model.
    pub failures: FailureModel,
    /// Prefetch depth (0 = off).
    pub prefetch_depth: u32,
    /// Seed for arrivals, placement and failure draws.
    pub seed: u64,
}

impl Default for SimulateConfig {
    fn default() -> Self {
        SimulateConfig {
            n_jobs: 24,
            mean_interarrival_s: 20.0,
            n_datasets: 3,
            dataset_bytes: 4 * GB,
            failures: FailureModel::none(),
            prefetch_depth: 0,
            seed: 20230101,
        }
    }
}

/// Simulation outcome.
#[derive(Debug)]
pub struct SimulateReport {
    /// Every job that ran, in completion order.
    pub completed: Vec<JobRun>,
    /// Job-history records accumulated for retrospective labeling.
    pub history_records: usize,
    /// Cache request hit ratio over the whole simulation.
    pub hit_ratio: f64,
    /// Cache byte hit ratio over the whole simulation.
    pub byte_hit_ratio: f64,
    /// DataNode heartbeats delivered.
    pub heartbeats: u64,
    /// Stale cache-metadata entries repaired from heartbeat reports.
    pub metadata_fixes: usize,
    /// Online (re)trainings the coordinator ran.
    pub trainings: u64,
    /// Task attempts that failed and were retried.
    pub failed_attempts: u64,
    /// Speculative/zombie attempts killed by the scheduler.
    pub killed_attempts: u64,
    /// Simulated clock at the end of the run.
    pub sim_end: SimTime,
    /// Events the DES engine fired.
    pub events_fired: u64,
    /// Fraction of prefetched blocks later hit (None when prefetch off).
    pub prefetch_useful: Option<f64>,
}

struct SimState {
    coordinator: CacheCoordinator,
    cfg: ClusterConfig,
    history: HistoryServer,
    completed: Vec<JobRun>,
    rng: Pcg64,
    datasets: Vec<Vec<crate::hdfs::BlockId>>,
    failures: FailureModel,
    jobs_started: usize,
    n_jobs: usize,
    heartbeats: u64,
    metadata_fixes: usize,
    hb_interval: SimDuration,
    mean_interarrival_s: f64,
}

impl SimState {
    fn start_job(&mut self, engine: &mut Engine<SimState>) {
        let id = JobId(self.jobs_started as u64);
        self.jobs_started += 1;
        let app = *self.rng.choose(&ALL_APPS);
        let blocks = self.rng.choose(&self.datasets).clone();
        let spec = app.job(id, blocks);
        let scheduler = Scheduler::new(&self.cfg).with_failures(self.failures.clone());
        let now = engine.now();
        let run = scheduler
            .run_jobs(&[spec], &mut self.coordinator, now)
            .pop()
            .expect("one job run");
        // Completion is an event so heartbeats interleave deterministically.
        let finish = run.finish;
        engine.schedule_at(finish.max(now), move |_, st: &mut SimState| {
            st.history.ingest(&run);
            st.completed.push(run);
        });
    }
}

/// Run the scenario; `scenario` picks the replacement policy.
pub fn run(
    cluster_cfg: &ClusterConfig,
    scenario: &Scenario,
    svm_cfg: &SvmConfig,
    sim_cfg: &SimulateConfig,
) -> Result<SimulateReport> {
    let mut cluster = Cluster::provision(cluster_cfg);
    let mut datasets = Vec::new();
    for i in 0..sim_cfg.n_datasets.max(1) {
        let fid = cluster.add_input(&format!("dataset/{i}"), sim_cfg.dataset_bytes);
        datasets.push(cluster.namenode.files.blocks_of(fid).to_vec());
    }
    let mut coordinator = make_coordinator(cluster, scenario, svm_cfg)?;
    if sim_cfg.prefetch_depth > 0 {
        coordinator = coordinator.with_prefetch(sim_cfg.prefetch_depth);
    }
    let cfg = coordinator.cluster.cfg.clone();
    let mut state = SimState {
        coordinator,
        cfg,
        history: HistoryServer::new(),
        completed: Vec::new(),
        rng: Pcg64::new(sim_cfg.seed, 0x51AA),
        datasets,
        failures: sim_cfg.failures.clone(),
        jobs_started: 0,
        n_jobs: sim_cfg.n_jobs,
        heartbeats: 0,
        metadata_fixes: 0,
        hb_interval: SimDuration::from_secs_f64(cluster_cfg.heartbeat_interval_s),
        mean_interarrival_s: sim_cfg.mean_interarrival_s.max(1e-3),
    };

    let mut engine: Engine<SimState> = Engine::new();

    // Heartbeat loop: cache reports reconcile NameNode metadata (paper
    // §4.1 "piggybacking cache and uncached commands on the heartbeat").
    fn heartbeat(engine: &mut Engine<SimState>, st: &mut SimState) {
        st.heartbeats += 1;
        st.metadata_fixes += st.coordinator.process_cache_reports();
        // Keep beating while work remains (arrivals or completions pending).
        if st.jobs_started < st.n_jobs || engine.pending() > 0 {
            engine.schedule_in(st.hb_interval, heartbeat);
        }
    }
    engine.schedule_in(state.hb_interval, heartbeat);

    // Poisson arrivals.
    fn arrival(engine: &mut Engine<SimState>, st: &mut SimState) {
        st.start_job(engine);
        if st.jobs_started < st.n_jobs {
            let gap = st.rng.gen_exp(1.0 / st.mean_interarrival_s);
            engine.schedule_in(SimDuration::from_secs_f64(gap), arrival);
        }
    }
    engine.schedule_at(SimTime::ZERO, arrival);

    engine.run(&mut state);

    let stats = state.coordinator.stats;
    Ok(SimulateReport {
        history_records: state.history.len(),
        hit_ratio: stats.hit_ratio(),
        byte_hit_ratio: stats.byte_hit_ratio(),
        heartbeats: state.heartbeats,
        metadata_fixes: state.metadata_fixes,
        trainings: state.coordinator.pipeline.trainings,
        failed_attempts: state.completed.iter().map(|r| r.failed_attempts).sum(),
        killed_attempts: state.completed.iter().map(|r| r.killed_attempts).sum(),
        sim_end: engine.now(),
        events_fired: engine.events_fired(),
        prefetch_useful: state.coordinator.prefetch_stats().map(|_| {
            // usefulness needs the prefetcher itself; expose via stats
            state
                .coordinator
                .prefetch_stats()
                .map(|s| {
                    if s.inserted == 0 {
                        0.0
                    } else {
                        s.useful_hits as f64 / s.inserted as f64
                    }
                })
                .unwrap_or(0.0)
        }),
        completed: state.completed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn svm_rust() -> SvmConfig {
        SvmConfig { backend: "rust".into(), ..Default::default() }
    }

    #[test]
    fn simulation_completes_all_jobs() {
        let cfg = ClusterConfig { datanodes: 3, replication: 2, ..Default::default() };
        let sim = SimulateConfig { n_jobs: 8, ..Default::default() };
        let report = run(&cfg, &Scenario::Policy("lru".into()), &svm_rust(), &sim).unwrap();
        assert_eq!(report.completed.len(), 8);
        assert!(report.heartbeats > 0, "heartbeats must fire");
        assert!(report.history_records >= 8 * 7);
        assert!(report.sim_end > SimTime::ZERO);
        assert!(report.events_fired > 8);
        assert!(report.hit_ratio > 0.0, "repeat jobs over shared datasets hit");
    }

    #[test]
    fn failure_injection_produces_retries_and_still_finishes() {
        let cfg = ClusterConfig { datanodes: 3, replication: 2, ..Default::default() };
        let sim = SimulateConfig {
            n_jobs: 6,
            failures: FailureModel::with_rates(0.15, 0.05, 99),
            ..Default::default()
        };
        let report = run(&cfg, &Scenario::Policy("lru".into()), &svm_rust(), &sim).unwrap();
        assert_eq!(report.completed.len(), 6);
        assert!(
            report.failed_attempts + report.killed_attempts > 0,
            "15%/5% rates must produce some failures"
        );
        // Every job still completed all tasks despite retries.
        for job in &report.completed {
            assert_eq!(job.maps_completed(), job.spec.n_maps());
        }
    }

    #[test]
    fn svm_scenario_trains_online_during_simulation() {
        let cfg = ClusterConfig { datanodes: 3, replication: 2, ..Default::default() };
        let sim = SimulateConfig { n_jobs: 12, seed: 5, ..Default::default() };
        let report = run(&cfg, &Scenario::SvmLru, &svm_rust(), &sim).unwrap();
        assert_eq!(report.completed.len(), 12);
        assert!(report.trainings > 0, "online retraining should trigger");
    }

    #[test]
    fn prefetching_reports_usefulness() {
        let cfg = ClusterConfig { datanodes: 3, replication: 2, ..Default::default() };
        let sim = SimulateConfig { n_jobs: 10, prefetch_depth: 2, seed: 7, ..Default::default() };
        let report = run(&cfg, &Scenario::Policy("lru".into()), &svm_rust(), &sim).unwrap();
        let usefulness = report.prefetch_useful.expect("prefetcher enabled");
        assert!((0.0..=1.0).contains(&usefulness));
    }

    #[test]
    fn sharded_simulation_completes_and_stays_consistent() {
        let cfg = ClusterConfig {
            datanodes: 3,
            replication: 2,
            cache_shards: 4,
            ..Default::default()
        };
        let sim = SimulateConfig { n_jobs: 8, ..Default::default() };
        let report = run(&cfg, &Scenario::Policy("lru".into()), &svm_rust(), &sim).unwrap();
        assert_eq!(report.completed.len(), 8);
        assert_eq!(report.metadata_fixes, 0, "sharded caches must not drift metadata");
        assert!(report.hit_ratio > 0.0);
    }

    #[test]
    fn tinylfu_admission_simulation_completes_and_stays_consistent() {
        let cfg = ClusterConfig {
            datanodes: 3,
            replication: 2,
            cache_admission: "tinylfu".into(),
            ..Default::default()
        };
        let sim = SimulateConfig { n_jobs: 8, ..Default::default() };
        let report = run(&cfg, &Scenario::Policy("lru".into()), &svm_rust(), &sim).unwrap();
        assert_eq!(report.completed.len(), 8);
        assert_eq!(report.metadata_fixes, 0, "admission must not drift metadata");
        // With identical arrivals the admission layer can only change cache
        // placement, never lose work.
        for job in &report.completed {
            assert_eq!(job.maps_completed(), job.spec.n_maps());
        }
    }

    #[test]
    fn svm_admission_simulation_trains_and_completes() {
        let cfg = ClusterConfig {
            datanodes: 3,
            replication: 2,
            cache_admission: "svm".into(),
            ..Default::default()
        };
        let sim = SimulateConfig { n_jobs: 12, seed: 5, ..Default::default() };
        // Plain LRU eviction + SVM admission: the classifier's second
        // deployment point must run end to end on the fallback backend.
        let report = run(&cfg, &Scenario::Policy("lru".into()), &svm_rust(), &sim).unwrap();
        assert_eq!(report.completed.len(), 12);
        assert!(report.trainings > 0, "svm admission must train the classifier");
    }

    #[test]
    fn deterministic_for_seed() {
        let cfg = ClusterConfig { datanodes: 3, replication: 2, ..Default::default() };
        let sim = SimulateConfig { n_jobs: 6, ..Default::default() };
        let a = run(&cfg, &Scenario::Policy("lru".into()), &svm_rust(), &sim).unwrap();
        let b = run(&cfg, &Scenario::Policy("lru".into()), &svm_rust(), &sim).unwrap();
        assert_eq!(a.hit_ratio, b.hit_ratio);
        assert_eq!(a.sim_end, b.sim_end);
        assert_eq!(a.events_fired, b.events_fired);
    }
}
