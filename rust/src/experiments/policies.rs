//! Policy-comparison ablation (beyond the paper): every implemented
//! replacement strategy from the Table 1 survey replayed over the same
//! Fig 3 trace — hit ratio, byte hit ratio and evictions side by side.

use anyhow::Result;

use crate::cache::registry::POLICY_NAMES;
use crate::config::SvmConfig;
use crate::util::bytes::MB;
use crate::util::table::{fmt_f, Table};

use super::common::{make_coordinator, replay_trace_two_pass, Scenario};

/// One policy's trace-replay result.
#[derive(Debug, Clone)]
pub struct PolicyResult {
    /// Replacement policy replayed (registry name).
    pub policy: String,
    /// Measured-pass request hit ratio.
    pub hit_ratio: f64,
    /// Measured-pass byte hit ratio.
    pub byte_hit_ratio: f64,
    /// Evictions over both replay passes.
    pub evictions: u64,
}

/// Replay the Fig 3 trace over every registered policy.
pub fn run(svm_cfg: &SvmConfig, seed: u64, cache_blocks: u64) -> Result<Vec<PolicyResult>> {
    let block_size = 64 * MB;
    let trace = crate::workload::fig3_trace(block_size, seed);
    let mut out = Vec::new();
    for &name in POLICY_NAMES {
        let (_cfg, cluster) =
            super::common::provision_fig3_cluster(block_size, cache_blocks, seed);
        let scenario = if name == "h-svm-lru" {
            Scenario::SvmLru
        } else {
            Scenario::Policy(name.to_string())
        };
        let mut coord = make_coordinator(cluster, &scenario, svm_cfg)?;
        let hit_ratio = replay_trace_two_pass(&mut coord, &trace)?;
        out.push(PolicyResult {
            policy: name.to_string(),
            hit_ratio,
            byte_hit_ratio: coord.stats.byte_hit_ratio(),
            evictions: coord.stats.evictions,
        });
    }
    out.sort_by(|a, b| b.hit_ratio.partial_cmp(&a.hit_ratio).unwrap());
    Ok(out)
}

/// Render the policy comparison as a table (best hit ratio first).
pub fn render(results: &[PolicyResult]) -> Table {
    let mut t = Table::new(vec!["policy", "hit ratio", "byte hit ratio", "evictions"]);
    for r in results {
        t.add_row(vec![
            r.policy.clone(),
            fmt_f(r.hit_ratio, 4),
            fmt_f(r.byte_hit_ratio, 4),
            r.evictions.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_covers_every_policy() {
        let svm_cfg = SvmConfig { backend: "rust".into(), ..Default::default() };
        let results = run(&svm_cfg, 3, 8).unwrap();
        assert_eq!(results.len(), POLICY_NAMES.len());
        for r in &results {
            assert!(
                (0.0..=1.0).contains(&r.hit_ratio),
                "{}: bad hit ratio {}",
                r.policy,
                r.hit_ratio
            );
        }
        // Sorted descending.
        for w in results.windows(2) {
            assert!(w[0].hit_ratio >= w[1].hit_ratio);
        }
    }
}
