//! Fig 6 — per-application normalized run time inside each workload under
//! H-SVM-LRU (normalized to the same app in the H-NoCache run).
//!
//! Paper shape: I/O-intensive apps (Grep, Sort) improve most; multi-stage
//! Join benefits least (its later stages read the previous stage's output,
//! which input caching cannot serve).

use std::collections::HashMap;

use anyhow::Result;

use crate::config::{ClusterConfig, SvmConfig};
use crate::util::table::{fmt_f, Table};
use crate::workload::WORKLOADS;

use super::common::{run_workload, Scenario};

/// Normalized per-app run times for one workload.
#[derive(Debug, Clone)]
pub struct AppBreakdown {
    /// Workload name ("W1".."W6").
    pub workload: &'static str,
    /// (app name with position suffix when repeated, normalized run time)
    pub apps: Vec<(String, f64)>,
}

/// Run the Fig 6 breakdown over all six workloads.
pub fn run(svm_cfg: &SvmConfig, seed: u64, scale: f64) -> Result<Vec<AppBreakdown>> {
    WORKLOADS
        .iter()
        .map(|def| {
            // Average each app's normalized time over several seeded runs
            // (the paper's five repetitions).
            let mut acc: Vec<(String, f64)> = Vec::new();
            let runs_per_point = super::fig5::RUNS_PER_POINT;
            for s in 0..runs_per_point {
                let cfg = ClusterConfig { seed: seed + s, ..Default::default() };
                let nocache = run_workload(def, &cfg, &Scenario::NoCache, svm_cfg, scale)?;
                let svm = run_workload(def, &cfg, &Scenario::SvmLru, svm_cfg, scale)?;
                let mut seen: HashMap<String, usize> = HashMap::new();
                for (i, (base, with_svm)) in
                    nocache.runs.iter().zip(&svm.runs).enumerate()
                {
                    let n = seen.entry(base.spec.app.clone()).or_insert(0);
                    *n += 1;
                    let label = if *n > 1 {
                        format!("{}#{n}", base.spec.app)
                    } else {
                        base.spec.app.clone()
                    };
                    let norm = with_svm.execution_time().as_secs_f64()
                        / base.execution_time().as_secs_f64().max(1e-9);
                    if s == 0 {
                        acc.push((label, norm));
                    } else {
                        acc[i].1 += norm;
                    }
                }
            }
            for (_, v) in acc.iter_mut() {
                *v /= runs_per_point as f64;
            }
            Ok(AppBreakdown { workload: def.name, apps: acc })
        })
        .collect()
}

/// Mean normalized run time per application name across workloads.
pub fn per_app_means(points: &[AppBreakdown]) -> Vec<(String, f64)> {
    let mut acc: HashMap<String, (f64, usize)> = HashMap::new();
    for bd in points {
        for (name, norm) in &bd.apps {
            let base = name.split('#').next().unwrap_or(name).to_string();
            let e = acc.entry(base).or_insert((0.0, 0));
            e.0 += norm;
            e.1 += 1;
        }
    }
    let mut out: Vec<(String, f64)> = acc
        .into_iter()
        .map(|(k, (sum, n))| (k, sum / n as f64))
        .collect();
    out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    out
}

/// Render the Fig 6 breakdown as a table.
pub fn render(points: &[AppBreakdown]) -> Table {
    let mut t = Table::new(vec!["workload", "application", "normalized run time"]);
    for bd in points {
        for (app, norm) in &bd.apps {
            t.add_row(vec![bd.workload.to_string(), app.clone(), fmt_f(*norm, 4)]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_app_means_aggregates_suffixed_names() {
        let points = vec![
            AppBreakdown {
                workload: "W3",
                apps: vec![
                    ("Grep".to_string(), 0.8),
                    ("Grep#2".to_string(), 0.6),
                    ("Sort".to_string(), 0.9),
                ],
            },
        ];
        let means = per_app_means(&points);
        let grep = means.iter().find(|(n, _)| n == "Grep").unwrap();
        assert!((grep.1 - 0.7).abs() < 1e-12);
        // Sorted ascending: best improvement first.
        assert_eq!(means[0].0, "Grep");
    }
}
